//! Figure 2: CWY vs explicit sequential Householder reflections — identical
//! numerics, very different wall time as L grows.
//!
//! Times a T-step rollout artifact for each L and verifies the two methods'
//! outputs agree to float tolerance (the "numerically equivalent" half of
//! the paper's claim).

use cwy::report::{Series, Table};
use cwy::runtime::Engine;
use cwy::util::timing::bench;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open("artifacts")?;
    let ls = [4usize, 8, 16, 32, 64];

    let mut table = Table::new(&["L", "CWY ms", "HR ms", "HR/CWY", "max |diff|"]);
    let mut series = Series::new("fig2_cwy_vs_hr", &["l", "cwy_ms", "hr_ms"]);

    for &l in &ls {
        let cwy_art = engine.load(&format!("rollout_cwy_l{l}"))?;
        let hr_art = engine.load(&format!("rollout_hr_l{l}"))?;

        // Both artifacts embed the same example inputs in the manifest specs;
        // regenerate them identically (seed 0, matching aot.py).
        let spec = &cwy_art.spec;
        let v_shape = spec.inputs[0].shape.clone();
        let h_shape = spec.inputs[1].shape.clone();
        let v = pseudo_randn(&v_shape, 0);
        let h = pseudo_randn(&h_shape, 1);

        let inputs = vec![v, h];
        let out_cwy = cwy_art.run(&inputs)?;
        let out_hr = hr_art.run(&inputs)?;
        let diff = out_cwy[0]
            .as_f32()?
            .iter()
            .zip(out_hr[0].as_f32()?)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);

        let s_cwy = bench("cwy", 2, 0.3, || {
            cwy_art.run(&inputs).expect("cwy");
        });
        let s_hr = bench("hr", 2, 0.3, || {
            hr_art.run(&inputs).expect("hr");
        });
        println!(
            "L={l:<4} cwy {:.3} ms   hr {:.3} ms   ratio {:.1}x   diff {diff:.2e}",
            s_cwy.mean_ms(),
            s_hr.mean_ms(),
            s_hr.mean_s / s_cwy.mean_s
        );
        table.row(&[
            l.to_string(),
            format!("{:.3}", s_cwy.mean_ms()),
            format!("{:.3}", s_hr.mean_ms()),
            format!("{:.1}x", s_hr.mean_s / s_cwy.mean_s),
            format!("{diff:.2e}"),
        ]);
        series.push(&[l as f64, s_cwy.mean_ms(), s_hr.mean_ms()]);
    }

    println!("\n## Figure 2 (rollout time vs L; N=64, T=32, CPU-PJRT)\n");
    print!("{}", table.to_markdown());
    let path = series.save(std::path::Path::new("reports"))?;
    println!("\nseries -> {}", path.display());
    Ok(())
}

/// Deterministic pseudo-normal tensor (same for both artifacts).
fn pseudo_randn(shape: &[usize], seed: u64) -> cwy::runtime::HostTensor {
    let mut rng = cwy::util::rng::Pcg32::seeded(seed + 1234);
    let n: usize = shape.iter().product();
    cwy::runtime::HostTensor::f32(shape.to_vec(), rng.normal_vec(n, 1.0))
}
