//! Table 4: video-prediction step time across recurrent-unit designs.
//!
//! Complements `examples/video_prediction.rs` (which reports the per-class
//! l1 table): here we measure the per-step cost and the parameter-count
//! ratio the paper highlights (ConvNERU ~4.5x fewer params than ConvLSTM).

use cwy::coordinator::{Schedule, Trainer};
use cwy::data::video::VideoTask;
use cwy::report::Table;
use cwy::runtime::{Engine, HostTensor};
use cwy::util::timing::stats;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open("artifacts")?;
    let methods = ["convneru_tcwy", "convneru_own", "convneru_free",
                   "convneru_zeros", "convlstm"];
    let steps = 20usize;

    let mut table = Table::new(&["METHOD", "ms/step", "l1 @20 steps", "PARAMS"]);
    let mut params_by_method = Vec::new();

    for method in methods {
        let name = format!("video_{method}_step");
        if engine.manifest.get(&name).is_err() {
            continue;
        }
        let mut trainer = Trainer::new(&engine, &name, Schedule::Constant(1e-3))?;
        let spec = trainer.artifact.spec.clone();
        let batch: usize = spec.meta_str("batch").unwrap().parse()?;
        let t: usize = spec.meta_str("t").unwrap().parse()?;
        let hw: usize = spec.meta_str("hw").unwrap().parse()?;
        let mut gen = VideoTask::new(hw, t, batch, 3);

        let mut times = Vec::new();
        let mut last_l1 = f32::NAN;
        for _ in 0..steps {
            let frames = gen.batch_mixed();
            let data = vec![HostTensor::f32(vec![batch, t, hw, hw, 1], frames)];
            let t0 = std::time::Instant::now();
            let (loss, _) = trainer.train_step(data)?;
            times.push(t0.elapsed().as_secs_f64());
            last_l1 = loss;
        }
        let s = stats(&name, &times[1..]);
        let params: f64 = spec
            .meta_str("param_count")
            .and_then(|p| p.parse().ok())
            .unwrap_or(f64::NAN);
        params_by_method.push((method, params));
        println!("{name}: {ms:.3} ms/step, l1 {last_l1:.2}, params {params}",
                 ms = s.mean_ms());
        table.row(&[
            method.to_string(),
            format!("{:.3}", s.mean_ms()),
            format!("{last_l1:.2}"),
            format!("{params}"),
        ]);
    }

    println!("\n## Table 4 (step cost; CPU-PJRT)\n");
    print!("{}", table.to_markdown());

    // The paper's parameter-ratio claim.
    let lstm = params_by_method.iter().find(|(m, _)| *m == "convlstm");
    let neru = params_by_method.iter().find(|(m, _)| *m == "convneru_tcwy");
    if let (Some((_, pl)), Some((_, pn))) = (lstm, neru) {
        println!("\nConvLSTM/ConvNERU parameter ratio: {:.2}x (paper: ~4.5x)",
                 pl / pn);
    }
    Ok(())
}
