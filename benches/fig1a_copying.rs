//! Figure 1a / Figure 4a: copying-task convergence curves across methods.
//!
//! Short-horizon version of `examples/copying_task.rs` sized for `cargo
//! bench`: trains each method for a fixed budget and reports where the loss
//! sits relative to the no-memory baseline 10 log8/(T+20).  `--long` runs
//! the Fig. 4a variant (longer horizon).

use cwy::coordinator::{Schedule, Trainer};
use cwy::data::copying::CopyTask;
use cwy::report::Table;
use cwy::runtime::{Engine, HostTensor};
use cwy::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", if args.has_flag("long") { 150 } else { 80 });
    let engine = Engine::open("artifacts")?;
    let methods = ["cwy", "hr", "exprnn", "scornn", "lstm", "rnn"];

    let mut table = Table::new(&["METHOD", "final loss", "vs baseline", "acc", "ms/step"]);
    for method in methods {
        let name = format!("copy_{method}_step");
        if engine.manifest.get(&name).is_err() {
            continue;
        }
        let mut trainer = Trainer::new(&engine, &name, Schedule::Constant(1e-3))?;
        let spec = trainer.artifact.spec.clone();
        let t_blank: usize = spec.meta_str("t_blank").unwrap().parse()?;
        let batch: usize = spec.meta_str("batch").unwrap().parse()?;
        let mut task = CopyTask::new(t_blank, batch, 0);
        let baseline = task.baseline_ce();

        for _ in 0..steps {
            let b = task.next_batch();
            trainer.train_step(vec![
                HostTensor::i32(vec![b.batch, b.t_total], b.tokens),
                HostTensor::i32(vec![b.batch, b.t_total], b.targets),
            ])?;
        }
        let h = &trainer.history;
        let final_loss = h.recent_mean_loss(10).unwrap();
        let acc = h.records.last().unwrap().metrics[0];
        let ms = h.total_wall_s() / steps as f64 * 1e3;
        println!("{method}: loss {final_loss:.4} (baseline {baseline:.4}), acc {acc:.3}, {ms:.2} ms/step");
        table.row(&[
            method.to_uppercase(),
            format!("{final_loss:.4}"),
            format!("{:+.4}", final_loss - baseline),
            format!("{acc:.3}"),
            format!("{ms:.2}"),
        ]);
    }
    println!("\n## Figure 1a (copying task @ {steps} steps; negative 'vs baseline' beats it)\n");
    print!("{}", table.to_markdown());
    Ok(())
}
