//! End-to-end native training step of the copy-task RNN (`rnn_copy`
//! family): forward rollout + exact BPTT + in-place SGD apply, timed as
//! one unit — the op the trainer hot loop and `cwy train --backend
//! native` actually execute per step.
//!
//! Two variants isolate what the ISSUE 5 substrate buys at the full-step
//! level:
//!
//! * **workspace** — reused [`RolloutWorkspace`]: zero heap allocations
//!   at steady state (pinned by `tests/alloc_discipline`);
//! * **fresh** — the same math through a throwaway workspace per step,
//!   i.e. the allocation profile the pre-ISSUE-5 path paid.
//!
//!   cargo bench --bench rollout_e2e                  # default sweep
//!   cargo bench --bench rollout_e2e -- --smoke --json BENCH_5.json

use std::sync::atomic::{AtomicUsize, Ordering};

use cwy::linalg::{parallel_for, pool_workers, set_thread_cap, Matrix};
use cwy::report::{BenchJson, Table};
use cwy::runtime::native::ops_rnn::{
    forward_backward_ws, CopyBatchRef, CopyRnnParams, RolloutWorkspace, IN_VOCAB, OUT_CLASSES,
};
use cwy::runtime::native::CellKind;
use cwy::telemetry::span_delta;
use cwy::util::cli::Args;
use cwy::util::rng::Pcg32;
use cwy::util::timing::{bench, bench_n, BenchStats};

struct Setup {
    params: CopyRnnParams,
    tokens: Vec<i32>,
    targets: Vec<i32>,
    batch: usize,
    t_total: usize,
}

fn setup(seed: u64, l: usize, n: usize, b: usize, t: usize) -> Setup {
    let mut rng = Pcg32::seeded(seed);
    let params = CopyRnnParams {
        v: Matrix::random_normal(&mut rng, l, n, 1.0),
        w_in: Matrix::random_normal(&mut rng, IN_VOCAB, n, 0.3),
        w_out: Matrix::random_normal(&mut rng, n, OUT_CLASSES, 0.3),
        b_out: Matrix::random_normal(&mut rng, 1, OUT_CLASSES, 0.1),
    };
    let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(IN_VOCAB as u32) as i32).collect();
    let targets: Vec<i32> = (0..b * t).map(|_| rng.below(OUT_CLASSES as u32) as i32).collect();
    Setup { params, tokens, targets, batch: b, t_total: t }
}

impl Setup {
    fn data(&self) -> CopyBatchRef<'_> {
        CopyBatchRef {
            tokens: &self.tokens,
            targets: &self.targets,
            batch: self.batch,
            t_total: self.t_total,
        }
    }
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has_flag("smoke");
    // (L, N, B, T): the middle row matches the bptt_native acceptance
    // configuration; the copy task itself runs T = t_blank + 20.
    let shapes: Vec<(usize, usize, usize, usize)> = if smoke {
        vec![(16, 64, 4, 8)]
    } else {
        vec![(16, 64, 16, 84), (64, 128, 16, 64), (64, 256, 32, 84)]
    };
    let timed = |name: &str, f: &mut dyn FnMut()| -> BenchStats {
        if smoke {
            bench_n(name, 1, 1, f)
        } else {
            bench(name, 1, 0.5, f)
        }
    };

    println!("# rollout_e2e: full rnn_copy training step (forward + BPTT + SGD), param=cwy\n");
    let mut json = BenchJson::new("rollout_e2e");
    let mut table = Table::new(&[
        "L", "N", "B", "T", "step ms (workspace)", "step ms (fresh)", "ws speedup", "eval ms",
    ]);
    // Operand-cache effectiveness across every training row below
    // (ISSUE 9): each tape recompute is 4 misses, each timestep's packed
    // gemms are hits, so a healthy run sits near 1000 milli.
    let telemetry = cwy::telemetry::global();
    let (hits0, misses0) = (telemetry.pack_hits(), telemetry.pack_misses());
    for &(l, n, b, t) in &shapes {
        let mut s = setup((l * 131 + n) as u64, l, n, b, t);
        let mut rws = RolloutWorkspace::new();
        // Warm the workspace (and validate the data path) once.
        forward_backward_ws(CellKind::Cwy, &s.params, &s.data(), true, &mut rws).unwrap();

        let s_ws = timed("train_step_ws", &mut || {
            let data = CopyBatchRef {
                tokens: &s.tokens,
                targets: &s.targets,
                batch: s.batch,
                t_total: s.t_total,
            };
            forward_backward_ws(CellKind::Cwy, &s.params, &data, true, &mut rws).unwrap();
            s.params.sgd_step(rws.grads(), 1e-3);
            std::hint::black_box(&s.params);
        });
        let s_fresh = timed("train_step_fresh", &mut || {
            let mut fresh = RolloutWorkspace::new();
            let data = CopyBatchRef {
                tokens: &s.tokens,
                targets: &s.targets,
                batch: s.batch,
                t_total: s.t_total,
            };
            forward_backward_ws(CellKind::Cwy, &s.params, &data, true, &mut fresh).unwrap();
            s.params.sgd_step(fresh.grads(), 1e-3);
            std::hint::black_box(&s.params);
        });
        let s_eval = timed("eval_forward", &mut || {
            let data = CopyBatchRef {
                tokens: &s.tokens,
                targets: &s.targets,
                batch: s.batch,
                t_total: s.t_total,
            };
            let loss =
                forward_backward_ws(CellKind::Cwy, &s.params, &data, false, &mut rws).unwrap();
            std::hint::black_box(loss);
        });
        let speedup = s_fresh.median_s / s_ws.median_s.max(1e-12);
        println!(
            "L={l:<3} N={n:<4} B={b:<3} T={t:<3} step {:>9.3} ms (fresh {:>9.3} ms, {speedup:.2}x)   eval {:>9.3} ms",
            s_ws.median_ms(),
            s_fresh.median_ms(),
            s_eval.median_ms()
        );
        table.row(&[
            l.to_string(),
            n.to_string(),
            b.to_string(),
            t.to_string(),
            format!("{:.3}", s_ws.median_ms()),
            format!("{:.3}", s_fresh.median_ms()),
            format!("{speedup:.2}x"),
            format!("{:.3}", s_eval.median_ms()),
        ]);
        json.push(&format!("train_step_l{l}_n{n}_b{b}_t{t}"), s_ws.median_ns());
        json.push(&format!("train_step_fresh_l{l}_n{n}_b{b}_t{t}"), s_fresh.median_ns());
        json.push(&format!("eval_forward_l{l}_n{n}_b{b}_t{t}"), s_eval.median_ns());

        // Thread-scaling rows: the same workspace step with the gemm
        // band-parallelism capped at 1/2/4 threads.  Band partitioning
        // never changes per-element arithmetic, so these rows measure
        // scaling only; small shapes sit under the parallel cutoff and
        // legitimately report flat numbers.
        for cap in [1usize, 2, 4] {
            set_thread_cap(cap);
            let s_cap = timed(&format!("train_step_threads{cap}"), &mut || {
                let data = CopyBatchRef {
                    tokens: &s.tokens,
                    targets: &s.targets,
                    batch: s.batch,
                    t_total: s.t_total,
                };
                forward_backward_ws(CellKind::Cwy, &s.params, &data, true, &mut rws).unwrap();
                s.params.sgd_step(rws.grads(), 1e-3);
                std::hint::black_box(&s.params);
            });
            println!(
                "L={l:<3} N={n:<4} B={b:<3} T={t:<3} step {:>9.3} ms @ {cap} thread(s)",
                s_cap.median_ms()
            );
            json.push(
                &format!("train_step_l{l}_n{n}_b{b}_t{t}_threads{cap}"),
                s_cap.median_ns(),
            );
        }
        set_thread_cap(0); // back to the hardware default for the sidecars

        // Telemetry sidecar: span attribution of one representative
        // step/eval (rollout_forward + bptt_backward + sgd_step, with the
        // nested gemm-variant spans counted flat alongside them).
        for (span, ns) in span_delta(|| {
            let data = CopyBatchRef {
                tokens: &s.tokens,
                targets: &s.targets,
                batch: s.batch,
                t_total: s.t_total,
            };
            forward_backward_ws(CellKind::Cwy, &s.params, &data, true, &mut rws).unwrap();
            s.params.sgd_step(rws.grads(), 1e-3);
        }) {
            json.push_phase(&format!("train_step_l{l}_n{n}_b{b}_t{t}"), span, ns as f64);
        }
        for (span, ns) in span_delta(|| {
            let data = CopyBatchRef {
                tokens: &s.tokens,
                targets: &s.targets,
                batch: s.batch,
                t_total: s.t_total,
            };
            forward_backward_ws(CellKind::Cwy, &s.params, &data, false, &mut rws).unwrap();
        }) {
            json.push_phase(&format!("eval_forward_l{l}_n{n}_b{b}_t{t}"), span, ns as f64);
        }
    }
    let (hits, misses) =
        (telemetry.pack_hits() - hits0, telemetry.pack_misses() - misses0);
    let hit_rate_milli = if hits + misses == 0 { 0 } else { hits * 1000 / (hits + misses) };
    json.push("pack_cache_hit_rate_milli", hit_rate_milli as f64);
    println!(
        "\npack cache: {hits} hits / {misses} misses ({hit_rate_milli} milli) over all rows"
    );

    // Pool-scaling acceptance shape (ISSUE 9): large enough that every
    // apply/backward gemm clears PARALLEL_FLOP_CUTOFF, run in smoke AND
    // full so `cwy bench-check` can gate threads4 >= 1.8x threads1 on
    // multi-core hosts.  Medians of 3 iterations keep the smoke rows
    // stable enough to gate on.
    {
        let (l, n, b, t) = (64usize, 256usize, 32usize, 16usize);
        let mut s = setup(0x5CA1E, l, n, b, t);
        let mut rws = RolloutWorkspace::new();
        forward_backward_ws(CellKind::Cwy, &s.params, &s.data(), true, &mut rws).unwrap();
        for cap in [1usize, 4] {
            set_thread_cap(cap);
            let s_cap = bench_n(&format!("scaling_train_step_threads{cap}"), 1, 3, || {
                let data = CopyBatchRef {
                    tokens: &s.tokens,
                    targets: &s.targets,
                    batch: s.batch,
                    t_total: s.t_total,
                };
                forward_backward_ws(CellKind::Cwy, &s.params, &data, true, &mut rws).unwrap();
                s.params.sgd_step(rws.grads(), 1e-3);
                std::hint::black_box(&s.params);
            });
            println!(
                "scaling L={l} N={n} B={b} T={t} step {:>9.3} ms @ {cap} thread(s)",
                s_cap.median_ms()
            );
            json.push(&format!("scaling_train_step_threads{cap}"), s_cap.median_ns());
        }
        set_thread_cap(0);
    }

    // Dispatch overhead head-to-head: 100 eight-band fan-outs through
    // the persistent pool vs the pre-ISSUE-9 `thread::scope` spawn/join
    // per dispatch.  Bodies are trivial on purpose — this measures the
    // handoff, not the kernel.
    let s_pool = timed("pool_dispatch_bands8", &mut || {
        for _ in 0..100 {
            let ran = AtomicUsize::new(0);
            parallel_for(8, &|_| {
                ran.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(ran.load(Ordering::Relaxed), 8);
        }
    });
    let s_scope = timed("scoped_spawn_bands8", &mut || {
        for _ in 0..100 {
            let ran = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                for _ in 0..8 {
                    scope.spawn(|| {
                        ran.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
            assert_eq!(ran.load(Ordering::Relaxed), 8);
        }
    });
    json.push("pool_dispatch_bands8", s_pool.median_ns());
    json.push("scoped_spawn_bands8", s_scope.median_ns());
    println!(
        "dispatch x100 (8 bands): pool {:>9.3} ms, scoped spawn {:>9.3} ms ({:.2}x), {} pool worker(s), {} pool tasks, {} steals",
        s_pool.median_ms(),
        s_scope.median_ms(),
        s_scope.median_s / s_pool.median_s.max(1e-12),
        pool_workers(),
        telemetry.pool_tasks(),
        telemetry.pool_steals(),
    );
    // Only emitted with live workers: bench-check treats a measured 0.0
    // as a hard failure, and a single-core host legitimately has none.
    if pool_workers() > 0 {
        json.push("pool_workers", pool_workers() as f64);
    }

    println!("\n## rnn_copy end-to-end training step (f32, param=cwy)\n");
    print!("{}", table.to_markdown());
    if let Some(path) = args.get("json") {
        json.merge_write(path).expect("writing bench json");
        println!(
            "\n# medians merged into {}",
            BenchJson::resolve_trajectory_path(path).display()
        );
    }
}
