//! Native-backend GEMM: the transpose-aware packed kernel (`linalg::gemm`)
//! vs the naive reference triple loop and the frozen PR-4 tiled kernel
//! (`gemm::legacy`).  The gemm is the hot path under every native-op
//! execution (CWY construction, rollouts, BPTT, linreg SGD), so the
//! numbers here bound native serve/train throughput; the NT/TN rows
//! additionally measure what transpose awareness saves over the
//! materialize-then-multiply pattern the substrate replaced.
//!
//!   cargo bench --bench gemm_native                    # default size sweep
//!   cargo bench --bench gemm_native -- --max-n 1024
//!   cargo bench --bench gemm_native -- --smoke --json BENCH_5.json
//!
//! `--smoke` runs every kernel once at one size (CI keeps the kernels
//! from rotting); `--json PATH` merges median ns/op per kernel into the
//! perf-trajectory file (`report::BenchJson`).

use cwy::linalg::gemm::{self, legacy, matmul_blocked, matmul_naive, KernelKind};
use cwy::linalg::Matrix;
use cwy::report::{BenchJson, Table};
use cwy::telemetry::span_delta;
use cwy::util::cli::Args;
use cwy::util::rng::Pcg32;
use cwy::util::timing::{bench_n, BenchStats};

fn main() {
    let args = Args::from_env();
    let smoke = args.has_flag("smoke");
    let max_n = args.get_usize("max-n", 512);
    let sizes: Vec<usize> = if smoke {
        // Both SIMD-acceptance sizes by default (the bench-check ratio
        // gate reads n=128 and n=256); `--n` narrows to one size.
        match args.get("n") {
            Some(n) => vec![n.parse().expect("--n")],
            None => vec![128, 256],
        }
    } else {
        [64usize, 128, 192, 256, 384, 512, 768, 1024]
            .into_iter()
            .filter(|&n| n <= max_n)
            .collect()
    };
    // Adaptive iteration counts sized off a 0.2 s budget, or exactly one
    // iteration in smoke mode.
    let timed = |name: &str, budget_s: f64, f: &mut dyn FnMut()| -> BenchStats {
        if smoke {
            bench_n(name, 1, 1, f)
        } else {
            cwy::util::timing::bench(name, 1, budget_s, f)
        }
    };

    let mut json = BenchJson::new("gemm_native");
    let mut table = Table::new(&["N", "kernel", "median ms", "vs naive"]);
    println!(
        "# gemm_native: f32 GEMM kernels (NN square + NT/TN transpose-aware); \
         dispatched microkernel: {}\n",
        gemm::active_kernel().name()
    );
    for &n in &sizes {
        let mut rng = Pcg32::seeded(n as u64);
        let a = Matrix::random_normal(&mut rng, n, n, 1.0);
        let b = Matrix::random_normal(&mut rng, n, n, 1.0);

        // Parity first: a bench that measures the wrong answer is noise.
        // (Only the NN-vs-naive diff is computed here; the TN/NT/beta=1
        // variants are pinned bitwise by the linalg::gemm property tests,
        // so no per-variant number is printed that was not measured.)
        let diff = matmul_blocked(&a, &b).max_abs_diff(&matmul_naive(&a, &b));
        assert!(diff < 1e-3 * n as f32, "N={n}: NN kernels disagree by {diff}");

        let s_naive = timed("naive", 0.2, &mut || {
            std::hint::black_box(matmul_naive(&a, &b));
        });
        let s_legacy = timed("legacy", 0.2, &mut || {
            std::hint::black_box(legacy::matmul(&a, &b));
        });
        let s_nn = timed("gemm_nn", 0.2, &mut || {
            std::hint::black_box(matmul_blocked(&a, &b));
        });
        // The portable strip kernel, pinned regardless of what the host
        // dispatches — the trajectory file then carries both points, so
        // the SIMD delta is measured on one machine, not across CI hosts.
        let mut portable_out = Matrix::zeros(n, n);
        let s_portable = timed("portable_nn", 0.2, &mut || {
            gemm::gemm_with(
                KernelKind::Portable,
                false,
                false,
                1.0,
                &a,
                &b,
                0.0,
                &mut portable_out,
            );
            std::hint::black_box(&portable_out);
        });

        // Transpose-aware paths vs the PR-4 materialize-then-multiply
        // pattern they replace (`x.t().matmul(y)` / `x.matmul(&y.t())`).
        let mut out = Matrix::zeros(n, n);
        let s_tn = timed("gemm_tn", 0.2, &mut || {
            gemm::gemm(true, false, 1.0, &a, &b, 0.0, &mut out);
            std::hint::black_box(&out);
        });
        let s_tn_mat = timed("materialized_tn", 0.2, &mut || {
            std::hint::black_box(legacy::matmul(&a.t(), &b));
        });
        let s_nt = timed("gemm_nt", 0.2, &mut || {
            gemm::gemm(false, true, 1.0, &a, &b, 0.0, &mut out);
            std::hint::black_box(&out);
        });
        let s_nt_mat = timed("materialized_nt", 0.2, &mut || {
            std::hint::black_box(legacy::matmul(&a, &b.t()));
        });
        // Fused accumulation vs allocate-product-then-add.
        let mut acc = Matrix::zeros(n, n);
        let s_fused = timed("gemm_nn_beta1", 0.2, &mut || {
            gemm::gemm(false, false, 1.0, &a, &b, 1.0, &mut acc);
            std::hint::black_box(&acc);
        });
        let s_addmm = timed("add_of_product", 0.2, &mut || {
            acc = acc.add(&legacy::matmul(&a, &b));
            std::hint::black_box(&acc);
        });

        let rows: [(&str, &BenchStats); 9] = [
            ("naive", &s_naive),
            ("legacy (PR-4)", &s_legacy),
            ("gemm NN", &s_nn),
            ("portable NN", &s_portable),
            ("gemm TN", &s_tn),
            ("materialized TN", &s_tn_mat),
            ("gemm NT", &s_nt),
            ("materialized NT", &s_nt_mat),
            ("gemm NN beta=1", &s_fused),
            // add_of_product reported via println below (not vs-naive
            // comparable; it includes the allocating add pass)
        ];
        for (label, s) in rows {
            let speedup = s_naive.median_s / s.median_s.max(1e-12);
            table.row(&[
                n.to_string(),
                label.to_string(),
                format!("{:.3}", s.median_ms()),
                format!("{speedup:.2}x"),
            ]);
        }
        println!(
            "N={n:<5} naive {:>8.3} ms  legacy {:>8.3} ms  NN {:>8.3} ms  \
             TN {:>8.3}/{:>8.3} ms  NT {:>8.3}/{:>8.3} ms  beta1 {:>8.3} ms \
             (add-of-product {:>8.3} ms, NN diff {diff:.2e})",
            s_naive.median_ms(),
            s_legacy.median_ms(),
            s_nn.median_ms(),
            s_tn.median_ms(),
            s_tn_mat.median_ms(),
            s_nt.median_ms(),
            s_nt_mat.median_ms(),
            s_fused.median_ms(),
            s_addmm.median_ms(),
        );

        json.push(&format!("gemm_nn_n{n}"), s_nn.median_ns());
        json.push(&format!("portable_nn_n{n}"), s_portable.median_ns());
        json.push(&format!("gemm_tn_n{n}"), s_tn.median_ns());
        json.push(&format!("gemm_nt_n{n}"), s_nt.median_ns());
        json.push(&format!("gemm_nn_beta1_n{n}"), s_fused.median_ns());
        json.push(&format!("legacy_nn_n{n}"), s_legacy.median_ns());
        json.push(&format!("naive_nn_n{n}"), s_naive.median_ns());

        // Telemetry sidecar: one extra representative run per
        // instrumented kernel, attributed by span (the naive/legacy
        // kernels predate the span set and contribute nothing).
        for (span, ns) in span_delta(|| {
            std::hint::black_box(matmul_blocked(&a, &b));
        }) {
            json.push_phase(&format!("gemm_nn_n{n}"), span, ns as f64);
        }
        for (span, ns) in span_delta(|| {
            gemm::gemm(true, false, 1.0, &a, &b, 0.0, &mut out);
        }) {
            json.push_phase(&format!("gemm_tn_n{n}"), span, ns as f64);
        }
        for (span, ns) in span_delta(|| {
            gemm::gemm(false, true, 1.0, &a, &b, 0.0, &mut out);
        }) {
            json.push_phase(&format!("gemm_nt_n{n}"), span, ns as f64);
        }
        for (span, ns) in span_delta(|| {
            gemm::gemm(false, false, 1.0, &a, &b, 1.0, &mut acc);
        }) {
            json.push_phase(&format!("gemm_nn_beta1_n{n}"), span, ns as f64);
        }
    }
    println!("\n## GEMM kernels (f32; median of adaptive runs)\n");
    print!("{}", table.to_markdown());
    if let Some(path) = args.get("json") {
        json.merge_write(path).expect("writing bench json");
        println!(
            "\n# medians merged into {}",
            BenchJson::resolve_trajectory_path(path).display()
        );
    }
}
