//! Native-backend GEMM: blocked/cache-tiled/multithreaded kernel vs the
//! naive reference triple loop (`linalg::gemm`).  The blocked kernel is
//! the hot path under every native-op execution (CWY construction,
//! rollouts, linreg SGD), so the speedup here bounds native serve/train
//! throughput.
//!
//!   cargo bench --bench gemm_native            # default size sweep
//!   cargo bench --bench gemm_native -- --max-n 1024

use cwy::linalg::gemm::{matmul_blocked, matmul_naive};
use cwy::linalg::Matrix;
use cwy::report::Table;
use cwy::util::cli::Args;
use cwy::util::rng::Pcg32;
use cwy::util::timing::bench;

fn main() {
    let args = Args::from_env();
    let max_n = args.get_usize("max-n", 512);
    let sizes: Vec<usize> = [64usize, 128, 192, 256, 384, 512, 768, 1024]
        .into_iter()
        .filter(|&n| n <= max_n)
        .collect();

    let mut table = Table::new(&["N", "naive ms", "blocked ms", "speedup", "max |diff|"]);
    println!("# gemm_native: square f32 GEMM, naive vs blocked+threaded\n");
    for &n in &sizes {
        let mut rng = Pcg32::seeded(n as u64);
        let a = Matrix::random_normal(&mut rng, n, n, 1.0);
        let b = Matrix::random_normal(&mut rng, n, n, 1.0);

        // Parity first: a bench that measures the wrong answer is noise.
        let diff = matmul_blocked(&a, &b).max_abs_diff(&matmul_naive(&a, &b));
        assert!(diff < 1e-3 * n as f32, "N={n}: kernels disagree by {diff}");

        let s_naive = bench("naive", 1, 0.2, || {
            std::hint::black_box(matmul_naive(&a, &b));
        });
        let s_blocked = bench("blocked", 1, 0.2, || {
            std::hint::black_box(matmul_blocked(&a, &b));
        });
        let speedup = s_naive.mean_s / s_blocked.mean_s.max(1e-12);
        println!(
            "N={n:<5} naive {:>9.3} ms   blocked {:>9.3} ms   {speedup:.2}x   diff {diff:.2e}",
            s_naive.mean_ms(),
            s_blocked.mean_ms()
        );
        table.row(&[
            n.to_string(),
            format!("{:.3}", s_naive.mean_ms()),
            format!("{:.3}", s_blocked.mean_ms()),
            format!("{speedup:.2}x"),
            format!("{diff:.2e}"),
        ]);
    }
    println!("\n## GEMM kernels (f32, square N)\n");
    print!("{}", table.to_markdown());
}
