//! Figure 1b / Figure 4b: pixel-by-pixel digit classification accuracy.
//!
//! Trains CWY and LSTM on the procedural pixel-digit stream (196-step pixel
//! sequences) and reports accuracy; `--permuted` applies the fixed pixel
//! permutation (the Fig. 4b variant).

use cwy::coordinator::{Schedule, Trainer};
use cwy::data::digits::DigitTask;
use cwy::report::Table;
use cwy::runtime::{Engine, HostTensor};
use cwy::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 120);
    let permuted = args.has_flag("permuted");
    let engine = Engine::open("artifacts")?;
    let methods = ["cwy", "lstm"];

    let mut table = Table::new(&["METHOD", "final loss", "train acc", "ms/step"]);
    for method in methods {
        let name = format!("smnist_{method}_step");
        if engine.manifest.get(&name).is_err() {
            continue;
        }
        let mut trainer = Trainer::new(&engine, &name, Schedule::Constant(1e-3))?;
        let spec = trainer.artifact.spec.clone();
        let batch: usize = spec.meta_str("batch").unwrap().parse()?;
        let t: usize = spec.meta_str("t").unwrap().parse()?;
        let mut task = DigitTask::new(batch, 0, permuted);

        for _ in 0..steps {
            let b = task.next_batch();
            trainer.train_step(vec![
                HostTensor::f32(vec![batch, t], b.pixels),
                HostTensor::i32(vec![batch], b.labels),
            ])?;
        }
        let h = &trainer.history;
        // accuracy averaged over the last 10 steps
        let tail = &h.records[h.records.len().saturating_sub(10)..];
        let acc: f32 = tail.iter().map(|r| r.metrics[0]).sum::<f32>() / tail.len() as f32;
        let ms = h.total_wall_s() / steps as f64 * 1e3;
        println!("{method}: loss {:.4}, acc {acc:.3}, {ms:.2} ms/step",
                 h.recent_mean_loss(10).unwrap());
        table.row(&[
            method.to_uppercase(),
            format!("{:.4}", h.recent_mean_loss(10).unwrap()),
            format!("{acc:.3}"),
            format!("{ms:.2}"),
        ]);
    }
    println!(
        "\n## Figure 1b ({}pixel-by-pixel digits @ {steps} steps)\n",
        if permuted { "permuted " } else { "" }
    );
    print!("{}", table.to_markdown());
    Ok(())
}
