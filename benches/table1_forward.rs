//! Table 1: forward-pass cost across orthogonal-RNN methods.
//!
//! Prints (a) the paper's analytical complexity rows evaluated at the
//! benchmark's (T, N, L) and (b) measured wall time of the AOT forward
//! rollout artifacts for each method and N.

use cwy::orthogonal::flops;
use cwy::report::Table;
use cwy::runtime::{Engine, HostTensor};
use cwy::util::rng::Pcg32;
use cwy::util::timing::bench;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open("artifacts")?;
    let methods = ["rnn", "cwy", "hr", "exprnn", "scornn"];
    let sizes = [64usize, 128];
    let (t_steps, l) = (32usize, 32usize);

    // Analytical rows (paper Table 1), evaluated at the measured scale.
    println!("## Table 1 — analytical (T={t_steps}, N=128, L={l})\n");
    let mut t1 = Table::new(&["METHOD", "SERIAL", "PARALLEL", "DOMAIN", "FLOPs"]);
    for r in flops::table1(t_steps, 128, l) {
        t1.row(&[
            r.method.to_string(),
            r.serial.to_string(),
            r.parallel.to_string(),
            r.domain.to_string(),
            format!("{:.2e}", r.flops),
        ]);
    }
    print!("{}", t1.to_markdown());

    // Measured rows.
    println!("\n## Table 1 — measured forward rollout (T={t_steps}, B=16, CPU-PJRT)\n");
    let mut tm = Table::new(&["METHOD", "N=64 ms", "N=128 ms"]);
    for method in methods {
        let mut cells = vec![method.to_uppercase()];
        for &n in &sizes {
            let name = format!("fwd_{method}_n{n}");
            let art = match engine.load(&name) {
                Ok(a) => a,
                Err(_) => {
                    cells.push("-".into());
                    continue;
                }
            };
            let inputs: Vec<HostTensor> = art
                .spec
                .inputs
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let mut rng = Pcg32::seeded(i as u64 + 9);
                    let count: usize = s.shape.iter().product();
                    HostTensor::f32(s.shape.clone(), rng.normal_vec(count, 0.5))
                })
                .collect();
            let stats = bench(&name, 2, 0.3, || {
                art.run(&inputs).expect("run");
            });
            cells.push(format!("{:.3}", stats.mean_ms()));
            println!("{name}: {:.3} ms", stats.mean_ms());
        }
        tm.row(&cells);
    }
    print!("{}", tm.to_markdown());
    Ok(())
}
