//! Table 3: NMT step time and short-horizon perplexity across methods.
//!
//! The full training comparison lives in `examples/nmt.rs`; this bench
//! isolates the per-step cost (the paper's TIME column) so the CWY-vs-
//! orthogonal-baseline speed ordering is directly measurable.

use cwy::coordinator::{Schedule, Trainer};
use cwy::data::corpus::CorpusGen;
use cwy::report::Table;
use cwy::runtime::{Engine, HostTensor};
use cwy::util::timing::stats;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open("artifacts")?;
    let methods = ["cwy_l16", "cwy_l32", "cwy_l64", "rnn", "gru", "lstm",
                   "scornn", "exprnn"];
    let steps = 30usize;

    let mut table = Table::new(&["MODEL", "ms/step", "PP @30 steps", "PARAMS"]);
    for method in methods {
        let name = format!("nmt_{method}_step");
        if engine.manifest.get(&name).is_err() {
            continue;
        }
        let mut trainer = Trainer::new(&engine, &name, Schedule::Constant(2e-3))?;
        let spec = trainer.artifact.spec.clone();
        let batch: usize = spec.meta_str("batch").unwrap().parse()?;
        let ts: usize = spec.meta_str("ts").unwrap().parse()?;
        let tt: usize = spec.meta_str("tt").unwrap().parse()?;
        let mut gen = CorpusGen::new(5);

        let mut times = Vec::new();
        let mut last_pp = f32::NAN;
        for _ in 0..steps {
            let b = gen.batch(batch, ts, tt);
            let data = vec![
                HostTensor::i32(vec![batch, ts], b.src),
                HostTensor::i32(vec![batch, tt], b.tgt_in),
                HostTensor::i32(vec![batch, tt], b.tgt_out),
            ];
            let t0 = std::time::Instant::now();
            let (_, m) = trainer.train_step(data)?;
            times.push(t0.elapsed().as_secs_f64());
            last_pp = m[0];
        }
        // Skip the first (compile-warm) step in the mean.
        let s = stats(&name, &times[1..]);
        println!("{name}: {:.3} ms/step, pp {last_pp:.3}", s.mean_ms());
        table.row(&[
            method.to_uppercase(),
            format!("{:.3}", s.mean_ms()),
            format!("{last_pp:.3}"),
            spec.meta_str("param_count").unwrap_or("-").to_string(),
        ]);
    }

    println!("\n## Table 3 (step time + early PP; CPU-PJRT)\n");
    print!("{}", table.to_markdown());
    Ok(())
}
