//! Figure 1c: parametrization construction time — CWY vs matrix exponential
//! vs Cayley map across matrix sizes N.
//!
//! The paper's claim: CWY is 1-3 orders of magnitude faster on parallel
//! hardware.  On CPU-PJRT the gap is narrower (no GPU batched solves),
//! but the ordering CWY < Cayley < expm must hold and widen with N.

use cwy::report::{Series, Table};
use cwy::runtime::{Engine, HostTensor};
use cwy::util::rng::Pcg32;
use cwy::util::timing::bench;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open("artifacts")?;
    let sizes = [64usize, 128, 256, 512];
    let methods = ["cwy", "expm", "cayley"];

    let mut table = Table::new(&["N", "CWY ms", "expm ms", "Cayley ms",
                                 "expm/CWY", "Cayley/CWY"]);
    let mut series = Series::new("fig1c_param_time", &["n", "cwy_ms", "expm_ms", "cayley_ms"]);

    for &n in &sizes {
        let mut times = Vec::new();
        for method in methods {
            let name = format!("param_{method}_n{n}");
            let art = match engine.load(&name) {
                Ok(a) => a,
                Err(_) => {
                    eprintln!("missing {name}");
                    times.push(f64::NAN);
                    continue;
                }
            };
            let mut rng = Pcg32::seeded(n as u64);
            let input = HostTensor::f32(vec![n, n], rng.normal_vec(n * n, 1.0));
            let stats = bench(&name, 2, 0.4, || {
                art.run(std::slice::from_ref(&input)).expect("run");
            });
            times.push(stats.mean_ms());
        }
        println!(
            "N={n:<5} cwy {:.3} ms   expm {:.3} ms   cayley {:.3} ms",
            times[0], times[1], times[2]
        );
        table.row(&[
            n.to_string(),
            format!("{:.3}", times[0]),
            format!("{:.3}", times[1]),
            format!("{:.3}", times[2]),
            format!("{:.1}x", times[1] / times[0]),
            format!("{:.1}x", times[2] / times[0]),
        ]);
        series.push(&[n as f64, times[0], times[1], times[2]]);
    }

    println!("\n## Figure 1c (construction time, CPU-PJRT)\n");
    print!("{}", table.to_markdown());
    let path = series.save(std::path::Path::new("reports"))?;
    println!("\nseries -> {}", path.display());
    Ok(())
}
