//! Closed-loop serve benchmark at production concurrency (ISSUE 8): an
//! in-process `cwy serve` event loop driven by the session harness —
//! thousands of logical sessions multiplexed over pipelined connections,
//! each keeping one request in flight.
//!
//! What it measures (and commits into the BENCH_8 trajectory):
//!
//! * `closed_loop_p50_ns` / `closed_loop_p99_ns` — client-observed
//!   round-trip latency under full concurrency;
//! * `mean_occupancy_milli` — mean rows per fused execution x1000
//!   (occupancy is the whole point of continuous batching: requests
//!   arriving while workers are busy coalesce into the next batch).
//!
//! The run hard-fails unless every request is answered exactly once —
//! the bench doubles as the 10k-session acceptance run.
//!
//!   cargo bench --bench serve_load                   # 10k sessions
//!   cargo bench --bench serve_load -- --smoke --json BENCH_8.json

use std::sync::Arc;

use cwy::report::{BenchJson, Table};
use cwy::serve::{
    run_sessions, serve, AdmissionCfg, BatchCfg, FakeModel, ModelFactory, ServeCfg, ServeModel,
    SessionCfg, SessionLoadCfg,
};
use cwy::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let smoke = args.has_flag("smoke");
    let sessions = if smoke { 200 } else { args.get_usize("sessions", 10_000) };
    let rounds = if smoke { 2 } else { args.get_usize("rounds", 3) };
    let conns = if smoke { 16 } else { args.get_usize("conns", 128) };
    let workers = args.get_usize("workers", 2);

    let fake_batch = 32usize;
    let factory: Arc<ModelFactory> = Arc::new(move || {
        Ok(Box::new(FakeModel::new(fake_batch, 16, 100)) as Box<dyn ServeModel>)
    });
    let cfg = ServeCfg {
        addr: "127.0.0.1:0".to_string(),
        workers,
        batch: BatchCfg {
            max_batch: fake_batch,
            max_wait_us: 1_000,
            queue_cap: 65_536,
            continuous: true,
        },
        session: SessionCfg { capacity: (2 * sessions).max(1_024), ..SessionCfg::default() },
        admission: AdmissionCfg {
            max_connections: conns + 16,
            ..AdmissionCfg::default()
        },
        ..ServeCfg::default()
    };
    let server = serve(cfg, factory).expect("starting in-process server");
    let addr = server.local_addr().to_string();

    println!(
        "# serve_load: {sessions} sessions x {rounds} rounds over {conns} connections \
         ({workers} workers, continuous batching) -> {addr}\n"
    );
    let load = SessionLoadCfg {
        addr,
        sessions,
        rounds,
        conns,
        use_sessions: true,
        ..SessionLoadCfg::default()
    };
    let report = run_sessions(&load).expect("closed-loop run");
    server.stop();

    print!("{}", report.to_table().to_markdown());
    assert!(
        report.complete(),
        "closed-loop invariant violated: sent {} answered {} (unanswered {}, duplicates {}, \
         stray {}, conn failures {})",
        report.sent,
        report.answered(),
        report.unanswered,
        report.duplicates,
        report.stray,
        report.conn_failures
    );
    println!("\n# every request answered exactly once");

    let mut table = Table::new(&["metric", "value"]);
    table.row(&["p50 (us)".to_string(), report.lat_p50_us.to_string()]);
    table.row(&["p99 (us)".to_string(), report.lat_p99_us.to_string()]);
    table.row(&["mean occupancy".to_string(), format!("{:.2}", report.mean_batch)]);
    table.row(&["throughput (req/s)".to_string(), format!("{:.1}", report.rps())]);
    println!("\n## closed-loop latency and occupancy\n");
    print!("{}", table.to_markdown());

    let mut json = BenchJson::new("serve_load");
    // Latencies are measured in whole microseconds; clamp to 1ns so a
    // sub-microsecond p50 can never commit a 0.0 median (which
    // bench-check treats as "never measured").
    json.push("closed_loop_p50_ns", ((report.lat_p50_us * 1_000) as f64).max(1.0));
    json.push("closed_loop_p99_ns", ((report.lat_p99_us * 1_000) as f64).max(1.0));
    json.push("mean_occupancy_milli", (report.mean_batch * 1_000.0).max(1.0));
    if let Some(path) = args.get("json") {
        json.merge_write(path).expect("writing bench json");
        println!(
            "\n# medians merged into {}",
            BenchJson::resolve_trajectory_path(path).display()
        );
    }
}
