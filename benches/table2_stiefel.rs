//! Table 2: Stiefel-manifold step cost — T-CWY vs RGD variants vs OWN.
//!
//! Prints the paper's analytical FLOP rows at (N, M) = (256, 32) plus the
//! measured wall time of (a) the AOT step/construct artifacts and (b) the
//! native rust implementations, confirming T-CWY is the cheapest.

use cwy::linalg::{householder_qr, Matrix};
use cwy::orthogonal::{flops, own, rgd, tcwy};
use cwy::report::Table;
use cwy::runtime::{Engine, HostTensor};
use cwy::util::rng::Pcg32;
use cwy::util::timing::bench;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open("artifacts")?;
    let (n, m) = (256usize, 32usize);

    println!("## Table 2 — analytical FLOPs (N={n}, M={m})\n");
    let mut t2 = Table::new(&["APPROACH", "PARALLEL", "INVERTED", "FLOPs expr", "FLOPs"]);
    for r in flops::table2(n, m) {
        t2.row(&[
            r.method.to_string(),
            r.parallel.to_string(),
            r.inverted.to_string(),
            r.flops_expr.to_string(),
            format!("{:.2e}", r.flops),
        ]);
    }
    print!("{}", t2.to_markdown());

    // Measured: AOT artifacts.
    println!("\n## Table 2 — measured, AOT artifacts (CPU-PJRT)\n");
    let mut rng = Pcg32::seeded(0);
    let omega0 = householder_qr(&Matrix::random_normal(&mut rng, n, m, 1.0)).0;
    let grad = Matrix::random_normal(&mut rng, n, m, 0.1);
    let v = Matrix::random_normal(&mut rng, m, n, 1.0);
    let vn = Matrix::random_normal(&mut rng, n, m, 0.1);

    let mut ta = Table::new(&["ARTIFACT", "mean ms"]);
    let arts: Vec<(String, Vec<HostTensor>)> = vec![
        ("stiefel_tcwy_construct".into(),
         vec![HostTensor::f32(vec![m, n], v.data.clone())]),
        ("stiefel_own_construct".into(),
         vec![HostTensor::f32(vec![n, m], vn.data.clone())]),
        ("stiefel_rgd_cc_step".into(), rgd_inputs(&omega0, &grad)),
        ("stiefel_rgd_ec_step".into(), rgd_inputs(&omega0, &grad)),
        ("stiefel_rgd_cqr_step".into(), rgd_inputs(&omega0, &grad)),
        ("stiefel_rgd_eqr_step".into(), rgd_inputs(&omega0, &grad)),
    ];
    for (name, inputs) in &arts {
        match engine.load(name) {
            Ok(art) => {
                let stats = bench(name, 2, 0.3, || {
                    art.run(inputs).expect("run");
                });
                println!("{name}: {:.3} ms", stats.mean_ms());
                ta.row(&[name.clone(), format!("{:.3}", stats.mean_ms())]);
            }
            Err(_) => {
                ta.row(&[name.clone(), "-".into()]);
            }
        }
    }
    print!("{}", ta.to_markdown());

    // Measured: native rust implementations.
    println!("\n## Table 2 — measured, native rust\n");
    let mut tn = Table::new(&["METHOD", "mean ms"]);
    let entries: Vec<(&str, Box<dyn Fn() + '_>)> = vec![
        ("T-CWY construct", Box::new(|| {
            std::hint::black_box(tcwy::matrix(&v));
        })),
        ("OWN construct", Box::new(|| {
            std::hint::black_box(own::matrix(&vn));
        })),
        ("RGD-C-C step", Box::new(|| {
            std::hint::black_box(rgd::step(&omega0, &grad, 0.1, rgd::Inner::Canonical, rgd::Retraction::Cayley));
        })),
        ("RGD-E-C step", Box::new(|| {
            std::hint::black_box(rgd::step(&omega0, &grad, 0.1, rgd::Inner::Euclidean, rgd::Retraction::Cayley));
        })),
        ("RGD-C-QR step", Box::new(|| {
            std::hint::black_box(rgd::step(&omega0, &grad, 0.1, rgd::Inner::Canonical, rgd::Retraction::Qr));
        })),
        ("RGD-E-QR step", Box::new(|| {
            std::hint::black_box(rgd::step(&omega0, &grad, 0.1, rgd::Inner::Euclidean, rgd::Retraction::Qr));
        })),
    ];
    for (name, f) in entries {
        let stats = bench(name, 1, 0.3, || f());
        println!("{name}: {:.3} ms", stats.mean_ms());
        tn.row(&[name.to_string(), format!("{:.3}", stats.mean_ms())]);
    }
    print!("{}", tn.to_markdown());
    Ok(())
}

fn rgd_inputs(omega: &Matrix, grad: &Matrix) -> Vec<HostTensor> {
    vec![
        HostTensor::f32(vec![omega.rows, omega.cols], omega.data.clone()),
        HostTensor::f32(vec![grad.rows, grad.cols], grad.data.clone()),
        HostTensor::scalar_f32(0.1),
    ]
}
