//! Gradient-path timing over a T-step rollout `h_{t+1} = h_t Q(V) + x_t`:
//!
//! * **fused** — this PR's zero-allocation, transpose-aware BPTT
//!   (`cwy_rollout_backward`: in-place apply-backward, pooled scratch,
//!   fused beta=1 accumulation);
//! * **PR-4** — the frozen allocating implementation
//!   (`backward::reference`): fresh `Vec` per matmul, materialized
//!   transposes, legacy tiled kernel.  The fused/PR-4 ratio is ISSUE 5's
//!   acceptance number (≥ 1.5× at N=128, L=64, T=64, B=16).  Under the
//!   portable microkernel the two paths agree **bitwise**, so the ratio
//!   measures structure only; under avx2+fma the fused side additionally
//!   banks the SIMD speedup and parity is asserted within f32 headroom;
//! * **sequential HR** — the per-Householder chain (Table 1's serial
//!   baseline, unchanged since PR 4).
//!
//!   cargo bench --bench bptt_native                 # default sweep
//!   cargo bench --bench bptt_native -- --max-n 256 --t 64
//!   cargo bench --bench bptt_native -- --smoke --json BENCH_5.json

use cwy::linalg::gemm::{self, KernelKind};
use cwy::linalg::Matrix;
use cwy::orthogonal::backward::{cwy_rollout_backward, hr_rollout_backward, reference};
use cwy::report::{BenchJson, Table};
use cwy::telemetry::span_delta;
use cwy::util::cli::Args;
use cwy::util::rng::Pcg32;
use cwy::util::timing::{bench, bench_n, BenchStats};

fn main() {
    let args = Args::from_env();
    let smoke = args.has_flag("smoke");
    let max_n = args.get_usize("max-n", 256);
    let t = args.get_usize("t", if smoke { 8 } else { 64 });
    let b = args.get_usize("b", if smoke { 4 } else { 16 });
    let shapes: Vec<(usize, usize)> = if smoke {
        vec![(64, 16)]
    } else {
        [(64usize, 8usize), (128, 16), (128, 64), (256, 32)]
            .into_iter()
            .filter(|&(n, _)| n <= max_n)
            .collect()
    };
    let timed = |name: &str, f: &mut dyn FnMut()| -> BenchStats {
        if smoke {
            bench_n(name, 1, 1, f)
        } else {
            bench(name, 1, 0.3, f)
        }
    };

    println!(
        "# bptt_native: BPTT through h_{{t+1}} = h_t Q(V) + x_t, T={t}, B={b}; \
         dispatched microkernel: {}\n",
        gemm::active_kernel().name()
    );
    let mut json = BenchJson::new("bptt_native");
    let mut table = Table::new(&[
        "N",
        "L",
        "fused ms",
        "PR-4 ms",
        "vs PR-4",
        "sequential HR ms",
        "vs HR",
        "max |dV diff|",
    ]);
    for &(n, l) in &shapes {
        let mut rng = Pcg32::seeded((n * 31 + l) as u64);
        let v = Matrix::random_normal(&mut rng, l, n, 1.0);
        let h0 = Matrix::random_normal(&mut rng, b, n, 1.0);
        let xs: Vec<Matrix> = (0..t)
            .map(|_| Matrix::random_normal(&mut rng, b, n, 0.3))
            .collect();
        let gs: Vec<Matrix> = (0..t)
            .map(|_| Matrix::random_normal(&mut rng, b, n, 0.3))
            .collect();

        // Parity first: a bench that measures different gradients is
        // noise.  Under the portable kernel fused vs PR-4 must agree
        // bitwise (shared accumulation order); under avx2+fma the fused
        // gemms group the reduction differently, so parity is f32-scaled.
        // Fused vs HR is always tolerance-based (genuinely different
        // algorithms).
        let (_, dv_fused) = cwy_rollout_backward(&v, &h0, &xs, &gs);
        let (_, dv_pr4) = reference::cwy_rollout_backward(&v, &h0, &xs, &gs);
        if gemm::active_kernel() == KernelKind::Portable {
            assert!(
                dv_fused
                    .data
                    .iter()
                    .zip(&dv_pr4.data)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "N={n} L={l}: fused BPTT drifted from the PR-4 reference \
                 (max |diff| {})",
                dv_fused.max_abs_diff(&dv_pr4)
            );
        } else {
            let scale = dv_pr4.data.iter().fold(1.0f32, |m, x| m.max(x.abs()));
            let d = dv_fused.max_abs_diff(&dv_pr4);
            assert!(
                d <= 3e-4 * scale,
                "N={n} L={l}: simd fused BPTT diverged from the PR-4 \
                 reference by {d} (scale {scale})"
            );
        }
        let (_, dv_hr) = hr_rollout_backward(&v, &h0, &xs, &gs);
        let scale = dv_hr.data.iter().fold(1.0f32, |m, x| m.max(x.abs()));
        let diff = dv_fused.max_abs_diff(&dv_hr);
        assert!(
            diff <= 3e-4 * scale,
            "N={n} L={l}: fused vs sequential dV diverge by {diff} (scale {scale})"
        );

        let s_fused = timed("fused", &mut || {
            std::hint::black_box(cwy_rollout_backward(&v, &h0, &xs, &gs));
        });
        let s_pr4 = timed("pr4", &mut || {
            std::hint::black_box(reference::cwy_rollout_backward(&v, &h0, &xs, &gs));
        });
        let s_hr = timed("sequential", &mut || {
            std::hint::black_box(hr_rollout_backward(&v, &h0, &xs, &gs));
        });
        let vs_pr4 = s_pr4.median_s / s_fused.median_s.max(1e-12);
        let vs_hr = s_hr.median_s / s_fused.median_s.max(1e-12);
        println!(
            "N={n:<4} L={l:<3} fused {:>9.3} ms   PR-4 {:>9.3} ms ({vs_pr4:.2}x)   \
             sequential {:>9.3} ms ({vs_hr:.2}x)   diff {diff:.2e}",
            s_fused.median_ms(),
            s_pr4.median_ms(),
            s_hr.median_ms()
        );
        table.row(&[
            n.to_string(),
            l.to_string(),
            format!("{:.3}", s_fused.median_ms()),
            format!("{:.3}", s_pr4.median_ms()),
            format!("{vs_pr4:.2}x"),
            format!("{:.3}", s_hr.median_ms()),
            format!("{vs_hr:.2}x"),
            format!("{diff:.2e}"),
        ]);
        json.push(&format!("rollout_bwd_fused_n{n}_l{l}"), s_fused.median_ns());
        json.push(&format!("rollout_bwd_pr4_n{n}_l{l}"), s_pr4.median_ns());
        json.push(&format!("rollout_bwd_hr_n{n}_l{l}"), s_hr.median_ns());
        // Telemetry sidecar: gemm-variant attribution of one fused
        // backward pass (the PR-4/HR paths run the uninstrumented legacy
        // kernel, so only the fused kernel has a phase breakdown).
        for (span, ns) in span_delta(|| {
            std::hint::black_box(cwy_rollout_backward(&v, &h0, &xs, &gs));
        }) {
            json.push_phase(&format!("rollout_bwd_fused_n{n}_l{l}"), span, ns as f64);
        }
        if !smoke && (n, l) == (128, 64) && t >= 64 && b >= 16 {
            println!(
                "#   acceptance (N=128, L=64, T={t}, B={b}): fused is {vs_pr4:.2}x \
                 the PR-4 implementation (target >= 1.5x)"
            );
            // ISSUE 5 acceptance, enforced mechanically on every full run
            // (smoke's 1-iteration medians are too noisy to judge;
            // --no-accept opts out for profiling oddly-loaded machines).
            assert!(
                args.has_flag("no-accept") || vs_pr4 >= 1.5,
                "fused rollout backward is only {vs_pr4:.2}x the PR-4 \
                 implementation at the acceptance shape (target >= 1.5x); \
                 rerun on an idle machine or pass --no-accept to bypass"
            );
        }
    }
    println!("\n## BPTT backward: fused vs PR-4 allocating vs sequential HR (f32)\n");
    print!("{}", table.to_markdown());
    if let Some(path) = args.get("json") {
        json.merge_write(path).expect("writing bench json");
        println!(
            "\n# medians merged into {}",
            BenchJson::resolve_trajectory_path(path).display()
        );
    }
}
