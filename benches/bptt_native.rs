//! Gradient-path timing: fused CWY BPTT vs the sequential
//! per-Householder backward over a T-step rollout — the Table 1 story,
//! now for training instead of inference.  Both differentiate the same
//! function (`orthogonal::backward` property tests pin the parity), so
//! the comparison is purely about the shape of the computation: the
//! fused path is a handful of (B,L)/(N,L) matmuls per step plus one
//! S-chain finish, while the HR chain walks L reflections serially at
//! every step, forward and backward.
//!
//!   cargo bench --bench bptt_native              # default sweep
//!   cargo bench --bench bptt_native -- --max-n 256 --t 64

use cwy::linalg::Matrix;
use cwy::orthogonal::backward::{cwy_rollout_backward, hr_rollout_backward};
use cwy::report::Table;
use cwy::util::cli::Args;
use cwy::util::rng::Pcg32;
use cwy::util::timing::bench;

fn main() {
    let args = Args::from_env();
    let max_n = args.get_usize("max-n", 256);
    let t = args.get_usize("t", 64);
    let b = args.get_usize("b", 4);
    let shapes: Vec<(usize, usize)> = [(64usize, 8usize), (128, 16), (256, 32), (512, 64)]
        .into_iter()
        .filter(|&(n, _)| n <= max_n)
        .collect();

    println!("# bptt_native: BPTT through h_{{t+1}} = h_t Q(V) + x_t, T={t}, B={b}\n");
    let mut table =
        Table::new(&["N", "L", "fused CWY ms", "sequential HR ms", "speedup", "max |dV diff|"]);
    for &(n, l) in &shapes {
        let mut rng = Pcg32::seeded((n * 31 + l) as u64);
        let v = Matrix::random_normal(&mut rng, l, n, 1.0);
        let h0 = Matrix::random_normal(&mut rng, b, n, 1.0);
        let xs: Vec<Matrix> = (0..t)
            .map(|_| Matrix::random_normal(&mut rng, b, n, 0.3))
            .collect();
        let gs: Vec<Matrix> = (0..t)
            .map(|_| Matrix::random_normal(&mut rng, b, n, 0.3))
            .collect();

        // Parity first: a bench that measures two different gradients is
        // noise.  Tolerance scales with the gradient magnitude (f32).
        let (_, dv_cwy) = cwy_rollout_backward(&v, &h0, &xs, &gs);
        let (_, dv_hr) = hr_rollout_backward(&v, &h0, &xs, &gs);
        let scale = dv_hr.data.iter().fold(1.0f32, |m, x| m.max(x.abs()));
        let diff = dv_cwy.max_abs_diff(&dv_hr);
        // Two genuinely different f32 algorithms over a T-step rollout:
        // allow rounding headroom beyond the short-rollout 1e-4 bound.
        assert!(
            diff <= 3e-4 * scale,
            "N={n} L={l}: fused vs sequential dV diverge by {diff} (scale {scale})"
        );

        let s_cwy = bench("fused", 1, 0.3, || {
            std::hint::black_box(cwy_rollout_backward(&v, &h0, &xs, &gs));
        });
        let s_hr = bench("sequential", 1, 0.3, || {
            std::hint::black_box(hr_rollout_backward(&v, &h0, &xs, &gs));
        });
        let speedup = s_hr.mean_s / s_cwy.mean_s.max(1e-12);
        println!(
            "N={n:<4} L={l:<3} fused {:>9.3} ms   sequential {:>9.3} ms   {speedup:.2}x   diff {diff:.2e}",
            s_cwy.mean_ms(),
            s_hr.mean_ms()
        );
        table.row(&[
            n.to_string(),
            l.to_string(),
            format!("{:.3}", s_cwy.mean_ms()),
            format!("{:.3}", s_hr.mean_ms()),
            format!("{speedup:.2}x"),
            format!("{diff:.2e}"),
        ]);
    }
    println!("\n## BPTT backward: fused CWY vs sequential Householder (f32)\n");
    print!("{}", table.to_markdown());
}
