//! Serve-path smoke benchmark: spin an in-process micro-batching server on
//! the fake backend and drive it with the closed-loop load client, then
//! print both client-side latency and server-side occupancy tables.
//!
//! Needs no artifacts, so it runs anywhere the crate builds:
//!
//!   cargo run --release --example serve_bench -- \
//!       --requests 2000 --concurrency 16 --workers 2 --max-batch 8

use std::sync::Arc;

use cwy::serve::{
    run_load, serve, BatchCfg, ClientCfg, FakeModel, ModelFactory, ServeCfg, ServeModel,
    SessionCfg,
};
use cwy::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let requests = args.get_usize("requests", 2_000);
    let concurrency = args.get_usize("concurrency", 16);
    let workers = args.get_usize("workers", 2);
    let max_batch = args.get_usize("max-batch", 8);
    let max_wait_us = args.get_usize("max-wait-us", 2_000) as u64;
    let delay_us = args.get_usize("fake-delay-us", 300) as u64;

    let factory: Arc<ModelFactory> = {
        let batch = max_batch;
        Arc::new(move || Ok(Box::new(FakeModel::new(batch, 16, delay_us)) as Box<dyn ServeModel>))
    };
    let server = serve(
        ServeCfg {
            addr: "127.0.0.1:0".to_string(),
            workers,
            batch: BatchCfg { max_batch, max_wait_us, queue_cap: 4_096 },
            session: SessionCfg::default(),
            lr: 0.0,
        },
        factory,
    )?;
    let addr = server.local_addr().to_string();
    println!(
        "# serve_bench: {requests} requests x {concurrency} connections -> {addr} \
         ({workers} workers, max-batch {max_batch}, max-wait {max_wait_us}us)"
    );

    let report = run_load(&ClientCfg {
        addr,
        requests,
        concurrency,
        deadline_us: None,
        use_sessions: args.has_flag("sessions"),
    })?;
    println!("\n## client\n");
    print!("{}", report.to_table().to_markdown());

    let snap = server.snapshot();
    println!("\n## server\n");
    print!("{}", snap.to_table().to_markdown());
    server.stop();

    anyhow::ensure!(report.dropped() == 0, "{} requests dropped", report.dropped());
    println!("\nserve_bench OK (mean server batch {:.2})", report.mean_batch);
    Ok(())
}
