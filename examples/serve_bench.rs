//! Serve-path smoke benchmark: spin an in-process micro-batching server
//! and drive it with the closed-loop load client, then print both
//! client-side latency and server-side occupancy tables.
//!
//! By default the server executes the toy CWY-cell artifact on the
//! **native** backend (DESIGN.md §2.6) — a real `Engine` →
//! `Compiled::run` request/response cycle with per-session recurrent
//! state, no Python AOT artifacts and no PJRT bindings needed.
//! `--backend fake` switches to the deterministic in-process model with
//! an artificial execution delay (useful for queueing experiments).
//!
//!   cargo run --release --example serve_bench -- \
//!       --requests 2000 --concurrency 16 --workers 2 [--backend native|fake]

use std::sync::Arc;

use cwy::runtime::fixture::TempDir;
use cwy::runtime::Backend;
use cwy::serve::{
    probe_serve_spec, run_load, serve, BatchCfg, ClientCfg, EngineModel, FakeModel,
    ModelFactory, ServeCfg, ServeModel,
};
use cwy::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let requests = args.get_usize("requests", 2_000);
    let concurrency = args.get_usize("concurrency", 16);
    let workers = args.get_usize("workers", 2);
    let mut max_batch = args.get_usize("max-batch", 8);
    let max_wait_us = args.get_usize("max-wait-us", 2_000) as u64;
    let backend = args.get_or("backend", "native");

    // Keeps the fixture directory alive until the run completes.
    let mut _fixture_guard: Option<TempDir> = None;
    let factory: Arc<ModelFactory> = match backend.as_str() {
        "native" => {
            let tmp = TempDir::with_toy_artifacts("serve-bench")?;
            let dir = tmp.path().display().to_string();
            _fixture_guard = Some(tmp);
            // The artifact's fused batch is the coalescing ceiling and the
            // default; an explicit smaller --max-batch is honored.
            let fused = probe_serve_spec(&dir, "toy_cell_step")?.0.batch;
            max_batch = match args.get("max-batch") {
                None => fused,
                Some(_) if max_batch > fused => {
                    println!("# --max-batch {max_batch} exceeds the fused batch; using {fused}");
                    fused
                }
                Some(_) => max_batch,
            };
            Arc::new(move || {
                Ok(Box::new(EngineModel::open_with(&dir, "toy_cell_step", Backend::Native)?)
                    as Box<dyn ServeModel>)
            })
        }
        "fake" => {
            let batch = max_batch;
            let delay_us = args.get_usize("fake-delay-us", 300) as u64;
            Arc::new(move || {
                Ok(Box::new(FakeModel::new(batch, 16, delay_us)) as Box<dyn ServeModel>)
            })
        }
        other => anyhow::bail!("unknown backend '{other}' (expected native|fake)"),
    };

    let server = serve(
        ServeCfg {
            addr: "127.0.0.1:0".to_string(),
            workers,
            batch: BatchCfg { max_batch, max_wait_us, queue_cap: 4_096, continuous: true },
            ..ServeCfg::default()
        },
        factory,
    )?;
    let addr = server.local_addr().to_string();
    println!(
        "# serve_bench: {requests} requests x {concurrency} connections -> {addr} \
         ({backend} backend, {workers} workers, max-batch {max_batch}, max-wait {max_wait_us}us)"
    );

    let report = run_load(&ClientCfg {
        addr,
        requests,
        concurrency,
        use_sessions: args.has_flag("sessions"),
        ..ClientCfg::default()
    })?;
    println!("\n## client\n");
    print!("{}", report.to_table().to_markdown());

    let snap = server.snapshot();
    println!("\n## server\n");
    print!("{}", snap.to_table().to_markdown());
    server.stop();

    anyhow::ensure!(report.ok > 0, "no request completed a full cycle");
    anyhow::ensure!(report.dropped() == 0, "{} requests dropped", report.dropped());
    println!("\nserve_bench OK (mean server batch {:.2})", report.mean_batch);
    Ok(())
}
