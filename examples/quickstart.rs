//! Quickstart: the whole stack in ~60 lines.
//!
//!   1. Open the artifact engine (PJRT CPU + manifest).
//!   2. Build an orthogonal matrix with the AOT CWY artifact and check it
//!      against the native rust implementation.
//!   3. Run a few fused train steps of the copying task.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use cwy::coordinator::{Schedule, Trainer};
use cwy::data::copying::CopyTask;
use cwy::linalg::Matrix;
use cwy::runtime::{Engine, HostTensor};
use cwy::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open("artifacts")?;
    println!("PJRT platform: {}", engine.platform());

    // --- 1. CWY construction: artifact vs native --------------------------
    let n = 64;
    let art = engine.load("param_cwy_n64")?;
    let mut rng = Pcg32::seeded(42);
    let v = Matrix::random_normal(&mut rng, n, n, 1.0);
    let out = art.run(&[HostTensor::f32(vec![n, n], v.data.clone())])?;
    let q = Matrix::from_rows(n, n, out[0].as_f32()?.to_vec());

    let q_native = cwy::orthogonal::cwy::matrix(&v);
    println!(
        "CWY({n}x{n}):  orthogonality defect {:.2e},  artifact-vs-native {:.2e}",
        q.orthogonality_defect(),
        q.max_abs_diff(&q_native)
    );

    // --- 2. Train the copying task for a handful of steps -----------------
    let mut trainer = Trainer::new(&engine, "copy_cwy_step", Schedule::Constant(1e-3))?;
    let spec = &trainer.artifact.spec;
    let t_blank: usize = spec.meta_str("t_blank").unwrap().parse()?;
    let batch: usize = spec.meta_str("batch").unwrap().parse()?;
    let mut task = CopyTask::new(t_blank, batch, 7);
    println!(
        "copying task: T={t_blank}, no-memory baseline CE = {:.4}",
        task.baseline_ce()
    );

    for step in 0..20 {
        let b = task.next_batch();
        let data = vec![
            HostTensor::i32(vec![b.batch, b.t_total], b.tokens),
            HostTensor::i32(vec![b.batch, b.t_total], b.targets),
        ];
        let (loss, metrics) = trainer.train_step(data)?;
        if step % 5 == 0 || step == 19 {
            println!("step {step:>3}: loss {loss:.4}  accuracy {:.3}", metrics[0]);
        }
    }
    println!("quickstart OK");
    Ok(())
}
