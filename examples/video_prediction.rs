//! Video-prediction driver (paper §4.3, Table 4 / Fig. 3): ConvNERU with
//! T-CWY / OWN / unconstrained kernels vs ConvLSTM vs the "Zeros"
//! no-recurrence ablation on the moving-shapes dataset, evaluated per
//! motion class like the paper's per-action split.
//!
//! Run: cargo run --release --example video_prediction -- [--steps 150] [--curves]

use cwy::coordinator::{evaluate, Schedule, Trainer};
use cwy::data::video::{VideoTask, CLASSES};
use cwy::report::{Series, Table};
use cwy::runtime::{Engine, HostTensor};
use cwy::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 150);
    let methods: Vec<String> = args
        .get_or(
            "methods",
            "convneru_tcwy,convneru_own,convneru_free,convneru_zeros,convlstm",
        )
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let engine = Engine::open(args.get_or("artifacts", "artifacts"))?;

    let mut header: Vec<&str> = vec!["METHOD"];
    header.extend(CLASSES.iter().map(|c| *c));
    header.push("MEAN");
    header.push("PARAMS");
    let mut table = Table::new(&header);
    let mut curves = Series::new("fig3_video_val", &["step", "method_idx", "val_l1"]);

    for (mi, method) in methods.iter().enumerate() {
        let name = format!("video_{method}_step");
        if engine.manifest.get(&name).is_err() {
            eprintln!("skipping {method}");
            continue;
        }
        let mut trainer = Trainer::new(&engine, &name, Schedule::Constant(1e-3))?;
        let spec = trainer.artifact.spec.clone();
        let batch: usize = spec.meta_str("batch").unwrap().parse()?;
        let t: usize = spec.meta_str("t").unwrap().parse()?;
        let hw: usize = spec.meta_str("hw").unwrap().parse()?;
        let params_count = spec.meta_str("param_count").unwrap_or("-").to_string();

        let mut train_gen = VideoTask::new(hw, t, batch, 21);
        let eval_art = engine.load(&format!("video_{method}_eval"))?;
        let mut val_gen = VideoTask::new(hw, t, batch, 1021);

        println!("== {method}: {steps} steps ==");
        for step in 0..steps {
            let frames = train_gen.batch_mixed();
            let data = vec![HostTensor::f32(vec![batch, t, hw, hw, 1], frames)];
            let (loss, _) = trainer.train_step(data)?;
            if step % 25 == 0 || step + 1 == steps {
                // validation l1 on a held-out mixed batch
                let vframes = val_gen.batch_mixed();
                let vdata = vec![HostTensor::f32(vec![batch, t, hw, hw, 1], vframes)];
                let m = evaluate(&eval_art, trainer.params(), vdata)?;
                curves.push(&[step as f64, mi as f64, m[0] as f64]);
                println!("  step {step:>4}: train l1 {loss:.2}  val l1 {:.2}", m[0]);
            }
        }

        // Per-class test evaluation (the Table 4 breakdown).
        let mut row = vec![method.to_string()];
        let mut total = 0.0f32;
        let mut test_gen = VideoTask::new(hw, t, batch, 99999);
        for class in 0..CLASSES.len() {
            let frames = test_gen.batch_of_class(class);
            let data = vec![HostTensor::f32(vec![batch, t, hw, hw, 1], frames)];
            let m = evaluate(&eval_art, trainer.params(), data)?;
            total += m[0];
            row.push(format!("{:.2}", m[0]));
        }
        row.push(format!("{:.2}", total / CLASSES.len() as f32));
        row.push(params_count);
        table.row(&row);
    }

    println!("\n## Table 4 (moving-shapes scale; per-class test l1)\n");
    print!("{}", table.to_markdown());
    // --curves is accepted for compatibility; curves are always saved.
    let _ = args.has_flag("curves");
    let path = curves.save(std::path::Path::new("reports"))?;
    println!("\nvalidation curves -> {}", path.display());
    Ok(())
}
