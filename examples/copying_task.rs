//! End-to-end driver for the Copying task (paper §4.1, Fig. 1a / Fig. 4a).
//!
//! Trains every exported method (CWY, sequential HR, EXPRNN, SCORNN, LSTM,
//! unconstrained RNN) on the same task with the same schedule and reports
//! the loss curves against the no-memory baseline 10 log8/(T+20).  This is
//! the repo's flagship E2E run: data generation, fused AOT train steps,
//! metrics, and report emission all through the rust coordinator.
//!
//! Run: cargo run --release --example copying_task -- [--steps 300] [--methods cwy,lstm]

use cwy::coordinator::{Schedule, Trainer};
use cwy::data::copying::CopyTask;
use cwy::report::Series;
use cwy::runtime::{Engine, HostTensor};
use cwy::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 300);
    let methods: Vec<String> = args
        .get_or("methods", "cwy,hr,exprnn,scornn,lstm,rnn")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let seed = args.get_usize("seed", 0) as u64;

    let engine = Engine::open(args.get_or("artifacts", "artifacts"))?;
    let mut series = Series::new("fig1a_copying", &["step", "method_idx", "loss", "accuracy"]);
    let mut finals: Vec<(String, f32, f32, f64)> = Vec::new();

    for (mi, method) in methods.iter().enumerate() {
        let name = format!("copy_{method}_step");
        if engine.manifest.get(&name).is_err() {
            eprintln!("skipping {method}: no artifact {name}");
            continue;
        }
        let mut trainer = Trainer::new(&engine, &name, Schedule::Constant(1e-3))?;
        let spec = &trainer.artifact.spec;
        let t_blank: usize = spec.meta_str("t_blank").unwrap().parse()?;
        let batch: usize = spec.meta_str("batch").unwrap().parse()?;
        let mut task = CopyTask::new(t_blank, batch, seed);
        let baseline = task.baseline_ce();
        println!("\n== {method} (baseline CE {baseline:.4}) ==");

        for step in 0..steps {
            let b = task.next_batch();
            let data = vec![
                HostTensor::i32(vec![b.batch, b.t_total], b.tokens),
                HostTensor::i32(vec![b.batch, b.t_total], b.targets),
            ];
            let (loss, metrics) = trainer.train_step(data)?;
            series.push(&[step as f64, mi as f64, loss as f64, metrics[0] as f64]);
            if step % 50 == 0 || step + 1 == steps {
                println!(
                    "  step {step:>4}: loss {loss:.4}  acc {:.3}  ({})",
                    metrics[0],
                    if loss < baseline { "beats baseline" } else { "above baseline" }
                );
            }
        }
        let hist = &trainer.history;
        finals.push((
            method.clone(),
            hist.recent_mean_loss(20).unwrap_or(f32::NAN),
            hist.records.last().map(|r| r.metrics[0]).unwrap_or(f32::NAN),
            hist.total_wall_s(),
        ));
    }

    println!("\n== summary (mean loss over final 20 steps) ==");
    println!("{:<10} {:>12} {:>10} {:>10}", "method", "final loss", "accuracy", "wall s");
    for (m, l, a, w) in &finals {
        println!("{m:<10} {l:>12.4} {a:>10.3} {w:>10.2}");
    }
    let path = series.save(std::path::Path::new("reports"))?;
    println!("\ncurves -> {}", path.display());
    Ok(())
}
