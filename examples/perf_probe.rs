// L3 perf probe: isolate marshalling cost from PJRT execution.
use cwy::coordinator::{Schedule, Trainer};
use cwy::data::copying::CopyTask;
use cwy::runtime::{Engine, HostTensor};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open("artifacts")?;
    let mut tr = Trainer::new(&engine, "copy_cwy_full_step", Schedule::Constant(1e-3))?;
    let spec = tr.artifact.spec.clone();
    let t_blank: usize = spec.meta_str("t_blank").unwrap().parse()?;
    let batch: usize = spec.meta_str("batch").unwrap().parse()?;
    let mut task = CopyTask::new(t_blank, batch, 0);

    // Warm up (compile)
    for _ in 0..3 {
        let b = task.next_batch();
        tr.train_step(vec![
            HostTensor::i32(vec![b.batch, b.t_total], b.tokens),
            HostTensor::i32(vec![b.batch, b.t_total], b.targets),
        ])?;
    }
    let n = 100;
    let t0 = Instant::now();
    for _ in 0..n {
        let b = task.next_batch();
        tr.train_step(vec![
            HostTensor::i32(vec![b.batch, b.t_total], b.tokens),
            HostTensor::i32(vec![b.batch, b.t_total], b.targets),
        ])?;
    }
    let per = t0.elapsed().as_secs_f64() / n as f64;
    println!("copy_cwy_full_step: {:.3} ms/step over {n} steps", per * 1e3);

    // data-gen cost alone
    let t1 = Instant::now();
    for _ in 0..n { std::hint::black_box(task.next_batch()); }
    println!("data gen: {:.3} ms/step", t1.elapsed().as_secs_f64() / n as f64 * 1e3);
    Ok(())
}
