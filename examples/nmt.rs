//! NMT driver (paper §4.2, Tables 3/5): seq2seq + attention on the synthetic
//! bilingual corpus, sweeping methods and the CWY capacity parameter L.
//!
//! Reports test perplexity, wall time and parameter count in the same shape
//! as Table 3, including the paper's L sweet-spot comparison.
//!
//! Run: cargo run --release --example nmt -- [--steps 200] [--methods cwy_l16,cwy_l32,gru]

use cwy::coordinator::{evaluate, Schedule, Trainer};
use cwy::data::corpus::CorpusGen;
use cwy::report::Table;
use cwy::runtime::{Engine, HostTensor};
use cwy::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 200);
    let methods: Vec<String> = args
        .get_or("methods", "cwy_l16,cwy_l32,cwy_l64,rnn,gru,lstm,scornn,exprnn")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let engine = Engine::open(args.get_or("artifacts", "artifacts"))?;

    let mut table = Table::new(&["MODEL", "TEST PP", "TRAIN PP", "TIME (s)", "PARAMS"]);

    for method in &methods {
        let name = format!("nmt_{method}_step");
        if engine.manifest.get(&name).is_err() {
            eprintln!("skipping {method}: no artifact");
            continue;
        }
        let mut trainer = Trainer::new(&engine, &name, Schedule::Constant(2e-3))?;
        let spec = trainer.artifact.spec.clone();
        let batch: usize = spec.meta_str("batch").unwrap().parse()?;
        let ts: usize = spec.meta_str("ts").unwrap().parse()?;
        let tt: usize = spec.meta_str("tt").unwrap().parse()?;
        let params_count = spec.meta_str("param_count").unwrap_or("-").to_string();

        let mut train_gen = CorpusGen::new(11);
        println!("== {method}: training {steps} steps ==");
        for step in 0..steps {
            let b = train_gen.batch(batch, ts, tt);
            let data = vec![
                HostTensor::i32(vec![batch, ts], b.src),
                HostTensor::i32(vec![batch, tt], b.tgt_in),
                HostTensor::i32(vec![batch, tt], b.tgt_out),
            ];
            let (loss, m) = trainer.train_step(data)?;
            if step % 50 == 0 || step + 1 == steps {
                println!("  step {step:>4}: ce {loss:.4}  pp {:.3}", m[0]);
            }
        }

        // Held-out evaluation with a disjoint seed (the generator is the
        // "test set": the grammar is the distribution).
        let eval_art = engine.load(&format!("nmt_{method}_eval"))?;
        let mut test_gen = CorpusGen::new(7777);
        let mut pp_sum = 0.0f32;
        let eval_batches = 10;
        for _ in 0..eval_batches {
            let b = test_gen.batch(batch, ts, tt);
            let data = vec![
                HostTensor::i32(vec![batch, ts], b.src),
                HostTensor::i32(vec![batch, tt], b.tgt_in),
                HostTensor::i32(vec![batch, tt], b.tgt_out),
            ];
            let m = evaluate(&eval_art, trainer.params(), data)?;
            pp_sum += m[1];
        }
        let test_pp = pp_sum / eval_batches as f32;
        let train_pp = trainer
            .history
            .records
            .last()
            .map(|r| r.metrics[0])
            .unwrap_or(f32::NAN);
        table.row(&[
            method.to_uppercase(),
            format!("{test_pp:.3}"),
            format!("{train_pp:.3}"),
            format!("{:.2}", trainer.history.total_wall_s()),
            params_count,
        ]);
    }

    println!("\n## Table 3 (synthetic-corpus scale)\n");
    print!("{}", table.to_markdown());
    Ok(())
}
