"""Build AOT-exportable train-step functions over flattened state.

Artifact calling convention (DESIGN.md §2.2):

    step  :  state...,  data...,  lr  ->  state'...,  metrics...
    grad  :  params..., data...       ->  grads...,   loss, metrics...
    apply :  params..., opt..., grads..., lr -> params'..., opt'...

`state` = params leaves ++ optimizer-state leaves (Adam: m, v, t).  The rust
coordinator treats state as an opaque ordered Vec<Tensor>; the manifest
records leaf names/shapes so checkpoints stay introspectable.

Everything (loss, backward, Adam update) fuses into one HLO module, so a
training step is a single PJRT execution with no python anywhere near it.
"""

from __future__ import annotations

from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp


def flatten_names(params) -> List[str]:
    """Stable dotted-path names for the leaves of a params pytree."""
    paths = jax.tree_util.tree_flatten_with_path(params)[0]
    names = []
    for path, _ in paths:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append(".".join(parts))
    return names


# ---------------------------------------------------------------------------
# Optimizers (in-graph)
# ---------------------------------------------------------------------------

def sgd_update(leaves, grads, _m, _v, _t, lr):
    new = [p - lr * g for p, g in zip(leaves, grads)]
    return new, _m, _v, _t


def adam_update(leaves, grads, m, v, t, lr,
                b1=0.9, b2=0.999, eps=1e-8):
    t2 = t + 1.0
    bc1 = 1.0 - b1 ** t2
    bc2 = 1.0 - b2 ** t2
    m2 = [b1 * mi + (1 - b1) * g for mi, g in zip(m, grads)]
    v2 = [b2 * vi + (1 - b2) * g * g for vi, g in zip(v, grads)]
    new = [p - lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
           for p, mi, vi in zip(leaves, m2, v2)]
    return new, m2, v2, t2


OPTIMIZERS = {"sgd": sgd_update, "adam": adam_update}


def opt_state_size(n_leaves: int, optimizer: str) -> int:
    """Number of optimizer-state tensors appended after the params leaves."""
    return 2 * n_leaves + 1 if optimizer == "adam" else 1  # m,v,t | t


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def make_step(loss_fn: Callable, treedef, n_leaves: int, n_data: int,
              optimizer: str = "adam"):
    """Fused loss+grad+update step over flat arguments.

    loss_fn(params_pytree, *data) -> (loss, metrics tuple)
    Returned fn(*flat) with flat = leaves + opt_state + data + [lr].
    """
    upd = OPTIMIZERS[optimizer]
    has_mv = optimizer == "adam"

    def step(*flat):
        leaves = list(flat[:n_leaves])
        off = n_leaves
        if has_mv:
            m = list(flat[off:off + n_leaves]); off += n_leaves
            v = list(flat[off:off + n_leaves]); off += n_leaves
        else:
            m = v = []
        t = flat[off]; off += 1
        data = flat[off:off + n_data]; off += n_data
        lr = flat[off]

        params = jax.tree_util.tree_unflatten(treedef, leaves)

        def scalar_loss(p):
            loss, metrics = loss_fn(p, *data)
            return loss, metrics

        (loss, metrics), grads_tree = jax.value_and_grad(
            scalar_loss, has_aux=True)(params)
        grads = jax.tree_util.tree_leaves(grads_tree)
        new, m2, v2, t2 = upd(leaves, grads, m, v, t, lr)
        out = tuple(new) + tuple(m2) + tuple(v2) + (t2, loss) + tuple(metrics)
        return out

    return step


def make_grad(loss_fn: Callable, treedef, n_leaves: int, n_data: int):
    """Gradient-only artifact for the data-parallel coordinator."""

    def grad_fn(*flat):
        leaves = list(flat[:n_leaves])
        data = flat[n_leaves:n_leaves + n_data]
        params = jax.tree_util.tree_unflatten(treedef, leaves)

        def scalar_loss(p):
            loss, metrics = loss_fn(p, *data)
            return loss, metrics

        (loss, metrics), grads_tree = jax.value_and_grad(
            scalar_loss, has_aux=True)(params)
        grads = jax.tree_util.tree_leaves(grads_tree)
        return tuple(grads) + (loss,) + tuple(metrics)

    return grad_fn


def make_apply(n_leaves: int, optimizer: str = "adam"):
    """Update-only artifact: params..., m..., v..., t, grads..., lr."""
    upd = OPTIMIZERS[optimizer]
    has_mv = optimizer == "adam"

    def apply_fn(*flat):
        leaves = list(flat[:n_leaves])
        off = n_leaves
        if has_mv:
            m = list(flat[off:off + n_leaves]); off += n_leaves
            v = list(flat[off:off + n_leaves]); off += n_leaves
        else:
            m = v = []
        t = flat[off]; off += 1
        grads = list(flat[off:off + n_leaves]); off += n_leaves
        lr = flat[off]
        new, m2, v2, t2 = upd(leaves, grads, m, v, t, lr)
        return tuple(new) + tuple(m2) + tuple(v2) + (t2,)

    return apply_fn


def make_eval(loss_fn: Callable, treedef, n_leaves: int, n_data: int):
    """Forward-only loss/metrics artifact (validation path)."""

    def eval_fn(*flat):
        leaves = list(flat[:n_leaves])
        data = flat[n_leaves:n_leaves + n_data]
        params = jax.tree_util.tree_unflatten(treedef, leaves)
        loss, metrics = loss_fn(params, *data)
        return (loss,) + tuple(metrics)

    return eval_fn


def init_state(params_leaves: Sequence[jax.Array], optimizer: str = "adam"):
    """Initial flat state = leaves ++ adam(m, v) ++ t."""
    if optimizer == "adam":
        zeros = [jnp.zeros_like(p) for p in params_leaves]
        return list(params_leaves) + zeros + [jnp.zeros_like(p) for p in params_leaves] + [jnp.zeros((), jnp.float32)]
    return list(params_leaves) + [jnp.zeros((), jnp.float32)]
