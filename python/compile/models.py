"""L2 task models reproducing the paper's four experiment families.

Each model exposes
    init(key, cfg)            -> params (pytree of f32 arrays)
    loss(params, *data, cfg)  -> (scalar loss, metrics tuple)
and is differentiable end-to-end, so `train_steps.py` can build AOT train
step / grad / apply artifacts from it uniformly.

Tasks:
  * copy    — the Copying task (§4.1): recall 10 random digits after a delay.
  * smnist  — pixel-by-pixel image classification (§4.1); the image source is
              the rust synthetic-digit generator (DESIGN.md §4.3).
  * nmt     — seq2seq + Bahdanau attention translation (§4.2, Fig 5).
  * video   — one-step-ahead video prediction with ConvNERU (§4.3, Fig 6).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import cells, parametrize, stiefel
from .cells import gru_cell, gru_init, lstm_cell, lstm_init, rollout

Params = Dict[str, jax.Array]

# ---------------------------------------------------------------------------
# Transition-method plumbing shared by the sequence tasks
# ---------------------------------------------------------------------------

ORTHO_METHODS = ("cwy", "cwy_full", "hr", "exprnn", "scornn")
GATED_METHODS = ("lstm", "gru")


def init_transition(key, method: str, n: int, l: int) -> Params:
    """Unconstrained transition parameters for an O(N) method (or RNN)."""
    if method in ("cwy", "cwy_full", "hr"):
        return {"v": parametrize.cwy_init(key, l, n)}
    if method in ("exprnn", "scornn"):
        return {"a": parametrize.henaff_skew(key, n)}
    if method == "rnn":
        scale = 1.0 / jnp.sqrt(n)
        return {"w": jax.random.uniform(key, (n, n), minval=-scale, maxval=scale)}
    raise ValueError(method)


def transition_operator(method: str, params: Params, *, use_pallas: bool = True):
    if method == "cwy":
        return parametrize.cwy_operator(params["v"], use_pallas=use_pallas)
    if method == "cwy_full":
        return parametrize.cwy_matrix_operator(params["v"], use_pallas=use_pallas)
    if method == "hr":
        return parametrize.hr_operator(params["v"])
    if method == "exprnn":
        return parametrize.exprnn_operator(params["a"])
    if method == "scornn":
        return parametrize.scornn_operator(params["a"])
    if method == "rnn":
        w = params["w"]
        return lambda h: h @ w
    raise ValueError(method)


def _seq_cell(method: str, params: Params, nonlin: str, use_pallas: bool):
    """Build (step, carry0_fn, out_dim_key) for any method incl. gated."""
    if method == "lstm":
        step = lstm_cell(params["cell"])
        return step, lambda b, n: (jnp.zeros((b, n)), jnp.zeros((b, n)))
    if method == "gru":
        step = gru_cell(params["cell"])
        return step, lambda b, n: jnp.zeros((b, n))
    op = transition_operator(method, params, use_pallas=use_pallas)
    step = cells.orthogonal_cell(op, params["win"], params["b"], nonlin)
    return step, lambda b, n: jnp.zeros((b, n))


def _seq_init(key, method: str, n: int, k_in: int, l: int) -> Params:
    keys = jax.random.split(key, 3)
    if method == "lstm":
        return {"cell": lstm_init(keys[0], n, k_in)}
    if method == "gru":
        return {"cell": gru_init(keys[0], n, k_in)}
    scale = 1.0 / jnp.sqrt(k_in)
    p = init_transition(keys[0], method, n, l)
    p["win"] = jax.random.uniform(keys[1], (n, k_in), minval=-scale, maxval=scale)
    p["b"] = jnp.zeros((n,), jnp.float32)
    return p


def _carry_h(carry):
    """Extract the hidden state from a cell carry (LSTM carries (h, c))."""
    return carry[0] if isinstance(carry, tuple) else carry


# ---------------------------------------------------------------------------
# Copying task (§4.1, Fig 1a / Fig 4a)
# ---------------------------------------------------------------------------

COPY_IN = 10   # tokens: 0 blank, 1..8 digits, 9 marker
COPY_OUT = 9   # outputs: 0 blank, 1..8 digits


def copy_init(key, cfg) -> Params:
    method, n, l = cfg["method"], cfg["n"], cfg["l"]
    k1, k2 = jax.random.split(key)
    p = _seq_init(k1, method, n, COPY_IN, l)
    scale = 1.0 / jnp.sqrt(n)
    p["wout"] = jax.random.uniform(k2, (COPY_OUT, n), minval=-scale, maxval=scale)
    p["bout"] = jnp.zeros((COPY_OUT,), jnp.float32)
    return p


def copy_loss(params: Params, tokens: jax.Array, targets: jax.Array, cfg):
    """tokens/targets: (B, T_total) int32.  Mean CE over every position."""
    method, n = cfg["method"], cfg["n"]
    x = jax.nn.one_hot(tokens, COPY_IN, dtype=jnp.float32)
    step, carry0 = _seq_cell(method, params, cfg.get("nonlin", "abs"),
                             cfg.get("use_pallas", True))
    b = tokens.shape[0]
    _, hs = rollout(step, carry0(b, n), x)           # (B, T, N)
    logits = hs @ params["wout"].T + params["bout"]  # (B, T, 9)
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, COPY_OUT, dtype=jnp.float32)
    ce = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32))
    return ce, (acc,)


# ---------------------------------------------------------------------------
# Pixel-by-pixel image classification (§4.1, Fig 1b / Fig 4b)
# ---------------------------------------------------------------------------

def smnist_init(key, cfg) -> Params:
    method, n, l = cfg["method"], cfg["n"], cfg["l"]
    k1, k2 = jax.random.split(key)
    p = _seq_init(k1, method, n, 1, l)
    scale = 1.0 / jnp.sqrt(n)
    p["wout"] = jax.random.uniform(k2, (10, n), minval=-scale, maxval=scale)
    p["bout"] = jnp.zeros((10,), jnp.float32)
    return p


def smnist_loss(params: Params, pixels: jax.Array, labels: jax.Array, cfg):
    """pixels: (B, T) f32 in [0,1]; labels: (B,) int32; classify from h_T."""
    method, n = cfg["method"], cfg["n"]
    x = pixels[:, :, None]  # (B, T, 1)
    step, carry0 = _seq_cell(method, params, cfg.get("nonlin", "abs"),
                             cfg.get("use_pallas", True))
    carry, _ = rollout(step, carry0(pixels.shape[0], n), x)
    h_t = _carry_h(carry)
    logits = h_t @ params["wout"].T + params["bout"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, 10, dtype=jnp.float32)
    ce = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return ce, (acc,)


# ---------------------------------------------------------------------------
# Neural machine translation (§4.2, Fig 5)
# ---------------------------------------------------------------------------

def nmt_init(key, cfg) -> Params:
    """Encoder cell + decoder cell + Bahdanau attention + embeddings."""
    method, n, l = cfg["method"], cfg["n"], cfg["l"]
    vocab, emb = cfg["vocab"], cfg["emb"]
    keys = jax.random.split(key, 8)
    scale_e = 1.0 / jnp.sqrt(emb)
    scale_n = 1.0 / jnp.sqrt(n)
    p = {
        "emb_src": jax.random.normal(keys[0], (vocab, emb)) * scale_e,
        "emb_tgt": jax.random.normal(keys[1], (vocab, emb)) * scale_e,
        # Bahdanau attention: alpha_i ~ v^T tanh(W1 h_i^e + W2 h^d)
        "att_w1": jax.random.uniform(keys[2], (n, n), minval=-scale_n, maxval=scale_n),
        "att_w2": jax.random.uniform(keys[3], (n, n), minval=-scale_n, maxval=scale_n),
        "att_v": jax.random.uniform(keys[4], (n,), minval=-scale_n, maxval=scale_n),
        "wout": jax.random.uniform(keys[5], (vocab, n), minval=-scale_n, maxval=scale_n),
        "bout": jnp.zeros((vocab,), jnp.float32),
        "enc": _seq_init(keys[6], method, n, emb, l),
        # decoder input: previous target embedding concat context vector
        "dec": _seq_init(keys[7], method, n, emb + n, l),
    }
    return p


def nmt_loss(params: Params, src: jax.Array, tgt_in: jax.Array,
             tgt_out: jax.Array, cfg):
    """src/tgt_in/tgt_out: (B, Ts)/(B, Tt)/(B, Tt) int32; 0 = padding.

    Teacher-forced decoder with additive attention over encoder states;
    CE masked on target padding.  Returns (mean-CE, (perplexity,)).
    """
    method, n = cfg["method"], cfg["n"]
    nonlin = cfg.get("nonlin", "abs")
    use_pallas = cfg.get("use_pallas", True)
    b, ts = src.shape

    x_src = params["emb_src"][src]  # (B, Ts, E)
    enc_step, enc_carry0 = _seq_cell(method, params["enc"], nonlin, use_pallas)
    _, enc_hs = rollout(enc_step, enc_carry0(b, n), x_src)  # (B, Ts, N)
    src_mask = (src != 0).astype(jnp.float32)  # (B, Ts)

    # Precompute the W1 h^e attention keys once.
    keys_att = enc_hs @ params["att_w1"].T  # (B, Ts, N)

    dec_step, dec_carry0 = _seq_cell(method, params["dec"], nonlin, use_pallas)
    x_tgt = params["emb_tgt"][tgt_in]  # (B, Tt, E)

    def step(carry, x_t):
        h = _carry_h(carry)
        score = jnp.tanh(keys_att + (h @ params["att_w2"].T)[:, None, :])
        alpha = jnp.einsum("btn,n->bt", score, params["att_v"])
        alpha = jnp.where(src_mask > 0, alpha, -1e9)
        alpha = jax.nn.softmax(alpha, axis=-1)
        ctx = jnp.einsum("bt,btn->bn", alpha, enc_hs)
        inp = jnp.concatenate([x_t, ctx], axis=-1)
        carry2, h2 = dec_step(carry, inp)
        return carry2, h2

    xs = jnp.swapaxes(x_tgt, 0, 1)  # (Tt, B, E)
    _, dec_hs = lax.scan(step, dec_carry0(b, n), xs)
    dec_hs = jnp.swapaxes(dec_hs, 0, 1)  # (B, Tt, N)

    logits = dec_hs @ params["wout"].T + params["bout"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(tgt_out, cfg["vocab"], dtype=jnp.float32)
    ce_tok = -jnp.sum(onehot * logp, axis=-1)  # (B, Tt)
    mask = (tgt_out != 0).astype(jnp.float32)
    ce = jnp.sum(ce_tok * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce, (jnp.exp(ce),)


# ---------------------------------------------------------------------------
# Video prediction with ConvNERU (§4.3, Fig 6)
# ---------------------------------------------------------------------------

def _conv(x, k):
    """NHWC same-padding conv; k is (kh, kw, cin, cout)."""
    return lax.conv_general_dilated(
        x, k, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def video_init(key, cfg) -> Params:
    """ConvNERU / ConvLSTM one-layer predictor with in/out 1x1 convs."""
    method, q, f = cfg["method"], cfg["q"], cfg["f"]
    cin = cfg.get("cin", 1)
    keys = jax.random.split(key, 6)
    glorot = lambda k, shape: jax.random.normal(k, shape) * jnp.sqrt(
        2.0 / (shape[0] * shape[1] * shape[2] + shape[3]))
    p: Params = {
        "k_in": glorot(keys[0], (q, q, cin, f)),
        "k_out": glorot(keys[1], (1, 1, f, cin)),
        "b": jnp.zeros((f,), jnp.float32),
        "b_out": jnp.zeros((cin,), jnp.float32),
    }
    if method == "convneru_tcwy":
        # V (f, q^2 f) parametrizes q*K-hat in St(q^2 f, f) via T-CWY.
        p["v"] = jax.random.normal(keys[2], (f, q * q * f)) * 0.5
    elif method == "convneru_own":
        p["vown"] = jax.random.normal(keys[2], (q * q * f, f)) * 0.1
    elif method in ("convneru_free", "convneru_rgd"):
        # free: Glorot init; rgd: orthogonal init handled by the caller
        # re-orthogonalizing at step time keeps the artifact shape identical.
        p["k_rec"] = glorot(keys[2], (q, q, f, f))
    elif method == "convneru_zeros":
        pass  # no recurrent kernel at all ("Zeros" row of Table 4)
    elif method == "convlstm":
        p["k_rec"] = glorot(keys[2], (q, q, f, 4 * f))
        p["k_in_lstm"] = glorot(keys[3], (q, q, cin, 4 * f))
        p["b_lstm"] = jnp.zeros((4 * f,), jnp.float32)
    else:
        raise ValueError(method)
    return p


def _recurrent_kernel(params: Params, cfg) -> jax.Array:
    """The transition kernel K (q,q,f,f), per-method parametrization."""
    method, q, f = cfg["method"], cfg["q"], cfg["f"]
    if method == "convneru_tcwy":
        omega = stiefel.tcwy_matrix(params["v"],
                                    use_pallas=cfg.get("use_pallas", True))
        return omega.reshape(q, q, f, f) / q
    if method == "convneru_own":
        omega = stiefel.own_matrix(params["vown"])
        return omega.reshape(q, q, f, f) / q
    if method in ("convneru_free", "convneru_rgd"):
        return params["k_rec"]
    raise ValueError(method)


def video_loss(params: Params, frames: jax.Array, cfg):
    """frames: (B, T, H, W, C).  Predict frame t+1 from frames <= t.

    l1-loss summed per frame, averaged over predictions (Table 4 metric is
    the per-frame l1 sum; we report the mean over (T-1) predicted frames).
    """
    method, f = cfg["method"], cfg["f"]
    b, t, h, w, c = frames.shape

    if method == "convlstm":
        def step(carry, x_t):
            hst, cst = carry
            z = (_conv(hst, params["k_rec"]) + _conv(x_t, params["k_in_lstm"])
                 + params["b_lstm"])
            i = jax.nn.sigmoid(z[..., :f])
            fg = jax.nn.sigmoid(z[..., f:2 * f])
            g = jnp.tanh(z[..., 2 * f:3 * f])
            o = jax.nn.sigmoid(z[..., 3 * f:])
            c2 = fg * cst + i * g
            h2 = o * jnp.tanh(c2)
            return (h2, c2), h2
        carry0 = (jnp.zeros((b, h, w, f)), jnp.zeros((b, h, w, f)))
    elif method == "convneru_zeros":
        def step(carry, x_t):
            g2 = jax.nn.relu(_conv(x_t, params["k_in"]) + params["b"])
            return carry, g2
        carry0 = jnp.zeros((b, h, w, f))
    else:
        k_rec = _recurrent_kernel(params, cfg)

        def step(g, x_t):
            g2 = jax.nn.relu(_conv(g, k_rec) + params["b"]
                             + _conv(x_t, params["k_in"]))
            return g2, g2
        carry0 = jnp.zeros((b, h, w, f))

    xs = jnp.swapaxes(frames, 0, 1)  # (T, B, H, W, C)
    _, gs = lax.scan(step, carry0, xs)
    gs = jnp.swapaxes(gs, 0, 1)  # (B, T, H, W, f)

    preds = jax.nn.sigmoid(_conv(
        gs[:, :-1].reshape(b * (t - 1), h, w, f), params["k_out"])
        + params["b_out"]).reshape(b, t - 1, h, w, c)
    target = frames[:, 1:]
    # per-frame l1 summed over pixels, averaged over batch x time
    l1 = jnp.mean(jnp.sum(jnp.abs(preds - target), axis=(2, 3, 4)))
    return l1, (l1,)
