"""Custom-call-free linear algebra for AOT export.

jax >= 0.5 lowers `jax.scipy.linalg.solve_triangular`, `expm`, `qr`, `eigh`
(on CPU) to typed-FFI LAPACK custom calls, which xla_extension 0.5.1 — the
backend behind the rust `xla` crate — rejects with
`Unknown custom-call API version enum value: 4 (API_VERSION_TYPED_FFI)`.

Every routine here therefore lowers to *plain HLO only* (dot/add/mul,
`lax.scan`, `lax.fori_loop`, dynamic slices), so exported artifacts compile
and run on the rust PJRT CPU client.  This restriction is not merely a
workaround: the log-depth triangular inversion below is exactly the
"O(L^2 log L) parallel preprocessing" the paper's Table 1 claims for CWY.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# Triangular inverse (exact, log-depth)
# ---------------------------------------------------------------------------

def triu_inv(S: jax.Array) -> jax.Array:
    """Inverse of an upper-triangular matrix via the nilpotent Neumann product.

    Write S = D(I + M) with D = diag(S) and M strictly upper-triangular.
    M is nilpotent (M^L = 0), so with X = -M,

        (I + M)^{-1} = sum_{k=0}^{L-1} X^k = prod_{j=0}^{J-1} (I + X^{2^j}),

    exact once 2^J >= L.  That is ceil(log2 L) matmuls — the parallel
    O(L^2 log L) inversion from the paper's complexity analysis.
    """
    n = S.shape[0]
    d = jnp.diagonal(S)
    dinv = 1.0 / d
    # D^{-1} S = I + M; X = -M.
    X = -(dinv[:, None] * S - jnp.eye(n, dtype=S.dtype))
    eye = jnp.eye(n, dtype=S.dtype)
    acc = eye + X
    p = X
    steps = max(1, (n - 1).bit_length())
    for _ in range(steps - 1):
        p = p @ p
        acc = acc @ (eye + p)
    # S^{-1} = (I+M)^{-1} D^{-1}
    return acc * dinv[None, :]


def tril_inv(S: jax.Array) -> jax.Array:
    """Inverse of a lower-triangular matrix (transpose of :func:`triu_inv`)."""
    return triu_inv(S.T).T


def triu_solve(S: jax.Array, B: jax.Array) -> jax.Array:
    """Solve S X = B for upper-triangular S (custom-call-free)."""
    return triu_inv(S) @ B


# ---------------------------------------------------------------------------
# Matrix exponential (Taylor + scaling-and-squaring)
# ---------------------------------------------------------------------------

def expm_taylor(A: jax.Array, order: int = 12, squarings: int = 6) -> jax.Array:
    """exp(A) by scaling-and-squaring with a Taylor polynomial.

    Matmuls only.  For the skew-symmetric arguments used by EXPRNN the
    spectral radius is moderate and (order=12, squarings=6) gives ~1e-6
    float32 accuracy for ||A|| <~ 10.
    """
    n = A.shape[0]
    As = A / (2.0 ** squarings)
    eye = jnp.eye(n, dtype=A.dtype)
    term = eye
    acc = eye
    for k in range(1, order + 1):
        term = term @ As / k
        acc = acc + term
    for _ in range(squarings):
        acc = acc @ acc
    return acc


# ---------------------------------------------------------------------------
# Dense inverse (Gauss-Jordan), for the Cayley transform
# ---------------------------------------------------------------------------

def gauss_jordan_inv(A: jax.Array) -> jax.Array:
    """Dense inverse via Gauss-Jordan elimination without pivoting.

    Lowers to a `fori_loop` of rank-1 updates (plain HLO).  Intended for the
    well-conditioned matrices the paper inverts — `I + A/2` with A
    skew-symmetric has eigenvalues `1 + i*lam/2`, so every diagonal pivot
    stays bounded away from zero.
    """
    n = A.shape[0]
    aug = jnp.concatenate([A, jnp.eye(n, dtype=A.dtype)], axis=1)  # (n, 2n)

    def body(i, aug):
        pivot = aug[i, :] / aug[i, i]
        col = aug[:, i]
        # eliminate column i from all rows except i, then set row i to pivot
        aug = aug - col[:, None] * pivot[None, :]
        aug = aug.at[i, :].set(pivot)
        return aug

    aug = lax.fori_loop(0, n, body, aug)
    return aug[:, n:]


def cayley(A: jax.Array) -> jax.Array:
    """Cayley transform (I + A/2)^{-1} (I - A/2), custom-call-free."""
    n = A.shape[0]
    eye = jnp.eye(n, dtype=A.dtype)
    return gauss_jordan_inv(eye + 0.5 * A) @ (eye - 0.5 * A)


# ---------------------------------------------------------------------------
# QR decomposition (Householder, scan-based)
# ---------------------------------------------------------------------------

def householder_qr(A: jax.Array):
    """Thin QR of A (n x m, n >= m) via Householder reflections in a scan.

    Returns (Q, R) with Q in St(n, m) and R upper-triangular with positive
    diagonal (the `qf` convention used by the paper's QR retraction).
    """
    n, m = A.shape
    eps = jnp.asarray(1e-12, A.dtype)

    def step(R, k):
        # Build the reflector for column k, masked below row k.
        col = R[:, k]
        idx = jnp.arange(n)
        mask = (idx >= k).astype(A.dtype)
        x = col * mask
        normx = jnp.sqrt(jnp.sum(x * x) + eps)
        alpha = jnp.where(x[k] >= 0, -normx, normx)
        v = x - alpha * (idx == k).astype(A.dtype)
        vnorm2 = jnp.sum(v * v) + eps
        R2 = R - (2.0 / vnorm2) * jnp.outer(v, v @ R)
        return R2, v

    R, vs = lax.scan(step, A, jnp.arange(m))

    # Accumulate Q = H(v_1) ... H(v_m) applied to [I; 0] columns.
    def apply_back(Q, v):
        vnorm2 = jnp.sum(v * v) + eps
        return Q - (2.0 / vnorm2) * jnp.outer(v, v @ Q), None

    Qfull = jnp.eye(n, m, dtype=A.dtype)
    # Apply reflections in reverse order: Q = H1 H2 ... Hm [I;0]
    Q, _ = lax.scan(apply_back, Qfull, vs, reverse=True)

    # Sign-fix: make diag(R) positive.
    signs = jnp.sign(jnp.diagonal(R[:m, :m])) + (jnp.diagonal(R[:m, :m]) == 0)
    Q = Q * signs[None, :]
    R = R[:m, :m] * signs[:, None]
    return Q, R


# ---------------------------------------------------------------------------
# Inverse matrix square root (Newton-Schulz), for OWN
# ---------------------------------------------------------------------------

def newton_schulz_invsqrt(G: jax.Array, iters: int = 25) -> jax.Array:
    """(G)^{-1/2} for symmetric positive-definite G, matmuls only.

    Coupled Newton-Schulz iteration on the trace-normalized matrix; converges
    quadratically when the spectrum of G/tr(G) lies in (0, 1].  Used by the
    OWN baseline, which the paper implements with an eigendecomposition
    (a LAPACK call we cannot export).
    """
    m = G.shape[0]
    eye = jnp.eye(m, dtype=G.dtype)
    tr = jnp.trace(G)
    Y = G / tr
    Z = eye

    def body(_, YZ):
        Y, Z = YZ
        T = 0.5 * (3.0 * eye - Z @ Y)
        return (Y @ T, T @ Z)

    Y, Z = lax.fori_loop(0, iters, body, (Y, Z))
    # Z -> (G/tr)^{-1/2}; scale back.
    return Z / jnp.sqrt(tr)


__all__ = [
    "triu_inv",
    "tril_inv",
    "triu_solve",
    "expm_taylor",
    "gauss_jordan_inv",
    "cayley",
    "householder_qr",
    "newton_schulz_invsqrt",
]
