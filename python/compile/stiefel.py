"""L2 Stiefel-manifold St(N, M) optimization methods (paper §2.2.2, §3.2).

Two families:

* Parametrizations — unconstrained parameters mapped onto the manifold,
  trained with vanilla SGD/Adam:
    - `tcwy_matrix`  (ours, Thm 3)
    - `own_matrix`   (Huang et al. 2018) via Newton–Schulz inverse sqrt
* Riemannian gradient descent — a retraction step `(Omega, G, lr) -> Omega'`
  staying on the manifold, with the four paper variants
  RGD-{canonical,euclidean} x {Cayley,QR}:
    - Cayley retraction uses the Sherman–Morrison–Woodbury low-rank form of
      the paper's Appendix A (Lemma 1): inverted matrix is 2M x 2M
      (canonical) or 3M x 3M (euclidean), never N x N.
    - QR retraction uses the custom-call-free Householder QR.

All custom-call-free (see linalg_hlo).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import tcwy as tcwy_kernel
from .linalg_hlo import gauss_jordan_inv, householder_qr, newton_schulz_invsqrt


# --- Parametrizations ---------------------------------------------------------

def tcwy_matrix(V: jax.Array, *, use_pallas: bool = True) -> jax.Array:
    """Omega = [I;0] - U S^{-1} U_1^T in St(N, M); V is (M, N)."""
    return tcwy_kernel.matrix(V, use_pallas=use_pallas)


def own_matrix(V: jax.Array) -> jax.Array:
    """Orthogonal Weight Normalization: Omega = V~ (V~^T V~)^{-1/2}.

    V is (N, M).  The paper centers V then whitens with the eigendecomposition
    P Lambda^{-1/2} P^T; the Newton–Schulz inverse square root computes the
    identical map with matmuls only (eigh is a LAPACK custom call we cannot
    export — DESIGN.md §2.5).
    """
    n = V.shape[0]
    Vc = V - jnp.mean(V, axis=0, keepdims=True)
    G = Vc.T @ Vc + 1e-5 * jnp.eye(V.shape[1], dtype=V.dtype)
    return Vc @ newton_schulz_invsqrt(G)


# --- RGD retractions ------------------------------------------------------------

def _bc_factors(omega: jax.Array, grad: jax.Array, lr, inner: str):
    """Low-rank factors B, C with lr*A = B C^T (paper Appendix A)."""
    if inner == "canonical":
        B = lr * jnp.concatenate([grad, omega], axis=1)            # (N, 2M)
        C = jnp.concatenate([omega, -grad], axis=1)                # (N, 2M)
    elif inner == "euclidean":
        E = grad.T @ omega - omega.T @ grad                        # (M, M)
        B = lr * jnp.concatenate([grad, omega, 0.5 * omega @ E], axis=1)
        C = jnp.concatenate([omega, -grad, omega], axis=1)         # (N, 3M)
    else:
        raise ValueError(inner)
    return B, C


def rgd_cayley_step(omega: jax.Array, grad: jax.Array, lr,
                    inner: str = "canonical") -> jax.Array:
    """Omega' = Cayley(lr A) Omega via SMW (Lemma 1):

        Cayley(A) Omega = Omega - B (I + C^T B / 2)^{-1} (C^T Omega),

    inverting only a 2M x 2M (canonical) or 3M x 3M (euclidean) matrix.
    Cayley(eta A) ~ I - eta A, so a positive step size descends.
    """
    B, C = _bc_factors(omega, grad, lr, inner)
    d = B.shape[1]
    inner_mat = jnp.eye(d, dtype=omega.dtype) + 0.5 * (C.T @ B)
    return omega - B @ (gauss_jordan_inv(inner_mat) @ (C.T @ omega))


def rgd_qr_step(omega: jax.Array, grad: jax.Array, lr,
                inner: str = "canonical") -> jax.Array:
    """Omega' = qf(Omega - lr * A Omega) with qf = Householder-QR Q factor."""
    if inner == "canonical":
        A_omega = grad @ (omega.T @ omega) - omega @ (grad.T @ omega)
    else:
        ghat = grad - 0.5 * omega @ (omega.T @ grad)
        A_omega = ghat @ (omega.T @ omega) - omega @ (ghat.T @ omega)
    q, _ = householder_qr(omega - lr * A_omega)
    return q


def rgd_step(omega: jax.Array, grad: jax.Array, lr, *,
             inner: str = "canonical", retraction: str = "cayley") -> jax.Array:
    """Dispatch over the paper's four RGD-A-B variants (Table 2 notation)."""
    if retraction == "cayley":
        return rgd_cayley_step(omega, grad, lr, inner)
    if retraction == "qr":
        return rgd_qr_step(omega, grad, lr, inner)
    raise ValueError(retraction)


RGD_VARIANTS = {
    "rgd_cc": dict(inner="canonical", retraction="cayley"),
    "rgd_ec": dict(inner="euclidean", retraction="cayley"),
    "rgd_cqr": dict(inner="canonical", retraction="qr"),
    "rgd_eqr": dict(inner="euclidean", retraction="qr"),
}
