"""L2 parametrizations of the orthogonal group O(N).

Each entry returns a *rollout operator*: a function `(h: (B,N)) -> (B,N)`
applying the (transposed) transition matrix to a batch of hidden states,
plus whatever precomputation the method amortizes across the RNN rollout
(paper §3.1).  All lower to custom-call-free HLO (see linalg_hlo).

Methods (paper §2.2.1):
  cwy     — Q = I - U S^{-1} U^T; precompute (U, S^{-1}) once per rollout.
  hr      — sequential Householder chain (Mhammedi et al. 2017 baseline).
  exprnn  — Q = expm(A - A^T) (Lezcano-Casado & Martinez-Rubio 2019).
  scornn  — Q = Cayley(A - A^T), D-tilde fixed to I as in the paper §2.2.1.
"""

from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from .kernels import cwy as cwy_kernel
from .kernels import householder as hr_kernel
from .linalg_hlo import cayley, expm_taylor

ApplyFn = Callable[[jax.Array], jax.Array]


def skew(A: jax.Array) -> jax.Array:
    """Project to Skew(N): A -> (A - A^T)/2 (scaled to match torch refs)."""
    return 0.5 * (A - A.T)


# --- CWY -------------------------------------------------------------------

def cwy_operator(V: jax.Array, *, use_pallas: bool = True) -> ApplyFn:
    """Precompute (U, Sinv) and return the fused rollout apply.

    When L == N the paper materializes Q once instead; `cwy_matrix_operator`
    implements that fast path.
    """
    U, Sinv = cwy_kernel.precompute(V, use_pallas=use_pallas)

    def apply(h: jax.Array) -> jax.Array:
        return cwy_kernel.apply(h, U, Sinv, use_pallas)

    return apply


def cwy_matrix_operator(V: jax.Array, *, use_pallas: bool = True) -> ApplyFn:
    """L = N fast path: materialize Q and roll out with a plain matmul."""
    Q = cwy_kernel.matrix(V, use_pallas=use_pallas)

    def apply(h: jax.Array) -> jax.Array:
        return h @ Q

    return apply


# --- Sequential Householder -------------------------------------------------

def hr_operator(V: jax.Array, *, use_pallas: bool = False) -> ApplyFn:
    """The sequential baseline: L chained reflections, no precompute."""

    def apply(h: jax.Array) -> jax.Array:
        return hr_kernel.apply_chain(h, V, use_pallas=use_pallas)

    return apply


# --- EXPRNN ------------------------------------------------------------------

def exprnn_operator(A: jax.Array) -> ApplyFn:
    """Q = expm(skew(A)); O(N^3) construct, matmul rollout."""
    Q = expm_taylor(skew(A))

    def apply(h: jax.Array) -> jax.Array:
        return h @ Q

    return apply


# --- SCORNN ------------------------------------------------------------------

def scornn_operator(A: jax.Array) -> ApplyFn:
    """Q = Cayley(skew(A)); O(N^3) construct via Gauss-Jordan inverse."""
    Q = cayley(skew(A))

    def apply(h: jax.Array) -> jax.Array:
        return h @ Q

    return apply


OPERATORS = {
    "cwy": cwy_operator,
    "cwy_full": cwy_matrix_operator,
    "hr": hr_operator,
    "exprnn": exprnn_operator,
    "scornn": scornn_operator,
}


# --- Initialization -----------------------------------------------------------

def henaff_skew(key: jax.Array, n: int) -> jax.Array:
    """Henaff et al. (2016) block-diagonal skew init used for the copy task."""
    theta = jax.random.uniform(key, (n // 2,), minval=-jnp.pi, maxval=jnp.pi)
    A = jnp.zeros((n, n), jnp.float32)
    idx = jnp.arange(n // 2)
    A = A.at[2 * idx, 2 * idx + 1].set(theta)
    A = A.at[2 * idx + 1, 2 * idx].set(-theta)
    return A


def cwy_init(key: jax.Array, l: int, n: int) -> jax.Array:
    """Random nonzero reflection vectors (paper App. C initializes from the
    QR-of-expm procedure; a spherical init is what their time-comparison
    uses and trains equivalently at our scales)."""
    V = jax.random.normal(key, (l, n), jnp.float32)
    return V
