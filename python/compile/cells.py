"""L2 recurrent cells.

`orthogonal_cell` is the paper's eq. (1) with the transition matrix applied
through a parametrization operator (cwy / hr / exprnn / scornn);
`lstm_cell` / `gru_cell` / `vanilla_cell` are the unconstrained baselines of
Tables 3/5.

All cells share the signature
    step(carry, x_t) -> (carry', h_t)
so the rollout is a single `lax.scan` regardless of method.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

ApplyFn = Callable[[jax.Array], jax.Array]


def nonlinearity(name: str):
    if name == "relu":
        return jax.nn.relu
    if name == "abs":
        # Exact norm-preserving nonlinearity (Dorobantu et al. 2016), used by
        # the paper's NMT experiments.
        return jnp.abs
    if name == "tanh":
        return jnp.tanh
    raise ValueError(name)


# --- Orthogonal RNN -----------------------------------------------------------

def orthogonal_cell(apply_q: ApplyFn, Win: jax.Array, b: jax.Array,
                    nonlin: str = "abs"):
    """h' = sigma(Q^T-rollout(h) + x Win^T + b)   (paper eq. 1)."""
    sigma = nonlinearity(nonlin)

    def step(h, x):
        h2 = sigma(apply_q(h) + x @ Win.T + b[None, :])
        return h2, h2

    return step


def vanilla_cell(W: jax.Array, Win: jax.Array, b: jax.Array,
                 nonlin: str = "tanh"):
    """Unconstrained RNN baseline (Table 3 row 'RNN')."""
    sigma = nonlinearity(nonlin)

    def step(h, x):
        h2 = sigma(h @ W + x @ Win.T + b[None, :])
        return h2, h2

    return step


# --- LSTM ----------------------------------------------------------------------

def lstm_init(key, n: int, k: int) -> Dict[str, jax.Array]:
    k1, k2 = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(n)
    return {
        "wx": jax.random.uniform(k1, (k, 4 * n), minval=-scale, maxval=scale),
        "wh": jax.random.uniform(k2, (n, 4 * n), minval=-scale, maxval=scale),
        "b": jnp.zeros((4 * n,), jnp.float32)
        # forget-gate bias init to 1 improves stability, matching common refs
        .at[n : 2 * n]
        .set(1.0),
    }


def lstm_cell(params: Dict[str, jax.Array]):
    n = params["wh"].shape[0]

    def step(carry, x):
        h, c = carry
        z = x @ params["wx"] + h @ params["wh"] + params["b"][None, :]
        i = jax.nn.sigmoid(z[:, :n])
        f = jax.nn.sigmoid(z[:, n : 2 * n])
        g = jnp.tanh(z[:, 2 * n : 3 * n])
        o = jax.nn.sigmoid(z[:, 3 * n :])
        c2 = f * c + i * g
        h2 = o * jnp.tanh(c2)
        return (h2, c2), h2

    return step


# --- GRU --------------------------------------------------------------------------

def gru_init(key, n: int, k: int) -> Dict[str, jax.Array]:
    k1, k2 = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(n)
    return {
        "wx": jax.random.uniform(k1, (k, 3 * n), minval=-scale, maxval=scale),
        "wh": jax.random.uniform(k2, (n, 3 * n), minval=-scale, maxval=scale),
        "b": jnp.zeros((3 * n,), jnp.float32),
    }


def gru_cell(params: Dict[str, jax.Array]):
    n = params["wh"].shape[0]

    def step(h, x):
        zx = x @ params["wx"] + params["b"][None, :]
        zh = h @ params["wh"]
        r = jax.nn.sigmoid(zx[:, :n] + zh[:, :n])
        u = jax.nn.sigmoid(zx[:, n : 2 * n] + zh[:, n : 2 * n])
        cand = jnp.tanh(zx[:, 2 * n :] + r * zh[:, 2 * n :])
        h2 = (1.0 - u) * h + u * cand
        return h2, h2

    return step


# --- Rollout helper ----------------------------------------------------------------

def rollout(step, carry0, xs_btk: jax.Array):
    """Scan a cell over time-major inputs; xs is (B, T, K)."""
    xs = jnp.swapaxes(xs_btk, 0, 1)  # (T, B, K)
    carry, hs = jax.lax.scan(step, carry0, xs)
    return carry, jnp.swapaxes(hs, 0, 1)  # (B, T, N)
