"""Build-time compile package: L1 kernels + L2 models -> AOT HLO artifacts.

Never imported at runtime — the rust coordinator only consumes the
`artifacts/` directory this package produces.
"""
