"""Pure-jnp/numpy oracles for kernel correctness (pytest target).

Everything here is written as naively as possible — explicit reflection
products, dense solves via numpy — so disagreement with the kernels is
always the kernels' fault.
"""

from __future__ import annotations

import numpy as np


def householder_matrix(v: np.ndarray) -> np.ndarray:
    v = np.asarray(v, np.float64)
    n = v.shape[0]
    return np.eye(n) - 2.0 * np.outer(v, v) / (v @ v)


def householder_product(V: np.ndarray) -> np.ndarray:
    """Q = H(v_1) ... H(v_L), explicit sequential float64 product."""
    V = np.asarray(V, np.float64)
    n = V.shape[1]
    q = np.eye(n)
    for v in V:
        q = q @ householder_matrix(v)
    return q


def cwy_matrix(V: np.ndarray) -> np.ndarray:
    """Q = I - U S^{-1} U^T with a dense float64 solve."""
    V = np.asarray(V, np.float64)
    U = (V / np.linalg.norm(V, axis=1, keepdims=True)).T  # (N, L)
    L = V.shape[0]
    G = U.T @ U
    S = 0.5 * np.eye(L) + np.triu(G, k=1)
    return np.eye(U.shape[0]) - U @ np.linalg.solve(S, U.T)


def tcwy_matrix(V: np.ndarray) -> np.ndarray:
    """Omega = [I;0] - U S^{-1} U_1^T, dense float64."""
    V = np.asarray(V, np.float64)
    m, n = V.shape
    U = (V / np.linalg.norm(V, axis=1, keepdims=True)).T  # (n, m)
    G = U.T @ U
    S = 0.5 * np.eye(m) + np.triu(G, k=1)
    eye_top = np.eye(n, m)
    return eye_top - U @ np.linalg.solve(S, U[:m, :].T)


def apply_rows(h: np.ndarray, Q: np.ndarray) -> np.ndarray:
    """Rows of h mapped by Q^T (matches kernels' batch convention)."""
    return np.asarray(h, np.float64) @ np.asarray(Q, np.float64)


def is_orthogonal(Q: np.ndarray, tol: float = 1e-4) -> bool:
    Q = np.asarray(Q, np.float64)
    return bool(np.abs(Q.T @ Q - np.eye(Q.shape[1])).max() < tol)


def jnp_cwy_apply(h, U, Sinv):
    """The jnp reference for the fused apply kernel (out = h @ Q)."""
    return h - ((h @ U) @ Sinv) @ U.T
