"""L1 kernel for the *sequential* Householder-reflection baseline (HR).

This is the method of Mhammedi et al. (2017) the paper compares against in
Figure 2: L reflections applied one after another,

    h <- h - 2 v (v^T h) / ||v||^2,

which has parallel depth O(L log N) — the serial chain CWY removes.  The
pallas kernel applies a single reflection (one grid step per reflection via
`lax.scan` at L2); keeping the chain explicit is the point of the baseline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _reflect_kernel(h_ref, v_ref, o_ref):
    h = h_ref[...]           # (B, N)
    v = v_ref[...]           # (N,)
    vnorm2 = jnp.sum(v * v)
    coef = (h @ v) * (2.0 / vnorm2)   # (B,)
    o_ref[...] = h - coef[:, None] * v[None, :]


def reflect(h: jax.Array, v: jax.Array, *, use_pallas: bool = True) -> jax.Array:
    """Apply one Householder reflection H(v) to each row of h (B, N)."""
    if use_pallas:
        return pl.pallas_call(
            _reflect_kernel,
            out_shape=jax.ShapeDtypeStruct(h.shape, h.dtype),
            interpret=True,
        )(h, v)
    vnorm2 = jnp.sum(v * v)
    coef = (h @ v) * (2.0 / vnorm2)
    return h - coef[:, None] * v[None, :]


def apply_chain(h: jax.Array, V: jax.Array, *, use_pallas: bool = False) -> jax.Array:
    """`h @ Q` with Q = H(v_1) ... H(v_L), as a sequential scan over L.

    Matches `cwy.apply(h, *cwy.precompute(V))` in exact arithmetic (Thm 2);
    each reflection is symmetric so right-multiplying by H(v_1) first, then
    H(v_2), ... composes to `h @ (H(v_1) ... H(v_L))`.
    """
    def step(h, v):
        return reflect(h, v, use_pallas=use_pallas), None

    out, _ = lax.scan(step, h, V)
    return out


def matrix(V: jax.Array) -> jax.Array:
    """Materialize Q = H(v_1) ... H(v_L) explicitly (O(L N^2) sequential)."""
    n = V.shape[1]
    q = jnp.eye(n, dtype=V.dtype)

    def step(q, v):
        vnorm2 = jnp.sum(v * v)
        return q - (2.0 / vnorm2) * jnp.outer(q @ v, v), None

    q, _ = lax.scan(step, q, V)
    return q
