"""L1 Pallas kernels: CWY / T-CWY / sequential-Householder hot paths."""

from . import cwy, householder, ref, tcwy  # noqa: F401
