"""L1 Pallas kernels for the CWY transform.

The CWY transform (paper Thm 2) represents a product of L Householder
reflections as

    Q = I - U S^{-1} U^T,   S = 0.5 I + striu(U^T U),

with U the column-normalized reflection vectors.  The two kernels here are
the compute hot-spots of a CWY-parametrized RNN:

* :func:`build_s` — the Gram panel `U^T U` plus the striu/diag masking.
* :func:`apply` — the fused rollout step `h <- h - ((h U) Sinv^T) U^T`,
  i.e. rows of `h` mapped by `Q^T` (the transition `W h` of eq. (1) in
  row-major batch form).

TPU adaptation (DESIGN.md §2.5): the kernels tile `U` into (BLK_N, L) VMEM
panels; both panel products are MXU-shaped matmuls, and the grid walks the
N dimension so the full N x L panel never has to be VMEM-resident.  On this
testbed kernels are lowered with ``interpret=True`` (CPU PJRT cannot run
Mosaic custom-calls), which produces the identical HLO dataflow.

Reverse-mode: ``pallas_call`` has no autodiff rule, so :func:`apply` carries
a ``jax.custom_vjp`` whose backward is the analytic adjoint (plain jnp —
it fuses into the same HLO module at export time).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..linalg_hlo import triu_inv

# Block size along the hidden dimension N.  128 matches the MXU systolic
# array edge; shrunk automatically for small N.
BLK_N = 128


def _grid_blocks(n: int, blk: int) -> int:
    return (n + blk - 1) // blk


# ---------------------------------------------------------------------------
# S-matrix build
# ---------------------------------------------------------------------------

def _build_s_kernel(u_ref, o_ref):
    """One grid step: accumulate a BLK_N slab of the Gram matrix U^T U."""
    i = pl.program_id(0)
    u = u_ref[...]  # (blk, L)
    partial = u.T @ u  # (L, L)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(i > 0)
    def _acc():
        o_ref[...] += partial


def _gram_pallas(U: jax.Array, block_n: int = BLK_N) -> jax.Array:
    n, l = U.shape
    blk = min(block_n, n)
    grid = _grid_blocks(n, blk)
    return pl.pallas_call(
        _build_s_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((blk, l), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((l, l), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((l, l), U.dtype),
        interpret=True,
    )(U)


@jax.custom_vjp
def gram(U: jax.Array) -> jax.Array:
    """U^T U via the blocked pallas kernel (pallas has no AD rule, so the
    symmetric-product adjoint U(G-bar + G-bar^T) is attached explicitly)."""
    return _gram_pallas(U)


def _gram_fwd(U):
    return _gram_pallas(U), U


def _gram_bwd(U, g):
    return (U @ (g + g.T),)


gram.defvjp(_gram_fwd, _gram_bwd)


def build_s(U: jax.Array, *, use_pallas: bool = True,
            block_n: int = BLK_N) -> jax.Array:
    """S = 0.5 I + striu(U^T U) for column-normalized U (N, L)."""
    n, l = U.shape
    g = gram(U) if use_pallas else U.T @ U
    return 0.5 * jnp.eye(l, dtype=U.dtype) + jnp.triu(g, k=1)


# ---------------------------------------------------------------------------
# Fused CWY apply
# ---------------------------------------------------------------------------

def _apply_kernel(h_ref, u_ref, sinv_ref, o_ref, acc_ref):
    """Fused apply: h <- h @ Q = h - ((h U) Sinv) U^T.

    Row-major batch convention: `out = h @ Q` with Q = H(v_1)...H(v_L) =
    I - U Sinv U^T, matching the sequential HR chain exactly (Thm 2).
    """
    h = h_ref[...]
    u = u_ref[...]
    si = sinv_ref[...]
    t = h @ u            # (B, L)   panel product 1 (MXU)
    v = t @ si           # (B, L)   small triangular-inverse panel
    o_ref[...] = h - v @ u.T  # panel product 2 (MXU)
    acc_ref[...] = t


def _apply_pallas(h: jax.Array, U: jax.Array, Sinv: jax.Array) -> jax.Array:
    b, n = h.shape
    _, l = U.shape
    out, _ = pl.pallas_call(
        _apply_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b, n), h.dtype),
            jax.ShapeDtypeStruct((b, l), h.dtype),
        ),
        interpret=True,
    )(h, U, Sinv)
    return out


def _apply_math(h, U, Sinv):
    return h - ((h @ U) @ Sinv) @ U.T


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def apply(h: jax.Array, U: jax.Array, Sinv: jax.Array,
          use_pallas: bool = True) -> jax.Array:
    """`h @ Q` for each row of `h` (B, N), `Q = I - U Sinv U^T`.

    Numerically identical to chaining the L reflections H(v_1)..H(v_L) on
    the right (Thm 2) — the Fig. 2 equivalence the paper demonstrates.
    """
    if use_pallas:
        return _apply_pallas(h, U, Sinv)
    return _apply_math(h, U, Sinv)


def _apply_fwd(h, U, Sinv, use_pallas):
    out = _apply_pallas(h, U, Sinv) if use_pallas else _apply_math(h, U, Sinv)
    return out, (h, U, Sinv)


def _apply_bwd(use_pallas, res, g):
    """Analytic adjoint of o = h - h U A U^T with A = Sinv.

    hbar    = g - ((g U) A^T) U^T            (right-multiply by Q^T)
    Ubar    = -h^T g U A^T - g^T h U A
    Abar    = -U^T h^T g U
    """
    h, U, Sinv = res
    hbar = g - ((g @ U) @ Sinv.T) @ U.T
    hTg_U = (h.T @ g) @ U
    gTh_U = (g.T @ h) @ U
    Ubar = -hTg_U @ Sinv.T - gTh_U @ Sinv
    Sinvbar = -(U.T @ hTg_U)
    return hbar, Ubar, Sinvbar


apply.defvjp(_apply_fwd, _apply_bwd)


# ---------------------------------------------------------------------------
# High-level parametrization entry points
# ---------------------------------------------------------------------------

def normalize(V: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Rows of V (L, N) -> column-normalized U (N, L)."""
    norms = jnp.sqrt(jnp.sum(V * V, axis=1, keepdims=True) + eps)
    return (V / norms).T


def precompute(V: jax.Array, *, use_pallas: bool = True):
    """V (L, N) raw reflection vectors -> (U, Sinv) rollout operands."""
    U = normalize(V)
    S = build_s(U, use_pallas=use_pallas)
    return U, triu_inv(S)


def matrix(V: jax.Array, *, use_pallas: bool = True) -> jax.Array:
    """Materialize Q = I - U S^{-1} U^T (the L = N fast path of §3.1)."""
    U, Sinv = precompute(V, use_pallas=use_pallas)
    n = U.shape[0]
    return jnp.eye(n, dtype=V.dtype) - U @ Sinv @ U.T
