"""L1 Pallas kernel for the Truncated CWY (T-CWY) Stiefel parametrization.

Paper Thm 3: for M < N and nonzero v^(1..M) in R^N,

    Omega = [I; 0] - U S^{-1} U_1^T  in  St(N, M),

where U (N, M) stacks the normalized vectors, U_1 is its top M x M block and
S = 0.5 I + striu(U^T U).  The construction needs 4NM^2 + 7M^3/3 FLOPs —
the cheapest Stiefel step in the paper's Table 2 — because the inverted
matrix is M x M *upper-triangular*.

The pallas kernel fuses the two panel products of the construction; the
triangular inverse reuses the log-depth nilpotent product from
``linalg_hlo.triu_inv`` (plain HLO).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..linalg_hlo import triu_inv
from .cwy import build_s, normalize


def _tcwy_kernel(u_ref, w_ref, o_ref):
    """Fused Omega = [I;0] - U @ W where W = S^{-1} U_1^T (M x M)."""
    u = u_ref[...]          # (N, M)
    w = w_ref[...]          # (M, M)
    m = w.shape[0]
    prod = u @ w            # (N, M) panel product (MXU-shaped)
    eye_top = jnp.eye(u.shape[0], m, dtype=u.dtype)
    o_ref[...] = eye_top - prod


def _omega_call(U, W):
    n, m = U.shape
    return pl.pallas_call(
        _tcwy_kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), U.dtype),
        interpret=True,
    )(U, W)


@jax.custom_vjp
def _omega_pallas(U, W):
    """Omega = [I;0] - U W with the linear-map adjoint attached (pallas has
    no reverse-mode rule)."""
    return _omega_call(U, W)


def _omega_fwd(U, W):
    return _omega_call(U, W), (U, W)


def _omega_bwd(res, g):
    U, W = res
    return (-(g @ W.T), -(U.T @ g))


_omega_pallas.defvjp(_omega_fwd, _omega_bwd)


def matrix(V: jax.Array, *, use_pallas: bool = True) -> jax.Array:
    """V (M, N) raw vectors -> Omega in St(N, M)."""
    m, n = V.shape
    if m > n:
        raise ValueError(f"T-CWY needs M <= N, got M={m} N={n}")
    U = normalize(V)                       # (N, M)
    S = build_s(U, use_pallas=use_pallas)  # (M, M)
    Sinv = triu_inv(S)
    U1 = U[:m, :]                          # top M x M block
    W = Sinv @ U1.T                        # (M, M)
    if use_pallas:
        return _omega_pallas(U, W)
    eye_top = jnp.eye(n, m, dtype=V.dtype)
    return eye_top - U @ W


def apply(x: jax.Array, V: jax.Array, *, use_pallas: bool = True) -> jax.Array:
    """x (B, M) -> x @ Omega^T (B, N) without materializing Omega twice.

    Used by ConvNERU where the Stiefel matrix acts on unfolded conv patches.
    """
    omega = matrix(V, use_pallas=use_pallas)
    return x @ omega.T
