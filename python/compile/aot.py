"""AOT artifact pipeline: lower every L2 model to HLO text + manifest.

Interchange is HLO *text* (not serialized HloModuleProto): jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (behind the rust
`xla` crate) rejects; the text parser reassigns ids and round-trips cleanly.

Outputs, per artifact:
    artifacts/<name>.hlo.txt    the lowered module
    artifacts/<name>.state.bin  initial flat state (f32 LE), step/grad kinds
    artifacts/manifest.json     machine-readable index for the rust runtime

Usage:
    python -m compile.aot --out-dir ../artifacts [--only REGEX] [--list]
"""

from __future__ import annotations

import argparse
import json
import os
import re
import struct
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import models, parametrize, stiefel, train_steps
from .kernels import cwy as cwy_kernel
from .kernels import householder as hr_kernel
from .linalg_hlo import cayley, expm_taylor

jax.config.update("jax_enable_x64", False)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Experiment configurations (shared with python/tests)
# ---------------------------------------------------------------------------

COPY_CFG = dict(n=64, l=64, t_blank=64, batch=32, nonlin="abs")
SMNIST_CFG = dict(n=96, l=48, t=196, batch=32, nonlin="abs")
NMT_CFG = dict(n=64, emb=32, vocab=64, ts=12, tt=12, batch=16, nonlin="abs")
VIDEO_CFG = dict(q=3, f=8, hw=16, t=8, batch=4, cin=1)

# cwy_full is the paper's L = N fast path (§3.1): materialize Q once per
# rollout instead of the two panel products per step.
COPY_METHODS = ["cwy", "cwy_full", "hr", "exprnn", "scornn", "lstm", "rnn"]
SMNIST_METHODS = ["cwy", "lstm"]
NMT_METHODS = ["cwy_l16", "cwy_l32", "cwy_l64", "rnn", "gru", "lstm",
               "scornn", "exprnn"]
VIDEO_METHODS = ["convneru_tcwy", "convneru_own", "convneru_free",
                 "convneru_zeros", "convlstm"]

METRICS = {"copy": ["loss", "accuracy"],
           "smnist": ["loss", "accuracy"],
           "nmt": ["loss", "perplexity"],
           "video": ["loss", "l1"]}


def _split_method(m: str) -> Tuple[str, int]:
    """'cwy_l32' -> ('cwy', 32); 'lstm' -> ('lstm', -1)."""
    mm = re.fullmatch(r"(\w+?)_l(\d+)", m)
    if mm:
        return mm.group(1), int(mm.group(2))
    return m, -1


# ---------------------------------------------------------------------------
# Artifact registry
# ---------------------------------------------------------------------------

class Artifact:
    """One lowered HLO module plus everything the rust runtime must know."""

    def __init__(self, name: str, kind: str, fn: Callable,
                 example_args: Sequence, arg_specs: List[dict],
                 out_names: List[str], state_leaves=None, meta=None):
        self.name = name
        self.kind = kind
        self.fn = fn
        self.example_args = example_args
        self.arg_specs = arg_specs
        self.out_names = out_names
        self.state_leaves = state_leaves
        self.meta = meta or {}


REGISTRY: Dict[str, Callable[[], List[Artifact]]] = {}


def _spec(name: str, arr, kind: str) -> dict:
    a = np.asarray(arr)
    return {"name": name, "shape": list(a.shape),
            "dtype": str(a.dtype), "kind": kind}


def _train_artifacts(task: str, method_tag: str, init_fn, loss_fn,
                     data_example: List[Tuple[str, np.ndarray]],
                     cfg: dict, kinds=("step", "eval"),
                     optimizer: str = "adam") -> List[Artifact]:
    """Common builder for step/grad/apply/eval artifacts of one model."""
    method, l_override = _split_method(method_tag)
    cfg = dict(cfg)
    cfg["method"] = method
    if l_override > 0:
        cfg["l"] = l_override

    key = jax.random.PRNGKey(cfg.get("seed", 0))
    params = init_fn(key, cfg)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    names = train_steps.flatten_names(params)
    n_leaves = len(leaves)
    n_data = len(data_example)

    loss_cfg = lambda p, *data: loss_fn(p, *data, cfg)
    metrics_names = METRICS[task]
    out: List[Artifact] = []

    if "step" in kinds:
        state = train_steps.init_state(leaves, optimizer)
        fn = train_steps.make_step(loss_cfg, treedef, n_leaves, n_data,
                                   optimizer)
        state_names = (names + [f"m.{n}" for n in names]
                       + [f"v.{n}" for n in names] + ["t"])
        specs = ([_spec(n, s, "state") for n, s in zip(state_names, state)]
                 + [_spec(n, d, "data") for n, d in data_example]
                 + [{"name": "lr", "shape": [], "dtype": "float32",
                     "kind": "hyper"}])
        args = list(state) + [d for _, d in data_example] + [np.float32(1e-3)]
        out.append(Artifact(
            f"{task}_{method_tag}_step", "step", fn, args, specs,
            state_names + metrics_names, state_leaves=state,
            meta={"task": task, "method": method_tag, "optimizer": optimizer,
                  "n_state": len(state), "n_params": n_leaves,
                  "param_count": int(sum(int(np.prod(np.asarray(l).shape))
                                         for l in leaves)),
                  **{k: str(v) for k, v in cfg.items()}}))

    if "grad" in kinds:
        fn = train_steps.make_grad(loss_cfg, treedef, n_leaves, n_data)
        specs = ([_spec(n, p, "state") for n, p in zip(names, leaves)]
                 + [_spec(n, d, "data") for n, d in data_example])
        args = list(leaves) + [d for _, d in data_example]
        out.append(Artifact(
            f"{task}_{method_tag}_grad", "grad", fn, args, specs,
            [f"g.{n}" for n in names] + ["loss"] + metrics_names[1:],
            state_leaves=list(leaves),
            meta={"task": task, "method": method_tag, "n_params": n_leaves}))

    if "apply" in kinds:
        fn = train_steps.make_apply(n_leaves, optimizer)
        m = [np.zeros_like(np.asarray(p)) for p in leaves]
        args = (list(leaves) + m + [np.copy(x) for x in m]
                + [np.float32(0.0)]
                + [np.zeros_like(np.asarray(p)) for p in leaves]
                + [np.float32(1e-3)])
        state_names = (names + [f"m.{n}" for n in names]
                       + [f"v.{n}" for n in names] + ["t"])
        specs = ([_spec(n, a, "state") for n, a in
                  zip(state_names, args[: 3 * n_leaves + 1])]
                 + [_spec(f"g.{n}", p, "data") for n, p in zip(names, leaves)]
                 + [{"name": "lr", "shape": [], "dtype": "float32",
                     "kind": "hyper"}])
        out.append(Artifact(
            f"{task}_{method_tag}_apply", "apply", fn, args, specs,
            state_names, meta={"task": task, "method": method_tag,
                               "optimizer": optimizer, "n_params": n_leaves}))

    if "eval" in kinds:
        fn = train_steps.make_eval(loss_cfg, treedef, n_leaves, n_data)
        specs = ([_spec(n, p, "state") for n, p in zip(names, leaves)]
                 + [_spec(n, d, "data") for n, d in data_example])
        args = list(leaves) + [d for _, d in data_example]
        out.append(Artifact(
            f"{task}_{method_tag}_eval", "eval", fn, args, specs,
            metrics_names, meta={"task": task, "method": method_tag,
                                 "n_params": n_leaves}))
    return out


# --- Copying task -------------------------------------------------------------

def _copy_data(cfg):
    t_total = cfg["t_blank"] + 20
    b = cfg["batch"]
    return [("tokens", np.zeros((b, t_total), np.int32)),
            ("targets", np.zeros((b, t_total), np.int32))]


for m in COPY_METHODS:
    def _mk_copy(m=m):
        kinds = (("step", "eval", "grad", "apply") if m == "cwy"
                 else ("step", "eval"))
        return _train_artifacts("copy", m, models.copy_init, models.copy_loss,
                                _copy_data(COPY_CFG), COPY_CFG, kinds)
    REGISTRY[f"copy_{m}"] = _mk_copy

# --- Pixel-by-pixel classification ---------------------------------------------

def _smnist_data(cfg):
    b = cfg["batch"]
    return [("pixels", np.zeros((b, cfg["t"]), np.float32)),
            ("labels", np.zeros((b,), np.int32))]


for m in SMNIST_METHODS:
    def _mk_smnist(m=m):
        return _train_artifacts("smnist", m, models.smnist_init,
                                models.smnist_loss, _smnist_data(SMNIST_CFG),
                                SMNIST_CFG)
    REGISTRY[f"smnist_{m}"] = _mk_smnist

# --- NMT --------------------------------------------------------------------------

def _nmt_data(cfg):
    b = cfg["batch"]
    return [("src", np.zeros((b, cfg["ts"]), np.int32)),
            ("tgt_in", np.zeros((b, cfg["tt"]), np.int32)),
            ("tgt_out", np.zeros((b, cfg["tt"]), np.int32))]


for m in NMT_METHODS:
    def _mk_nmt(m=m):
        cfg = dict(NMT_CFG)
        cfg["l"] = 32  # default L when the tag has no _lXX suffix
        return _train_artifacts("nmt", m, models.nmt_init, models.nmt_loss,
                                _nmt_data(cfg), cfg)
    REGISTRY[f"nmt_{m}"] = _mk_nmt

# --- Video prediction ---------------------------------------------------------------

def _video_data(cfg):
    b, t, hw = cfg["batch"], cfg["t"], cfg["hw"]
    return [("frames", np.zeros((b, t, hw, hw, cfg["cin"]), np.float32))]


for m in VIDEO_METHODS:
    def _mk_video(m=m):
        return _train_artifacts("video", m, models.video_init,
                                models.video_loss, _video_data(VIDEO_CFG),
                                VIDEO_CFG)
    REGISTRY[f"video_{m}"] = _mk_video


# --- Micro artifacts: Figure 1c (construction time) ----------------------------------

def _micro(name: str, fn, args_named: List[Tuple[str, np.ndarray]],
           out_names: List[str], meta=None) -> Artifact:
    specs = [_spec(n, a, "data") for n, a in args_named]
    return Artifact(name, "micro", fn, [a for _, a in args_named], specs,
                    out_names, meta=meta)


FIG1C_SIZES = [64, 128, 256, 512]

for n in FIG1C_SIZES:
    def _mk_p_cwy(n=n):
        rng = np.random.RandomState(0)
        V = rng.randn(n, n).astype(np.float32)
        fn = lambda v: (cwy_kernel.matrix(v, use_pallas=False),)
        return [_micro(f"param_cwy_n{n}", fn, [("v", V)], ["q"],
                       {"fig": "1c", "method": "cwy", "n": str(n)})]

    def _mk_p_expm(n=n):
        rng = np.random.RandomState(0)
        A = rng.randn(n, n).astype(np.float32)
        fn = lambda a: (expm_taylor(0.5 * (a - a.T)),)
        return [_micro(f"param_expm_n{n}", fn, [("a", A)], ["q"],
                       {"fig": "1c", "method": "expm", "n": str(n)})]

    def _mk_p_cayley(n=n):
        rng = np.random.RandomState(0)
        A = rng.randn(n, n).astype(np.float32)
        fn = lambda a: (cayley(0.5 * (a - a.T)),)
        return [_micro(f"param_cayley_n{n}", fn, [("a", A)], ["q"],
                       {"fig": "1c", "method": "cayley", "n": str(n)})]

    REGISTRY[f"param_cwy_n{n}"] = _mk_p_cwy
    REGISTRY[f"param_expm_n{n}"] = _mk_p_expm
    REGISTRY[f"param_cayley_n{n}"] = _mk_p_cayley


# --- Micro artifacts: Figure 2 (CWY vs sequential HR rollout) --------------------------

FIG2_LS = [4, 8, 16, 32, 64]
FIG2_N, FIG2_T, FIG2_B = 64, 32, 16

for l in FIG2_LS:
    def _mk_roll_cwy(l=l):
        rng = np.random.RandomState(0)
        V = rng.randn(l, FIG2_N).astype(np.float32)
        h = rng.randn(FIG2_B, FIG2_N).astype(np.float32)

        def fn(v, h0):
            op = parametrize.cwy_operator(v, use_pallas=False)

            def step(hh, _):
                return op(hh), None
            h2, _ = jax.lax.scan(step, h0, None, length=FIG2_T)
            return (h2,)
        return [_micro(f"rollout_cwy_l{l}", fn, [("v", V), ("h", h)], ["h"],
                       {"fig": "2", "method": "cwy", "l": str(l),
                        "n": str(FIG2_N), "t": str(FIG2_T)})]

    def _mk_roll_hr(l=l):
        rng = np.random.RandomState(0)
        V = rng.randn(l, FIG2_N).astype(np.float32)
        h = rng.randn(FIG2_B, FIG2_N).astype(np.float32)

        def fn(v, h0):
            def step(hh, _):
                return hr_kernel.apply_chain(hh, v), None
            h2, _ = jax.lax.scan(step, h0, None, length=FIG2_T)
            return (h2,)
        return [_micro(f"rollout_hr_l{l}", fn, [("v", V), ("h", h)], ["h"],
                       {"fig": "2", "method": "hr", "l": str(l),
                        "n": str(FIG2_N), "t": str(FIG2_T)})]

    REGISTRY[f"rollout_cwy_l{l}"] = _mk_roll_cwy
    REGISTRY[f"rollout_hr_l{l}"] = _mk_roll_hr


# --- Micro artifacts: Table 1 (forward pass across methods) ----------------------------

T1_METHODS = ["rnn", "cwy", "hr", "exprnn", "scornn"]
T1_SIZES = [64, 128]
T1_T, T1_B = 32, 16

for m in T1_METHODS:
    for n in T1_SIZES:
        def _mk_fwd(m=m, n=n):
            l = min(n, 32)
            key = jax.random.PRNGKey(0)
            params = models.init_transition(key, m, n, l)
            leaves, treedef = jax.tree_util.tree_flatten(params)
            rng = np.random.RandomState(0)
            h = rng.randn(T1_B, n).astype(np.float32)

            def fn(*args):
                ps = jax.tree_util.tree_unflatten(treedef, args[:-1])
                h0 = args[-1]
                op = models.transition_operator(m, ps, use_pallas=False)

                def step(hh, _):
                    return jnp.abs(op(hh)), None
                h2, _ = jax.lax.scan(step, h0, None, length=T1_T)
                return (h2,)

            names = train_steps.flatten_names(params)
            args_named = [(nm, np.asarray(lv))
                          for nm, lv in zip(names, leaves)]
            args_named.append(("h", h))
            return [_micro(f"fwd_{m}_n{n}", fn, args_named, ["h"],
                           {"table": "1", "method": m, "n": str(n),
                            "t": str(T1_T)})]
        REGISTRY[f"fwd_{m}_n{n}"] = _mk_fwd


# --- Micro artifacts: Table 2 (Stiefel step) --------------------------------------------

T2_N, T2_M = 256, 32


def _stiefel_omega():
    rng = np.random.RandomState(0)
    a = rng.randn(T2_N, T2_M)
    q, _ = np.linalg.qr(a)
    return q.astype(np.float32)


for variant, kw in stiefel.RGD_VARIANTS.items():
    def _mk_rgd(variant=variant, kw=kw):
        omega = _stiefel_omega()
        rng = np.random.RandomState(1)
        g = (rng.randn(T2_N, T2_M) * 0.1).astype(np.float32)

        def fn(om, gr, lr):
            return (stiefel.rgd_step(om, gr, lr, **kw),)
        return [_micro(f"stiefel_{variant}_step", fn,
                       [("omega", omega), ("grad", g),
                        ("lr", np.float32(0.1))], ["omega"],
                       {"table": "2", "method": variant,
                        "n": str(T2_N), "m": str(T2_M)})]
    REGISTRY[f"stiefel_{variant}"] = _mk_rgd


def _mk_tcwy_construct():
    rng = np.random.RandomState(0)
    V = rng.randn(T2_M, T2_N).astype(np.float32)
    fn = lambda v: (stiefel.tcwy_matrix(v, use_pallas=False),)
    return [_micro("stiefel_tcwy_construct", fn, [("v", V)], ["omega"],
                   {"table": "2", "method": "tcwy", "n": str(T2_N),
                    "m": str(T2_M)})]


def _mk_own_construct():
    rng = np.random.RandomState(0)
    V = (rng.randn(T2_N, T2_M) * 0.1).astype(np.float32)
    fn = lambda v: (stiefel.own_matrix(v),)
    return [_micro("stiefel_own_construct", fn, [("v", V)], ["omega"],
                   {"table": "2", "method": "own", "n": str(T2_N),
                    "m": str(T2_M)})]


REGISTRY["stiefel_tcwy"] = _mk_tcwy_construct
REGISTRY["stiefel_own"] = _mk_own_construct


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def build(only, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    pat = re.compile(only) if only else None
    manifest_path = os.path.join(out_dir, "manifest.json")
    manifest = {"artifacts": []}
    if os.path.exists(manifest_path) and only:
        with open(manifest_path) as f:
            manifest = json.load(f)

    built = []
    for reg_name, builder in sorted(REGISTRY.items()):
        if pat and not pat.search(reg_name):
            continue
        for art in builder():
            path = os.path.join(out_dir, f"{art.name}.hlo.txt")
            print(f"[aot] lowering {art.name} ...", flush=True)
            shapes = [jax.ShapeDtypeStruct(np.asarray(a).shape,
                                           np.asarray(a).dtype)
                      for a in art.example_args]
            # keep_unused=True: jit would otherwise prune arguments the
            # graph doesn't read (e.g. ConvLSTM's unused k_in), breaking the
            # manifest's input arity.
            lowered = jax.jit(art.fn, keep_unused=True).lower(*shapes)
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)

            out_shapes = jax.eval_shape(art.fn, *shapes)
            outputs = [{"name": nm, "shape": list(s.shape),
                        "dtype": str(s.dtype)}
                       for nm, s in zip(art.out_names, out_shapes)]

            entry = {"name": art.name, "file": f"{art.name}.hlo.txt",
                     "kind": art.kind, "inputs": art.arg_specs,
                     "outputs": outputs, "meta": art.meta}

            if art.state_leaves is not None:
                bin_name = f"{art.name}.state.bin"
                with open(os.path.join(out_dir, bin_name), "wb") as f:
                    for leaf in art.state_leaves:
                        a = np.asarray(leaf, np.float32)
                        f.write(struct.pack("<Q", a.size))
                        f.write(a.tobytes())
                entry["state_bin"] = bin_name

            manifest["artifacts"] = [e for e in manifest["artifacts"]
                                     if e["name"] != art.name]
            manifest["artifacts"].append(entry)
            built.append(art.name)
            # Write incrementally so a crash mid-build never loses entries.
            manifest["artifacts"].sort(key=lambda e: e["name"])
            with open(manifest_path, "w") as f:
                json.dump(manifest, f, indent=1)

    print(f"[aot] built {len(built)} artifacts -> {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="regex over registry names (incremental build)")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list:
        for name in sorted(REGISTRY):
            print(name)
        return
    build(args.only, args.out_dir)


if __name__ == "__main__":
    main()
