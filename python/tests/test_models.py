"""L2 model shape/loss sanity + short-training descent per method."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models, parametrize

SMALL_COPY = dict(method="cwy", n=16, l=8, t_blank=8, batch=4, nonlin="abs",
                  use_pallas=False)


def copy_batch(cfg, seed=0):
    rng = np.random.RandomState(seed)
    t_total = cfg["t_blank"] + 20
    b = cfg["batch"]
    tokens = np.zeros((b, t_total), np.int32)
    targets = np.zeros((b, t_total), np.int32)
    digits = rng.randint(1, 9, size=(b, 10))
    tokens[:, :10] = digits
    tokens[:, 10 + cfg["t_blank"]] = 9
    targets[:, -10:] = digits
    return jnp.asarray(tokens), jnp.asarray(targets)


@pytest.mark.parametrize("method", ["cwy", "hr", "exprnn", "scornn", "rnn",
                                    "lstm", "gru"])
def test_copy_loss_finite(method):
    cfg = dict(SMALL_COPY, method=method)
    params = models.copy_init(jax.random.PRNGKey(0), cfg)
    tokens, targets = copy_batch(cfg)
    loss, (acc,) = models.copy_loss(params, tokens, targets, cfg)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(acc) <= 1.0


def test_copy_loss_near_uniform_at_init():
    # With random init the CE should be near log(9) over all positions.
    cfg = dict(SMALL_COPY)
    params = models.copy_init(jax.random.PRNGKey(1), cfg)
    tokens, targets = copy_batch(cfg)
    loss, _ = models.copy_loss(params, tokens, targets, cfg)
    assert float(loss) < 2.0 * np.log(9.0)


@pytest.mark.parametrize("method", ["cwy", "lstm"])
def test_copy_short_training_descends(method):
    cfg = dict(SMALL_COPY, method=method)
    params = models.copy_init(jax.random.PRNGKey(2), cfg)
    tokens, targets = copy_batch(cfg)

    def loss_fn(p):
        return models.copy_loss(p, tokens, targets, cfg)[0]

    l0 = float(loss_fn(params))
    step = jax.jit(lambda p: jax.tree_util.tree_map(
        lambda x, g: x - 0.05 * g, p, jax.grad(loss_fn)(p)))
    for _ in range(20):
        params = step(params)
    assert float(loss_fn(params)) < l0


def test_smnist_shapes():
    cfg = dict(method="cwy", n=24, l=8, nonlin="abs", use_pallas=False)
    params = models.smnist_init(jax.random.PRNGKey(3), cfg)
    rng = np.random.RandomState(0)
    pixels = jnp.asarray(rng.rand(4, 49), jnp.float32)
    labels = jnp.asarray(rng.randint(0, 10, size=4), jnp.int32)
    loss, (acc,) = models.smnist_loss(params, pixels, labels, cfg)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(acc) <= 1.0


def test_nmt_loss_and_masking():
    cfg = dict(method="cwy", n=16, l=8, vocab=32, emb=8, nonlin="abs",
               use_pallas=False)
    params = models.nmt_init(jax.random.PRNGKey(4), cfg)
    rng = np.random.RandomState(1)
    src = jnp.asarray(rng.randint(1, 32, size=(2, 6)), jnp.int32)
    tgt_in = jnp.asarray(rng.randint(1, 32, size=(2, 6)), jnp.int32)
    tgt_out_np = rng.randint(1, 32, size=(2, 6)).astype(np.int32)
    loss_full, (pp,) = models.nmt_loss(
        params, src, tgt_in, jnp.asarray(tgt_out_np), cfg)
    assert np.isfinite(float(loss_full))
    assert float(pp) == pytest.approx(np.exp(float(loss_full)), rel=1e-4)

    # Padding half the targets must change the masked mean loss.
    tgt_masked = tgt_out_np.copy()
    tgt_masked[:, 3:] = 0
    loss_masked, _ = models.nmt_loss(
        params, src, tgt_in, jnp.asarray(tgt_masked), cfg)
    assert not np.isclose(float(loss_full), float(loss_masked))


def test_nmt_gradients_flow_to_attention():
    cfg = dict(method="rnn", n=12, l=4, vocab=16, emb=6, nonlin="abs",
               use_pallas=False)
    params = models.nmt_init(jax.random.PRNGKey(5), cfg)
    rng = np.random.RandomState(2)
    src = jnp.asarray(rng.randint(1, 16, size=(2, 5)), jnp.int32)
    tgt = jnp.asarray(rng.randint(1, 16, size=(2, 5)), jnp.int32)

    g = jax.grad(lambda p: models.nmt_loss(p, src, tgt, tgt, cfg)[0])(params)
    for key in ["att_w1", "att_w2", "att_v"]:
        assert float(jnp.abs(g[key]).max()) > 0.0, key


VIDEO_CFG = dict(q=3, f=4, hw=8, t=4, batch=2, cin=1, use_pallas=False)


@pytest.mark.parametrize("method", ["convneru_tcwy", "convneru_own",
                                    "convneru_free", "convneru_zeros",
                                    "convlstm"])
def test_video_loss_finite(method):
    cfg = dict(VIDEO_CFG, method=method)
    params = models.video_init(jax.random.PRNGKey(6), cfg)
    rng = np.random.RandomState(3)
    frames = jnp.asarray(rng.rand(2, 4, 8, 8, 1), jnp.float32)
    loss, _ = models.video_loss(params, frames, cfg)
    assert np.isfinite(float(loss))


def test_video_tcwy_kernel_is_stiefel():
    cfg = dict(VIDEO_CFG, method="convneru_tcwy")
    params = models.video_init(jax.random.PRNGKey(7), cfg)
    k = models._recurrent_kernel(params, cfg)
    q, f = cfg["q"], cfg["f"]
    omega = np.asarray(k).reshape(q * q * f, f) * q
    np.testing.assert_allclose(omega.T @ omega, np.eye(f), atol=1e-3)


def test_video_norm_nonexplosion():
    """ConvNERU's hidden-state norm must not explode (Appendix B claim),
    in contrast to an unconstrained kernel scaled up."""
    cfg = dict(VIDEO_CFG, method="convneru_tcwy", t=12)
    params = models.video_init(jax.random.PRNGKey(8), cfg)
    rng = np.random.RandomState(4)
    frames = jnp.asarray(rng.rand(1, 12, 8, 8, 1), jnp.float32)
    loss, _ = models.video_loss(params, frames, cfg)
    assert np.isfinite(float(loss)) and float(loss) < 1e4


@pytest.mark.parametrize("method", ["cwy", "exprnn", "scornn"])
def test_transition_operators_orthogonal(method):
    n, l = 16, 8
    params = models.init_transition(jax.random.PRNGKey(9), method, n, l)
    op = models.transition_operator(method, params, use_pallas=False)
    h = jnp.asarray(np.eye(n), jnp.float32)
    q = np.asarray(op(h))  # rows of I mapped -> Q itself
    np.testing.assert_allclose(q.T @ q, np.eye(n), atol=1e-3)


def test_henaff_init_is_skew():
    a = np.asarray(parametrize.henaff_skew(jax.random.PRNGKey(10), 16))
    np.testing.assert_allclose(a, -a.T, atol=1e-6)
