"""Train-step builders: flattening, Adam semantics, grad/apply composition."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import train_steps


def toy_loss(params, x, y):
    pred = x @ params["w"] + params["b"]
    loss = jnp.mean((pred - y) ** 2)
    return loss, (loss,)


def toy_setup(seed=0):
    rng = np.random.RandomState(seed)
    params = {
        "b": jnp.zeros((3,), jnp.float32),
        "w": jnp.asarray(rng.randn(5, 3) * 0.1, jnp.float32),
    }
    x = jnp.asarray(rng.randn(8, 5), jnp.float32)
    w_true = rng.randn(5, 3).astype(np.float32)
    y = jnp.asarray(np.asarray(x) @ w_true, jnp.float32)
    return params, x, y


def test_flatten_names_stable():
    params, _, _ = toy_setup()
    names = train_steps.flatten_names(params)
    assert names == ["b", "w"]  # dict order is sorted by jax pytrees


def test_step_decreases_loss():
    params, x, y = toy_setup()
    leaves, treedef = jax.tree_util.tree_flatten(params)
    step = jax.jit(train_steps.make_step(toy_loss, treedef, len(leaves), 2,
                                         "adam"))
    state = train_steps.init_state(leaves, "adam")
    losses = []
    for _ in range(60):
        out = step(*state, x, y, jnp.float32(0.05))
        state = list(out[: len(state)])
        losses.append(float(out[len(state)]))
    assert losses[-1] < losses[0] * 0.3


def test_sgd_step_matches_manual():
    params, x, y = toy_setup(1)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    step = train_steps.make_step(toy_loss, treedef, len(leaves), 2, "sgd")
    state = train_steps.init_state(leaves, "sgd")
    out = step(*state, x, y, jnp.float32(0.1))

    def scalar_loss(p):
        return toy_loss(p, x, y)[0]

    grads = jax.grad(scalar_loss)(params)
    expect_b = np.asarray(params["b"]) - 0.1 * np.asarray(grads["b"])
    np.testing.assert_allclose(np.asarray(out[0]), expect_b, atol=1e-6)


def test_grad_plus_apply_equals_step():
    """grad -> apply composition must reproduce the fused step exactly."""
    params, x, y = toy_setup(2)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    n = len(leaves)

    step = train_steps.make_step(toy_loss, treedef, n, 2, "adam")
    grad = train_steps.make_grad(toy_loss, treedef, n, 2)
    apply = train_steps.make_apply(n, "adam")

    state = train_steps.init_state(leaves, "adam")
    lr = jnp.float32(0.01)

    fused = step(*state, x, y, lr)

    gout = grad(*leaves, x, y)
    grads = gout[:n]
    split = apply(*state, *grads, lr)

    for a, b in zip(fused[: 3 * n + 1], split):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_adam_bias_correction_first_step():
    """After one step from zero moments, update ~= lr * sign(grad)."""
    params = {"w": jnp.asarray([[2.0]], jnp.float32)}
    leaves, treedef = jax.tree_util.tree_flatten(params)

    def loss(p, x, y):
        l = jnp.sum(p["w"] * x) + 0.0 * jnp.sum(y)
        return l, (l,)

    step = train_steps.make_step(loss, treedef, 1, 2, "adam")
    state = train_steps.init_state(leaves, "adam")
    x = jnp.ones((1, 1), jnp.float32)
    y = jnp.zeros((1,), jnp.float32)
    out = step(*state, x, y, jnp.float32(0.1))
    # grad = 1 -> w' = 2.0 - 0.1 * m_hat / (sqrt(v_hat)+eps) ~= 1.9
    assert abs(float(out[0][0, 0]) - 1.9) < 1e-3


def test_eval_matches_loss():
    params, x, y = toy_setup(3)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    ev = train_steps.make_eval(toy_loss, treedef, len(leaves), 2)
    out = ev(*leaves, x, y)
    direct = toy_loss(params, x, y)[0]
    np.testing.assert_allclose(float(out[0]), float(direct), atol=1e-6)


def test_opt_state_size():
    assert train_steps.opt_state_size(5, "adam") == 11
    assert train_steps.opt_state_size(5, "sgd") == 1
