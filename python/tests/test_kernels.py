"""L1 kernel correctness: pallas kernels vs pure-numpy oracles.

Includes hypothesis sweeps over shapes when hypothesis is available, with a
deterministic fallback grid otherwise (the CI image may not ship hypothesis).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import cwy, householder, ref, tcwy

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

RNG = np.random.RandomState(0)


def rand_v(l, n, seed=0):
    return np.random.RandomState(seed).randn(l, n).astype(np.float32)


# ---------------------------------------------------------------------------
# CWY == sequential Householder product (Thm 2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("l,n", [(1, 4), (2, 8), (5, 16), (16, 16), (8, 64),
                                 (32, 32)])
def test_cwy_matrix_equals_householder_product(l, n):
    v = rand_v(l, n, seed=l * 100 + n)
    q_ref = ref.householder_product(v)
    q_cwy = np.asarray(cwy.matrix(jnp.asarray(v), use_pallas=True))
    np.testing.assert_allclose(q_cwy, q_ref, atol=5e-4)


@pytest.mark.parametrize("l,n", [(4, 16), (8, 32)])
def test_cwy_matrix_orthogonal(l, n):
    v = rand_v(l, n, seed=7)
    q = np.asarray(cwy.matrix(jnp.asarray(v)))
    assert ref.is_orthogonal(q)


# ---------------------------------------------------------------------------
# Fused apply kernel vs oracle (pallas and jnp paths)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [True, False])
@pytest.mark.parametrize("b,l,n", [(1, 2, 8), (4, 8, 32), (16, 16, 64),
                                   (3, 5, 17)])
def test_apply_matches_matrix_action(b, l, n, use_pallas):
    v = rand_v(l, n, seed=b + l + n)
    h = np.random.RandomState(1).randn(b, n).astype(np.float32)
    U, Sinv = cwy.precompute(jnp.asarray(v), use_pallas=use_pallas)
    out = np.asarray(cwy.apply(jnp.asarray(h), U, Sinv, use_pallas))
    q = ref.householder_product(v)
    np.testing.assert_allclose(out, ref.apply_rows(h, q), atol=5e-4)


def test_apply_pallas_equals_jnp():
    v = rand_v(8, 32, seed=3)
    h = np.random.RandomState(2).randn(4, 32).astype(np.float32)
    U, Sinv = cwy.precompute(jnp.asarray(v), use_pallas=False)
    a = np.asarray(cwy.apply(jnp.asarray(h), U, Sinv, True))
    b = np.asarray(cwy.apply(jnp.asarray(h), U, Sinv, False))
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_apply_norm_preserving():
    v = rand_v(16, 48, seed=4)
    h = np.random.RandomState(3).randn(6, 48).astype(np.float32)
    U, Sinv = cwy.precompute(jnp.asarray(v))
    out = np.asarray(cwy.apply(jnp.asarray(h), U, Sinv, True))
    np.testing.assert_allclose(
        np.linalg.norm(out, axis=1), np.linalg.norm(h, axis=1), rtol=1e-3)


# ---------------------------------------------------------------------------
# Gradients of the custom VJPs vs jnp autodiff
# ---------------------------------------------------------------------------

def test_apply_vjp_matches_autodiff():
    v = rand_v(8, 24, seed=5)
    h = np.random.RandomState(4).randn(4, 24).astype(np.float32)
    U, Sinv = cwy.precompute(jnp.asarray(v), use_pallas=False)

    def f_pallas(h, U, Sinv):
        return jnp.sum(jnp.sin(cwy.apply(h, U, Sinv, True)))

    def f_jnp(h, U, Sinv):
        return jnp.sum(jnp.sin(ref.jnp_cwy_apply(h, U, Sinv)))

    g1 = jax.grad(f_pallas, argnums=(0, 1, 2))(jnp.asarray(h), U, Sinv)
    g2 = jax.grad(f_jnp, argnums=(0, 1, 2))(jnp.asarray(h), U, Sinv)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_gram_vjp_matches_autodiff():
    u = np.random.RandomState(6).randn(20, 6).astype(np.float32)

    def f_pallas(u):
        return jnp.sum(jnp.cos(cwy.gram(u)))

    def f_jnp(u):
        return jnp.sum(jnp.cos(u.T @ u))

    g1 = jax.grad(f_pallas)(jnp.asarray(u))
    g2 = jax.grad(f_jnp)(jnp.asarray(u))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


def test_grad_through_scan_pallas_vs_jnp():
    v = jnp.asarray(rand_v(6, 16, seed=8))
    h = jnp.asarray(np.random.RandomState(7).randn(3, 16), jnp.float32)

    def rollout(v, h, up):
        U, Sinv = cwy.precompute(v, use_pallas=up)

        def step(hh, _):
            return cwy.apply(hh, U, Sinv, up), None

        h2, _ = jax.lax.scan(step, h, None, length=4)
        return jnp.sum(jnp.tanh(h2))

    g1 = jax.grad(rollout)(v, h, True)
    g2 = jax.grad(rollout)(v, h, False)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


# ---------------------------------------------------------------------------
# T-CWY (Thm 3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [True, False])
@pytest.mark.parametrize("m,n", [(1, 4), (4, 16), (8, 32), (16, 64)])
def test_tcwy_matches_oracle(m, n, use_pallas):
    v = rand_v(m, n, seed=m * 10 + n)
    omega = np.asarray(tcwy.matrix(jnp.asarray(v), use_pallas=use_pallas))
    np.testing.assert_allclose(omega, ref.tcwy_matrix(v), atol=5e-4)


@pytest.mark.parametrize("m,n", [(4, 16), (8, 24)])
def test_tcwy_on_stiefel(m, n):
    v = rand_v(m, n, seed=9)
    omega = np.asarray(tcwy.matrix(jnp.asarray(v)))
    assert ref.is_orthogonal(omega)


def test_tcwy_equals_truncated_cwy():
    # Thm 3: Omega = first M columns of the full CWY/HR product.
    v = rand_v(5, 20, seed=10)
    omega = np.asarray(tcwy.matrix(jnp.asarray(v), use_pallas=False))
    q = ref.householder_product(v)
    np.testing.assert_allclose(omega, q[:, :5], atol=5e-4)


def test_tcwy_vjp_matches_jnp():
    v = jnp.asarray(rand_v(4, 16, seed=11))

    def f(v, up):
        return jnp.sum(jnp.sin(tcwy.matrix(v, use_pallas=up)))

    g1 = jax.grad(lambda v: f(v, True))(v)
    g2 = jax.grad(lambda v: f(v, False))(v)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


def test_tcwy_rejects_bad_shape():
    with pytest.raises(ValueError):
        tcwy.matrix(jnp.zeros((8, 4)))


# ---------------------------------------------------------------------------
# Householder chain kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [True, False])
def test_reflect_matches_oracle(use_pallas):
    v = np.random.RandomState(12).randn(16).astype(np.float32)
    h = np.random.RandomState(13).randn(4, 16).astype(np.float32)
    out = np.asarray(householder.reflect(
        jnp.asarray(h), jnp.asarray(v), use_pallas=use_pallas))
    expect = ref.apply_rows(h, ref.householder_matrix(v))
    np.testing.assert_allclose(out, expect, atol=1e-4)


def test_chain_equals_cwy_apply():
    # The Fig. 2 claim: CWY and HR are numerically equivalent.
    v = rand_v(8, 32, seed=14)
    h = np.random.RandomState(15).randn(4, 32).astype(np.float32)
    chain = np.asarray(householder.apply_chain(jnp.asarray(h), jnp.asarray(v)))
    U, Sinv = cwy.precompute(jnp.asarray(v), use_pallas=False)
    fused = np.asarray(cwy.apply(jnp.asarray(h), U, Sinv, False))
    np.testing.assert_allclose(chain, fused, atol=5e-4)


def test_hr_matrix_matches_oracle():
    v = rand_v(6, 12, seed=16)
    q = np.asarray(householder.matrix(jnp.asarray(v)))
    np.testing.assert_allclose(q, ref.householder_product(v), atol=5e-4)


# ---------------------------------------------------------------------------
# Hypothesis sweeps (shape/dtype space) when available
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        l=st.integers(min_value=1, max_value=12),
        n_extra=st.integers(min_value=0, max_value=20),
        b=st.integers(min_value=1, max_value=8),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def test_hypothesis_apply_sweep(l, n_extra, b, seed):
        n = l + n_extra + 1
        v = np.random.RandomState(seed).randn(l, n).astype(np.float32)
        h = np.random.RandomState(seed + 1).randn(b, n).astype(np.float32)
        U, Sinv = cwy.precompute(jnp.asarray(v), use_pallas=True)
        out = np.asarray(cwy.apply(jnp.asarray(h), U, Sinv, True))
        expect = ref.apply_rows(h, ref.householder_product(v))
        np.testing.assert_allclose(out, expect, atol=2e-3)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(min_value=1, max_value=10),
        n_extra=st.integers(min_value=1, max_value=24),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def test_hypothesis_tcwy_sweep(m, n_extra, seed):
        n = m + n_extra
        v = np.random.RandomState(seed).randn(m, n).astype(np.float32)
        omega = np.asarray(tcwy.matrix(jnp.asarray(v), use_pallas=True))
        np.testing.assert_allclose(omega, ref.tcwy_matrix(v), atol=2e-3)
else:

    @pytest.mark.parametrize("seed", range(12))
    def test_fallback_apply_sweep(seed):
        rng = np.random.RandomState(seed)
        l = rng.randint(1, 12)
        n = l + rng.randint(1, 20)
        b = rng.randint(1, 8)
        v = rng.randn(l, n).astype(np.float32)
        h = rng.randn(b, n).astype(np.float32)
        U, Sinv = cwy.precompute(jnp.asarray(v), use_pallas=True)
        out = np.asarray(cwy.apply(jnp.asarray(h), U, Sinv, True))
        expect = ref.apply_rows(h, ref.householder_product(v))
        np.testing.assert_allclose(out, expect, atol=2e-3)
