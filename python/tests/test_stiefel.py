"""Stiefel methods: manifold invariants, descent, FLOP ordering claims."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import stiefel


def random_stiefel(n, m, seed=0):
    rng = np.random.RandomState(seed)
    q, _ = np.linalg.qr(rng.randn(n, m))
    return jnp.asarray(q, jnp.float32)


def defect(omega):
    omega = np.asarray(omega, np.float64)
    return np.abs(omega.T @ omega - np.eye(omega.shape[1])).max()


def test_tcwy_on_manifold():
    rng = np.random.RandomState(1)
    for (m, n) in [(2, 8), (8, 32), (16, 128)]:
        v = jnp.asarray(rng.randn(m, n), jnp.float32)
        assert defect(stiefel.tcwy_matrix(v)) < 1e-3


def test_own_on_manifold():
    rng = np.random.RandomState(2)
    v = jnp.asarray(rng.randn(48, 8) * 0.3, jnp.float32)
    assert defect(stiefel.own_matrix(v)) < 5e-2


@pytest.mark.parametrize("variant", sorted(stiefel.RGD_VARIANTS))
def test_rgd_stays_on_manifold(variant):
    kw = stiefel.RGD_VARIANTS[variant]
    omega = random_stiefel(24, 6, seed=3)
    rng = np.random.RandomState(4)
    grad = jnp.asarray(rng.randn(24, 6) * 0.3, jnp.float32)
    nxt = stiefel.rgd_step(omega, grad, 0.1, **kw)
    assert defect(nxt) < 5e-3, variant


@pytest.mark.parametrize("variant", sorted(stiefel.RGD_VARIANTS))
def test_rgd_descends(variant):
    """f(Omega) = ||Omega - Target||^2/2 decreases under every variant."""
    kw = stiefel.RGD_VARIANTS[variant]
    target = random_stiefel(16, 4, seed=5)
    omega = random_stiefel(16, 4, seed=6)

    def f(o):
        return 0.5 * float(jnp.sum((o - target) ** 2))

    before = f(omega)
    for _ in range(30):
        grad = omega - target
        omega = stiefel.rgd_step(omega, grad, 0.1, **kw)
    assert f(omega) < before, f"{variant}: {before} -> {f(omega)}"


def test_rgd_zero_grad_fixed_point():
    omega = random_stiefel(20, 5, seed=7)
    zero = jnp.zeros((20, 5), jnp.float32)
    for variant, kw in stiefel.RGD_VARIANTS.items():
        nxt = stiefel.rgd_step(omega, zero, 0.3, **kw)
        np.testing.assert_allclose(
            np.asarray(nxt), np.asarray(omega), atol=1e-3,
            err_msg=variant)


def test_tcwy_gradient_flows():
    v = jnp.asarray(np.random.RandomState(8).randn(4, 16), jnp.float32)
    target = random_stiefel(16, 4, seed=9)

    def loss(v):
        return jnp.sum((stiefel.tcwy_matrix(v, use_pallas=False) - target) ** 2)

    # A few SGD steps must reduce the loss (exercises Thm 4's setting).
    l0 = float(loss(v))
    for _ in range(40):
        v = v - 0.1 * jax.grad(loss)(v)
    assert float(loss(v)) < l0 * 0.7


def test_bc_factors_reproduce_a():
    """lr*A must equal B C^T for both inner products (Appendix A)."""
    omega = random_stiefel(12, 3, seed=10)
    grad = jnp.asarray(np.random.RandomState(11).randn(12, 3), jnp.float32)
    lr = 0.37

    # canonical: A = G W^T - W G^T
    b, c = stiefel._bc_factors(omega, grad, lr, "canonical")
    a_direct = lr * (grad @ omega.T - omega @ grad.T)
    np.testing.assert_allclose(np.asarray(b @ c.T), np.asarray(a_direct),
                               atol=1e-4)

    b, c = stiefel._bc_factors(omega, grad, lr, "euclidean")
    e = grad.T @ omega - omega.T @ grad
    a_direct = lr * (grad @ omega.T - omega @ grad.T
                     + 0.5 * omega @ e @ omega.T)
    np.testing.assert_allclose(np.asarray(b @ c.T), np.asarray(a_direct),
                               atol=1e-4)
