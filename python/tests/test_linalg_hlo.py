"""Custom-call-free linalg vs scipy/numpy references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import linalg_hlo as lh


def test_triu_inv_various_sizes():
    for n in [1, 2, 3, 5, 8, 16, 33, 64]:
        rng = np.random.RandomState(n)
        s = np.triu(rng.randn(n, n)).astype(np.float32)
        s += np.eye(n, dtype=np.float32) * 2.0 * np.sign(np.diag(s) + 1e-3)
        inv = np.asarray(lh.triu_inv(jnp.asarray(s)))
        np.testing.assert_allclose(inv @ s, np.eye(n), atol=2e-3)


def test_triu_inv_is_triangular():
    rng = np.random.RandomState(0)
    s = np.triu(rng.randn(12, 12)).astype(np.float32) + 3 * np.eye(12, dtype=np.float32)
    inv = np.asarray(lh.triu_inv(jnp.asarray(s)))
    np.testing.assert_allclose(np.tril(inv, k=-1), 0.0, atol=1e-5)


def test_triu_inv_cwy_s_matrix():
    # The actual S shape used by CWY: 0.5 I + striu of a Gram matrix.
    rng = np.random.RandomState(1)
    u = rng.randn(64, 16)
    u /= np.linalg.norm(u, axis=0, keepdims=True)
    s = (0.5 * np.eye(16) + np.triu(u.T @ u, k=1)).astype(np.float32)
    inv = np.asarray(lh.triu_inv(jnp.asarray(s)))
    np.testing.assert_allclose(inv @ s, np.eye(16), atol=1e-4)


def test_tril_inv():
    rng = np.random.RandomState(2)
    s = np.tril(rng.randn(10, 10)).astype(np.float32) + 3 * np.eye(10, dtype=np.float32)
    inv = np.asarray(lh.tril_inv(jnp.asarray(s)))
    np.testing.assert_allclose(inv @ s, np.eye(10), atol=1e-3)


def test_expm_taylor_vs_scipy():
    scipy = pytest.importorskip("scipy.linalg")
    rng = np.random.RandomState(3)
    for n in [2, 8, 24]:
        a = rng.randn(n, n).astype(np.float32) * 0.5
        a = 0.5 * (a - a.T)
        got = np.asarray(lh.expm_taylor(jnp.asarray(a)))
        expect = scipy.expm(a.astype(np.float64))
        np.testing.assert_allclose(got, expect, atol=1e-4)


def test_expm_orthogonal_for_skew():
    rng = np.random.RandomState(4)
    a = rng.randn(16, 16).astype(np.float32)
    a = 0.5 * (a - a.T)
    q = np.asarray(lh.expm_taylor(jnp.asarray(a)))
    np.testing.assert_allclose(q.T @ q, np.eye(16), atol=1e-4)


def test_gauss_jordan_inv():
    rng = np.random.RandomState(5)
    for n in [1, 4, 16, 40]:
        a = rng.randn(n, n).astype(np.float32) + 4 * np.eye(n, dtype=np.float32)
        inv = np.asarray(lh.gauss_jordan_inv(jnp.asarray(a)))
        np.testing.assert_allclose(inv @ a, np.eye(n), atol=2e-3)


def test_cayley_orthogonal():
    rng = np.random.RandomState(6)
    a = rng.randn(20, 20).astype(np.float32)
    a = 0.5 * (a - a.T)
    q = np.asarray(lh.cayley(jnp.asarray(a)))
    np.testing.assert_allclose(q.T @ q, np.eye(20), atol=1e-4)


def test_cayley_matches_dense_solve():
    rng = np.random.RandomState(7)
    a = rng.randn(12, 12)
    a = 0.5 * (a - a.T)
    got = np.asarray(lh.cayley(jnp.asarray(a.astype(np.float32))))
    expect = np.linalg.solve(np.eye(12) + a / 2, np.eye(12) - a / 2)
    np.testing.assert_allclose(got, expect, atol=1e-4)


def test_householder_qr_reconstruction():
    rng = np.random.RandomState(8)
    for (n, m) in [(8, 3), (16, 16), (30, 7)]:
        a = rng.randn(n, m).astype(np.float32)
        q, r = lh.householder_qr(jnp.asarray(a))
        q, r = np.asarray(q), np.asarray(r)
        np.testing.assert_allclose(q @ r, a, atol=2e-3)
        np.testing.assert_allclose(q.T @ q, np.eye(m), atol=1e-3)
        assert (np.diag(r) >= -1e-5).all()
        np.testing.assert_allclose(np.tril(r, k=-1), 0.0, atol=1e-4)


def test_qr_matches_numpy_qf():
    rng = np.random.RandomState(9)
    a = rng.randn(12, 5).astype(np.float32)
    q, _ = lh.householder_qr(jnp.asarray(a))
    qn, rn = np.linalg.qr(a.astype(np.float64))
    # Fix numpy's sign convention to positive diag(R).
    signs = np.sign(np.diag(rn))
    np.testing.assert_allclose(np.asarray(q), qn * signs[None, :], atol=1e-3)


def test_newton_schulz_invsqrt():
    rng = np.random.RandomState(10)
    for m in [2, 8, 16]:
        a = rng.randn(m + 6, m).astype(np.float32)
        g = a.T @ a + 1e-3 * np.eye(m, dtype=np.float32)
        zi = np.asarray(lh.newton_schulz_invsqrt(jnp.asarray(g), iters=40))
        np.testing.assert_allclose(zi @ g @ zi, np.eye(m), atol=5e-2)


def test_everything_differentiable():
    """Each routine must admit reverse-mode AD (artifacts fuse grads)."""
    rng = np.random.RandomState(11)
    s = np.triu(rng.randn(6, 6)).astype(np.float32) + 2 * np.eye(6, dtype=np.float32)
    a = rng.randn(6, 6).astype(np.float32)
    sk = 0.5 * (a - a.T)

    for fn, arg in [
        (lh.triu_inv, jnp.asarray(s)),
        (lh.expm_taylor, jnp.asarray(sk)),
        (lh.gauss_jordan_inv, jnp.asarray(a + 4 * np.eye(6, dtype=np.float32))),
        (lh.cayley, jnp.asarray(sk)),
    ]:
        g = jax.grad(lambda x: jnp.sum(jnp.sin(fn(x))))(arg)
        assert np.isfinite(np.asarray(g)).all(), fn.__name__
