//! Transpose-aware, allocation-free GEMM — the hot path under the native
//! execution backend (DESIGN.md §3.1, §3.3).
//!
//! The single entry point is [`gemm`]:
//!
//! ```text
//! C = beta * C + alpha * op(A) @ op(B)      op(X) = X or X^T
//! ```
//!
//! which subsumes every product the CWY forward/backward substrate needs
//! (NN, NT, TN — and TT for completeness) *without materializing a
//! transposed copy as a fresh `Matrix`* and *without allocating the
//! output*: transposed operands are packed once per call into a
//! thread-local panel buffer that is reused across calls, so the packed
//! rows stream cache-friendly through the same microkernel the plain
//! path uses, and steady-state callers perform zero heap allocations.
//!
//! # Accumulation-order contract (bitwise parity)
//!
//! The whole test suite leans on one invariant, inherited from the seed:
//! every output element is a single serial sum over `k` in ascending
//! order, with the `a_ik == 0.0` skip applied identically everywhere.
//! The microkernel therefore accumulates each `C` element into a
//! zero-initialized scratch row (full `k` sweep) and only then combines
//! `beta * c + alpha * acc` in one rounding step per term.  Consequences:
//!
//! * `gemm(NN, 1, A, B, 0, C)` is bitwise identical to [`matmul_naive`];
//! * `gemm(TN/NT/TT, ...)` is bitwise identical to materializing the
//!   transpose(s) and calling the NN path (packing reorders memory, not
//!   arithmetic);
//! * `gemm(_, _, α, A, B, 1, C)` is bitwise identical to
//!   `C.add(&product.scale(α))`, so fused accumulation can replace the
//!   allocating `add`/`sub` chains with no numeric drift at all.
//!
//! The microkernel is 4×-row-blocked: four output rows share each
//! streamed `op(B)` row, and the four accumulator rows are independent
//! serial chains, so the inner loop vectorizes over columns (SIMD) and
//! keeps four FMA chains in flight (ILP) without touching the per-element
//! accumulation order.
//!
//! The frozen PR-4 kernel lives in [`legacy`] as the measurement baseline
//! for `benches/bptt_native` / `BENCH_5.json` and as a bitwise parity
//! oracle for the packed paths.

use std::cell::RefCell;

use crate::linalg::Matrix;

/// Output-column strip width: one scratch strip (4 rows x TILE_J) plus
/// the streamed `op(B)` row segment stay L1-resident.
pub const TILE_J: usize = 128;
/// Microkernel height: output rows per block, each an independent
/// accumulator chain.
pub const MR: usize = 4;
/// Multiply-add count below which thread spawn overhead dominates and
/// the single-threaded kernel wins.
pub const PARALLEL_FLOP_CUTOFF: usize = 1 << 18;

/// Reference kernel: straightforward (i, k, j) loop, inner loop
/// contiguous in both `b` and `out` rows.  Kept allocating and simple —
/// it is the parity baseline for tests and `benches/gemm_native`.
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let mut out = Matrix::zeros(a.rows, b.cols);
    let n = b.cols;
    for i in 0..a.rows {
        let arow = a.row(i);
        let orow = &mut out.data[i * n..(i + 1) * n];
        for (k, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b.data[k * n..(k + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += aik * bv;
            }
        }
    }
    out
}

fn hardware_threads() -> usize {
    std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
}

/// GEMMs currently executing on this process.  Concurrent callers (e.g.
/// serve worker threads each running a fused batch) split the hardware
/// thread budget instead of each spawning `available_parallelism()`
/// threads and oversubscribing the CPU.
static ACTIVE_GEMMS: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// RAII registration in [`ACTIVE_GEMMS`] (panic-safe decrement).
struct GemmSlot {
    budget: usize,
}

impl GemmSlot {
    fn acquire() -> GemmSlot {
        use std::sync::atomic::Ordering;
        let active = ACTIVE_GEMMS.fetch_add(1, Ordering::Relaxed) + 1;
        GemmSlot { budget: (hardware_threads() / active).max(1) }
    }
}

impl Drop for GemmSlot {
    fn drop(&mut self) {
        ACTIVE_GEMMS.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
    }
}

thread_local! {
    /// Reused packing buffers for transposed operands (`op = ^T`).  They
    /// grow to the largest panel a thread ever needs and then serve every
    /// later call allocation-free; per-thread residency is bounded by the
    /// largest transposed operand the workload touches.
    static PACK_A: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    static PACK_B: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Pack `src` (r x c, row-major) transposed into `dst` (c x r, row-major),
/// reusing `dst`'s capacity.  Reorders memory only — every later
/// multiply-add sees the same values in the same `k` order.
fn pack_transposed(src: &Matrix, dst: &mut Vec<f32>) {
    let (r, c) = (src.rows, src.cols);
    dst.clear();
    dst.resize(r * c, 0.0);
    for i in 0..r {
        let srow = &src.data[i * c..(i + 1) * c];
        for (j, &v) in srow.iter().enumerate() {
            dst[j * r + i] = v;
        }
    }
}

/// The microkernel over one band of output rows (`i0..i0 + rows`).
///
/// `x` is `op(A)` row-major (m x k), `bp` is `op(B)` row-major (k x n);
/// `cband` holds rows `i0..` of `C`.  Each element's sum is accumulated
/// in a scratch strip over the full ascending `k` range, then combined
/// as `beta * c + alpha * acc` in a single pass — see the module docs
/// for why this exact shape is load-bearing.
#[allow(clippy::too_many_arguments)]
fn band_kernel(
    x: &[f32],
    kdim: usize,
    n: usize,
    i0: usize,
    alpha: f32,
    beta: f32,
    bp: &[f32],
    cband: &mut [f32],
) {
    if n == 0 {
        return;
    }
    let rows = cband.len() / n;
    let mut scratch = [0.0f32; MR * TILE_J];
    let mut jb = 0;
    while jb < n {
        let jw = TILE_J.min(n - jb);
        let mut i = 0;
        // 4-row blocks: one streamed bp row feeds four accumulator rows.
        while i + MR <= rows {
            let (s0, rest) = scratch.split_at_mut(jw);
            let (s1, rest) = rest.split_at_mut(jw);
            let (s2, rest) = rest.split_at_mut(jw);
            let s3 = &mut rest[..jw];
            s0.fill(0.0);
            s1.fill(0.0);
            s2.fill(0.0);
            s3.fill(0.0);
            let x0 = &x[(i0 + i) * kdim..(i0 + i + 1) * kdim];
            let x1 = &x[(i0 + i + 1) * kdim..(i0 + i + 2) * kdim];
            let x2 = &x[(i0 + i + 2) * kdim..(i0 + i + 3) * kdim];
            let x3 = &x[(i0 + i + 3) * kdim..(i0 + i + 4) * kdim];
            for kk in 0..kdim {
                let brow = &bp[kk * n + jb..kk * n + jb + jw];
                let (a0, a1, a2, a3) = (x0[kk], x1[kk], x2[kk], x3[kk]);
                if a0 != 0.0 {
                    for (s, &bv) in s0.iter_mut().zip(brow) {
                        *s += a0 * bv;
                    }
                }
                if a1 != 0.0 {
                    for (s, &bv) in s1.iter_mut().zip(brow) {
                        *s += a1 * bv;
                    }
                }
                if a2 != 0.0 {
                    for (s, &bv) in s2.iter_mut().zip(brow) {
                        *s += a2 * bv;
                    }
                }
                if a3 != 0.0 {
                    for (s, &bv) in s3.iter_mut().zip(brow) {
                        *s += a3 * bv;
                    }
                }
            }
            for (r, srow) in [&*s0, &*s1, &*s2, &*s3].into_iter().enumerate() {
                combine(&mut cband[(i + r) * n + jb..(i + r) * n + jb + jw], srow, alpha, beta);
            }
            i += MR;
        }
        // Remainder rows, one accumulator chain each.
        while i < rows {
            let s0 = &mut scratch[..jw];
            s0.fill(0.0);
            let xr = &x[(i0 + i) * kdim..(i0 + i + 1) * kdim];
            for (kk, &aik) in xr.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &bp[kk * n + jb..kk * n + jb + jw];
                for (s, &bv) in s0.iter_mut().zip(brow) {
                    *s += aik * bv;
                }
            }
            combine(&mut cband[i * n + jb..i * n + jb + jw], s0, alpha, beta);
            i += 1;
        }
        jb += jw;
    }
}

/// `c = beta * c + alpha * s`, one rounding per term so the fused form
/// matches `c.scale(beta).add(&product.scale(alpha))` bitwise.  `beta == 0`
/// never reads `c` (the buffer may hold stale workspace contents).
#[inline]
fn combine(crow: &mut [f32], srow: &[f32], alpha: f32, beta: f32) {
    if beta == 0.0 {
        for (c, &s) in crow.iter_mut().zip(srow) {
            *c = alpha * s;
        }
    } else if beta == 1.0 {
        for (c, &s) in crow.iter_mut().zip(srow) {
            *c += alpha * s;
        }
    } else {
        for (c, &s) in crow.iter_mut().zip(srow) {
            *c = beta * *c + alpha * s;
        }
    }
}

/// General matrix multiply-accumulate: `c = beta*c + alpha*op(a)@op(b)`,
/// with `op` selected per operand by `trans_a` / `trans_b`.
///
/// * No allocation of the output — `c` must be preshaped to
///   `(op(a).rows, op(b).cols)` (asserted).
/// * Transposed operands are packed into reused thread-local panels, so
///   `x.t().matmul(&y)`-style call sites collapse to one call with zero
///   temporaries (transpose-variant cheat sheet in DESIGN.md §3.3).
/// * `beta = 0.0` overwrites (never reads) `c`; `beta = 1.0` fuses the
///   `d += a@b` accumulation pattern of the BPTT.
/// * Output rows split across scoped threads above
///   [`PARALLEL_FLOP_CUTOFF`] multiply-adds, as before.
pub fn gemm(
    trans_a: bool,
    trans_b: bool,
    alpha: f32,
    a: &Matrix,
    b: &Matrix,
    beta: f32,
    c: &mut Matrix,
) {
    let (m, ka) = if trans_a { (a.cols, a.rows) } else { (a.rows, a.cols) };
    let (kb, n) = if trans_b { (b.cols, b.rows) } else { (b.rows, b.cols) };
    assert_eq!(ka, kb, "gemm reduction-dim mismatch");
    assert_eq!((c.rows, c.cols), (m, n), "gemm output shape mismatch");
    let k = ka;
    // Per-variant telemetry: ~two clock reads and three relaxed atomic
    // adds per call — no lock, no allocation (alloc_discipline covers
    // this path with recording live).
    let gemm_span = match (trans_a, trans_b) {
        (false, false) => crate::span!(gemm_nn),
        (false, true) => crate::span!(gemm_nt),
        (true, false) => crate::span!(gemm_tn),
        (true, true) => crate::span!(gemm_tt),
    };
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 || alpha == 0.0 {
        // No products contribute; only the beta term remains.
        if beta == 0.0 {
            c.data.fill(0.0);
        } else if beta != 1.0 {
            for v in &mut c.data {
                *v *= beta;
            }
        }
        return;
    }
    // Only calls that reach the product loops count FLOPs; the beta-only
    // early-outs above perform no multiply-adds.
    crate::telemetry::global()
        .add_gemm_flops(gemm_span.id(), crate::orthogonal::flops::gemm_flops(m, k, n));
    PACK_A.with(|pa| {
        PACK_B.with(|pb| {
            let (mut pa, mut pb) = (pa.borrow_mut(), pb.borrow_mut());
            if trans_a {
                pack_transposed(a, &mut pa);
            }
            if trans_b {
                pack_transposed(b, &mut pb);
            }
            let x: &[f32] = if trans_a { &pa } else { &a.data };
            let bp: &[f32] = if trans_b { &pb } else { &b.data };
            if m * k * n < PARALLEL_FLOP_CUTOFF {
                band_kernel(x, k, n, 0, alpha, beta, bp, &mut c.data);
                return;
            }
            let slot = GemmSlot::acquire();
            let threads = slot.budget.min(m);
            if threads <= 1 {
                band_kernel(x, k, n, 0, alpha, beta, bp, &mut c.data);
                return;
            }
            let rows_per = m.div_ceil(threads);
            std::thread::scope(|s| {
                for (band_idx, out_band) in c.data.chunks_mut(rows_per * n).enumerate() {
                    s.spawn(move || {
                        band_kernel(x, k, n, band_idx * rows_per, alpha, beta, bp, out_band);
                    });
                }
            });
        })
    });
}

/// Plain product `a @ b` through the [`gemm`] NN path (allocates the
/// output; `Matrix::matmul` routes here).
pub fn matmul_blocked(a: &Matrix, b: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(a.rows, b.cols);
    gemm(false, false, 1.0, a, b, 0.0, &mut out);
    out
}

/// The frozen PR-4 GEMM: blocked/cache-tiled band kernel with per-call
/// output allocation and no transpose awareness.  Kept verbatim as (a)
/// the baseline `benches/bptt_native` and `BENCH_5.json` measure the
/// substrate against, and (b) a bitwise parity oracle — it shares the
/// ascending-`k` accumulation order and zero-skip with [`gemm`], so the
/// two must agree to the last bit.
pub mod legacy {
    use super::Matrix;

    const TILE_K: usize = 64;
    const TILE_J: usize = 256;

    fn band_kernel(a: &[f32], k: usize, n: usize, i0: usize, out_band: &mut [f32], b: &[f32]) {
        if n == 0 {
            return;
        }
        let rows = out_band.len() / n;
        let mut kb = 0;
        while kb < k {
            let kend = (kb + TILE_K).min(k);
            let mut jb = 0;
            while jb < n {
                let jend = (jb + TILE_J).min(n);
                for i in 0..rows {
                    let arow = &a[(i0 + i) * k..(i0 + i) * k + k];
                    let orow = &mut out_band[i * n + jb..i * n + jend];
                    for (kk, &aik) in arow[kb..kend].iter().enumerate() {
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b[(kb + kk) * n + jb..(kb + kk) * n + jend];
                        for (o, &bv) in orow.iter_mut().zip(brow) {
                            *o += aik * bv;
                        }
                    }
                }
                jb = jend;
            }
            kb = kend;
        }
    }

    /// PR-4 `Matrix::matmul`: allocate + zero the output, run the tiled
    /// band kernel, threading above the same FLOP cutoff.
    pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols, b.rows, "matmul shape mismatch");
        let (m, k, n) = (a.rows, a.cols, b.cols);
        let mut out = Matrix::zeros(m, n);
        if m == 0 || n == 0 || k == 0 {
            return out;
        }
        if m * k * n < super::PARALLEL_FLOP_CUTOFF {
            band_kernel(&a.data, k, n, 0, &mut out.data, &b.data);
            return out;
        }
        let slot = super::GemmSlot::acquire();
        let threads = slot.budget.min(m);
        if threads <= 1 {
            band_kernel(&a.data, k, n, 0, &mut out.data, &b.data);
            return out;
        }
        let rows_per = m.div_ceil(threads);
        let (a_data, b_data) = (&a.data[..], &b.data[..]);
        std::thread::scope(|s| {
            for (band_idx, out_band) in out.data.chunks_mut(rows_per * n).enumerate() {
                s.spawn(move || {
                    band_kernel(a_data, k, n, band_idx * rows_per, out_band, b_data);
                });
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, forall};
    use crate::util::rng::Pcg32;

    fn bits(m: &Matrix) -> Vec<u32> {
        m.data.iter().map(|x| x.to_bits()).collect()
    }

    fn assert_bitwise(a: &Matrix, b: &Matrix, what: &str) -> Result<(), String> {
        if bits(a) == bits(b) {
            Ok(())
        } else {
            Err(format!("{what}: bitwise mismatch (max |diff| {})", a.max_abs_diff(b)))
        }
    }

    /// Random shapes spanning the edge cases the satellite demands:
    /// L = 1 / B = 1 rows, dims straddling the strip width and the
    /// microkernel height.
    fn ragged_dims(rng: &mut Pcg32) -> (usize, usize, usize) {
        let pick = |rng: &mut Pcg32| match rng.below(5) {
            0 => 1,
            1 => MR - 1,
            2 => MR + 1,
            _ => 1 + rng.below(TILE_J as u32 + 19) as usize,
        };
        (pick(rng), pick(rng), pick(rng))
    }

    #[test]
    fn nn_matches_naive_on_ragged_shapes() {
        forall(
            24,
            |rng| {
                let (m, k, n) = ragged_dims(rng);
                let a = Matrix::random_normal(rng, m, k, 1.0);
                let b = Matrix::random_normal(rng, k, n, 1.0);
                (a, b)
            },
            |(a, b)| {
                let fast = matmul_blocked(a, b);
                let slow = matmul_naive(a, b);
                // The accumulation-order contract makes this exact, not
                // approximate — assert the stronger property.
                assert_bitwise(&fast, &slow, "NN vs naive")
            },
        );
    }

    #[test]
    fn blocked_matches_naive_above_parallel_cutoff() {
        // 97 * 83 * 101 multiply-adds exceed PARALLEL_FLOP_CUTOFF — force
        // the threaded band path plus a ragged last band.
        forall(
            3,
            |rng| {
                let a = Matrix::random_normal(rng, 97, 83, 1.0);
                let b = Matrix::random_normal(rng, 83, 101, 1.0);
                (a, b)
            },
            |(a, b)| {
                let fast = matmul_blocked(a, b);
                let slow = matmul_naive(a, b);
                assert_close(&fast.data, &slow.data, 1e-5)
            },
        );
    }

    /// NT / TN / TT bit-match materializing the transpose(s) and running
    /// the allocating NN path — packing reorders memory, not arithmetic.
    #[test]
    fn transpose_variants_bitwise_match_materialized() {
        forall(
            24,
            |rng| {
                let (m, k, n) = ragged_dims(rng);
                let (ta, tb) =
                    [(true, false), (false, true), (true, true)][rng.below(3) as usize];
                let a_dims = if ta { (k, m) } else { (m, k) };
                let b_dims = if tb { (n, k) } else { (k, n) };
                let a = Matrix::random_normal(rng, a_dims.0, a_dims.1, 1.0);
                let b = Matrix::random_normal(rng, b_dims.0, b_dims.1, 1.0);
                (ta, tb, a, b, m, n)
            },
            |(ta, tb, a, b, m, n)| {
                let mut c = Matrix::zeros(*m, *n);
                gemm(*ta, *tb, 1.0, a, b, 0.0, &mut c);
                let am = if *ta { a.t() } else { a.clone() };
                let bm = if *tb { b.t() } else { b.clone() };
                let reference = am.matmul(&bm);
                assert_bitwise(&c, &reference, "transposed gemm vs materialized")
            },
        );
    }

    /// Fused accumulation (`beta = 1`) and scaling (`alpha`) bit-match the
    /// allocating `add`/`scale` composition they replace in the BPTT.
    #[test]
    fn fused_accumulate_bitwise_matches_add_of_product() {
        forall(
            24,
            |rng| {
                let (m, k, n) = ragged_dims(rng);
                let a = Matrix::random_normal(rng, m, k, 1.0);
                let b = Matrix::random_normal(rng, k, n, 1.0);
                let c0 = Matrix::random_normal(rng, m, n, 1.0);
                let alpha = [1.0f32, -1.0, 0.5][rng.below(3) as usize];
                (a, b, c0, alpha)
            },
            |(a, b, c0, alpha)| {
                let mut fused = c0.clone();
                gemm(false, false, *alpha, a, b, 1.0, &mut fused);
                let reference = c0.add(&a.matmul(b).scale(*alpha));
                assert_bitwise(&fused, &reference, "fused accumulate")
            },
        );
    }

    /// `beta = 0` must overwrite without reading `c` — stale workspace
    /// contents (even NaN) cannot leak into the output.
    #[test]
    fn beta_zero_ignores_stale_output_contents() {
        let mut rng = Pcg32::seeded(9);
        let a = Matrix::random_normal(&mut rng, 5, 7, 1.0);
        let b = Matrix::random_normal(&mut rng, 7, 3, 1.0);
        let mut c = Matrix::zeros(5, 3);
        c.data.fill(f32::NAN);
        gemm(false, false, 1.0, &a, &b, 0.0, &mut c);
        assert_bitwise(&c, &a.matmul(&b), "beta=0 with NaN-poisoned c").unwrap();
    }

    /// alpha = 0 / k = 0 reduce to the pure beta term.
    #[test]
    fn degenerate_reductions_apply_beta_only() {
        let mut rng = Pcg32::seeded(10);
        let c0 = Matrix::random_normal(&mut rng, 4, 6, 1.0);
        let a = Matrix::zeros(4, 0);
        let b = Matrix::zeros(0, 6);
        let mut c = c0.clone();
        gemm(false, false, 1.0, &a, &b, 1.0, &mut c);
        assert_bitwise(&c, &c0, "k=0, beta=1 is the identity").unwrap();
        let mut c = c0.clone();
        gemm(false, false, 1.0, &a, &b, 0.0, &mut c);
        assert!(c.data.iter().all(|&x| x == 0.0));
        let a = Matrix::random_normal(&mut rng, 4, 5, 1.0);
        let b = Matrix::random_normal(&mut rng, 5, 6, 1.0);
        let mut c = c0.clone();
        gemm(false, false, 0.0, &a, &b, 2.0, &mut c);
        assert_bitwise(&c, &c0.scale(2.0), "alpha=0 scales by beta").unwrap();
    }

    /// The frozen PR-4 kernel shares the accumulation contract, so old
    /// and new paths agree to the last bit — the property that lets
    /// `benches/bptt_native` attribute its speedup to structure, not to
    /// numerics drift.
    #[test]
    fn legacy_kernel_bitwise_matches_gemm() {
        forall(
            16,
            |rng| {
                let (m, k, n) = ragged_dims(rng);
                let a = Matrix::random_normal(rng, m, k, 1.0);
                let b = Matrix::random_normal(rng, k, n, 1.0);
                (a, b)
            },
            |(a, b)| assert_bitwise(&legacy::matmul(a, b), &a.matmul(b), "legacy vs gemm"),
        );
    }

    #[test]
    fn rows_smaller_than_thread_count_still_correct() {
        // m = 1 with a wide reduction exceeds the cutoff but cannot be
        // split into more than one band.
        let mut rng = Pcg32::seeded(7);
        let a = Matrix::random_normal(&mut rng, 1, 700, 1.0);
        let b = Matrix::random_normal(&mut rng, 700, 600, 1.0);
        let fast = matmul_blocked(&a, &b);
        let slow = matmul_naive(&a, &b);
        assert_close(&fast.data, &slow.data, 1e-4).unwrap();
    }

    #[test]
    fn degenerate_dims_produce_zero_shapes() {
        let a = Matrix::zeros(3, 0);
        let b = Matrix::zeros(0, 4);
        let c = matmul_blocked(&a, &b);
        assert_eq!((c.rows, c.cols), (3, 4));
        assert!(c.data.iter().all(|&x| x == 0.0));
    }
}
