//! Blocked, cache-tiled, multithreaded GEMM — the hot path under the
//! native execution backend (DESIGN.md §3.1).
//!
//! Two kernels share one accumulation order (k ascending per output
//! element), so they agree bitwise and the property suite can compare
//! them tightly:
//!
//! * [`matmul_naive`] — the reference (i, k, j) triple loop, kept as the
//!   parity baseline for tests and `benches/gemm_native`;
//! * [`matmul_blocked`] — tiles the reduction axis in [`TILE_K`] panels
//!   and the output columns in [`TILE_J`] strips so each `B` panel stays
//!   cache-resident across a whole row band, then splits the row bands
//!   over `std::thread::scope` workers (no extra dependencies).
//!
//! `Matrix::matmul` routes everything here; small products take the
//! single-threaded tiled path (spawning threads under
//! [`PARALLEL_FLOP_CUTOFF`] multiply-adds costs more than it saves).

use crate::linalg::Matrix;

/// Rows of `B` (reduction-axis panel) kept hot while a row band runs.
pub const TILE_K: usize = 64;
/// Output-column strip width: one strip of an output row plus the
/// matching `B` panel columns fit in L1 together.
pub const TILE_J: usize = 256;
/// Multiply-add count below which thread spawn overhead dominates and
/// the single-threaded tiled kernel wins.
pub const PARALLEL_FLOP_CUTOFF: usize = 1 << 18;

/// Reference kernel: straightforward (i, k, j) loop, inner loop
/// contiguous in both `b` and `out` rows.
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let mut out = Matrix::zeros(a.rows, b.cols);
    let n = b.cols;
    for i in 0..a.rows {
        let arow = a.row(i);
        let orow = &mut out.data[i * n..(i + 1) * n];
        for (k, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b.data[k * n..(k + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += aik * bv;
            }
        }
    }
    out
}

/// Tiled kernel over one band of output rows (`i0..i0 + rows`).
///
/// Loop order (kb, jb, i, kk) walks the reduction axis in ascending
/// order for every output element, so results match [`matmul_naive`]
/// bitwise while the `TILE_K x TILE_J` panel of `b` is reused across
/// all rows of the band.
fn band_kernel(a: &[f32], k: usize, n: usize, i0: usize, out_band: &mut [f32], b: &[f32]) {
    if n == 0 {
        return;
    }
    let rows = out_band.len() / n;
    let mut kb = 0;
    while kb < k {
        let kend = (kb + TILE_K).min(k);
        let mut jb = 0;
        while jb < n {
            let jend = (jb + TILE_J).min(n);
            for i in 0..rows {
                let arow = &a[(i0 + i) * k..(i0 + i) * k + k];
                let orow = &mut out_band[i * n + jb..i * n + jend];
                for (kk, &aik) in arow[kb..kend].iter().enumerate() {
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b[(kb + kk) * n + jb..(kb + kk) * n + jend];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += aik * bv;
                    }
                }
            }
            jb = jend;
        }
        kb = kend;
    }
}

fn hardware_threads() -> usize {
    std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
}

/// GEMMs currently executing on this process.  Concurrent callers (e.g.
/// serve worker threads each running a fused batch) split the hardware
/// thread budget instead of each spawning `available_parallelism()`
/// threads and oversubscribing the CPU.
static ACTIVE_GEMMS: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// RAII registration in [`ACTIVE_GEMMS`] (panic-safe decrement).
struct GemmSlot {
    budget: usize,
}

impl GemmSlot {
    fn acquire() -> GemmSlot {
        use std::sync::atomic::Ordering;
        let active = ACTIVE_GEMMS.fetch_add(1, Ordering::Relaxed) + 1;
        GemmSlot { budget: (hardware_threads() / active).max(1) }
    }
}

impl Drop for GemmSlot {
    fn drop(&mut self) {
        ACTIVE_GEMMS.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
    }
}

/// Blocked, multithreaded matmul: `out = a @ b`.
///
/// Output rows are split into contiguous bands, one scoped thread per
/// band; bands are disjoint `&mut` slices of the output buffer, so no
/// synchronization is needed beyond the scope join.
pub fn matmul_blocked(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut out = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return out;
    }
    if m * k * n < PARALLEL_FLOP_CUTOFF {
        band_kernel(&a.data, k, n, 0, &mut out.data, &b.data);
        return out;
    }
    let slot = GemmSlot::acquire();
    let threads = slot.budget.min(m);
    if threads <= 1 {
        band_kernel(&a.data, k, n, 0, &mut out.data, &b.data);
        return out;
    }
    let rows_per = m.div_ceil(threads);
    let (a_data, b_data) = (&a.data[..], &b.data[..]);
    std::thread::scope(|s| {
        for (band_idx, out_band) in out.data.chunks_mut(rows_per * n).enumerate() {
            s.spawn(move || {
                band_kernel(a_data, k, n, band_idx * rows_per, out_band, b_data);
            });
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, forall};

    /// The acceptance property: blocked/threaded output equals the naive
    /// reference across ragged shapes, including dims smaller than a tile
    /// and bands that do not divide the thread count evenly.
    #[test]
    fn blocked_matches_naive_on_ragged_shapes() {
        forall(
            24,
            |rng| {
                let m = 1 + rng.below(TILE_K as u32 + 13) as usize;
                let k = 1 + rng.below(TILE_K as u32 + 29) as usize;
                let n = 1 + rng.below(TILE_J as u32 + 17) as usize;
                let a = Matrix::random_normal(rng, m, k, 1.0);
                let b = Matrix::random_normal(rng, k, n, 1.0);
                (a, b)
            },
            |(a, b)| {
                let fast = matmul_blocked(a, b);
                let slow = matmul_naive(a, b);
                assert_close(&fast.data, &slow.data, 1e-5)
            },
        );
    }

    #[test]
    fn blocked_matches_naive_above_parallel_cutoff() {
        // 97 * 83 * 101 multiply-adds exceed PARALLEL_FLOP_CUTOFF — force
        // the threaded band path plus a ragged last band.
        forall(
            3,
            |rng| {
                let a = Matrix::random_normal(rng, 97, 83, 1.0);
                let b = Matrix::random_normal(rng, 83, 101, 1.0);
                (a, b)
            },
            |(a, b)| {
                let fast = matmul_blocked(a, b);
                let slow = matmul_naive(a, b);
                assert_close(&fast.data, &slow.data, 1e-5)
            },
        );
    }

    #[test]
    fn degenerate_dims_produce_zero_shapes() {
        let a = Matrix::zeros(3, 0);
        let b = Matrix::zeros(0, 4);
        let c = matmul_blocked(&a, &b);
        assert_eq!((c.rows, c.cols), (3, 4));
        assert!(c.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn rows_smaller_than_thread_count_still_correct() {
        // m = 1 with a wide reduction exceeds the cutoff but cannot be
        // split into more than one band.
        let mut rng = crate::util::rng::Pcg32::seeded(7);
        let a = Matrix::random_normal(&mut rng, 1, 700, 1.0);
        let b = Matrix::random_normal(&mut rng, 700, 600, 1.0);
        let fast = matmul_blocked(&a, &b);
        let slow = matmul_naive(&a, &b);
        assert_close(&fast.data, &slow.data, 1e-4).unwrap();
    }
}
