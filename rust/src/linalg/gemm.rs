//! Transpose-aware, allocation-free GEMM — the hot path under the native
//! execution backend (DESIGN.md §3.1, §3.3).
//!
//! The single entry point is [`gemm`]:
//!
//! ```text
//! C = beta * C + alpha * op(A) @ op(B)      op(X) = X or X^T
//! ```
//!
//! which subsumes every product the CWY forward/backward substrate needs
//! (NN, NT, TN — and TT for completeness) *without materializing a
//! transposed copy as a fresh `Matrix`* and *without allocating the
//! output*: transposed operands are packed once per call into a
//! thread-local panel buffer that is reused across calls, so the packed
//! rows stream cache-friendly through the same microkernel the plain
//! path uses, and steady-state callers perform zero heap allocations.
//!
//! # Accumulation-order contract (bitwise parity)
//!
//! The whole test suite leans on one invariant, inherited from the seed:
//! every output element is a single serial sum over `k` in ascending
//! order, with the `a_ik == 0.0` skip applied identically everywhere.
//! The microkernel therefore accumulates each `C` element into a
//! zero-initialized scratch row (full `k` sweep) and only then combines
//! `beta * c + alpha * acc` in one rounding step per term.  Consequences:
//!
//! * `gemm(NN, 1, A, B, 0, C)` is bitwise identical to [`matmul_naive`];
//! * `gemm(TN/NT/TT, ...)` is bitwise identical to materializing the
//!   transpose(s) and calling the NN path (packing reorders memory, not
//!   arithmetic);
//! * `gemm(_, _, α, A, B, 1, C)` is bitwise identical to
//!   `C.add(&product.scale(α))`, so fused accumulation can replace the
//!   allocating `add`/`sub` chains with no numeric drift at all.
//!
//! # Kernel dispatch (portable vs AVX2+FMA)
//!
//! The microkernel exists in two forms behind a one-time runtime dispatch
//! ([`active_kernel`]):
//!
//! * [`KernelKind::Portable`] — the scalar strip kernel above, kept
//!   bit-for-bit.  Its inner loop runs independent per-column accumulator
//!   chains, so LLVM may autovectorize it *without* changing any rounding
//!   (reassociation and FMA contraction are never licensed), and every
//!   bitwise guarantee in this module continues to hold.
//! * [`KernelKind::Avx2Fma`] — an explicit `std::arch` register-tiled
//!   kernel: an `MR x NR` = 4x16 tile held in eight 8-lane FMA
//!   accumulators, fed by lane-contiguous packed-B panels with software
//!   prefetch on the streaming panel.  Each `C` element is STILL one
//!   ascending-`k` chain (a fixed lane of a fixed accumulator register),
//!   so transpose variants and fused-beta forms remain bitwise-consistent
//!   *within* this kernel; but FMA's single rounding per multiply-add and
//!   the dropped `a_ik == 0.0` skip mean its results drift from the
//!   portable kernel by O(k·eps).  Cross-kernel assertions are therefore
//!   tolerance-based (DESIGN.md §3.3), while portable-vs-oracle stays
//!   bitwise.
//!
//! Dispatch is decided once per process — `CWY_PORTABLE_KERNEL=1` forces
//! the fallback, non-x86_64 builds always take it — and published to the
//! telemetry registry as the `kernel_dispatch` gauge so `cwy client
//! --stats` and trace exports show which kernel actually ran.
//! [`gemm_with`] pins a kernel explicitly; the parity property tests use
//! it to exercise both paths in one process on one host.
//!
//! The frozen PR-4 kernel lives in [`legacy`] as the measurement baseline
//! for `benches/bptt_native` / the BENCH trajectory files and as a
//! bitwise parity oracle for the packed portable path.

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::linalg::Matrix;

/// Output-column strip width of the portable kernel: one scratch strip
/// (4 rows x TILE_J) plus the streamed `op(B)` row segment stay
/// L1-resident.
pub const TILE_J: usize = 128;
/// Microkernel height: output rows per block, each an independent
/// accumulator chain.  Shared by the portable strip and the SIMD register
/// tile.
pub const MR: usize = 4;
/// SIMD register-tile width: two 8-lane AVX2 accumulators per row.
pub const NR: usize = 16;
/// f32 lanes per AVX2 vector.
pub const LANES: usize = 8;
/// Multiply-add count below which parallel-dispatch overhead dominates
/// and the single-threaded kernel wins.  The persistent pool
/// ([`crate::linalg::pool`]) made dispatch much cheaper than the old
/// per-call `thread::scope` spawn, but a band handoff still costs
/// cross-core cache traffic, so small products stay inline.
pub const PARALLEL_FLOP_CUTOFF: usize = 1 << 18;

/// Which microkernel a [`gemm_with`] call runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Scalar strip kernel with the bitwise-stable accumulation order.
    Portable,
    /// Explicit AVX2+FMA register tile.  Requesting it on a host without
    /// avx2+fma (or on a non-x86_64 build) silently falls back to
    /// [`KernelKind::Portable`] instead of faulting.
    Avx2Fma,
}

impl KernelKind {
    /// Label used by the telemetry `kernel_dispatch` gauge and the bench
    /// trajectory files.
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Portable => "portable",
            KernelKind::Avx2Fma => "avx2fma",
        }
    }
}

/// Host support for the AVX2+FMA kernel, independent of the dispatch
/// override — so `gemm_with(Avx2Fma, ..)` can honor an explicit request
/// even when `CWY_PORTABLE_KERNEL` pinned the *default* to portable.
#[cfg(target_arch = "x86_64")]
fn simd_supported() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn simd_supported() -> bool {
    false
}

/// The process-wide kernel choice: detected once, published to the
/// telemetry `kernel_dispatch` gauge, then immutable.
///
/// `CWY_PORTABLE_KERNEL` set to anything but `0`/empty forces the
/// portable fallback — CI uses it to exercise that path on AVX2 hosts.
pub fn active_kernel() -> KernelKind {
    static ACTIVE: OnceLock<KernelKind> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let forced_portable = std::env::var("CWY_PORTABLE_KERNEL")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        let kind = if !forced_portable && simd_supported() {
            KernelKind::Avx2Fma
        } else {
            KernelKind::Portable
        };
        crate::telemetry::global().set_kernel_dispatch(match kind {
            KernelKind::Portable => crate::telemetry::KERNEL_PORTABLE,
            KernelKind::Avx2Fma => crate::telemetry::KERNEL_AVX2FMA,
        });
        kind
    })
}

/// Fold an explicit kernel request onto what the host can actually run:
/// `Avx2Fma` without avx2+fma support (or off x86_64) becomes
/// `Portable`.  The operand cache keys packs by the RESOLVED kernel so a
/// pack built on one host layout is never consumed by the other.
pub(crate) fn resolve_kernel(kind: KernelKind) -> KernelKind {
    if kind == KernelKind::Avx2Fma && !simd_supported() {
        KernelKind::Portable
    } else {
        kind
    }
}

/// SIMD panel packing for the operand cache — same routine the per-call
/// path uses, so cached panels are byte-identical to per-call panels.
#[cfg(target_arch = "x86_64")]
pub(crate) fn pack_panels_for(src: &[f32], k: usize, n: usize, dst: &mut Vec<f32>) {
    avx2::pack_panels(src, k, n, dst);
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn pack_panels_for(_src: &[f32], _k: usize, _n: usize, _dst: &mut Vec<f32>) {
    unreachable!("SIMD panels are only packed when the avx2 kernel resolves (x86_64 only)");
}

/// Runtime cap on gemm worker threads (0 = use available parallelism).
/// Overrides `CWY_GEMM_THREADS`; `benches/rollout_e2e` uses it for the
/// committed 1/2/4-thread scaling rows.  Band partitioning never changes
/// per-element arithmetic, so results are identical at any cap.
pub fn set_thread_cap(cap: usize) {
    THREAD_CAP.store(cap, Ordering::Relaxed);
}

static THREAD_CAP: AtomicUsize = AtomicUsize::new(0);

/// Threads the process is configured for, BEFORE any runtime
/// [`set_thread_cap`] override: `CWY_GEMM_THREADS` if set, else
/// `available_parallelism`.  The persistent pool sizes its worker set
/// from this once at start (`CWY_GEMM_THREADS=1` degrades it to zero
/// workers); [`hardware_threads`] layers the runtime cap on top for
/// per-dispatch band counts.
pub(crate) fn configured_threads() -> usize {
    static ENV_CAP: OnceLock<usize> = OnceLock::new();
    let env_cap = *ENV_CAP.get_or_init(|| {
        std::env::var("CWY_GEMM_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(0)
    });
    if env_cap > 0 {
        return env_cap;
    }
    std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
}

fn hardware_threads() -> usize {
    let cap = THREAD_CAP.load(Ordering::Relaxed);
    if cap > 0 {
        return cap;
    }
    configured_threads()
}

/// Reference kernel: straightforward (i, k, j) loop, inner loop
/// contiguous in both `b` and `out` rows.  Kept allocating and simple —
/// it is the parity baseline for tests and `benches/gemm_native`.
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let mut out = Matrix::zeros(a.rows, b.cols);
    let n = b.cols;
    for i in 0..a.rows {
        let arow = a.row(i);
        let orow = &mut out.data[i * n..(i + 1) * n];
        for (k, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b.data[k * n..(k + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += aik * bv;
            }
        }
    }
    out
}

/// GEMMs currently executing on this process.  Concurrent callers (e.g.
/// serve worker threads each running a fused batch) split the hardware
/// thread budget instead of each spawning `available_parallelism()`
/// threads and oversubscribing the CPU.
static ACTIVE_GEMMS: AtomicUsize = AtomicUsize::new(0);

/// RAII registration in [`ACTIVE_GEMMS`] (panic-safe decrement).
struct GemmSlot {
    budget: usize,
    registered: bool,
}

impl GemmSlot {
    fn acquire() -> GemmSlot {
        // Pool-aware budget (ISSUE 9): a gemm issued from inside a
        // pooled band already owns exactly one pool thread's share of
        // the machine, so it runs inline — and does NOT register in
        // ACTIVE_GEMMS, so sibling top-level gemms keep their split of
        // the one shared cap.  This is what lets rollout-over-batch-rows
        // parallelism compose with GEMM band parallelism without
        // oversubscription.
        if crate::linalg::pool::in_pool_context() {
            return GemmSlot { budget: 1, registered: false };
        }
        let active = ACTIVE_GEMMS.fetch_add(1, Ordering::Relaxed) + 1;
        GemmSlot { budget: (hardware_threads() / active).max(1), registered: true }
    }
}

impl Drop for GemmSlot {
    fn drop(&mut self) {
        if self.registered {
            ACTIVE_GEMMS.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// The thread budget a gemm issued right now would get — test hook for
/// the nested-parallelism regression in `linalg::pool`.
#[cfg(test)]
pub(crate) fn current_gemm_budget() -> usize {
    GemmSlot::acquire().budget
}

thread_local! {
    /// Reused packing buffers for transposed operands (`op = ^T`).  They
    /// grow to the largest panel a thread ever needs and then serve every
    /// later call allocation-free; per-thread residency is bounded by the
    /// largest transposed operand the workload touches.
    static PACK_A: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    static PACK_B: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    /// Lane-contiguous `op(B)` panels for the SIMD kernel — same reuse
    /// discipline as the transpose packs, so the SIMD path adds no
    /// steady-state allocations (tests/alloc_discipline.rs).
    static PACK_PANELS: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Pack `src` (r x c, row-major) transposed into `dst` (c x r, row-major),
/// reusing `dst`'s capacity.  Reorders memory only — every later
/// multiply-add sees the same values in the same `k` order.  Shared with
/// the [`crate::linalg::pack`] operand cache, which stores exactly this
/// layout so packed calls stay bitwise-identical to per-call packing.
pub(crate) fn pack_transposed(src: &Matrix, dst: &mut Vec<f32>) {
    let (r, c) = (src.rows, src.cols);
    dst.clear();
    dst.resize(r * c, 0.0);
    for i in 0..r {
        let srow = &src.data[i * c..(i + 1) * c];
        for (j, &v) in srow.iter().enumerate() {
            dst[j * r + i] = v;
        }
    }
}

/// The portable microkernel over one band of output rows (`i0..i0 + rows`).
///
/// `x` is `op(A)` row-major (m x k), `bp` is `op(B)` row-major (k x n);
/// `cband` holds rows `i0..` of `C`.  Each element's sum is accumulated
/// in a scratch strip over the full ascending `k` range, then combined
/// as `beta * c + alpha * acc` in a single pass — see the module docs
/// for why this exact shape is load-bearing.
#[allow(clippy::too_many_arguments)]
fn band_kernel(
    x: &[f32],
    kdim: usize,
    n: usize,
    i0: usize,
    alpha: f32,
    beta: f32,
    bp: &[f32],
    cband: &mut [f32],
) {
    if n == 0 {
        return;
    }
    let rows = cband.len() / n;
    let mut scratch = [0.0f32; MR * TILE_J];
    let mut jb = 0;
    while jb < n {
        let jw = TILE_J.min(n - jb);
        let mut i = 0;
        // 4-row blocks: one streamed bp row feeds four accumulator rows.
        while i + MR <= rows {
            let (s0, rest) = scratch.split_at_mut(jw);
            let (s1, rest) = rest.split_at_mut(jw);
            let (s2, rest) = rest.split_at_mut(jw);
            let s3 = &mut rest[..jw];
            s0.fill(0.0);
            s1.fill(0.0);
            s2.fill(0.0);
            s3.fill(0.0);
            let x0 = &x[(i0 + i) * kdim..(i0 + i + 1) * kdim];
            let x1 = &x[(i0 + i + 1) * kdim..(i0 + i + 2) * kdim];
            let x2 = &x[(i0 + i + 2) * kdim..(i0 + i + 3) * kdim];
            let x3 = &x[(i0 + i + 3) * kdim..(i0 + i + 4) * kdim];
            for kk in 0..kdim {
                let brow = &bp[kk * n + jb..kk * n + jb + jw];
                let (a0, a1, a2, a3) = (x0[kk], x1[kk], x2[kk], x3[kk]);
                if a0 != 0.0 {
                    for (s, &bv) in s0.iter_mut().zip(brow) {
                        *s += a0 * bv;
                    }
                }
                if a1 != 0.0 {
                    for (s, &bv) in s1.iter_mut().zip(brow) {
                        *s += a1 * bv;
                    }
                }
                if a2 != 0.0 {
                    for (s, &bv) in s2.iter_mut().zip(brow) {
                        *s += a2 * bv;
                    }
                }
                if a3 != 0.0 {
                    for (s, &bv) in s3.iter_mut().zip(brow) {
                        *s += a3 * bv;
                    }
                }
            }
            for (r, srow) in [&*s0, &*s1, &*s2, &*s3].into_iter().enumerate() {
                combine(&mut cband[(i + r) * n + jb..(i + r) * n + jb + jw], srow, alpha, beta);
            }
            i += MR;
        }
        // Remainder rows, one accumulator chain each.
        while i < rows {
            let s0 = &mut scratch[..jw];
            s0.fill(0.0);
            let xr = &x[(i0 + i) * kdim..(i0 + i + 1) * kdim];
            for (kk, &aik) in xr.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &bp[kk * n + jb..kk * n + jb + jw];
                for (s, &bv) in s0.iter_mut().zip(brow) {
                    *s += aik * bv;
                }
            }
            combine(&mut cband[i * n + jb..i * n + jb + jw], s0, alpha, beta);
            i += 1;
        }
        jb += jw;
    }
}

/// `c = beta * c + alpha * s`, one rounding per term so the fused form
/// matches `c.scale(beta).add(&product.scale(alpha))` bitwise.  `beta == 0`
/// never reads `c` (the buffer may hold stale workspace contents).
///
/// Deliberately scalar and shared by both microkernels: Rust never
/// licenses FP contraction, so this compiles to plain mul/add even when
/// inlined into the FMA kernel, and the fused-beta bitwise guarantees
/// hold per-kernel.
#[inline]
fn combine(crow: &mut [f32], srow: &[f32], alpha: f32, beta: f32) {
    if beta == 0.0 {
        for (c, &s) in crow.iter_mut().zip(srow) {
            *c = alpha * s;
        }
    } else if beta == 1.0 {
        for (c, &s) in crow.iter_mut().zip(srow) {
            *c += alpha * s;
        }
    } else {
        for (c, &s) in crow.iter_mut().zip(srow) {
            *c = beta * *c + alpha * s;
        }
    }
}

/// Explicit AVX2+FMA microkernel (x86_64 only) — see the module docs for
/// the register-tile shape and the numeric contract it keeps vs. trades.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{combine, LANES, MR, NR};
    use core::arch::x86_64::*;

    /// Distance (in k-steps) the streaming-panel prefetch runs ahead of
    /// the FMA loop: 16 steps x 16 lanes x 4 B = two panel cache lines in
    /// flight — enough to cover L2 latency without thrashing L1.
    const PREFETCH_K: usize = 16;

    /// Pack `op(B)` (row-major `kdim x n`) into lane-contiguous panels:
    /// `dst[p*kdim*NR + kk*NR + lane] = b[kk*n + p*NR + lane]`, with the
    /// rightmost panel zero-padded so the microkernel always loads two
    /// full vectors per k-step.
    pub fn pack_panels(b: &[f32], kdim: usize, n: usize, dst: &mut Vec<f32>) {
        let panels = n.div_ceil(NR);
        dst.clear();
        dst.resize(panels * kdim * NR, 0.0);
        for p in 0..panels {
            let jb = p * NR;
            let jw = NR.min(n - jb);
            let base = p * kdim * NR;
            for kk in 0..kdim {
                dst[base + kk * NR..base + kk * NR + jw]
                    .copy_from_slice(&b[kk * n + jb..kk * n + jb + jw]);
            }
        }
    }

    /// Spill one row's accumulator pair to `stash` (lane order = column
    /// order within the panel).
    #[inline]
    unsafe fn spill(stash: &mut [f32; NR], lo: __m256, hi: __m256) {
        _mm256_storeu_ps(stash.as_mut_ptr(), lo);
        _mm256_storeu_ps(stash.as_mut_ptr().add(LANES), hi);
    }

    /// AVX2+FMA band kernel over output rows `i0..i0+rows` of `C`.
    ///
    /// `x` is `op(A)` row-major (full matrix, m x kdim); `panels` is the
    /// [`pack_panels`] layout of `op(B)`; `cband` holds rows `i0..` of
    /// `C`.  Each `C` element is one lane of one accumulator register —
    /// a single ascending-`k` FMA chain, combined once via [`combine`] —
    /// the same per-element shape as the portable kernel up to FMA
    /// rounding and the dropped zero-skip.
    ///
    /// # Safety
    /// The host must support avx2 and fma (checked by the dispatcher).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn band_kernel(
        x: &[f32],
        kdim: usize,
        n: usize,
        i0: usize,
        alpha: f32,
        beta: f32,
        panels: &[f32],
        cband: &mut [f32],
    ) {
        if n == 0 {
            return;
        }
        let rows = cband.len() / n;
        let mut stash = [0.0f32; NR];
        for p in 0..n.div_ceil(NR) {
            let jb = p * NR;
            let jw = NR.min(n - jb);
            let pbase = panels.as_ptr().add(p * kdim * NR);
            let mut i = 0;
            // 4x16 register tile: eight live accumulators, two panel
            // loads and four broadcasts feeding eight FMAs per k-step.
            while i + MR <= rows {
                let x0 = x.as_ptr().add((i0 + i) * kdim);
                let x1 = x0.add(kdim);
                let x2 = x1.add(kdim);
                let x3 = x2.add(kdim);
                let mut c00 = _mm256_setzero_ps();
                let mut c01 = _mm256_setzero_ps();
                let mut c10 = _mm256_setzero_ps();
                let mut c11 = _mm256_setzero_ps();
                let mut c20 = _mm256_setzero_ps();
                let mut c21 = _mm256_setzero_ps();
                let mut c30 = _mm256_setzero_ps();
                let mut c31 = _mm256_setzero_ps();
                for kk in 0..kdim {
                    let bptr = pbase.add(kk * NR);
                    // wrapping_add: running past the panel end is fine
                    // for a prefetch but must not be `add` UB.
                    _mm_prefetch::<_MM_HINT_T0>(bptr.wrapping_add(PREFETCH_K * NR) as *const i8);
                    let b0 = _mm256_loadu_ps(bptr);
                    let b1 = _mm256_loadu_ps(bptr.add(LANES));
                    let a0 = _mm256_set1_ps(*x0.add(kk));
                    c00 = _mm256_fmadd_ps(a0, b0, c00);
                    c01 = _mm256_fmadd_ps(a0, b1, c01);
                    let a1 = _mm256_set1_ps(*x1.add(kk));
                    c10 = _mm256_fmadd_ps(a1, b0, c10);
                    c11 = _mm256_fmadd_ps(a1, b1, c11);
                    let a2 = _mm256_set1_ps(*x2.add(kk));
                    c20 = _mm256_fmadd_ps(a2, b0, c20);
                    c21 = _mm256_fmadd_ps(a2, b1, c21);
                    let a3 = _mm256_set1_ps(*x3.add(kk));
                    c30 = _mm256_fmadd_ps(a3, b0, c30);
                    c31 = _mm256_fmadd_ps(a3, b1, c31);
                }
                for (r, (lo, hi)) in
                    [(c00, c01), (c10, c11), (c20, c21), (c30, c31)].into_iter().enumerate()
                {
                    spill(&mut stash, lo, hi);
                    let crow = &mut cband[(i + r) * n + jb..(i + r) * n + jb + jw];
                    combine(crow, &stash[..jw], alpha, beta);
                }
                i += MR;
            }
            // Row tail: one 1x16 tile per remaining row.
            while i < rows {
                let xr = x.as_ptr().add((i0 + i) * kdim);
                let mut lo = _mm256_setzero_ps();
                let mut hi = _mm256_setzero_ps();
                for kk in 0..kdim {
                    let bptr = pbase.add(kk * NR);
                    let a = _mm256_set1_ps(*xr.add(kk));
                    lo = _mm256_fmadd_ps(a, _mm256_loadu_ps(bptr), lo);
                    hi = _mm256_fmadd_ps(a, _mm256_loadu_ps(bptr.add(LANES)), hi);
                }
                spill(&mut stash, lo, hi);
                let crow = &mut cband[i * n + jb..i * n + jb + jw];
                combine(crow, &stash[..jw], alpha, beta);
                i += 1;
            }
        }
    }
}

/// Split `c` into row bands and run `kernel` on each — single-threaded
/// below [`PARALLEL_FLOP_CUTOFF`] multiply-adds, dispatched to the
/// persistent pool ([`crate::linalg::pool`]) above, with the thread
/// budget shared across concurrent gemms and capped by
/// [`set_thread_cap`] / `CWY_GEMM_THREADS`.
///
/// The band partition is exactly the pre-pool `chunks_mut(rows_per * n)`
/// split — `rows_per = m.div_ceil(threads)`, last band ragged — so the
/// ascending-`k` accumulation contract (module docs) is untouched: band
/// boundaries reorder which thread computes a row, never the arithmetic
/// inside it.
fn for_each_band<F>(m: usize, k: usize, n: usize, c: &mut [f32], kernel: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if m * k * n < PARALLEL_FLOP_CUTOFF {
        kernel(0, c);
        return;
    }
    let slot = GemmSlot::acquire();
    let threads = slot.budget.min(m);
    if threads <= 1 {
        kernel(0, c);
        return;
    }
    let rows_per = m.div_ceil(threads);
    let band_elems = rows_per * n;
    let len = c.len();
    let base = c.as_mut_ptr() as usize;
    crate::linalg::pool::parallel_for(len.div_ceil(band_elems), &|band_idx| {
        let start = band_idx * band_elems;
        let end = (start + band_elems).min(len);
        // SAFETY: band indices address disjoint half-open ranges of `c`,
        // and `parallel_for` blocks until every band completes, so no
        // band slice outlives (or aliases within) the `c` borrow.
        let band =
            unsafe { std::slice::from_raw_parts_mut((base as *mut f32).add(start), end - start) };
        kernel(band_idx * rows_per, band);
    });
}

/// General matrix multiply-accumulate: `c = beta*c + alpha*op(a)@op(b)`,
/// with `op` selected per operand by `trans_a` / `trans_b`, on the
/// microkernel chosen by [`active_kernel`].
///
/// * No allocation of the output — `c` must be preshaped to
///   `(op(a).rows, op(b).cols)` (asserted).
/// * Transposed operands are packed into reused thread-local panels, so
///   `x.t().matmul(&y)`-style call sites collapse to one call with zero
///   temporaries (transpose-variant cheat sheet in DESIGN.md §3.3).
/// * `beta = 0.0` overwrites (never reads) `c`; `beta = 1.0` fuses the
///   `d += a@b` accumulation pattern of the BPTT.
/// * Output rows split across the persistent pool above
///   [`PARALLEL_FLOP_CUTOFF`] multiply-adds — same band partition the
///   scoped-thread path used, now without a spawn/join per call.
pub fn gemm(
    trans_a: bool,
    trans_b: bool,
    alpha: f32,
    a: &Matrix,
    b: &Matrix,
    beta: f32,
    c: &mut Matrix,
) {
    gemm_with(active_kernel(), trans_a, trans_b, alpha, a, b, beta, c)
}

/// [`gemm`] with the microkernel pinned explicitly — the kernel-parity
/// property tests use this to exercise both dispatch paths in one
/// process.  An `Avx2Fma` request on a host without avx2+fma falls back
/// to the portable kernel rather than faulting.
#[allow(clippy::too_many_arguments)]
pub fn gemm_with(
    kind: KernelKind,
    trans_a: bool,
    trans_b: bool,
    alpha: f32,
    a: &Matrix,
    b: &Matrix,
    beta: f32,
    c: &mut Matrix,
) {
    let (m, ka) = if trans_a { (a.cols, a.rows) } else { (a.rows, a.cols) };
    let (kb, n) = if trans_b { (b.cols, b.rows) } else { (b.rows, b.cols) };
    assert_eq!(ka, kb, "gemm reduction-dim mismatch");
    assert_eq!((c.rows, c.cols), (m, n), "gemm output shape mismatch");
    let k = ka;
    let kind = resolve_kernel(kind);
    // Per-variant telemetry: ~two clock reads and three relaxed atomic
    // adds per call — no lock, no allocation (alloc_discipline covers
    // this path with recording live).
    let gemm_span = match (trans_a, trans_b) {
        (false, false) => crate::span!(gemm_nn),
        (false, true) => crate::span!(gemm_nt),
        (true, false) => crate::span!(gemm_tn),
        (true, true) => crate::span!(gemm_tt),
    };
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 || alpha == 0.0 {
        // No products contribute; only the beta term remains.
        if beta == 0.0 {
            c.data.fill(0.0);
        } else if beta != 1.0 {
            for v in &mut c.data {
                *v *= beta;
            }
        }
        return;
    }
    // Only calls that reach the product loops count FLOPs; the beta-only
    // early-outs above perform no multiply-adds.
    crate::telemetry::global()
        .add_gemm_flops(gemm_span.id(), crate::orthogonal::flops::gemm_flops(m, k, n));
    PACK_A.with(|pa| {
        PACK_B.with(|pb| {
            let (mut pa, mut pb) = (pa.borrow_mut(), pb.borrow_mut());
            if trans_a {
                pack_transposed(a, &mut pa);
            }
            if trans_b {
                pack_transposed(b, &mut pb);
            }
            let x: &[f32] = if trans_a { &pa } else { &a.data };
            let bp: &[f32] = if trans_b { &pb } else { &b.data };
            match kind {
                #[cfg(target_arch = "x86_64")]
                KernelKind::Avx2Fma => PACK_PANELS.with(|pp| {
                    let mut pp = pp.borrow_mut();
                    avx2::pack_panels(bp, k, n, &mut pp);
                    let panels: &[f32] = &pp;
                    for_each_band(m, k, n, &mut c.data, |i0, band| {
                        // SAFETY: the `kind` fold above established
                        // avx2+fma support via `simd_supported`.
                        unsafe { avx2::band_kernel(x, k, n, i0, alpha, beta, panels, band) }
                    });
                }),
                _ => for_each_band(m, k, n, &mut c.data, |i0, band| {
                    band_kernel(x, k, n, i0, alpha, beta, bp, band)
                }),
            }
        })
    });
}

/// [`gemm`] with `op(b)`'s packing stage served from a
/// [`crate::linalg::pack::PackedOperand`] built by `ensure` — the
/// per-call `PACK_B`/`PACK_PANELS` work drops out.  This is the operand
/// cache's win: a rollout multiplies against the same CWY operator
/// matrices at all T timesteps, so the operator is packed once per tape
/// rebuild instead of once per gemm call.
///
/// `trans_b` and the active kernel must match what the pack was built
/// for (asserted — a stale pack fails loudly, it never multiplies
/// against dead bytes).  Results are bitwise identical to the
/// equivalent [`gemm`] call: the cached pack holds exactly the bytes the
/// per-call path would have packed, consumed in the same order by the
/// same kernel.
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed(
    trans_a: bool,
    trans_b: bool,
    alpha: f32,
    a: &Matrix,
    b: &Matrix,
    pack: &crate::linalg::pack::PackedOperand,
    beta: f32,
    c: &mut Matrix,
) {
    let (m, ka) = if trans_a { (a.cols, a.rows) } else { (a.rows, a.cols) };
    let (kb, n) = if trans_b { (b.cols, b.rows) } else { (b.rows, b.cols) };
    assert_eq!(ka, kb, "gemm reduction-dim mismatch");
    assert_eq!((c.rows, c.cols), (m, n), "gemm output shape mismatch");
    let k = ka;
    let kind = resolve_kernel(active_kernel());
    assert!(
        pack.matches(b, trans_b, kind),
        "gemm_packed: operand pack is stale or keyed for a different operand/kernel"
    );
    let gemm_span = match (trans_a, trans_b) {
        (false, false) => crate::span!(gemm_nn),
        (false, true) => crate::span!(gemm_nt),
        (true, false) => crate::span!(gemm_tn),
        (true, true) => crate::span!(gemm_tt),
    };
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 || alpha == 0.0 {
        if beta == 0.0 {
            c.data.fill(0.0);
        } else if beta != 1.0 {
            for v in &mut c.data {
                *v *= beta;
            }
        }
        return;
    }
    crate::telemetry::global()
        .add_gemm_flops(gemm_span.id(), crate::orthogonal::flops::gemm_flops(m, k, n));
    crate::telemetry::global().add_pack_hit();
    PACK_A.with(|pa| {
        let mut pa = pa.borrow_mut();
        if trans_a {
            pack_transposed(a, &mut pa);
        }
        let x: &[f32] = if trans_a { &pa } else { &a.data };
        match kind {
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx2Fma => {
                let panels: &[f32] = &pack.panels;
                for_each_band(m, k, n, &mut c.data, |i0, band| {
                    // SAFETY: `resolve_kernel` only yields Avx2Fma when
                    // `simd_supported` confirmed avx2+fma.
                    unsafe { avx2::band_kernel(x, k, n, i0, alpha, beta, panels, band) }
                });
            }
            _ => {
                let bp: &[f32] = if trans_b { &pack.bt } else { &b.data };
                for_each_band(m, k, n, &mut c.data, |i0, band| {
                    band_kernel(x, k, n, i0, alpha, beta, bp, band)
                });
            }
        }
    });
}

/// Plain product `a @ b` through the [`gemm`] NN path (allocates the
/// output; `Matrix::matmul` routes here).
pub fn matmul_blocked(a: &Matrix, b: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(a.rows, b.cols);
    gemm(false, false, 1.0, a, b, 0.0, &mut out);
    out
}

/// The frozen PR-4 GEMM: blocked/cache-tiled band kernel with per-call
/// output allocation and no transpose awareness.  Kept verbatim as (a)
/// the baseline `benches/bptt_native` / `benches/gemm_native` measure the
/// substrate against, and (b) a bitwise parity oracle — it shares the
/// ascending-`k` accumulation order and zero-skip with the portable
/// [`gemm`] kernel, so those two must agree to the last bit (the SIMD
/// kernel is held to f32-scaled tolerances instead; module docs).
pub mod legacy {
    use super::Matrix;

    const TILE_K: usize = 64;
    /// Frozen PR-4 column-strip width.  Named distinctly from the live
    /// kernel's `gemm::TILE_J = 128` — it used to shadow it as `TILE_J`,
    /// which had already confused the bench tile-sweep comments.
    const LEGACY_TILE_J: usize = 256;

    fn band_kernel(a: &[f32], k: usize, n: usize, i0: usize, out_band: &mut [f32], b: &[f32]) {
        if n == 0 {
            return;
        }
        let rows = out_band.len() / n;
        let mut kb = 0;
        while kb < k {
            let kend = (kb + TILE_K).min(k);
            let mut jb = 0;
            while jb < n {
                let jend = (jb + LEGACY_TILE_J).min(n);
                for i in 0..rows {
                    let arow = &a[(i0 + i) * k..(i0 + i) * k + k];
                    let orow = &mut out_band[i * n + jb..i * n + jend];
                    for (kk, &aik) in arow[kb..kend].iter().enumerate() {
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b[(kb + kk) * n + jb..(kb + kk) * n + jend];
                        for (o, &bv) in orow.iter_mut().zip(brow) {
                            *o += aik * bv;
                        }
                    }
                }
                jb = jend;
            }
            kb = kend;
        }
    }

    /// PR-4 `Matrix::matmul`: allocate + zero the output, run the tiled
    /// band kernel, threading above the same FLOP cutoff.
    pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols, b.rows, "matmul shape mismatch");
        let (m, k, n) = (a.rows, a.cols, b.cols);
        let mut out = Matrix::zeros(m, n);
        if m == 0 || n == 0 || k == 0 {
            return out;
        }
        if m * k * n < super::PARALLEL_FLOP_CUTOFF {
            band_kernel(&a.data, k, n, 0, &mut out.data, &b.data);
            return out;
        }
        let slot = super::GemmSlot::acquire();
        let threads = slot.budget.min(m);
        if threads <= 1 {
            band_kernel(&a.data, k, n, 0, &mut out.data, &b.data);
            return out;
        }
        let rows_per = m.div_ceil(threads);
        let band_elems = rows_per * n;
        let len = out.data.len();
        let base = out.data.as_mut_ptr() as usize;
        let (a_data, b_data) = (&a.data[..], &b.data[..]);
        crate::linalg::pool::parallel_for(len.div_ceil(band_elems), &|band_idx| {
            let start = band_idx * band_elems;
            let end = (start + band_elems).min(len);
            // SAFETY: disjoint bands of `out.data`; the dispatch blocks
            // until every band completes.
            let band = unsafe {
                std::slice::from_raw_parts_mut((base as *mut f32).add(start), end - start)
            };
            band_kernel(a_data, k, n, band_idx * rows_per, band, b_data);
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, forall};
    use crate::util::rng::Pcg32;

    fn bits(m: &Matrix) -> Vec<u32> {
        m.data.iter().map(|x| x.to_bits()).collect()
    }

    fn assert_bitwise(a: &Matrix, b: &Matrix, what: &str) -> Result<(), String> {
        if bits(a) == bits(b) {
            Ok(())
        } else {
            Err(format!("{what}: bitwise mismatch (max |diff| {})", a.max_abs_diff(b)))
        }
    }

    /// `op(a) @ op(b)` on an explicitly pinned kernel.
    fn mm_with(kind: KernelKind, ta: bool, tb: bool, a: &Matrix, b: &Matrix) -> Matrix {
        let (m, n) = (
            if ta { a.cols } else { a.rows },
            if tb { b.rows } else { b.cols },
        );
        let mut c = Matrix::zeros(m, n);
        gemm_with(kind, ta, tb, 1.0, a, b, 0.0, &mut c);
        c
    }

    /// Random shapes spanning the edge cases the satellite demands:
    /// L = 1 / B = 1 rows, dims straddling the strip width and the
    /// microkernel height.
    fn ragged_dims(rng: &mut Pcg32) -> (usize, usize, usize) {
        let pick = |rng: &mut Pcg32| match rng.below(5) {
            0 => 1,
            1 => MR - 1,
            2 => MR + 1,
            _ => 1 + rng.below(TILE_J as u32 + 19) as usize,
        };
        (pick(rng), pick(rng), pick(rng))
    }

    #[test]
    fn portable_nn_bitwise_matches_naive_on_ragged_shapes() {
        forall(
            24,
            |rng| {
                let (m, k, n) = ragged_dims(rng);
                let a = Matrix::random_normal(rng, m, k, 1.0);
                let b = Matrix::random_normal(rng, k, n, 1.0);
                (a, b)
            },
            |(a, b)| {
                let fast = mm_with(KernelKind::Portable, false, false, a, b);
                let slow = matmul_naive(a, b);
                // The accumulation-order contract makes this exact, not
                // approximate — assert the stronger property.
                assert_bitwise(&fast, &slow, "portable NN vs naive")
            },
        );
    }

    #[test]
    fn simd_nn_matches_naive_within_tolerance_on_ragged_shapes() {
        // FMA rounds once per multiply-add and skips no zeros, so the
        // SIMD kernel is held to an f32-scaled tolerance, not bits
        // (module docs).  On hosts without avx2+fma this exercises the
        // explicit-fallback path of `gemm_with` instead.
        forall(
            24,
            |rng| {
                let (m, k, n) = ragged_dims(rng);
                let a = Matrix::random_normal(rng, m, k, 1.0);
                let b = Matrix::random_normal(rng, k, n, 1.0);
                (a, b)
            },
            |(a, b)| {
                let fast = mm_with(KernelKind::Avx2Fma, false, false, a, b);
                let slow = matmul_naive(a, b);
                assert_close(&fast.data, &slow.data, 1e-4)
            },
        );
    }

    /// ISSUE 7 satellite: sweep every microkernel tail regime — rows
    /// around MR, columns around the lane width / register tile / strip
    /// width, k ∈ {0, 1, odd, pow2, pow2+1} — against the naive oracle on
    /// BOTH dispatch paths (portable bitwise, SIMD within tolerance).
    #[test]
    fn microkernel_tail_sweep_on_both_dispatch_paths() {
        let row_cases = [MR - 1, MR, MR + 1];
        let col_cases =
            [LANES - 1, LANES, LANES + 1, NR - 1, NR, NR + 1, TILE_J - 1, TILE_J + 1];
        let k_cases = [0usize, 1, 63, 64, 65];
        let mut rng = Pcg32::seeded(0x51AD);
        for &m in &row_cases {
            for &n in &col_cases {
                for &k in &k_cases {
                    let a = Matrix::random_normal(&mut rng, m, k, 1.0);
                    let b = Matrix::random_normal(&mut rng, k, n, 1.0);
                    let oracle = matmul_naive(&a, &b);
                    let portable = mm_with(KernelKind::Portable, false, false, &a, &b);
                    assert_bitwise(&portable, &oracle, &format!("portable m={m} n={n} k={k}"))
                        .unwrap();
                    let simd = mm_with(KernelKind::Avx2Fma, false, false, &a, &b);
                    assert_close(&simd.data, &oracle.data, 1e-5)
                        .map_err(|e| format!("simd m={m} n={n} k={k}: {e}"))
                        .unwrap();
                }
            }
        }
    }

    #[test]
    fn blocked_matches_naive_above_parallel_cutoff() {
        // 97 * 83 * 101 multiply-adds exceed PARALLEL_FLOP_CUTOFF — force
        // the threaded band path plus a ragged last band.
        forall(
            3,
            |rng| {
                let a = Matrix::random_normal(rng, 97, 83, 1.0);
                let b = Matrix::random_normal(rng, 83, 101, 1.0);
                (a, b)
            },
            |(a, b)| {
                let fast = matmul_blocked(a, b);
                let slow = matmul_naive(a, b);
                assert_close(&fast.data, &slow.data, 1e-5)
            },
        );
    }

    /// NT / TN / TT bit-match materializing the transpose(s) and running
    /// the allocating NN path — packing reorders memory, not arithmetic.
    /// Both sides route through the dispatched kernel, so this holds on
    /// portable AND SIMD (each kernel is self-consistent across variants).
    #[test]
    fn transpose_variants_bitwise_match_materialized() {
        forall(
            24,
            |rng| {
                let (m, k, n) = ragged_dims(rng);
                let (ta, tb) =
                    [(true, false), (false, true), (true, true)][rng.below(3) as usize];
                let a_dims = if ta { (k, m) } else { (m, k) };
                let b_dims = if tb { (n, k) } else { (k, n) };
                let a = Matrix::random_normal(rng, a_dims.0, a_dims.1, 1.0);
                let b = Matrix::random_normal(rng, b_dims.0, b_dims.1, 1.0);
                (ta, tb, a, b, m, n)
            },
            |(ta, tb, a, b, m, n)| {
                let mut c = Matrix::zeros(*m, *n);
                gemm(*ta, *tb, 1.0, a, b, 0.0, &mut c);
                let am = if *ta { a.t() } else { a.clone() };
                let bm = if *tb { b.t() } else { b.clone() };
                let reference = am.matmul(&bm);
                assert_bitwise(&c, &reference, "transposed gemm vs materialized")
            },
        );
    }

    /// The same within-kernel consistency, pinned to the SIMD path
    /// explicitly so it is exercised even when dispatch picks portable.
    #[test]
    fn simd_transpose_variants_bitwise_match_materialized() {
        forall(
            12,
            |rng| {
                let (m, k, n) = ragged_dims(rng);
                let a = Matrix::random_normal(rng, k, m, 1.0); // A^T layout
                let b = Matrix::random_normal(rng, n, k, 1.0); // B^T layout
                (a, b, m, n)
            },
            |(a, b, m, n)| {
                let mut c = Matrix::zeros(*m, *n);
                gemm_with(KernelKind::Avx2Fma, true, true, 1.0, a, b, 0.0, &mut c);
                let reference = mm_with(KernelKind::Avx2Fma, false, false, &a.t(), &b.t());
                assert_bitwise(&c, &reference, "simd TT vs materialized")
            },
        );
    }

    /// Fused accumulation (`beta = 1`) and scaling (`alpha`) bit-match the
    /// allocating `add`/`scale` composition they replace in the BPTT —
    /// per kernel: `combine` is shared and scalar, so this holds on both
    /// dispatch paths (both sides here run the same dispatched kernel).
    #[test]
    fn fused_accumulate_bitwise_matches_add_of_product() {
        forall(
            24,
            |rng| {
                let (m, k, n) = ragged_dims(rng);
                let a = Matrix::random_normal(rng, m, k, 1.0);
                let b = Matrix::random_normal(rng, k, n, 1.0);
                let c0 = Matrix::random_normal(rng, m, n, 1.0);
                let alpha = [1.0f32, -1.0, 0.5][rng.below(3) as usize];
                (a, b, c0, alpha)
            },
            |(a, b, c0, alpha)| {
                let mut fused = c0.clone();
                gemm(false, false, *alpha, a, b, 1.0, &mut fused);
                let reference = c0.add(&a.matmul(b).scale(*alpha));
                assert_bitwise(&fused, &reference, "fused accumulate")
            },
        );
    }

    /// `beta = 0` must overwrite without reading `c` — stale workspace
    /// contents (even NaN) cannot leak into the output.
    #[test]
    fn beta_zero_ignores_stale_output_contents() {
        let mut rng = Pcg32::seeded(9);
        let a = Matrix::random_normal(&mut rng, 5, 7, 1.0);
        let b = Matrix::random_normal(&mut rng, 7, 3, 1.0);
        for kind in [KernelKind::Portable, KernelKind::Avx2Fma] {
            let mut c = Matrix::zeros(5, 3);
            c.data.fill(f32::NAN);
            gemm_with(kind, false, false, 1.0, &a, &b, 0.0, &mut c);
            let reference = mm_with(kind, false, false, &a, &b);
            assert_bitwise(&c, &reference, "beta=0 with NaN-poisoned c").unwrap();
        }
    }

    /// alpha = 0 / k = 0 reduce to the pure beta term.
    #[test]
    fn degenerate_reductions_apply_beta_only() {
        let mut rng = Pcg32::seeded(10);
        let c0 = Matrix::random_normal(&mut rng, 4, 6, 1.0);
        let a = Matrix::zeros(4, 0);
        let b = Matrix::zeros(0, 6);
        let mut c = c0.clone();
        gemm(false, false, 1.0, &a, &b, 1.0, &mut c);
        assert_bitwise(&c, &c0, "k=0, beta=1 is the identity").unwrap();
        let mut c = c0.clone();
        gemm(false, false, 1.0, &a, &b, 0.0, &mut c);
        assert!(c.data.iter().all(|&x| x == 0.0));
        let a = Matrix::random_normal(&mut rng, 4, 5, 1.0);
        let b = Matrix::random_normal(&mut rng, 5, 6, 1.0);
        let mut c = c0.clone();
        gemm(false, false, 0.0, &a, &b, 2.0, &mut c);
        assert_bitwise(&c, &c0.scale(2.0), "alpha=0 scales by beta").unwrap();
    }

    /// The frozen PR-4 kernel shares the accumulation contract with the
    /// PORTABLE kernel, so the old and new scalar paths agree to the last
    /// bit — the property that lets `benches/bptt_native` attribute its
    /// speedup to structure, not numerics drift.  (The SIMD kernel is
    /// compared by tolerance instead — see the sweep test.)
    #[test]
    fn legacy_kernel_bitwise_matches_portable_gemm() {
        forall(
            16,
            |rng| {
                let (m, k, n) = ragged_dims(rng);
                let a = Matrix::random_normal(rng, m, k, 1.0);
                let b = Matrix::random_normal(rng, k, n, 1.0);
                (a, b)
            },
            |(a, b)| {
                let portable = mm_with(KernelKind::Portable, false, false, a, b);
                assert_bitwise(&legacy::matmul(a, b), &portable, "legacy vs portable gemm")
            },
        );
    }

    #[test]
    fn rows_smaller_than_thread_count_still_correct() {
        // m = 1 with a wide reduction exceeds the cutoff but cannot be
        // split into more than one band.
        let mut rng = Pcg32::seeded(7);
        let a = Matrix::random_normal(&mut rng, 1, 700, 1.0);
        let b = Matrix::random_normal(&mut rng, 700, 600, 1.0);
        let fast = matmul_blocked(&a, &b);
        let slow = matmul_naive(&a, &b);
        assert_close(&fast.data, &slow.data, 1e-4).unwrap();
    }

    #[test]
    fn degenerate_dims_produce_zero_shapes() {
        let a = Matrix::zeros(3, 0);
        let b = Matrix::zeros(0, 4);
        let c = matmul_blocked(&a, &b);
        assert_eq!((c.rows, c.cols), (3, 4));
        assert!(c.data.iter().all(|&x| x == 0.0));
    }

    /// Band partitioning never changes per-element arithmetic, so any
    /// thread cap — including 1 — reproduces the uncapped result exactly.
    #[test]
    fn thread_cap_changes_parallelism_not_results() {
        let mut rng = Pcg32::seeded(11);
        // Above the cutoff so the cap is actually consulted.
        let a = Matrix::random_normal(&mut rng, 96, 80, 1.0);
        let b = Matrix::random_normal(&mut rng, 80, 96, 1.0);
        let uncapped = matmul_blocked(&a, &b);
        for cap in [1usize, 2, 4] {
            set_thread_cap(cap);
            let capped = matmul_blocked(&a, &b);
            set_thread_cap(0);
            assert_bitwise(&capped, &uncapped, &format!("thread cap {cap}")).unwrap();
        }
    }

    /// ISSUE 9 satellite: pooled GEMM is bitwise-equal to single-threaded
    /// under the portable kernel for thread counts {1, 2, 4} on ragged
    /// band splits — prime-ish row counts so `m.div_ceil(threads)` leaves
    /// a short last band at every cap.
    #[test]
    fn pooled_gemm_bitwise_matches_single_thread_on_ragged_bands() {
        forall(
            6,
            |rng| {
                // m chosen ragged; k, n sized so m*k*n clears the cutoff
                // and the pool is actually dispatched.
                let m = [37, 53, 61, 97][rng.below(4) as usize];
                let a = Matrix::random_normal(rng, m, 96, 1.0);
                let b = Matrix::random_normal(rng, 96, 96, 1.0);
                (a, b)
            },
            |(a, b)| {
                assert!(a.rows * a.cols * b.cols >= PARALLEL_FLOP_CUTOFF);
                set_thread_cap(1);
                let serial = mm_with(KernelKind::Portable, false, false, a, b);
                let mut result = Ok(());
                for cap in [2usize, 4] {
                    set_thread_cap(cap);
                    let pooled = mm_with(KernelKind::Portable, false, false, a, b);
                    result = result.and(assert_bitwise(
                        &pooled,
                        &serial,
                        &format!("pooled portable gemm, cap {cap}, m {}", a.rows),
                    ));
                }
                set_thread_cap(0);
                result
            },
        );
    }

    /// ISSUE 9: a packed-operand call is bitwise identical to the plain
    /// call it replaces, across transpose variants, fused beta, and
    /// repacks after an in-place operand update (epoch bump).
    #[test]
    fn packed_gemm_bitwise_matches_plain_gemm() {
        use crate::linalg::pack::PackedOperand;
        let mut rng = Pcg32::seeded(0x9AC5);
        let kind = active_kernel();
        let mut pack = PackedOperand::new();
        for (ta, tb) in [(false, false), (false, true), (true, false), (true, true)] {
            for beta in [0.0f32, 1.0] {
                let (m, k, n) = ragged_dims(&mut rng);
                let a_dims = if ta { (k, m) } else { (m, k) };
                let b_dims = if tb { (n, k) } else { (k, n) };
                let a = Matrix::random_normal(&mut rng, a_dims.0, a_dims.1, 1.0);
                let mut b = Matrix::random_normal(&mut rng, b_dims.0, b_dims.1, 1.0);
                let c0 = Matrix::random_normal(&mut rng, m, n, 1.0);
                for epoch in [1u64, 2] {
                    if epoch == 2 {
                        // In-place update behind the same pointer: the
                        // epoch bump must force a repack that sees it.
                        for v in &mut b.data {
                            *v += 0.25;
                        }
                    }
                    pack.ensure(&b, tb, kind, epoch);
                    let mut plain = c0.clone();
                    gemm(ta, tb, 1.0, &a, &b, beta, &mut plain);
                    let mut packed = c0.clone();
                    gemm_packed(ta, tb, 1.0, &a, &b, &pack, beta, &mut packed);
                    assert_bitwise(
                        &packed,
                        &plain,
                        &format!("packed vs plain ta={ta} tb={tb} beta={beta} epoch={epoch}"),
                    )
                    .unwrap();
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "stale")]
    fn packed_gemm_rejects_a_stale_pack() {
        use crate::linalg::pack::PackedOperand;
        let mut rng = Pcg32::seeded(0x57A1);
        let b = Matrix::random_normal(&mut rng, 8, 8, 1.0);
        let other = Matrix::random_normal(&mut rng, 8, 8, 1.0);
        let mut pack = PackedOperand::new();
        pack.ensure(&other, false, active_kernel(), 1);
        let a = Matrix::random_normal(&mut rng, 4, 8, 1.0);
        let mut c = Matrix::zeros(4, 8);
        gemm_packed(false, false, 1.0, &a, &b, &pack, 0.0, &mut c);
    }

    /// The one-time dispatch is cached and published to the telemetry
    /// `kernel_dispatch` gauge with a matching label.
    #[test]
    fn active_kernel_is_cached_and_published_to_telemetry() {
        let k = active_kernel();
        assert_eq!(k, active_kernel(), "dispatch must be one-time");
        let code = crate::telemetry::global().kernel_dispatch();
        let expected = match k {
            KernelKind::Portable => crate::telemetry::KERNEL_PORTABLE,
            KernelKind::Avx2Fma => crate::telemetry::KERNEL_AVX2FMA,
        };
        assert_eq!(code, expected);
        assert_eq!(crate::telemetry::kernel_dispatch_name(code), k.name());
    }
}
