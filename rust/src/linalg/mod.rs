//! Pure-Rust dense linear algebra substrate.
//!
//! Used by the native baseline implementations in `crate::orthogonal`
//! (Tables 1-2 harness, property tests) and by the coordinator for
//! orthogonality verification of artifact outputs.  Mirrors the
//! custom-call-free algorithms exported at L2 (`python/compile/linalg_hlo.py`)
//! so both sides can be cross-checked.

pub mod expm;
pub mod gemm;
pub mod matrix;
pub mod pack;
pub mod pool;
pub mod qr;
pub mod simd;
pub mod tri;

pub use expm::{cayley, expm, expm_default};
pub use gemm::{
    active_kernel, gemm, gemm_packed, gemm_with, matmul_blocked, matmul_naive, set_thread_cap,
    KernelKind,
};
pub use matrix::{Matrix, ShapeError, Workspace};
pub use pack::PackedOperand;
pub use pool::{in_pool_context, parallel_for, pool_workers};
pub use qr::{gauss_jordan_inv, householder_qr};
pub use tri::{triu_inv, triu_inv_into, triu_inv_neumann, triu_solve, triu_solve_vec};
