//! Lane-width vector primitives for the reflection hot paths
//! (`orthogonal::{cwy, householder, backward}`) — the second hot family
//! after GEMM: per-row dots, squared norms, and axpy updates that
//! dominate small-N rollouts where gemm tiles don't amortize.
//!
//! Dispatch follows [`gemm::active_kernel`]: one process-wide decision
//! shared with the GEMM microkernel (and the same `CWY_PORTABLE_KERNEL`
//! override).  The portable versions keep the exact serial ascending
//! accumulation order of the scalar loops they replaced, so forcing the
//! portable kernel reproduces pre-SIMD results bit for bit; the AVX2+FMA
//! versions run four independent accumulator chains (reductions) or fuse
//! multiply-adds (axpy), so cross-kernel comparisons are tolerance-based
//! (DESIGN.md §3.3).
//!
//! `Matrix::axpy` deliberately does NOT route here: its bitwise contract
//! against the allocating `add`/`scale` wrappers
//! (`in_place_ops_bitwise_match_allocating_wrappers`) must hold on every
//! host regardless of dispatch.
//!
//! None of these helpers allocate — they stay inside the
//! `tests/alloc_discipline.rs` zero-allocation contract.

#[cfg(target_arch = "x86_64")]
use crate::linalg::gemm::{active_kernel, KernelKind};

/// `sum_i a[i] * b[i]` (lengths asserted equal).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    #[cfg(target_arch = "x86_64")]
    if active_kernel() == KernelKind::Avx2Fma {
        // SAFETY: Avx2Fma is only ever selected after runtime detection.
        return unsafe { avx2::dot(a, b) };
    }
    dot_portable(a, b)
}

/// `sum_i a[i]^2`.
#[inline]
pub fn norm_sq(a: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if active_kernel() == KernelKind::Avx2Fma {
        // SAFETY: as in `dot`.
        return unsafe { avx2::norm_sq(a) };
    }
    norm_sq_portable(a)
}

/// `y[i] += alpha * x[i]` (lengths asserted equal).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "axpy length mismatch");
    #[cfg(target_arch = "x86_64")]
    if active_kernel() == KernelKind::Avx2Fma {
        // SAFETY: as in `dot`.
        return unsafe { avx2::axpy(alpha, x, y) };
    }
    axpy_portable(alpha, x, y)
}

/// One serial ascending chain — bitwise identical to the
/// `iter().zip().map(|(x, y)| x * y).sum()` loops it replaced.
fn dot_portable(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        s += x * y;
    }
    s
}

fn norm_sq_portable(a: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for x in a {
        s += x * x;
    }
    s
}

/// Independent per-element updates — LLVM may autovectorize this without
/// changing any rounding (`y + alpha * x` per element, no contraction).
fn axpy_portable(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    const LANES: usize = 8;
    /// Independent accumulator chains per reduction: enough ILP to hide
    /// FMA latency (4-5 cycles) at FMA throughput (0.5 cycles).
    const CHAINS: usize = 4;

    /// Sum the 8 lanes of one vector.
    #[inline]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps::<1>(s, s));
        _mm_cvtss_f32(s)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc = [_mm256_setzero_ps(); CHAINS];
        let mut i = 0;
        while i + CHAINS * LANES <= n {
            for (c, chain) in acc.iter_mut().enumerate() {
                *chain = _mm256_fmadd_ps(
                    _mm256_loadu_ps(ap.add(i + c * LANES)),
                    _mm256_loadu_ps(bp.add(i + c * LANES)),
                    *chain,
                );
            }
            i += CHAINS * LANES;
        }
        while i + LANES <= n {
            acc[0] =
                _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i)), acc[0]);
            i += LANES;
        }
        let v = _mm256_add_ps(_mm256_add_ps(acc[0], acc[1]), _mm256_add_ps(acc[2], acc[3]));
        let mut s = hsum(v);
        while i < n {
            s += *ap.add(i) * *bp.add(i);
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn norm_sq(a: &[f32]) -> f32 {
        let n = a.len();
        let ap = a.as_ptr();
        let mut acc = [_mm256_setzero_ps(); CHAINS];
        let mut i = 0;
        while i + CHAINS * LANES <= n {
            for (c, chain) in acc.iter_mut().enumerate() {
                let v = _mm256_loadu_ps(ap.add(i + c * LANES));
                *chain = _mm256_fmadd_ps(v, v, *chain);
            }
            i += CHAINS * LANES;
        }
        while i + LANES <= n {
            let v = _mm256_loadu_ps(ap.add(i));
            acc[0] = _mm256_fmadd_ps(v, v, acc[0]);
            i += LANES;
        }
        let v = _mm256_add_ps(_mm256_add_ps(acc[0], acc[1]), _mm256_add_ps(acc[2], acc[3]));
        let mut s = hsum(v);
        while i < n {
            let x = *ap.add(i);
            s += x * x;
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = _mm256_set1_ps(alpha);
        let (xp, yp) = (x.as_ptr(), y.as_mut_ptr());
        let mut i = 0;
        while i + LANES <= n {
            let r = _mm256_fmadd_ps(va, _mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)));
            _mm256_storeu_ps(yp.add(i), r);
            i += LANES;
        }
        while i < n {
            *yp.add(i) += alpha * *xp.add(i);
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Length grid straddling every vector-body boundary: the 4-chain
    /// stride (32), the single-vector stride (8), and the scalar tail.
    const SIZES: [usize; 14] = [0, 1, 7, 8, 9, 15, 16, 31, 32, 33, 63, 64, 65, 257];

    #[test]
    fn dispatched_reductions_match_portable_within_tolerance() {
        let mut rng = Pcg32::seeded(41);
        for n in SIZES {
            let a: Vec<f32> = rng.normal_vec(n, 1.0);
            let b: Vec<f32> = rng.normal_vec(n, 1.0);
            let tol = 1e-5 * (n.max(1) as f32).sqrt();
            let (d, dp) = (dot(&a, &b), dot_portable(&a, &b));
            assert!((d - dp).abs() <= tol, "dot n={n}: {d} vs {dp}");
            let (q, qp) = (norm_sq(&a), norm_sq_portable(&a));
            assert!((q - qp).abs() <= tol * 4.0, "norm_sq n={n}: {q} vs {qp}");
        }
    }

    #[test]
    fn dispatched_axpy_matches_portable_per_element() {
        let mut rng = Pcg32::seeded(43);
        for n in SIZES {
            let x: Vec<f32> = rng.normal_vec(n, 1.0);
            let y0: Vec<f32> = rng.normal_vec(n, 1.0);
            let mut y1 = y0.clone();
            axpy(0.37, &x, &mut y1);
            let mut y2 = y0.clone();
            axpy_portable(0.37, &x, &mut y2);
            for (i, (p, q)) in y1.iter().zip(&y2).enumerate() {
                // Elementwise: at most one rounding difference (FMA).
                assert!((p - q).abs() <= 1e-6, "axpy n={n} elt {i}: {p} vs {q}");
            }
        }
    }

    /// The portable forms ARE the serial scalar loops the call sites used
    /// before — bit for bit, so `CWY_PORTABLE_KERNEL=1` reproduces
    /// pre-SIMD numerics exactly.
    #[test]
    fn portable_ops_keep_the_serial_scalar_order() {
        let mut rng = Pcg32::seeded(42);
        let a: Vec<f32> = rng.normal_vec(37, 1.0);
        let b: Vec<f32> = rng.normal_vec(37, 1.0);
        let serial: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot_portable(&a, &b).to_bits(), serial.to_bits());
        let nsq: f32 = a.iter().map(|x| x * x).sum();
        assert_eq!(norm_sq_portable(&a).to_bits(), nsq.to_bits());
        let mut y = b.clone();
        axpy_portable(-0.5, &a, &mut y);
        for (i, (yi, (&ai, &bi))) in y.iter().zip(a.iter().zip(&b)).enumerate() {
            let want = bi - 0.5 * ai;
            assert_eq!(yi.to_bits(), want.to_bits(), "axpy elt {i}");
        }
    }
}
