//! Cached pre-packed GEMM operands (DESIGN.md §3.4).
//!
//! A CWY rollout applies the SAME operator `Q = I - U S^{-1} U^T` at
//! every one of its T timesteps, and a serve batch applies the same
//! artifact weights to every request row — yet `gemm` repacks the
//! operator operand (transpose copy and/or SIMD lane panels) on every
//! call.  A [`PackedOperand`] amortizes that: the owner packs once per
//! operator rebuild via [`PackedOperand::ensure`] and every later
//! [`super::gemm::gemm_packed`] call consumes the cached panels
//! directly.  The cached bytes are exactly what the per-call path would
//! have packed, so packed calls stay bitwise-identical to plain `gemm`.
//!
//! # Keying and invalidation
//!
//! The cache key is `(data pointer, shape, trans, resolved kernel,
//! version)`.  Pointer+shape catch reallocation and shape changes;
//! `version` is the owner's invalidation epoch and is the load-bearing
//! part: an in-place update (SGD stepping `U`, a tape `recompute`)
//! changes contents behind a stable pointer, which no pointer key can
//! see.  Owners bump their epoch on every rebuild — `CwyPacks` in
//! `orthogonal::cwy` ties it to the tape-recompute cycle.  A mismatched
//! key repacks (counted as a `pack_misses`); `gemm_packed` asserts the
//! key matches its operands so a stale pack fails loudly instead of
//! multiplying against dead bytes.

use super::gemm::{self, KernelKind};
use super::Matrix;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
struct PackKey {
    ptr: usize,
    rows: usize,
    cols: usize,
    trans: bool,
    kernel: KernelKind,
    version: u64,
}

/// One cached, pre-packed `op(B)` operand.  Reuses its buffers across
/// rebuilds, so steady-state `ensure` calls (same shape, new epoch)
/// allocate nothing.
#[derive(Default)]
pub struct PackedOperand {
    key: Option<PackKey>,
    /// Row-major transposed copy of `B` (`trans` packs only) — what the
    /// per-call `PACK_B` thread-local would hold.
    pub(crate) bt: Vec<f32>,
    /// Lane-contiguous SIMD panels of `op(B)` (`Avx2Fma` packs only) —
    /// what the per-call `PACK_PANELS` thread-local would hold.
    pub(crate) panels: Vec<f32>,
}

impl PackedOperand {
    pub fn new() -> Self {
        Self::default()
    }

    /// (Re)build the pack for `op(b)` under `kind` unless the cached one
    /// already matches; returns `true` on a cache hit.  Bump `version`
    /// whenever `b`'s contents change in place (module docs).
    pub fn ensure(&mut self, b: &Matrix, trans: bool, kind: KernelKind, version: u64) -> bool {
        let kind = gemm::resolve_kernel(kind);
        let key = PackKey {
            ptr: b.data.as_ptr() as usize,
            rows: b.rows,
            cols: b.cols,
            trans,
            kernel: kind,
            version,
        };
        if self.key == Some(key) {
            return true;
        }
        crate::telemetry::global().add_pack_miss();
        let (k, n) = if trans { (b.cols, b.rows) } else { (b.rows, b.cols) };
        if trans {
            gemm::pack_transposed(b, &mut self.bt);
        }
        if kind == KernelKind::Avx2Fma {
            let src: &[f32] = if trans { &self.bt } else { &b.data };
            gemm::pack_panels_for(src, k, n, &mut self.panels);
        }
        self.key = Some(key);
        false
    }

    /// Whether the cached pack was built from `op(b)` under `kind`
    /// (any version — the epoch is the owner's contract, not the
    /// call site's).
    pub fn matches(&self, b: &Matrix, trans: bool, kind: KernelKind) -> bool {
        let kind = gemm::resolve_kernel(kind);
        matches!(self.key, Some(key) if key.ptr == b.data.as_ptr() as usize
            && key.rows == b.rows
            && key.cols == b.cols
            && key.trans == trans
            && key.kernel == kind)
    }

    pub fn is_empty(&self) -> bool {
        self.key.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::active_kernel;
    use crate::util::rng::Pcg32;

    #[test]
    fn ensure_hits_until_the_version_bumps() {
        let mut rng = Pcg32::seeded(0xAC4E);
        let b = Matrix::random_normal(&mut rng, 12, 20, 1.0);
        let kind = active_kernel();
        let mut pack = PackedOperand::new();
        assert!(pack.is_empty());
        assert!(!pack.ensure(&b, true, kind, 1), "first build is a miss");
        assert!(pack.ensure(&b, true, kind, 1), "same key must hit");
        assert!(pack.matches(&b, true, kind));
        assert!(!pack.matches(&b, false, kind), "trans is part of the key");
        assert!(!pack.ensure(&b, true, kind, 2), "an epoch bump must repack");
    }

    #[test]
    fn reshaped_or_moved_operand_misses() {
        let mut rng = Pcg32::seeded(0xAC4F);
        let b = Matrix::random_normal(&mut rng, 8, 8, 1.0);
        let kind = active_kernel();
        let mut pack = PackedOperand::new();
        pack.ensure(&b, false, kind, 1);
        let moved = b.clone();
        assert!(!pack.matches(&moved, false, kind), "a fresh buffer must not match");
        assert!(!pack.ensure(&moved, false, kind, 1));
    }
}
