//! Triangular solves and inverses (native mirror of `linalg_hlo.triu_inv`).

use super::matrix::{Matrix, Workspace};

/// Back-substitution solve of U x = b into a caller-provided `x`
/// (allocation-free core shared by every solve entry).
pub fn triu_solve_vec_into(u: &Matrix, b: &[f32], x: &mut [f32]) {
    let n = u.rows;
    assert_eq!(u.cols, n);
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);
    for i in (0..n).rev() {
        let mut s = b[i];
        for j in i + 1..n {
            s -= u[(i, j)] * x[j];
        }
        x[i] = s / u[(i, i)];
    }
}

/// Back-substitution solve of U x = b for upper-triangular U.
pub fn triu_solve_vec(u: &Matrix, b: &[f32]) -> Vec<f32> {
    let mut x = vec![0.0f32; u.rows];
    triu_solve_vec_into(u, b, &mut x);
    x
}

/// Solve U X = B column-by-column (B is n x m).
pub fn triu_solve(u: &Matrix, b: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(u.rows, b.cols);
    let mut ws = Workspace::new();
    triu_solve_into(u, b, &mut out, &mut ws);
    out
}

/// Solve U X = B into a preshaped `out`, scratch drawn from `ws`
/// (allocation-free at steady state).  Bitwise-identical to
/// [`triu_solve`].
pub fn triu_solve_into(u: &Matrix, b: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
    let n = u.rows;
    assert_eq!(b.rows, n);
    assert_eq!((out.rows, out.cols), (n, b.cols), "triu_solve output shape");
    let mut col = ws.take(1, n);
    let mut x = ws.take(1, n);
    for c in 0..b.cols {
        for r in 0..n {
            col.data[r] = b[(r, c)];
        }
        triu_solve_vec_into(u, &col.data, &mut x.data);
        for r in 0..n {
            out[(r, c)] = x.data[r];
        }
    }
    ws.give(col);
    ws.give(x);
}

/// Inverse of an upper-triangular matrix; costs ~n^3/3 FLOPs (Hunger 2005),
/// which is the count the paper's Table 2 credits T-CWY for.
pub fn triu_inv(u: &Matrix) -> Matrix {
    triu_solve(u, &Matrix::eye(u.rows))
}

/// Inverse into a preshaped `out` with pooled scratch — the form the
/// per-step CWY operator rebuild uses so `S⁻¹` costs no allocation.
/// Bitwise-identical to [`triu_inv`].
pub fn triu_inv_into(u: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
    let n = u.rows;
    assert_eq!((out.rows, out.cols), (n, n), "triu_inv output shape");
    let mut col = ws.take(1, n);
    let mut x = ws.take(1, n);
    for c in 0..n {
        col.data.fill(0.0);
        col.data[c] = 1.0;
        triu_solve_vec_into(u, &col.data, &mut x.data);
        for r in 0..n {
            out[(r, c)] = x.data[r];
        }
    }
    ws.give(col);
    ws.give(x);
}

/// Inverse via the log-depth nilpotent Neumann product — the exact same
/// algorithm the exported HLO uses (linalg_hlo.triu_inv), for parity tests.
pub fn triu_inv_neumann(s: &Matrix) -> Matrix {
    let n = s.rows;
    // D^{-1} and X = -(D^{-1} S - I)
    let mut x = Matrix::zeros(n, n);
    let dinv: Vec<f32> = (0..n).map(|i| 1.0 / s[(i, i)]).collect();
    for i in 0..n {
        for j in 0..n {
            let v = dinv[i] * s[(i, j)] - if i == j { 1.0 } else { 0.0 };
            x[(i, j)] = -v;
        }
    }
    let eye = Matrix::eye(n);
    let mut acc = eye.add(&x);
    let mut p = x;
    let steps = usize::BITS - (n.max(2) - 1).leading_zeros();
    for _ in 0..steps.saturating_sub(1) {
        p = p.matmul(&p);
        acc = acc.matmul(&eye.add(&p));
    }
    // (I+M)^{-1} D^{-1}
    let mut out = acc;
    for i in 0..n {
        for j in 0..n {
            out[(i, j)] *= dinv[j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    fn random_triu(rng: &mut Pcg32, n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                m[(i, j)] = rng.normal();
            }
            m[(i, i)] += if m[(i, i)] >= 0.0 { 2.0 } else { -2.0 };
        }
        m
    }

    #[test]
    fn solve_roundtrip() {
        let mut rng = Pcg32::seeded(11);
        let u = random_triu(&mut rng, 8);
        let x: Vec<f32> = rng.normal_vec(8, 1.0);
        let b = u.matvec(&x);
        let got = triu_solve_vec(&u, &b);
        for (a, b) in got.iter().zip(&x) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn inv_property() {
        forall(
            24,
            |rng| {
                let n = 1 + rng.below(12) as usize;
                random_triu(rng, n)
            },
            |u| {
                let inv = triu_inv(u);
                let defect = inv.matmul(u).max_abs_diff(&Matrix::eye(u.rows));
                if defect < 1e-3 {
                    Ok(())
                } else {
                    Err(format!("defect {defect} at n={}", u.rows))
                }
            },
        );
    }

    #[test]
    fn inv_into_bitwise_matches_allocating() {
        forall(
            12,
            |rng| {
                let n = 1 + rng.below(12) as usize;
                random_triu(rng, n)
            },
            |u| {
                let reference = triu_inv(u);
                let mut ws = Workspace::new();
                let mut out = Matrix::zeros(u.rows, u.rows);
                out.fill(f32::NAN); // stale contents must not leak
                triu_inv_into(u, &mut out, &mut ws);
                let same = reference
                    .data
                    .iter()
                    .zip(&out.data)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                if same { Ok(()) } else { Err("triu_inv_into drifted".into()) }
            },
        );
    }

    #[test]
    fn neumann_matches_backsub() {
        forall(
            16,
            |rng| {
                let n = 1 + rng.below(10) as usize;
                random_triu(rng, n)
            },
            |u| {
                let a = triu_inv(u);
                let b = triu_inv_neumann(u);
                let d = a.max_abs_diff(&b);
                if d < 1e-3 { Ok(()) } else { Err(format!("diff {d}")) }
            },
        );
    }
}
