//! Persistent work-stealing thread pool for band-parallel kernels
//! (DESIGN.md §3.4).
//!
//! PR 5/7 made the GEMM hot path allocation-free and vectorized, but the
//! threading layer still paid a full OS `thread::scope` spawn/join on
//! every call above [`super::gemm::PARALLEL_FLOP_CUTOFF`] — tens of
//! microseconds of kernel time per dispatch, serialized against the very
//! GEMMs the CWY parametrization exists to parallelize.  This module
//! replaces that with a process-wide pool:
//!
//! * **Lazy, one-time start.** The first parallel dispatch spawns
//!   `configured_threads() - 1` workers (the dispatching thread is the
//!   +1); `CWY_GEMM_THREADS=1` degrades the pool to zero workers and
//!   every dispatch runs inline — the CI single-thread leg.
//! * **Zero allocation per dispatch.** A [`parallel_for`] call publishes
//!   a stack-allocated job (erased closure + atomic band cursor) into a
//!   fixed slot table; workers claim band indices with `fetch_add`.  No
//!   queues, no boxing, no channel — the steady-state training loop
//!   stays inside the `tests/alloc_discipline.rs` zero-byte window with
//!   the pool live.
//! * **Work-stealing at band granularity.** Every worker scans all
//!   published jobs, so an idle worker steals bands from whichever
//!   dispatch is running — concurrent serve-worker GEMMs share the one
//!   worker set instead of oversubscribing the machine.
//! * **Nesting runs inline.** Workers (and dispatchers while they chew
//!   their own bands) are marked [`in_pool_context`]; a GEMM issued from
//!   inside a pooled band sees that flag, takes a budget of 1, and runs
//!   serially — rollout-over-batch-rows parallelism composes with GEMM
//!   band parallelism without thread explosion.
//!
//! # Safety protocol (stack job + hazard counters)
//!
//! The job lives on the dispatcher's stack, so retraction must prove no
//! worker can still touch it.  Two counters make that airtight:
//!
//! 1. a worker holds `visitors[slot] > 0` for the whole window in which
//!    it may dereference the slot's pointer;
//! 2. a claimed band holds `job.inflight > 0` until its body returns.
//!
//! The dispatcher waits for every band to finish (`executed == bands`),
//! nulls the slot, then spins until the slot's visitor count drains.
//! Only then does `parallel_for` return and the job die.  Band bodies
//! run under `catch_unwind`, so a panicking kernel poisons the job (the
//! dispatcher re-panics after retraction) instead of deadlocking it.
//!
//! Telemetry: every band executed counts into `pool_tasks`; bands
//! executed by a worker other than the dispatcher count into
//! `pool_steals`; published-but-unfinished bands are the
//! `pool_queue_depth` gauge; worker park durations feed the
//! `pool_park_us` histogram.  All preregistered, all lock-free.

use std::cell::Cell;
use std::ptr;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Concurrent dispatchers the slot table supports.  A dispatch that
/// finds every slot occupied runs its bands inline instead of waiting —
/// the pool degrades, it never blocks.
const MAX_JOBS: usize = 16;

/// Spin iterations before a waiter starts yielding the CPU, and before
/// an idle worker parks on the condvar.
const SPIN_LIMIT: u32 = 256;

/// One published `parallel_for` call.  Lives on the dispatcher's stack;
/// see the module docs for the retraction protocol that makes the raw
/// `body` pointer sound.
struct Job {
    /// Lifetime-erased band closure; valid until retraction completes.
    body: *const (dyn Fn(usize) + Sync),
    /// Next band index to hand out (`fetch_add` issues each exactly once).
    cursor: AtomicUsize,
    /// Bands claimed but not yet finished.
    inflight: AtomicUsize,
    /// Bands finished (panicked bands count — they are done claiming).
    executed: AtomicUsize,
    /// Set when a band body panicked; the dispatcher re-raises.
    panicked: AtomicBool,
    bands: usize,
}

struct Pool {
    slots: [AtomicPtr<Job>; MAX_JOBS],
    /// Per-slot hazard counters (module docs, step 1).
    visitors: [AtomicUsize; MAX_JOBS],
    /// Count of workers parked on `wake`.
    sleepers: Mutex<usize>,
    wake: Condvar,
    /// Worker threads spawned at start (dispatchers are the +1).
    workers: usize,
}

thread_local! {
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// True on pool worker threads and on a dispatcher while it executes its
/// own bands: a parallel region is already running on this thread, so
/// nested parallelism should run inline (`GemmSlot::acquire` checks
/// this).
pub fn in_pool_context() -> bool {
    IN_POOL.with(Cell::get)
}

static POOL: OnceLock<&'static Pool> = OnceLock::new();

fn get() -> &'static Pool {
    POOL.get_or_init(|| {
        // Sized once from the env/hardware configuration, deliberately
        // ignoring the runtime `set_thread_cap` override: the cap varies
        // per bench row, the worker set cannot.  A cap below the worker
        // count simply publishes fewer bands per dispatch.
        let workers = super::gemm::configured_threads().saturating_sub(1);
        let pool: &'static Pool = Box::leak(Box::new(Pool {
            slots: [const { AtomicPtr::new(ptr::null_mut()) }; MAX_JOBS],
            visitors: [const { AtomicUsize::new(0) }; MAX_JOBS],
            sleepers: Mutex::new(0),
            wake: Condvar::new(),
            workers,
        }));
        crate::telemetry::global().set_pool_workers(workers as u64);
        for i in 0..workers {
            std::thread::Builder::new()
                .name(format!("cwy-pool-{i}"))
                .spawn(move || worker_loop(pool))
                .expect("spawning pool worker");
        }
        pool
    })
}

/// Worker threads in the pool (0 when `CWY_GEMM_THREADS=1` or on a
/// single-core host — every dispatch then runs inline).  First call
/// starts the pool.
pub fn pool_workers() -> usize {
    get().workers
}

impl Pool {
    fn publish(&self, job: &Job) -> Option<usize> {
        let ptr = job as *const Job as *mut Job;
        for s in 0..MAX_JOBS {
            if self.slots[s]
                .compare_exchange(ptr::null_mut(), ptr, Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
            {
                return Some(s);
            }
        }
        None
    }

    fn retract(&self, s: usize) {
        self.slots[s].store(ptr::null_mut(), Ordering::Release);
        let mut spins = 0u32;
        while self.visitors[s].load(Ordering::Acquire) != 0 {
            spins += 1;
            if spins < SPIN_LIMIT {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
    }

    fn has_work(&self) -> bool {
        self.slots.iter().any(|s| !s.load(Ordering::Acquire).is_null())
    }

    fn wake_workers(&self) {
        let sleepers = self.sleepers.lock().unwrap();
        if *sleepers > 0 {
            self.wake.notify_all();
        }
    }
}

/// Claim and run bands of `job` until its cursor is exhausted; returns
/// whether any band ran here.  `stolen` marks execution by a pool worker
/// (vs the dispatching thread) for the steal counter.  Never unwinds:
/// band panics are caught and recorded on the job.
fn run_bands(job: &Job, stolen: bool) -> bool {
    let telemetry = crate::telemetry::global();
    let mut ran = false;
    loop {
        // inflight is raised BEFORE the claim so a cancelling dispatcher
        // that sees inflight == 0 after exhausting the cursor knows no
        // band body can still start.
        job.inflight.fetch_add(1, Ordering::AcqRel);
        let band = job.cursor.fetch_add(1, Ordering::AcqRel);
        if band >= job.bands {
            job.inflight.fetch_sub(1, Ordering::Release);
            return ran;
        }
        ran = true;
        let body = std::panic::AssertUnwindSafe(|| {
            let _task_span = crate::span!(pool_task);
            // SAFETY: the dispatcher keeps the job (and the closure
            // behind `body`) alive until `executed == bands` and the
            // slot's visitors drain — we hold both pins here.
            (unsafe { &*job.body })(band);
        });
        if std::panic::catch_unwind(body).is_err() {
            job.panicked.store(true, Ordering::Release);
        }
        job.executed.fetch_add(1, Ordering::Release);
        job.inflight.fetch_sub(1, Ordering::Release);
        telemetry.add_pool_task();
        if stolen {
            telemetry.add_pool_steal();
        }
        telemetry.pool_queue_sub(1);
    }
}

fn worker_loop(pool: &'static Pool) {
    IN_POOL.with(|c| c.set(true));
    let mut idle_spins = 0u32;
    loop {
        let mut ran = false;
        for s in 0..MAX_JOBS {
            // Cheap pre-check without touching the hazard counter keeps
            // idle scans off the visitors cache lines.
            if pool.slots[s].load(Ordering::Acquire).is_null() {
                continue;
            }
            pool.visitors[s].fetch_add(1, Ordering::AcqRel);
            let p = pool.slots[s].load(Ordering::Acquire);
            if !p.is_null() {
                // SAFETY: visitors[s] > 0 pins the job against
                // retraction for this whole block.
                ran |= run_bands(unsafe { &*p }, true);
            }
            pool.visitors[s].fetch_sub(1, Ordering::Release);
        }
        if ran {
            idle_spins = 0;
            continue;
        }
        idle_spins += 1;
        if idle_spins < SPIN_LIMIT {
            std::hint::spin_loop();
            continue;
        }
        // Park until a dispatcher publishes (the timeout is a safety net
        // against a lost wakeup, not a poll interval).  Publishers store
        // the slot before taking the lock, so a worker that sees no work
        // under the lock is guaranteed a later notify.
        let parked = Instant::now();
        let mut sleepers = pool.sleepers.lock().unwrap();
        if pool.has_work() {
            drop(sleepers);
            idle_spins = 0;
            continue;
        }
        *sleepers += 1;
        let (mut sleepers, _) =
            pool.wake.wait_timeout(sleepers, Duration::from_millis(100)).unwrap();
        *sleepers -= 1;
        drop(sleepers);
        crate::telemetry::global().record_pool_park(parked.elapsed().as_micros() as u64);
        idle_spins = 0;
    }
}

/// Run `body(band)` for every `band in 0..bands`, spreading bands across
/// the pool.  Blocks until every band has finished; the dispatching
/// thread claims bands itself, so the call is work-conserving even when
/// all workers are busy elsewhere.  Allocation-free after the one-time
/// pool start.
///
/// Bands are claimed in ascending order but may run concurrently in any
/// interleaving: bodies must write disjoint data per band (the GEMM band
/// split — disjoint output row ranges — is the canonical caller, and
/// partitioning never changes per-element arithmetic, so results stay
/// bitwise-identical at any worker count).
///
/// Runs inline (plain serial loop) when: `bands <= 1`, this thread is
/// already inside a pooled band ([`in_pool_context`]), the pool has no
/// workers (`CWY_GEMM_THREADS=1`), or the slot table is full.
pub fn parallel_for(bands: usize, body: &(dyn Fn(usize) + Sync)) {
    if bands == 0 {
        return;
    }
    if bands == 1 || in_pool_context() {
        for band in 0..bands {
            body(band);
        }
        return;
    }
    let pool = get();
    if pool.workers == 0 {
        for band in 0..bands {
            body(band);
        }
        return;
    }
    // SAFETY: erases the borrow lifetime only; this frame outlives every
    // dereference because it does not return before retraction proves
    // all claimed bands finished and all slot readers left.
    #[allow(clippy::missing_transmute_annotations)]
    let erased: *const (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(body) };
    let job = Job {
        body: erased,
        cursor: AtomicUsize::new(0),
        inflight: AtomicUsize::new(0),
        executed: AtomicUsize::new(0),
        panicked: AtomicBool::new(false),
        bands,
    };
    // Gauge up BEFORE the job becomes visible: a worker may start
    // executing (and decrementing) the instant the slot is published.
    let telemetry = crate::telemetry::global();
    telemetry.pool_queue_add(bands as u64);
    let Some(slot) = pool.publish(&job) else {
        telemetry.pool_queue_sub(bands as u64);
        for band in 0..bands {
            body(band);
        }
        return;
    };
    pool.wake_workers();
    // The dispatcher is a full participant — the pool ADDS workers, it
    // never idles the submitting thread.  Mark it in-pool for the
    // duration so a nested dispatch from its own bands runs inline.
    IN_POOL.with(|c| c.set(true));
    run_bands(&job, false);
    IN_POOL.with(|c| c.set(false));
    let mut spins = 0u32;
    while job.executed.load(Ordering::Acquire) < bands {
        spins += 1;
        if spins < SPIN_LIMIT {
            std::hint::spin_loop();
        } else {
            std::thread::yield_now();
        }
    }
    pool.retract(slot);
    if job.panicked.load(Ordering::Acquire) {
        panic!("a pooled band panicked (original payload on the worker's stderr)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{self, set_thread_cap, KernelKind};
    use crate::linalg::Matrix;
    use crate::util::rng::Pcg32;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_band_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(hits.len(), &|band| {
            hits[band].fetch_add(1, Ordering::Relaxed);
        });
        for (band, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "band {band}");
        }
    }

    #[test]
    fn nested_dispatch_runs_inline_and_still_covers_all_bands() {
        let outer: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        let inner = AtomicU64::new(0);
        parallel_for(outer.len(), &|band| {
            assert!(in_pool_context(), "bands must observe pool context");
            outer[band].fetch_add(1, Ordering::Relaxed);
            // A dispatch from inside a band must run inline, not deadlock
            // or recurse into the slot table.
            parallel_for(5, &|_| {
                inner.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert!(outer.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(inner.load(Ordering::Relaxed), 8 * 5);
        assert!(!in_pool_context(), "dispatcher flag must be restored");
    }

    /// ISSUE 9 satellite: nested parallelism shares one cap.  With the
    /// runtime cap at 2 (the `CWY_GEMM_THREADS=2` scenario), a GEMM
    /// issued from inside a pooled band must see a thread budget of 1 —
    /// rollout-level and GEMM-level parallelism never multiply — and the
    /// results must stay bitwise-identical to the serial path.
    #[test]
    fn nested_gemm_inside_pool_band_gets_inline_budget() {
        let mut rng = Pcg32::seeded(0x900f);
        // Above PARALLEL_FLOP_CUTOFF so the budget is actually consulted.
        let a = Matrix::random_normal(&mut rng, 96, 80, 1.0);
        let b = Matrix::random_normal(&mut rng, 80, 96, 1.0);
        let mut reference = Matrix::zeros(96, 96);
        gemm::gemm_with(KernelKind::Portable, false, false, 1.0, &a, &b, 0.0, &mut reference);
        let outs: Vec<std::sync::Mutex<Matrix>> =
            (0..4).map(|_| std::sync::Mutex::new(Matrix::zeros(96, 96))).collect();
        set_thread_cap(2);
        parallel_for(outs.len(), &|band| {
            assert_eq!(
                gemm::current_gemm_budget(),
                1,
                "a gemm inside a pooled band must run inline"
            );
            let mut out = outs[band].lock().unwrap();
            gemm::gemm_with(KernelKind::Portable, false, false, 1.0, &a, &b, 0.0, &mut out);
        });
        set_thread_cap(0);
        for out in &outs {
            let out = out.lock().unwrap();
            assert_eq!(
                out.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                reference.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "nested pooled gemm drifted from the serial result"
            );
        }
    }

    #[test]
    fn dispatch_records_pool_telemetry() {
        let t = crate::telemetry::global();
        let before = t.pool_tasks();
        parallel_for(12, &|_| std::hint::black_box(()));
        if pool_workers() > 0 {
            assert!(t.pool_tasks() >= before + 12, "pooled bands must be counted");
        }
        // The gauge is shared with concurrently-running tests, so only
        // its invariant (never underflows into huge values) is checked.
        assert!(t.pool_queue_depth() < 1 << 32, "queue gauge underflowed");
    }
}
