//! Householder QR (thin) and Gauss-Jordan dense inverse.

use super::matrix::Matrix;

/// Thin QR of A (n x m, n >= m): A = Q R with Q in St(n, m) and
/// diag(R) > 0 (the `qf` convention of the paper's QR retraction).
pub fn householder_qr(a: &Matrix) -> (Matrix, Matrix) {
    let (n, m) = (a.rows, a.cols);
    assert!(n >= m, "thin QR needs n >= m");
    let mut r = a.clone();
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(m);

    for k in 0..m {
        // Householder vector for column k below the diagonal.
        let mut x = vec![0.0f32; n];
        for i in k..n {
            x[i] = r[(i, k)];
        }
        let normx = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        if normx < 1e-12 {
            vs.push(x);
            continue;
        }
        let alpha = if x[k] >= 0.0 { -normx } else { normx };
        x[k] -= alpha;
        let vnorm2: f32 = x.iter().map(|v| v * v).sum::<f32>().max(1e-24);
        // R <- H R, H = I - 2 v v^T / ||v||^2
        for j in 0..m {
            let dot: f32 = (k..n).map(|i| x[i] * r[(i, j)]).sum();
            let c = 2.0 * dot / vnorm2;
            for i in k..n {
                r[(i, j)] -= c * x[i];
            }
        }
        vs.push(x);
    }

    // Q = H_1 ... H_m [I; 0]
    let mut q = Matrix::eye_rect(n, m);
    for (k, v) in vs.iter().enumerate().rev() {
        let vnorm2: f32 = v.iter().map(|x| x * x).sum::<f32>().max(1e-24);
        for j in 0..m {
            let dot: f32 = (k..n).map(|i| v[i] * q[(i, j)]).sum();
            let c = 2.0 * dot / vnorm2;
            for i in k..n {
                q[(i, j)] -= c * v[i];
            }
        }
    }

    // Sign-fix so diag(R) >= 0.
    let mut r_out = Matrix::zeros(m, m);
    for i in 0..m {
        let s = if r[(i, i)] < 0.0 { -1.0 } else { 1.0 };
        for j in 0..m {
            r_out[(i, j)] = s * r[(i, j)];
        }
        for row in 0..n {
            q[(row, i)] *= s;
        }
    }
    (q, r_out)
}

/// Dense inverse by Gauss-Jordan with partial pivoting.
pub fn gauss_jordan_inv(a: &Matrix) -> Matrix {
    let n = a.rows;
    assert_eq!(a.cols, n);
    let mut aug = Matrix::zeros(n, 2 * n);
    for i in 0..n {
        for j in 0..n {
            aug[(i, j)] = a[(i, j)];
        }
        aug[(i, n + i)] = 1.0;
    }
    for col in 0..n {
        // partial pivot
        let mut piv = col;
        for row in col + 1..n {
            if aug[(row, col)].abs() > aug[(piv, col)].abs() {
                piv = row;
            }
        }
        if piv != col {
            for j in 0..2 * n {
                let tmp = aug[(col, j)];
                aug[(col, j)] = aug[(piv, j)];
                aug[(piv, j)] = tmp;
            }
        }
        let d = aug[(col, col)];
        assert!(d.abs() > 1e-12, "singular matrix in gauss_jordan_inv");
        for j in 0..2 * n {
            aug[(col, j)] /= d;
        }
        for row in 0..n {
            if row == col {
                continue;
            }
            let f = aug[(row, col)];
            if f == 0.0 {
                continue;
            }
            for j in 0..2 * n {
                aug[(row, j)] -= f * aug[(col, j)];
            }
        }
    }
    let mut out = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            out[(i, j)] = aug[(i, n + j)];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn qr_reconstructs() {
        forall(
            16,
            |rng| {
                let n = 4 + rng.below(12) as usize;
                let m = 1 + rng.below(n as u32 - 1) as usize;
                Matrix::random_normal(rng, n, m, 1.0)
            },
            |a| {
                let (q, r) = householder_qr(a);
                let back = q.matmul(&r);
                let d = back.max_abs_diff(a);
                if d < 1e-3 { Ok(()) } else { Err(format!("recon diff {d}")) }
            },
        );
    }

    #[test]
    fn qr_orthogonal_positive_diag() {
        forall(
            16,
            |rng| Matrix::random_normal(rng, 10, 6, 1.0),
            |a| {
                let (q, r) = householder_qr(a);
                if q.orthogonality_defect() > 1e-3 {
                    return Err("Q not orthogonal".into());
                }
                for i in 0..r.rows {
                    if r[(i, i)] < 0.0 {
                        return Err(format!("R[{i},{i}] negative"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn gj_inverse() {
        forall(
            16,
            |rng| {
                let n = 1 + rng.below(10) as usize;
                let mut m = Matrix::random_normal(rng, n, n, 1.0);
                for i in 0..n {
                    m[(i, i)] += 4.0; // keep well-conditioned
                }
                m
            },
            |a| {
                let inv = gauss_jordan_inv(a);
                let d = inv.matmul(a).max_abs_diff(&Matrix::eye(a.rows));
                if d < 1e-3 { Ok(()) } else { Err(format!("defect {d}")) }
            },
        );
    }
}
