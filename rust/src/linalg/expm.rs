//! Matrix exponential and Cayley transform (native baselines for EXPRNN /
//! SCORNN and the Figure 1c harness).

use super::matrix::Matrix;
use super::qr::gauss_jordan_inv;

/// exp(A) via Taylor scaling-and-squaring — mirrors `linalg_hlo.expm_taylor`
/// so the native and AOT paths are numerically comparable.
pub fn expm(a: &Matrix, order: usize, squarings: usize) -> Matrix {
    let n = a.rows;
    assert_eq!(a.cols, n);
    let scaled = a.scale(1.0 / (1u64 << squarings) as f32);
    let mut term = Matrix::eye(n);
    let mut acc = Matrix::eye(n);
    for k in 1..=order {
        term = term.matmul(&scaled).scale(1.0 / k as f32);
        acc = acc.add(&term);
    }
    for _ in 0..squarings {
        acc = acc.matmul(&acc);
    }
    acc
}

/// Default accuracy settings used across the repo.
pub fn expm_default(a: &Matrix) -> Matrix {
    expm(a, 12, 6)
}

/// Cayley transform (I + A/2)^{-1}(I - A/2); maps Skew(N) into O^{+1}(N).
pub fn cayley(a: &Matrix) -> Matrix {
    let n = a.rows;
    let eye = Matrix::eye(n);
    let plus = eye.add(&a.scale(0.5));
    let minus = eye.sub(&a.scale(0.5));
    gauss_jordan_inv(&plus).matmul(&minus)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    #[test]
    fn expm_zero_is_identity() {
        let e = expm_default(&Matrix::zeros(5, 5));
        assert!(e.max_abs_diff(&Matrix::eye(5)) < 1e-6);
    }

    #[test]
    fn expm_rotation_2x2() {
        // exp([[0, -t], [t, 0]]) = [[cos t, -sin t], [sin t, cos t]]
        let t = 0.7f32;
        let a = Matrix::from_rows(2, 2, vec![0.0, -t, t, 0.0]);
        let e = expm_default(&a);
        assert!((e[(0, 0)] - t.cos()).abs() < 1e-5);
        assert!((e[(0, 1)] + t.sin()).abs() < 1e-5);
        assert!((e[(1, 0)] - t.sin()).abs() < 1e-5);
    }

    #[test]
    fn expm_of_skew_is_orthogonal() {
        forall(
            12,
            |rng| {
                let n = 2 + rng.below(10) as usize;
                Matrix::random_normal(rng, n, n, 0.5).skew()
            },
            |a| {
                let q = expm_default(a);
                let d = q.orthogonality_defect();
                if d < 1e-3 { Ok(()) } else { Err(format!("defect {d}")) }
            },
        );
    }

    #[test]
    fn cayley_of_skew_is_orthogonal() {
        forall(
            12,
            |rng| {
                let n = 2 + rng.below(10) as usize;
                Matrix::random_normal(rng, n, n, 0.7).skew()
            },
            |a| {
                let q = cayley(a);
                let d = q.orthogonality_defect();
                if d < 1e-3 { Ok(()) } else { Err(format!("defect {d}")) }
            },
        );
    }

    #[test]
    fn cayley_determinant_positive_branch() {
        // Cayley hits O^{+1}(N): check det > 0 via QR-free 2x2 case.
        let a = Matrix::from_rows(2, 2, vec![0.0, 1.0, -1.0, 0.0]);
        let q = cayley(&a);
        let det = q[(0, 0)] * q[(1, 1)] - q[(0, 1)] * q[(1, 0)];
        assert!(det > 0.0);
    }
}
