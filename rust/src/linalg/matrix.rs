//! Dense row-major f32 matrix with the operations the native baselines need.

use crate::util::rng::Pcg32;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// n x m slab of an identity, i.e. the `[I; 0]` of the T-CWY formula.
    pub fn eye_rect(rows: usize, cols: usize) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows.min(cols) {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Matrix { rows, cols, data }
    }

    pub fn random_normal(rng: &mut Pcg32, rows: usize, cols: usize, scale: f32) -> Matrix {
        Matrix { rows, cols, data: rng.normal_vec(rows * cols, scale) }
    }

    pub fn t(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix product (the L3/native-backend hot path).  Delegates to the
    /// blocked, cache-tiled, multithreaded kernel in [`crate::linalg::gemm`];
    /// small products stay single-threaded there, and both paths keep the
    /// reference accumulation order (see `gemm::matmul_naive`).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        super::gemm::matmul_blocked(self, other)
    }

    /// y = A x for a vector x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn scale(&self, s: f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|a| a * s).collect(),
        }
    }

    /// (A - A^T)/2 — projection to Skew(N).
    pub fn skew(&self) -> Matrix {
        assert_eq!(self.rows, self.cols);
        self.sub(&self.t()).scale(0.5)
    }

    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// max |A_ij - B_ij|
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// ||A^T A - I||_max — orthogonality defect of the columns.
    pub fn orthogonality_defect(&self) -> f32 {
        let g = self.t().matmul(self);
        g.max_abs_diff(&Matrix::eye(self.cols))
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;

    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg32::seeded(1);
        let a = Matrix::random_normal(&mut rng, 5, 7, 1.0);
        let out = a.matmul(&Matrix::eye(7));
        assert!(a.max_abs_diff(&out) < 1e-6);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_rows(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg32::seeded(2);
        let a = Matrix::random_normal(&mut rng, 4, 6, 1.0);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn skew_is_antisymmetric() {
        let mut rng = Pcg32::seeded(3);
        let s = Matrix::random_normal(&mut rng, 6, 6, 1.0).skew();
        assert!(s.add(&s.t()).frobenius() < 1e-6);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg32::seeded(4);
        let a = Matrix::random_normal(&mut rng, 3, 5, 1.0);
        let x: Vec<f32> = rng.normal_vec(5, 1.0);
        let xm = Matrix::from_rows(5, 1, x.clone());
        let via_mm = a.matmul(&xm);
        let via_mv = a.matvec(&x);
        for i in 0..3 {
            assert!((via_mm[(i, 0)] - via_mv[i]).abs() < 1e-5);
        }
    }
}
