//! Dense row-major f32 matrix with the operations the native baselines need.
//!
//! Since the zero-allocation substrate pass (DESIGN.md §3.3) the hot-path
//! entry points are the `_into` / in-place methods plus the [`Workspace`]
//! buffer pool; the original allocating methods remain as thin wrappers
//! so cold paths and tests keep their ergonomic form.  Every wrapper is
//! bitwise-identical to its in-place counterpart (same kernels, same
//! accumulation order — see `linalg::gemm`).

use crate::util::rng::Pcg32;

/// Typed shape-mismatch error for the fallible call sites that consume
/// runtime-shaped data (serve sessions, artifact tensors).  Internal math
/// with statically consistent shapes keeps using the panicking methods;
/// anything fed from the wire must go through a `try_` variant so a bad
/// request cannot take down a worker thread.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShapeError {
    /// The operation that rejected the operands (e.g. `"matvec"`).
    pub op: &'static str,
    pub expected: Vec<usize>,
    pub got: Vec<usize>,
}

impl std::fmt::Display for ShapeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: shape mismatch (expected {:?}, got {:?})",
            self.op, self.expected, self.got
        )
    }
}

impl std::error::Error for ShapeError {}

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// n x m slab of an identity, i.e. the `[I; 0]` of the T-CWY formula.
    pub fn eye_rect(rows: usize, cols: usize) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows.min(cols) {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Matrix { rows, cols, data }
    }

    pub fn random_normal(rng: &mut Pcg32, rows: usize, cols: usize, scale: f32) -> Matrix {
        Matrix { rows, cols, data: rng.normal_vec(rows * cols, scale) }
    }

    /// Reshape to `(rows, cols)`, zero-filled, reusing the existing
    /// buffer capacity (no allocation once the buffer has grown to the
    /// workload's steady-state shapes).
    pub fn resize_zeroed(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// Reshape to `(rows, cols)` **without** clearing: contents are
    /// unspecified (stale values from earlier use).  For buffers every
    /// element of which is overwritten before being read (`beta = 0`
    /// gemm outputs, `copy_from` targets) — skips the redundant
    /// O(rows·cols) memset `resize_zeroed` would pay per step.
    pub fn resize_for_overwrite(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        if self.data.len() != rows * cols {
            self.data.resize(rows * cols, 0.0);
        }
    }

    pub fn t(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix product (the L3/native-backend hot path).  Delegates to the
    /// transpose-aware kernel in [`crate::linalg::gemm`]; small products
    /// stay single-threaded there, and all paths keep the reference
    /// accumulation order (see `gemm::matmul_naive`).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        super::gemm::matmul_blocked(self, other)
    }

    /// `out = self @ other` without allocating: `out` must be preshaped
    /// to `(self.rows, other.cols)`.  Bitwise-identical to [`Matrix::matmul`].
    pub fn matmul_into(&self, other: &Matrix, out: &mut Matrix) {
        super::gemm::gemm(false, false, 1.0, self, other, 0.0, out);
    }

    /// y = A x for a vector x (panicking form — internal call sites with
    /// statically consistent shapes).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        match self.try_matvec(x) {
            Ok(y) => y,
            Err(e) => panic!("{e}"),
        }
    }

    /// y = A x, rejecting a mis-shaped `x` with a typed [`ShapeError`]
    /// instead of panicking — the form runtime-fed data must use (a serve
    /// worker feeding stale-shaped session state after a parameter swap
    /// must surface an error frame, not die on an assert).
    pub fn try_matvec(&self, x: &[f32]) -> Result<Vec<f32>, ShapeError> {
        if self.cols != x.len() {
            return Err(ShapeError {
                op: "matvec",
                expected: vec![self.cols],
                got: vec![x.len()],
            });
        }
        Ok((0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect())
    }

    /// `self += other`, elementwise, in place.
    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// `self -= other`, elementwise, in place.
    pub fn sub_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
    }

    /// `self += alpha * x` (the SGD apply / gradient-accumulate primitive).
    /// Bitwise-identical to `self.add(&x.scale(alpha))`.
    pub fn axpy(&mut self, alpha: f32, x: &Matrix) {
        assert_eq!((self.rows, self.cols), (x.rows, x.cols));
        for (a, b) in self.data.iter_mut().zip(&x.data) {
            *a += alpha * b;
        }
    }

    /// `self *= s`, in place.
    pub fn scale_in_place(&mut self, s: f32) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    /// Copy `other`'s contents into this buffer, reshaping as needed
    /// (allocation-free when the capacity already fits).
    pub fn copy_from(&mut self, other: &Matrix) {
        self.rows = other.rows;
        self.cols = other.cols;
        self.data.clear();
        self.data.extend_from_slice(&other.data);
    }

    /// Set every element to `v`.
    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        let mut out = self.clone();
        out.add_assign(other);
        out
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        let mut out = self.clone();
        out.sub_assign(other);
        out
    }

    pub fn scale(&self, s: f32) -> Matrix {
        let mut out = self.clone();
        out.scale_in_place(s);
        out
    }

    /// (A - A^T)/2 — projection to Skew(N).
    pub fn skew(&self) -> Matrix {
        assert_eq!(self.rows, self.cols);
        self.sub(&self.t()).scale(0.5)
    }

    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// max |A_ij - B_ij|
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// ||A^T A - I||_max — orthogonality defect of the columns.
    pub fn orthogonality_defect(&self) -> f32 {
        let mut g = Matrix::zeros(self.cols, self.cols);
        super::gemm::gemm(true, false, 1.0, self, self, 0.0, &mut g);
        g.max_abs_diff(&Matrix::eye(self.cols))
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;

    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

/// Reusable scratch-buffer pool for the `_into` kernels (DESIGN.md §3.3).
///
/// `take` hands out a zero-filled matrix backed by a pooled buffer;
/// `give` returns the backing buffer for reuse.  After a warmup pass at
/// the workload's steady-state shapes every `take` is allocation-free,
/// which is what the counting-allocator test in `tests/alloc_discipline`
/// pins down.  Not thread-safe by design — each worker owns its own pool
/// (serve workers, trainer threads, the rollout workspace).
#[derive(Default)]
pub struct Workspace {
    pool: Vec<Vec<f32>>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace { pool: Vec::new() }
    }

    /// Borrow a zero-filled `(rows, cols)` matrix from the pool.  Prefers
    /// the smallest pooled buffer that already fits (so a large buffer is
    /// not burned on a small request); falls back to growing the
    /// best-available buffer, which is the warmup-only allocation.
    pub fn take(&mut self, rows: usize, cols: usize) -> Matrix {
        let need = rows * cols;
        let pick = self
            .pool
            .iter()
            .enumerate()
            .filter(|(_, b)| b.capacity() >= need)
            .min_by_key(|(_, b)| b.capacity())
            .map(|(i, _)| i)
            .or_else(|| {
                // Nothing fits: grow the largest buffer (fewest reallocs
                // over a warmup with mixed shapes).
                self.pool
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, b)| b.capacity())
                    .map(|(i, _)| i)
            });
        let mut data = match pick {
            Some(i) => self.pool.swap_remove(i),
            None => Vec::new(),
        };
        data.clear();
        data.resize(need, 0.0);
        Matrix { rows, cols, data }
    }

    /// Return a matrix taken with [`Workspace::take`] to the pool.
    pub fn give(&mut self, m: Matrix) {
        self.pool.push(m.data);
    }

    /// Number of pooled (idle) buffers — used by the allocation tests.
    pub fn idle(&self) -> usize {
        self.pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg32::seeded(1);
        let a = Matrix::random_normal(&mut rng, 5, 7, 1.0);
        let out = a.matmul(&Matrix::eye(7));
        assert!(a.max_abs_diff(&out) < 1e-6);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_rows(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg32::seeded(2);
        let a = Matrix::random_normal(&mut rng, 4, 6, 1.0);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn skew_is_antisymmetric() {
        let mut rng = Pcg32::seeded(3);
        let s = Matrix::random_normal(&mut rng, 6, 6, 1.0).skew();
        assert!(s.add(&s.t()).frobenius() < 1e-6);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg32::seeded(4);
        let a = Matrix::random_normal(&mut rng, 3, 5, 1.0);
        let x: Vec<f32> = rng.normal_vec(5, 1.0);
        let xm = Matrix::from_rows(5, 1, x.clone());
        let via_mm = a.matmul(&xm);
        let via_mv = a.matvec(&x);
        for i in 0..3 {
            assert!((via_mm[(i, 0)] - via_mv[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn try_matvec_returns_typed_shape_error() {
        let a = Matrix::eye(3);
        let err = a.try_matvec(&[1.0, 2.0]).unwrap_err();
        assert_eq!(err.op, "matvec");
        assert_eq!(err.expected, vec![3]);
        assert_eq!(err.got, vec![2]);
        // And the error formats usefully / converts into anyhow.
        let msg = format!("{err}");
        assert!(msg.contains("matvec"), "{msg}");
        let _: anyhow::Error = err.into();
        assert_eq!(a.try_matvec(&[1.0, 2.0, 3.0]).unwrap(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn in_place_ops_bitwise_match_allocating_wrappers() {
        let mut rng = Pcg32::seeded(5);
        let a = Matrix::random_normal(&mut rng, 4, 6, 1.0);
        let b = Matrix::random_normal(&mut rng, 4, 6, 1.0);
        let bits = |m: &Matrix| m.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>();

        let mut x = a.clone();
        x.add_assign(&b);
        assert_eq!(bits(&x), bits(&a.add(&b)));

        let mut x = a.clone();
        x.sub_assign(&b);
        assert_eq!(bits(&x), bits(&a.sub(&b)));

        let mut x = a.clone();
        x.axpy(-0.37, &b);
        assert_eq!(bits(&x), bits(&a.add(&b.scale(-0.37))));

        let mut x = a.clone();
        x.scale_in_place(1.7);
        assert_eq!(bits(&x), bits(&a.scale(1.7)));

        let c = Matrix::random_normal(&mut rng, 6, 3, 1.0);
        let mut out = Matrix::zeros(4, 3);
        a.matmul_into(&c, &mut out);
        assert_eq!(bits(&out), bits(&a.matmul(&c)));
    }

    #[test]
    fn workspace_reuses_buffers_without_regrowth() {
        let mut ws = Workspace::new();
        let m = ws.take(4, 8);
        assert!(m.data.iter().all(|&x| x == 0.0));
        let cap = m.data.capacity();
        let ptr = m.data.as_ptr();
        ws.give(m);
        assert_eq!(ws.idle(), 1);
        // Same-or-smaller shapes reuse the identical backing buffer.
        let mut m2 = ws.take(2, 8);
        assert_eq!(m2.data.as_ptr(), ptr);
        assert_eq!(m2.data.capacity(), cap);
        m2.fill(3.0);
        ws.give(m2);
        // Re-take zero-fills stale contents.
        let m3 = ws.take(4, 8);
        assert!(m3.data.iter().all(|&x| x == 0.0));
        ws.give(m3);
        // Smallest-fit policy: a small buffer is preferred over a large one.
        let big = ws.take(32, 32);
        ws.give(big);
        ws.give(Matrix::zeros(1, 4));
        let small = ws.take(1, 2);
        assert!(small.data.capacity() < 32 * 32);
    }

    #[test]
    fn resize_zeroed_keeps_capacity() {
        let mut m = Matrix::zeros(8, 8);
        let cap = m.data.capacity();
        let ptr = m.data.as_ptr();
        m[(0, 0)] = 5.0;
        m.resize_zeroed(4, 4);
        assert_eq!((m.rows, m.cols), (4, 4));
        assert!(m.data.iter().all(|&x| x == 0.0));
        assert_eq!(m.data.capacity(), cap);
        assert_eq!(m.data.as_ptr(), ptr);
    }
}
