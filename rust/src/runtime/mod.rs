//! Runtime layer: manifest artifacts behind the backend seam.
//! `Engine::open` -> `load(name)` -> `Compiled::run(inputs)`.
//!
//! Python never appears here: artifacts are either HLO text produced
//! once by `make artifacts` and executed through PJRT, or registered
//! native ops ([`native`]) interpreted directly in Rust — the [`Backend`]
//! selector (DESIGN.md §2.6) picks per engine, defaulting to PJRT with a
//! native fallback.  Either way a training/bench step is one fused
//! execution of a loss+grad+update module.

pub mod engine;
pub mod fixture;
pub mod manifest;
pub mod native;
pub mod tensor;

pub use engine::{Backend, Compiled, Engine};
pub use manifest::{ArtifactSpec, Manifest, Role, TensorSpec};
pub use native::{CellKind, NativeExec, NativeOp, StepMode};
pub use tensor::{Data, Dtype, HostTensor};
