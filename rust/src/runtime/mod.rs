//! Runtime layer: the `xla` crate (PJRT CPU) wrapped behind the artifact
//! manifest.  `Engine::open` -> `load(name)` -> `Compiled::run(inputs)`.
//!
//! Python never appears here: artifacts are HLO text produced once by
//! `make artifacts`, and every training/bench step is a single PJRT
//! execution of a fused loss+grad+update module.

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::{Compiled, Engine};
pub use manifest::{ArtifactSpec, Manifest, Role, TensorSpec};
pub use tensor::{Data, Dtype, HostTensor};
