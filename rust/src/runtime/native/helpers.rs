//! Shared signature-validation and tensor-marshalling helpers for the
//! native op families (DESIGN.md §2.6).  Every family validates its
//! manifest contract with these so error messages stay uniform.

use anyhow::{bail, Result};

use crate::linalg::Matrix;
use crate::runtime::manifest::{ArtifactSpec, Role, TensorSpec};
use crate::runtime::tensor::{Dtype, HostTensor};

/// View a rank-2 f32 host tensor as a [`Matrix`].
pub fn mat(t: &HostTensor) -> Result<Matrix> {
    if t.shape.len() != 2 {
        bail!("expected a rank-2 tensor, got shape {:?}", t.shape);
    }
    Ok(Matrix::from_rows(t.shape[0], t.shape[1], t.as_f32()?.to_vec()))
}

/// Wrap a [`Matrix`] back into a rank-2 f32 host tensor.
pub fn tensor(m: Matrix) -> HostTensor {
    HostTensor::f32(vec![m.rows, m.cols], m.data)
}

/// The two dimensions of a rank-2 port spec.
pub fn dims2(ts: &TensorSpec) -> Result<(usize, usize)> {
    if ts.shape.len() != 2 {
        bail!("port '{}': expected rank 2, got shape {:?}", ts.name, ts.shape);
    }
    Ok((ts.shape[0], ts.shape[1]))
}

/// Require an exact port shape.
pub fn expect_shape(ts: &TensorSpec, want: &[usize]) -> Result<()> {
    if ts.shape != want {
        bail!("port '{}': shape {:?}, op expects {:?}", ts.name, ts.shape, want);
    }
    Ok(())
}

/// Require a port dtype.
pub fn expect_dtype(ts: &TensorSpec, want: Dtype) -> Result<()> {
    if ts.dtype != want {
        bail!("port '{}': dtype {:?}, op expects {:?}", ts.name, ts.dtype, want);
    }
    Ok(())
}

/// Require input/output counts; dtypes are checked per-port by the
/// family (see [`expect_all_f32`] for the common all-f32 case).
pub fn expect_arity(spec: &ArtifactSpec, inputs: usize, outputs: usize) -> Result<()> {
    if spec.inputs.len() != inputs {
        bail!("op takes {inputs} inputs, manifest lists {}", spec.inputs.len());
    }
    if spec.outputs.len() != outputs {
        bail!("op yields {outputs} outputs, manifest lists {}", spec.outputs.len());
    }
    Ok(())
}

/// Require every port (inputs and outputs) to be f32.
pub fn expect_all_f32(spec: &ArtifactSpec) -> Result<()> {
    for ts in spec.inputs.iter().chain(&spec.outputs) {
        if ts.dtype != Dtype::F32 {
            bail!("port '{}': this op is f32-only", ts.name);
        }
    }
    Ok(())
}

/// Require the leading input roles to match the op's calling convention.
pub fn expect_roles(spec: &ArtifactSpec, roles: &[Role]) -> Result<()> {
    for (ts, want) in spec.inputs.iter().zip(roles) {
        if ts.role != *want {
            bail!("port '{}': role {:?}, op expects {:?}", ts.name, ts.role, want);
        }
    }
    Ok(())
}
