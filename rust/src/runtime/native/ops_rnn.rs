//! Native op family `rnn_copy`: a **trainable** orthogonal-recurrence RNN
//! on the paper's copying task (§4.1) — the experiment the CWY
//! parametrization exists for, now executable under plain `cargo test`
//! with no Python and no PJRT.
//!
//! Model (linear orthogonal RNN, the §2.2 state being the parameters):
//!
//! ```text
//! h_0 = 0
//! h_{t+1} = h_t Q(V) + W_in[token_t]        Q per meta.param: cwy | hr | tcwy
//! logits_t = h_{t+1} W_out + b_out          softmax CE vs target_t
//! loss = mean over batch x time
//! ```
//!
//! Gradients are exact BPTT through the parametrization
//! ([`crate::orthogonal::backward`]): fused CWY accumulation for `cwy`,
//! the sequential per-Householder chain for `hr`, and the Thm 3 Ω-path
//! (square, St(N,N) = O(N)) for `tcwy`.  Every matmul routes through the
//! blocked GEMM hot path.
//!
//! | `meta.op`        | kind  | signature (roles) |
//! |------------------|-------|-------------------|
//! | `rnn_copy_step`  | step  | V, W_in `[10,n]`, W_out `[n,9]`, b `[1,9]` state; tokens, targets `[b,t]` i32 data; lr hyper → params', loss, grad_norm |
//! | `rnn_copy_grad`  | grad  | params (state), tokens, targets → ∇params, loss, grad_norm |
//! | `rnn_copy_apply` | apply | params (state), ∇params (data), lr hyper → params' |
//! | `rnn_copy_eval`  | eval  | params, tokens, targets (all data) → loss |
//!
//! `meta.param` selects the parametrization; `cwy`/`hr` differentiate the
//! *same* function, so their gradients agree elementwise — the PR's
//! acceptance check and the Fig. 2 story at the gradient level.

use anyhow::{bail, Result};

use super::helpers::{dims2, expect_arity, expect_dtype, expect_roles, expect_shape, mat, tensor};
use super::{CellKind, FamilyDef, NativeOp, StepMode, PARAM_META_KEY};
use crate::linalg::Matrix;
use crate::orthogonal::backward::{hr_chain_backward, CwyGrad, TcwyGrad};
use crate::orthogonal::{cwy, householder, tcwy};
use crate::runtime::manifest::{ArtifactSpec, Role};
use crate::runtime::tensor::{Dtype, HostTensor};

/// Input alphabet of the copying task: blank, digits 1..=8, marker 9.
pub const IN_VOCAB: usize = 10;
/// Output classes: blank + digits 1..=8.
pub const OUT_CLASSES: usize = 9;

pub static FAMILY: FamilyDef = FamilyDef {
    name: "rnn_copy",
    ops: &["rnn_copy_step", "rnn_copy_grad", "rnn_copy_apply", "rnn_copy_eval"],
    resolve,
    validate,
    run,
};

fn resolve(op: &str, spec: &ArtifactSpec) -> Option<Result<NativeOp>> {
    let mode = match op {
        "rnn_copy_step" => StepMode::Step,
        "rnn_copy_grad" => StepMode::Grad,
        "rnn_copy_apply" => StepMode::Apply,
        "rnn_copy_eval" => StepMode::Eval,
        _ => return None,
    };
    let kind = match spec.meta_str(PARAM_META_KEY) {
        Some(p) => match CellKind::parse_param(p) {
            Some(k) => k,
            None => {
                return Some(Err(anyhow::anyhow!(
                    "bad '{PARAM_META_KEY}' meta '{p}' (expected cwy|hr|tcwy)"
                )))
            }
        },
        None => {
            return Some(Err(anyhow::anyhow!(
                "op '{op}' needs a '{PARAM_META_KEY}' meta key (cwy|hr|tcwy)"
            )))
        }
    };
    Some(Ok(NativeOp::RnnCopy(kind, mode)))
}

/// Validate the (V, W_in, W_out, b) parameter block starting at input
/// `off`; returns the reflection shape (l, n).
fn validate_params(spec: &ArtifactSpec, kind: CellKind, off: usize) -> Result<(usize, usize)> {
    let (l, n) = dims2(&spec.inputs[off])?;
    if kind == CellKind::Tcwy && l != n {
        bail!(
            "rnn_copy with param=tcwy needs square V (the recurrence lives \
             on St(N,N) = O(N)), got {:?}",
            spec.inputs[off].shape
        );
    }
    expect_shape(&spec.inputs[off + 1], &[IN_VOCAB, n])?;
    expect_shape(&spec.inputs[off + 2], &[n, OUT_CLASSES])?;
    expect_shape(&spec.inputs[off + 3], &[1, OUT_CLASSES])?;
    for ts in &spec.inputs[off..off + 4] {
        expect_dtype(ts, Dtype::F32)?;
    }
    Ok((l, n))
}

/// Validate the (tokens, targets) data block starting at input `off`.
fn validate_data(spec: &ArtifactSpec, off: usize) -> Result<()> {
    let (b, t) = dims2(&spec.inputs[off])?;
    if b == 0 || t == 0 {
        bail!("tokens shape {:?} has an empty axis", spec.inputs[off].shape);
    }
    expect_shape(&spec.inputs[off + 1], &[b, t])?;
    expect_dtype(&spec.inputs[off], Dtype::I32)?;
    expect_dtype(&spec.inputs[off + 1], Dtype::I32)?;
    Ok(())
}

fn param_shapes(l: usize, n: usize) -> [Vec<usize>; 4] {
    [
        vec![l, n],
        vec![IN_VOCAB, n],
        vec![n, OUT_CLASSES],
        vec![1, OUT_CLASSES],
    ]
}

fn validate(spec: &ArtifactSpec, op: NativeOp) -> Result<()> {
    let NativeOp::RnnCopy(kind, mode) = op else {
        bail!("op {op:?} is not in the rnn_copy family");
    };
    for ts in &spec.outputs {
        expect_dtype(ts, Dtype::F32)?;
    }
    match mode {
        StepMode::Step => {
            expect_arity(spec, 7, 6)?;
            expect_roles(
                spec,
                &[
                    Role::State,
                    Role::State,
                    Role::State,
                    Role::State,
                    Role::Data,
                    Role::Data,
                    Role::Hyper,
                ],
            )?;
            let (l, n) = validate_params(spec, kind, 0)?;
            validate_data(spec, 4)?;
            expect_shape(&spec.inputs[6], &[])?;
            expect_dtype(&spec.inputs[6], Dtype::F32)?;
            for (ts, want) in spec.outputs[..4].iter().zip(param_shapes(l, n)) {
                expect_shape(ts, &want)?;
            }
            expect_shape(&spec.outputs[4], &[])?;
            expect_shape(&spec.outputs[5], &[])
        }
        StepMode::Grad => {
            expect_arity(spec, 6, 6)?;
            expect_roles(
                spec,
                &[Role::State, Role::State, Role::State, Role::State, Role::Data, Role::Data],
            )?;
            let (l, n) = validate_params(spec, kind, 0)?;
            validate_data(spec, 4)?;
            for (ts, want) in spec.outputs[..4].iter().zip(param_shapes(l, n)) {
                expect_shape(ts, &want)?;
            }
            expect_shape(&spec.outputs[4], &[])?;
            expect_shape(&spec.outputs[5], &[])
        }
        StepMode::Apply => {
            expect_arity(spec, 9, 4)?;
            expect_roles(
                spec,
                &[
                    Role::State,
                    Role::State,
                    Role::State,
                    Role::State,
                    Role::Data,
                    Role::Data,
                    Role::Data,
                    Role::Data,
                    Role::Hyper,
                ],
            )?;
            let (l, n) = validate_params(spec, kind, 0)?;
            let shapes = param_shapes(l, n);
            for (ts, want) in spec.inputs[4..8].iter().zip(&shapes) {
                expect_shape(ts, want)?;
                expect_dtype(ts, Dtype::F32)?;
            }
            expect_shape(&spec.inputs[8], &[])?;
            expect_dtype(&spec.inputs[8], Dtype::F32)?;
            for (ts, want) in spec.outputs.iter().zip(&shapes) {
                expect_shape(ts, want)?;
            }
            Ok(())
        }
        StepMode::Eval => {
            expect_arity(spec, 6, 1)?;
            // Pure function of (params..., data...): everything is data.
            expect_roles(spec, &[Role::Data; 6])?;
            validate_params(spec, kind, 0)?;
            validate_data(spec, 4)?;
            expect_shape(&spec.outputs[0], &[])
        }
    }
}

/// The four trainable tensors of the copy-task RNN.
pub struct CopyRnnParams {
    /// Reflection rows: (L, N), square (N, N) for `tcwy`.
    pub v: Matrix,
    /// Token embedding, (IN_VOCAB, N).
    pub w_in: Matrix,
    /// Readout, (N, OUT_CLASSES).
    pub w_out: Matrix,
    /// Readout bias, (1, OUT_CLASSES).
    pub b_out: Matrix,
}

/// Gradients with respect to the four parameter tensors.
pub struct CopyRnnGrads {
    pub v: Matrix,
    pub w_in: Matrix,
    pub w_out: Matrix,
    pub b_out: Matrix,
}

impl CopyRnnGrads {
    /// Euclidean norm over the whole parameter block — the per-step
    /// descent diagnostic surfaced in `metrics::History`.
    pub fn global_norm(&self) -> f32 {
        [&self.v, &self.w_in, &self.w_out, &self.b_out]
            .iter()
            .map(|m| m.data.iter().map(|x| x * x).sum::<f32>())
            .sum::<f32>()
            .sqrt()
    }
}

/// The recurrent transition `h → h Q` for each parametrization, with the
/// state it needs to run BPTT afterwards.
enum Transition {
    Cwy(cwy::CwyOperator),
    Hr,
    /// Materialized square Ω (Thm 3 at M = N).
    Tcwy(Matrix),
}

impl Transition {
    fn new(kind: CellKind, v: &Matrix) -> Transition {
        match kind {
            CellKind::Cwy => Transition::Cwy(cwy::CwyOperator::new(v)),
            CellKind::Hr => Transition::Hr,
            CellKind::Tcwy => Transition::Tcwy(tcwy::matrix(v)),
        }
    }

    fn apply(&self, v: &Matrix, h: &Matrix) -> Matrix {
        match self {
            Transition::Cwy(op) => op.apply(h),
            Transition::Hr => {
                let mut out = h.clone();
                householder::apply_chain(v, &mut out);
                out
            }
            Transition::Tcwy(omega) => h.matmul(omega),
        }
    }
}

/// Accumulates the V-path of the BPTT, per parametrization.
enum TransitionGrad {
    Cwy(CwyGrad),
    Hr(Matrix),
    Tcwy { grad: TcwyGrad, omega: Matrix, domega: Matrix },
}

impl TransitionGrad {
    fn new(kind: CellKind, v: &Matrix, trans: &Transition) -> TransitionGrad {
        match kind {
            CellKind::Cwy => TransitionGrad::Cwy(CwyGrad::new(v)),
            CellKind::Hr => TransitionGrad::Hr(Matrix::zeros(v.rows, v.cols)),
            CellKind::Tcwy => {
                let Transition::Tcwy(omega) = trans else { unreachable!() };
                TransitionGrad::Tcwy {
                    grad: TcwyGrad::new(v),
                    omega: omega.clone(),
                    domega: Matrix::zeros(omega.rows, omega.cols),
                }
            }
        }
    }

    /// Backward through one transition `y = h Q`: upstream `g = dL/dy`,
    /// stored input `h`; returns `dL/dh` and accumulates the V-path.
    fn backward(&mut self, v: &Matrix, h: &Matrix, g: &Matrix) -> Matrix {
        match self {
            TransitionGrad::Cwy(grad) => grad.apply_backward(h, g),
            TransitionGrad::Hr(dv) => {
                let (dh, dvs) = hr_chain_backward(v, h, g);
                *dv = dv.add(&dvs);
                dh
            }
            TransitionGrad::Tcwy { omega, domega, .. } => {
                *domega = domega.add(&h.t().matmul(g));
                g.matmul(&omega.t())
            }
        }
    }

    fn into_dv(self, v: &Matrix) -> Matrix {
        match self {
            TransitionGrad::Cwy(grad) => grad.into_dv(v),
            TransitionGrad::Hr(dv) => dv,
            TransitionGrad::Tcwy { mut grad, domega, .. } => {
                grad.matrix_backward(&domega);
                grad.into_dv(v)
            }
        }
    }
}

/// One copy-task batch viewed by the RNN: row-major `(batch, t_total)`
/// token and target grids.
pub struct CopyBatchRef<'a> {
    pub tokens: &'a [i32],
    pub targets: &'a [i32],
    pub batch: usize,
    pub t_total: usize,
}

/// Forward pass (and optionally exact BPTT) of the copy-task RNN.
pub fn forward_backward(
    kind: CellKind,
    params: &CopyRnnParams,
    data: &CopyBatchRef,
    want_grads: bool,
) -> Result<(f32, Option<CopyRnnGrads>)> {
    let CopyRnnParams { v, w_in, w_out, b_out } = params;
    let (batch, t_total) = (data.batch, data.t_total);
    let n = v.cols;
    let denom = (batch * t_total) as f32;
    let trans = Transition::new(kind, v);

    // ---- forward, storing hidden states and per-step logit gradients
    let mut hs: Vec<Matrix> = Vec::with_capacity(t_total + 1);
    hs.push(Matrix::zeros(batch, n));
    let mut dlogits: Vec<Matrix> = Vec::with_capacity(t_total);
    let mut loss_sum = 0.0f32;
    for t in 0..t_total {
        let mut x = Matrix::zeros(batch, n);
        for b in 0..batch {
            let tok = data.tokens[b * t_total + t];
            if tok < 0 || tok as usize >= IN_VOCAB {
                bail!("token {tok} at (row {b}, t {t}) outside 0..{IN_VOCAB}");
            }
            x.row_mut(b).copy_from_slice(w_in.row(tok as usize));
        }
        let h_next = trans.apply(v, hs.last().unwrap()).add(&x);
        let logits = h_next.matmul(w_out);
        let mut dl = Matrix::zeros(batch, OUT_CLASSES);
        for b in 0..batch {
            let tgt = data.targets[b * t_total + t];
            if tgt < 0 || tgt as usize >= OUT_CLASSES {
                bail!("target {tgt} at (row {b}, t {t}) outside 0..{OUT_CLASSES}");
            }
            // Stable softmax cross-entropy on logits + b_out.
            let bias = b_out.row(0);
            let mut mx = f32::NEG_INFINITY;
            for (lc, bc) in logits.row(b).iter().zip(bias) {
                mx = mx.max(lc + bc);
            }
            let mut e = [0.0f32; OUT_CLASSES];
            let mut z = 0.0f32;
            for ((ec, lc), bc) in e.iter_mut().zip(logits.row(b)).zip(bias) {
                *ec = (lc + bc - mx).exp();
                z += *ec;
            }
            loss_sum -= (e[tgt as usize] / z).max(1e-30).ln();
            for (c, &ec) in e.iter().enumerate() {
                let hit = if c == tgt as usize { 1.0 } else { 0.0 };
                dl[(b, c)] = (ec / z - hit) / denom;
            }
        }
        hs.push(h_next);
        if want_grads {
            dlogits.push(dl);
        }
    }
    let loss = loss_sum / denom;
    if !want_grads {
        return Ok((loss, None));
    }

    // ---- backward (BPTT)
    let mut tg = TransitionGrad::new(kind, v, &trans);
    let mut d_win = Matrix::zeros(IN_VOCAB, n);
    let mut d_wout = Matrix::zeros(n, OUT_CLASSES);
    let mut d_b = Matrix::zeros(1, OUT_CLASSES);
    let mut g = Matrix::zeros(batch, n);
    for t in (0..t_total).rev() {
        let dl = &dlogits[t];
        d_wout = d_wout.add(&hs[t + 1].t().matmul(dl));
        for b in 0..batch {
            for c in 0..OUT_CLASSES {
                d_b[(0, c)] += dl[(b, c)];
            }
        }
        g = g.add(&dl.matmul(&w_out.t()));
        // h_{t+1} = (h_t Q) + x_t: dx_t = g lands on the token's row of
        // W_in; the transition backward yields dL/dh_t.
        for b in 0..batch {
            let tok = data.tokens[b * t_total + t] as usize;
            for (dw, gv) in d_win.row_mut(tok).iter_mut().zip(g.row(b)) {
                *dw += gv;
            }
        }
        g = tg.backward(v, &hs[t], &g);
    }
    let grads = CopyRnnGrads { v: tg.into_dv(v), w_in: d_win, w_out: d_wout, b_out: d_b };
    Ok((loss, Some(grads)))
}

struct Inputs {
    params: CopyRnnParams,
    tokens: Vec<i32>,
    targets: Vec<i32>,
    batch: usize,
    t_total: usize,
}

impl Inputs {
    fn data(&self) -> CopyBatchRef<'_> {
        CopyBatchRef {
            tokens: &self.tokens,
            targets: &self.targets,
            batch: self.batch,
            t_total: self.t_total,
        }
    }
}

fn unpack(inputs: &[&HostTensor]) -> Result<Inputs> {
    Ok(Inputs {
        params: CopyRnnParams {
            v: mat(inputs[0])?,
            w_in: mat(inputs[1])?,
            w_out: mat(inputs[2])?,
            b_out: mat(inputs[3])?,
        },
        tokens: inputs[4].as_i32()?.to_vec(),
        targets: inputs[5].as_i32()?.to_vec(),
        batch: inputs[4].shape[0],
        t_total: inputs[4].shape[1],
    })
}

fn run(_spec: &ArtifactSpec, op: NativeOp, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    let NativeOp::RnnCopy(kind, mode) = op else {
        bail!("op {op:?} is not in the rnn_copy family");
    };
    match mode {
        StepMode::Step | StepMode::Grad => {
            let inp = unpack(inputs)?;
            let (loss, grads) = forward_backward(kind, &inp.params, &inp.data(), true)?;
            let grads = grads.expect("grads requested");
            let gnorm = grads.global_norm();
            let out_params = match mode {
                StepMode::Grad => [grads.v, grads.w_in, grads.w_out, grads.b_out],
                _ => {
                    let lr = inputs[6].scalar()?;
                    let p = &inp.params;
                    [
                        p.v.sub(&grads.v.scale(lr)),
                        p.w_in.sub(&grads.w_in.scale(lr)),
                        p.w_out.sub(&grads.w_out.scale(lr)),
                        p.b_out.sub(&grads.b_out.scale(lr)),
                    ]
                }
            };
            let mut out: Vec<HostTensor> = out_params.into_iter().map(tensor).collect();
            out.push(HostTensor::scalar_f32(loss));
            out.push(HostTensor::scalar_f32(gnorm));
            Ok(out)
        }
        StepMode::Apply => {
            let lr = inputs[8].scalar()?;
            (0..4)
                .map(|i| {
                    let p = mat(inputs[i])?;
                    let g = mat(inputs[4 + i])?;
                    Ok(tensor(p.sub(&g.scale(lr))))
                })
                .collect()
        }
        StepMode::Eval => {
            let inp = unpack(inputs)?;
            let (loss, _) = forward_backward(kind, &inp.params, &inp.data(), false)?;
            Ok(vec![HostTensor::scalar_f32(loss)])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orthogonal::backward::finite_diff;
    use crate::util::rng::Pcg32;

    struct Tiny {
        params: CopyRnnParams,
        tokens: Vec<i32>,
        targets: Vec<i32>,
        batch: usize,
        t_total: usize,
    }

    impl Tiny {
        fn data(&self) -> CopyBatchRef<'_> {
            CopyBatchRef {
                tokens: &self.tokens,
                targets: &self.targets,
                batch: self.batch,
                t_total: self.t_total,
            }
        }
    }

    fn tiny_setup(seed: u64, l: usize, n: usize, b: usize, t: usize) -> Tiny {
        let mut rng = Pcg32::seeded(seed);
        let params = CopyRnnParams {
            v: Matrix::random_normal(&mut rng, l, n, 1.0),
            w_in: Matrix::random_normal(&mut rng, IN_VOCAB, n, 0.3),
            w_out: Matrix::random_normal(&mut rng, n, OUT_CLASSES, 0.3),
            b_out: Matrix::random_normal(&mut rng, 1, OUT_CLASSES, 0.1),
        };
        let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(IN_VOCAB as u32) as i32).collect();
        let targets: Vec<i32> = (0..b * t).map(|_| rng.below(OUT_CLASSES as u32) as i32).collect();
        Tiny { params, tokens, targets, batch: b, t_total: t }
    }

    /// Exact-BPTT check: every parameter gradient matches central finite
    /// differences of the f32 forward loss (tolerance-scaled for f32),
    /// for all three parametrizations.
    #[test]
    fn gradients_match_finite_differences() {
        for kind in [CellKind::Cwy, CellKind::Hr, CellKind::Tcwy] {
            let (l, n, b, t) = match kind {
                CellKind::Tcwy => (6, 6, 2, 5),
                _ => (3, 6, 2, 5),
            };
            let tiny = tiny_setup(9, l, n, b, t);
            let p = &tiny.params;
            let loss_of = |params: &CopyRnnParams| {
                forward_backward(kind, params, &tiny.data(), false).unwrap().0
            };
            let (_, grads) = forward_backward(kind, p, &tiny.data(), true).unwrap();
            let grads = grads.unwrap();
            let with = |v: Matrix, w_in: Matrix, w_out: Matrix, b_out: Matrix| {
                CopyRnnParams { v, w_in, w_out, b_out }
            };
            // The loss is O(ln 9) and the FD quotient divides f32 noise by
            // 2*eps, so compare with a scaled tolerance.
            let eps = 3e-3;
            let tol = 3e-3;
            let fd_v = finite_diff(&p.v, eps, |x| {
                loss_of(&with(x.clone(), p.w_in.clone(), p.w_out.clone(), p.b_out.clone()))
            });
            let fd_win = finite_diff(&p.w_in, eps, |x| {
                loss_of(&with(p.v.clone(), x.clone(), p.w_out.clone(), p.b_out.clone()))
            });
            let fd_wout = finite_diff(&p.w_out, eps, |x| {
                loss_of(&with(p.v.clone(), p.w_in.clone(), x.clone(), p.b_out.clone()))
            });
            let fd_b = finite_diff(&p.b_out, eps, |x| {
                loss_of(&with(p.v.clone(), p.w_in.clone(), p.w_out.clone(), x.clone()))
            });
            let cases: [(&str, &Matrix, Matrix); 4] = [
                ("v", &grads.v, fd_v),
                ("w_in", &grads.w_in, fd_win),
                ("w_out", &grads.w_out, fd_wout),
                ("b_out", &grads.b_out, fd_b),
            ];
            for (name, analytic, numeric) in cases {
                let scale = numeric.data.iter().fold(1.0f32, |m, x| m.max(x.abs()));
                let err = analytic.max_abs_diff(&numeric) / scale;
                assert!(err < tol, "{kind:?} d{name}: scaled FD error {err}");
            }
        }
    }

    /// cwy and hr parametrize the same function, so their BPTT gradients
    /// agree elementwise (acceptance bound 1e-4) on the same rollout.
    #[test]
    fn cwy_and_hr_grads_agree_elementwise() {
        let tiny = tiny_setup(21, 4, 12, 3, 8);
        let run = |kind| forward_backward(kind, &tiny.params, &tiny.data(), true).unwrap();
        let (loss_c, gc) = run(CellKind::Cwy);
        let (loss_h, gh) = run(CellKind::Hr);
        let (gc, gh) = (gc.unwrap(), gh.unwrap());
        assert!((loss_c - loss_h).abs() <= 1e-5, "loss {loss_c} vs {loss_h}");
        assert!(gc.v.max_abs_diff(&gh.v) <= 1e-4);
        assert!(gc.w_in.max_abs_diff(&gh.w_in) <= 1e-4);
        assert!(gc.w_out.max_abs_diff(&gh.w_out) <= 1e-4);
        assert!(gc.b_out.max_abs_diff(&gh.b_out) <= 1e-4);
    }

    /// A few fused steps on a fixed batch drive the loss down — the
    /// smallest possible descent smoke for the family itself (the full
    /// below-baseline run lives in the trainer integration suite).
    #[test]
    fn repeated_steps_descend_on_fixed_batch() {
        let mut tiny = tiny_setup(5, 4, 16, 4, 10);
        let mut losses = Vec::new();
        for _ in 0..30 {
            let data = CopyBatchRef {
                tokens: &tiny.tokens,
                targets: &tiny.targets,
                batch: tiny.batch,
                t_total: tiny.t_total,
            };
            let (loss, grads) = forward_backward(CellKind::Cwy, &tiny.params, &data, true).unwrap();
            let g = grads.unwrap();
            losses.push(loss);
            let lr = 0.5;
            let p = &mut tiny.params;
            p.v = p.v.sub(&g.v.scale(lr));
            p.w_in = p.w_in.sub(&g.w_in.scale(lr));
            p.w_out = p.w_out.sub(&g.w_out.scale(lr));
            p.b_out = p.b_out.sub(&g.b_out.scale(lr));
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.5),
            "no descent: {} -> {}",
            losses[0],
            losses.last().unwrap()
        );
        assert!(losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn rejects_out_of_range_tokens() {
        let mut tiny = tiny_setup(3, 2, 4, 1, 3);
        tiny.tokens[1] = 12;
        let err = forward_backward(CellKind::Cwy, &tiny.params, &tiny.data(), false).unwrap_err();
        assert!(format!("{err:#}").contains("outside"), "{err:#}");
    }
}
