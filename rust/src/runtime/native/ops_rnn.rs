//! Native op family `rnn_copy`: a **trainable** orthogonal-recurrence RNN
//! on the paper's copying task (§4.1) — the experiment the CWY
//! parametrization exists for, now executable under plain `cargo test`
//! with no Python and no PJRT.
//!
//! Model (linear orthogonal RNN, the §2.2 state being the parameters):
//!
//! ```text
//! h_0 = 0
//! h_{t+1} = h_t Q(V) + W_in[token_t]        Q per meta.param: cwy | hr | tcwy
//! logits_t = h_{t+1} W_out + b_out          softmax CE vs target_t
//! loss = mean over batch x time
//! ```
//!
//! Gradients are exact BPTT through the parametrization
//! ([`crate::orthogonal::backward`]): fused CWY accumulation for `cwy`,
//! the sequential per-Householder chain for `hr`, and the Thm 3 Ω-path
//! (square, St(N,N) = O(N)) for `tcwy`.
//!
//! Since the zero-allocation substrate pass (DESIGN.md §3.3) the whole
//! rollout — forward states, per-step logit gradients, the BPTT itself —
//! runs over a preallocated [`RolloutWorkspace`]: the hidden-state ring,
//! logits/grad scratch, the parametrization tape, and the gemm pack
//! panels are all reused across training steps, so a steady-state step
//! performs **zero heap allocations** after warmup (pinned by
//! `tests/alloc_discipline`).  Every matmul routes through the
//! transpose-aware [`crate::linalg::gemm`] with fused `beta = 1`
//! accumulation — `d_wout += hsᵀ dl` is one call, no `.t()`
//! materialization, no temporary.  The family's `run` keeps a
//! thread-local workspace, so trainer loops and serve workers each reuse
//! their own buffers across calls.
//!
//! | `meta.op`        | kind  | signature (roles) |
//! |------------------|-------|-------------------|
//! | `rnn_copy_step`  | step  | V, W_in `[10,n]`, W_out `[n,9]`, b `[1,9]` state; tokens, targets `[b,t]` i32 data; lr hyper → params', loss, grad_norm |
//! | `rnn_copy_grad`  | grad  | params (state), tokens, targets → ∇params, loss, grad_norm |
//! | `rnn_copy_apply` | apply | params (state), ∇params (data), lr hyper → params' |
//! | `rnn_copy_eval`  | eval  | params, tokens, targets (all data) → loss |
//!
//! `meta.param` selects the parametrization; `cwy`/`hr` differentiate the
//! *same* function, so their gradients agree elementwise — the PR-4
//! acceptance check and the Fig. 2 story at the gradient level.

use std::cell::RefCell;

use anyhow::{bail, Result};

use super::helpers::{dims2, expect_arity, expect_dtype, expect_roles, expect_shape, mat, tensor};
use super::{CellKind, FamilyDef, NativeOp, StepMode, PARAM_META_KEY};
use crate::linalg::{gemm, Matrix, Workspace};
use crate::orthogonal::backward::{hr_chain_backward, CwyGrad, TcwyGrad};
use crate::orthogonal::householder;
use crate::runtime::manifest::{ArtifactSpec, Role};
use crate::runtime::tensor::{Dtype, HostTensor};

/// Input alphabet of the copying task: blank, digits 1..=8, marker 9.
pub const IN_VOCAB: usize = 10;
/// Output classes: blank + digits 1..=8.
pub const OUT_CLASSES: usize = 9;

pub static FAMILY: FamilyDef = FamilyDef {
    name: "rnn_copy",
    ops: &["rnn_copy_step", "rnn_copy_grad", "rnn_copy_apply", "rnn_copy_eval"],
    resolve,
    validate,
    run,
};

fn resolve(op: &str, spec: &ArtifactSpec) -> Option<Result<NativeOp>> {
    let mode = match op {
        "rnn_copy_step" => StepMode::Step,
        "rnn_copy_grad" => StepMode::Grad,
        "rnn_copy_apply" => StepMode::Apply,
        "rnn_copy_eval" => StepMode::Eval,
        _ => return None,
    };
    let kind = match spec.meta_str(PARAM_META_KEY) {
        Some(p) => match CellKind::parse_param(p) {
            Some(k) => k,
            None => {
                return Some(Err(anyhow::anyhow!(
                    "bad '{PARAM_META_KEY}' meta '{p}' (expected cwy|hr|tcwy)"
                )))
            }
        },
        None => {
            return Some(Err(anyhow::anyhow!(
                "op '{op}' needs a '{PARAM_META_KEY}' meta key (cwy|hr|tcwy)"
            )))
        }
    };
    Some(Ok(NativeOp::RnnCopy(kind, mode)))
}

/// Validate the (V, W_in, W_out, b) parameter block starting at input
/// `off`; returns the reflection shape (l, n).
fn validate_params(spec: &ArtifactSpec, kind: CellKind, off: usize) -> Result<(usize, usize)> {
    let (l, n) = dims2(&spec.inputs[off])?;
    if kind == CellKind::Tcwy && l != n {
        bail!(
            "rnn_copy with param=tcwy needs square V (the recurrence lives \
             on St(N,N) = O(N)), got {:?}",
            spec.inputs[off].shape
        );
    }
    expect_shape(&spec.inputs[off + 1], &[IN_VOCAB, n])?;
    expect_shape(&spec.inputs[off + 2], &[n, OUT_CLASSES])?;
    expect_shape(&spec.inputs[off + 3], &[1, OUT_CLASSES])?;
    for ts in &spec.inputs[off..off + 4] {
        expect_dtype(ts, Dtype::F32)?;
    }
    Ok((l, n))
}

/// Validate the (tokens, targets) data block starting at input `off`.
fn validate_data(spec: &ArtifactSpec, off: usize) -> Result<()> {
    let (b, t) = dims2(&spec.inputs[off])?;
    if b == 0 || t == 0 {
        bail!("tokens shape {:?} has an empty axis", spec.inputs[off].shape);
    }
    expect_shape(&spec.inputs[off + 1], &[b, t])?;
    expect_dtype(&spec.inputs[off], Dtype::I32)?;
    expect_dtype(&spec.inputs[off + 1], Dtype::I32)?;
    Ok(())
}

fn param_shapes(l: usize, n: usize) -> [Vec<usize>; 4] {
    [
        vec![l, n],
        vec![IN_VOCAB, n],
        vec![n, OUT_CLASSES],
        vec![1, OUT_CLASSES],
    ]
}

fn validate(spec: &ArtifactSpec, op: NativeOp) -> Result<()> {
    let NativeOp::RnnCopy(kind, mode) = op else {
        bail!("op {op:?} is not in the rnn_copy family");
    };
    for ts in &spec.outputs {
        expect_dtype(ts, Dtype::F32)?;
    }
    match mode {
        StepMode::Step => {
            expect_arity(spec, 7, 6)?;
            expect_roles(
                spec,
                &[
                    Role::State,
                    Role::State,
                    Role::State,
                    Role::State,
                    Role::Data,
                    Role::Data,
                    Role::Hyper,
                ],
            )?;
            let (l, n) = validate_params(spec, kind, 0)?;
            validate_data(spec, 4)?;
            expect_shape(&spec.inputs[6], &[])?;
            expect_dtype(&spec.inputs[6], Dtype::F32)?;
            for (ts, want) in spec.outputs[..4].iter().zip(param_shapes(l, n)) {
                expect_shape(ts, &want)?;
            }
            expect_shape(&spec.outputs[4], &[])?;
            expect_shape(&spec.outputs[5], &[])
        }
        StepMode::Grad => {
            expect_arity(spec, 6, 6)?;
            expect_roles(
                spec,
                &[Role::State, Role::State, Role::State, Role::State, Role::Data, Role::Data],
            )?;
            let (l, n) = validate_params(spec, kind, 0)?;
            validate_data(spec, 4)?;
            for (ts, want) in spec.outputs[..4].iter().zip(param_shapes(l, n)) {
                expect_shape(ts, &want)?;
            }
            expect_shape(&spec.outputs[4], &[])?;
            expect_shape(&spec.outputs[5], &[])
        }
        StepMode::Apply => {
            expect_arity(spec, 9, 4)?;
            expect_roles(
                spec,
                &[
                    Role::State,
                    Role::State,
                    Role::State,
                    Role::State,
                    Role::Data,
                    Role::Data,
                    Role::Data,
                    Role::Data,
                    Role::Hyper,
                ],
            )?;
            let (l, n) = validate_params(spec, kind, 0)?;
            let shapes = param_shapes(l, n);
            for (ts, want) in spec.inputs[4..8].iter().zip(&shapes) {
                expect_shape(ts, want)?;
                expect_dtype(ts, Dtype::F32)?;
            }
            expect_shape(&spec.inputs[8], &[])?;
            expect_dtype(&spec.inputs[8], Dtype::F32)?;
            for (ts, want) in spec.outputs.iter().zip(&shapes) {
                expect_shape(ts, want)?;
            }
            Ok(())
        }
        StepMode::Eval => {
            expect_arity(spec, 6, 1)?;
            // Pure function of (params..., data...): everything is data.
            expect_roles(spec, &[Role::Data; 6])?;
            validate_params(spec, kind, 0)?;
            validate_data(spec, 4)?;
            expect_shape(&spec.outputs[0], &[])
        }
    }
}

/// The four trainable tensors of the copy-task RNN.
pub struct CopyRnnParams {
    /// Reflection rows: (L, N), square (N, N) for `tcwy`.
    pub v: Matrix,
    /// Token embedding, (IN_VOCAB, N).
    pub w_in: Matrix,
    /// Readout, (N, OUT_CLASSES).
    pub w_out: Matrix,
    /// Readout bias, (1, OUT_CLASSES).
    pub b_out: Matrix,
}

impl CopyRnnParams {
    /// In-place SGD update `p -= lr * g` over the whole block — the
    /// allocation-free training apply (bitwise-identical to the
    /// `p.sub(&g.scale(lr))` it replaces).
    pub fn sgd_step(&mut self, grads: &CopyRnnGrads, lr: f32) {
        let _span = crate::span!(sgd_step);
        self.v.axpy(-lr, &grads.v);
        self.w_in.axpy(-lr, &grads.w_in);
        self.w_out.axpy(-lr, &grads.w_out);
        self.b_out.axpy(-lr, &grads.b_out);
    }
}

/// Gradients with respect to the four parameter tensors.
pub struct CopyRnnGrads {
    pub v: Matrix,
    pub w_in: Matrix,
    pub w_out: Matrix,
    pub b_out: Matrix,
}

impl CopyRnnGrads {
    fn empty() -> CopyRnnGrads {
        CopyRnnGrads {
            v: Matrix::zeros(0, 0),
            w_in: Matrix::zeros(0, 0),
            w_out: Matrix::zeros(0, 0),
            b_out: Matrix::zeros(0, 0),
        }
    }

    /// Euclidean norm over the whole parameter block — the per-step
    /// descent diagnostic surfaced in `metrics::History`.
    pub fn global_norm(&self) -> f32 {
        [&self.v, &self.w_in, &self.w_out, &self.b_out]
            .iter()
            .map(|m| m.data.iter().map(|x| x * x).sum::<f32>())
            .sum::<f32>()
            .sqrt()
    }
}

/// One copy-task batch viewed by the RNN: row-major `(batch, t_total)`
/// token and target grids.
pub struct CopyBatchRef<'a> {
    pub tokens: &'a [i32],
    pub targets: &'a [i32],
    pub batch: usize,
    pub t_total: usize,
}

/// Every buffer the rollout forward + BPTT touches, preallocated and
/// reused across training steps (DESIGN.md §3.3): the hidden-state ring
/// `hs[0..=T]`, per-step logit-gradient scratch, the running BPTT
/// gradient `g`, the parametrization tape (CWY or T-CWY, rebuilt in
/// place per step), the materialized Ω for the tcwy recurrence, the
/// output gradients, and the shared gemm scratch pool.  After one warmup
/// step at the workload's shapes, [`forward_backward_ws`] allocates
/// nothing.
pub struct RolloutWorkspace {
    ws: Workspace,
    hs: Vec<Matrix>,
    dlogits: Vec<Matrix>,
    logits: Matrix,
    g: Matrix,
    grads: CopyRnnGrads,
    cwy: Option<CwyGrad>,
    tcwy: Option<TcwyGrad>,
    omega: Matrix,
    domega: Matrix,
}

impl Default for RolloutWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

impl RolloutWorkspace {
    pub fn new() -> RolloutWorkspace {
        RolloutWorkspace {
            ws: Workspace::new(),
            hs: Vec::new(),
            dlogits: Vec::new(),
            logits: Matrix::zeros(0, 0),
            g: Matrix::zeros(0, 0),
            grads: CopyRnnGrads::empty(),
            cwy: None,
            tcwy: None,
            omega: Matrix::zeros(0, 0),
            domega: Matrix::zeros(0, 0),
        }
    }

    /// The gradients computed by the last `want_grads` call to
    /// [`forward_backward_ws`].
    pub fn grads(&self) -> &CopyRnnGrads {
        &self.grads
    }

    /// Move the gradients out (the allocating-API wrapper uses this).
    fn take_grads(&mut self) -> CopyRnnGrads {
        std::mem::replace(&mut self.grads, CopyRnnGrads::empty())
    }
}

/// Forward pass (and optionally exact BPTT) of the copy-task RNN over a
/// reused [`RolloutWorkspace`].  Returns the mean CE loss; when
/// `want_grads`, the parameter gradients are left in `rws.grads()`.
/// Zero heap allocations at steady state; bitwise-identical to the
/// allocating [`forward_backward`] wrapper.
pub fn forward_backward_ws(
    kind: CellKind,
    params: &CopyRnnParams,
    data: &CopyBatchRef,
    want_grads: bool,
    rws: &mut RolloutWorkspace,
) -> Result<f32> {
    let CopyRnnParams { v, w_in, w_out, b_out } = params;
    let (batch, t_total) = (data.batch, data.t_total);
    let n = v.cols;
    let denom = (batch * t_total) as f32;

    // Phase telemetry: tape rebuild + rollout under `rollout_forward`,
    // the BPTT sweep under `bptt_backward` — the split the trainer's
    // per-step `phase_ns` columns and `--trace` timelines report.
    let forward_span = crate::span!(rollout_forward);

    // ---- rebuild the transition operands in place for this step's V
    match kind {
        CellKind::Cwy => match &mut rws.cwy {
            Some(grad) => grad.recompute(v, &mut rws.ws),
            None => rws.cwy = Some(CwyGrad::new(v)),
        },
        CellKind::Hr => {}
        CellKind::Tcwy => {
            match &mut rws.tcwy {
                Some(grad) => grad.recompute(v, &mut rws.ws),
                None => rws.tcwy = Some(TcwyGrad::new(v)),
            }
            rws.omega.resize_zeroed(n, v.rows);
            rws.tcwy.as_ref().unwrap().omega_into(&mut rws.omega);
            rws.domega.resize_zeroed(n, v.rows);
        }
    }

    // ---- shape the rollout buffers.  Only h_0 needs zeroing: every
    // element of hs[1..=T], logits, and dlogits is overwritten before it
    // is read (beta = 0 gemm / copy_from / full per-row CE write), so
    // those skip the per-step memset entirely.
    if rws.hs.len() < t_total + 1 {
        rws.hs.resize_with(t_total + 1, || Matrix::zeros(0, 0));
    }
    rws.hs[0].resize_zeroed(batch, n);
    for h in rws.hs.iter_mut().take(t_total + 1).skip(1) {
        h.resize_for_overwrite(batch, n);
    }
    rws.logits.resize_for_overwrite(batch, OUT_CLASSES);
    if want_grads {
        if rws.dlogits.len() < t_total {
            rws.dlogits.resize_with(t_total, || Matrix::zeros(0, 0));
        }
        for d in rws.dlogits.iter_mut().take(t_total) {
            d.resize_for_overwrite(batch, OUT_CLASSES);
        }
    }

    // ---- forward, storing hidden states and per-step logit gradients
    let mut loss_sum = 0.0f32;
    for t in 0..t_total {
        let (left, right) = rws.hs.split_at_mut(t + 1);
        let h_prev = &left[t];
        let h_next = &mut right[0];
        match kind {
            CellKind::Cwy => {
                rws.cwy
                    .as_ref()
                    .expect("cwy tape built above")
                    .apply_forward_into(h_prev, h_next, &mut rws.ws);
            }
            CellKind::Hr => {
                h_next.copy_from(h_prev);
                householder::apply_chain(v, h_next);
            }
            CellKind::Tcwy => {
                gemm(false, false, 1.0, h_prev, &rws.omega, 0.0, h_next);
            }
        }
        // h_{t+1} += W_in[token_t], row-wise (the embedding add).
        for b in 0..batch {
            let tok = data.tokens[b * t_total + t];
            if tok < 0 || tok as usize >= IN_VOCAB {
                bail!("token {tok} at (row {b}, t {t}) outside 0..{IN_VOCAB}");
            }
            for (hv, wv) in h_next.row_mut(b).iter_mut().zip(w_in.row(tok as usize)) {
                *hv += wv;
            }
        }
        gemm(false, false, 1.0, h_next, w_out, 0.0, &mut rws.logits);
        for b in 0..batch {
            let tgt = data.targets[b * t_total + t];
            if tgt < 0 || tgt as usize >= OUT_CLASSES {
                bail!("target {tgt} at (row {b}, t {t}) outside 0..{OUT_CLASSES}");
            }
            // Stable softmax cross-entropy on logits + b_out.
            let bias = b_out.row(0);
            let mut mx = f32::NEG_INFINITY;
            for (lc, bc) in rws.logits.row(b).iter().zip(bias) {
                mx = mx.max(lc + bc);
            }
            let mut e = [0.0f32; OUT_CLASSES];
            let mut z = 0.0f32;
            for ((ec, lc), bc) in e.iter_mut().zip(rws.logits.row(b)).zip(bias) {
                *ec = (lc + bc - mx).exp();
                z += *ec;
            }
            loss_sum -= (e[tgt as usize] / z).max(1e-30).ln();
            if want_grads {
                let dl = &mut rws.dlogits[t];
                for (c, &ec) in e.iter().enumerate() {
                    let hit = if c == tgt as usize { 1.0 } else { 0.0 };
                    dl[(b, c)] = (ec / z - hit) / denom;
                }
            }
        }
    }
    let loss = loss_sum / denom;
    drop(forward_span);
    if !want_grads {
        return Ok(loss);
    }
    let _backward_span = crate::span!(bptt_backward);

    // ---- backward (BPTT), every accumulation a fused beta = 1 gemm
    rws.grads.v.resize_zeroed(v.rows, v.cols);
    rws.grads.w_in.resize_zeroed(IN_VOCAB, n);
    rws.grads.w_out.resize_zeroed(n, OUT_CLASSES);
    rws.grads.b_out.resize_zeroed(1, OUT_CLASSES);
    rws.g.resize_zeroed(batch, n);
    for t in (0..t_total).rev() {
        let dl = &rws.dlogits[t];
        // d_wout += hs[t+1]ᵀ dl — the call the issue names: one fused
        // TN gemm, zero temporaries.
        gemm(true, false, 1.0, &rws.hs[t + 1], dl, 1.0, &mut rws.grads.w_out);
        for b in 0..batch {
            for c in 0..OUT_CLASSES {
                rws.grads.b_out[(0, c)] += dl[(b, c)];
            }
        }
        // g += dl @ W_outᵀ (NT path, fused accumulate).
        gemm(false, true, 1.0, dl, w_out, 1.0, &mut rws.g);
        // h_{t+1} = (h_t Q) + x_t: dx_t = g lands on the token's row of
        // W_in; the transition backward yields dL/dh_t.
        for b in 0..batch {
            let tok = data.tokens[b * t_total + t] as usize;
            for (dw, gv) in rws.grads.w_in.row_mut(tok).iter_mut().zip(rws.g.row(b)) {
                *dw += gv;
            }
        }
        match kind {
            CellKind::Cwy => {
                rws.cwy
                    .as_mut()
                    .expect("cwy tape built above")
                    .apply_backward_in_place(&rws.hs[t], &mut rws.g, &mut rws.ws);
            }
            CellKind::Hr => {
                let (dh, dvs) = hr_chain_backward(v, &rws.hs[t], &rws.g);
                rws.g.copy_from(&dh);
                rws.grads.v.add_assign(&dvs);
            }
            CellKind::Tcwy => {
                gemm(true, false, 1.0, &rws.hs[t], &rws.g, 1.0, &mut rws.domega);
                let mut gnext = rws.ws.take(batch, n);
                gemm(false, true, 1.0, &rws.g, &rws.omega, 0.0, &mut gnext);
                rws.g.copy_from(&gnext);
                rws.ws.give(gnext);
            }
        }
    }
    match kind {
        CellKind::Cwy => {
            let grad = rws.cwy.as_mut().expect("cwy tape built above");
            grad.finish_into(v, &mut rws.grads.v, &mut rws.ws);
        }
        CellKind::Hr => {}
        CellKind::Tcwy => {
            let grad = rws.tcwy.as_mut().expect("tcwy tape built above");
            grad.matrix_backward_ws(&rws.domega, &mut rws.ws);
            grad.finish_into(v, &mut rws.grads.v, &mut rws.ws);
        }
    }
    Ok(loss)
}

/// Forward pass (and optionally exact BPTT) of the copy-task RNN —
/// allocating wrapper over [`forward_backward_ws`] with a throwaway
/// workspace, kept for tests and one-shot callers.
pub fn forward_backward(
    kind: CellKind,
    params: &CopyRnnParams,
    data: &CopyBatchRef,
    want_grads: bool,
) -> Result<(f32, Option<CopyRnnGrads>)> {
    let mut rws = RolloutWorkspace::new();
    let loss = forward_backward_ws(kind, params, data, want_grads, &mut rws)?;
    let grads = if want_grads { Some(rws.take_grads()) } else { None };
    Ok((loss, grads))
}

struct Inputs {
    params: CopyRnnParams,
    tokens: Vec<i32>,
    targets: Vec<i32>,
    batch: usize,
    t_total: usize,
}

impl Inputs {
    fn data(&self) -> CopyBatchRef<'_> {
        CopyBatchRef {
            tokens: &self.tokens,
            targets: &self.targets,
            batch: self.batch,
            t_total: self.t_total,
        }
    }
}

fn unpack(inputs: &[&HostTensor]) -> Result<Inputs> {
    Ok(Inputs {
        params: CopyRnnParams {
            v: mat(inputs[0])?,
            w_in: mat(inputs[1])?,
            w_out: mat(inputs[2])?,
            b_out: mat(inputs[3])?,
        },
        tokens: inputs[4].as_i32()?.to_vec(),
        targets: inputs[5].as_i32()?.to_vec(),
        batch: inputs[4].shape[0],
        t_total: inputs[4].shape[1],
    })
}

thread_local! {
    /// Per-thread rollout workspace: the trainer loop and each serve
    /// worker reuse their own buffers across `run` calls, so repeated
    /// steps at fixed shapes stop allocating inside the rollout.
    static RWS: RefCell<RolloutWorkspace> = RefCell::new(RolloutWorkspace::new());
}

fn run(_spec: &ArtifactSpec, op: NativeOp, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    let NativeOp::RnnCopy(kind, mode) = op else {
        bail!("op {op:?} is not in the rnn_copy family");
    };
    match mode {
        StepMode::Step | StepMode::Grad => {
            let inp = unpack(inputs)?;
            RWS.with(|cell| {
                let rws = &mut *cell.borrow_mut();
                let loss = forward_backward_ws(kind, &inp.params, &inp.data(), true, rws)?;
                let grads = rws.grads();
                let gnorm = grads.global_norm();
                let out_params: [Matrix; 4] = match mode {
                    StepMode::Grad => [
                        grads.v.clone(),
                        grads.w_in.clone(),
                        grads.w_out.clone(),
                        grads.b_out.clone(),
                    ],
                    _ => {
                        let lr = inputs[6].scalar()?;
                        let mut p = CopyRnnParams {
                            v: inp.params.v.clone(),
                            w_in: inp.params.w_in.clone(),
                            w_out: inp.params.w_out.clone(),
                            b_out: inp.params.b_out.clone(),
                        };
                        p.sgd_step(grads, lr);
                        [p.v, p.w_in, p.w_out, p.b_out]
                    }
                };
                let mut out: Vec<HostTensor> = out_params.into_iter().map(tensor).collect();
                out.push(HostTensor::scalar_f32(loss));
                out.push(HostTensor::scalar_f32(gnorm));
                Ok(out)
            })
        }
        StepMode::Apply => {
            let lr = inputs[8].scalar()?;
            (0..4)
                .map(|i| {
                    let mut p = mat(inputs[i])?;
                    let g = mat(inputs[4 + i])?;
                    p.axpy(-lr, &g);
                    Ok(tensor(p))
                })
                .collect()
        }
        StepMode::Eval => {
            let inp = unpack(inputs)?;
            let loss = RWS.with(|cell| {
                forward_backward_ws(kind, &inp.params, &inp.data(), false, &mut cell.borrow_mut())
            })?;
            Ok(vec![HostTensor::scalar_f32(loss)])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orthogonal::backward::finite_diff;
    use crate::util::rng::Pcg32;

    struct Tiny {
        params: CopyRnnParams,
        tokens: Vec<i32>,
        targets: Vec<i32>,
        batch: usize,
        t_total: usize,
    }

    impl Tiny {
        fn data(&self) -> CopyBatchRef<'_> {
            CopyBatchRef {
                tokens: &self.tokens,
                targets: &self.targets,
                batch: self.batch,
                t_total: self.t_total,
            }
        }
    }

    fn tiny_setup(seed: u64, l: usize, n: usize, b: usize, t: usize) -> Tiny {
        let mut rng = Pcg32::seeded(seed);
        let params = CopyRnnParams {
            v: Matrix::random_normal(&mut rng, l, n, 1.0),
            w_in: Matrix::random_normal(&mut rng, IN_VOCAB, n, 0.3),
            w_out: Matrix::random_normal(&mut rng, n, OUT_CLASSES, 0.3),
            b_out: Matrix::random_normal(&mut rng, 1, OUT_CLASSES, 0.1),
        };
        let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(IN_VOCAB as u32) as i32).collect();
        let targets: Vec<i32> = (0..b * t).map(|_| rng.below(OUT_CLASSES as u32) as i32).collect();
        Tiny { params, tokens, targets, batch: b, t_total: t }
    }

    /// Exact-BPTT check: every parameter gradient matches central finite
    /// differences of the f32 forward loss (tolerance-scaled for f32),
    /// for all three parametrizations.
    #[test]
    fn gradients_match_finite_differences() {
        for kind in [CellKind::Cwy, CellKind::Hr, CellKind::Tcwy] {
            let (l, n, b, t) = match kind {
                CellKind::Tcwy => (6, 6, 2, 5),
                _ => (3, 6, 2, 5),
            };
            let tiny = tiny_setup(9, l, n, b, t);
            let p = &tiny.params;
            let loss_of = |params: &CopyRnnParams| {
                forward_backward(kind, params, &tiny.data(), false).unwrap().0
            };
            let (_, grads) = forward_backward(kind, p, &tiny.data(), true).unwrap();
            let grads = grads.unwrap();
            let with = |v: Matrix, w_in: Matrix, w_out: Matrix, b_out: Matrix| {
                CopyRnnParams { v, w_in, w_out, b_out }
            };
            // The loss is O(ln 9) and the FD quotient divides f32 noise by
            // 2*eps, so compare with a scaled tolerance.
            let eps = 3e-3;
            let tol = 3e-3;
            let fd_v = finite_diff(&p.v, eps, |x| {
                loss_of(&with(x.clone(), p.w_in.clone(), p.w_out.clone(), p.b_out.clone()))
            });
            let fd_win = finite_diff(&p.w_in, eps, |x| {
                loss_of(&with(p.v.clone(), x.clone(), p.w_out.clone(), p.b_out.clone()))
            });
            let fd_wout = finite_diff(&p.w_out, eps, |x| {
                loss_of(&with(p.v.clone(), p.w_in.clone(), x.clone(), p.b_out.clone()))
            });
            let fd_b = finite_diff(&p.b_out, eps, |x| {
                loss_of(&with(p.v.clone(), p.w_in.clone(), p.w_out.clone(), x.clone()))
            });
            let cases: [(&str, &Matrix, Matrix); 4] = [
                ("v", &grads.v, fd_v),
                ("w_in", &grads.w_in, fd_win),
                ("w_out", &grads.w_out, fd_wout),
                ("b_out", &grads.b_out, fd_b),
            ];
            for (name, analytic, numeric) in cases {
                let scale = numeric.data.iter().fold(1.0f32, |m, x| m.max(x.abs()));
                let err = analytic.max_abs_diff(&numeric) / scale;
                assert!(err < tol, "{kind:?} d{name}: scaled FD error {err}");
            }
        }
    }

    /// The zero-allocation path is also the *same-answer* path: a reused
    /// workspace must reproduce a fresh one bit-for-bit, step after step,
    /// for every parametrization — including B = 1 / L = 1 edge shapes.
    #[test]
    fn reused_workspace_bitwise_matches_fresh() {
        let bits = |m: &Matrix| m.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        for kind in [CellKind::Cwy, CellKind::Hr, CellKind::Tcwy] {
            let shapes: &[(usize, usize, usize, usize)] = match kind {
                CellKind::Tcwy => &[(5, 5, 3, 6), (4, 4, 1, 1)],
                _ => &[(3, 7, 2, 6), (1, 5, 1, 1)],
            };
            let mut rws = RolloutWorkspace::new();
            for (step, &(l, n, b, t)) in shapes.iter().enumerate() {
                let tiny = tiny_setup(100 + step as u64, l, n, b, t);
                let loss_ws =
                    forward_backward_ws(kind, &tiny.params, &tiny.data(), true, &mut rws)
                        .unwrap();
                let (loss_fresh, grads_fresh) =
                    forward_backward(kind, &tiny.params, &tiny.data(), true).unwrap();
                let gf = grads_fresh.unwrap();
                assert_eq!(
                    loss_ws.to_bits(),
                    loss_fresh.to_bits(),
                    "{kind:?} step {step}: loss drifted"
                );
                let gw = rws.grads();
                for (name, a, b) in [
                    ("v", &gw.v, &gf.v),
                    ("w_in", &gw.w_in, &gf.w_in),
                    ("w_out", &gw.w_out, &gf.w_out),
                    ("b_out", &gw.b_out, &gf.b_out),
                ] {
                    assert_eq!(bits(a), bits(b), "{kind:?} step {step}: d{name} drifted");
                }
            }
        }
    }

    /// cwy and hr parametrize the same function, so their BPTT gradients
    /// agree elementwise (acceptance bound 1e-4) on the same rollout.
    #[test]
    fn cwy_and_hr_grads_agree_elementwise() {
        let tiny = tiny_setup(21, 4, 12, 3, 8);
        let run = |kind| forward_backward(kind, &tiny.params, &tiny.data(), true).unwrap();
        let (loss_c, gc) = run(CellKind::Cwy);
        let (loss_h, gh) = run(CellKind::Hr);
        let (gc, gh) = (gc.unwrap(), gh.unwrap());
        assert!((loss_c - loss_h).abs() <= 1e-5, "loss {loss_c} vs {loss_h}");
        assert!(gc.v.max_abs_diff(&gh.v) <= 1e-4);
        assert!(gc.w_in.max_abs_diff(&gh.w_in) <= 1e-4);
        assert!(gc.w_out.max_abs_diff(&gh.w_out) <= 1e-4);
        assert!(gc.b_out.max_abs_diff(&gh.b_out) <= 1e-4);
    }

    /// A few fused steps on a fixed batch drive the loss down — the
    /// smallest possible descent smoke for the family itself (the full
    /// below-baseline run lives in the trainer integration suite).  Runs
    /// through the workspace + in-place SGD path the trainer hot loop uses.
    #[test]
    fn repeated_steps_descend_on_fixed_batch() {
        let mut tiny = tiny_setup(5, 4, 16, 4, 10);
        let mut rws = RolloutWorkspace::new();
        let mut losses = Vec::new();
        for _ in 0..30 {
            let data = CopyBatchRef {
                tokens: &tiny.tokens,
                targets: &tiny.targets,
                batch: tiny.batch,
                t_total: tiny.t_total,
            };
            let loss =
                forward_backward_ws(CellKind::Cwy, &tiny.params, &data, true, &mut rws).unwrap();
            losses.push(loss);
            tiny.params.sgd_step(rws.grads(), 0.5);
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.5),
            "no descent: {} -> {}",
            losses[0],
            losses.last().unwrap()
        );
        assert!(losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn rejects_out_of_range_tokens() {
        let mut tiny = tiny_setup(3, 2, 4, 1, 3);
        tiny.tokens[1] = 12;
        let err = forward_backward(CellKind::Cwy, &tiny.params, &tiny.data(), false).unwrap_err();
        assert!(format!("{err:#}").contains("outside"), "{err:#}");
    }
}
