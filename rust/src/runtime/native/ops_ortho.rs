//! Native op family `ortho`: the paper's forward constructions — CWY and
//! Householder orthogonal matrices (Thm 2), the T-CWY Stiefel frame
//! (Thm 3), fused rollouts, and the frozen-parameter recurrent `cell_*`
//! step artifacts the serve subsystem drives (DESIGN.md §6.2).
//!
//! | `meta.op`      | kind  | signature (roles)                              | computation |
//! |----------------|-------|------------------------------------------------|-------------|
//! | `cwy`          | micro | V `[l,n]` → Q `[n,n]`                          | Thm 2: `I - U S^-1 U^T` |
//! | `hr`           | micro | V `[l,n]` → Q `[n,n]`                          | sequential Householder product |
//! | `tcwy`         | micro | V `[m,n]` → Ω `[n,m]`                          | Thm 3 Stiefel frame |
//! | `rollout_cwy`  | micro | V `[l,n]`, H `[b,n]` → `[b,n]`                 | fused `H @ Q` |
//! | `rollout_hr`   | micro | V `[l,n]`, H `[b,n]` → `[b,n]`                 | sequential reflection chain |
//! | `cell_cwy`     | step  | V `[l,n]` state, h `[b,n]` state, x `[b,n]` data, lr hyper → V', h', y | `h' = h Q(V) + x`, `y = h'` |
//! | `cell_hr`      | step  | same as `cell_cwy`                             | same recurrence, HR chain |
//! | `cell_tcwy`    | step  | V `[m,n]` state, h `[b,m]` state, x `[b,n]` data, lr hyper → V', h', y | `h' = h + x Ω(V)`, `y = h'` |
//!
//! The recurrent cells treat V as frozen parameters (`V' = V`): serving
//! runs step artifacts with `lr = 0` by convention (DESIGN.md §6.2).  The
//! *trainable* recurrent family is `rnn_copy_*` ([`super::ops_rnn`]).

use std::cell::RefCell;

use anyhow::{bail, Result};

use super::helpers::{dims2, expect_all_f32, expect_arity, expect_roles, expect_shape, mat, tensor};
use super::{CellKind, FamilyDef, NativeOp};
use crate::linalg::{gemm, Matrix, Workspace};
use crate::orthogonal::{cwy, householder, tcwy};
use crate::runtime::manifest::{ArtifactSpec, Role};
use crate::runtime::tensor::HostTensor;

thread_local! {
    /// Per-thread gemm scratch for the fused apply paths: each serve
    /// worker reuses its own pool across requests instead of allocating
    /// operator temporaries per call (DESIGN.md §3.3).
    static WS: RefCell<Workspace> = RefCell::new(Workspace::new());
}

pub static FAMILY: FamilyDef = FamilyDef {
    name: "ortho",
    ops: &[
        "cwy",
        "hr",
        "tcwy",
        "rollout_cwy",
        "rollout_hr",
        "cell_cwy",
        "cell_hr",
        "cell_tcwy",
    ],
    resolve,
    validate,
    run,
};

fn resolve(op: &str, _spec: &ArtifactSpec) -> Option<Result<NativeOp>> {
    Some(Ok(match op {
        "cwy" => NativeOp::CwyMatrix,
        "hr" => NativeOp::HrMatrix,
        "tcwy" => NativeOp::TcwyMatrix,
        "rollout_cwy" => NativeOp::RolloutCwy,
        "rollout_hr" => NativeOp::RolloutHr,
        "cell_cwy" => NativeOp::Cell(CellKind::Cwy),
        "cell_hr" => NativeOp::Cell(CellKind::Hr),
        "cell_tcwy" => NativeOp::Cell(CellKind::Tcwy),
        _ => return None,
    }))
}

/// Check the manifest signature against the op contract (shapes must be
/// mutually consistent; the actual numbers are the manifest's choice).
fn validate(spec: &ArtifactSpec, op: NativeOp) -> Result<()> {
    expect_all_f32(spec)?;
    match op {
        NativeOp::CwyMatrix | NativeOp::HrMatrix => {
            expect_arity(spec, 1, 1)?;
            let (_, n) = dims2(&spec.inputs[0])?;
            expect_shape(&spec.outputs[0], &[n, n])
        }
        NativeOp::TcwyMatrix => {
            expect_arity(spec, 1, 1)?;
            let (m, n) = dims2(&spec.inputs[0])?;
            if m > n {
                bail!("T-CWY needs M <= N, got V {:?}", spec.inputs[0].shape);
            }
            expect_shape(&spec.outputs[0], &[n, m])
        }
        NativeOp::RolloutCwy | NativeOp::RolloutHr => {
            expect_arity(spec, 2, 1)?;
            let (_, n) = dims2(&spec.inputs[0])?;
            let (b, n2) = dims2(&spec.inputs[1])?;
            if n2 != n {
                bail!("V cols {n} != H cols {n2}");
            }
            expect_shape(&spec.outputs[0], &[b, n])
        }
        NativeOp::Cell(kind) => {
            expect_arity(spec, 4, 3)?;
            expect_roles(spec, &[Role::State, Role::State, Role::Data, Role::Hyper])?;
            let (l, n) = dims2(&spec.inputs[0])?;
            let (b, hn) = dims2(&spec.inputs[1])?;
            let (bx, xn) = dims2(&spec.inputs[2])?;
            if bx != b {
                bail!("h rows {b} != x rows {bx}");
            }
            let h_cols = match kind {
                CellKind::Cwy | CellKind::Hr => n,
                CellKind::Tcwy => {
                    if l > n {
                        bail!("T-CWY cell needs M <= N, got V {:?}", spec.inputs[0].shape);
                    }
                    l
                }
            };
            if hn != h_cols {
                bail!("h cols {hn}, cell expects {h_cols}");
            }
            if xn != n {
                bail!("x cols {xn}, cell expects {n}");
            }
            expect_shape(&spec.outputs[0], &[l, n])?;
            expect_shape(&spec.outputs[1], &[b, hn])?;
            expect_shape(&spec.outputs[2], &[b, hn])
        }
        other => bail!("op {other:?} is not in the ortho family"),
    }
}

fn run(_spec: &ArtifactSpec, op: NativeOp, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    match op {
        NativeOp::CwyMatrix => {
            let v = mat(inputs[0])?;
            Ok(vec![tensor(cwy::matrix(&v))])
        }
        NativeOp::HrMatrix => {
            let v = mat(inputs[0])?;
            Ok(vec![tensor(householder::matrix(&v))])
        }
        NativeOp::TcwyMatrix => {
            let v = mat(inputs[0])?;
            Ok(vec![tensor(tcwy::matrix(&v))])
        }
        NativeOp::RolloutCwy => {
            let v = mat(inputs[0])?;
            let h = mat(inputs[1])?;
            let mut out = Matrix::zeros(h.rows, h.cols);
            WS.with(|ws| {
                cwy::CwyOperator::new(&v).apply_into(&h, &mut out, &mut ws.borrow_mut())
            });
            Ok(vec![tensor(out)])
        }
        NativeOp::RolloutHr => {
            let v = mat(inputs[0])?;
            let mut h = mat(inputs[1])?;
            householder::apply_chain(&v, &mut h);
            Ok(vec![tensor(h)])
        }
        NativeOp::Cell(kind) => {
            let v = mat(inputs[0])?;
            let h = mat(inputs[1])?;
            let x = mat(inputs[2])?;
            let h_next = match kind {
                CellKind::Cwy => {
                    let mut out = Matrix::zeros(h.rows, h.cols);
                    WS.with(|ws| {
                        cwy::CwyOperator::new(&v).apply_into(&h, &mut out, &mut ws.borrow_mut())
                    });
                    out.add_assign(&x);
                    out
                }
                CellKind::Hr => {
                    let mut rotated = h;
                    householder::apply_chain(&v, &mut rotated);
                    rotated.add(&x)
                }
                CellKind::Tcwy => {
                    // h + x Ω(V): fused beta = 1 accumulate, no temporary.
                    let mut out = h;
                    gemm(false, false, 1.0, &x, &tcwy::matrix(&v), 1.0, &mut out);
                    out
                }
            };
            // V is frozen (see module docs); state outputs come first,
            // in state-input order, per the step convention (§2.2).
            Ok(vec![inputs[0].clone(), tensor(h_next.clone()), tensor(h_next)])
        }
        other => bail!("op {other:?} is not in the ortho family"),
    }
}
