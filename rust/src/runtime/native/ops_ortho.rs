//! Native op family `ortho`: the paper's forward constructions — CWY and
//! Householder orthogonal matrices (Thm 2), the T-CWY Stiefel frame
//! (Thm 3), fused rollouts, and the frozen-parameter recurrent `cell_*`
//! step artifacts the serve subsystem drives (DESIGN.md §6.2).
//!
//! | `meta.op`      | kind  | signature (roles)                              | computation |
//! |----------------|-------|------------------------------------------------|-------------|
//! | `cwy`          | micro | V `[l,n]` → Q `[n,n]`                          | Thm 2: `I - U S^-1 U^T` |
//! | `hr`           | micro | V `[l,n]` → Q `[n,n]`                          | sequential Householder product |
//! | `tcwy`         | micro | V `[m,n]` → Ω `[n,m]`                          | Thm 3 Stiefel frame |
//! | `rollout_cwy`  | micro | V `[l,n]`, H `[b,n]` → `[b,n]`                 | fused `H @ Q` |
//! | `rollout_hr`   | micro | V `[l,n]`, H `[b,n]` → `[b,n]`                 | sequential reflection chain |
//! | `cell_cwy`     | step  | V `[l,n]` state, h `[b,n]` state, x `[b,n]` data, lr hyper → V', h', y | `h' = h Q(V) + x`, `y = h'` |
//! | `cell_hr`      | step  | same as `cell_cwy`                             | same recurrence, HR chain |
//! | `cell_tcwy`    | step  | V `[m,n]` state, h `[b,m]` state, x `[b,n]` data, lr hyper → V', h', y | `h' = h + x Ω(V)`, `y = h'` |
//!
//! The recurrent cells treat V as frozen parameters (`V' = V`): serving
//! runs step artifacts with `lr = 0` by convention (DESIGN.md §6.2).  The
//! *trainable* recurrent family is `rnn_copy_*` ([`super::ops_rnn`]).

use std::cell::{Cell, RefCell};

use anyhow::{bail, Result};

use super::helpers::{dims2, expect_all_f32, expect_arity, expect_roles, expect_shape, mat, tensor};
use super::{CellKind, FamilyDef, NativeOp};
use crate::linalg::{gemm, Matrix, Workspace};
use crate::orthogonal::{cwy, householder, tcwy};
use crate::runtime::manifest::{ArtifactSpec, Role};
use crate::runtime::tensor::HostTensor;

thread_local! {
    /// Caller-installed [`OperatorCache`] (see [`with_operator_cache`]).
    /// Null when no scope is active; the `LOCAL` fallback serves then.
    static INSTALLED: Cell<*mut OperatorCache> = const { Cell::new(std::ptr::null_mut()) };

    /// Fallback cache for threads that never install one (tests, the CLI
    /// demo paths) — still amortizes repeated applies on one thread.
    static LOCAL: RefCell<OperatorCache> = RefCell::new(OperatorCache::new());
}

/// Cached CWY operator for the serve hot path (ISSUE 9).  A serve worker
/// runs the same artifact (same `V`) for every request of a batch and
/// across batches, yet `run` receives `V` as a fresh tensor copy each
/// call — so the cache keys by *value*: an FNV-1a hash of the bits as a
/// fast reject, then exact equality against the retained copy (hash
/// collisions must not alias distinct operators).  On a hit the
/// normalize / `S` build / `triu_inv` / panel packing all drop out.
pub struct OperatorCache {
    hash: u64,
    v: Matrix,
    op: Option<cwy::CwyOperator>,
    ws: Workspace,
}

impl Default for OperatorCache {
    fn default() -> Self {
        Self::new()
    }
}

impl OperatorCache {
    pub fn new() -> OperatorCache {
        OperatorCache {
            hash: 0,
            v: Matrix::zeros(0, 0),
            op: None,
            ws: Workspace::new(),
        }
    }

    fn hash_of(v: &Matrix) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |x: u64| h = (h ^ x).wrapping_mul(0x100000001b3);
        mix(v.rows as u64);
        mix(v.cols as u64);
        for x in &v.data {
            mix(x.to_bits() as u64);
        }
        h
    }

    /// `out = batch @ Q(v)`, rebuilding the cached operator only when `v`
    /// actually changed.  Returns `true` on a cache hit.
    pub fn apply(&mut self, v: &Matrix, batch: &Matrix, out: &mut Matrix) -> bool {
        let hash = Self::hash_of(v);
        let hit = self.op.is_some() && self.hash == hash && self.v == *v;
        if !hit {
            self.op = Some(cwy::CwyOperator::new(v));
            self.v = v.clone();
            self.hash = hash;
        }
        let op = self.op.as_ref().expect("operator was just ensured");
        op.apply_into(batch, out, &mut self.ws);
        hit
    }
}

/// Run `f` with `cache` installed as this thread's operator cache: every
/// CWY apply inside (the `rollout_cwy` / `cell_cwy` ops) consults it
/// instead of rebuilding the operator per call.  Serve workers wrap each
/// model execution so the cache lives in [`crate::serve::worker`]'s
/// per-worker scratch and survives across batches.  Scopes nest; the
/// previous installation is restored even on panic.
pub fn with_operator_cache<R>(cache: &mut OperatorCache, f: impl FnOnce() -> R) -> R {
    struct Restore(*mut OperatorCache);
    impl Drop for Restore {
        fn drop(&mut self) {
            INSTALLED.with(|c| c.set(self.0));
        }
    }
    let prev = INSTALLED.with(|c| c.replace(cache as *mut OperatorCache));
    let _restore = Restore(prev);
    f()
}

/// The installed-or-local cached apply used by the op bodies.
fn cached_cwy_apply(v: &Matrix, batch: &Matrix, out: &mut Matrix) {
    let installed = INSTALLED.with(|c| c.get());
    if !installed.is_null() {
        // SAFETY: the pointer was installed from an exclusive borrow by
        // `with_operator_cache`, is only visible to this thread, and the
        // scope guard clears it before that borrow ends.  Op bodies never
        // re-enter `run`, so the cache is not aliased re-entrantly.
        unsafe { (*installed).apply(v, batch, out) };
    } else {
        LOCAL.with(|c| c.borrow_mut().apply(v, batch, out));
    }
}

pub static FAMILY: FamilyDef = FamilyDef {
    name: "ortho",
    ops: &[
        "cwy",
        "hr",
        "tcwy",
        "rollout_cwy",
        "rollout_hr",
        "cell_cwy",
        "cell_hr",
        "cell_tcwy",
    ],
    resolve,
    validate,
    run,
};

fn resolve(op: &str, _spec: &ArtifactSpec) -> Option<Result<NativeOp>> {
    Some(Ok(match op {
        "cwy" => NativeOp::CwyMatrix,
        "hr" => NativeOp::HrMatrix,
        "tcwy" => NativeOp::TcwyMatrix,
        "rollout_cwy" => NativeOp::RolloutCwy,
        "rollout_hr" => NativeOp::RolloutHr,
        "cell_cwy" => NativeOp::Cell(CellKind::Cwy),
        "cell_hr" => NativeOp::Cell(CellKind::Hr),
        "cell_tcwy" => NativeOp::Cell(CellKind::Tcwy),
        _ => return None,
    }))
}

/// Check the manifest signature against the op contract (shapes must be
/// mutually consistent; the actual numbers are the manifest's choice).
fn validate(spec: &ArtifactSpec, op: NativeOp) -> Result<()> {
    expect_all_f32(spec)?;
    match op {
        NativeOp::CwyMatrix | NativeOp::HrMatrix => {
            expect_arity(spec, 1, 1)?;
            let (_, n) = dims2(&spec.inputs[0])?;
            expect_shape(&spec.outputs[0], &[n, n])
        }
        NativeOp::TcwyMatrix => {
            expect_arity(spec, 1, 1)?;
            let (m, n) = dims2(&spec.inputs[0])?;
            if m > n {
                bail!("T-CWY needs M <= N, got V {:?}", spec.inputs[0].shape);
            }
            expect_shape(&spec.outputs[0], &[n, m])
        }
        NativeOp::RolloutCwy | NativeOp::RolloutHr => {
            expect_arity(spec, 2, 1)?;
            let (_, n) = dims2(&spec.inputs[0])?;
            let (b, n2) = dims2(&spec.inputs[1])?;
            if n2 != n {
                bail!("V cols {n} != H cols {n2}");
            }
            expect_shape(&spec.outputs[0], &[b, n])
        }
        NativeOp::Cell(kind) => {
            expect_arity(spec, 4, 3)?;
            expect_roles(spec, &[Role::State, Role::State, Role::Data, Role::Hyper])?;
            let (l, n) = dims2(&spec.inputs[0])?;
            let (b, hn) = dims2(&spec.inputs[1])?;
            let (bx, xn) = dims2(&spec.inputs[2])?;
            if bx != b {
                bail!("h rows {b} != x rows {bx}");
            }
            let h_cols = match kind {
                CellKind::Cwy | CellKind::Hr => n,
                CellKind::Tcwy => {
                    if l > n {
                        bail!("T-CWY cell needs M <= N, got V {:?}", spec.inputs[0].shape);
                    }
                    l
                }
            };
            if hn != h_cols {
                bail!("h cols {hn}, cell expects {h_cols}");
            }
            if xn != n {
                bail!("x cols {xn}, cell expects {n}");
            }
            expect_shape(&spec.outputs[0], &[l, n])?;
            expect_shape(&spec.outputs[1], &[b, hn])?;
            expect_shape(&spec.outputs[2], &[b, hn])
        }
        other => bail!("op {other:?} is not in the ortho family"),
    }
}

fn run(_spec: &ArtifactSpec, op: NativeOp, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    match op {
        NativeOp::CwyMatrix => {
            let v = mat(inputs[0])?;
            Ok(vec![tensor(cwy::matrix(&v))])
        }
        NativeOp::HrMatrix => {
            let v = mat(inputs[0])?;
            Ok(vec![tensor(householder::matrix(&v))])
        }
        NativeOp::TcwyMatrix => {
            let v = mat(inputs[0])?;
            Ok(vec![tensor(tcwy::matrix(&v))])
        }
        NativeOp::RolloutCwy => {
            let v = mat(inputs[0])?;
            let h = mat(inputs[1])?;
            let mut out = Matrix::zeros(h.rows, h.cols);
            cached_cwy_apply(&v, &h, &mut out);
            Ok(vec![tensor(out)])
        }
        NativeOp::RolloutHr => {
            let v = mat(inputs[0])?;
            let mut h = mat(inputs[1])?;
            householder::apply_chain(&v, &mut h);
            Ok(vec![tensor(h)])
        }
        NativeOp::Cell(kind) => {
            let v = mat(inputs[0])?;
            let h = mat(inputs[1])?;
            let x = mat(inputs[2])?;
            let h_next = match kind {
                CellKind::Cwy => {
                    let mut out = Matrix::zeros(h.rows, h.cols);
                    cached_cwy_apply(&v, &h, &mut out);
                    out.add_assign(&x);
                    out
                }
                CellKind::Hr => {
                    let mut rotated = h;
                    householder::apply_chain(&v, &mut rotated);
                    rotated.add(&x)
                }
                CellKind::Tcwy => {
                    // h + x Ω(V): fused beta = 1 accumulate, no temporary.
                    let mut out = h;
                    gemm(false, false, 1.0, &x, &tcwy::matrix(&v), 1.0, &mut out);
                    out
                }
            };
            // V is frozen (see module docs); state outputs come first,
            // in state-input order, per the step convention (§2.2).
            Ok(vec![inputs[0].clone(), tensor(h_next.clone()), tensor(h_next)])
        }
        other => bail!("op {other:?} is not in the ortho family"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// The serve operator cache must key by value: same bits hit, any
    /// changed bit (or shape) rebuilds, and hits are bitwise-identical
    /// to a fresh operator.
    #[test]
    fn operator_cache_hits_by_value_and_stays_bitwise() {
        let mut rng = Pcg32::seeded(0x0C0A);
        let v = Matrix::random_normal(&mut rng, 6, 16, 1.0);
        let h = Matrix::random_normal(&mut rng, 4, 16, 1.0);
        let mut cache = OperatorCache::new();
        let mut first = Matrix::zeros(4, 16);
        assert!(!cache.apply(&v, &h, &mut first), "cold cache must miss");
        // A fresh copy of the same values must hit, with identical bits.
        let mut again = Matrix::zeros(4, 16);
        assert!(cache.apply(&v.clone(), &h, &mut again), "same value must hit");
        assert!(first.data.iter().zip(&again.data).all(|(a, b)| a.to_bits() == b.to_bits()));
        let reference = cwy::CwyOperator::new(&v).apply(&h);
        assert!(reference.data.iter().zip(&again.data).all(|(a, b)| a.to_bits() == b.to_bits()));
        // One flipped bit must rebuild.
        let mut v2 = v.clone();
        v2[(0, 0)] += 1.0;
        let mut third = Matrix::zeros(4, 16);
        assert!(!cache.apply(&v2, &h, &mut third), "changed value must miss");
        assert!(!cache.apply(&v, &h, &mut third), "old value was evicted");
    }

    /// `with_operator_cache` installs for the scope, restores the prior
    /// installation afterwards, and the op bodies actually consult it.
    #[test]
    fn installed_cache_scopes_and_serves_the_ops() {
        let mut rng = Pcg32::seeded(0x0C0B);
        let v = Matrix::random_normal(&mut rng, 5, 12, 1.0);
        let h = Matrix::random_normal(&mut rng, 3, 12, 1.0);
        let mut cache = OperatorCache::new();
        let mut out = Matrix::zeros(3, 12);
        with_operator_cache(&mut cache, || {
            cached_cwy_apply(&v, &h, &mut out);
        });
        // Warmed inside the scope: a direct apply on the same cache hits.
        let mut out2 = Matrix::zeros(3, 12);
        assert!(cache.apply(&v, &h, &mut out2), "scope must have warmed the cache");
        assert!(out.data.iter().zip(&out2.data).all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}
