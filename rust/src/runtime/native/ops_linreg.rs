//! Native op family `linreg`: the toy fused-SGD family exercising the
//! full §2.2 step/grad/apply/eval calling convention with an exact
//! closed-form gradient.  The trainer / data-parallel integration suites
//! run on it because every quantity is analytically checkable.
//!
//! | `meta.op`      | kind  | signature (roles)                              | computation |
//! |----------------|-------|------------------------------------------------|-------------|
//! | `linreg_step`  | step  | W `[k,m]` state, x `[b,k]`, y `[b,m]` data, lr hyper → W', loss | fused SGD: `W - lr · ∇` |
//! | `linreg_grad`  | grad  | W, x, y → ∇ `[k,m]`, loss                      | per-shard gradient |
//! | `linreg_apply` | apply | W state, ∇ data, lr hyper → W'                 | all-reduced update |
//! | `linreg_eval`  | eval  | W, x, y → loss                                 | pure forward |

use anyhow::{bail, Result};

use super::helpers::{dims2, expect_all_f32, expect_arity, expect_roles, expect_shape, mat, tensor};
use super::{FamilyDef, NativeOp};
use crate::linalg::Matrix;
use crate::runtime::manifest::{ArtifactSpec, Role};
use crate::runtime::tensor::HostTensor;

pub static FAMILY: FamilyDef = FamilyDef {
    name: "linreg",
    ops: &["linreg_step", "linreg_grad", "linreg_apply", "linreg_eval"],
    resolve,
    validate,
    run,
};

fn resolve(op: &str, _spec: &ArtifactSpec) -> Option<Result<NativeOp>> {
    Some(Ok(match op {
        "linreg_step" => NativeOp::LinregStep,
        "linreg_grad" => NativeOp::LinregGrad,
        "linreg_apply" => NativeOp::LinregApply,
        "linreg_eval" => NativeOp::LinregEval,
        _ => return None,
    }))
}

fn validate(spec: &ArtifactSpec, op: NativeOp) -> Result<()> {
    expect_all_f32(spec)?;
    match op {
        NativeOp::LinregStep => {
            expect_arity(spec, 4, 2)?;
            expect_roles(spec, &[Role::State, Role::Data, Role::Data, Role::Hyper])?;
            validate_core(spec)?;
            let (k, m) = dims2(&spec.inputs[0])?;
            expect_shape(&spec.outputs[0], &[k, m])?;
            expect_shape(&spec.outputs[1], &[])
        }
        NativeOp::LinregGrad => {
            expect_arity(spec, 3, 2)?;
            expect_roles(spec, &[Role::State, Role::Data, Role::Data])?;
            validate_core(spec)?;
            let (k, m) = dims2(&spec.inputs[0])?;
            expect_shape(&spec.outputs[0], &[k, m])?;
            expect_shape(&spec.outputs[1], &[])
        }
        NativeOp::LinregApply => {
            expect_arity(spec, 3, 1)?;
            expect_roles(spec, &[Role::State, Role::Data, Role::Hyper])?;
            let (k, m) = dims2(&spec.inputs[0])?;
            expect_shape(&spec.inputs[1], &[k, m])?;
            expect_shape(&spec.inputs[2], &[])?;
            expect_shape(&spec.outputs[0], &[k, m])
        }
        NativeOp::LinregEval => {
            expect_arity(spec, 3, 1)?;
            // Eval artifacts are pure functions of (params..., data...)
            // (§2.2): every input is data, nothing persists.
            expect_roles(spec, &[Role::Data, Role::Data, Role::Data])?;
            validate_core(spec)?;
            expect_shape(&spec.outputs[0], &[])
        }
        other => bail!("op {other:?} is not in the linreg family"),
    }
}

/// Shared (W, x, y) consistency for the family.
fn validate_core(spec: &ArtifactSpec) -> Result<()> {
    let (k, m) = dims2(&spec.inputs[0])?;
    let (b, xk) = dims2(&spec.inputs[1])?;
    let (by, ym) = dims2(&spec.inputs[2])?;
    if xk != k {
        bail!("x cols {xk} != W rows {k}");
    }
    if by != b {
        bail!("x rows {b} != y rows {by}");
    }
    if ym != m {
        bail!("y cols {ym} != W cols {m}");
    }
    if spec.inputs.len() == 4 {
        expect_shape(&spec.inputs[3], &[])?;
    }
    Ok(())
}

fn run(_spec: &ArtifactSpec, op: NativeOp, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    match op {
        NativeOp::LinregStep => {
            let w = mat(inputs[0])?;
            let x = mat(inputs[1])?;
            let y = mat(inputs[2])?;
            let lr = inputs[3].scalar()?;
            let (resid, loss) = forward(&w, &x, &y);
            let grad = gradient(&x, &resid);
            let w_next = w.sub(&grad.scale(lr));
            Ok(vec![tensor(w_next), HostTensor::scalar_f32(loss)])
        }
        NativeOp::LinregGrad => {
            let w = mat(inputs[0])?;
            let x = mat(inputs[1])?;
            let y = mat(inputs[2])?;
            let (resid, loss) = forward(&w, &x, &y);
            Ok(vec![tensor(gradient(&x, &resid)), HostTensor::scalar_f32(loss)])
        }
        NativeOp::LinregApply => {
            let w = mat(inputs[0])?;
            let g = mat(inputs[1])?;
            let lr = inputs[2].scalar()?;
            Ok(vec![tensor(w.sub(&g.scale(lr)))])
        }
        NativeOp::LinregEval => {
            let w = mat(inputs[0])?;
            let x = mat(inputs[1])?;
            let y = mat(inputs[2])?;
            let (_, loss) = forward(&w, &x, &y);
            Ok(vec![HostTensor::scalar_f32(loss)])
        }
        other => bail!("op {other:?} is not in the linreg family"),
    }
}

/// Mean-squared-error forward pass: residual `xW - y` and scalar loss.
fn forward(w: &Matrix, x: &Matrix, y: &Matrix) -> (Matrix, f32) {
    let resid = x.matmul(w).sub(y);
    let b = x.rows.max(1) as f32;
    let loss = resid.data.iter().map(|r| r * r).sum::<f32>() / b;
    (resid, loss)
}

/// Exact MSE gradient: `(2 / b) x^T (xW - y)`.
fn gradient(x: &Matrix, resid: &Matrix) -> Matrix {
    let b = x.rows.max(1) as f32;
    x.t().matmul(resid).scale(2.0 / b)
}
