//! Native CPU execution backend (DESIGN.md §2.6, §3.1) — a modular
//! registry of op families.
//!
//! The PJRT path executes HLO text through the `xla` crate; when those
//! bindings are the offline stub, nothing downstream of `Engine::open`
//! used to run.  This backend closes that gap: the paper's computations
//! reduce to a handful of fused matmuls, which is exactly what `linalg` +
//! `orthogonal` implement — cheap enough to evaluate directly on the CPU.
//!
//! A native artifact is a manifest entry whose `meta.op` names a
//! registered op.  Ops are grouped into **families**, one module each,
//! registered in the [`FAMILIES`] table; every family independently owns
//! its op names, its compile-time manifest contract (`validate`), and its
//! run closure, so adding a family never grows someone else's match:
//!
//! | family | module | ops |
//! |--------|--------|-----|
//! | `ortho` | [`ops_ortho`] | `cwy`, `hr`, `tcwy`, `rollout_{cwy,hr}`, `cell_{cwy,hr,tcwy}` |
//! | `linreg` | [`ops_linreg`] | `linreg_{step,grad,apply,eval}` |
//! | `rnn_copy` | [`ops_rnn`] | `rnn_copy_{step,grad,apply,eval}` (× `meta.param` = `cwy\|hr\|tcwy`) |
//!
//! [`NativeExec::compile`] resolves `meta.op` through the registry and
//! validates the manifest signature against the op's contract (the native
//! analogue of an XLA compile error); `run` then executes the artifact
//! contract — shapes, §2.2 calling convention, `state_bin` initial state —
//! identically to the PJRT path, so `Trainer`, `DataParallel`, and the
//! serve worker pool run unchanged on either backend.

use anyhow::{anyhow, Result};

use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::tensor::HostTensor;

pub mod helpers;
pub mod ops_linreg;
pub mod ops_ortho;
pub mod ops_rnn;

/// Manifest meta key naming the registered native op.
pub const OP_META_KEY: &str = "op";

/// Manifest meta key selecting the orthogonal parametrization of an op
/// family that supports several (`cwy` | `hr` | `tcwy`).
pub const PARAM_META_KEY: &str = "param";

/// Which orthogonal construction a recurrent cell / RNN family uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CellKind {
    Cwy,
    Hr,
    Tcwy,
}

impl CellKind {
    /// Parse a `meta.param` value.
    pub fn parse_param(s: &str) -> Option<CellKind> {
        Some(match s {
            "cwy" => CellKind::Cwy,
            "hr" => CellKind::Hr,
            "tcwy" => CellKind::Tcwy,
            _ => return None,
        })
    }
}

/// Which §2.2 artifact role an op family member plays.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepMode {
    /// Fused `state', metrics = step(state..., data..., lr)`.
    Step,
    /// Per-shard `grads, metrics = grad(state..., data...)`.
    Grad,
    /// All-reduced `state' = apply(state..., grads..., lr)`.
    Apply,
    /// Pure `metrics = eval(params..., data...)`.
    Eval,
}

/// A registered native computation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NativeOp {
    CwyMatrix,
    HrMatrix,
    TcwyMatrix,
    RolloutCwy,
    RolloutHr,
    Cell(CellKind),
    LinregStep,
    LinregGrad,
    LinregApply,
    LinregEval,
    /// CWY/T-CWY/HR-parametrized recurrent net on the copying task.
    RnnCopy(CellKind, StepMode),
}

/// One op family's registration: its op-name inventory plus the three
/// hooks the interpreter needs.  `resolve` returns `None` when the op
/// string belongs to another family, `Some(Err)` when the string is this
/// family's but its meta is inconsistent (e.g. a bad `param`).
pub struct FamilyDef {
    pub name: &'static str,
    pub ops: &'static [&'static str],
    pub resolve: fn(&str, &ArtifactSpec) -> Option<Result<NativeOp>>,
    pub validate: fn(&ArtifactSpec, NativeOp) -> Result<()>,
    pub run: fn(&ArtifactSpec, NativeOp, &[&HostTensor]) -> Result<Vec<HostTensor>>,
}

/// The op-family registry.  Adding a family = adding a module + one row.
pub static FAMILIES: &[&FamilyDef] =
    &[&ops_ortho::FAMILY, &ops_linreg::FAMILY, &ops_rnn::FAMILY];

/// Every registered `meta.op` string, in family order (introspection /
/// `cwy list` tooling).
pub fn registered_ops() -> Vec<&'static str> {
    FAMILIES.iter().flat_map(|f| f.ops.iter().copied()).collect()
}

/// A "compiled" native artifact: the resolved op and its family,
/// signature-checked against the manifest entry.
pub struct NativeExec {
    op: NativeOp,
    family: &'static FamilyDef,
}

impl NativeExec {
    /// Resolve `meta.op` through the registry and validate the artifact
    /// signature against the op's contract.  Errors here mirror XLA
    /// compile-time failures.
    pub fn compile(spec: &ArtifactSpec) -> Result<NativeExec> {
        let op_str = spec.meta_str(OP_META_KEY).ok_or_else(|| {
            anyhow!(
                "{}: no '{}' meta key — the native backend executes registered ops, \
                 not HLO text; this artifact needs the PJRT backend (DESIGN.md §2.6)",
                spec.name,
                OP_META_KEY
            )
        })?;
        let (op, family) = FAMILIES
            .iter()
            .find_map(|f| (f.resolve)(op_str, spec).map(|r| r.map(|op| (op, *f))))
            .ok_or_else(|| anyhow!("{}: unknown native op '{op_str}'", spec.name))?
            .map_err(|e| anyhow!("{}: {e:#}", spec.name))?;
        (family.validate)(spec, op)
            .map_err(|e| anyhow!("{}: bad native signature: {e:#}", spec.name))?;
        Ok(NativeExec { op, family })
    }

    pub fn op(&self) -> NativeOp {
        self.op
    }

    /// Execute one artifact call.  `inputs` are already checked against
    /// the manifest shapes/dtypes by `Compiled::run_refs`.
    pub fn run(&self, spec: &ArtifactSpec, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        (self.family.run)(spec, self.op, inputs)
            .map_err(|e| anyhow!("{} (native {:?}): {e:#}", spec.name, self.op))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::orthogonal::cwy;
    use crate::runtime::manifest::Manifest;
    use crate::util::prop::assert_close;
    use crate::util::rng::Pcg32;
    use std::path::PathBuf;

    use super::helpers::tensor;

    fn manifest(extra: &str) -> Manifest {
        Manifest::parse_str(
            &format!(r#"{{"artifacts":[{extra}]}}"#),
            PathBuf::from("/tmp"),
        )
        .unwrap()
    }

    const CWY_ART: &str = r#"{"name":"q","file":"q.hlo","kind":"micro",
        "inputs":[{"name":"v","shape":[3,8],"dtype":"float32"}],
        "outputs":[{"name":"q","shape":[8,8],"dtype":"float32"}],
        "meta":{"op":"cwy"}}"#;

    #[test]
    fn compile_resolves_and_validates() {
        let m = manifest(CWY_ART);
        let exec = NativeExec::compile(m.get("q").unwrap()).unwrap();
        assert_eq!(exec.op(), NativeOp::CwyMatrix);
    }

    #[test]
    fn compile_rejects_missing_and_unknown_ops() {
        let m = manifest(
            r#"{"name":"a","file":"a.hlo","kind":"micro",
               "inputs":[],"outputs":[],"meta":{}},
              {"name":"b","file":"b.hlo","kind":"micro",
               "inputs":[],"outputs":[],"meta":{"op":"warp_drive"}}"#,
        );
        let err = NativeExec::compile(m.get("a").unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("no 'op' meta"), "{err:#}");
        let err = NativeExec::compile(m.get("b").unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("unknown native op"), "{err:#}");
    }

    #[test]
    fn compile_rejects_inconsistent_shapes() {
        let m = manifest(
            r#"{"name":"q","file":"q.hlo","kind":"micro",
               "inputs":[{"name":"v","shape":[3,8],"dtype":"float32"}],
               "outputs":[{"name":"q","shape":[7,7],"dtype":"float32"}],
               "meta":{"op":"cwy"}}"#,
        );
        assert!(NativeExec::compile(m.get("q").unwrap()).is_err());
    }

    #[test]
    fn registry_covers_every_family_without_overlap() {
        let ops = registered_ops();
        assert!(ops.len() >= 12, "registry shrank: {ops:?}");
        for (i, name) in ops.iter().enumerate() {
            assert!(
                !ops[i + 1..].contains(name),
                "op '{name}' registered by two families"
            );
        }
        // Every inventoried op resolves through exactly its family.
        let dummy = manifest(CWY_ART);
        let spec = dummy.get("q").unwrap();
        for f in FAMILIES {
            for &name in f.ops {
                let hits: Vec<&str> = FAMILIES
                    .iter()
                    .filter(|g| (g.resolve)(name, spec).is_some())
                    .map(|g| g.name)
                    .collect();
                assert_eq!(hits, vec![f.name], "op '{name}' resolution");
            }
        }
    }

    #[test]
    fn cwy_op_matches_native_construction() {
        let m = manifest(CWY_ART);
        let spec = m.get("q").unwrap();
        let exec = NativeExec::compile(spec).unwrap();
        let mut rng = Pcg32::seeded(11);
        let v = Matrix::random_normal(&mut rng, 3, 8, 1.0);
        let vt = tensor(v.clone());
        let out = exec.run(spec, &[&vt]).unwrap();
        assert_eq!(out[0].shape, vec![8, 8]);
        assert_close(out[0].as_f32().unwrap(), &cwy::matrix(&v).data, 1e-6).unwrap();
    }

    #[test]
    fn linreg_step_descends() {
        let m = manifest(
            r#"{"name":"s","file":"s.hlo","kind":"step",
               "inputs":[{"name":"w","shape":[4,2],"dtype":"float32","kind":"state"},
                         {"name":"x","shape":[8,4],"dtype":"float32"},
                         {"name":"y","shape":[8,2],"dtype":"float32"},
                         {"name":"lr","shape":[],"dtype":"float32","kind":"hyper"}],
               "outputs":[{"name":"w","shape":[4,2],"dtype":"float32"},
                          {"name":"loss","shape":[],"dtype":"float32"}],
               "meta":{"op":"linreg_step"}}"#,
        );
        let spec = m.get("s").unwrap();
        let exec = NativeExec::compile(spec).unwrap();
        let mut rng = Pcg32::seeded(3);
        let w_true = Matrix::random_normal(&mut rng, 4, 2, 1.0);
        let x = Matrix::random_normal(&mut rng, 8, 4, 1.0);
        let y = x.matmul(&w_true);
        let mut w = HostTensor::f32(vec![4, 2], vec![0.0; 8]);
        let (xt, yt) = (tensor(x), tensor(y));
        let lr = HostTensor::scalar_f32(0.05);
        let mut losses = Vec::new();
        for _ in 0..60 {
            let out = exec.run(spec, &[&w, &xt, &yt, &lr]).unwrap();
            losses.push(out[1].scalar().unwrap());
            w = out[0].clone();
        }
        assert!(losses[0] > 0.1, "first loss {} too small to mean anything", losses[0]);
        assert!(
            *losses.last().unwrap() < losses[0] * 0.01,
            "no descent: {} -> {}",
            losses[0],
            losses.last().unwrap()
        );
    }
}
