//! Manifest parsing: the machine-readable index `python/compile/aot.py`
//! writes next to the HLO artifacts.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::tensor::Dtype;
use crate::util::json::{parse, Json};

/// Role of an artifact input/output in the calling convention.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    State,
    Data,
    Hyper,
    Output,
}

/// Shape/dtype spec for one tensor in an artifact signature.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    pub role: Role,
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub state_bin: Option<String>,
    pub meta: BTreeMap<String, String>,
}

impl ArtifactSpec {
    pub fn n_state(&self) -> usize {
        self.inputs.iter().filter(|s| s.role == Role::State).count()
    }

    pub fn n_data(&self) -> usize {
        self.inputs.iter().filter(|s| s.role == Role::Data).count()
    }

    pub fn has_lr(&self) -> bool {
        self.inputs.iter().any(|s| s.role == Role::Hyper)
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).map(|s| s.as_str())
    }
}

/// The parsed manifest: artifact name -> spec.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn tensor_spec(j: &Json, role_override: Option<Role>) -> Result<TensorSpec> {
    let name = j
        .path(&["name"])
        .as_str()
        .ok_or_else(|| anyhow!("tensor spec missing name"))?
        .to_string();
    let shape = j
        .path(&["shape"])
        .as_arr()
        .ok_or_else(|| anyhow!("tensor spec missing shape"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = Dtype::parse(j.path(&["dtype"]).as_str().unwrap_or("float32"))?;
    let role = match role_override {
        Some(r) => r,
        None => match j.path(&["kind"]).as_str() {
            Some("state") => Role::State,
            Some("hyper") => Role::Hyper,
            _ => Role::Data,
        },
    };
    Ok(TensorSpec { name, shape, dtype, role })
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse_str(&text, dir)
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse_str(text: &str, dir: PathBuf) -> Result<Manifest> {
        let doc = parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let arts = doc
            .path(&["artifacts"])
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let mut artifacts = BTreeMap::new();
        for a in arts {
            let name = a
                .path(&["name"])
                .as_str()
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = a
                .path(&["file"])
                .as_str()
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?
                .to_string();
            let kind = a.path(&["kind"]).as_str().unwrap_or("micro").to_string();
            let inputs = a
                .path(&["inputs"])
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|j| tensor_spec(j, None))
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .path(&["outputs"])
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|j| tensor_spec(j, Some(Role::Output)))
                .collect::<Result<Vec<_>>>()?;
            let state_bin = a
                .path(&["state_bin"])
                .as_str()
                .map(|s| s.to_string());
            let mut meta = BTreeMap::new();
            if let Json::Obj(m) = a.path(&["meta"]) {
                for (k, v) in m {
                    if let Some(s) = v.as_str() {
                        meta.insert(k.clone(), s.to_string());
                    } else if let Some(x) = v.as_f64() {
                        meta.insert(k.clone(), format!("{x}"));
                    }
                }
            }
            artifacts.insert(
                name.clone(),
                ArtifactSpec { name, file, kind, inputs, outputs, state_bin, meta },
            );
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// Artifact names matching a predicate on (name, spec).
    pub fn select<'a>(
        &'a self,
        mut pred: impl FnMut(&str, &ArtifactSpec) -> bool + 'a,
    ) -> Vec<&'a ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|(n, s)| pred(n, s))
            .map(|(_, s)| s)
            .collect()
    }

    /// Read the initial flat state tensors recorded for a step artifact.
    pub fn load_state(&self, spec: &ArtifactSpec) -> Result<Vec<crate::runtime::tensor::HostTensor>> {
        let bin = spec
            .state_bin
            .as_ref()
            .ok_or_else(|| anyhow!("artifact {} has no state_bin", spec.name))?;
        let bytes = fs::read(self.dir.join(bin))
            .with_context(|| format!("reading {bin}"))?;
        let state_specs: Vec<&TensorSpec> =
            spec.inputs.iter().filter(|s| s.role == Role::State).collect();
        let mut out = Vec::with_capacity(state_specs.len());
        let mut off = 0usize;
        for ts in state_specs {
            if off + 8 > bytes.len() {
                bail!("state_bin truncated at tensor {}", ts.name);
            }
            let count =
                u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap()) as usize;
            off += 8;
            let expect: usize = ts.shape.iter().product();
            if count != expect {
                bail!(
                    "state_bin tensor {}: recorded {count} elems, manifest says {expect}",
                    ts.name
                );
            }
            let nbytes = count * 4;
            if off + nbytes > bytes.len() {
                bail!("state_bin truncated in tensor {}", ts.name);
            }
            let vals: Vec<f32> = bytes[off..off + nbytes]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            off += nbytes;
            out.push(crate::runtime::tensor::HostTensor::f32(ts.shape.clone(), vals));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"artifacts":[
      {"name":"toy_step","file":"toy_step.hlo.txt","kind":"step",
       "inputs":[{"name":"w","shape":[2,2],"dtype":"float32","kind":"state"},
                 {"name":"x","shape":[4],"dtype":"int32","kind":"data"},
                 {"name":"lr","shape":[],"dtype":"float32","kind":"hyper"}],
       "outputs":[{"name":"w","shape":[2,2],"dtype":"float32"},
                  {"name":"loss","shape":[],"dtype":"float32"}],
       "meta":{"task":"toy","n":"2"}}]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse_str(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let a = m.get("toy_step").unwrap();
        assert_eq!(a.n_state(), 1);
        assert_eq!(a.n_data(), 1);
        assert!(a.has_lr());
        assert_eq!(a.outputs.len(), 2);
        assert_eq!(a.meta_str("task"), Some("toy"));
        assert_eq!(a.inputs[1].dtype, Dtype::I32);
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse_str(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert!(m.get("nope").is_err());
    }
}
