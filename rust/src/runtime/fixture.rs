//! Toy artifact fixture for the native backend (DESIGN.md §2.6).
//!
//! `make artifacts` needs the Python layer; the native backend does not.
//! This module writes a complete, self-contained artifacts directory —
//! `manifest.json` plus `state_bin` dumps in the §2.3 format — whose
//! entries name registered native ops, so the full execution path
//! (`Engine::open` → `load` → `Compiled::run`, trainer, data-parallel,
//! serve workers) runs for real with no Python and no PJRT bindings.
//!
//! Used by `rust/tests/integration_{runtime,trainer,serve}.rs`,
//! `examples/serve_bench`, and `cwy serve --backend native` when no
//! artifacts directory exists.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use crate::linalg::Matrix;
use crate::runtime::tensor::HostTensor;
use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// Reflection count of the forward/rollout artifacts.
pub const FWD_L: usize = 4;
/// Hidden width of the forward/rollout artifacts.
pub const FWD_N: usize = 12;
/// Rollout batch rows.
pub const FWD_B: usize = 2;
/// T-CWY frame: St(TCWY_N, TCWY_M).
pub const TCWY_M: usize = 3;
pub const TCWY_N: usize = 10;

/// Recurrent cell: L reflections over width N, fused batch B.
/// L != B on purpose — the serve per-row heuristic (DESIGN.md §6.2)
/// classifies V as worker-resident only because its leading dim differs
/// from the fused batch.
pub const CELL_L: usize = 6;
pub const CELL_N: usize = 12;
pub const CELL_B: usize = 4;

/// Linear-regression family: y = x W, W in (IN, OUT), batches of B rows.
pub const LINREG_IN: usize = 6;
pub const LINREG_OUT: usize = 3;
pub const LINREG_B: usize = 8;

/// Copy-task RNN family (`rnn_copy_*`): task shape and model size.  The
/// numbers are tuned so plain SGD with the k^-0.5 schedule (Thm 4)
/// reliably drives the loss below the memoryless baseline
/// `10 ln 8 / (T + 20)` within a few hundred steps on the native backend.
pub const COPY_T_BLANK: usize = 4;
pub const COPY_T_TOTAL: usize = COPY_T_BLANK + 20;
pub const COPY_B: usize = 8;
pub const COPY_N: usize = 32;
pub const COPY_L: usize = 8;

/// The cell's recorded reflection parameters (state_bin tensor 0).
pub fn toy_cell_v0() -> Matrix {
    Matrix::random_normal(&mut Pcg32::seeded(2024), CELL_L, CELL_N, 1.0)
}

/// The cell's recorded initial hidden row: every fused row starts from
/// this vector, and fresh serve sessions inherit row 0 (§6.2).
/// Deliberately non-zero so tests can tell "state_bin was read" from
/// "fell back to zeros".
pub fn toy_cell_h0_row() -> Vec<f32> {
    vec![0.25; CELL_N]
}

/// Ground-truth teacher weights the linreg data is generated from.
pub fn linreg_teacher() -> Matrix {
    Matrix::random_normal(&mut Pcg32::seeded(77), LINREG_IN, LINREG_OUT, 1.0)
}

/// Initial parameters of the copy-task RNN, in state order (V, W_in,
/// W_out, b_out).  `square_v` selects the (N, N) reflection block the
/// tcwy variant needs; cwy and hr share the same (L, N) init so their
/// gradients are comparable on identical rollouts.
pub fn copy_rnn_init(square_v: bool) -> Vec<HostTensor> {
    use crate::runtime::native::ops_rnn::{IN_VOCAB, OUT_CLASSES};
    let l = if square_v { COPY_N } else { COPY_L };
    let v = Matrix::random_normal(&mut Pcg32::seeded(2025), l, COPY_N, 1.0);
    let w_in = Matrix::random_normal(&mut Pcg32::seeded(2026), IN_VOCAB, COPY_N, 0.3);
    let w_out = Matrix::random_normal(&mut Pcg32::seeded(2027), COPY_N, OUT_CLASSES, 0.3);
    let b_out = Matrix::zeros(1, OUT_CLASSES);
    [v, w_in, w_out, b_out]
        .into_iter()
        .map(|m| HostTensor::f32(vec![m.rows, m.cols], m.data))
        .collect()
}

/// Copy-task data provider matching the `copy_*` artifacts' shapes.
pub fn copy_provider(seed: u64) -> impl FnMut() -> Vec<HostTensor> {
    let mut task = crate::data::copying::CopyTask::new(COPY_T_BLANK, COPY_B, seed);
    move || {
        let b = task.next_batch();
        vec![
            HostTensor::i32(vec![b.batch, b.t_total], b.tokens),
            HostTensor::i32(vec![b.batch, b.t_total], b.targets),
        ]
    }
}

/// The memoryless-predictor cross entropy of the fixture's copy task —
/// the bar real training must beat.
pub fn copy_baseline_ce() -> f32 {
    crate::data::copying::CopyTask::new(COPY_T_BLANK, 1, 0).baseline_ce()
}

/// Noise-free data provider for the linreg family: fresh `x`, `y = x W*`
/// per call.  SGD from the recorded zero init drives the loss to ~0.
pub fn linreg_provider(seed: u64) -> impl FnMut() -> Vec<HostTensor> {
    let teacher = linreg_teacher();
    let mut rng = Pcg32::seeded(seed);
    move || {
        let x = Matrix::random_normal(&mut rng, LINREG_B, LINREG_IN, 1.0);
        let y = x.matmul(&teacher);
        vec![
            HostTensor::f32(vec![LINREG_B, LINREG_IN], x.data),
            HostTensor::f32(vec![LINREG_B, LINREG_OUT], y.data),
        ]
    }
}

/// Serialize tensors in the `state_bin` format (§2.3): per tensor,
/// little-endian `u64 count | f32 data...`, in state order.
pub fn state_bin_bytes(tensors: &[HostTensor]) -> Result<Vec<u8>> {
    let mut bytes = Vec::new();
    for t in tensors {
        let data = t.as_f32().context("state_bin tensors are f32")?;
        bytes.extend_from_slice(&(data.len() as u64).to_le_bytes());
        for &v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    Ok(bytes)
}

fn tensor_json_dtyped(name: &str, shape: &[usize], kind: Option<&str>, dtype: &str) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert(
        "shape".to_string(),
        Json::Arr(shape.iter().map(|&d| Json::Num(d as f64)).collect()),
    );
    m.insert("dtype".to_string(), Json::Str(dtype.to_string()));
    if let Some(k) = kind {
        m.insert("kind".to_string(), Json::Str(k.to_string()));
    }
    Json::Obj(m)
}

fn tensor_json(name: &str, shape: &[usize], kind: Option<&str>) -> Json {
    tensor_json_dtyped(name, shape, kind, "float32")
}

struct Art {
    name: String,
    kind: &'static str,
    inputs: Vec<Json>,
    outputs: Vec<Json>,
    state_bin: Option<String>,
    meta: Vec<(&'static str, String)>,
}

impl Art {
    fn json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("file".to_string(), Json::Str(format!("{}.hlo.txt", self.name)));
        m.insert("kind".to_string(), Json::Str(self.kind.to_string()));
        m.insert("inputs".to_string(), Json::Arr(self.inputs.clone()));
        m.insert("outputs".to_string(), Json::Arr(self.outputs.clone()));
        if let Some(sb) = &self.state_bin {
            m.insert("state_bin".to_string(), Json::Str(sb.clone()));
        }
        let mut meta = std::collections::BTreeMap::new();
        for (k, v) in &self.meta {
            meta.insert(k.to_string(), Json::Str(v.clone()));
        }
        m.insert("meta".to_string(), Json::Obj(meta));
        Json::Obj(m)
    }
}

/// Write the toy artifacts directory: manifest + state bins.
///
/// Artifact inventory (all executable natively except `hlo_only`, which
/// exists to exercise the "needs PJRT" error path):
///
/// * `param_cwy` / `param_hr` — V → Q, the Thm 2 pair;
/// * `stiefel_tcwy` — V → Ω on St(N, M);
/// * `rollout_cwy` / `rollout_hr` — (V, H) → H Q, the Fig. 2 pair;
/// * `toy_cell_step` — recurrent CWY cell with recorded initial state;
/// * `linreg_{step,grad,apply,eval}` — fused SGD family for the trainer
///   and data-parallel suites, zero-initialized weights;
/// * `copy_{cwy,hr,tcwy}_{step,grad,apply,eval}` — trainable rnn_copy
///   family on the copying task (exact BPTT, loss + grad_norm metrics);
/// * `hlo_only` — no `meta.op`.
pub fn write_toy_artifacts(dir: &Path) -> Result<()> {
    fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;

    let mut arts = vec![
        Art {
            name: "param_cwy".into(),
            kind: "micro",
            inputs: vec![tensor_json("v", &[FWD_L, FWD_N], None)],
            outputs: vec![tensor_json("q", &[FWD_N, FWD_N], None)],
            state_bin: None,
            meta: vec![("op", "cwy".into()), ("method", "cwy".into())],
        },
        Art {
            name: "param_hr".into(),
            kind: "micro",
            inputs: vec![tensor_json("v", &[FWD_L, FWD_N], None)],
            outputs: vec![tensor_json("q", &[FWD_N, FWD_N], None)],
            state_bin: None,
            meta: vec![("op", "hr".into()), ("method", "hr".into())],
        },
        Art {
            name: "stiefel_tcwy".into(),
            kind: "micro",
            inputs: vec![tensor_json("v", &[TCWY_M, TCWY_N], None)],
            outputs: vec![tensor_json("omega", &[TCWY_N, TCWY_M], None)],
            state_bin: None,
            meta: vec![("op", "tcwy".into()), ("method", "tcwy".into())],
        },
        Art {
            name: "rollout_cwy".into(),
            kind: "micro",
            inputs: vec![
                tensor_json("v", &[FWD_L, FWD_N], None),
                tensor_json("h", &[FWD_B, FWD_N], None),
            ],
            outputs: vec![tensor_json("out", &[FWD_B, FWD_N], None)],
            state_bin: None,
            meta: vec![("op", "rollout_cwy".into())],
        },
        Art {
            name: "rollout_hr".into(),
            kind: "micro",
            inputs: vec![
                tensor_json("v", &[FWD_L, FWD_N], None),
                tensor_json("h", &[FWD_B, FWD_N], None),
            ],
            outputs: vec![tensor_json("out", &[FWD_B, FWD_N], None)],
            state_bin: None,
            meta: vec![("op", "rollout_hr".into())],
        },
        Art {
            name: "toy_cell_step".into(),
            kind: "step",
            inputs: vec![
                tensor_json("v", &[CELL_L, CELL_N], Some("state")),
                tensor_json("h", &[CELL_B, CELL_N], Some("state")),
                tensor_json("x", &[CELL_B, CELL_N], None),
                tensor_json("lr", &[], Some("hyper")),
            ],
            outputs: vec![
                tensor_json("v", &[CELL_L, CELL_N], None),
                tensor_json("h", &[CELL_B, CELL_N], None),
                tensor_json("y", &[CELL_B, CELL_N], None),
            ],
            state_bin: Some("toy_cell.state.bin".into()),
            meta: vec![
                ("op", "cell_cwy".into()),
                ("task", "toy_cell".into()),
                ("batch", CELL_B.to_string()),
            ],
        },
        Art {
            name: "linreg_step".into(),
            kind: "step",
            inputs: vec![
                tensor_json("w", &[LINREG_IN, LINREG_OUT], Some("state")),
                tensor_json("x", &[LINREG_B, LINREG_IN], None),
                tensor_json("y", &[LINREG_B, LINREG_OUT], None),
                tensor_json("lr", &[], Some("hyper")),
            ],
            outputs: vec![
                tensor_json("w", &[LINREG_IN, LINREG_OUT], None),
                tensor_json("loss", &[], None),
            ],
            state_bin: Some("linreg.state.bin".into()),
            meta: vec![
                ("op", "linreg_step".into()),
                ("task", "linreg".into()),
                ("batch", LINREG_B.to_string()),
                ("n_params", "1".into()),
            ],
        },
        Art {
            name: "linreg_grad".into(),
            kind: "grad",
            inputs: vec![
                tensor_json("w", &[LINREG_IN, LINREG_OUT], Some("state")),
                tensor_json("x", &[LINREG_B, LINREG_IN], None),
                tensor_json("y", &[LINREG_B, LINREG_OUT], None),
            ],
            outputs: vec![
                tensor_json("g", &[LINREG_IN, LINREG_OUT], None),
                tensor_json("loss", &[], None),
            ],
            state_bin: None,
            meta: vec![("op", "linreg_grad".into()), ("n_params", "1".into())],
        },
        Art {
            name: "linreg_apply".into(),
            kind: "apply",
            inputs: vec![
                tensor_json("w", &[LINREG_IN, LINREG_OUT], Some("state")),
                tensor_json("g", &[LINREG_IN, LINREG_OUT], None),
                tensor_json("lr", &[], Some("hyper")),
            ],
            outputs: vec![tensor_json("w", &[LINREG_IN, LINREG_OUT], None)],
            state_bin: None,
            meta: vec![("op", "linreg_apply".into())],
        },
        Art {
            name: "linreg_eval".into(),
            kind: "eval",
            inputs: vec![
                tensor_json("w", &[LINREG_IN, LINREG_OUT], None),
                tensor_json("x", &[LINREG_B, LINREG_IN], None),
                tensor_json("y", &[LINREG_B, LINREG_OUT], None),
            ],
            outputs: vec![tensor_json("loss", &[], None)],
            state_bin: None,
            meta: vec![("op", "linreg_eval".into())],
        },
        Art {
            name: "hlo_only".into(),
            kind: "micro",
            inputs: vec![tensor_json("x", &[2, 2], None)],
            outputs: vec![tensor_json("y", &[2, 2], None)],
            state_bin: None,
            meta: vec![],
        },
    ];

    arts.extend(copy_rnn_arts());

    let manifest = {
        let mut m = std::collections::BTreeMap::new();
        m.insert(
            "artifacts".to_string(),
            Json::Arr(arts.iter().map(|a| a.json()).collect()),
        );
        Json::Obj(m)
    };
    fs::write(dir.join("manifest.json"), manifest.dump())
        .context("writing manifest.json")?;

    // toy_cell_step state: V0 then h0 (every row = the recorded row).
    let v0 = toy_cell_v0();
    let h0: Vec<f32> = (0..CELL_B).flat_map(|_| toy_cell_h0_row()).collect();
    let cell_state = [
        HostTensor::f32(vec![CELL_L, CELL_N], v0.data),
        HostTensor::f32(vec![CELL_B, CELL_N], h0),
    ];
    fs::write(dir.join("toy_cell.state.bin"), state_bin_bytes(&cell_state)?)
        .context("writing toy_cell.state.bin")?;

    // linreg state: W0 = 0 (the teacher is deliberately not the init).
    let w0 = [HostTensor::f32(
        vec![LINREG_IN, LINREG_OUT],
        vec![0.0; LINREG_IN * LINREG_OUT],
    )];
    fs::write(dir.join("linreg.state.bin"), state_bin_bytes(&w0)?)
        .context("writing linreg.state.bin")?;

    // copy-task RNN states: cwy and hr share one init (so gradients are
    // comparable on identical rollouts); tcwy records the square V.
    for (param, square) in [("cwy", false), ("hr", false), ("tcwy", true)] {
        let bin = format!("copy_{param}.state.bin");
        fs::write(dir.join(&bin), state_bin_bytes(&copy_rnn_init(square))?)
            .with_context(|| format!("writing {bin}"))?;
    }

    Ok(())
}

/// The `copy_{cwy,hr,tcwy}_{step,grad,apply,eval}` artifact entries: the
/// trainable rnn_copy op family over the procedural copying task, in the
/// full §2.2 step/grad/apply/eval calling convention.
fn copy_rnn_arts() -> Vec<Art> {
    use crate::runtime::native::ops_rnn::{IN_VOCAB, OUT_CLASSES};
    let mut arts = Vec::new();
    for (param, vrows) in [("cwy", COPY_L), ("hr", COPY_L), ("tcwy", COPY_N)] {
        let params = |kind: Option<&str>| {
            vec![
                tensor_json("v", &[vrows, COPY_N], kind),
                tensor_json("w_in", &[IN_VOCAB, COPY_N], kind),
                tensor_json("w_out", &[COPY_N, OUT_CLASSES], kind),
                tensor_json("b_out", &[1, OUT_CLASSES], kind),
            ]
        };
        let data = || {
            vec![
                tensor_json_dtyped("tokens", &[COPY_B, COPY_T_TOTAL], None, "int32"),
                tensor_json_dtyped("targets", &[COPY_B, COPY_T_TOTAL], None, "int32"),
            ]
        };
        let metrics = || {
            vec![tensor_json("loss", &[], None), tensor_json("grad_norm", &[], None)]
        };
        let meta = |op: &str| -> Vec<(&'static str, String)> {
            vec![
                ("op", format!("rnn_copy_{op}")),
                ("param", param.to_string()),
                ("method", param.to_string()),
                ("task", "copy".to_string()),
                ("t_blank", COPY_T_BLANK.to_string()),
                ("batch", COPY_B.to_string()),
                ("n_params", "4".to_string()),
            ]
        };
        let lr = || tensor_json("lr", &[], Some("hyper"));
        arts.push(Art {
            name: format!("copy_{param}_step"),
            kind: "step",
            inputs: params(Some("state")).into_iter().chain(data()).chain([lr()]).collect(),
            outputs: params(None).into_iter().chain(metrics()).collect(),
            state_bin: Some(format!("copy_{param}.state.bin")),
            meta: meta("step"),
        });
        arts.push(Art {
            name: format!("copy_{param}_grad"),
            kind: "grad",
            inputs: params(Some("state")).into_iter().chain(data()).collect(),
            outputs: params(None).into_iter().chain(metrics()).collect(),
            state_bin: None,
            meta: meta("grad"),
        });
        let grad_ins = vec![
            tensor_json("dv", &[vrows, COPY_N], None),
            tensor_json("dw_in", &[IN_VOCAB, COPY_N], None),
            tensor_json("dw_out", &[COPY_N, OUT_CLASSES], None),
            tensor_json("db_out", &[1, OUT_CLASSES], None),
        ];
        arts.push(Art {
            name: format!("copy_{param}_apply"),
            kind: "apply",
            inputs: params(Some("state")).into_iter().chain(grad_ins).chain([lr()]).collect(),
            outputs: params(None),
            state_bin: None,
            meta: meta("apply"),
        });
        arts.push(Art {
            name: format!("copy_{param}_eval"),
            kind: "eval",
            inputs: params(None).into_iter().chain(data()).collect(),
            outputs: vec![tensor_json("loss", &[], None)],
            state_bin: None,
            meta: meta("eval"),
        });
    }
    arts
}

static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Self-cleaning unique temp directory (no tempfile crate vendored).
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(tag: &str) -> Result<TempDir> {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos())
            .unwrap_or(0);
        let path = std::env::temp_dir().join(format!(
            "cwy-{tag}-{}-{}-{nanos}",
            std::process::id(),
            TEMP_SEQ.fetch_add(1, Ordering::Relaxed),
        ));
        fs::create_dir_all(&path)
            .with_context(|| format!("creating {}", path.display()))?;
        Ok(TempDir { path })
    }

    /// Create a temp directory already populated by [`write_toy_artifacts`].
    pub fn with_toy_artifacts(tag: &str) -> Result<TempDir> {
        let dir = TempDir::new(tag)?;
        write_toy_artifacts(dir.path())?;
        Ok(dir)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    #[test]
    fn fixture_round_trips_through_manifest_loader() {
        let dir = TempDir::with_toy_artifacts("fixture-test").unwrap();
        let m = Manifest::load(dir.path()).unwrap();
        assert!(m.artifacts.len() >= 22);
        let cell = m.get("toy_cell_step").unwrap();
        assert_eq!(cell.n_state(), 2);
        assert_eq!(cell.n_data(), 1);
        assert!(cell.has_lr());
        let state = m.load_state(cell).unwrap();
        assert_eq!(state.len(), 2);
        assert_eq!(state[0].shape, vec![CELL_L, CELL_N]);
        assert_eq!(state[1].shape, vec![CELL_B, CELL_N]);
        assert_eq!(state[1].as_f32().unwrap()[0], 0.25);
        let lin = m.get("linreg_step").unwrap();
        assert_eq!(m.load_state(lin).unwrap()[0].len(), LINREG_IN * LINREG_OUT);
    }

    #[test]
    fn copy_rnn_artifacts_compile_and_share_cwy_hr_init() {
        use crate::runtime::native::NativeExec;
        let dir = TempDir::with_toy_artifacts("fixture-copy").unwrap();
        let m = Manifest::load(dir.path()).unwrap();
        for param in ["cwy", "hr", "tcwy"] {
            for op in ["step", "grad", "apply", "eval"] {
                let spec = m.get(&format!("copy_{param}_{op}")).unwrap();
                NativeExec::compile(spec).unwrap_or_else(|e| {
                    panic!("copy_{param}_{op} failed native compile: {e:#}")
                });
            }
            let step = m.get(&format!("copy_{param}_step")).unwrap();
            assert_eq!(step.n_state(), 4);
            assert_eq!(step.n_data(), 2);
            assert!(step.has_lr());
            assert_eq!(m.load_state(step).unwrap().len(), 4);
        }
        // cwy and hr record the *same* initial parameters, so gradient
        // parity tests compare identical rollouts.
        let a = m.load_state(m.get("copy_cwy_step").unwrap()).unwrap();
        let b = m.load_state(m.get("copy_hr_step").unwrap()).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
        // tcwy's reflection block is square.
        let t = m.load_state(m.get("copy_tcwy_step").unwrap()).unwrap();
        assert_eq!(t[0].shape, vec![COPY_N, COPY_N]);
    }

    #[test]
    fn temp_dirs_are_unique_and_cleaned() {
        let a = TempDir::new("uniq").unwrap();
        let b = TempDir::new("uniq").unwrap();
        assert_ne!(a.path(), b.path());
        let kept = a.path().to_path_buf();
        drop(a);
        assert!(!kept.exists());
        assert!(b.path().exists());
    }
}
