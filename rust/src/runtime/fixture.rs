//! Toy artifact fixture for the native backend (DESIGN.md §2.6).
//!
//! `make artifacts` needs the Python layer; the native backend does not.
//! This module writes a complete, self-contained artifacts directory —
//! `manifest.json` plus `state_bin` dumps in the §2.3 format — whose
//! entries name registered native ops, so the full execution path
//! (`Engine::open` → `load` → `Compiled::run`, trainer, data-parallel,
//! serve workers) runs for real with no Python and no PJRT bindings.
//!
//! Used by `rust/tests/integration_{runtime,trainer,serve}.rs`,
//! `examples/serve_bench`, and `cwy serve --backend native` when no
//! artifacts directory exists.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use crate::linalg::Matrix;
use crate::runtime::tensor::HostTensor;
use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// Reflection count of the forward/rollout artifacts.
pub const FWD_L: usize = 4;
/// Hidden width of the forward/rollout artifacts.
pub const FWD_N: usize = 12;
/// Rollout batch rows.
pub const FWD_B: usize = 2;
/// T-CWY frame: St(TCWY_N, TCWY_M).
pub const TCWY_M: usize = 3;
pub const TCWY_N: usize = 10;

/// Recurrent cell: L reflections over width N, fused batch B.
/// L != B on purpose — the serve per-row heuristic (DESIGN.md §6.2)
/// classifies V as worker-resident only because its leading dim differs
/// from the fused batch.
pub const CELL_L: usize = 6;
pub const CELL_N: usize = 12;
pub const CELL_B: usize = 4;

/// Linear-regression family: y = x W, W in (IN, OUT), batches of B rows.
pub const LINREG_IN: usize = 6;
pub const LINREG_OUT: usize = 3;
pub const LINREG_B: usize = 8;

/// The cell's recorded reflection parameters (state_bin tensor 0).
pub fn toy_cell_v0() -> Matrix {
    Matrix::random_normal(&mut Pcg32::seeded(2024), CELL_L, CELL_N, 1.0)
}

/// The cell's recorded initial hidden row: every fused row starts from
/// this vector, and fresh serve sessions inherit row 0 (§6.2).
/// Deliberately non-zero so tests can tell "state_bin was read" from
/// "fell back to zeros".
pub fn toy_cell_h0_row() -> Vec<f32> {
    vec![0.25; CELL_N]
}

/// Ground-truth teacher weights the linreg data is generated from.
pub fn linreg_teacher() -> Matrix {
    Matrix::random_normal(&mut Pcg32::seeded(77), LINREG_IN, LINREG_OUT, 1.0)
}

/// Noise-free data provider for the linreg family: fresh `x`, `y = x W*`
/// per call.  SGD from the recorded zero init drives the loss to ~0.
pub fn linreg_provider(seed: u64) -> impl FnMut() -> Vec<HostTensor> {
    let teacher = linreg_teacher();
    let mut rng = Pcg32::seeded(seed);
    move || {
        let x = Matrix::random_normal(&mut rng, LINREG_B, LINREG_IN, 1.0);
        let y = x.matmul(&teacher);
        vec![
            HostTensor::f32(vec![LINREG_B, LINREG_IN], x.data),
            HostTensor::f32(vec![LINREG_B, LINREG_OUT], y.data),
        ]
    }
}

/// Serialize tensors in the `state_bin` format (§2.3): per tensor,
/// little-endian `u64 count | f32 data...`, in state order.
pub fn state_bin_bytes(tensors: &[HostTensor]) -> Result<Vec<u8>> {
    let mut bytes = Vec::new();
    for t in tensors {
        let data = t.as_f32().context("state_bin tensors are f32")?;
        bytes.extend_from_slice(&(data.len() as u64).to_le_bytes());
        for &v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    Ok(bytes)
}

fn tensor_json(name: &str, shape: &[usize], kind: Option<&str>) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert(
        "shape".to_string(),
        Json::Arr(shape.iter().map(|&d| Json::Num(d as f64)).collect()),
    );
    m.insert("dtype".to_string(), Json::Str("float32".to_string()));
    if let Some(k) = kind {
        m.insert("kind".to_string(), Json::Str(k.to_string()));
    }
    Json::Obj(m)
}

struct Art {
    name: &'static str,
    kind: &'static str,
    inputs: Vec<Json>,
    outputs: Vec<Json>,
    state_bin: Option<&'static str>,
    meta: Vec<(&'static str, String)>,
}

impl Art {
    fn json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.to_string()));
        m.insert("file".to_string(), Json::Str(format!("{}.hlo.txt", self.name)));
        m.insert("kind".to_string(), Json::Str(self.kind.to_string()));
        m.insert("inputs".to_string(), Json::Arr(self.inputs.clone()));
        m.insert("outputs".to_string(), Json::Arr(self.outputs.clone()));
        if let Some(sb) = self.state_bin {
            m.insert("state_bin".to_string(), Json::Str(sb.to_string()));
        }
        let mut meta = std::collections::BTreeMap::new();
        for (k, v) in &self.meta {
            meta.insert(k.to_string(), Json::Str(v.clone()));
        }
        m.insert("meta".to_string(), Json::Obj(meta));
        Json::Obj(m)
    }
}

/// Write the toy artifacts directory: manifest + state bins.
///
/// Artifact inventory (all executable natively except `hlo_only`, which
/// exists to exercise the "needs PJRT" error path):
///
/// * `param_cwy` / `param_hr` — V → Q, the Thm 2 pair;
/// * `stiefel_tcwy` — V → Ω on St(N, M);
/// * `rollout_cwy` / `rollout_hr` — (V, H) → H Q, the Fig. 2 pair;
/// * `toy_cell_step` — recurrent CWY cell with recorded initial state;
/// * `linreg_{step,grad,apply,eval}` — fused SGD family for the trainer
///   and data-parallel suites, zero-initialized weights;
/// * `hlo_only` — no `meta.op`.
pub fn write_toy_artifacts(dir: &Path) -> Result<()> {
    fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;

    let arts = vec![
        Art {
            name: "param_cwy",
            kind: "micro",
            inputs: vec![tensor_json("v", &[FWD_L, FWD_N], None)],
            outputs: vec![tensor_json("q", &[FWD_N, FWD_N], None)],
            state_bin: None,
            meta: vec![("op", "cwy".into()), ("method", "cwy".into())],
        },
        Art {
            name: "param_hr",
            kind: "micro",
            inputs: vec![tensor_json("v", &[FWD_L, FWD_N], None)],
            outputs: vec![tensor_json("q", &[FWD_N, FWD_N], None)],
            state_bin: None,
            meta: vec![("op", "hr".into()), ("method", "hr".into())],
        },
        Art {
            name: "stiefel_tcwy",
            kind: "micro",
            inputs: vec![tensor_json("v", &[TCWY_M, TCWY_N], None)],
            outputs: vec![tensor_json("omega", &[TCWY_N, TCWY_M], None)],
            state_bin: None,
            meta: vec![("op", "tcwy".into()), ("method", "tcwy".into())],
        },
        Art {
            name: "rollout_cwy",
            kind: "micro",
            inputs: vec![
                tensor_json("v", &[FWD_L, FWD_N], None),
                tensor_json("h", &[FWD_B, FWD_N], None),
            ],
            outputs: vec![tensor_json("out", &[FWD_B, FWD_N], None)],
            state_bin: None,
            meta: vec![("op", "rollout_cwy".into())],
        },
        Art {
            name: "rollout_hr",
            kind: "micro",
            inputs: vec![
                tensor_json("v", &[FWD_L, FWD_N], None),
                tensor_json("h", &[FWD_B, FWD_N], None),
            ],
            outputs: vec![tensor_json("out", &[FWD_B, FWD_N], None)],
            state_bin: None,
            meta: vec![("op", "rollout_hr".into())],
        },
        Art {
            name: "toy_cell_step",
            kind: "step",
            inputs: vec![
                tensor_json("v", &[CELL_L, CELL_N], Some("state")),
                tensor_json("h", &[CELL_B, CELL_N], Some("state")),
                tensor_json("x", &[CELL_B, CELL_N], None),
                tensor_json("lr", &[], Some("hyper")),
            ],
            outputs: vec![
                tensor_json("v", &[CELL_L, CELL_N], None),
                tensor_json("h", &[CELL_B, CELL_N], None),
                tensor_json("y", &[CELL_B, CELL_N], None),
            ],
            state_bin: Some("toy_cell.state.bin"),
            meta: vec![
                ("op", "cell_cwy".into()),
                ("task", "toy_cell".into()),
                ("batch", CELL_B.to_string()),
            ],
        },
        Art {
            name: "linreg_step",
            kind: "step",
            inputs: vec![
                tensor_json("w", &[LINREG_IN, LINREG_OUT], Some("state")),
                tensor_json("x", &[LINREG_B, LINREG_IN], None),
                tensor_json("y", &[LINREG_B, LINREG_OUT], None),
                tensor_json("lr", &[], Some("hyper")),
            ],
            outputs: vec![
                tensor_json("w", &[LINREG_IN, LINREG_OUT], None),
                tensor_json("loss", &[], None),
            ],
            state_bin: Some("linreg.state.bin"),
            meta: vec![
                ("op", "linreg_step".into()),
                ("task", "linreg".into()),
                ("batch", LINREG_B.to_string()),
                ("n_params", "1".into()),
            ],
        },
        Art {
            name: "linreg_grad",
            kind: "grad",
            inputs: vec![
                tensor_json("w", &[LINREG_IN, LINREG_OUT], Some("state")),
                tensor_json("x", &[LINREG_B, LINREG_IN], None),
                tensor_json("y", &[LINREG_B, LINREG_OUT], None),
            ],
            outputs: vec![
                tensor_json("g", &[LINREG_IN, LINREG_OUT], None),
                tensor_json("loss", &[], None),
            ],
            state_bin: None,
            meta: vec![("op", "linreg_grad".into()), ("n_params", "1".into())],
        },
        Art {
            name: "linreg_apply",
            kind: "apply",
            inputs: vec![
                tensor_json("w", &[LINREG_IN, LINREG_OUT], Some("state")),
                tensor_json("g", &[LINREG_IN, LINREG_OUT], None),
                tensor_json("lr", &[], Some("hyper")),
            ],
            outputs: vec![tensor_json("w", &[LINREG_IN, LINREG_OUT], None)],
            state_bin: None,
            meta: vec![("op", "linreg_apply".into())],
        },
        Art {
            name: "linreg_eval",
            kind: "eval",
            inputs: vec![
                tensor_json("w", &[LINREG_IN, LINREG_OUT], None),
                tensor_json("x", &[LINREG_B, LINREG_IN], None),
                tensor_json("y", &[LINREG_B, LINREG_OUT], None),
            ],
            outputs: vec![tensor_json("loss", &[], None)],
            state_bin: None,
            meta: vec![("op", "linreg_eval".into())],
        },
        Art {
            name: "hlo_only",
            kind: "micro",
            inputs: vec![tensor_json("x", &[2, 2], None)],
            outputs: vec![tensor_json("y", &[2, 2], None)],
            state_bin: None,
            meta: vec![],
        },
    ];

    let manifest = {
        let mut m = std::collections::BTreeMap::new();
        m.insert(
            "artifacts".to_string(),
            Json::Arr(arts.iter().map(|a| a.json()).collect()),
        );
        Json::Obj(m)
    };
    fs::write(dir.join("manifest.json"), manifest.dump())
        .context("writing manifest.json")?;

    // toy_cell_step state: V0 then h0 (every row = the recorded row).
    let v0 = toy_cell_v0();
    let h0: Vec<f32> = (0..CELL_B).flat_map(|_| toy_cell_h0_row()).collect();
    let cell_state = [
        HostTensor::f32(vec![CELL_L, CELL_N], v0.data),
        HostTensor::f32(vec![CELL_B, CELL_N], h0),
    ];
    fs::write(dir.join("toy_cell.state.bin"), state_bin_bytes(&cell_state)?)
        .context("writing toy_cell.state.bin")?;

    // linreg state: W0 = 0 (the teacher is deliberately not the init).
    let w0 = [HostTensor::f32(
        vec![LINREG_IN, LINREG_OUT],
        vec![0.0; LINREG_IN * LINREG_OUT],
    )];
    fs::write(dir.join("linreg.state.bin"), state_bin_bytes(&w0)?)
        .context("writing linreg.state.bin")?;

    Ok(())
}

static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Self-cleaning unique temp directory (no tempfile crate vendored).
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(tag: &str) -> Result<TempDir> {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos())
            .unwrap_or(0);
        let path = std::env::temp_dir().join(format!(
            "cwy-{tag}-{}-{}-{nanos}",
            std::process::id(),
            TEMP_SEQ.fetch_add(1, Ordering::Relaxed),
        ));
        fs::create_dir_all(&path)
            .with_context(|| format!("creating {}", path.display()))?;
        Ok(TempDir { path })
    }

    /// Create a temp directory already populated by [`write_toy_artifacts`].
    pub fn with_toy_artifacts(tag: &str) -> Result<TempDir> {
        let dir = TempDir::new(tag)?;
        write_toy_artifacts(dir.path())?;
        Ok(dir)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    #[test]
    fn fixture_round_trips_through_manifest_loader() {
        let dir = TempDir::with_toy_artifacts("fixture-test").unwrap();
        let m = Manifest::load(dir.path()).unwrap();
        assert!(m.artifacts.len() >= 10);
        let cell = m.get("toy_cell_step").unwrap();
        assert_eq!(cell.n_state(), 2);
        assert_eq!(cell.n_data(), 1);
        assert!(cell.has_lr());
        let state = m.load_state(cell).unwrap();
        assert_eq!(state.len(), 2);
        assert_eq!(state[0].shape, vec![CELL_L, CELL_N]);
        assert_eq!(state[1].shape, vec![CELL_B, CELL_N]);
        assert_eq!(state[1].as_f32().unwrap()[0], 0.25);
        let lin = m.get("linreg_step").unwrap();
        assert_eq!(m.load_state(lin).unwrap()[0].len(), LINREG_IN * LINREG_OUT);
    }

    #[test]
    fn temp_dirs_are_unique_and_cleaned() {
        let a = TempDir::new("uniq").unwrap();
        let b = TempDir::new("uniq").unwrap();
        assert_ne!(a.path(), b.path());
        let kept = a.path().to_path_buf();
        drop(a);
        assert!(!kept.exists());
        assert!(b.path().exists());
    }
}
