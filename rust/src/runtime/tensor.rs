//! Host-side tensors marshalled in and out of PJRT literals.

use anyhow::{bail, Result};

/// Element type of a host tensor (the artifact pipeline emits f32/i32 only).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "float32" | "f32" => Ok(Dtype::F32),
            "int32" | "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

/// Tensor payload.
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host tensor: shape + typed buffer.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data: Data::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data: Data::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::f32(vec![], vec![v])
    }

    pub fn zeros(shape: Vec<usize>, dtype: Dtype) -> HostTensor {
        let n = shape.iter().product();
        match dtype {
            Dtype::F32 => HostTensor::f32(shape, vec![0.0; n]),
            Dtype::I32 => HostTensor::i32(shape, vec![0; n]),
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> Dtype {
        match self.data {
            Data::F32(_) => Dtype::F32,
            Data::I32(_) => Dtype::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            Data::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    /// Rows `start..start+count` along the leading axis as a new tensor
    /// (used by batch sharding and the serve row scatter).
    pub fn slice_rows(&self, start: usize, count: usize) -> Result<HostTensor> {
        if self.shape.is_empty() {
            bail!("cannot slice a scalar by rows");
        }
        let rows = self.shape[0];
        if start + count > rows {
            bail!("rows {start}..{} out of bounds (leading dim {rows})", start + count);
        }
        let row_len: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = count;
        let (a, b) = (start * row_len, (start + count) * row_len);
        Ok(match &self.data {
            Data::F32(v) => HostTensor::f32(shape, v[a..b].to_vec()),
            Data::I32(v) => HostTensor::i32(shape, v[a..b].to_vec()),
        })
    }

    /// Scalar extraction (loss / metric outputs).
    pub fn scalar(&self) -> Result<f32> {
        match &self.data {
            Data::F32(v) if v.len() == 1 => Ok(v[0]),
            Data::I32(v) if v.len() == 1 => Ok(v[0] as f32),
            _ => bail!("tensor is not a scalar (len={})", self.len()),
        }
    }

    /// Convert to a PJRT literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            Data::F32(v) => {
                if dims.is_empty() {
                    return Ok(xla::Literal::scalar(v[0]));
                }
                xla::Literal::vec1(v).reshape(&dims)?
            }
            Data::I32(v) => {
                if dims.is_empty() {
                    return Ok(xla::Literal::scalar(v[0]));
                }
                xla::Literal::vec1(v).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    /// Read back from a PJRT literal given the expected shape/dtype.
    pub fn from_literal(lit: &xla::Literal, shape: &[usize], dtype: Dtype) -> Result<HostTensor> {
        Ok(match dtype {
            Dtype::F32 => HostTensor::f32(shape.to_vec(), lit.to_vec::<f32>()?),
            Dtype::I32 => HostTensor::i32(shape.to_vec(), lit.to_vec::<i32>()?),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = HostTensor::f32(vec![2, 3], vec![1.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), Dtype::F32);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar_f32(2.5);
        assert_eq!(t.scalar().unwrap(), 2.5);
        assert!(HostTensor::f32(vec![2], vec![0.0; 2]).scalar().is_err());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("float32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("int32").unwrap(), Dtype::I32);
        assert!(Dtype::parse("bfloat16").is_err());
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn slice_rows_bounds_and_content() {
        let t = HostTensor::i32(vec![3, 2], vec![1, 2, 3, 4, 5, 6]);
        let mid = t.slice_rows(1, 2).unwrap();
        assert_eq!(mid.shape, vec![2, 2]);
        assert_eq!(mid.as_i32().unwrap(), &[3, 4, 5, 6]);
        assert!(t.slice_rows(2, 2).is_err());
        assert!(HostTensor::scalar_f32(1.0).slice_rows(0, 1).is_err());
    }
}
