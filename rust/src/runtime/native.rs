//! Native CPU execution backend (DESIGN.md §2.6, §3.1).
//!
//! The PJRT path executes HLO text through the `xla` crate; when those
//! bindings are the offline stub, nothing downstream of `Engine::open`
//! used to run.  This module closes that gap: the paper's point is that
//! the CWY/T-CWY parametrizations reduce to a handful of fused matmuls,
//! which is exactly the computation `linalg` + `orthogonal` already
//! implement — cheap enough to evaluate directly on the CPU without an
//! external compiler stack.
//!
//! A native artifact is a manifest entry whose `meta.op` names one of
//! the registered ops below.  `NativeExec::compile` resolves the op and
//! validates the manifest signature against the op's contract (the
//! native analogue of an XLA compile error); `run` then executes the
//! artifact contract — shapes, calling convention, `state_bin` initial
//! state — identically to the PJRT path, so `Trainer`, `DataParallel`,
//! and the serve worker pool run unchanged on either backend.
//!
//! Registered ops:
//!
//! | `meta.op`      | kind  | signature (roles)                              | computation |
//! |----------------|-------|------------------------------------------------|-------------|
//! | `cwy`          | micro | V `[l,n]` → Q `[n,n]`                          | Thm 2: `I - U S^-1 U^T` |
//! | `hr`           | micro | V `[l,n]` → Q `[n,n]`                          | sequential Householder product |
//! | `tcwy`         | micro | V `[m,n]` → Ω `[n,m]`                          | Thm 3 Stiefel frame |
//! | `rollout_cwy`  | micro | V `[l,n]`, H `[b,n]` → `[b,n]`                 | fused `H @ Q` |
//! | `rollout_hr`   | micro | V `[l,n]`, H `[b,n]` → `[b,n]`                 | sequential reflection chain |
//! | `cell_cwy`     | step  | V `[l,n]` state, h `[b,n]` state, x `[b,n]` data, lr hyper → V', h', y | `h' = h Q(V) + x`, `y = h'` |
//! | `cell_hr`      | step  | same as `cell_cwy`                             | same recurrence, HR chain |
//! | `cell_tcwy`    | step  | V `[m,n]` state, h `[b,m]` state, x `[b,n]` data, lr hyper → V', h', y | `h' = h + x Ω(V)`, `y = h'` |
//! | `linreg_step`  | step  | W `[k,m]` state, x `[b,k]`, y `[b,m]` data, lr hyper → W', loss | fused SGD: `W - lr · ∇` |
//! | `linreg_grad`  | grad  | W, x, y → ∇ `[k,m]`, loss                      | per-shard gradient |
//! | `linreg_apply` | apply | W state, ∇ data, lr hyper → W'                 | all-reduced update |
//! | `linreg_eval`  | eval  | W, x, y → loss                                 | pure forward |
//!
//! The recurrent cells treat V as frozen parameters (`V' = V`): serving
//! runs step artifacts with `lr = 0` by convention (DESIGN.md §6.2), and
//! the SGD path proper is exercised by the `linreg_*` family, whose
//! gradient is exact.

use anyhow::{anyhow, bail, Result};

use crate::linalg::Matrix;
use crate::orthogonal::{cwy, householder, tcwy};
use crate::runtime::manifest::{ArtifactSpec, Role, TensorSpec};
use crate::runtime::tensor::{Dtype, HostTensor};

/// Manifest meta key naming the registered native op.
pub const OP_META_KEY: &str = "op";

/// Which orthogonal construction a recurrent cell uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CellKind {
    Cwy,
    Hr,
    Tcwy,
}

/// A registered native computation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NativeOp {
    CwyMatrix,
    HrMatrix,
    TcwyMatrix,
    RolloutCwy,
    RolloutHr,
    Cell(CellKind),
    LinregStep,
    LinregGrad,
    LinregApply,
    LinregEval,
}

impl NativeOp {
    pub fn parse(s: &str) -> Option<NativeOp> {
        Some(match s {
            "cwy" => NativeOp::CwyMatrix,
            "hr" => NativeOp::HrMatrix,
            "tcwy" => NativeOp::TcwyMatrix,
            "rollout_cwy" => NativeOp::RolloutCwy,
            "rollout_hr" => NativeOp::RolloutHr,
            "cell_cwy" => NativeOp::Cell(CellKind::Cwy),
            "cell_hr" => NativeOp::Cell(CellKind::Hr),
            "cell_tcwy" => NativeOp::Cell(CellKind::Tcwy),
            "linreg_step" => NativeOp::LinregStep,
            "linreg_grad" => NativeOp::LinregGrad,
            "linreg_apply" => NativeOp::LinregApply,
            "linreg_eval" => NativeOp::LinregEval,
            _ => return None,
        })
    }
}

/// A "compiled" native artifact: the resolved op, signature-checked
/// against the manifest entry.
pub struct NativeExec {
    op: NativeOp,
}

impl NativeExec {
    /// Resolve `meta.op` and validate the artifact signature against the
    /// op's contract.  Errors here mirror XLA compile-time failures.
    pub fn compile(spec: &ArtifactSpec) -> Result<NativeExec> {
        let op_str = spec.meta_str(OP_META_KEY).ok_or_else(|| {
            anyhow!(
                "{}: no '{}' meta key — the native backend executes registered ops, \
                 not HLO text; this artifact needs the PJRT backend (DESIGN.md §2.6)",
                spec.name,
                OP_META_KEY
            )
        })?;
        let op = NativeOp::parse(op_str).ok_or_else(|| {
            anyhow!("{}: unknown native op '{op_str}'", spec.name)
        })?;
        validate(spec, op).map_err(|e| anyhow!("{}: bad native signature: {e:#}", spec.name))?;
        Ok(NativeExec { op })
    }

    pub fn op(&self) -> NativeOp {
        self.op
    }

    /// Execute one artifact call.  `inputs` are already checked against
    /// the manifest shapes/dtypes by `Compiled::run_refs`.
    pub fn run(&self, spec: &ArtifactSpec, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        match self.op {
            NativeOp::CwyMatrix => {
                let v = mat(inputs[0])?;
                Ok(vec![tensor(cwy::matrix(&v))])
            }
            NativeOp::HrMatrix => {
                let v = mat(inputs[0])?;
                Ok(vec![tensor(householder::matrix(&v))])
            }
            NativeOp::TcwyMatrix => {
                let v = mat(inputs[0])?;
                Ok(vec![tensor(tcwy::matrix(&v))])
            }
            NativeOp::RolloutCwy => {
                let v = mat(inputs[0])?;
                let h = mat(inputs[1])?;
                Ok(vec![tensor(cwy::CwyOperator::new(&v).apply(&h))])
            }
            NativeOp::RolloutHr => {
                let v = mat(inputs[0])?;
                let mut h = mat(inputs[1])?;
                householder::apply_chain(&v, &mut h);
                Ok(vec![tensor(h)])
            }
            NativeOp::Cell(kind) => {
                let v = mat(inputs[0])?;
                let h = mat(inputs[1])?;
                let x = mat(inputs[2])?;
                let h_next = match kind {
                    CellKind::Cwy => cwy::CwyOperator::new(&v).apply(&h).add(&x),
                    CellKind::Hr => {
                        let mut rotated = h;
                        householder::apply_chain(&v, &mut rotated);
                        rotated.add(&x)
                    }
                    CellKind::Tcwy => h.add(&x.matmul(&tcwy::matrix(&v))),
                };
                // V is frozen (see module docs); state outputs come first,
                // in state-input order, per the step convention (§2.2).
                Ok(vec![inputs[0].clone(), tensor(h_next.clone()), tensor(h_next)])
            }
            NativeOp::LinregStep => {
                let w = mat(inputs[0])?;
                let x = mat(inputs[1])?;
                let y = mat(inputs[2])?;
                let lr = inputs[3].scalar()?;
                let (resid, loss) = linreg_forward(&w, &x, &y);
                let grad = linreg_gradient(&x, &resid);
                let w_next = w.sub(&grad.scale(lr));
                Ok(vec![tensor(w_next), HostTensor::scalar_f32(loss)])
            }
            NativeOp::LinregGrad => {
                let w = mat(inputs[0])?;
                let x = mat(inputs[1])?;
                let y = mat(inputs[2])?;
                let (resid, loss) = linreg_forward(&w, &x, &y);
                Ok(vec![tensor(linreg_gradient(&x, &resid)), HostTensor::scalar_f32(loss)])
            }
            NativeOp::LinregApply => {
                let w = mat(inputs[0])?;
                let g = mat(inputs[1])?;
                let lr = inputs[2].scalar()?;
                Ok(vec![tensor(w.sub(&g.scale(lr)))])
            }
            NativeOp::LinregEval => {
                let w = mat(inputs[0])?;
                let x = mat(inputs[1])?;
                let y = mat(inputs[2])?;
                let (_, loss) = linreg_forward(&w, &x, &y);
                Ok(vec![HostTensor::scalar_f32(loss)])
            }
        }
        .map_err(|e: anyhow::Error| anyhow!("{} (native {:?}): {e:#}", spec.name, self.op))
    }
}

/// Mean-squared-error forward pass: residual `xW - y` and scalar loss.
fn linreg_forward(w: &Matrix, x: &Matrix, y: &Matrix) -> (Matrix, f32) {
    let resid = x.matmul(w).sub(y);
    let b = x.rows.max(1) as f32;
    let loss = resid.data.iter().map(|r| r * r).sum::<f32>() / b;
    (resid, loss)
}

/// Exact MSE gradient: `(2 / b) x^T (xW - y)`.
fn linreg_gradient(x: &Matrix, resid: &Matrix) -> Matrix {
    let b = x.rows.max(1) as f32;
    x.t().matmul(resid).scale(2.0 / b)
}

fn mat(t: &HostTensor) -> Result<Matrix> {
    if t.shape.len() != 2 {
        bail!("expected a rank-2 tensor, got shape {:?}", t.shape);
    }
    Ok(Matrix::from_rows(t.shape[0], t.shape[1], t.as_f32()?.to_vec()))
}

fn tensor(m: Matrix) -> HostTensor {
    HostTensor::f32(vec![m.rows, m.cols], m.data)
}

fn dims2(ts: &TensorSpec) -> Result<(usize, usize)> {
    if ts.shape.len() != 2 {
        bail!("port '{}': expected rank 2, got shape {:?}", ts.name, ts.shape);
    }
    Ok((ts.shape[0], ts.shape[1]))
}

fn expect_shape(ts: &TensorSpec, want: &[usize]) -> Result<()> {
    if ts.shape != want {
        bail!("port '{}': shape {:?}, op expects {:?}", ts.name, ts.shape, want);
    }
    Ok(())
}

fn expect_arity(spec: &ArtifactSpec, inputs: usize, outputs: usize) -> Result<()> {
    if spec.inputs.len() != inputs {
        bail!("op takes {inputs} inputs, manifest lists {}", spec.inputs.len());
    }
    if spec.outputs.len() != outputs {
        bail!("op yields {outputs} outputs, manifest lists {}", spec.outputs.len());
    }
    for ts in spec.inputs.iter().chain(&spec.outputs) {
        if ts.dtype != Dtype::F32 {
            bail!("port '{}': native ops are f32-only", ts.name);
        }
    }
    Ok(())
}

fn expect_roles(spec: &ArtifactSpec, roles: &[Role]) -> Result<()> {
    for (ts, want) in spec.inputs.iter().zip(roles) {
        if ts.role != *want {
            bail!("port '{}': role {:?}, op expects {:?}", ts.name, ts.role, want);
        }
    }
    Ok(())
}

/// Check the manifest signature against the op contract (shapes must be
/// mutually consistent; the actual numbers are the manifest's choice).
fn validate(spec: &ArtifactSpec, op: NativeOp) -> Result<()> {
    match op {
        NativeOp::CwyMatrix | NativeOp::HrMatrix => {
            expect_arity(spec, 1, 1)?;
            let (_, n) = dims2(&spec.inputs[0])?;
            expect_shape(&spec.outputs[0], &[n, n])
        }
        NativeOp::TcwyMatrix => {
            expect_arity(spec, 1, 1)?;
            let (m, n) = dims2(&spec.inputs[0])?;
            if m > n {
                bail!("T-CWY needs M <= N, got V {:?}", spec.inputs[0].shape);
            }
            expect_shape(&spec.outputs[0], &[n, m])
        }
        NativeOp::RolloutCwy | NativeOp::RolloutHr => {
            expect_arity(spec, 2, 1)?;
            let (_, n) = dims2(&spec.inputs[0])?;
            let (b, n2) = dims2(&spec.inputs[1])?;
            if n2 != n {
                bail!("V cols {n} != H cols {n2}");
            }
            expect_shape(&spec.outputs[0], &[b, n])
        }
        NativeOp::Cell(kind) => {
            expect_arity(spec, 4, 3)?;
            expect_roles(spec, &[Role::State, Role::State, Role::Data, Role::Hyper])?;
            let (l, n) = dims2(&spec.inputs[0])?;
            let (b, hn) = dims2(&spec.inputs[1])?;
            let (bx, xn) = dims2(&spec.inputs[2])?;
            if bx != b {
                bail!("h rows {b} != x rows {bx}");
            }
            let h_cols = match kind {
                CellKind::Cwy | CellKind::Hr => n,
                CellKind::Tcwy => {
                    if l > n {
                        bail!("T-CWY cell needs M <= N, got V {:?}", spec.inputs[0].shape);
                    }
                    l
                }
            };
            if hn != h_cols {
                bail!("h cols {hn}, cell expects {h_cols}");
            }
            if xn != n {
                bail!("x cols {xn}, cell expects {n}");
            }
            expect_shape(&spec.outputs[0], &[l, n])?;
            expect_shape(&spec.outputs[1], &[b, hn])?;
            expect_shape(&spec.outputs[2], &[b, hn])
        }
        NativeOp::LinregStep => {
            expect_arity(spec, 4, 2)?;
            expect_roles(spec, &[Role::State, Role::Data, Role::Data, Role::Hyper])?;
            validate_linreg_core(spec)?;
            let (k, m) = dims2(&spec.inputs[0])?;
            expect_shape(&spec.outputs[0], &[k, m])?;
            expect_shape(&spec.outputs[1], &[])
        }
        NativeOp::LinregGrad => {
            expect_arity(spec, 3, 2)?;
            expect_roles(spec, &[Role::State, Role::Data, Role::Data])?;
            validate_linreg_core(spec)?;
            let (k, m) = dims2(&spec.inputs[0])?;
            expect_shape(&spec.outputs[0], &[k, m])?;
            expect_shape(&spec.outputs[1], &[])
        }
        NativeOp::LinregApply => {
            expect_arity(spec, 3, 1)?;
            expect_roles(spec, &[Role::State, Role::Data, Role::Hyper])?;
            let (k, m) = dims2(&spec.inputs[0])?;
            expect_shape(&spec.inputs[1], &[k, m])?;
            expect_shape(&spec.inputs[2], &[])?;
            expect_shape(&spec.outputs[0], &[k, m])
        }
        NativeOp::LinregEval => {
            expect_arity(spec, 3, 1)?;
            // Eval artifacts are pure functions of (params..., data...)
            // (§2.2): every input is data, nothing persists.
            expect_roles(spec, &[Role::Data, Role::Data, Role::Data])?;
            validate_linreg_core(spec)?;
            expect_shape(&spec.outputs[0], &[])
        }
    }
}

/// Shared (W, x, y) consistency for the linreg family.
fn validate_linreg_core(spec: &ArtifactSpec) -> Result<()> {
    let (k, m) = dims2(&spec.inputs[0])?;
    let (b, xk) = dims2(&spec.inputs[1])?;
    let (by, ym) = dims2(&spec.inputs[2])?;
    if xk != k {
        bail!("x cols {xk} != W rows {k}");
    }
    if by != b {
        bail!("x rows {b} != y rows {by}");
    }
    if ym != m {
        bail!("y cols {ym} != W cols {m}");
    }
    if spec.inputs.len() == 4 {
        expect_shape(&spec.inputs[3], &[])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use crate::util::prop::assert_close;
    use crate::util::rng::Pcg32;
    use std::path::PathBuf;

    fn manifest(extra: &str) -> Manifest {
        Manifest::parse_str(
            &format!(r#"{{"artifacts":[{extra}]}}"#),
            PathBuf::from("/tmp"),
        )
        .unwrap()
    }

    const CWY_ART: &str = r#"{"name":"q","file":"q.hlo","kind":"micro",
        "inputs":[{"name":"v","shape":[3,8],"dtype":"float32"}],
        "outputs":[{"name":"q","shape":[8,8],"dtype":"float32"}],
        "meta":{"op":"cwy"}}"#;

    #[test]
    fn compile_resolves_and_validates() {
        let m = manifest(CWY_ART);
        let exec = NativeExec::compile(m.get("q").unwrap()).unwrap();
        assert_eq!(exec.op(), NativeOp::CwyMatrix);
    }

    #[test]
    fn compile_rejects_missing_and_unknown_ops() {
        let m = manifest(
            r#"{"name":"a","file":"a.hlo","kind":"micro",
               "inputs":[],"outputs":[],"meta":{}},
              {"name":"b","file":"b.hlo","kind":"micro",
               "inputs":[],"outputs":[],"meta":{"op":"warp_drive"}}"#,
        );
        let err = NativeExec::compile(m.get("a").unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("no 'op' meta"), "{err:#}");
        let err = NativeExec::compile(m.get("b").unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("unknown native op"), "{err:#}");
    }

    #[test]
    fn compile_rejects_inconsistent_shapes() {
        let m = manifest(
            r#"{"name":"q","file":"q.hlo","kind":"micro",
               "inputs":[{"name":"v","shape":[3,8],"dtype":"float32"}],
               "outputs":[{"name":"q","shape":[7,7],"dtype":"float32"}],
               "meta":{"op":"cwy"}}"#,
        );
        assert!(NativeExec::compile(m.get("q").unwrap()).is_err());
    }

    #[test]
    fn cwy_op_matches_native_construction() {
        let m = manifest(CWY_ART);
        let spec = m.get("q").unwrap();
        let exec = NativeExec::compile(spec).unwrap();
        let mut rng = Pcg32::seeded(11);
        let v = Matrix::random_normal(&mut rng, 3, 8, 1.0);
        let vt = tensor(v.clone());
        let out = exec.run(spec, &[&vt]).unwrap();
        assert_eq!(out[0].shape, vec![8, 8]);
        assert_close(out[0].as_f32().unwrap(), &cwy::matrix(&v).data, 1e-6).unwrap();
    }

    #[test]
    fn linreg_step_descends() {
        let m = manifest(
            r#"{"name":"s","file":"s.hlo","kind":"step",
               "inputs":[{"name":"w","shape":[4,2],"dtype":"float32","kind":"state"},
                         {"name":"x","shape":[8,4],"dtype":"float32"},
                         {"name":"y","shape":[8,2],"dtype":"float32"},
                         {"name":"lr","shape":[],"dtype":"float32","kind":"hyper"}],
               "outputs":[{"name":"w","shape":[4,2],"dtype":"float32"},
                          {"name":"loss","shape":[],"dtype":"float32"}],
               "meta":{"op":"linreg_step"}}"#,
        );
        let spec = m.get("s").unwrap();
        let exec = NativeExec::compile(spec).unwrap();
        let mut rng = Pcg32::seeded(3);
        let w_true = Matrix::random_normal(&mut rng, 4, 2, 1.0);
        let x = Matrix::random_normal(&mut rng, 8, 4, 1.0);
        let y = x.matmul(&w_true);
        let mut w = HostTensor::f32(vec![4, 2], vec![0.0; 8]);
        let (xt, yt) = (tensor(x), tensor(y));
        let lr = HostTensor::scalar_f32(0.05);
        let mut losses = Vec::new();
        for _ in 0..60 {
            let out = exec.run(spec, &[&w, &xt, &yt, &lr]).unwrap();
            losses.push(out[1].scalar().unwrap());
            w = out[0].clone();
        }
        assert!(losses[0] > 0.1, "first loss {} too small to mean anything", losses[0]);
        assert!(
            *losses.last().unwrap() < losses[0] * 0.01,
            "no descent: {} -> {}",
            losses[0],
            losses.last().unwrap()
        );
    }
}
