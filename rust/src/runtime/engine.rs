//! Execution engine: load manifest artifacts, compile once, execute from
//! the rust hot path — on either side of the backend seam (DESIGN.md §2.6).
//!
//! Two backends implement the same artifact contract behind [`Compiled`]:
//!
//! * **PJRT** — parse the HLO text and hand it to the `xla` crate
//!   (adapted from /opt/xla-example/load_hlo).  Requires the real PJRT
//!   bindings; with the offline stub, client construction errors.
//! * **Native** — interpret the artifact's registered `meta.op` directly
//!   in Rust ([`crate::runtime::native`]), built on `linalg`/`orthogonal`.
//!
//! [`Backend::Auto`] (the default) prefers PJRT and falls back to native
//! when the bindings are unavailable, so the trainer, serve workers, and
//! CLI run end-to-end in every environment.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::native::NativeExec;
use crate::runtime::tensor::HostTensor;

/// Which execution backend an [`Engine`] opens (DESIGN.md §2.6).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// Prefer PJRT, fall back to native when the bindings are the stub.
    #[default]
    Auto,
    /// Interpret registered native ops in Rust; never touches PJRT.
    Native,
    /// Require the real PJRT bindings; error when they are unavailable.
    Pjrt,
}

impl Backend {
    /// Parse a CLI flag value (`auto|native|pjrt`).
    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "auto" => Ok(Backend::Auto),
            "native" => Ok(Backend::Native),
            "pjrt" => Ok(Backend::Pjrt),
            other => bail!("unknown backend '{other}' (expected auto|native|pjrt)"),
        }
    }
}

/// Backend-specific executable for one artifact.
enum Exec {
    Pjrt(xla::PjRtLoadedExecutable),
    Native(NativeExec),
}

/// A compiled artifact bound to its manifest spec.
pub struct Compiled {
    pub spec: ArtifactSpec,
    exec: Exec,
}

impl Compiled {
    /// Execute with host tensors; returns outputs in manifest order.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    /// Execute from borrowed tensors — the trainer hot path uses this to
    /// avoid cloning the whole state vector every step (EXPERIMENTS.md
    /// §Perf records the before/after).
    pub fn run_refs(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, artifact expects {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape != s.shape {
                bail!(
                    "{}: input '{}' shape {:?} != manifest {:?}",
                    self.spec.name,
                    s.name,
                    t.shape,
                    s.shape
                );
            }
            if t.dtype() != s.dtype {
                bail!(
                    "{}: input '{}' dtype {:?} != manifest {:?}",
                    self.spec.name,
                    s.name,
                    t.dtype(),
                    s.dtype
                );
            }
        }
        match &self.exec {
            Exec::Pjrt(exe) => self.run_pjrt(exe, inputs),
            Exec::Native(exec) => {
                let outputs = exec.run(&self.spec, inputs)?;
                if outputs.len() != self.spec.outputs.len() {
                    bail!(
                        "{}: native op yielded {} outputs, manifest says {}",
                        self.spec.name,
                        outputs.len(),
                        self.spec.outputs.len()
                    );
                }
                Ok(outputs)
            }
        }
    }

    fn run_pjrt(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let bufs = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("{}: execute failed: {e}", self.spec.name))?;
        let mut tup = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: readback failed: {e}", self.spec.name))?;
        let parts = tup
            .decompose_tuple()
            .map_err(|e| anyhow!("{}: decompose failed: {e}", self.spec.name))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, s)| HostTensor::from_literal(lit, &s.shape, s.dtype))
            .collect()
    }
}

/// Resolved backend client: a PJRT device or the in-process interpreter.
enum Client {
    Pjrt(xla::PjRtClient),
    Native,
}

/// Engine: one backend client + an executable cache over the manifest.
pub struct Engine {
    pub manifest: Manifest,
    client: Client,
    cache: RefCell<HashMap<String, Rc<Compiled>>>,
}

impl Engine {
    /// Open the artifacts directory with backend auto-selection (PJRT
    /// when the real bindings are present, native otherwise); compiles
    /// lazily, caches per name.
    pub fn open(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        Self::open_with(dir, Backend::Auto)
    }

    /// Open with an explicit backend choice.
    pub fn open_with(dir: impl AsRef<std::path::Path>, backend: Backend) -> Result<Engine> {
        let manifest = Manifest::load(&dir)?;
        let client = match backend {
            Backend::Pjrt => Client::Pjrt(
                xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?,
            ),
            Backend::Native => Client::Native,
            Backend::Auto => match xla::PjRtClient::cpu() {
                Ok(c) => Client::Pjrt(c),
                Err(_) => Client::Native,
            },
        };
        Ok(Engine { manifest, client, cache: RefCell::new(HashMap::new()) })
    }

    /// The backend this engine resolved to (never [`Backend::Auto`]).
    pub fn backend(&self) -> Backend {
        match self.client {
            Client::Pjrt(_) => Backend::Pjrt,
            Client::Native => Backend::Native,
        }
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Compiled>> {
        if let Some(c) = self.cache.borrow().get(name) {
            return Ok(c.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let exec = match &self.client {
            Client::Pjrt(client) => {
                let path = self.manifest.dir.join(&spec.file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                )
                .map_err(|e| anyhow!("{name}: parsing HLO text: {e}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("{name}: XLA compile: {e}"))?;
                Exec::Pjrt(exe)
            }
            Client::Native => Exec::Native(NativeExec::compile(&spec)?),
        };
        let compiled = Rc::new(Compiled { spec, exec });
        self.cache
            .borrow_mut()
            .insert(name.to_string(), compiled.clone());
        Ok(compiled)
    }

    /// Per-worker bootstrap for the serve pool (DESIGN.md §6.5).
    ///
    /// `Compiled` holds `Rc`/`RefCell` state and is not `Send`, so the
    /// serve subsystem shards by engine instance: every worker thread
    /// calls this once to get a private engine with the artifacts it will
    /// serve already compiled, then never shares either across threads.
    pub fn open_worker(
        dir: impl AsRef<std::path::Path>,
        artifacts: &[&str],
    ) -> Result<(Engine, Vec<Rc<Compiled>>)> {
        Self::open_worker_with(dir, Backend::Auto, artifacts)
    }

    /// [`Engine::open_worker`] with an explicit backend choice.
    pub fn open_worker_with(
        dir: impl AsRef<std::path::Path>,
        backend: Backend,
        artifacts: &[&str],
    ) -> Result<(Engine, Vec<Rc<Compiled>>)> {
        let engine = Engine::open_with(dir, backend)?;
        let compiled = artifacts
            .iter()
            .map(|name| engine.load(name))
            .collect::<Result<Vec<_>>>()?;
        Ok((engine, compiled))
    }

    /// Initial training state for a step artifact, from its state.bin.
    pub fn initial_state(&self, name: &str) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.get(name)?;
        self.manifest
            .load_state(spec)
            .with_context(|| format!("loading initial state for {name}"))
    }

    pub fn platform(&self) -> String {
        match &self.client {
            Client::Pjrt(c) => c.platform_name(),
            Client::Native => "native-cpu".to_string(),
        }
    }
}
