//! PJRT execution engine: load HLO text artifacts, compile once, execute
//! from the rust hot path.  Adapted from /opt/xla-example/load_hlo.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::tensor::HostTensor;

/// A compiled artifact bound to its manifest spec.
pub struct Compiled {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Compiled {
    /// Execute with host tensors; returns outputs in manifest order.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    /// Execute from borrowed tensors — the trainer hot path uses this to
    /// avoid cloning the whole state vector every step (EXPERIMENTS.md
    /// §Perf records the before/after).
    pub fn run_refs(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, artifact expects {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape != s.shape {
                bail!(
                    "{}: input '{}' shape {:?} != manifest {:?}",
                    self.spec.name,
                    s.name,
                    t.shape,
                    s.shape
                );
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let bufs = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("{}: execute failed: {e}", self.spec.name))?;
        let mut tup = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: readback failed: {e}", self.spec.name))?;
        let parts = tup
            .decompose_tuple()
            .map_err(|e| anyhow!("{}: decompose failed: {e}", self.spec.name))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, s)| HostTensor::from_literal(lit, &s.shape, s.dtype))
            .collect()
    }
}

/// Engine: one PJRT CPU client + an executable cache over the manifest.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Compiled>>>,
}

impl Engine {
    /// Open the artifacts directory (compiles lazily, caches per name).
    pub fn open(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Engine { manifest, client, cache: RefCell::new(HashMap::new()) })
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Compiled>> {
        if let Some(c) = self.cache.borrow().get(name) {
            return Ok(c.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("{name}: parsing HLO text: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("{name}: XLA compile: {e}"))?;
        let compiled = Rc::new(Compiled { spec, exe });
        self.cache
            .borrow_mut()
            .insert(name.to_string(), compiled.clone());
        Ok(compiled)
    }

    /// Per-worker bootstrap for the serve pool (DESIGN.md §6.5).
    ///
    /// `Compiled` holds `Rc`/`RefCell` state and is not `Send`, so the
    /// serve subsystem shards by engine instance: every worker thread
    /// calls this once to get a private engine with the artifacts it will
    /// serve already compiled, then never shares either across threads.
    pub fn open_worker(
        dir: impl AsRef<std::path::Path>,
        artifacts: &[&str],
    ) -> Result<(Engine, Vec<Rc<Compiled>>)> {
        let engine = Engine::open(dir)?;
        let compiled = artifacts
            .iter()
            .map(|name| engine.load(name))
            .collect::<Result<Vec<_>>>()?;
        Ok((engine, compiled))
    }

    /// Initial training state for a step artifact, from its state.bin.
    pub fn initial_state(&self, name: &str) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.get(name)?;
        self.manifest
            .load_state(spec)
            .with_context(|| format!("loading initial state for {name}"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
