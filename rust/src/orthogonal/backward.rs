//! Analytic backward passes for the CWY / T-CWY parametrizations and the
//! sequential Householder baseline — the native **backward substrate**
//! (DESIGN.md §3.2).
//!
//! The paper's claim (Thms 2–4) is about *training*: the CWY transform
//! makes the gradient of an orthogonal recurrence a handful of fused
//! matmuls instead of a length-L sequential chain.  This module implements
//! exactly that backward:
//!
//! * [`CwyGrad`] — gradient of `Y = H Q(V)` (and of `Q` itself) with
//!   respect to both `H` and the raw reflection rows `V`, back through
//!   `normalize`, `build_s`, and `triu_inv`.  Per-step cost is
//!   `O(B·N·L + N·L² + L³)` with no `N×N` intermediate — the fused
//!   counterpart of the forward operator.
//! * [`TcwyGrad`] — gradient of the Thm 3 Stiefel frame
//!   `Ω = [I;0] − U S⁻¹ U₁ᵀ` with respect to `V`.
//! * [`hr_chain_backward`] — backward through the sequential reflection
//!   chain (Mhammedi et al. 2017), inherently serial in L: the baseline
//!   the fused path is benched against (`benches/bptt_native.rs`).
//! * [`cwy_rollout_backward`] / [`hr_rollout_backward`] — BPTT through a
//!   T-step rollout `h_{t+1} = h_t Q + x_t` of the recurrent cell.
//!
//! Every matmul routes through [`crate::linalg::Matrix::matmul`], i.e. the
//! blocked GEMM hot path (§3.1), so the bench trajectory there covers
//! training as well as inference.  All formulas are verified against
//! central finite differences by the property tests below.
//!
//! Degenerate reflection rows (norm ≤ [`cwy::DEGENERATE_NORM`]) carry
//! **zero** gradient on every path — never NaN: the CWY chain maps them
//! to a constant canonical basis vector in `normalize`, and the HR chain
//! treats them as the identity reflection (forward and backward alike,
//! see [`householder`]).  The two parametrizations agree as functions
//! only on non-degenerate rows.

use crate::linalg::{triu_inv, Matrix};

use super::cwy::{self, build_s, normalize, CwyOperator};
use super::householder;

/// Shared backward context for the CWY-family parametrizations: the
/// forward operands `U`, `S⁻¹` plus gradient accumulators `dU`, `dA`
/// (where `A = S⁻¹`), and the row norms needed to finish through
/// `normalize`.
///
/// The chain `dU/dA → dS → d(UᵀU) → dU → dV` is linear in the incoming
/// cotangents, so contributions from many timesteps can be *accumulated*
/// into `du`/`da` and the (comparatively expensive) `S`-chain run once at
/// [`ParamTape::into_dv`] — this is what makes the fused BPTT cheap.
struct ParamTape {
    u: Matrix,    // (N, L) normalized columns
    sinv: Matrix, // (L, L) upper-triangular inverse of S
    norms: Vec<f32>,
    degenerate: Vec<bool>,
    du: Matrix, // accumulated dL/dU, (N, L)
    da: Matrix, // accumulated dL/dA, (L, L)
}

impl ParamTape {
    fn new(v: &Matrix) -> ParamTape {
        let u = normalize(v);
        let sinv = triu_inv(&build_s(&u));
        let norms = cwy::row_norms(v);
        let degenerate = norms.iter().map(|&n| n <= cwy::DEGENERATE_NORM).collect();
        let (du, da) = (Matrix::zeros(u.rows, u.cols), Matrix::zeros(u.cols, u.cols));
        ParamTape { u, sinv, norms, degenerate, du, da }
    }

    /// Finish the chain: `dS = −Aᵀ dA Aᵀ`, keep the strict upper triangle
    /// (only those entries of `UᵀU` enter `S`), push through the Gram
    /// product and the row normalization.
    fn into_dv(self, v: &Matrix) -> Matrix {
        let l = self.u.cols;
        let ds = self.sinv.t().matmul(&self.da).matmul(&self.sinv.t()).scale(-1.0);
        let mut p = Matrix::zeros(l, l);
        for i in 0..l {
            for j in i + 1..l {
                p[(i, j)] = ds[(i, j)];
            }
        }
        let du = self.du.add(&self.u.matmul(&p.add(&p.t())));
        // normalize backward, row i of V vs column i of U:
        // dv_i = (du_i − u_i (u_iᵀ du_i)) / ‖v_i‖; degenerate rows are
        // constant under normalize, so their gradient is exactly zero.
        let n = self.u.rows;
        let mut dv = Matrix::zeros(v.rows, v.cols);
        for i in 0..l {
            if self.degenerate[i] {
                continue;
            }
            let dot: f32 = (0..n).map(|j| self.u[(j, i)] * du[(j, i)]).sum();
            for j in 0..n {
                dv[(i, j)] = (du[(j, i)] - self.u[(j, i)] * dot) / self.norms[i];
            }
        }
        dv
    }
}

/// Accumulating backward pass for the full CWY transform (Thm 2).
pub struct CwyGrad {
    tape: ParamTape,
}

impl CwyGrad {
    pub fn new(v: &Matrix) -> CwyGrad {
        CwyGrad { tape: ParamTape::new(v) }
    }

    /// The forward operator sharing this tape's operands (for rollouts
    /// that interleave applies and backward accumulation).
    pub fn operator(&self) -> CwyOperator {
        CwyOperator { u: self.tape.u.clone(), sinv: self.tape.sinv.clone() }
    }

    /// Backward of one fused apply `Y = H Q(V)`: given the apply's input
    /// `h` (B, N) and the upstream gradient `g = dL/dY` (B, N), returns
    /// `dL/dH` and accumulates the `V`-path into the tape.  Cost
    /// `O(B·N·L + B·L²)` — no `N×N` intermediate.
    pub fn apply_backward(&mut self, h: &Matrix, g: &Matrix) -> Matrix {
        let u = &self.tape.u;
        let a = &self.tape.sinv;
        let gu = g.matmul(u); // (B, L)
        let hu = h.matmul(u); // (B, L)
        // dH = G (I − U A Uᵀ)ᵀ = G − (G U) Aᵀ Uᵀ
        let dh = g.sub(&gu.matmul(&a.t()).matmul(&u.t()));
        // dU += −Hᵀ(G U) Aᵀ − Gᵀ(H U) A   (from M = U A Uᵀ, dL/dM = −Hᵀ G)
        let du_h = h.t().matmul(&gu).matmul(&a.t());
        let du_g = g.t().matmul(&hu).matmul(a);
        self.tape.du = self.tape.du.sub(&du_h).sub(&du_g);
        // dA += −(H U)ᵀ (G U)
        self.tape.da = self.tape.da.sub(&hu.t().matmul(&gu));
        dh
    }

    /// Backward of the materialized matrix `Q = I − U S⁻¹ Uᵀ`: accumulate
    /// the `V`-path for an upstream gradient `dq = dL/dQ` (N, N).
    pub fn matrix_backward(&mut self, dq: &Matrix) {
        let u = &self.tape.u;
        let a = &self.tape.sinv;
        let qu = dq.matmul(u); // (N, L)
        let qtu = dq.t().matmul(u); // (N, L)
        self.tape.du = self.tape.du.sub(&qu.matmul(&a.t())).sub(&qtu.matmul(a));
        self.tape.da = self.tape.da.sub(&u.t().matmul(&qu));
    }

    /// Finish all accumulated contributions into `dL/dV`.
    pub fn into_dv(self, v: &Matrix) -> Matrix {
        self.tape.into_dv(v)
    }
}

/// Accumulating backward pass for the T-CWY Stiefel frame (Thm 3/4):
/// `Ω = [I;0] − U W` with `W = S⁻¹ U₁ᵀ`, `U₁ = U[..M, ..M]`.
pub struct TcwyGrad {
    tape: ParamTape,
    u1: Matrix, // (M, M) leading block of U
    w: Matrix,  // (M, M) = S⁻¹ U₁ᵀ
}

impl TcwyGrad {
    pub fn new(v: &Matrix) -> TcwyGrad {
        assert!(v.rows <= v.cols, "T-CWY needs M <= N");
        let tape = ParamTape::new(v);
        let m = v.rows;
        let mut u1 = Matrix::zeros(m, m);
        for i in 0..m {
            for j in 0..m {
                u1[(i, j)] = tape.u[(i, j)];
            }
        }
        let w = tape.sinv.matmul(&u1.t());
        TcwyGrad { tape, u1, w }
    }

    /// Accumulate the `V`-path for an upstream gradient `g = dL/dΩ` (N, M).
    pub fn matrix_backward(&mut self, g: &Matrix) {
        let m = self.u1.rows;
        // Ω = E − U W:  dU += −G Wᵀ,  dW = −Uᵀ G
        self.tape.du = self.tape.du.sub(&g.matmul(&self.w.t()));
        let dw = self.tape.u.t().matmul(g).scale(-1.0);
        // W = A U₁ᵀ:  dA += dW U₁,  dU₁ = dWᵀ A (added into the leading
        // M×M block of dU)
        self.tape.da = self.tape.da.add(&dw.matmul(&self.u1));
        let du1 = dw.t().matmul(&self.tape.sinv);
        for i in 0..m {
            for j in 0..m {
                self.tape.du[(i, j)] += du1[(i, j)];
            }
        }
    }

    /// Finish all accumulated contributions into `dL/dV`.
    pub fn into_dv(self, v: &Matrix) -> Matrix {
        self.tape.into_dv(v)
    }
}

/// Backward through the sequential Householder chain
/// `Y = H · H(v_1)⋯H(v_L)` (row convention of
/// [`householder::apply_chain`]).  Replays the forward to recover the
/// per-reflection inputs, then walks the chain in reverse — inherently
/// serial in L, which is exactly the bottleneck Thm 2 removes.  Returns
/// `(dL/dH, dL/dV)`.
///
/// `H(v)` divides by `‖v‖²`, so the chain is undefined at `v ≈ 0`; like
/// the CWY path, degenerate rows (norm ≤ [`cwy::DEGENERATE_NORM`]) are
/// handled explicitly — treated as the identity reflection in the replay
/// and assigned zero gradient — so the backward never emits NaN.
pub fn hr_chain_backward(vs: &Matrix, h: &Matrix, g: &Matrix) -> (Matrix, Matrix) {
    let l = vs.rows;
    let degenerate_s = cwy::DEGENERATE_NORM * cwy::DEGENERATE_NORM;
    // Forward replay, storing the input to each reflection.
    let mut inters: Vec<Matrix> = Vec::with_capacity(l + 1);
    inters.push(h.clone());
    for i in 0..l {
        let v = vs.row(i).to_vec();
        let mut next = inters[i].clone();
        if v.iter().map(|x| x * x).sum::<f32>() > degenerate_s {
            for b in 0..next.rows {
                householder::reflect_vec(&v, next.row_mut(b));
            }
        }
        inters.push(next);
    }
    let mut dvs = Matrix::zeros(vs.rows, vs.cols);
    let mut gcur = g.clone();
    for i in (0..l).rev() {
        let v = vs.row(i);
        let s: f32 = v.iter().map(|x| x * x).sum();
        if s <= degenerate_s {
            continue; // identity reflection: zero dV row, g passes through
        }
        let hin = &inters[i];
        let b = hin.rows;
        let n = hin.cols;
        // Per-row dots hv = H v, gv = G v.
        let hv: Vec<f32> = (0..b)
            .map(|r| hin.row(r).iter().zip(v).map(|(a, c)| a * c).sum())
            .collect();
        let gv: Vec<f32> = (0..b)
            .map(|r| gcur.row(r).iter().zip(v).map(|(a, c)| a * c).sum())
            .collect();
        let beta: f32 = gv.iter().zip(&hv).map(|(a, c)| a * c).sum();
        // dv = −(2/s)(Hᵀ gv + Gᵀ hv) + (4β/s²) v
        for j in 0..n {
            let mut acc = 0.0f32;
            for r in 0..b {
                acc += hin[(r, j)] * gv[r] + gcur[(r, j)] * hv[r];
            }
            dvs[(i, j)] = -(2.0 / s) * acc + (4.0 * beta / (s * s)) * v[j];
        }
        // dH = G − (2/s) gv vᵀ  (the reflection is symmetric)
        for r in 0..b {
            let c = 2.0 * gv[r] / s;
            for (gj, vj) in gcur.row_mut(r).iter_mut().zip(v) {
                *gj -= c * vj;
            }
        }
    }
    (gcur, dvs)
}

/// Forward states of the rollout `h_{t+1} = h_t Q(V) + x_t`, as computed
/// by the *fused* CWY operator; returns `[h_0, …, h_T]`.
pub fn cwy_rollout_states(v: &Matrix, h0: &Matrix, xs: &[Matrix]) -> Vec<Matrix> {
    let op = CwyOperator::new(v);
    let mut hs = Vec::with_capacity(xs.len() + 1);
    hs.push(h0.clone());
    for x in xs {
        let next = op.apply(hs.last().unwrap()).add(x);
        hs.push(next);
    }
    hs
}

/// Forward states of the same rollout via the sequential reflection chain.
pub fn hr_rollout_states(v: &Matrix, h0: &Matrix, xs: &[Matrix]) -> Vec<Matrix> {
    let mut hs = Vec::with_capacity(xs.len() + 1);
    hs.push(h0.clone());
    for x in xs {
        let mut next = hs.last().unwrap().clone();
        householder::apply_chain(v, &mut next);
        hs.push(next.add(x));
    }
    hs
}

/// Fused BPTT through the rollout: `gs[t] = dL/dh_{t+1}` for each step of
/// `h_{t+1} = h_t Q(V) + x_t`.  Returns `(dL/dh_0, dL/dV)`.  One
/// [`CwyGrad::apply_backward`] per step, one `S`-chain finish total.
pub fn cwy_rollout_backward(
    v: &Matrix,
    h0: &Matrix,
    xs: &[Matrix],
    gs: &[Matrix],
) -> (Matrix, Matrix) {
    assert_eq!(xs.len(), gs.len());
    // One tape for the whole rollout: its operator drives the forward
    // replay, so normalize/build_s/triu_inv run once, not twice.
    let mut grad = CwyGrad::new(v);
    let op = grad.operator();
    let mut hs = Vec::with_capacity(xs.len() + 1);
    hs.push(h0.clone());
    for x in xs {
        let next = op.apply(hs.last().unwrap()).add(x);
        hs.push(next);
    }
    let mut g = Matrix::zeros(h0.rows, h0.cols);
    for t in (0..xs.len()).rev() {
        g = g.add(&gs[t]);
        g = grad.apply_backward(&hs[t], &g);
    }
    (g, grad.into_dv(v))
}

/// Sequential-baseline BPTT through the same rollout: per step, per
/// reflection, in reverse.  Returns `(dL/dh_0, dL/dV)`.
pub fn hr_rollout_backward(
    v: &Matrix,
    h0: &Matrix,
    xs: &[Matrix],
    gs: &[Matrix],
) -> (Matrix, Matrix) {
    assert_eq!(xs.len(), gs.len());
    let hs = hr_rollout_states(v, h0, xs);
    let mut dv = Matrix::zeros(v.rows, v.cols);
    let mut g = Matrix::zeros(h0.rows, h0.cols);
    for t in (0..xs.len()).rev() {
        g = g.add(&gs[t]);
        let (dh, dvs) = hr_chain_backward(v, &hs[t], &g);
        dv = dv.add(&dvs);
        g = dh;
    }
    (g, dv)
}

/// Central finite-difference gradient of a scalar function of `x`,
/// `g_ij = (f(x + ε e_ij) − f(x − ε e_ij)) / 2ε` — the reference every
/// analytic backward here is verified against.
pub fn finite_diff(x: &Matrix, eps: f32, mut f: impl FnMut(&Matrix) -> f32) -> Matrix {
    let mut g = Matrix::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        for j in 0..x.cols {
            let mut xp = x.clone();
            xp[(i, j)] += eps;
            let mut xm = x.clone();
            xm[(i, j)] -= eps;
            g[(i, j)] = (f(&xp) - f(&xm)) / (2.0 * eps);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orthogonal::tcwy;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    /// FD step and the f32 tolerance scale it implies: central differences
    /// on an f32 forward pass carry ~|f|·1e-7/ε noise, so comparisons are
    /// scaled by max(1, ‖grad‖∞) with a 10× margin over the measured worst
    /// case (calibrated against the float64 reference).
    const EPS: f32 = 3e-3;
    const TOL: f32 = 2e-3;

    fn inner(a: &Matrix, b: &Matrix) -> f32 {
        a.data.iter().zip(&b.data).map(|(x, y)| x * y).sum()
    }

    fn scaled_diff(analytic: &Matrix, numeric: &Matrix) -> f32 {
        let scale = numeric.data.iter().fold(1.0f32, |m, x| m.max(x.abs()));
        analytic.max_abs_diff(numeric) / scale
    }

    #[test]
    fn prop_cwy_apply_backward_matches_fd() {
        forall(
            8,
            |rng| {
                let l = 1 + rng.below(5) as usize;
                let n = l + 1 + rng.below(8) as usize;
                let b = 1 + rng.below(3) as usize;
                (
                    Matrix::random_normal(rng, l, n, 1.0),
                    Matrix::random_normal(rng, b, n, 1.0),
                    Matrix::random_normal(rng, b, n, 1.0),
                )
            },
            |(v, h, g)| {
                let mut grad = CwyGrad::new(v);
                let dh = grad.apply_backward(h, g);
                let dv = grad.into_dv(v);
                let dv_fd = finite_diff(v, EPS, |vv| {
                    inner(g, &CwyOperator::new(vv).apply(h))
                });
                let dh_fd = finite_diff(h, EPS, |hh| {
                    inner(g, &CwyOperator::new(v).apply(hh))
                });
                let (ev, eh) = (scaled_diff(&dv, &dv_fd), scaled_diff(&dh, &dh_fd));
                if ev < TOL && eh < TOL {
                    Ok(())
                } else {
                    Err(format!("dV err {ev}, dH err {eh}"))
                }
            },
        );
    }

    #[test]
    fn prop_cwy_matrix_backward_matches_fd() {
        forall(
            8,
            |rng| {
                let l = 1 + rng.below(5) as usize;
                let n = l + 1 + rng.below(8) as usize;
                (
                    Matrix::random_normal(rng, l, n, 1.0),
                    Matrix::random_normal(rng, n, n, 1.0),
                )
            },
            |(v, gq)| {
                let mut grad = CwyGrad::new(v);
                grad.matrix_backward(gq);
                let dv = grad.into_dv(v);
                let dv_fd = finite_diff(v, EPS, |vv| inner(gq, &cwy::matrix(vv)));
                let e = scaled_diff(&dv, &dv_fd);
                if e < TOL { Ok(()) } else { Err(format!("dV err {e}")) }
            },
        );
    }

    #[test]
    fn prop_tcwy_backward_matches_fd() {
        forall(
            8,
            |rng| {
                let m = 1 + rng.below(4) as usize;
                let n = m + 1 + rng.below(8) as usize;
                (
                    Matrix::random_normal(rng, m, n, 1.0),
                    Matrix::random_normal(rng, n, m, 1.0),
                )
            },
            |(v, g)| {
                let mut grad = TcwyGrad::new(v);
                grad.matrix_backward(g);
                let dv = grad.into_dv(v);
                let dv_fd = finite_diff(v, EPS, |vv| inner(g, &tcwy::matrix(vv)));
                let e = scaled_diff(&dv, &dv_fd);
                if e < TOL { Ok(()) } else { Err(format!("dV err {e}")) }
            },
        );
    }

    /// T-CWY degenerates to the square orthogonal case at M = N; the
    /// backward must stay exact there too (the rnn_copy tcwy cell uses
    /// this regime).
    #[test]
    fn prop_tcwy_square_backward_matches_fd() {
        forall(
            6,
            |rng| {
                let n = 2 + rng.below(6) as usize;
                (
                    Matrix::random_normal(rng, n, n, 1.0),
                    Matrix::random_normal(rng, n, n, 1.0),
                )
            },
            |(v, g)| {
                let mut grad = TcwyGrad::new(v);
                grad.matrix_backward(g);
                let dv = grad.into_dv(v);
                let dv_fd = finite_diff(v, EPS, |vv| inner(g, &tcwy::matrix(vv)));
                let e = scaled_diff(&dv, &dv_fd);
                if e < TOL { Ok(()) } else { Err(format!("dV err {e}")) }
            },
        );
    }

    #[test]
    fn prop_hr_chain_backward_matches_fd() {
        forall(
            8,
            |rng| {
                let l = 1 + rng.below(5) as usize;
                let n = l + 1 + rng.below(8) as usize;
                let b = 1 + rng.below(3) as usize;
                (
                    Matrix::random_normal(rng, l, n, 1.0),
                    Matrix::random_normal(rng, b, n, 1.0),
                    Matrix::random_normal(rng, b, n, 1.0),
                )
            },
            |(v, h, g)| {
                let (dh, dv) = hr_chain_backward(v, h, g);
                let apply = |vv: &Matrix, hh: &Matrix| {
                    let mut out = hh.clone();
                    householder::apply_chain(vv, &mut out);
                    inner(g, &out)
                };
                let dv_fd = finite_diff(v, EPS, |vv| apply(vv, h));
                let dh_fd = finite_diff(h, EPS, |hh| apply(v, hh));
                let (ev, eh) = (scaled_diff(&dv, &dv_fd), scaled_diff(&dh, &dh_fd));
                if ev < TOL && eh < TOL {
                    Ok(())
                } else {
                    Err(format!("dV err {ev}, dH err {eh}"))
                }
            },
        );
    }

    /// BPTT through a short rollout vs finite differences — the property
    /// behind the rnn_copy training path (one-step and multi-step).
    #[test]
    fn prop_rollout_bptt_matches_fd() {
        forall(
            6,
            |rng| {
                let l = 1 + rng.below(4) as usize;
                let n = l + 1 + rng.below(6) as usize;
                let b = 1 + rng.below(2) as usize;
                let t = 1 + rng.below(3) as usize; // includes the one-step case
                let v = Matrix::random_normal(rng, l, n, 1.0);
                let h0 = Matrix::random_normal(rng, b, n, 1.0);
                let xs: Vec<Matrix> = (0..t)
                    .map(|_| Matrix::random_normal(rng, b, n, 1.0))
                    .collect();
                let gs: Vec<Matrix> = (0..t)
                    .map(|_| Matrix::random_normal(rng, b, n, 1.0))
                    .collect();
                (v, h0, xs, gs)
            },
            |(v, h0, xs, gs)| {
                let loss = |vv: &Matrix, hh0: &Matrix| {
                    let hs = cwy_rollout_states(vv, hh0, xs);
                    (0..xs.len()).map(|t| inner(&gs[t], &hs[t + 1])).sum::<f32>()
                };
                let (dh0, dv) = cwy_rollout_backward(v, h0, xs, gs);
                let dv_fd = finite_diff(v, EPS, |vv| loss(vv, h0));
                let dh_fd = finite_diff(h0, EPS, |hh| loss(v, hh));
                let (ev, eh) = (scaled_diff(&dv, &dv_fd), scaled_diff(&dh0, &dh_fd));
                // Rollouts compound f32 noise over T steps; widen the
                // margin accordingly.
                if ev < 2.0 * TOL && eh < 2.0 * TOL {
                    Ok(())
                } else {
                    Err(format!("dV err {ev}, dh0 err {eh}"))
                }
            },
        );
    }

    /// Thm 2 at the gradient level: the fused CWY backward and the
    /// sequential per-Householder backward differentiate the *same*
    /// function, so their gradients agree elementwise on the same rollout.
    /// Bound scales with the gradient magnitude (f32, two genuinely
    /// different algorithms); the PR's absolute 1e-4 acceptance bound is
    /// asserted on the loss-normalized fixture rollout in
    /// `integration_trainer::native::copy_cwy_and_hr_gradients_agree...`.
    #[test]
    fn cwy_and_hr_rollout_gradients_agree() {
        let mut rng = Pcg32::seeded(41);
        let (l, n, b, t) = (6, 16, 3, 5);
        let v = Matrix::random_normal(&mut rng, l, n, 1.0);
        let h0 = Matrix::random_normal(&mut rng, b, n, 1.0);
        let xs: Vec<Matrix> = (0..t)
            .map(|_| Matrix::random_normal(&mut rng, b, n, 1.0))
            .collect();
        let gs: Vec<Matrix> = (0..t)
            .map(|_| Matrix::random_normal(&mut rng, b, n, 1.0))
            .collect();
        let (dh_cwy, dv_cwy) = cwy_rollout_backward(&v, &h0, &xs, &gs);
        let (dh_hr, dv_hr) = hr_rollout_backward(&v, &h0, &xs, &gs);
        let dv_scale = dv_hr.data.iter().fold(1.0f32, |m, x| m.max(x.abs()));
        let dh_scale = dh_hr.data.iter().fold(1.0f32, |m, x| m.max(x.abs()));
        let dv_err = dv_cwy.max_abs_diff(&dv_hr) / dv_scale;
        let dh_err = dh_cwy.max_abs_diff(&dh_hr) / dh_scale;
        assert!(dv_err <= 1e-4, "dV disagreement {dv_err} (scale {dv_scale})");
        assert!(dh_err <= 1e-4, "dh0 disagreement {dh_err} (scale {dh_scale})");
    }

    /// Regression for the normalize fix: a degenerate reflection row gets
    /// gradient exactly zero (the parametrization is constant there), and
    /// every other gradient entry stays finite.
    #[test]
    fn degenerate_row_gets_zero_gradient() {
        let mut rng = Pcg32::seeded(17);
        let mut v = Matrix::random_normal(&mut rng, 4, 8, 1.0);
        for j in 0..8 {
            v[(1, j)] = 0.0;
        }
        let h = Matrix::random_normal(&mut rng, 2, 8, 1.0);
        let g = Matrix::random_normal(&mut rng, 2, 8, 1.0);
        let mut grad = CwyGrad::new(&v);
        grad.apply_backward(&h, &g);
        let dv = grad.into_dv(&v);
        assert!(dv.data.iter().all(|x| x.is_finite()), "non-finite gradient");
        for j in 0..8 {
            assert_eq!(dv[(1, j)], 0.0, "degenerate row must have zero grad");
        }
        // Healthy rows still carry signal.
        assert!(dv.frobenius() > 0.0);
        // The HR chain divides by ‖v‖² and must apply the same explicit
        // handling: zero gradient for the degenerate row, no NaN anywhere.
        let (dh_hr, dv_hr) = hr_chain_backward(&v, &h, &g);
        assert!(dh_hr.data.iter().all(|x| x.is_finite()), "non-finite HR dH");
        assert!(dv_hr.data.iter().all(|x| x.is_finite()), "non-finite HR dV");
        for j in 0..8 {
            assert_eq!(dv_hr[(1, j)], 0.0, "degenerate row must have zero HR grad");
        }
        assert!(dv_hr.frobenius() > 0.0);
    }
}
