//! Analytic backward passes for the CWY / T-CWY parametrizations and the
//! sequential Householder baseline — the native **backward substrate**
//! (DESIGN.md §3.2).
//!
//! The paper's claim (Thms 2–4) is about *training*: the CWY transform
//! makes the gradient of an orthogonal recurrence a handful of fused
//! matmuls instead of a length-L sequential chain.  This module implements
//! exactly that backward:
//!
//! * [`CwyGrad`] — gradient of `Y = H Q(V)` (and of `Q` itself) with
//!   respect to both `H` and the raw reflection rows `V`, back through
//!   `normalize`, `build_s`, and `triu_inv`.  Per-step cost is
//!   `O(B·N·L + N·L² + L³)` with no `N×N` intermediate — the fused
//!   counterpart of the forward operator.
//! * [`TcwyGrad`] — gradient of the Thm 3 Stiefel frame
//!   `Ω = [I;0] − U S⁻¹ U₁ᵀ` with respect to `V`.
//! * [`hr_chain_backward`] — backward through the sequential reflection
//!   chain (Mhammedi et al. 2017), inherently serial in L: the baseline
//!   the fused path is benched against (`benches/bptt_native.rs`).
//! * [`cwy_rollout_backward`] / [`hr_rollout_backward`] — BPTT through a
//!   T-step rollout `h_{t+1} = h_t Q + x_t` of the recurrent cell.
//!
//! Since the zero-allocation substrate pass (DESIGN.md §3.3) the hot
//! entry points are in-place: [`CwyGrad::recompute`] rebuilds the tape
//! for new parameters reusing every buffer, [`CwyGrad::apply_backward_in_place`]
//! turns the upstream gradient into `dL/dH` in its own buffer while
//! accumulating the V-path through fused `beta = 1` gemms (no
//! materialized transposes, no temporaries beyond pooled scratch), and
//! [`CwyGrad::finish_into`] runs the S-chain once per rollout into a
//! caller buffer.  The PR-4 allocating implementation is frozen verbatim
//! in [`reference`] as the `BENCH_5` measurement baseline and a parity
//! oracle — under the portable GEMM kernel the fused path must agree
//! with it to the last bit; under the AVX2+FMA kernel (different
//! accumulation grouping, fused rounding) agreement is asserted within
//! f32-scaled tolerances instead (`linalg::gemm` module docs).
//!
//! Degenerate reflection rows (norm ≤ [`cwy::DEGENERATE_NORM`]) carry
//! **zero** gradient on every path — never NaN: the CWY chain maps them
//! to a constant canonical basis vector in `normalize`, and the HR chain
//! treats them as the identity reflection (forward and backward alike,
//! see [`householder`]).  The two parametrizations agree as functions
//! only on non-degenerate rows.

use crate::linalg::{gemm, gemm_packed, simd, triu_inv_into, Matrix, Workspace};

use super::cwy::{
    self, apply_with_packed, normalize_with_norms_into, row_norms_into, CwyOperator, CwyPacks,
};
use super::householder;

/// Shared backward context for the CWY-family parametrizations: the
/// forward operands `U`, `S⁻¹` plus gradient accumulators `dU`, `dA`
/// (where `A = S⁻¹`), and the row norms needed to finish through
/// `normalize`.
///
/// The chain `dU/dA → dS → d(UᵀU) → dU → dV` is linear in the incoming
/// cotangents, so contributions from many timesteps can be *accumulated*
/// into `du`/`da` and the (comparatively expensive) `S`-chain run once at
/// [`ParamTape::finish_into`] — this is what makes the fused BPTT cheap.
/// Every buffer is reused across [`ParamTape::recompute`] calls, so a
/// steady-state training loop rebuilds the tape allocation-free.
struct ParamTape {
    u: Matrix,    // (N, L) normalized columns
    s: Matrix,    // (L, L) S = 0.5 I + striu(UᵀU), kept for rebuilds
    sinv: Matrix, // (L, L) upper-triangular inverse of S
    norms: Vec<f32>,
    degenerate: Vec<bool>,
    du: Matrix, // accumulated dL/dU, (N, L)
    da: Matrix, // accumulated dL/dA, (L, L)
    /// Pre-packed `U`/`S⁻¹` panels (ISSUE 9), rebuilt once per
    /// `recompute` and reused by every forward apply and backward step of
    /// the rollout that shares this tape.
    packs: CwyPacks,
}

impl ParamTape {
    fn new(v: &Matrix) -> ParamTape {
        let mut tape = ParamTape {
            u: Matrix::zeros(0, 0),
            s: Matrix::zeros(0, 0),
            sinv: Matrix::zeros(0, 0),
            norms: Vec::new(),
            degenerate: Vec::new(),
            du: Matrix::zeros(0, 0),
            da: Matrix::zeros(0, 0),
            packs: CwyPacks::new(),
        };
        let mut ws = Workspace::new();
        tape.recompute(v, &mut ws);
        tape
    }

    /// Rebuild the forward operands for new parameters and zero the
    /// accumulators, reusing every buffer (allocation-free at steady
    /// state).  One `row_norms` pass feeds `normalize`, the degenerate
    /// mask, and the final division — the norm dedup of ISSUE 5.
    fn recompute(&mut self, v: &Matrix, ws: &mut Workspace) {
        let (l, n) = (v.rows, v.cols);
        self.norms.clear();
        self.norms.resize(l, 0.0);
        row_norms_into(v, &mut self.norms);
        self.degenerate.clear();
        self.degenerate
            .extend(self.norms.iter().map(|&x| x <= cwy::DEGENERATE_NORM));
        self.u.resize_zeroed(n, l);
        normalize_with_norms_into(v, &self.norms, &mut self.u);
        self.s.resize_zeroed(l, l);
        cwy::build_s_into(&self.u, &mut self.s, ws);
        self.sinv.resize_zeroed(l, l);
        triu_inv_into(&self.s, &mut self.sinv, ws);
        self.du.resize_zeroed(n, l);
        self.da.resize_zeroed(l, l);
        // Operands just changed in place — re-pack their panels once so
        // all T timesteps of the coming rollout reuse them.
        self.packs.repack(&self.u, &self.sinv);
    }

    /// Finish the chain: `dS = −Aᵀ dA Aᵀ`, keep the strict upper triangle
    /// (only those entries of `UᵀU` enter `S`), push through the Gram
    /// product and the row normalization.  Writes into a preshaped `dv`;
    /// the accumulators are left untouched, so callers that want to keep
    /// accumulating must `recompute` first.
    fn finish_into(&mut self, v: &Matrix, dv: &mut Matrix, ws: &mut Workspace) {
        let l = self.u.cols;
        let n = self.u.rows;
        assert_eq!((dv.rows, dv.cols), (v.rows, v.cols), "finish output shape");
        let mut t1 = ws.take(l, l);
        gemm(true, false, 1.0, &self.sinv, &self.da, 0.0, &mut t1); // Aᵀ dA
        let mut ds = ws.take(l, l);
        gemm(false, true, 1.0, &t1, &self.sinv, 0.0, &mut ds); // (Aᵀ dA) Aᵀ
        ds.scale_in_place(-1.0);
        // q = striu(ds) + striu(ds)ᵀ, written exactly as the reference
        // computes `p.add(&p.t())` (the `+ 0.0` keeps −0.0 edge cases
        // bit-identical to the allocating path).
        let mut q = ws.take(l, l);
        for i in 0..l {
            for j in i + 1..l {
                let d = ds[(i, j)];
                q[(i, j)] = d + 0.0;
                q[(j, i)] = 0.0 + d;
            }
        }
        let mut dufin = ws.take(n, l);
        dufin.copy_from(&self.du);
        gemm(false, false, 1.0, &self.u, &q, 1.0, &mut dufin); // du + U q
        // normalize backward, row i of V vs column i of U:
        // dv_i = (du_i − u_i (u_iᵀ du_i)) / ‖v_i‖; degenerate rows are
        // constant under normalize, so their gradient is exactly zero.
        dv.fill(0.0);
        for i in 0..l {
            if self.degenerate[i] {
                continue;
            }
            let dot: f32 = (0..n).map(|j| self.u[(j, i)] * dufin[(j, i)]).sum();
            for j in 0..n {
                dv[(i, j)] = (dufin[(j, i)] - self.u[(j, i)] * dot) / self.norms[i];
            }
        }
        ws.give(t1);
        ws.give(ds);
        ws.give(q);
        ws.give(dufin);
    }
}

/// Accumulating backward pass for the full CWY transform (Thm 2).
pub struct CwyGrad {
    tape: ParamTape,
}

impl CwyGrad {
    pub fn new(v: &Matrix) -> CwyGrad {
        CwyGrad { tape: ParamTape::new(v) }
    }

    /// Rebuild for new parameters, reusing every internal buffer and
    /// zeroing the accumulators — the steady-state training entry.
    pub fn recompute(&mut self, v: &Matrix, ws: &mut Workspace) {
        self.tape.recompute(v, ws);
    }

    /// The forward operator sharing this tape's operands (for rollouts
    /// that interleave applies and backward accumulation).
    pub fn operator(&self) -> CwyOperator {
        CwyOperator::from_parts(self.tape.u.clone(), self.tape.sinv.clone())
    }

    /// Fused forward apply `out = h Q(V)` using the tape's operands
    /// directly (no operator clone), allocation-free with pooled scratch.
    /// Reuses the tape's pre-packed panels across all T timesteps.
    pub fn apply_forward_into(&self, h: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        apply_with_packed(&self.tape.u, &self.tape.sinv, &self.tape.packs, h, out, ws);
    }

    /// Backward of one fused apply `Y = H Q(V)`: given the apply's input
    /// `h` (B, N) and the upstream gradient `g = dL/dY` (B, N), returns
    /// `dL/dH` and accumulates the `V`-path into the tape.  Cost
    /// `O(B·N·L + B·L²)` — no `N×N` intermediate.  (Allocating wrapper
    /// over [`CwyGrad::apply_backward_in_place`], bitwise-identical.)
    pub fn apply_backward(&mut self, h: &Matrix, g: &Matrix) -> Matrix {
        let mut ws = Workspace::new();
        let mut dh = g.clone();
        self.apply_backward_in_place(h, &mut dh, &mut ws);
        dh
    }

    /// In-place backward of one fused apply: `g` enters as `dL/dY` and
    /// leaves as `dL/dH`; the `V`-path lands in the tape accumulators via
    /// fused `beta = 1` gemms.  No materialized transposes, no
    /// allocation beyond pooled scratch.
    pub fn apply_backward_in_place(&mut self, h: &Matrix, g: &mut Matrix, ws: &mut Workspace) {
        let tape = &mut self.tape;
        let (b, l, n) = (h.rows, tape.u.cols, tape.u.rows);
        // The six gemms whose B operand is tape-owned (`U`, `S⁻¹`, their
        // transposes) run packed against the tape's panels; the three TN
        // gemms keep per-call packing — their B operand varies per step.
        let mut gu = ws.take(b, l);
        gemm_packed(false, false, 1.0, g, &tape.u, &tape.packs.u_nn, 0.0, &mut gu); // G U
        let mut hu = ws.take(b, l);
        gemm_packed(false, false, 1.0, h, &tape.u, &tape.packs.u_nn, 0.0, &mut hu); // H U
        // dU −= Hᵀ(G U) Aᵀ  then  dU −= Gᵀ(H U) A
        // (from M = U A Uᵀ, dL/dM = −Hᵀ G; same order as the reference)
        let mut m1 = ws.take(n, l);
        gemm(true, false, 1.0, h, &gu, 0.0, &mut m1); // Hᵀ (G U)
        gemm_packed(false, true, -1.0, &m1, &tape.sinv, &tape.packs.sinv_nt, 1.0, &mut tape.du);
        gemm(true, false, 1.0, g, &hu, 0.0, &mut m1); // Gᵀ (H U)
        gemm_packed(false, false, -1.0, &m1, &tape.sinv, &tape.packs.sinv_nn, 1.0, &mut tape.du);
        // dA −= (H U)ᵀ (G U)
        gemm(true, false, -1.0, &hu, &gu, 1.0, &mut tape.da);
        // dH = G (I − U A Uᵀ)ᵀ = G − (G U) Aᵀ Uᵀ — last, so the V-path
        // above saw the original G.
        let mut t = ws.take(b, l);
        gemm_packed(false, true, 1.0, &gu, &tape.sinv, &tape.packs.sinv_nt, 0.0, &mut t); // (G U) Aᵀ
        gemm_packed(false, true, -1.0, &t, &tape.u, &tape.packs.u_nt, 1.0, g);
        ws.give(gu);
        ws.give(hu);
        ws.give(m1);
        ws.give(t);
    }

    /// Backward of the materialized matrix `Q = I − U S⁻¹ Uᵀ`: accumulate
    /// the `V`-path for an upstream gradient `dq = dL/dQ` (N, N).
    pub fn matrix_backward(&mut self, dq: &Matrix) {
        let tape = &mut self.tape;
        let (n, l) = (tape.u.rows, tape.u.cols);
        let mut qu = Matrix::zeros(n, l);
        gemm(false, false, 1.0, dq, &tape.u, 0.0, &mut qu); // dQ U
        let mut qtu = Matrix::zeros(n, l);
        gemm(true, false, 1.0, dq, &tape.u, 0.0, &mut qtu); // dQᵀ U
        gemm(false, true, -1.0, &qu, &tape.sinv, 1.0, &mut tape.du);
        gemm(false, false, -1.0, &qtu, &tape.sinv, 1.0, &mut tape.du);
        gemm(true, false, -1.0, &tape.u, &qu, 1.0, &mut tape.da);
    }

    /// Finish all accumulated contributions into `dL/dV`.
    pub fn into_dv(mut self, v: &Matrix) -> Matrix {
        let mut dv = Matrix::zeros(v.rows, v.cols);
        let mut ws = Workspace::new();
        self.tape.finish_into(v, &mut dv, &mut ws);
        dv
    }

    /// Allocation-free finish: write `dL/dV` into a preshaped `dv`.
    pub fn finish_into(&mut self, v: &Matrix, dv: &mut Matrix, ws: &mut Workspace) {
        self.tape.finish_into(v, dv, ws);
    }
}

/// Accumulating backward pass for the T-CWY Stiefel frame (Thm 3/4):
/// `Ω = [I;0] − U W` with `W = S⁻¹ U₁ᵀ`, `U₁ = U[..M, ..M]`.
pub struct TcwyGrad {
    tape: ParamTape,
    u1: Matrix, // (M, M) leading block of U
    w: Matrix,  // (M, M) = S⁻¹ U₁ᵀ
}

impl TcwyGrad {
    pub fn new(v: &Matrix) -> TcwyGrad {
        assert!(v.rows <= v.cols, "T-CWY needs M <= N");
        let mut grad = TcwyGrad {
            tape: ParamTape::new(v),
            u1: Matrix::zeros(0, 0),
            w: Matrix::zeros(0, 0),
        };
        grad.rebuild_frame();
        grad
    }

    /// Rebuild for new parameters, reusing buffers (cf. [`CwyGrad::recompute`]).
    pub fn recompute(&mut self, v: &Matrix, ws: &mut Workspace) {
        assert!(v.rows <= v.cols, "T-CWY needs M <= N");
        self.tape.recompute(v, ws);
        self.rebuild_frame();
    }

    fn rebuild_frame(&mut self) {
        let m = self.tape.u.cols;
        self.u1.resize_zeroed(m, m);
        for i in 0..m {
            for j in 0..m {
                self.u1[(i, j)] = self.tape.u[(i, j)];
            }
        }
        self.w.resize_zeroed(m, m);
        gemm(false, true, 1.0, &self.tape.sinv, &self.u1, 0.0, &mut self.w); // S⁻¹ U₁ᵀ
    }

    /// Materialize `Ω = [I;0] − U W` into a preshaped `(N, M)` buffer —
    /// the frame the square T-CWY recurrence multiplies by, sharing the
    /// tape's operands so nothing is recomputed.
    pub fn omega_into(&self, out: &mut Matrix) {
        let (n, m) = (self.tape.u.rows, self.tape.u.cols);
        assert_eq!((out.rows, out.cols), (n, m), "omega output shape");
        out.fill(0.0);
        for i in 0..n.min(m) {
            out[(i, i)] = 1.0;
        }
        gemm(false, false, -1.0, &self.tape.u, &self.w, 1.0, out);
    }

    /// Accumulate the `V`-path for an upstream gradient `g = dL/dΩ` (N, M).
    pub fn matrix_backward(&mut self, g: &Matrix) {
        let mut ws = Workspace::new();
        self.matrix_backward_ws(g, &mut ws);
    }

    /// Allocation-free [`TcwyGrad::matrix_backward`] with pooled scratch.
    pub fn matrix_backward_ws(&mut self, g: &Matrix, ws: &mut Workspace) {
        let m = self.u1.rows;
        let tape = &mut self.tape;
        // Ω = E − U W:  dU += −G Wᵀ,  dW = −Uᵀ G
        gemm(false, true, -1.0, g, &self.w, 1.0, &mut tape.du);
        let mut dw = ws.take(m, m);
        gemm(true, false, -1.0, &tape.u, g, 0.0, &mut dw);
        // W = A U₁ᵀ:  dA += dW U₁,  dU₁ = dWᵀ A (added into the leading
        // M×M block of dU)
        gemm(false, false, 1.0, &dw, &self.u1, 1.0, &mut tape.da);
        let mut du1 = ws.take(m, m);
        gemm(true, false, 1.0, &dw, &tape.sinv, 0.0, &mut du1);
        // du has exactly M columns, so the leading M×M block spans whole
        // rows — one lane-width axpy per row (alpha = 1 adds exactly,
        // fused or not, so this is bitwise-neutral to the scalar loop).
        for i in 0..m {
            simd::axpy(1.0, du1.row(i), tape.du.row_mut(i));
        }
        ws.give(dw);
        ws.give(du1);
    }

    /// Finish all accumulated contributions into `dL/dV`.
    pub fn into_dv(mut self, v: &Matrix) -> Matrix {
        let mut dv = Matrix::zeros(v.rows, v.cols);
        let mut ws = Workspace::new();
        self.tape.finish_into(v, &mut dv, &mut ws);
        dv
    }

    /// Allocation-free finish: write `dL/dV` into a preshaped `dv`.
    pub fn finish_into(&mut self, v: &Matrix, dv: &mut Matrix, ws: &mut Workspace) {
        self.tape.finish_into(v, dv, ws);
    }
}

/// Backward through the sequential Householder chain
/// `Y = H · H(v_1)⋯H(v_L)` (row convention of
/// [`householder::apply_chain`]).  Replays the forward to recover the
/// per-reflection inputs, then walks the chain in reverse — inherently
/// serial in L, which is exactly the bottleneck Thm 2 removes.  Returns
/// `(dL/dH, dL/dV)`.
///
/// `H(v)` divides by `‖v‖²`, so the chain is undefined at `v ≈ 0`; like
/// the CWY path, degenerate rows (norm ≤ [`cwy::DEGENERATE_NORM`]) are
/// handled explicitly — treated as the identity reflection in the replay
/// and assigned zero gradient — so the backward never emits NaN.
pub fn hr_chain_backward(vs: &Matrix, h: &Matrix, g: &Matrix) -> (Matrix, Matrix) {
    let l = vs.rows;
    let degenerate_s = cwy::DEGENERATE_NORM * cwy::DEGENERATE_NORM;
    // Forward replay, storing the input to each reflection.
    let mut inters: Vec<Matrix> = Vec::with_capacity(l + 1);
    inters.push(h.clone());
    for i in 0..l {
        let v = vs.row(i).to_vec();
        let mut next = inters[i].clone();
        if simd::norm_sq(&v) > degenerate_s {
            for b in 0..next.rows {
                householder::reflect_vec(&v, next.row_mut(b));
            }
        }
        inters.push(next);
    }
    let mut dvs = Matrix::zeros(vs.rows, vs.cols);
    let mut gcur = g.clone();
    // Row-major rank-1 accumulator for the dv sum — the old j-outer loop
    // walked H and G column-strided; accumulating row axpys instead
    // streams both matrices contiguously through the lane-width kernels.
    let mut dv_acc = vec![0.0f32; vs.cols];
    for i in (0..l).rev() {
        let v = vs.row(i);
        let s = simd::norm_sq(v);
        if s <= degenerate_s {
            continue; // identity reflection: zero dV row, g passes through
        }
        let hin = &inters[i];
        let b = hin.rows;
        // Per-row dots hv = H v, gv = G v.
        let hv: Vec<f32> = (0..b).map(|r| simd::dot(hin.row(r), v)).collect();
        let gv: Vec<f32> = (0..b).map(|r| simd::dot(gcur.row(r), v)).collect();
        let beta: f32 = gv.iter().zip(&hv).map(|(a, c)| a * c).sum();
        // dv = −(2/s)(Hᵀ gv + Gᵀ hv) + (4β/s²) v
        dv_acc.fill(0.0);
        for r in 0..b {
            simd::axpy(gv[r], hin.row(r), &mut dv_acc);
            simd::axpy(hv[r], gcur.row(r), &mut dv_acc);
        }
        let (cg, cv) = (-(2.0 / s), 4.0 * beta / (s * s));
        for (dst, (&aj, &vj)) in dvs.row_mut(i).iter_mut().zip(dv_acc.iter().zip(v)) {
            *dst = cg * aj + cv * vj;
        }
        // dH = G − (2/s) gv vᵀ  (the reflection is symmetric)
        for (r, &gvr) in gv.iter().enumerate() {
            simd::axpy(-2.0 * gvr / s, v, gcur.row_mut(r));
        }
    }
    (gcur, dvs)
}

/// Forward states of the rollout `h_{t+1} = h_t Q(V) + x_t`, as computed
/// by the *fused* CWY operator; returns `[h_0, …, h_T]`.
pub fn cwy_rollout_states(v: &Matrix, h0: &Matrix, xs: &[Matrix]) -> Vec<Matrix> {
    let op = CwyOperator::new(v);
    let mut ws = Workspace::new();
    let mut hs = Vec::with_capacity(xs.len() + 1);
    hs.push(h0.clone());
    for x in xs {
        let mut next = Matrix::zeros(h0.rows, h0.cols);
        op.apply_into(hs.last().unwrap(), &mut next, &mut ws);
        next.add_assign(x);
        hs.push(next);
    }
    hs
}

/// Forward states of the same rollout via the sequential reflection chain.
pub fn hr_rollout_states(v: &Matrix, h0: &Matrix, xs: &[Matrix]) -> Vec<Matrix> {
    let mut hs = Vec::with_capacity(xs.len() + 1);
    hs.push(h0.clone());
    for x in xs {
        let mut next = hs.last().unwrap().clone();
        householder::apply_chain(v, &mut next);
        hs.push(next.add(x));
    }
    hs
}

/// Fused BPTT through the rollout: `gs[t] = dL/dh_{t+1}` for each step of
/// `h_{t+1} = h_t Q(V) + x_t`.  Returns `(dL/dh_0, dL/dV)`.  One
/// [`CwyGrad::apply_backward_in_place`] per step, one `S`-chain finish
/// total, all scratch pooled.  Bitwise-identical to the frozen PR-4 path
/// in [`reference`] under the portable kernel (see module docs).
pub fn cwy_rollout_backward(
    v: &Matrix,
    h0: &Matrix,
    xs: &[Matrix],
    gs: &[Matrix],
) -> (Matrix, Matrix) {
    assert_eq!(xs.len(), gs.len());
    // One tape for the whole rollout: its operands drive the forward
    // replay, so normalize/build_s/triu_inv run once, not twice.
    let mut ws = Workspace::new();
    let mut grad = CwyGrad::new(v);
    let mut hs = Vec::with_capacity(xs.len() + 1);
    hs.push(h0.clone());
    for x in xs {
        let mut next = Matrix::zeros(h0.rows, h0.cols);
        grad.apply_forward_into(hs.last().unwrap(), &mut next, &mut ws);
        next.add_assign(x);
        hs.push(next);
    }
    let mut g = Matrix::zeros(h0.rows, h0.cols);
    for t in (0..xs.len()).rev() {
        g.add_assign(&gs[t]);
        grad.apply_backward_in_place(&hs[t], &mut g, &mut ws);
    }
    let mut dv = Matrix::zeros(v.rows, v.cols);
    grad.finish_into(v, &mut dv, &mut ws);
    (g, dv)
}

/// Sequential-baseline BPTT through the same rollout: per step, per
/// reflection, in reverse.  Returns `(dL/dh_0, dL/dV)`.
pub fn hr_rollout_backward(
    v: &Matrix,
    h0: &Matrix,
    xs: &[Matrix],
    gs: &[Matrix],
) -> (Matrix, Matrix) {
    assert_eq!(xs.len(), gs.len());
    let hs = hr_rollout_states(v, h0, xs);
    let mut dv = Matrix::zeros(v.rows, v.cols);
    let mut g = Matrix::zeros(h0.rows, h0.cols);
    for t in (0..xs.len()).rev() {
        g = g.add(&gs[t]);
        let (dh, dvs) = hr_chain_backward(v, &hs[t], &g);
        dv = dv.add(&dvs);
        g = dh;
    }
    (g, dv)
}

/// Central finite-difference gradient of a scalar function of `x`,
/// `g_ij = (f(x + ε e_ij) − f(x − ε e_ij)) / 2ε` — the reference every
/// analytic backward here is verified against.
pub fn finite_diff(x: &Matrix, eps: f32, mut f: impl FnMut(&Matrix) -> f32) -> Matrix {
    let mut g = Matrix::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        for j in 0..x.cols {
            let mut xp = x.clone();
            xp[(i, j)] += eps;
            let mut xm = x.clone();
            xm[(i, j)] -= eps;
            g[(i, j)] = (f(&xp) - f(&xm)) / (2.0 * eps);
        }
    }
    g
}

/// The PR-4 backward path, frozen verbatim: per-op output allocation,
/// materialized transposes (`.t()` before every TN/NT product), the
/// legacy tiled GEMM, and a fresh normalize/build_s/triu_inv per tape.
///
/// Kept for two jobs:
/// * **measurement baseline** — `benches/bptt_native` and `BENCH_5.json`
///   report the fused substrate's speedup over exactly this code, on the
///   same machine, so the delta isolates allocation + transpose +
///   fusion structure rather than kernel drift;
/// * **parity oracle** — both paths share the ascending-`k` accumulation
///   contract (`linalg::gemm` module docs), so the fused rollout must
///   reproduce this one bit-for-bit, which the property tests assert.
pub mod reference {
    use crate::linalg::gemm::legacy;
    use crate::linalg::{triu_inv, Matrix};

    use super::super::cwy::{self, normalize};

    fn build_s(u: &Matrix) -> Matrix {
        let l = u.cols;
        let gram = legacy::matmul(&u.t(), u);
        let mut s = Matrix::zeros(l, l);
        for i in 0..l {
            s[(i, i)] = 0.5;
            for j in i + 1..l {
                s[(i, j)] = gram[(i, j)];
            }
        }
        s
    }

    struct Tape {
        u: Matrix,
        sinv: Matrix,
        norms: Vec<f32>,
        degenerate: Vec<bool>,
        du: Matrix,
        da: Matrix,
    }

    impl Tape {
        fn new(v: &Matrix) -> Tape {
            let u = normalize(v);
            let sinv = triu_inv(&build_s(&u));
            let norms = cwy::row_norms(v);
            let degenerate = norms.iter().map(|&n| n <= cwy::DEGENERATE_NORM).collect();
            let (du, da) = (Matrix::zeros(u.rows, u.cols), Matrix::zeros(u.cols, u.cols));
            Tape { u, sinv, norms, degenerate, du, da }
        }

        fn apply(&self, h: &Matrix) -> Matrix {
            let t = legacy::matmul(h, &self.u);
            let v = legacy::matmul(&t, &self.sinv);
            h.sub(&legacy::matmul(&v, &self.u.t()))
        }

        fn apply_backward(&mut self, h: &Matrix, g: &Matrix) -> Matrix {
            let u = &self.u;
            let a = &self.sinv;
            let gu = legacy::matmul(g, u);
            let hu = legacy::matmul(h, u);
            let dh = g.sub(&legacy::matmul(&legacy::matmul(&gu, &a.t()), &u.t()));
            let du_h = legacy::matmul(&legacy::matmul(&h.t(), &gu), &a.t());
            let du_g = legacy::matmul(&legacy::matmul(&g.t(), &hu), a);
            self.du = self.du.sub(&du_h).sub(&du_g);
            self.da = self.da.sub(&legacy::matmul(&hu.t(), &gu));
            dh
        }

        fn into_dv(self, v: &Matrix) -> Matrix {
            let l = self.u.cols;
            let ds = legacy::matmul(&legacy::matmul(&self.sinv.t(), &self.da), &self.sinv.t())
                .scale(-1.0);
            let mut p = Matrix::zeros(l, l);
            for i in 0..l {
                for j in i + 1..l {
                    p[(i, j)] = ds[(i, j)];
                }
            }
            let du = self.du.add(&legacy::matmul(&self.u, &p.add(&p.t())));
            let n = self.u.rows;
            let mut dv = Matrix::zeros(v.rows, v.cols);
            for i in 0..l {
                if self.degenerate[i] {
                    continue;
                }
                let dot: f32 = (0..n).map(|j| self.u[(j, i)] * du[(j, i)]).sum();
                for j in 0..n {
                    dv[(i, j)] = (du[(j, i)] - self.u[(j, i)] * dot) / self.norms[i];
                }
            }
            dv
        }
    }

    /// PR-4 `cwy_rollout_backward`: the allocating BPTT this PR's fused
    /// path is measured against.
    pub fn cwy_rollout_backward(
        v: &Matrix,
        h0: &Matrix,
        xs: &[Matrix],
        gs: &[Matrix],
    ) -> (Matrix, Matrix) {
        assert_eq!(xs.len(), gs.len());
        let mut grad = Tape::new(v);
        let mut hs = Vec::with_capacity(xs.len() + 1);
        hs.push(h0.clone());
        for x in xs {
            let next = grad.apply(hs.last().unwrap()).add(x);
            hs.push(next);
        }
        let mut g = Matrix::zeros(h0.rows, h0.cols);
        for t in (0..xs.len()).rev() {
            g = g.add(&gs[t]);
            g = grad.apply_backward(&hs[t], &g);
        }
        (g, grad.into_dv(v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orthogonal::tcwy;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    /// FD step and the f32 tolerance scale it implies: central differences
    /// on an f32 forward pass carry ~|f|·1e-7/ε noise, so comparisons are
    /// scaled by max(1, ‖grad‖∞) with a 10× margin over the measured worst
    /// case (calibrated against the float64 reference).
    const EPS: f32 = 3e-3;
    const TOL: f32 = 2e-3;

    fn inner(a: &Matrix, b: &Matrix) -> f32 {
        a.data.iter().zip(&b.data).map(|(x, y)| x * y).sum()
    }

    fn scaled_diff(analytic: &Matrix, numeric: &Matrix) -> f32 {
        let scale = numeric.data.iter().fold(1.0f32, |m, x| m.max(x.abs()));
        analytic.max_abs_diff(numeric) / scale
    }

    #[test]
    fn prop_cwy_apply_backward_matches_fd() {
        forall(
            8,
            |rng| {
                let l = 1 + rng.below(5) as usize;
                let n = l + 1 + rng.below(8) as usize;
                let b = 1 + rng.below(3) as usize;
                (
                    Matrix::random_normal(rng, l, n, 1.0),
                    Matrix::random_normal(rng, b, n, 1.0),
                    Matrix::random_normal(rng, b, n, 1.0),
                )
            },
            |(v, h, g)| {
                let mut grad = CwyGrad::new(v);
                let dh = grad.apply_backward(h, g);
                let dv = grad.into_dv(v);
                let dv_fd = finite_diff(v, EPS, |vv| {
                    inner(g, &CwyOperator::new(vv).apply(h))
                });
                let dh_fd = finite_diff(h, EPS, |hh| {
                    inner(g, &CwyOperator::new(v).apply(hh))
                });
                let (ev, eh) = (scaled_diff(&dv, &dv_fd), scaled_diff(&dh, &dh_fd));
                if ev < TOL && eh < TOL {
                    Ok(())
                } else {
                    Err(format!("dV err {ev}, dH err {eh}"))
                }
            },
        );
    }

    #[test]
    fn prop_cwy_matrix_backward_matches_fd() {
        forall(
            8,
            |rng| {
                let l = 1 + rng.below(5) as usize;
                let n = l + 1 + rng.below(8) as usize;
                (
                    Matrix::random_normal(rng, l, n, 1.0),
                    Matrix::random_normal(rng, n, n, 1.0),
                )
            },
            |(v, gq)| {
                let mut grad = CwyGrad::new(v);
                grad.matrix_backward(gq);
                let dv = grad.into_dv(v);
                let dv_fd = finite_diff(v, EPS, |vv| inner(gq, &cwy::matrix(vv)));
                let e = scaled_diff(&dv, &dv_fd);
                if e < TOL { Ok(()) } else { Err(format!("dV err {e}")) }
            },
        );
    }

    #[test]
    fn prop_tcwy_backward_matches_fd() {
        forall(
            8,
            |rng| {
                let m = 1 + rng.below(4) as usize;
                let n = m + 1 + rng.below(8) as usize;
                (
                    Matrix::random_normal(rng, m, n, 1.0),
                    Matrix::random_normal(rng, n, m, 1.0),
                )
            },
            |(v, g)| {
                let mut grad = TcwyGrad::new(v);
                grad.matrix_backward(g);
                let dv = grad.into_dv(v);
                let dv_fd = finite_diff(v, EPS, |vv| inner(g, &tcwy::matrix(vv)));
                let e = scaled_diff(&dv, &dv_fd);
                if e < TOL { Ok(()) } else { Err(format!("dV err {e}")) }
            },
        );
    }

    /// T-CWY degenerates to the square orthogonal case at M = N; the
    /// backward must stay exact there too (the rnn_copy tcwy cell uses
    /// this regime).
    #[test]
    fn prop_tcwy_square_backward_matches_fd() {
        forall(
            6,
            |rng| {
                let n = 2 + rng.below(6) as usize;
                (
                    Matrix::random_normal(rng, n, n, 1.0),
                    Matrix::random_normal(rng, n, n, 1.0),
                )
            },
            |(v, g)| {
                let mut grad = TcwyGrad::new(v);
                grad.matrix_backward(g);
                let dv = grad.into_dv(v);
                let dv_fd = finite_diff(v, EPS, |vv| inner(g, &tcwy::matrix(vv)));
                let e = scaled_diff(&dv, &dv_fd);
                if e < TOL { Ok(()) } else { Err(format!("dV err {e}")) }
            },
        );
    }

    /// The tape's Ω must equal the standalone construction, and rebuilding
    /// a recycled tape for new parameters must equal a fresh tape.
    #[test]
    fn tcwy_omega_and_recompute_match_fresh() {
        let mut rng = Pcg32::seeded(91);
        let mut ws = Workspace::new();
        let v1 = Matrix::random_normal(&mut rng, 4, 9, 1.0);
        let v2 = Matrix::random_normal(&mut rng, 4, 9, 1.0);
        let mut grad = TcwyGrad::new(&v1);
        let mut omega = Matrix::zeros(9, 4);
        grad.omega_into(&mut omega);
        assert!(omega.max_abs_diff(&tcwy::matrix(&v1)) < 1e-6);
        // Recycle for v2: same dv as a fresh tape.
        grad.recompute(&v2, &mut ws);
        grad.omega_into(&mut omega);
        assert!(omega.max_abs_diff(&tcwy::matrix(&v2)) < 1e-6);
        let g = Matrix::random_normal(&mut rng, 9, 4, 1.0);
        grad.matrix_backward_ws(&g, &mut ws);
        let dv_recycled = {
            let mut dv = Matrix::zeros(4, 9);
            grad.finish_into(&v2, &mut dv, &mut ws);
            dv
        };
        let mut fresh = TcwyGrad::new(&v2);
        fresh.matrix_backward(&g);
        assert_eq!(dv_recycled, fresh.into_dv(&v2));
    }

    #[test]
    fn prop_hr_chain_backward_matches_fd() {
        forall(
            8,
            |rng| {
                let l = 1 + rng.below(5) as usize;
                let n = l + 1 + rng.below(8) as usize;
                let b = 1 + rng.below(3) as usize;
                (
                    Matrix::random_normal(rng, l, n, 1.0),
                    Matrix::random_normal(rng, b, n, 1.0),
                    Matrix::random_normal(rng, b, n, 1.0),
                )
            },
            |(v, h, g)| {
                let (dh, dv) = hr_chain_backward(v, h, g);
                let apply = |vv: &Matrix, hh: &Matrix| {
                    let mut out = hh.clone();
                    householder::apply_chain(vv, &mut out);
                    inner(g, &out)
                };
                let dv_fd = finite_diff(v, EPS, |vv| apply(vv, h));
                let dh_fd = finite_diff(h, EPS, |hh| apply(v, hh));
                let (ev, eh) = (scaled_diff(&dv, &dv_fd), scaled_diff(&dh, &dh_fd));
                if ev < TOL && eh < TOL {
                    Ok(())
                } else {
                    Err(format!("dV err {ev}, dH err {eh}"))
                }
            },
        );
    }

    /// BPTT through a short rollout vs finite differences — the property
    /// behind the rnn_copy training path (one-step and multi-step).
    #[test]
    fn prop_rollout_bptt_matches_fd() {
        forall(
            6,
            |rng| {
                let l = 1 + rng.below(4) as usize;
                let n = l + 1 + rng.below(6) as usize;
                let b = 1 + rng.below(2) as usize;
                let t = 1 + rng.below(3) as usize; // includes the one-step case
                let v = Matrix::random_normal(rng, l, n, 1.0);
                let h0 = Matrix::random_normal(rng, b, n, 1.0);
                let xs: Vec<Matrix> = (0..t)
                    .map(|_| Matrix::random_normal(rng, b, n, 1.0))
                    .collect();
                let gs: Vec<Matrix> = (0..t)
                    .map(|_| Matrix::random_normal(rng, b, n, 1.0))
                    .collect();
                (v, h0, xs, gs)
            },
            |(v, h0, xs, gs)| {
                let loss = |vv: &Matrix, hh0: &Matrix| {
                    let hs = cwy_rollout_states(vv, hh0, xs);
                    (0..xs.len()).map(|t| inner(&gs[t], &hs[t + 1])).sum::<f32>()
                };
                let (dh0, dv) = cwy_rollout_backward(v, h0, xs, gs);
                let dv_fd = finite_diff(v, EPS, |vv| loss(vv, h0));
                let dh_fd = finite_diff(h0, EPS, |hh| loss(v, hh));
                let (ev, eh) = (scaled_diff(&dv, &dv_fd), scaled_diff(&dh0, &dh_fd));
                // Rollouts compound f32 noise over T steps; widen the
                // margin accordingly.
                if ev < 2.0 * TOL && eh < 2.0 * TOL {
                    Ok(())
                } else {
                    Err(format!("dV err {ev}, dh0 err {eh}"))
                }
            },
        );
    }

    /// The zero-allocation contract's numeric half: the fused in-place
    /// rollout backward reproduces the frozen PR-4 implementation,
    /// across random shapes including L = 1 / B = 1 / T = 1.  Under the
    /// portable kernel the two share the ascending-`k` accumulation
    /// order end to end, so the comparison is bit-for-bit; under the
    /// AVX2+FMA kernel the fused path groups the reduction differently
    /// (lane accumulators, single-rounded madds) and the comparison is
    /// f32-scaled instead.  CI exercises both regimes: the default leg
    /// dispatches AVX2 where supported, a matrix leg forces the portable
    /// kernel via `CWY_PORTABLE_KERNEL=1` and takes the bitwise branch.
    #[test]
    fn prop_fused_rollout_bitwise_matches_pr4_reference() {
        let bitwise = gemm::active_kernel() == gemm::KernelKind::Portable;
        forall(
            10,
            |rng| {
                let l = 1 + rng.below(6) as usize;
                let n = l + 1 + rng.below(10) as usize;
                let b = 1 + rng.below(4) as usize;
                let t = 1 + rng.below(5) as usize;
                let v = Matrix::random_normal(rng, l, n, 1.0);
                let h0 = Matrix::random_normal(rng, b, n, 1.0);
                let xs: Vec<Matrix> = (0..t)
                    .map(|_| Matrix::random_normal(rng, b, n, 0.5))
                    .collect();
                let gs: Vec<Matrix> = (0..t)
                    .map(|_| Matrix::random_normal(rng, b, n, 0.5))
                    .collect();
                (v, h0, xs, gs)
            },
            |(v, h0, xs, gs)| {
                let (dh_new, dv_new) = cwy_rollout_backward(v, h0, xs, gs);
                let (dh_ref, dv_ref) = reference::cwy_rollout_backward(v, h0, xs, gs);
                if bitwise {
                    let bits =
                        |m: &Matrix| m.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                    if bits(&dh_new) == bits(&dh_ref) && bits(&dv_new) == bits(&dv_ref) {
                        return Ok(());
                    }
                } else {
                    let (eh, ev) =
                        (scaled_diff(&dh_new, &dh_ref), scaled_diff(&dv_new, &dv_ref));
                    if eh < 5e-4 && ev < 5e-4 {
                        return Ok(());
                    }
                }
                Err(format!(
                    "fused vs PR-4 drift (bitwise={bitwise}): |dh| {} |dv| {}",
                    dh_new.max_abs_diff(&dh_ref),
                    dv_new.max_abs_diff(&dv_ref)
                ))
            },
        );
    }

    /// A recycled tape (recompute) behaves exactly like a fresh one — the
    /// property that lets the rollout workspace reuse its tape across
    /// training steps.
    #[test]
    fn recomputed_tape_matches_fresh_tape() {
        let mut rng = Pcg32::seeded(53);
        let mut ws = Workspace::new();
        let v1 = Matrix::random_normal(&mut rng, 5, 11, 1.0);
        let v2 = Matrix::random_normal(&mut rng, 5, 11, 1.0);
        let h = Matrix::random_normal(&mut rng, 3, 11, 1.0);
        let g0 = Matrix::random_normal(&mut rng, 3, 11, 1.0);

        let mut recycled = CwyGrad::new(&v1);
        let mut sink = Matrix::zeros(3, 11);
        recycled.apply_forward_into(&h, &mut sink, &mut ws);
        let mut g = g0.clone();
        recycled.apply_backward_in_place(&h, &mut g, &mut ws);
        // Now rebuild for v2 and run the same step as a fresh tape.
        recycled.recompute(&v2, &mut ws);
        let mut out_recycled = Matrix::zeros(3, 11);
        recycled.apply_forward_into(&h, &mut out_recycled, &mut ws);
        let mut g_recycled = g0.clone();
        recycled.apply_backward_in_place(&h, &mut g_recycled, &mut ws);
        let mut dv_recycled = Matrix::zeros(5, 11);
        recycled.finish_into(&v2, &mut dv_recycled, &mut ws);

        let mut fresh = CwyGrad::new(&v2);
        let out_fresh = {
            let mut out = Matrix::zeros(3, 11);
            fresh.apply_forward_into(&h, &mut out, &mut ws);
            out
        };
        let dh_fresh = fresh.apply_backward(&h, &g0);
        let dv_fresh = fresh.into_dv(&v2);
        assert_eq!(out_recycled, out_fresh);
        assert_eq!(g_recycled, dh_fresh);
        assert_eq!(dv_recycled, dv_fresh);
    }

    /// Thm 2 at the gradient level: the fused CWY backward and the
    /// sequential per-Householder backward differentiate the *same*
    /// function, so their gradients agree elementwise on the same rollout.
    /// Bound scales with the gradient magnitude (f32, two genuinely
    /// different algorithms); the PR's absolute 1e-4 acceptance bound is
    /// asserted on the loss-normalized fixture rollout in
    /// `integration_trainer::native::copy_cwy_and_hr_gradients_agree...`.
    #[test]
    fn cwy_and_hr_rollout_gradients_agree() {
        let mut rng = Pcg32::seeded(41);
        let (l, n, b, t) = (6, 16, 3, 5);
        let v = Matrix::random_normal(&mut rng, l, n, 1.0);
        let h0 = Matrix::random_normal(&mut rng, b, n, 1.0);
        let xs: Vec<Matrix> = (0..t)
            .map(|_| Matrix::random_normal(&mut rng, b, n, 1.0))
            .collect();
        let gs: Vec<Matrix> = (0..t)
            .map(|_| Matrix::random_normal(&mut rng, b, n, 1.0))
            .collect();
        let (dh_cwy, dv_cwy) = cwy_rollout_backward(&v, &h0, &xs, &gs);
        let (dh_hr, dv_hr) = hr_rollout_backward(&v, &h0, &xs, &gs);
        let dv_scale = dv_hr.data.iter().fold(1.0f32, |m, x| m.max(x.abs()));
        let dh_scale = dh_hr.data.iter().fold(1.0f32, |m, x| m.max(x.abs()));
        let dv_err = dv_cwy.max_abs_diff(&dv_hr) / dv_scale;
        let dh_err = dh_cwy.max_abs_diff(&dh_hr) / dh_scale;
        assert!(dv_err <= 1e-4, "dV disagreement {dv_err} (scale {dv_scale})");
        assert!(dh_err <= 1e-4, "dh0 disagreement {dh_err} (scale {dh_scale})");
    }

    /// Regression for the normalize fix: a degenerate reflection row gets
    /// gradient exactly zero (the parametrization is constant there), and
    /// every other gradient entry stays finite.
    #[test]
    fn degenerate_row_gets_zero_gradient() {
        let mut rng = Pcg32::seeded(17);
        let mut v = Matrix::random_normal(&mut rng, 4, 8, 1.0);
        for j in 0..8 {
            v[(1, j)] = 0.0;
        }
        let h = Matrix::random_normal(&mut rng, 2, 8, 1.0);
        let g = Matrix::random_normal(&mut rng, 2, 8, 1.0);
        let mut grad = CwyGrad::new(&v);
        grad.apply_backward(&h, &g);
        let dv = grad.into_dv(&v);
        assert!(dv.data.iter().all(|x| x.is_finite()), "non-finite gradient");
        for j in 0..8 {
            assert_eq!(dv[(1, j)], 0.0, "degenerate row must have zero grad");
        }
        // Healthy rows still carry signal.
        assert!(dv.frobenius() > 0.0);
        // The HR chain divides by ‖v‖² and must apply the same explicit
        // handling: zero gradient for the degenerate row, no NaN anywhere.
        let (dh_hr, dv_hr) = hr_chain_backward(&v, &h, &g);
        assert!(dh_hr.data.iter().all(|x| x.is_finite()), "non-finite HR dH");
        assert!(dv_hr.data.iter().all(|x| x.is_finite()), "non-finite HR dV");
        for j in 0..8 {
            assert_eq!(dv_hr[(1, j)], 0.0, "degenerate row must have zero HR grad");
        }
        assert!(dv_hr.frobenius() > 0.0);
    }
}
