//! Native T-CWY Stiefel parametrization (paper Thm 3):
//! Omega = [I; 0] - U S^{-1} U_1^T in St(N, M).

use super::cwy::{build_s, normalize};
use crate::linalg::{triu_inv, Matrix};

/// Construct Omega from raw vectors V (M, N), M <= N.
pub fn matrix(v: &Matrix) -> Matrix {
    let (m, n) = (v.rows, v.cols);
    assert!(m <= n, "T-CWY needs M <= N");
    let u = normalize(v); // (N, M)
    let sinv = triu_inv(&build_s(&u));
    // U_1 = top M x M block of U.
    let mut u1t = Matrix::zeros(m, m);
    for i in 0..m {
        for j in 0..m {
            u1t[(i, j)] = u[(j, i)];
        }
    }
    let w = sinv.matmul(&u1t); // (M, M)
    Matrix::eye_rect(n, m).sub(&u.matmul(&w))
}

/// Check Thm 3's claim Omega = (H(v_1)...H(v_M))[:, :M] without forming the
/// N x N product — used by tests against the explicit product.
pub fn first_columns_of_product(v: &Matrix) -> Matrix {
    let q = super::householder::matrix(v);
    let (m, n) = (v.rows, v.cols);
    let mut out = Matrix::zeros(n, m);
    for i in 0..n {
        for j in 0..m {
            out[(i, j)] = q[(i, j)];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn lands_on_stiefel() {
        forall(
            16,
            |rng| {
                let m = 1 + rng.below(6) as usize;
                let n = m + 1 + rng.below(12) as usize;
                Matrix::random_normal(rng, m, n, 1.0)
            },
            |v| {
                let omega = matrix(v);
                let d = omega.orthogonality_defect();
                if d < 1e-3 { Ok(()) } else { Err(format!("defect {d}")) }
            },
        );
    }

    #[test]
    fn frame_gram_is_identity() {
        // Stiefel membership stated explicitly: Omega^T Omega = I_k for
        // the truncated k-column frame (not just the defect scalar).
        forall(
            16,
            |rng| {
                let m = 1 + rng.below(6) as usize;
                let n = m + 1 + rng.below(12) as usize;
                Matrix::random_normal(rng, m, n, 1.0)
            },
            |v| {
                let omega = matrix(v);
                let gram = omega.t().matmul(&omega);
                let d = gram.max_abs_diff(&Matrix::eye(v.rows));
                if d < 1e-3 { Ok(()) } else { Err(format!("|Q^T Q - I| = {d}")) }
            },
        );
    }

    #[test]
    fn equals_full_cwy_when_square() {
        // With k = n the `[I; 0]` slab is the full identity and U_1 = U,
        // so Thm 3's Omega degenerates to Thm 2's full CWY transform.
        forall(
            12,
            |rng| {
                let n = 2 + rng.below(10) as usize;
                Matrix::random_normal(rng, n, n, 1.0)
            },
            |v| {
                let d = matrix(v).max_abs_diff(&crate::orthogonal::cwy::matrix(v));
                if d < 5e-4 { Ok(()) } else { Err(format!("tcwy vs cwy diff {d}")) }
            },
        );
    }

    #[test]
    fn equals_truncated_cwy_product() {
        // Thm 3: Omega equals the first M columns of the full reflection
        // product — verified against the explicit sequential product.
        forall(
            12,
            |rng| {
                let m = 1 + rng.below(5) as usize;
                let n = m + 2 + rng.below(8) as usize;
                Matrix::random_normal(rng, m, n, 1.0)
            },
            |v| {
                let direct = matrix(v);
                let via_product = first_columns_of_product(v);
                let d = direct.max_abs_diff(&via_product);
                if d < 5e-4 { Ok(()) } else { Err(format!("diff {d}")) }
            },
        );
    }
}
