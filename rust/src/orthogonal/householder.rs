//! Sequential Householder reflections (Mhammedi et al. 2017) — the native
//! baseline CWY is measured against (paper Fig. 2).

use crate::linalg::Matrix;

/// Apply H(v) = I - 2 v v^T / ||v||^2 to a vector in place.
pub fn reflect_vec(v: &[f32], h: &mut [f32]) {
    let vnorm2: f32 = v.iter().map(|x| x * x).sum();
    let dot: f32 = v.iter().zip(h.iter()).map(|(a, b)| a * b).sum();
    let c = 2.0 * dot / vnorm2;
    for (hi, vi) in h.iter_mut().zip(v) {
        *hi -= c * vi;
    }
}

/// h <- (H(v_1) ... H(v_L))^T h applied row-wise to a batch (B, N);
/// the chain is inherently sequential in L — the bottleneck the paper fixes.
pub fn apply_chain(vs: &Matrix, batch: &mut Matrix) {
    for l in 0..vs.rows {
        let v = vs.row(l).to_vec();
        for b in 0..batch.rows {
            reflect_vec(&v, batch.row_mut(b));
        }
    }
}

/// Materialize Q = H(v_1) ... H(v_L) (O(L N^2), sequential).
pub fn matrix(vs: &Matrix) -> Matrix {
    let n = vs.cols;
    let mut q = Matrix::eye(n);
    // Q <- Q H(v): subtract 2 (Q v) v^T / ||v||^2
    for l in 0..vs.rows {
        let v = vs.row(l);
        let vnorm2: f32 = v.iter().map(|x| x * x).sum();
        let qv = q.matvec(v);
        for i in 0..n {
            let c = 2.0 * qv[i] / vnorm2;
            for j in 0..n {
                q[(i, j)] -= c * v[j];
            }
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    #[test]
    fn reflection_is_involution() {
        let mut rng = Pcg32::seeded(21);
        let v: Vec<f32> = rng.normal_vec(8, 1.0);
        let orig: Vec<f32> = rng.normal_vec(8, 1.0);
        let mut h = orig.clone();
        reflect_vec(&v, &mut h);
        reflect_vec(&v, &mut h);
        for (a, b) in h.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn product_is_orthogonal() {
        forall(
            12,
            |rng| {
                let l = 1 + rng.below(6) as usize;
                let n = l + 2 + rng.below(8) as usize;
                Matrix::random_normal(rng, l, n, 1.0)
            },
            |vs| {
                let q = matrix(vs);
                let d = q.orthogonality_defect();
                if d < 1e-4 { Ok(()) } else { Err(format!("defect {d}")) }
            },
        );
    }

    #[test]
    fn chain_matches_matrix() {
        let mut rng = Pcg32::seeded(5);
        let vs = Matrix::random_normal(&mut rng, 4, 10, 1.0);
        let q = matrix(&vs);
        let h0 = Matrix::random_normal(&mut rng, 3, 10, 1.0);
        // rows mapped by Q^T == batch @ Q
        let expect = h0.matmul(&q);
        let mut got = h0.clone();
        apply_chain(&vs, &mut got);
        assert!(expect.max_abs_diff(&got) < 1e-4);
    }
}
