//! Sequential Householder reflections (Mhammedi et al. 2017) — the native
//! baseline CWY is measured against (paper Fig. 2).
//!
//! `H(v)` divides by `‖v‖²` and is undefined at `v ≈ 0`: degenerate
//! vectors (norm ≤ [`cwy::DEGENERATE_NORM`]) are treated as the
//! **identity** reflection everywhere in this module, matching the
//! zero-gradient convention of `backward::hr_chain_backward` so forward
//! and backward differentiate the same function and neither emits NaN.
//! (The CWY path instead renormalizes such rows to a canonical basis
//! vector — the two parametrizations agree only on non-degenerate rows.)

use super::cwy;
use crate::linalg::{simd, Matrix};

/// Apply H(v) = I - 2 v v^T / ||v||^2 to a vector in place; a degenerate
/// `v` (see module docs) is the identity.
///
/// Norm, dot, and the rank-1 update run on the dispatched lane-width
/// primitives (`linalg::simd`); the portable path keeps the exact serial
/// order of the scalar loops this function always had.
pub fn reflect_vec(v: &[f32], h: &mut [f32]) {
    let vnorm2 = simd::norm_sq(v);
    if vnorm2 <= cwy::DEGENERATE_NORM * cwy::DEGENERATE_NORM {
        return;
    }
    let c = 2.0 * simd::dot(v, h) / vnorm2;
    simd::axpy(-c, v, h);
}

/// h <- (H(v_1) ... H(v_L))^T h applied row-wise to a batch (B, N);
/// the chain is inherently sequential in L — the bottleneck the paper fixes.
pub fn apply_chain(vs: &Matrix, batch: &mut Matrix) {
    for l in 0..vs.rows {
        let v = vs.row(l).to_vec();
        for b in 0..batch.rows {
            reflect_vec(&v, batch.row_mut(b));
        }
    }
}

/// Materialize Q = H(v_1) ... H(v_L) (O(L N^2), sequential); degenerate
/// rows contribute the identity (see module docs).
pub fn matrix(vs: &Matrix) -> Matrix {
    let n = vs.cols;
    let mut q = Matrix::eye(n);
    // Q <- Q H(v): subtract 2 (Q v) v^T / ||v||^2
    for l in 0..vs.rows {
        let v = vs.row(l);
        let vnorm2 = simd::norm_sq(v);
        if vnorm2 <= cwy::DEGENERATE_NORM * cwy::DEGENERATE_NORM {
            continue;
        }
        let qv = q.matvec(v);
        for (i, &qvi) in qv.iter().enumerate() {
            simd::axpy(-2.0 * qvi / vnorm2, v, q.row_mut(i));
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    #[test]
    fn reflection_is_involution() {
        let mut rng = Pcg32::seeded(21);
        let v: Vec<f32> = rng.normal_vec(8, 1.0);
        let orig: Vec<f32> = rng.normal_vec(8, 1.0);
        let mut h = orig.clone();
        reflect_vec(&v, &mut h);
        reflect_vec(&v, &mut h);
        for (a, b) in h.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn product_is_orthogonal() {
        forall(
            12,
            |rng| {
                let l = 1 + rng.below(6) as usize;
                let n = l + 2 + rng.below(8) as usize;
                Matrix::random_normal(rng, l, n, 1.0)
            },
            |vs| {
                let q = matrix(vs);
                let d = q.orthogonality_defect();
                if d < 1e-4 { Ok(()) } else { Err(format!("defect {d}")) }
            },
        );
    }

    /// Regression (ISSUE 4 satellite): a near-zero reflection vector used
    /// to divide by ~0 and poison the chain with NaN; it must now act as
    /// the identity, keeping Q finite and exactly orthogonal.
    #[test]
    fn degenerate_vector_is_identity_reflection() {
        let mut rng = Pcg32::seeded(23);
        let mut vs = Matrix::random_normal(&mut rng, 3, 8, 1.0);
        for j in 0..8 {
            vs[(1, j)] = 1e-9;
        }
        let q = matrix(&vs);
        assert!(q.data.iter().all(|x| x.is_finite()), "non-finite Q");
        assert!(q.orthogonality_defect() < 1e-4);
        // The degenerate row contributes nothing: dropping it gives the
        // same product.
        let kept = Matrix::from_rows(
            2,
            8,
            [vs.row(0), vs.row(2)].concat(),
        );
        assert!(q.max_abs_diff(&matrix(&kept)) < 1e-6);
        // apply_chain agrees with the materialized product.
        let h0 = Matrix::random_normal(&mut rng, 2, 8, 1.0);
        let mut h = h0.clone();
        apply_chain(&vs, &mut h);
        assert!(h.max_abs_diff(&h0.matmul(&q)) < 1e-4);
    }

    #[test]
    fn chain_matches_matrix() {
        let mut rng = Pcg32::seeded(5);
        let vs = Matrix::random_normal(&mut rng, 4, 10, 1.0);
        let q = matrix(&vs);
        let h0 = Matrix::random_normal(&mut rng, 3, 10, 1.0);
        // rows mapped by Q^T == batch @ Q
        let expect = h0.matmul(&q);
        let mut got = h0.clone();
        apply_chain(&vs, &mut got);
        assert!(expect.max_abs_diff(&got) < 1e-4);
    }
}
