//! Orthogonal Weight Normalization (Huang et al. 2018) — native baseline.
//!
//! Omega = V~ (V~^T V~)^{-1/2}, V~ = V - mean(V).  The inverse square root
//! uses the same coupled Newton-Schulz iteration as the exported HLO
//! (`linalg_hlo.newton_schulz_invsqrt`) so both sides agree numerically.

use crate::linalg::Matrix;

/// Coupled Newton-Schulz (G/tr)^{-1/2}; requires SPD G.
pub fn newton_schulz_invsqrt(g: &Matrix, iters: usize) -> Matrix {
    let m = g.rows;
    let tr: f32 = (0..m).map(|i| g[(i, i)]).sum();
    let eye = Matrix::eye(m);
    let mut y = g.scale(1.0 / tr);
    let mut z = eye.clone();
    for _ in 0..iters {
        let t = eye.scale(3.0).sub(&z.matmul(&y)).scale(0.5);
        y = y.matmul(&t);
        z = t.matmul(&z);
    }
    z.scale(1.0 / tr.sqrt())
}

/// OWN map: V (N, M) -> Omega in St(N, M).
pub fn matrix(v: &Matrix) -> Matrix {
    let (n, m) = (v.rows, v.cols);
    // Center columns (subtract the column mean, i.e. 1 1^T V / N).
    let mut vc = v.clone();
    for j in 0..m {
        let mean: f32 = (0..n).map(|i| v[(i, j)]).sum::<f32>() / n as f32;
        for i in 0..n {
            vc[(i, j)] -= mean;
        }
    }
    let mut g = vc.t().matmul(&vc);
    for i in 0..m {
        g[(i, i)] += 1e-5;
    }
    vc.matmul(&newton_schulz_invsqrt(&g, 30))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn invsqrt_property() {
        forall(
            10,
            |rng| {
                let m = 2 + rng.below(6) as usize;
                let a = Matrix::random_normal(rng, m + 4, m, 1.0);
                a.t().matmul(&a) // SPD
            },
            |g| {
                let zi = newton_schulz_invsqrt(g, 40);
                // zi * G * zi should be I
                let back = zi.matmul(g).matmul(&zi);
                let d = back.max_abs_diff(&Matrix::eye(g.rows));
                if d < 5e-2 { Ok(()) } else { Err(format!("defect {d}")) }
            },
        );
    }

    #[test]
    fn own_lands_on_stiefel() {
        forall(
            10,
            |rng| {
                let m = 2 + rng.below(5) as usize;
                let n = m + 6 + rng.below(10) as usize;
                Matrix::random_normal(rng, n, m, 0.3)
            },
            |v| {
                let omega = matrix(v);
                let d = omega.orthogonality_defect();
                if d < 5e-2 { Ok(()) } else { Err(format!("defect {d}")) }
            },
        );
    }
}
