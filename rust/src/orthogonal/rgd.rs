//! Riemannian gradient descent on St(N, M) — native baselines for the four
//! RGD variants of the paper's Table 2 (Appendix A, SMW low-rank form).

use crate::linalg::{gauss_jordan_inv, householder_qr, Matrix};

/// Inner-product choice for the tangent projection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Inner {
    Canonical,
    Euclidean,
}

/// Retraction choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Retraction {
    Cayley,
    Qr,
}

/// Low-rank factors B, C with lr * A = B C^T (paper Appendix A).
fn bc_factors(omega: &Matrix, grad: &Matrix, lr: f32, inner: Inner) -> (Matrix, Matrix) {
    let (n, m) = (omega.rows, omega.cols);
    match inner {
        Inner::Canonical => {
            let mut b = Matrix::zeros(n, 2 * m);
            let mut c = Matrix::zeros(n, 2 * m);
            for i in 0..n {
                for j in 0..m {
                    b[(i, j)] = lr * grad[(i, j)];
                    b[(i, m + j)] = lr * omega[(i, j)];
                    c[(i, j)] = omega[(i, j)];
                    c[(i, m + j)] = -grad[(i, j)];
                }
            }
            (b, c)
        }
        Inner::Euclidean => {
            let e = grad.t().matmul(omega).sub(&omega.t().matmul(grad)); // (M, M)
            let oe = omega.matmul(&e).scale(0.5);
            let mut b = Matrix::zeros(n, 3 * m);
            let mut c = Matrix::zeros(n, 3 * m);
            for i in 0..n {
                for j in 0..m {
                    b[(i, j)] = lr * grad[(i, j)];
                    b[(i, m + j)] = lr * omega[(i, j)];
                    b[(i, 2 * m + j)] = lr * oe[(i, j)];
                    c[(i, j)] = omega[(i, j)];
                    c[(i, m + j)] = -grad[(i, j)];
                    c[(i, 2 * m + j)] = omega[(i, j)];
                }
            }
            (b, c)
        }
    }
}

/// One RGD step with Cayley retraction via Sherman-Morrison-Woodbury:
/// Omega' = Cayley(lr A) Omega = Omega - B (I + C^T B / 2)^{-1} (C^T Omega).
/// Note Cayley(eta A) ~ I - eta A, so a *positive* step size descends.
pub fn cayley_step(omega: &Matrix, grad: &Matrix, lr: f32, inner: Inner) -> Matrix {
    let (b, c) = bc_factors(omega, grad, lr, inner);
    let d = b.cols;
    let inner_mat = Matrix::eye(d).add(&c.t().matmul(&b).scale(0.5));
    let rhs = c.t().matmul(omega);
    omega.sub(&b.matmul(&gauss_jordan_inv(&inner_mat).matmul(&rhs)))
}

/// One RGD step with QR retraction: Omega' = qf(Omega - lr * A Omega).
pub fn qr_step(omega: &Matrix, grad: &Matrix, lr: f32, inner: Inner) -> Matrix {
    let a_omega = match inner {
        Inner::Canonical => {
            let oto = omega.t().matmul(omega);
            grad.matmul(&oto).sub(&omega.matmul(&grad.t().matmul(omega)))
        }
        Inner::Euclidean => {
            let ghat = grad.sub(&omega.matmul(&omega.t().matmul(grad)).scale(0.5));
            let oto = omega.t().matmul(omega);
            ghat.matmul(&oto).sub(&omega.matmul(&ghat.t().matmul(omega)))
        }
    };
    let (q, _r) = householder_qr(&omega.sub(&a_omega.scale(lr)));
    q
}

/// Dispatch over the paper's RGD-A-B naming.
pub fn step(omega: &Matrix, grad: &Matrix, lr: f32, inner: Inner, retr: Retraction) -> Matrix {
    match retr {
        Retraction::Cayley => cayley_step(omega, grad, lr, inner),
        Retraction::Qr => qr_step(omega, grad, lr, inner),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    fn random_stiefel(rng: &mut Pcg32, n: usize, m: usize) -> Matrix {
        let a = Matrix::random_normal(rng, n, m, 1.0);
        householder_qr(&a).0
    }

    #[test]
    fn steps_stay_on_manifold() {
        for inner in [Inner::Canonical, Inner::Euclidean] {
            for retr in [Retraction::Cayley, Retraction::Qr] {
                forall(
                    6,
                    |rng| {
                        let m = 2 + rng.below(4) as usize;
                        let n = m + 4 + rng.below(8) as usize;
                        let omega = random_stiefel(rng, n, m);
                        let grad = Matrix::random_normal(rng, n, m, 0.2);
                        (omega, grad)
                    },
                    |(omega, grad)| {
                        let next = step(omega, grad, 0.1, inner, retr);
                        let d = next.orthogonality_defect();
                        if d < 5e-3 {
                            Ok(())
                        } else {
                            Err(format!("{inner:?}/{retr:?} defect {d}"))
                        }
                    },
                );
            }
        }
    }

    #[test]
    fn descends_a_quadratic() {
        // f(Omega) = ||Omega - Target||_F^2 / 2, grad = Omega - Target.
        let mut rng = Pcg32::seeded(77);
        let target = random_stiefel(&mut rng, 12, 3);
        let mut omega = random_stiefel(&mut rng, 12, 3);
        let f = |o: &Matrix| o.sub(&target).frobenius();
        let before = f(&omega);
        for _ in 0..50 {
            let grad = omega.sub(&target);
            omega = step(&omega, &grad, 0.2, Inner::Canonical, Retraction::Cayley);
        }
        let after = f(&omega);
        assert!(after < before, "no descent: {before} -> {after}");
    }

    #[test]
    fn zero_grad_is_fixed_point() {
        let mut rng = Pcg32::seeded(78);
        let omega = random_stiefel(&mut rng, 10, 4);
        let zero = Matrix::zeros(10, 4);
        for inner in [Inner::Canonical, Inner::Euclidean] {
            let next = cayley_step(&omega, &zero, 0.5, inner);
            assert!(omega.max_abs_diff(&next) < 1e-4);
        }
    }
}
