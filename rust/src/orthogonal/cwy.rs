//! Native CWY transform (paper Thm 2): Q = I - U S^{-1} U^T.
//!
//! This is the rust mirror of `python/compile/kernels/cwy.py`, used for
//! Table 1/2 harnesses, orthogonality property tests, and cross-checking
//! artifact outputs.

use crate::linalg::{triu_inv, Matrix};

/// Precomputed CWY operands for a rollout.
pub struct CwyOperator {
    /// Column-normalized reflection vectors, (N, L).
    pub u: Matrix,
    /// Inverse of S = 0.5 I + striu(U^T U), (L, L).
    pub sinv: Matrix,
}

/// Rows of V with norm at or below this are **degenerate**: the direction
/// v/||v|| is numerically meaningless and its backward pass divides by the
/// norm, so f32 rows this small would produce garbage forward values and
/// NaN/Inf gradients.  Chosen well above f32 denormals and well below any
/// norm a sanely-initialized reflection row can reach.
pub const DEGENERATE_NORM: f32 = 1e-6;

/// Euclidean norms of the rows of V.
pub fn row_norms(v: &Matrix) -> Vec<f32> {
    (0..v.rows)
        .map(|i| v.row(i).iter().map(|x| x * x).sum::<f32>().sqrt())
        .collect()
}

/// Indices of degenerate rows of V (norm <= [`DEGENERATE_NORM`]).
pub fn degenerate_rows(v: &Matrix) -> Vec<usize> {
    row_norms(v)
        .iter()
        .enumerate()
        .filter(|(_, &n)| n <= DEGENERATE_NORM)
        .map(|(i, _)| i)
        .collect()
}

/// Normalize rows of V (L, N) into columns of U (N, L).
///
/// A degenerate row (see [`DEGENERATE_NORM`]) is replaced by the canonical
/// basis vector `e_{i mod N}` — exactly unit norm, so Q stays exactly
/// orthogonal — instead of the old `norm.max(1e-12)` clamp, which scaled
/// noise up to O(1e12) and silently produced a garbage direction.  The
/// replacement is an explicit, documented choice; the backward pass
/// ([`crate::orthogonal::backward`]) treats such rows as constant and
/// assigns them zero gradient.
pub fn normalize(v: &Matrix) -> Matrix {
    let (l, n) = (v.rows, v.cols);
    let mut u = Matrix::zeros(n, l);
    for i in 0..l {
        let row = v.row(i);
        let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm <= DEGENERATE_NORM {
            u[(i % n, i)] = 1.0;
        } else {
            for j in 0..n {
                u[(j, i)] = row[j] / norm;
            }
        }
    }
    u
}

/// S = 0.5 I + striu(U^T U).
pub fn build_s(u: &Matrix) -> Matrix {
    let l = u.cols;
    let gram = u.t().matmul(u);
    let mut s = Matrix::zeros(l, l);
    for i in 0..l {
        s[(i, i)] = 0.5;
        for j in i + 1..l {
            s[(i, j)] = gram[(i, j)];
        }
    }
    s
}

impl CwyOperator {
    /// Precompute from raw reflection vectors V (L, N).
    pub fn new(v: &Matrix) -> CwyOperator {
        let u = normalize(v);
        let sinv = triu_inv(&build_s(&u));
        CwyOperator { u, sinv }
    }

    /// Apply to a batch (B, N) of row-vector hidden states: `out = h @ Q`,
    /// matching the kernels' convention and the sequential HR chain.
    pub fn apply(&self, batch: &Matrix) -> Matrix {
        let t = batch.matmul(&self.u);      // (B, L)
        let v = t.matmul(&self.sinv);       // (B, L)
        batch.sub(&v.matmul(&self.u.t()))
    }

    /// Materialize Q = I - U S^{-1} U^T.
    pub fn matrix(&self) -> Matrix {
        let n = self.u.rows;
        Matrix::eye(n).sub(&self.u.matmul(&self.sinv).matmul(&self.u.t()))
    }
}

/// Convenience: Q from raw vectors.
pub fn matrix(v: &Matrix) -> Matrix {
    CwyOperator::new(v).matrix()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orthogonal::householder;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    #[test]
    fn equals_householder_product() {
        // Thm 2: CWY == explicit sequential reflections in exact arithmetic.
        forall(
            16,
            |rng| {
                let l = 1 + rng.below(8) as usize;
                let n = l + rng.below(12) as usize + 1;
                Matrix::random_normal(rng, l, n, 1.0)
            },
            |v| {
                let q_cwy = matrix(v);
                let q_hr = householder::matrix(v);
                let d = q_cwy.max_abs_diff(&q_hr);
                if d < 5e-4 { Ok(()) } else { Err(format!("cwy vs hr diff {d}")) }
            },
        );
    }

    #[test]
    fn is_orthogonal() {
        forall(
            16,
            |rng| {
                let l = 1 + rng.below(10) as usize;
                let n = l + 4;
                Matrix::random_normal(rng, l, n, 1.0)
            },
            |v| {
                let d = matrix(v).orthogonality_defect();
                if d < 1e-3 { Ok(()) } else { Err(format!("defect {d}")) }
            },
        );
    }

    #[test]
    fn apply_matches_matrix() {
        let mut rng = Pcg32::seeded(31);
        let v = Matrix::random_normal(&mut rng, 6, 16, 1.0);
        let op = CwyOperator::new(&v);
        let h = Matrix::random_normal(&mut rng, 4, 16, 1.0);
        let direct = h.matmul(&op.matrix());
        let fused = op.apply(&h);
        assert!(direct.max_abs_diff(&fused) < 1e-4);
    }

    /// Regression (ISSUE 4): a near-zero reflection row used to be scaled
    /// by `1/norm.max(1e-12)`, producing an O(1e12)-noise direction.  It
    /// must now map to an exact canonical basis vector so Q stays exactly
    /// orthogonal and every entry stays finite.
    #[test]
    fn degenerate_row_renormalizes_explicitly() {
        let mut rng = Pcg32::seeded(77);
        let mut v = Matrix::random_normal(&mut rng, 4, 10, 1.0);
        for j in 0..10 {
            v[(2, j)] = 1e-9; // norm ~3e-9, far below DEGENERATE_NORM
        }
        assert_eq!(degenerate_rows(&v), vec![2]);
        let u = normalize(&v);
        // Column 2 of U is exactly e_2.
        for j in 0..10 {
            let want = if j == 2 { 1.0 } else { 0.0 };
            assert_eq!(u[(j, 2)], want, "u[{j},2]");
        }
        let q = matrix(&v);
        assert!(q.data.iter().all(|x| x.is_finite()), "non-finite Q entry");
        assert!(q.orthogonality_defect() < 1e-3);
        // A healthy V has no degenerate rows and keeps the old behavior.
        let healthy = Matrix::random_normal(&mut rng, 4, 10, 1.0);
        assert!(degenerate_rows(&healthy).is_empty());
    }

    #[test]
    fn norm_preserving() {
        let mut rng = Pcg32::seeded(32);
        let v = Matrix::random_normal(&mut rng, 8, 24, 1.0);
        let op = CwyOperator::new(&v);
        let h = Matrix::random_normal(&mut rng, 5, 24, 1.0);
        let out = op.apply(&h);
        for b in 0..5 {
            let n0: f32 = h.row(b).iter().map(|x| x * x).sum::<f32>().sqrt();
            let n1: f32 = out.row(b).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n0 - n1).abs() / n0 < 1e-3, "row {b}: {n0} vs {n1}");
        }
    }
}
