//! Native CWY transform (paper Thm 2): Q = I - U S^{-1} U^T.
//!
//! This is the rust mirror of `python/compile/kernels/cwy.py`, used for
//! Table 1/2 harnesses, orthogonality property tests, and cross-checking
//! artifact outputs.  Since the zero-allocation substrate pass
//! (DESIGN.md §3.3) the hot entry points are the `_into` variants — the
//! gram matrix streams through the transpose-aware TN gemm path instead
//! of materializing `U^T`, and `apply_into` runs the fused transform with
//! pooled scratch; the allocating forms remain as bitwise-identical
//! wrappers.

use crate::linalg::{
    active_kernel, gemm, gemm_packed, simd, triu_inv, Matrix, PackedOperand, Workspace,
};

/// Precomputed CWY operands for a rollout.
pub struct CwyOperator {
    /// Column-normalized reflection vectors, (N, L).  Mutating this in
    /// place stales [`CwyPacks`] undetectably — rebuild the operator (or
    /// go through a tape `recompute`, which re-packs) instead.
    pub u: Matrix,
    /// Inverse of S = 0.5 I + striu(U^T U), (L, L).  Same in-place
    /// mutation caveat as `u`.
    pub sinv: Matrix,
    /// Pre-packed GEMM panels for `u`/`sinv` — built once at
    /// construction, reused by every `apply_into` across a rollout or a
    /// serve batch.
    packs: CwyPacks,
}

/// The four operand packs the CWY forward/backward hot loops consume,
/// plus the invalidation epoch that ties them to one operator rebuild
/// (ISSUE 9).  The forward applies `U` (NN), `S⁻¹` (NN), and `Uᵀ` (NT)
/// at every timestep; the backward additionally streams `S⁻¹ᵀ` (NT) —
/// so one `repack` per tape rebuild serves 9 packed gemms per timestep.
#[derive(Default)]
pub struct CwyPacks {
    epoch: u64,
    pub(crate) u_nn: PackedOperand,
    pub(crate) u_nt: PackedOperand,
    pub(crate) sinv_nn: PackedOperand,
    pub(crate) sinv_nt: PackedOperand,
}

impl CwyPacks {
    pub fn new() -> CwyPacks {
        CwyPacks::default()
    }

    /// Rebuild all four packs from freshly (re)computed operands.  Bumps
    /// the epoch first: tape recomputes update `u`/`sinv` in place behind
    /// stable pointers, which a pointer/shape key alone cannot see.
    /// Steady-state calls reuse the pack buffers — no allocation once
    /// shapes have settled (tests/alloc_discipline.rs).
    pub fn repack(&mut self, u: &Matrix, sinv: &Matrix) {
        self.epoch = self.epoch.wrapping_add(1);
        let kind = active_kernel();
        self.u_nn.ensure(u, false, kind, self.epoch);
        self.u_nt.ensure(u, true, kind, self.epoch);
        self.sinv_nn.ensure(sinv, false, kind, self.epoch);
        self.sinv_nt.ensure(sinv, true, kind, self.epoch);
    }
}

/// Rows of V with norm at or below this are **degenerate**: the direction
/// v/||v|| is numerically meaningless and its backward pass divides by the
/// norm, so f32 rows this small would produce garbage forward values and
/// NaN/Inf gradients.  Chosen well above f32 denormals and well below any
/// norm a sanely-initialized reflection row can reach.
pub const DEGENERATE_NORM: f32 = 1e-6;

/// Euclidean norms of the rows of V.
pub fn row_norms(v: &Matrix) -> Vec<f32> {
    let mut out = vec![0.0; v.rows];
    row_norms_into(v, &mut out);
    out
}

/// Euclidean norms of the rows of V into a caller-provided buffer — the
/// one pass whose result `normalize`, `degenerate_rows`, and the backward
/// tape all share (they used to each recompute it).
pub fn row_norms_into(v: &Matrix, out: &mut [f32]) {
    assert_eq!(out.len(), v.rows);
    for (i, o) in out.iter_mut().enumerate() {
        // Dispatched lane-width reduction; the portable path keeps the
        // exact serial sum-of-squares order this loop always had.
        *o = simd::norm_sq(v.row(i)).sqrt();
    }
}

/// Indices of degenerate rows of V (norm <= [`DEGENERATE_NORM`]).
pub fn degenerate_rows(v: &Matrix) -> Vec<usize> {
    row_norms(v)
        .iter()
        .enumerate()
        .filter(|(_, &n)| n <= DEGENERATE_NORM)
        .map(|(i, _)| i)
        .collect()
}

/// Normalize rows of V (L, N) into columns of U (N, L).
///
/// A degenerate row (see [`DEGENERATE_NORM`]) is replaced by the canonical
/// basis vector `e_{i mod N}` — exactly unit norm, so Q stays exactly
/// orthogonal — instead of the old `norm.max(1e-12)` clamp, which scaled
/// noise up to O(1e12) and silently produced a garbage direction.  The
/// replacement is an explicit, documented choice; the backward pass
/// ([`crate::orthogonal::backward`]) treats such rows as constant and
/// assigns them zero gradient.
pub fn normalize(v: &Matrix) -> Matrix {
    let norms = row_norms(v);
    normalize_with_norms(v, &norms)
}

/// [`normalize`] with the row norms already in hand, so callers that also
/// need the norms (the backward tape) pay for exactly one pass.
pub fn normalize_with_norms(v: &Matrix, norms: &[f32]) -> Matrix {
    let mut u = Matrix::zeros(v.cols, v.rows);
    normalize_with_norms_into(v, norms, &mut u);
    u
}

/// Allocation-free core of [`normalize`]: writes U into a preshaped
/// `(N, L)` buffer.  Bitwise-identical to the allocating forms.
pub fn normalize_with_norms_into(v: &Matrix, norms: &[f32], u: &mut Matrix) {
    let (l, n) = (v.rows, v.cols);
    assert_eq!(norms.len(), l, "row_norms length mismatch");
    assert_eq!((u.rows, u.cols), (n, l), "normalize output shape");
    u.fill(0.0);
    for i in 0..l {
        let row = v.row(i);
        let norm = norms[i];
        if norm <= DEGENERATE_NORM {
            u[(i % n, i)] = 1.0;
        } else {
            for j in 0..n {
                u[(j, i)] = row[j] / norm;
            }
        }
    }
}

/// S = 0.5 I + striu(U^T U).
pub fn build_s(u: &Matrix) -> Matrix {
    let mut s = Matrix::zeros(u.cols, u.cols);
    let mut ws = Workspace::new();
    build_s_into(u, &mut s, &mut ws);
    s
}

/// Allocation-free [`build_s`]: the gram `U^T U` streams through the TN
/// gemm path (no materialized `U^T`) into pooled scratch, and S is
/// assembled in a preshaped `(L, L)` buffer.
pub fn build_s_into(u: &Matrix, s: &mut Matrix, ws: &mut Workspace) {
    let l = u.cols;
    assert_eq!((s.rows, s.cols), (l, l), "build_s output shape");
    let mut gram = ws.take(l, l);
    gemm(true, false, 1.0, u, u, 0.0, &mut gram);
    s.fill(0.0);
    for i in 0..l {
        s[(i, i)] = 0.5;
        for j in i + 1..l {
            s[(i, j)] = gram[(i, j)];
        }
    }
    ws.give(gram);
}

/// Fused apply core shared by [`CwyOperator`] and the backward tape:
/// `out = batch - ((batch @ U) @ S⁻¹) @ Uᵀ`, all scratch pooled, the
/// trailing product running through the NT path (no materialized `Uᵀ`).
pub(crate) fn apply_with_operands(
    u: &Matrix,
    sinv: &Matrix,
    batch: &Matrix,
    out: &mut Matrix,
    ws: &mut Workspace,
) {
    let (b, l) = (batch.rows, u.cols);
    let mut t = ws.take(b, l);
    gemm(false, false, 1.0, batch, u, 0.0, &mut t); // (B, L)
    let mut ta = ws.take(b, l);
    gemm(false, false, 1.0, &t, sinv, 0.0, &mut ta); // (B, L)
    out.copy_from(batch);
    gemm(false, true, -1.0, &ta, u, 1.0, out); // out -= ta @ Uᵀ
    ws.give(t);
    ws.give(ta);
}

/// [`apply_with_operands`] over pre-packed operand panels (ISSUE 9): the
/// operator is identical at every timestep of a rollout, so `U`/`S⁻¹`
/// are packed once per rebuild and each step only packs its varying A
/// side.  Bitwise-identical to the unpacked form — the packs hold the
/// same bytes per-call packing would produce.
pub(crate) fn apply_with_packed(
    u: &Matrix,
    sinv: &Matrix,
    packs: &CwyPacks,
    batch: &Matrix,
    out: &mut Matrix,
    ws: &mut Workspace,
) {
    let (b, l) = (batch.rows, u.cols);
    let mut t = ws.take(b, l);
    gemm_packed(false, false, 1.0, batch, u, &packs.u_nn, 0.0, &mut t); // (B, L)
    let mut ta = ws.take(b, l);
    gemm_packed(false, false, 1.0, &t, sinv, &packs.sinv_nn, 0.0, &mut ta); // (B, L)
    out.copy_from(batch);
    gemm_packed(false, true, -1.0, &ta, u, &packs.u_nt, 1.0, out); // out -= ta @ Uᵀ
    ws.give(t);
    ws.give(ta);
}

impl CwyOperator {
    /// Precompute from raw reflection vectors V (L, N).
    pub fn new(v: &Matrix) -> CwyOperator {
        let u = normalize(v);
        let sinv = triu_inv(&build_s(&u));
        CwyOperator::from_parts(u, sinv)
    }

    /// Assemble from already-derived operands, packing their panels once.
    pub fn from_parts(u: Matrix, sinv: Matrix) -> CwyOperator {
        let mut packs = CwyPacks::new();
        packs.repack(&u, &sinv);
        CwyOperator { u, sinv, packs }
    }

    /// Apply to a batch (B, N) of row-vector hidden states: `out = h @ Q`,
    /// matching the kernels' convention and the sequential HR chain.
    pub fn apply(&self, batch: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(batch.rows, batch.cols);
        let mut ws = Workspace::new();
        self.apply_into(batch, &mut out, &mut ws);
        out
    }

    /// Allocation-free [`CwyOperator::apply`]: `out` preshaped `(B, N)`,
    /// scratch pooled in `ws`.  Bitwise-identical to the wrapper.
    pub fn apply_into(&self, batch: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        assert_eq!((out.rows, out.cols), (batch.rows, batch.cols), "apply output shape");
        apply_with_packed(&self.u, &self.sinv, &self.packs, batch, out, ws);
    }

    /// Materialize Q = I - U S^{-1} U^T.
    pub fn matrix(&self) -> Matrix {
        let n = self.u.rows;
        let mut q = Matrix::eye(n);
        let mut w = Matrix::zeros(n, self.u.cols);
        gemm(false, false, 1.0, &self.u, &self.sinv, 0.0, &mut w);
        gemm(false, true, -1.0, &w, &self.u, 1.0, &mut q); // I - (U S⁻¹) Uᵀ
        q
    }
}

/// Convenience: Q from raw vectors.
pub fn matrix(v: &Matrix) -> Matrix {
    CwyOperator::new(v).matrix()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orthogonal::householder;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    #[test]
    fn equals_householder_product() {
        // Thm 2: CWY == explicit sequential reflections in exact arithmetic.
        forall(
            16,
            |rng| {
                let l = 1 + rng.below(8) as usize;
                let n = l + rng.below(12) as usize + 1;
                Matrix::random_normal(rng, l, n, 1.0)
            },
            |v| {
                let q_cwy = matrix(v);
                let q_hr = householder::matrix(v);
                let d = q_cwy.max_abs_diff(&q_hr);
                if d < 5e-4 { Ok(()) } else { Err(format!("cwy vs hr diff {d}")) }
            },
        );
    }

    #[test]
    fn is_orthogonal() {
        forall(
            16,
            |rng| {
                let l = 1 + rng.below(10) as usize;
                let n = l + 4;
                Matrix::random_normal(rng, l, n, 1.0)
            },
            |v| {
                let d = matrix(v).orthogonality_defect();
                if d < 1e-3 { Ok(()) } else { Err(format!("defect {d}")) }
            },
        );
    }

    #[test]
    fn apply_matches_matrix() {
        let mut rng = Pcg32::seeded(31);
        let v = Matrix::random_normal(&mut rng, 6, 16, 1.0);
        let op = CwyOperator::new(&v);
        let h = Matrix::random_normal(&mut rng, 4, 16, 1.0);
        let direct = h.matmul(&op.matrix());
        let fused = op.apply(&h);
        assert!(direct.max_abs_diff(&fused) < 1e-4);
    }

    /// The satellite property: `apply_into` over a reused workspace (and
    /// stale output contents) bit-matches the allocating `apply`, across
    /// random shapes including L = 1 and B = 1.
    #[test]
    fn apply_into_bitwise_matches_apply() {
        let mut ws = Workspace::new();
        forall(
            16,
            |rng| {
                let l = 1 + rng.below(8) as usize;
                let n = l + 1 + rng.below(12) as usize;
                let b = 1 + rng.below(5) as usize;
                (
                    Matrix::random_normal(rng, l, n, 1.0),
                    Matrix::random_normal(rng, b, n, 1.0),
                )
            },
            |(v, h)| {
                let op = CwyOperator::new(v);
                let reference = op.apply(h);
                let mut out = Matrix::zeros(h.rows, h.cols);
                out.fill(f32::NAN); // stale contents must not leak
                op.apply_into(h, &mut out, &mut ws);
                let same = reference
                    .data
                    .iter()
                    .zip(&out.data)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                if same { Ok(()) } else { Err("apply_into drifted from apply".into()) }
            },
        );
    }

    /// ISSUE 9: the pre-packed apply path must be bitwise-identical to
    /// the per-call-packing path it replaced, across ragged shapes.
    #[test]
    fn packed_apply_bitwise_matches_unpacked() {
        let mut ws = Workspace::new();
        forall(
            16,
            |rng| {
                let l = 1 + rng.below(8) as usize;
                let n = l + 1 + rng.below(12) as usize;
                let b = 1 + rng.below(5) as usize;
                (
                    Matrix::random_normal(rng, l, n, 1.0),
                    Matrix::random_normal(rng, b, n, 1.0),
                )
            },
            |(v, h)| {
                let op = CwyOperator::new(v);
                let mut unpacked = Matrix::zeros(h.rows, h.cols);
                apply_with_operands(&op.u, &op.sinv, h, &mut unpacked, &mut ws);
                let mut packed = Matrix::zeros(h.rows, h.cols);
                packed.fill(f32::NAN);
                op.apply_into(h, &mut packed, &mut ws);
                let same = unpacked
                    .data
                    .iter()
                    .zip(&packed.data)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                if same { Ok(()) } else { Err("packed apply drifted from unpacked".into()) }
            },
        );
    }

    /// Norm dedup: normalize given precomputed norms equals normalize
    /// recomputing them, and the shared pass matches `row_norms`.
    #[test]
    fn normalize_with_norms_matches_normalize() {
        let mut rng = Pcg32::seeded(47);
        let v = Matrix::random_normal(&mut rng, 5, 9, 1.0);
        let norms = row_norms(&v);
        let mut direct = vec![0.0; 5];
        row_norms_into(&v, &mut direct);
        assert_eq!(norms, direct);
        assert_eq!(normalize(&v), normalize_with_norms(&v, &norms));
        let mut u = Matrix::zeros(9, 5);
        normalize_with_norms_into(&v, &norms, &mut u);
        assert_eq!(u, normalize(&v));
    }

    /// Regression (ISSUE 4): a near-zero reflection row used to be scaled
    /// by `1/norm.max(1e-12)`, producing an O(1e12)-noise direction.  It
    /// must now map to an exact canonical basis vector so Q stays exactly
    /// orthogonal and every entry stays finite.
    #[test]
    fn degenerate_row_renormalizes_explicitly() {
        let mut rng = Pcg32::seeded(77);
        let mut v = Matrix::random_normal(&mut rng, 4, 10, 1.0);
        for j in 0..10 {
            v[(2, j)] = 1e-9; // norm ~3e-9, far below DEGENERATE_NORM
        }
        assert_eq!(degenerate_rows(&v), vec![2]);
        let u = normalize(&v);
        // Column 2 of U is exactly e_2.
        for j in 0..10 {
            let want = if j == 2 { 1.0 } else { 0.0 };
            assert_eq!(u[(j, 2)], want, "u[{j},2]");
        }
        let q = matrix(&v);
        assert!(q.data.iter().all(|x| x.is_finite()), "non-finite Q entry");
        assert!(q.orthogonality_defect() < 1e-3);
        // A healthy V has no degenerate rows and keeps the old behavior.
        let healthy = Matrix::random_normal(&mut rng, 4, 10, 1.0);
        assert!(degenerate_rows(&healthy).is_empty());
    }

    #[test]
    fn norm_preserving() {
        let mut rng = Pcg32::seeded(32);
        let v = Matrix::random_normal(&mut rng, 8, 24, 1.0);
        let op = CwyOperator::new(&v);
        let h = Matrix::random_normal(&mut rng, 5, 24, 1.0);
        let out = op.apply(&h);
        for b in 0..5 {
            let n0: f32 = h.row(b).iter().map(|x| x * x).sum::<f32>().sqrt();
            let n1: f32 = out.row(b).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n0 - n1).abs() / n0 < 1e-3, "row {b}: {n0} vs {n1}");
        }
    }
}
