//! Native CWY transform (paper Thm 2): Q = I - U S^{-1} U^T.
//!
//! This is the rust mirror of `python/compile/kernels/cwy.py`, used for
//! Table 1/2 harnesses, orthogonality property tests, and cross-checking
//! artifact outputs.

use crate::linalg::{triu_inv, Matrix};

/// Precomputed CWY operands for a rollout.
pub struct CwyOperator {
    /// Column-normalized reflection vectors, (N, L).
    pub u: Matrix,
    /// Inverse of S = 0.5 I + striu(U^T U), (L, L).
    pub sinv: Matrix,
}

/// Normalize rows of V (L, N) into columns of U (N, L).
pub fn normalize(v: &Matrix) -> Matrix {
    let (l, n) = (v.rows, v.cols);
    let mut u = Matrix::zeros(n, l);
    for i in 0..l {
        let row = v.row(i);
        let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
        for j in 0..n {
            u[(j, i)] = row[j] / norm;
        }
    }
    u
}

/// S = 0.5 I + striu(U^T U).
pub fn build_s(u: &Matrix) -> Matrix {
    let l = u.cols;
    let gram = u.t().matmul(u);
    let mut s = Matrix::zeros(l, l);
    for i in 0..l {
        s[(i, i)] = 0.5;
        for j in i + 1..l {
            s[(i, j)] = gram[(i, j)];
        }
    }
    s
}

impl CwyOperator {
    /// Precompute from raw reflection vectors V (L, N).
    pub fn new(v: &Matrix) -> CwyOperator {
        let u = normalize(v);
        let sinv = triu_inv(&build_s(&u));
        CwyOperator { u, sinv }
    }

    /// Apply to a batch (B, N) of row-vector hidden states: `out = h @ Q`,
    /// matching the kernels' convention and the sequential HR chain.
    pub fn apply(&self, batch: &Matrix) -> Matrix {
        let t = batch.matmul(&self.u);      // (B, L)
        let v = t.matmul(&self.sinv);       // (B, L)
        batch.sub(&v.matmul(&self.u.t()))
    }

    /// Materialize Q = I - U S^{-1} U^T.
    pub fn matrix(&self) -> Matrix {
        let n = self.u.rows;
        Matrix::eye(n).sub(&self.u.matmul(&self.sinv).matmul(&self.u.t()))
    }
}

/// Convenience: Q from raw vectors.
pub fn matrix(v: &Matrix) -> Matrix {
    CwyOperator::new(v).matrix()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orthogonal::householder;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    #[test]
    fn equals_householder_product() {
        // Thm 2: CWY == explicit sequential reflections in exact arithmetic.
        forall(
            16,
            |rng| {
                let l = 1 + rng.below(8) as usize;
                let n = l + rng.below(12) as usize + 1;
                Matrix::random_normal(rng, l, n, 1.0)
            },
            |v| {
                let q_cwy = matrix(v);
                let q_hr = householder::matrix(v);
                let d = q_cwy.max_abs_diff(&q_hr);
                if d < 5e-4 { Ok(()) } else { Err(format!("cwy vs hr diff {d}")) }
            },
        );
    }

    #[test]
    fn is_orthogonal() {
        forall(
            16,
            |rng| {
                let l = 1 + rng.below(10) as usize;
                let n = l + 4;
                Matrix::random_normal(rng, l, n, 1.0)
            },
            |v| {
                let d = matrix(v).orthogonality_defect();
                if d < 1e-3 { Ok(()) } else { Err(format!("defect {d}")) }
            },
        );
    }

    #[test]
    fn apply_matches_matrix() {
        let mut rng = Pcg32::seeded(31);
        let v = Matrix::random_normal(&mut rng, 6, 16, 1.0);
        let op = CwyOperator::new(&v);
        let h = Matrix::random_normal(&mut rng, 4, 16, 1.0);
        let direct = h.matmul(&op.matrix());
        let fused = op.apply(&h);
        assert!(direct.max_abs_diff(&fused) < 1e-4);
    }

    #[test]
    fn norm_preserving() {
        let mut rng = Pcg32::seeded(32);
        let v = Matrix::random_normal(&mut rng, 8, 24, 1.0);
        let op = CwyOperator::new(&v);
        let h = Matrix::random_normal(&mut rng, 5, 24, 1.0);
        let out = op.apply(&h);
        for b in 0..5 {
            let n0: f32 = h.row(b).iter().map(|x| x * x).sum::<f32>().sqrt();
            let n1: f32 = out.row(b).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n0 - n1).abs() / n0 < 1e-3, "row {b}: {n0} vs {n1}");
        }
    }
}
