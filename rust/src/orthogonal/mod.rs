//! Native implementations of every orthogonal / Stiefel optimization method
//! the paper compares (§2.2), plus the analytical complexity model behind
//! Tables 1-2.
//!
//! These mirror the L2 exports in `python/compile/{parametrize,stiefel}.py`;
//! the integration tests cross-check artifact outputs against this module.

pub mod backward;
pub mod cwy;
pub mod flops;
pub mod householder;
pub mod own;
pub mod rgd;
pub mod tcwy;

use crate::linalg::{cayley, expm_default, Matrix};

/// EXPRNN parametrization: Q = expm(skew(A)).
pub fn exprnn_matrix(a: &Matrix) -> Matrix {
    expm_default(&a.skew())
}

/// SCORNN parametrization: Q = Cayley(skew(A)) (D-tilde = I, as in §2.2.1).
pub fn scornn_matrix(a: &Matrix) -> Matrix {
    cayley(&a.skew())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn exprnn_scornn_orthogonal() {
        forall(
            8,
            |rng| {
                let n = 2 + rng.below(10) as usize;
                Matrix::random_normal(rng, n, n, 0.5)
            },
            |a| {
                let d1 = exprnn_matrix(a).orthogonality_defect();
                let d2 = scornn_matrix(a).orthogonality_defect();
                if d1 < 1e-3 && d2 < 1e-3 {
                    Ok(())
                } else {
                    Err(format!("exprnn {d1}, scornn {d2}"))
                }
            },
        );
    }
}
