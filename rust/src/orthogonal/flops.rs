//! Analytical complexity model — regenerates the paper's Table 1 and the
//! FLOP column of Table 2 from the same counting rules the paper cites
//! (Hunger 2005; Hammarling & Lucas 2008; Trefethen & Bau 1997):
//!   * (d1 x d2)(d2 x d3) matmul: 2 d1 d2 d3 FLOPs
//!   * dense d x d inverse: d^3; upper-triangular: d^3 / 3
//!   * thin QR of d1 x d2: 2 d2^2 (d1 - d2/3)
//!   * SPD eigendecomposition (= SVD): (8/3) d^3

/// A Table-1 row: serial / parallel forward-pass complexity (symbolic
/// strings) plus a concrete FLOP estimate for given (T, N, L).
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub method: &'static str,
    pub serial: &'static str,
    pub parallel: &'static str,
    pub domain: &'static str,
    pub flops: f64,
}

pub fn table1(t: usize, n: usize, l: usize) -> Vec<Table1Row> {
    let (t, n, l) = (t as f64, n as f64, l as f64);
    vec![
        Table1Row {
            method: "RNN",
            serial: "T N^2",
            parallel: "T log N",
            domain: "-",
            flops: 2.0 * t * n * n,
        },
        Table1Row {
            method: "SCORNN",
            serial: "T N^2 + N^3",
            parallel: "T log N + N^2 log N",
            domain: "O^{+1}(N) \\ Theta",
            flops: 2.0 * t * n * n + n * n * n,
        },
        Table1Row {
            method: "RGD (U(N))",
            serial: "T N^2 + N^3",
            parallel: "T log N + N^2 log N",
            domain: "U(N)",
            flops: 2.0 * t * n * n + n * n * n,
        },
        Table1Row {
            method: "EXPRNN",
            serial: "T N^2 + N^3",
            parallel: "T log N + N^3",
            domain: "O^{+1}(N)",
            flops: 2.0 * t * n * n + n * n * n,
        },
        Table1Row {
            method: "EURNN (L iter.)",
            serial: "T L N",
            parallel: "T L",
            domain: "U(N) when L=N",
            flops: 4.0 * t * l * n,
        },
        Table1Row {
            method: "HR (L refl.)",
            serial: "T L N",
            parallel: "T L log N",
            domain: "O_L(N)",
            flops: 4.0 * t * l * n,
        },
        Table1Row {
            method: "CWY (L refl., ours)",
            serial: "T L N + L^2 N + L^3",
            parallel: "T log(L N) + L^2 log L",
            domain: "O_L(N)",
            flops: 4.0 * t * l * n + 2.0 * l * l * n + l * l * l / 3.0,
        },
    ]
}

/// FLOPs of one `(m x k)(k x n)` GEMM call under the same `2 d1 d2 d3`
/// counting rule as the tables.  The telemetry registry's per-variant
/// GEMM FLOP counters use this, so measured GFLOP/s in the `metrics`
/// frame is directly comparable with the Table 1/2 analytical model.
pub fn gemm_flops(m: usize, k: usize, n: usize) -> u64 {
    2 * (m as u64) * (k as u64) * (n as u64)
}

/// A Table-2 row: Stiefel step cost for (N, M).
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub method: &'static str,
    pub parallel: &'static str,
    pub inverted: &'static str,
    /// Symbolic leading-term expression from the paper.
    pub flops_expr: &'static str,
    /// Evaluated at the given (N, M).
    pub flops: f64,
}

pub fn table2(n: usize, m: usize) -> Vec<Table2Row> {
    let (nf, mf) = (n as f64, m as f64);
    let m3 = mf * mf * mf;
    vec![
        Table2Row {
            method: "RGD-C-QR",
            parallel: "M log(MN)",
            inverted: "-",
            flops_expr: "10 N M^2 - 2 M^3 / 3",
            flops: 10.0 * nf * mf * mf - 2.0 * m3 / 3.0,
        },
        Table2Row {
            method: "RGD-E-QR",
            parallel: "M log(MN)",
            inverted: "-",
            flops_expr: "14 N M^2 - 2 M^3 / 3",
            flops: 14.0 * nf * mf * mf - 2.0 * m3 / 3.0,
        },
        Table2Row {
            method: "RGD-C-C",
            parallel: "log(MN) + M^2 log M",
            inverted: "2M x 2M dense",
            flops_expr: "28 N M^2 + 16 M^3",
            flops: 28.0 * nf * mf * mf + 16.0 * m3,
        },
        Table2Row {
            method: "RGD-E-C",
            parallel: "log(MN) + M^2 log M",
            inverted: "3M x 3M dense",
            flops_expr: "72 N M^2 + 25 M^3",
            flops: 72.0 * nf * mf * mf + 25.0 * m3,
        },
        Table2Row {
            method: "OWN",
            parallel: "log(MN) + M^3",
            inverted: "- (eigendecomposition)",
            flops_expr: "4 N M^2 + 14 M^3 / 3",
            flops: 4.0 * nf * mf * mf + 14.0 * m3 / 3.0,
        },
        Table2Row {
            method: "T-CWY (ours)",
            parallel: "log(MN) + M^2 log M",
            inverted: "M x M upper-triangular",
            flops_expr: "4 N M^2 + 7 M^3 / 3",
            flops: 4.0 * nf * mf * mf + 7.0 * m3 / 3.0,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcwy_has_fewest_flops() {
        // The paper's headline claim for Table 2: with N >= M, T-CWY needs
        // the smallest FLOP count of all Stiefel methods.
        for (n, m) in [(64, 8), (256, 32), (1024, 128), (4096, 64)] {
            let rows = table2(n, m);
            let tcwy = rows.iter().find(|r| r.method.starts_with("T-CWY")).unwrap();
            for r in &rows {
                assert!(
                    tcwy.flops <= r.flops,
                    "N={n} M={m}: T-CWY {} > {} {}",
                    tcwy.flops,
                    r.method,
                    r.flops
                );
            }
        }
    }

    #[test]
    fn cwy_beats_cubic_methods_for_small_l() {
        // For L << N the CWY rollout cost is far below the N^3 methods.
        let rows = table1(1000, 1024, 128);
        let cwy = rows.iter().find(|r| r.method.contains("CWY")).unwrap();
        let exprnn = rows.iter().find(|r| r.method == "EXPRNN").unwrap();
        assert!(cwy.flops < exprnn.flops);
    }

    #[test]
    fn table_shapes() {
        assert_eq!(table1(10, 16, 4).len(), 7);
        assert_eq!(table2(16, 4).len(), 6);
    }
}
