//! Copying-task generator (paper §4.1).
//!
//! Input:  10 digits from {1..8}, then T blanks (0), one marker (9),
//!         then 9 blanks.
//! Target: T+10 blanks, then the 10 input digits.
//! The no-memory baseline cross-entropy is 10 log 8 / (T + 20).

use crate::util::rng::Pcg32;

/// One generated batch of the copying task, token- and target-major.
pub struct CopyBatch {
    /// (batch, t_total) input tokens in 0..=9, flattened row-major.
    pub tokens: Vec<i32>,
    /// (batch, t_total) target classes in 0..=8, flattened row-major.
    pub targets: Vec<i32>,
    pub batch: usize,
    pub t_total: usize,
}

pub struct CopyTask {
    pub t_blank: usize,
    pub batch: usize,
    rng: Pcg32,
}

impl CopyTask {
    pub fn new(t_blank: usize, batch: usize, seed: u64) -> CopyTask {
        CopyTask { t_blank, batch, rng: Pcg32::new(seed, 101) }
    }

    pub fn t_total(&self) -> usize {
        self.t_blank + 20
    }

    /// The paper's memoryless-baseline cross entropy: 10 log 8 / (T + 20).
    pub fn baseline_ce(&self) -> f32 {
        10.0 * (8.0f32).ln() / (self.t_blank as f32 + 20.0)
    }

    pub fn next_batch(&mut self) -> CopyBatch {
        let t_total = self.t_total();
        let mut tokens = vec![0i32; self.batch * t_total];
        let mut targets = vec![0i32; self.batch * t_total];
        for b in 0..self.batch {
            let row = b * t_total;
            let digits: Vec<i32> =
                (0..10).map(|_| 1 + self.rng.below(8) as i32).collect();
            for (i, &d) in digits.iter().enumerate() {
                tokens[row + i] = d;
            }
            // positions 10 .. 10+t_blank are blanks (already 0)
            tokens[row + 10 + self.t_blank] = 9; // start marker
            // final 9 positions blank
            for (i, &d) in digits.iter().enumerate() {
                targets[row + self.t_blank + 10 + i] = d;
            }
        }
        CopyBatch { tokens, targets, batch: self.batch, t_total }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure() {
        let mut task = CopyTask::new(30, 4, 7);
        let b = task.next_batch();
        assert_eq!(b.t_total, 50);
        for r in 0..4 {
            let row = &b.tokens[r * 50..(r + 1) * 50];
            let tgt = &b.targets[r * 50..(r + 1) * 50];
            // first ten are digits 1..8
            assert!(row[..10].iter().all(|&t| (1..=8).contains(&t)));
            // blanks until the marker
            assert!(row[10..40].iter().all(|&t| t == 0));
            assert_eq!(row[40], 9);
            assert!(row[41..].iter().all(|&t| t == 0));
            // targets: blanks then the digits
            assert!(tgt[..40].iter().all(|&t| t == 0));
            assert_eq!(&tgt[40..], &row[..10]);
        }
    }

    #[test]
    fn baseline_matches_paper_formula() {
        let task = CopyTask::new(1000, 1, 0);
        let expect = 10.0 * (8.0f32).ln() / 1020.0;
        assert!((task.baseline_ce() - expect).abs() < 1e-7);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = CopyTask::new(10, 2, 5).next_batch();
        let b = CopyTask::new(10, 2, 5).next_batch();
        assert_eq!(a.tokens, b.tokens);
        let c = CopyTask::new(10, 2, 6).next_batch();
        assert_ne!(a.tokens, c.tokens);
    }
}
