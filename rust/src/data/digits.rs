//! Procedural pixel-digit dataset — the MNIST stand-in for the
//! pixel-by-pixel classification experiment (paper §4.1; DESIGN.md §4.3).
//!
//! Each sample is a 14x14 grayscale glyph of a digit 0-9 drawn from stroke
//! segments, jittered in position/thickness/noise, flattened to a length-196
//! pixel sequence (optionally under a fixed random permutation, matching the
//! "permuted MNIST" variant of Fig. 4b).

use crate::util::rng::Pcg32;

pub const SIDE: usize = 14;
pub const SEQ_LEN: usize = SIDE * SIDE;

/// Stroke segments per digit in a 0..=6 coordinate grid (x0,y0,x1,y1).
const STROKES: [&[(i32, i32, i32, i32)]; 10] = [
    // 0
    &[(1, 0, 5, 0), (5, 0, 5, 6), (5, 6, 1, 6), (1, 6, 1, 0)],
    // 1
    &[(3, 0, 3, 6), (2, 1, 3, 0)],
    // 2
    &[(1, 1, 5, 0), (5, 0, 5, 3), (5, 3, 1, 6), (1, 6, 5, 6)],
    // 3
    &[(1, 0, 5, 0), (5, 0, 5, 6), (5, 6, 1, 6), (2, 3, 5, 3)],
    // 4
    &[(1, 0, 1, 3), (1, 3, 5, 3), (4, 0, 4, 6)],
    // 5
    &[(5, 0, 1, 0), (1, 0, 1, 3), (1, 3, 5, 3), (5, 3, 5, 6), (5, 6, 1, 6)],
    // 6
    &[(5, 0, 1, 2), (1, 2, 1, 6), (1, 6, 5, 6), (5, 6, 5, 3), (5, 3, 1, 3)],
    // 7
    &[(1, 0, 5, 0), (5, 0, 2, 6)],
    // 8
    &[(1, 0, 5, 0), (5, 0, 5, 6), (5, 6, 1, 6), (1, 6, 1, 0), (1, 3, 5, 3)],
    // 9
    &[(5, 3, 1, 3), (1, 3, 1, 0), (1, 0, 5, 0), (5, 0, 5, 6), (5, 6, 2, 6)],
];

/// One batch: pixels (batch, SEQ_LEN) in [0,1], labels (batch,).
pub struct DigitBatch {
    pub pixels: Vec<f32>,
    pub labels: Vec<i32>,
    pub batch: usize,
}

pub struct DigitTask {
    pub batch: usize,
    permutation: Option<Vec<usize>>,
    rng: Pcg32,
}

impl DigitTask {
    pub fn new(batch: usize, seed: u64, permuted: bool) -> DigitTask {
        let permutation = if permuted {
            // Fixed permutation drawn from an independent stream so the
            // train/val/test splits share it (as in permuted MNIST).
            let mut prng = Pcg32::new(0xfeed, 9);
            Some(prng.permutation(SEQ_LEN))
        } else {
            None
        };
        DigitTask { batch, permutation, rng: Pcg32::new(seed, 202) }
    }

    /// Render a digit glyph into a SIDE x SIDE image with jitter + noise.
    fn render(&mut self, digit: usize) -> Vec<f32> {
        let mut img = vec![0.0f32; SEQ_LEN];
        let ox = self.rng.below(3) as i32 + 1; // offset 1..3
        let oy = self.rng.below(3) as i32 + 1;
        let scale = 1.5 + self.rng.uniform() * 0.4; // grid 0..6 -> ~0..10 px
        for &(x0, y0, x1, y1) in STROKES[digit] {
            // Bresenham-ish dense sampling of the segment.
            let steps = 24;
            for s in 0..=steps {
                let t = s as f32 / steps as f32;
                let x = (x0 as f32 + t * (x1 - x0) as f32) * scale + ox as f32;
                let y = (y0 as f32 + t * (y1 - y0) as f32) * scale + oy as f32;
                let (xi, yi) = (x.round() as i32, y.round() as i32);
                for (dx, dy, w) in [(0, 0, 1.0f32), (1, 0, 0.35), (0, 1, 0.35)] {
                    let (px, py) = (xi + dx, yi + dy);
                    if (0..SIDE as i32).contains(&px) && (0..SIDE as i32).contains(&py) {
                        let idx = py as usize * SIDE + px as usize;
                        img[idx] = (img[idx] + w).min(1.0);
                    }
                }
            }
        }
        // Light pixel noise.
        for p in img.iter_mut() {
            *p = (*p + self.rng.normal() * 0.02).clamp(0.0, 1.0);
        }
        img
    }

    pub fn next_batch(&mut self) -> DigitBatch {
        let mut pixels = Vec::with_capacity(self.batch * SEQ_LEN);
        let mut labels = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let digit = self.rng.below(10) as usize;
            let img = self.render(digit);
            match &self.permutation {
                Some(p) => pixels.extend(p.iter().map(|&i| img[i])),
                None => pixels.extend_from_slice(&img),
            }
            labels.push(digit as i32);
        }
        DigitBatch { pixels, labels, batch: self.batch }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_range() {
        let mut t = DigitTask::new(8, 3, false);
        let b = t.next_batch();
        assert_eq!(b.pixels.len(), 8 * SEQ_LEN);
        assert_eq!(b.labels.len(), 8);
        assert!(b.pixels.iter().all(|&p| (0.0..=1.0).contains(&p)));
        assert!(b.labels.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn digits_are_distinguishable() {
        // Mean image of distinct digits should differ substantially.
        let mut t = DigitTask::new(1, 0, false);
        let a = t.render(0);
        let b = t.render(1);
        let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 5.0, "digits 0 and 1 too similar: {diff}");
    }

    #[test]
    fn glyphs_have_ink() {
        let mut t = DigitTask::new(1, 1, false);
        for d in 0..10 {
            let img = t.render(d);
            let ink: f32 = img.iter().sum();
            assert!(ink > 3.0, "digit {d} nearly blank: ink={ink}");
        }
    }

    #[test]
    fn permutation_is_shared_and_applied() {
        let mut a = DigitTask::new(4, 9, true);
        let mut b = DigitTask::new(4, 9, true);
        assert_eq!(a.next_batch().pixels, b.next_batch().pixels);
        // permuted differs from unpermuted stream with the same seed
        let mut c = DigitTask::new(4, 9, false);
        assert_ne!(a.next_batch().pixels, c.next_batch().pixels);
    }
}
