//! Dataset generators (all procedural — DESIGN.md §4.3 documents the
//! substitutions for MNIST / Tatoeba / KTH).

pub mod copying;
pub mod corpus;
pub mod digits;
pub mod video;
