//! Moving-shapes video generator — the KTH stand-in for the video-prediction
//! experiment (paper §4.3; DESIGN.md §4.3).
//!
//! KTH's structure is a static camera with one actor performing one of six
//! motion classes.  The generator mirrors that: one bright shape on a dark
//! background following a class-specific dynamic:
//!   Walk  — slow horizontal translation
//!   Jog   — medium translation
//!   Run   — fast translation
//!   Box   — small-amplitude horizontal oscillation (punching)
//!   Wave  — vertical-arm oscillation (shape sways up/down)
//!   Clap  — two shapes meeting periodically
//! Learning to predict the next frame requires exactly the temporal state
//! ConvNERU's recurrence provides, and the translation-vs-oscillation split
//! mirrors KTH's per-class difficulty ordering.

use crate::util::rng::Pcg32;

pub const CLASSES: [&str; 6] = ["walk", "jog", "run", "box", "wave", "clap"];

/// One clip: frames (t, h, w, 1) flattened row-major, values in [0,1].
pub struct Clip {
    pub frames: Vec<f32>,
    pub t: usize,
    pub hw: usize,
}

pub struct VideoTask {
    pub hw: usize,
    pub t: usize,
    pub batch: usize,
    rng: Pcg32,
}

impl VideoTask {
    pub fn new(hw: usize, t: usize, batch: usize, seed: u64) -> VideoTask {
        VideoTask { hw, t, batch, rng: Pcg32::new(seed, 404) }
    }

    fn draw_blob(&self, frame: &mut [f32], cx: f32, cy: f32, r: f32) {
        let hw = self.hw as i32;
        for y in 0..hw {
            for x in 0..hw {
                let dx = x as f32 - cx;
                let dy = y as f32 - cy;
                let d2 = dx * dx + dy * dy;
                let v = (-d2 / (r * r)).exp();
                let idx = (y * hw + x) as usize;
                frame[idx] = (frame[idx] + v).min(1.0);
            }
        }
    }

    /// Render one clip of the given class (0..6).
    pub fn clip(&mut self, class: usize) -> Clip {
        let hw = self.hw;
        let n = hw * hw;
        let mut frames = vec![0.0f32; self.t * n];
        let cy0 = hw as f32 * (0.35 + 0.3 * self.rng.uniform());
        let cx0 = hw as f32 * (0.2 + 0.2 * self.rng.uniform());
        let phase = self.rng.uniform() * std::f32::consts::TAU;
        let r = hw as f32 * 0.12;

        for t in 0..self.t {
            let tf = t as f32;
            let frame = &mut frames[t * n..(t + 1) * n];
            match class {
                0 | 1 | 2 => {
                    // walk/jog/run: translation at increasing speed
                    let speed = [0.4, 0.8, 1.4][class];
                    let cx = (cx0 + speed * tf) % hw as f32;
                    let bob = (tf * 1.3 + phase).sin() * 0.5;
                    self.draw_blob(frame, cx, cy0 + bob, r);
                }
                3 => {
                    // box: fast small horizontal oscillation
                    let cx = cx0 + 2.0 * (tf * 2.1 + phase).sin();
                    self.draw_blob(frame, cx, cy0, r);
                }
                4 => {
                    // wave: vertical oscillation
                    let cy = cy0 + 2.5 * (tf * 1.1 + phase).sin();
                    self.draw_blob(frame, cx0, cy, r);
                }
                5 => {
                    // clap: two blobs meeting periodically
                    let sep = 3.0 + 2.5 * (tf * 1.7 + phase).cos();
                    self.draw_blob(frame, cx0 - sep, cy0, r * 0.8);
                    self.draw_blob(frame, cx0 + sep, cy0, r * 0.8);
                }
                _ => panic!("class out of range"),
            }
            // sensor noise
            for p in frame.iter_mut() {
                *p = (*p + self.rng.normal() * 0.01).clamp(0.0, 1.0);
            }
        }
        Clip { frames, t: self.t, hw }
    }

    /// A batch for the artifact input (batch, t, hw, hw, 1), single class.
    pub fn batch_of_class(&mut self, class: usize) -> Vec<f32> {
        let n = self.t * self.hw * self.hw;
        let mut out = Vec::with_capacity(self.batch * n);
        for _ in 0..self.batch {
            out.extend(self.clip(class).frames);
        }
        out
    }

    /// A mixed-class batch (uniform over the six classes).
    pub fn batch_mixed(&mut self) -> Vec<f32> {
        let n = self.t * self.hw * self.hw;
        let mut out = Vec::with_capacity(self.batch * n);
        for _ in 0..self.batch {
            let class = self.rng.below(6) as usize;
            out.extend(self.clip(class).frames);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_shape_and_range() {
        let mut v = VideoTask::new(16, 8, 2, 1);
        let c = v.clip(0);
        assert_eq!(c.frames.len(), 8 * 256);
        assert!(c.frames.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn every_frame_has_signal() {
        let mut v = VideoTask::new(16, 8, 1, 2);
        for class in 0..6 {
            let c = v.clip(class);
            for t in 0..8 {
                let e: f32 = c.frames[t * 256..(t + 1) * 256].iter().sum();
                assert!(e > 1.0, "class {class} frame {t} empty: {e}");
            }
        }
    }

    #[test]
    fn translation_classes_move() {
        // centroid of the run class must displace much more than box.
        let centroid = |frame: &[f32], hw: usize| -> f32 {
            let total: f32 = frame.iter().sum();
            let mut cx = 0.0;
            for y in 0..hw {
                for x in 0..hw {
                    cx += x as f32 * frame[y * hw + x];
                }
            }
            cx / total.max(1e-6)
        };
        let mut v = VideoTask::new(16, 6, 1, 3);
        let run = v.clip(2);
        let boxc = v.clip(3);
        let drun = (centroid(&run.frames[5 * 256..], 16)
            - centroid(&run.frames[..256], 16))
        .abs();
        let dbox = (centroid(&boxc.frames[5 * 256..], 16)
            - centroid(&boxc.frames[..256], 16))
        .abs();
        assert!(drun > dbox, "run moved {drun}, box moved {dbox}");
    }

    #[test]
    fn batch_sizes() {
        let mut v = VideoTask::new(16, 8, 3, 4);
        assert_eq!(v.batch_of_class(0).len(), 3 * 8 * 256);
        assert_eq!(v.batch_mixed().len(), 3 * 8 * 256);
    }
}
