//! Synthetic bilingual corpus — the Tatoeba stand-in for the NMT experiment
//! (paper §4.2; DESIGN.md §4.3).
//!
//! A toy source grammar generates subject-verb-object(-modifier) sentences;
//! the "translation" applies a deterministic lexicon plus a systematic
//! reordering (adjective-noun swap and verb-final order), so the model must
//! learn both token mapping and alignment — exactly what attention is for.
//! Vocabulary is a fixed 64-token space shared with the exported artifacts
//! (NMT_CFG.vocab): 0 = pad, 1 = BOS, 2 = EOS.

use crate::util::rng::Pcg32;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
/// First content-token id; source and target use disjoint content ranges.
const SRC_BASE: i32 = 3;
const TGT_BASE: i32 = 32;

const N_SUBJ: u32 = 6;
const N_VERB: u32 = 6;
const N_OBJ: u32 = 8;
const N_ADJ: u32 = 6;

/// One aligned sentence pair (unpadded token ids).
#[derive(Clone, Debug)]
pub struct Pair {
    pub src: Vec<i32>,
    pub tgt: Vec<i32>,
}

pub struct CorpusGen {
    rng: Pcg32,
}

impl CorpusGen {
    pub fn new(seed: u64) -> CorpusGen {
        CorpusGen { rng: Pcg32::new(seed, 303) }
    }

    /// Sample one sentence pair from the toy grammar.
    ///
    /// Source:  SUBJ VERB [ADJ] OBJ          (English-like order)
    /// Target:  subj [obj adj-swapped] verb  (verb-final, adj after noun)
    pub fn pair(&mut self) -> Pair {
        let subj = self.rng.below(N_SUBJ) as i32;
        let verb = self.rng.below(N_VERB) as i32;
        let obj = self.rng.below(N_OBJ) as i32;
        let has_adj = self.rng.uniform() < 0.5;
        let adj = self.rng.below(N_ADJ) as i32;

        let s_subj = SRC_BASE + subj;
        let s_verb = SRC_BASE + N_SUBJ as i32 + verb;
        let s_obj = SRC_BASE + (N_SUBJ + N_VERB) as i32 + obj;
        let s_adj = SRC_BASE + (N_SUBJ + N_VERB + N_OBJ) as i32 + adj;

        let t_subj = TGT_BASE + subj;
        let t_verb = TGT_BASE + N_SUBJ as i32 + verb;
        let t_obj = TGT_BASE + (N_SUBJ + N_VERB) as i32 + obj;
        let t_adj = TGT_BASE + (N_SUBJ + N_VERB + N_OBJ) as i32 + adj;

        let mut src = vec![s_subj, s_verb];
        if has_adj {
            src.push(s_adj);
        }
        src.push(s_obj);
        src.push(EOS);

        // Target: verb-final, noun-adjective order swapped.
        let mut tgt = vec![t_subj, t_obj];
        if has_adj {
            tgt.push(t_adj);
        }
        tgt.push(t_verb);
        tgt.push(EOS);

        Pair { src, tgt }
    }

    /// A padded batch for the AOT artifact shapes (B, ts) / (B, tt).
    pub fn batch(&mut self, b: usize, ts: usize, tt: usize) -> NmtBatch {
        let mut src = vec![PAD; b * ts];
        let mut tgt_in = vec![PAD; b * tt];
        let mut tgt_out = vec![PAD; b * tt];
        for r in 0..b {
            let p = self.pair();
            for (i, &tok) in p.src.iter().take(ts).enumerate() {
                src[r * ts + i] = tok;
            }
            // decoder input = BOS + tgt[..-1], output = tgt
            tgt_in[r * tt] = BOS;
            for (i, &tok) in p.tgt.iter().take(tt - 1).enumerate() {
                tgt_in[r * tt + i + 1] = tok;
            }
            for (i, &tok) in p.tgt.iter().take(tt).enumerate() {
                tgt_out[r * tt + i] = tok;
            }
        }
        NmtBatch { src, tgt_in, tgt_out, batch: b, ts, tt }
    }
}

/// Padded NMT batch matching the artifact input layout.
pub struct NmtBatch {
    pub src: Vec<i32>,
    pub tgt_in: Vec<i32>,
    pub tgt_out: Vec<i32>,
    pub batch: usize,
    pub ts: usize,
    pub tt: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_bounds() {
        let mut g = CorpusGen::new(1);
        for _ in 0..200 {
            let p = g.pair();
            assert!(p.src.iter().all(|&t| t == EOS || (SRC_BASE..TGT_BASE).contains(&t)));
            assert!(p.tgt.iter().all(|&t| t == EOS || (TGT_BASE..64).contains(&t)));
        }
    }

    #[test]
    fn translation_is_deterministic_reordering() {
        let mut g = CorpusGen::new(2);
        for _ in 0..100 {
            let p = g.pair();
            // token counts must match (same content words + EOS)
            assert_eq!(p.src.len(), p.tgt.len());
            // verb-final property: last content token of tgt is a verb id
            let verb_range = TGT_BASE + N_SUBJ as i32
                ..TGT_BASE + (N_SUBJ + N_VERB) as i32;
            let last_content = p.tgt[p.tgt.len() - 2];
            assert!(verb_range.contains(&last_content));
        }
    }

    #[test]
    fn batch_layout() {
        let mut g = CorpusGen::new(3);
        let b = g.batch(4, 12, 12);
        assert_eq!(b.src.len(), 48);
        // decoder input starts with BOS
        for r in 0..4 {
            assert_eq!(b.tgt_in[r * 12], BOS);
        }
        // shifted alignment: tgt_in[i+1] == tgt_out[i] for content tokens
        for r in 0..4 {
            for i in 0..11 {
                if b.tgt_out[r * 12 + i] != PAD && b.tgt_in[r * 12 + i + 1] != PAD {
                    assert_eq!(b.tgt_in[r * 12 + i + 1], b.tgt_out[r * 12 + i]);
                }
            }
        }
    }
}
