//! `cwy` CLI — the L3 coordinator entrypoint.
//!
//! Subcommands:
//!   list                               show artifacts in the manifest
//!   train  --artifact copy_cwy_step    train a step artifact
//!   train-dp --base copy_cwy           data-parallel (grad + all-reduce + apply)
//!   tables --t 1000 --n 1024 --l 128   print the analytical Tables 1-2
//!   verify                             orthogonality cross-checks vs native
//!   serve  --artifact copy_cwy_step    micro-batching inference server
//!   client --requests 1000             load generator (--closed-loop: session harness)
//!   bench-check --committed J --measured J   perf-trajectory CI gate

use anyhow::{bail, Context, Result};
use cwy::coordinator::{checkpoint, Schedule, Trainer};
use cwy::data::{copying::CopyTask, corpus::CorpusGen, digits::DigitTask, video::VideoTask};
use cwy::orthogonal::flops;
use cwy::report::Table;
use cwy::runtime::{Backend, Engine, HostTensor};
use cwy::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "list" => cmd_list(&args),
        "train" => cmd_train(&args),
        "train-dp" => cmd_train_dp(&args),
        "tables" => cmd_tables(&args),
        "verify" => cmd_verify(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "bench-check" => cmd_bench_check(&args),
        _ => {
            eprintln!(
                "usage: cwy <list|train|train-dp|tables|verify|serve|client|bench-check> \
                 [--artifacts DIR] [--backend auto|native|pjrt] ...\n\
                 train:    --artifact NAME --steps N --schedule constant:1e-3 [--seed S] [--ckpt PATH]\n\
                 \x20         or --task copy [--param cwy|hr|tcwy] (native rnn_copy family; uses the\n\
                 \x20         built-in fixture when no artifacts directory exists)\n\
                 \x20         [--trace PATH] writes a Chrome/Perfetto trace + phase summary\n\
                 train-dp: --base NAME --workers W --steps N\n\
                 tables:   [--t 1000 --n 1024 --l 128 --m 128]\n\
                 serve:    --addr HOST:PORT --artifact NAME --workers W --max-batch B --max-wait-us U\n\
                 \x20         [--backend auto|native|pjrt|fake --queue-cap N --lr F]\n\
                 \x20         [--batching continuous|timed --max-conns N --max-inflight N]\n\
                 \x20         [--faults SEED:SPEC deterministic chaos, e.g. 42:panic=0.15,slow=0.05@500;\n\
                 \x20          the CWY_FAULTS env var is the fallback] \n\
                 \x20         (--backend native with no --artifact serves the toy fixture)\n\
                 client:   --addr HOST:PORT --requests N --concurrency C [--deadline-us U --sessions]\n\
                 \x20         or --closed-loop --sessions N --rounds R --conns C (exactly-once harness)\n\
                 \x20         [--retries N resend budget for overloaded/stale_state/worker_failed]\n\
                 \x20         [--stats fetch+print the server metrics frame only] [--prom]\n\
                 bench-check: --committed BENCH.json --measured BENCH.json (CI perf gate)\n\
                 --backend auto (default) prefers PJRT and falls back to the native rust backend."
            );
            Ok(())
        }
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts", "artifacts")
}

/// Open the engine honoring the global `--backend` flag (DESIGN.md §2.6).
fn open_engine(args: &Args) -> Result<Engine> {
    let backend = Backend::parse(&args.get_or("backend", "auto"))?;
    Engine::open_with(artifacts_dir(args), backend)
}

fn cmd_list(args: &Args) -> Result<()> {
    let engine = open_engine(args)?;
    let mut t = Table::new(&["artifact", "kind", "task", "method", "params"]);
    for (name, spec) in &engine.manifest.artifacts {
        t.row(&[
            name.clone(),
            spec.kind.clone(),
            spec.meta_str("task").unwrap_or("-").to_string(),
            spec.meta_str("method").unwrap_or("-").to_string(),
            spec.meta_str("param_count").unwrap_or("-").to_string(),
        ]);
    }
    print!("{}", t.to_markdown());
    Ok(())
}

/// Build the right data provider for a task given the artifact meta.
fn make_provider(
    task: &str,
    spec: &cwy::runtime::ArtifactSpec,
    seed: u64,
) -> Result<Box<dyn FnMut() -> Vec<HostTensor>>> {
    match task {
        "copy" => {
            let t_blank: usize = spec.meta_str("t_blank").unwrap_or("64").parse()?;
            let batch: usize = spec.meta_str("batch").unwrap_or("32").parse()?;
            let mut gen = CopyTask::new(t_blank, batch, seed);
            let t_total = gen.t_total();
            Ok(Box::new(move || {
                let b = gen.next_batch();
                vec![
                    HostTensor::i32(vec![b.batch, t_total], b.tokens),
                    HostTensor::i32(vec![b.batch, t_total], b.targets),
                ]
            }))
        }
        "smnist" => {
            let batch: usize = spec.meta_str("batch").unwrap_or("32").parse()?;
            let t: usize = spec.meta_str("t").unwrap_or("196").parse()?;
            let mut gen = DigitTask::new(batch, seed, false);
            Ok(Box::new(move || {
                let b = gen.next_batch();
                vec![
                    HostTensor::f32(vec![b.batch, t], b.pixels),
                    HostTensor::i32(vec![b.batch], b.labels),
                ]
            }))
        }
        "nmt" => {
            let batch: usize = spec.meta_str("batch").unwrap_or("16").parse()?;
            let ts: usize = spec.meta_str("ts").unwrap_or("12").parse()?;
            let tt: usize = spec.meta_str("tt").unwrap_or("12").parse()?;
            let mut gen = CorpusGen::new(seed);
            Ok(Box::new(move || {
                let b = gen.batch(batch, ts, tt);
                vec![
                    HostTensor::i32(vec![batch, ts], b.src),
                    HostTensor::i32(vec![batch, tt], b.tgt_in),
                    HostTensor::i32(vec![batch, tt], b.tgt_out),
                ]
            }))
        }
        "video" => {
            let batch: usize = spec.meta_str("batch").unwrap_or("4").parse()?;
            let t: usize = spec.meta_str("t").unwrap_or("8").parse()?;
            let hw: usize = spec.meta_str("hw").unwrap_or("16").parse()?;
            let mut gen = VideoTask::new(hw, t, batch, seed);
            Ok(Box::new(move || {
                vec![HostTensor::f32(vec![batch, t, hw, hw, 1], gen.batch_mixed())]
            }))
        }
        other => bail!("unknown task '{other}'"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    // Resolve the artifact: explicit --artifact, or the task/param pair
    // (`--task copy --param cwy|hr|tcwy`) naming the trainable rnn_copy
    // family; with no artifacts directory the native fixture supplies it,
    // so `cwy train --task copy --backend native` works from a bare
    // checkout (DESIGN.md §3.2).
    let (name, task_mode) = match args.get("artifact") {
        Some(n) => (n.to_string(), false),
        None => {
            let task = args.get_or("task", "");
            if task != "copy" {
                anyhow::bail!(
                    "train needs --artifact NAME, or --task copy \
                     [--param cwy|hr|tcwy] for the native copy-task family"
                );
            }
            let param = args.get_or("param", "cwy");
            if !["cwy", "hr", "tcwy"].contains(&param.as_str()) {
                anyhow::bail!("--param must be cwy|hr|tcwy, got '{param}'");
            }
            (format!("copy_{param}_step"), true)
        }
    };
    let dir = artifacts_dir(args);
    let mut _fixture_guard: Option<cwy::runtime::fixture::TempDir> = None;
    let engine = if task_mode
        && !std::path::Path::new(&dir).join("manifest.json").exists()
    {
        let backend = Backend::parse(&args.get_or("backend", "auto"))?;
        let tmp = cwy::runtime::fixture::TempDir::with_toy_artifacts("train-demo")?;
        println!("# no artifacts at {dir}: training {name} from the native fixture");
        let e = Engine::open_with(tmp.path(), backend)?;
        _fixture_guard = Some(tmp);
        e
    } else {
        open_engine(args)?
    };
    // Task mode defaults to the configuration the fixture is tuned for:
    // the paper's k^-0.5 rate (Thm 4) and enough steps to beat the
    // memoryless baseline.
    let steps = args.get_usize("steps", if task_mode { 300 } else { 100 });
    let seed = args.get_usize("seed", 0) as u64;
    let default_schedule = if task_mode { "invsqrt:0.5" } else { "constant:0.001" };
    let schedule = Schedule::parse(&args.get_or("schedule", default_schedule))
        .ok_or_else(|| anyhow::anyhow!("bad --schedule"))?;
    let log_every = args.get_usize("log-every", 10);
    // --trace PATH: install the process trace ring before the first step
    // so every span of the run is captured (DESIGN.md §7).  1M slots
    // covers ~100k steps of the 10-span native pipeline.
    let trace_path = args.get("trace");
    if trace_path.is_some() {
        cwy::telemetry::enable_tracing(1 << 20);
    }

    let mut trainer = Trainer::new(&engine, &name, schedule)?;
    let task = trainer
        .artifact
        .spec
        .meta_str("task")
        .unwrap_or("copy")
        .to_string();
    let mut provider = make_provider(&task, &trainer.artifact.spec, seed)?;

    println!(
        "# training {name} for {steps} steps (task={task}, backend={})",
        engine.platform()
    );
    let baseline = trainer
        .artifact
        .spec
        .meta_str("t_blank")
        .and_then(|s| s.parse::<usize>().ok())
        .map(|t_blank| CopyTask::new(t_blank, 1, 0).baseline_ce());
    if let Some(b) = baseline {
        println!("# memoryless-baseline CE (10 ln 8 / (T+20)): {b:.4}");
    }
    trainer.train(&mut provider, steps, |step, loss, metrics| {
        if step % log_every == 0 || step + 1 == steps {
            println!("step {step:>5}  loss {loss:.5}  metrics {metrics:?}");
        }
    })?;
    let final_loss = trainer.history.recent_mean_loss(10).unwrap_or(f32::NAN);
    println!(
        "# done: final loss {:.5} (last-10 mean {final_loss:.5}), total wall {:.2}s",
        trainer.history.last_loss().unwrap_or(f32::NAN),
        trainer.history.total_wall_s()
    );
    if let Some(gn) = trainer.history.last_metric("grad_norm") {
        println!("# final grad norm: {gn:.5}");
    }
    if let Some(b) = baseline {
        println!(
            "# {} the memoryless baseline ({b:.4})",
            if final_loss < b { "BELOW" } else { "ABOVE" }
        );
    }
    if let Some(path) = trace_path {
        let (events, dropped) = cwy::telemetry::write_chrome_trace(path)?;
        println!("# trace -> {path} ({events} events, {dropped} dropped)");
        let totals = trainer.history.phase_totals_ns();
        let coverage = trainer.history.phase_coverage();
        println!(
            "# phase totals: forward {:.3}s  backward {:.3}s  sgd {:.3}s  \
             = {:.1}% of {:.2}s step wall{}",
            totals[0] as f64 / 1e9,
            totals[1] as f64 / 1e9,
            totals[2] as f64 / 1e9,
            100.0 * coverage,
            trainer.history.total_wall_s(),
            if coverage < 0.9 { " (target >= 90% on the native backend)" } else { "" }
        );
    }
    if let Some(path) = args.get("ckpt") {
        checkpoint::save(path, trainer.step, &trainer.state)?;
        println!("# checkpoint -> {path}");
    }
    if let Some(path) = args.get("curve") {
        std::fs::write(path, trainer.history.to_csv())?;
        println!("# curve -> {path}");
    }
    Ok(())
}

fn cmd_train_dp(args: &Args) -> Result<()> {
    let engine = open_engine(args)?;
    let base = args
        .get("base")
        .ok_or_else(|| anyhow::anyhow!("--base required (e.g. copy_cwy)"))?;
    let workers = args.get_usize("workers", 4);
    let steps = args.get_usize("steps", 50);
    let seed = args.get_usize("seed", 0) as u64;
    let schedule = Schedule::parse(&args.get_or("schedule", "constant:0.001"))
        .ok_or_else(|| anyhow::anyhow!("bad --schedule"))?;

    let mut dp = cwy::coordinator::DataParallel::new(&engine, base, workers, schedule)?;
    let step_spec = engine.manifest.get(&format!("{base}_step"))?.clone();
    let task = step_spec.meta_str("task").unwrap_or("copy").to_string();

    println!("# data-parallel training {base}: {workers} workers, {steps} steps");
    let mut providers: Vec<Box<dyn FnMut() -> Vec<HostTensor>>> = (0..workers)
        .map(|w| make_provider(&task, &step_spec, seed + 1000 * w as u64))
        .collect::<Result<_>>()?;
    for s in 0..steps {
        let batches: Vec<Vec<HostTensor>> =
            providers.iter_mut().map(|p| p()).collect();
        let loss = dp.train_step(batches)?;
        if s % 10 == 0 || s + 1 == steps {
            println!("step {s:>5}  mean worker loss {loss:.5}");
        }
    }
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let t = args.get_usize("t", 1000);
    let n = args.get_usize("n", 1024);
    let l = args.get_usize("l", 128);
    let m = args.get_usize("m", 128);

    println!("## Table 1 (forward-pass complexity; FLOPs at T={t}, N={n}, L={l})\n");
    let mut t1 = Table::new(&["METHOD", "SERIAL", "PARALLEL", "DOMAIN", "FLOPs"]);
    for r in flops::table1(t, n, l) {
        t1.row(&[
            r.method.to_string(),
            r.serial.to_string(),
            r.parallel.to_string(),
            r.domain.to_string(),
            format!("{:.3e}", r.flops),
        ]);
    }
    print!("{}", t1.to_markdown());

    println!("\n## Table 2 (Stiefel step; FLOPs at N={n}, M={m})\n");
    let mut t2 = Table::new(&["APPROACH", "PARALLEL TIME", "INVERTED MATRIX", "FLOPs expr", "FLOPs"]);
    for r in flops::table2(n, m) {
        t2.row(&[
            r.method.to_string(),
            r.parallel.to_string(),
            r.inverted.to_string(),
            r.flops_expr.to_string(),
            format!("{:.3e}", r.flops),
        ]);
    }
    print!("{}", t2.to_markdown());
    Ok(())
}

/// Cross-check artifact constructions against the native implementations.
fn cmd_verify(args: &Args) -> Result<()> {
    use cwy::linalg::Matrix;
    use cwy::util::rng::Pcg32;

    let engine = open_engine(args)?;
    let mut failures = 0;

    // CWY: artifact param_cwy_n64 vs native construction.
    for n in [64usize, 128] {
        let name = format!("param_cwy_n{n}");
        if engine.manifest.get(&name).is_err() {
            continue;
        }
        let art = engine.load(&name)?;
        let mut rng = Pcg32::seeded(123);
        let v = Matrix::random_normal(&mut rng, n, n, 1.0);
        let out = art.run(&[HostTensor::f32(vec![n, n], v.data.clone())])?;
        let q_art = Matrix::from_rows(n, n, out[0].as_f32()?.to_vec());
        let q_nat = cwy::orthogonal::cwy::matrix(&v);
        let diff = q_art.max_abs_diff(&q_nat);
        let defect = q_art.orthogonality_defect();
        let ok = diff < 2e-3 && defect < 2e-3;
        println!("{name}: |art-native|={diff:.2e} defect={defect:.2e} {}",
                 if ok { "OK" } else { "FAIL" });
        if !ok {
            failures += 1;
        }
    }

    // T-CWY Stiefel check.
    if engine.manifest.get("stiefel_tcwy_construct").is_ok() {
        let art = engine.load("stiefel_tcwy_construct")?;
        let (n, m) = (256usize, 32usize);
        let mut rng = Pcg32::seeded(5);
        let v = Matrix::random_normal(&mut rng, m, n, 1.0);
        let out = art.run(&[HostTensor::f32(vec![m, n], v.data.clone())])?;
        let omega = Matrix::from_rows(n, m, out[0].as_f32()?.to_vec());
        let native = cwy::orthogonal::tcwy::matrix(&v);
        let diff = omega.max_abs_diff(&native);
        let defect = omega.orthogonality_defect();
        let ok = diff < 2e-3 && defect < 2e-3;
        println!("stiefel_tcwy_construct: |art-native|={diff:.2e} defect={defect:.2e} {}",
                 if ok { "OK" } else { "FAIL" });
        if !ok {
            failures += 1;
        }
    }

    if failures > 0 {
        bail!("{failures} verification failures");
    }
    println!("all verifications passed");
    Ok(())
}

/// Micro-batching inference server over the runtime backend seam
/// (DESIGN.md §2.6, §6): engine-backed workers (`auto|native|pjrt`) or
/// the deterministic in-process `fake` model.
fn cmd_serve(args: &Args) -> Result<()> {
    use cwy::serve::{
        probe_serve_spec, serve, AdmissionCfg, BatchCfg, EngineModel, FakeModel, FaultPlan,
        ModelFactory, RestartPolicy, ServeCfg, ServeModel, SessionCfg,
    };
    use std::sync::Arc;

    let addr = args.get_or("addr", "127.0.0.1:7070");
    let workers = args.get_usize("workers", 2);
    let mut max_batch = args.get_usize("max-batch", 8);
    let max_wait_us = args.get_usize("max-wait-us", 2_000) as u64;
    let queue_cap = args.get_usize("queue-cap", 1_024);
    let continuous = match args.get_or("batching", "continuous").as_str() {
        "continuous" => true,
        "timed" => false,
        other => bail!("--batching must be `continuous` or `timed`, got `{other}`"),
    };
    let admission_defaults = AdmissionCfg::default();
    let admission = AdmissionCfg {
        max_connections: args.get_usize("max-conns", admission_defaults.max_connections),
        max_inflight_per_conn: args
            .get_usize("max-inflight", admission_defaults.max_inflight_per_conn),
        ..admission_defaults
    };
    let lr = args.get_f32("lr", 0.0);
    // Deterministic chaos: `--faults seed:spec` wins over the CWY_FAULTS
    // env var (the CI chaos matrix sets the env; a flag overrides it for
    // local repros).  DESIGN.md §6.8 documents the grammar.
    let fault_spec = args
        .get("faults")
        .map(|s| s.to_string())
        .or_else(|| std::env::var("CWY_FAULTS").ok());
    let faults = match fault_spec {
        Some(s) if !s.trim().is_empty() => Some(FaultPlan::parse(&s)?),
        _ => None,
    };
    let default_backend = if args.get("artifact").is_some() { "auto" } else { "fake" };
    let backend = args.get_or("backend", default_backend);

    // Keeps a demo fixture directory alive for the server's lifetime
    // (dropped — and cleaned up — only after `join` returns).
    let mut _fixture_guard: Option<cwy::runtime::fixture::TempDir> = None;
    let factory: Arc<ModelFactory> = match backend.as_str() {
        "fake" => {
            let batch = max_batch;
            let dim = args.get_usize("fake-dim", 16);
            let delay_us = args.get_usize("fake-delay-us", 200) as u64;
            Arc::new(move || Ok(Box::new(FakeModel::new(batch, dim, delay_us)) as Box<dyn ServeModel>))
        }
        engine_backend => {
            let chosen = Backend::parse(engine_backend)?;
            let (dir, name) = match args.get("artifact") {
                Some(n) => (artifacts_dir(args), n.to_string()),
                None if chosen == Backend::Native => {
                    // Zero-setup demo: serve the toy fixture's CWY cell.
                    let tmp = cwy::runtime::fixture::TempDir::with_toy_artifacts("serve-demo")?;
                    let dir = tmp.path().display().to_string();
                    _fixture_guard = Some(tmp);
                    println!("# no --artifact: serving toy_cell_step from fixture {dir}");
                    (dir, "toy_cell_step".to_string())
                }
                None => bail!("--artifact required with --backend {engine_backend}"),
            };
            // Probe the manifest (no compile): the artifact's fused batch
            // is the ceiling (the worker chunks at it regardless) and the
            // default when no --max-batch is given; an explicit smaller
            // --max-batch still limits coalescing.
            let (serve_spec, art_spec) = probe_serve_spec(&dir, &name)?;
            let fused = serve_spec.batch;
            max_batch = match args.get("max-batch") {
                None => fused,
                Some(_) if max_batch > fused => {
                    println!(
                        "# --max-batch {max_batch} exceeds the artifact's fused batch; \
                         using {fused}"
                    );
                    fused
                }
                Some(_) => max_batch,
            };
            // The native cell_* ops serve frozen parameters (V' = V), so a
            // nonzero --lr would be a silent no-op — say so up front.
            if lr != 0.0
                && art_spec.meta_str("op").is_some_and(|op| op.starts_with("cell_"))
            {
                println!(
                    "# note: --lr {lr} has no effect on native op '{}': \
                     recurrent cells serve frozen parameters (DESIGN.md §2.6)",
                    art_spec.meta_str("op").unwrap_or("?")
                );
            }
            Arc::new(move || {
                Ok(Box::new(EngineModel::open_with(&dir, &name, chosen)?) as Box<dyn ServeModel>)
            })
        }
    };

    let cfg = ServeCfg {
        addr,
        workers,
        batch: BatchCfg { max_batch, max_wait_us, queue_cap, continuous },
        session: SessionCfg::default(),
        admission,
        lr,
        restart: RestartPolicy::default(),
        faults,
    };
    let server = serve(cfg, factory)?;
    println!(
        "# cwy serve: {} backend on {} ({} workers, max-batch {}, max-wait {}us, \
         {} batching, max-conns {})",
        backend,
        server.local_addr(),
        workers,
        max_batch,
        max_wait_us,
        if continuous { "continuous" } else { "timed" },
        admission.max_connections,
    );
    server.join();
    Ok(())
}

/// Closed-loop load generator; exits non-zero on any dropped
/// (non-deadline) request so CI can assert serving health.
///
/// After the run (or instead of it, with `--stats`) the server's
/// `metrics` frame renders as the final latency table — p50/p95/p99/p999,
/// shed/occupancy, and the per-phase queue/assemble/execute/write-back
/// percentiles from the telemetry registry.  `--prom` additionally dumps
/// the Prometheus text exposition of the same frame.
fn cmd_client(args: &Args) -> Result<()> {
    use cwy::serve::{
        fetch_metrics, fetch_stats, metrics_table, run_load, run_sessions, ClientCfg,
        SessionLoadCfg,
    };

    let addr = args.get_or("addr", "127.0.0.1:7070");
    let show_metrics = |addr: &str| -> Result<()> {
        let frame = fetch_metrics(addr)?;
        print!("{}", metrics_table(&frame).to_markdown());
        if args.has_flag("prom") {
            print!("{}", cwy::telemetry::render_prometheus(frame.path(&["telemetry"])));
        }
        Ok(())
    };
    if args.has_flag("stats") {
        return show_metrics(&addr);
    }

    if args.has_flag("closed-loop") {
        let defaults = SessionLoadCfg::default();
        let cfg = SessionLoadCfg {
            addr,
            sessions: args.get_usize("sessions", defaults.sessions),
            rounds: args.get_usize("rounds", defaults.rounds),
            conns: args.get_usize("conns", defaults.conns),
            deadline_us: args.get("deadline-us").and_then(|v| v.parse().ok()),
            use_sessions: !args.has_flag("no-session-state"),
            max_retries: args.get_usize("retries", defaults.max_retries as usize) as u32,
        };
        println!(
            "# cwy client --closed-loop: {} sessions x {} rounds over {} connections -> {}",
            cfg.sessions, cfg.rounds, cfg.conns, cfg.addr
        );
        let report = run_sessions(&cfg)?;
        print!("{}", report.to_table().to_markdown());
        let _ = show_metrics(&cfg.addr);
        if !report.complete() {
            bail!(
                "closed-loop invariant violated: sent {} answered {} \
                 (unanswered {}, duplicates {}, stray {}, conn failures {})",
                report.sent,
                report.answered(),
                report.unanswered,
                report.duplicates,
                report.stray,
                report.conn_failures
            );
        }
        println!("closed-loop OK: every request answered exactly once");
        return Ok(());
    }

    let cfg = ClientCfg {
        addr,
        requests: args.get_usize("requests", 1_000),
        concurrency: args.get_usize("concurrency", 32),
        deadline_us: args.get("deadline-us").and_then(|v| v.parse().ok()),
        use_sessions: args.has_flag("sessions"),
        max_retries: args.get_usize("retries", 3) as u32,
    };
    println!(
        "# cwy client: {} requests over {} connections -> {}",
        cfg.requests, cfg.concurrency, cfg.addr
    );
    let report = run_load(&cfg)?;
    print!("{}", report.to_table().to_markdown());
    if show_metrics(&cfg.addr).is_err() {
        // Pre-metrics servers still answer the bare stats frame.
        if let Ok(stats) = fetch_stats(&cfg.addr) {
            println!("# server stats: {stats}");
        }
    }
    if report.dropped() > 0 {
        bail!("{} requests dropped without a deadline excuse", report.dropped());
    }
    Ok(())
}

/// CI gate over the perf-trajectory files:
///
/// * every kernel key staked in the committed `BENCH_*.json` must be
///   present in the freshly measured file (a kernel silently vanishing
///   from a bench is a failure, not a skip);
/// * every **measured** median must be non-zero — a 0.0 median means the
///   bench never actually timed anything, the blind spot that let
///   placeholder trajectory files ride through CI unmeasured.  Committed
///   files may stake keys at 0.0 (awaiting their first CI measurement);
///   the measured side may not;
/// * the ISSUE 5 fused/PR-4 BPTT ratio (>= 1.5x at N=128 L=64) is
///   re-enforced whenever the measured run covered the acceptance shape;
/// * when the measured run dispatched the `avx2fma` microkernel (the
///   top-level `kernel` stamp), the SIMD GEMM must beat the frozen
///   `gemm::legacy` oracle by >= 2x at N=128 and N=256.  On a
///   portable-only host the stamp says `portable` and this gate is
///   reported as skipped rather than measuring a meaningless ratio;
/// * the ISSUE 9 pool-scaling gate: with >= 3 pool workers on the
///   measuring host, the threads=4 training step must be >= 1.8x the
///   threads=1 step at the dedicated scaling shape;
/// * the ISSUE 9 operand-cache gate: a measured pack-cache hit rate of
///   exactly 0 fails (the packed hot path stopped consulting the cache).
fn cmd_bench_check(args: &Args) -> Result<()> {
    use cwy::util::json::{self, Json};

    let committed_path = args
        .get("committed")
        .ok_or_else(|| anyhow::anyhow!("--committed PATH required"))?;
    let measured_path = args
        .get("measured")
        .ok_or_else(|| anyhow::anyhow!("--measured PATH required"))?;
    let read = |p: &str| -> Result<Json> {
        let text = std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?;
        let j = json::parse(&text).map_err(|e| anyhow::anyhow!("{p}: {e}"))?;
        if j.path(&["schema"]).as_str() != Some("cwy-bench-trajectory-v1") {
            bail!("{p}: not a cwy-bench-trajectory-v1 file");
        }
        Ok(j)
    };
    let committed = read(committed_path)?;
    let measured = read(measured_path)?;

    let mut checked = 0usize;
    let mut staked = 0usize;
    let mut missing: Vec<String> = Vec::new();
    if let Json::Obj(benches) = committed.path(&["benches"]) {
        for (bench, kernels) in benches {
            if let Json::Obj(ks) = kernels {
                for (kernel, median) in ks {
                    checked += 1;
                    if median.as_f64() == Some(0.0) {
                        staked += 1; // committed stake awaiting first CI run
                    }
                    if measured.path(&["benches", bench, kernel]).as_f64().is_none() {
                        missing.push(format!("{bench}.{kernel}"));
                    }
                }
            }
        }
    }
    if !missing.is_empty() {
        bail!(
            "{} committed trajectory kernels missing from the measured run \
             (a bench stopped emitting them): {}",
            missing.len(),
            missing.join(", ")
        );
    }
    if staked > 0 {
        println!("# bench-check: {staked} committed stake keys awaiting first CI measurement");
    }
    println!("# bench-check: all {checked} committed kernels present in the measured run");

    // Measured 0.0 medians are a hard failure everywhere, not just on the
    // keys the ratio gates read.
    let mut zeros: Vec<String> = Vec::new();
    if let Json::Obj(benches) = measured.path(&["benches"]) {
        for (bench, kernels) in benches {
            if let Json::Obj(ks) = kernels {
                for (kernel, median) in ks {
                    if median.as_f64().map(|x| x <= 0.0).unwrap_or(true) {
                        zeros.push(format!("{bench}.{kernel}"));
                    }
                }
            }
        }
    }
    if !zeros.is_empty() {
        bail!(
            "{} measured medians are 0.0 (the bench never timed them): {}",
            zeros.len(),
            zeros.join(", ")
        );
    }

    let fused = measured
        .path(&["benches", "bptt_native", "rollout_bwd_fused_n128_l64"])
        .as_f64();
    let pr4 = measured
        .path(&["benches", "bptt_native", "rollout_bwd_pr4_n128_l64"])
        .as_f64();
    match (fused, pr4) {
        (Some(f), Some(p)) if f > 0.0 => {
            let ratio = p / f;
            println!(
                "# bench-check: fused BPTT is {ratio:.2}x PR-4 at N=128 L=64 \
                 (target >= 1.5x)"
            );
            if ratio < 1.5 {
                bail!("fused rollout backward regressed to {ratio:.2}x PR-4 (target >= 1.5x)");
            }
        }
        _ => println!("# bench-check: acceptance shape not measured; ratio gate skipped"),
    }

    // SIMD microkernel acceptance (ISSUE 7): gemm_nn must beat the frozen
    // legacy oracle >= 2x at both acceptance sizes — but only when the
    // measuring host actually ran the avx2+fma kernel.
    match measured.path(&["kernel"]).as_str() {
        Some("avx2fma") => {
            for n in [128usize, 256] {
                let simd = measured
                    .path(&["benches", "gemm_native", &format!("gemm_nn_n{n}")])
                    .as_f64();
                let legacy = measured
                    .path(&["benches", "gemm_native", &format!("legacy_nn_n{n}")])
                    .as_f64();
                match (simd, legacy) {
                    (Some(s), Some(l)) if s > 0.0 => {
                        let ratio = l / s;
                        println!(
                            "# bench-check: simd gemm_nn is {ratio:.2}x legacy at N={n} \
                             (target >= 2.0x)"
                        );
                        if ratio < 2.0 {
                            bail!(
                                "simd gemm_nn is only {ratio:.2}x legacy at N={n} \
                                 (target >= 2.0x)"
                            );
                        }
                    }
                    _ => bail!(
                        "avx2fma run is missing gemm_nn_n{n}/legacy_nn_n{n} medians \
                         needed for the SIMD ratio gate"
                    ),
                }
            }
        }
        Some(k) => println!("# bench-check: measured kernel is `{k}`; SIMD ratio gate skipped"),
        None => println!("# bench-check: measured file has no kernel stamp; SIMD gate skipped"),
    }

    // Continuous-batching acceptance (ISSUE 8): when the closed-loop
    // serve_load bench ran, its mean occupancy must show real coalescing
    // (>= 1.5 rows per fused execution at production concurrency) and the
    // latency tail must be ordered sanely (p99 >= p50 — a crossed tail
    // means the percentile accounting itself is broken).
    let occ = measured
        .path(&["benches", "serve_load", "mean_occupancy_milli"])
        .as_f64();
    let p50 = measured.path(&["benches", "serve_load", "closed_loop_p50_ns"]).as_f64();
    let p99 = measured.path(&["benches", "serve_load", "closed_loop_p99_ns"]).as_f64();
    match (occ, p50, p99) {
        (Some(occ), Some(p50), Some(p99)) if occ > 0.0 => {
            println!(
                "# bench-check: closed-loop occupancy {:.2} rows/exec, \
                 p50 {:.0}ns p99 {:.0}ns (target occupancy >= 1.5)",
                occ / 1000.0,
                p50,
                p99
            );
            if occ < 1_500.0 {
                bail!(
                    "closed-loop mean occupancy {:.2} rows/exec: continuous batching \
                     is not coalescing (target >= 1.5)",
                    occ / 1000.0
                );
            }
            if p99 < p50 {
                bail!("closed-loop p99 ({p99:.0}ns) below p50 ({p50:.0}ns): broken percentiles");
            }
        }
        _ => println!("# bench-check: serve_load not measured; occupancy gate skipped"),
    }

    // Persistent-pool scaling acceptance (ISSUE 9): on hosts where the
    // pool actually has workers to scale onto (>= 3, i.e. >= 4 usable
    // cores — the bench only emits `pool_workers` when it saw any), the
    // threads=4 training step at the dedicated scaling shape must beat
    // threads=1 by >= 1.8x.  Fewer workers means the ratio measures the
    // host, not the pool, so the gate reports a loud skip instead.
    let t1 = measured
        .path(&["benches", "rollout_e2e", "scaling_train_step_threads1"])
        .as_f64();
    let t4 = measured
        .path(&["benches", "rollout_e2e", "scaling_train_step_threads4"])
        .as_f64();
    let workers = measured.path(&["benches", "rollout_e2e", "pool_workers"]).as_f64();
    match (t1, t4, workers) {
        (Some(t1), Some(t4), Some(w)) if w >= 3.0 && t4 > 0.0 => {
            let ratio = t1 / t4;
            println!(
                "# bench-check: pooled train step is {ratio:.2}x threads=1 at threads=4 \
                 ({w:.0} workers; target >= 1.8x)"
            );
            if ratio < 1.8 {
                bail!(
                    "pooled threads=4 train step is only {ratio:.2}x threads=1 \
                     (target >= 1.8x)"
                );
            }
        }
        (Some(_), Some(_), _) => println!(
            "# bench-check: fewer than 3 pool workers on the measuring host; \
             pool scaling gate skipped"
        ),
        _ => println!("# bench-check: rollout_e2e scaling rows not measured; pool gate skipped"),
    }

    // Operand-cache acceptance (ISSUE 9): the packed-gemm hot path must
    // actually be served from the cache.  A measured rate of 0 means the
    // tape/serve paths silently fell back to per-call packing.
    match measured
        .path(&["benches", "rollout_e2e", "pack_cache_hit_rate_milli"])
        .as_f64()
    {
        Some(rate) if rate > 0.0 => {
            println!("# bench-check: operand-pack cache hit rate {:.1}% (target > 0)", rate / 10.0)
        }
        Some(_) => bail!(
            "operand-pack cache hit rate is 0: the packed-gemm hot path stopped \
             using the cache"
        ),
        None => println!("# bench-check: pack-cache rate not measured; cache gate skipped"),
    }
    println!("bench-check OK");
    Ok(())
}
