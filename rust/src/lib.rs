//! # cwy — CWY / T-CWY orthogonal-optimization framework
//!
//! Rust + JAX + Pallas reproduction of *"CWY Parametrization: a Solution for
//! Parallelized Optimization of Orthogonal and Stiefel Matrices"*
//! (Likhosherstov, Davis, Choromanski, Weller; AISTATS 2021).
//!
//! Three layers (see DESIGN.md):
//! * **L1** Pallas kernels and **L2** JAX models live under `python/compile/`
//!   and are lowered once (`make artifacts`) to HLO text.
//! * **L3** (this crate) is the coordinator: it loads the artifacts through
//!   [`runtime::Engine`], trains with [`coordinator::Trainer`] /
//!   [`coordinator::DataParallel`], generates data with [`data`], and
//!   cross-checks everything against the native implementations in
//!   [`orthogonal`] + [`linalg`].
//! * **L4** is the serving fabric: [`serve`] turns the runtime into a
//!   multi-threaded, micro-batching inference server (`cwy serve`) with a
//!   matching load generator (`cwy client`).

pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod optim;
pub mod orthogonal;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod telemetry;
pub mod util;
