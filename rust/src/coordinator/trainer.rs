//! Training orchestrator over AOT step artifacts.
//!
//! A `Trainer` owns the flat state vector and drives `state' = step(state,
//! data, lr)` executions; the convention (state... / data... / lr inputs,
//! state'... / metrics... outputs) is recorded per-artifact in the manifest,
//! so this loop is generic over every task/method in the repo.

use std::rc::Rc;

use anyhow::{bail, Result};

use super::metrics::History;
use super::schedule::Schedule;
use crate::runtime::engine::{Compiled, Engine};
use crate::runtime::tensor::HostTensor;
use crate::util::timing::Stopwatch;

/// Supplies the `data...` tensors for each step (batch generators live in
/// `crate::data`; examples adapt them through closures).
pub trait DataProvider {
    fn next_batch(&mut self) -> Vec<HostTensor>;
}

impl<F: FnMut() -> Vec<HostTensor>> DataProvider for F {
    fn next_batch(&mut self) -> Vec<HostTensor> {
        self()
    }
}

pub struct Trainer {
    pub artifact: Rc<Compiled>,
    pub state: Vec<HostTensor>,
    pub schedule: Schedule,
    pub history: History,
    pub step: usize,
    n_state: usize,
    n_data: usize,
    has_lr: bool,
}

impl Trainer {
    /// Build from a `*_step` artifact, loading its recorded initial state.
    pub fn new(engine: &Engine, artifact_name: &str, schedule: Schedule) -> Result<Trainer> {
        let artifact = engine.load(artifact_name)?;
        let state = engine.initial_state(artifact_name)?;
        let n_state = artifact.spec.n_state();
        let n_data = artifact.spec.n_data();
        if state.len() != n_state {
            bail!(
                "{artifact_name}: state.bin has {} tensors, manifest says {n_state}",
                state.len()
            );
        }
        // Outputs beyond the state are metrics, loss first.  The loss is
        // recorded separately by `History::push`, so the named columns
        // cover only the *extra* metrics (e.g. the rnn_copy family's
        // per-step `grad_norm` descent diagnostic) — previously the loss
        // name leaked in here and desynced the CSV header from its rows.
        let metric_names: Vec<String> = artifact.spec.outputs[n_state..]
            .iter()
            .skip(1)
            .map(|s| s.name.clone())
            .collect();
        Ok(Trainer {
            artifact,
            state,
            schedule,
            history: History::new(metric_names),
            step: 0,
            n_state,
            n_data,
            has_lr: true,
        })
    }

    /// Restore state from a checkpoint produced by `checkpoint::save`.
    pub fn restore(&mut self, step: usize, state: Vec<HostTensor>) -> Result<()> {
        if state.len() != self.n_state {
            bail!("checkpoint has {} tensors, expected {}", state.len(), self.n_state);
        }
        self.state = state;
        self.step = step;
        Ok(())
    }

    /// One fused train step; returns (loss, metrics beyond loss).
    pub fn train_step(&mut self, data: Vec<HostTensor>) -> Result<(f32, Vec<f32>)> {
        if data.len() != self.n_data {
            bail!("step got {} data tensors, expected {}", data.len(), self.n_data);
        }
        let lr = self.schedule.at(self.step);
        let lr_t = HostTensor::scalar_f32(lr);
        // Borrow the state instead of cloning it — at N=1024-scale models
        // the state clone dominates rust-side step time (§Perf).
        let mut inputs: Vec<&HostTensor> =
            Vec::with_capacity(self.n_state + self.n_data + 1);
        inputs.extend(self.state.iter());
        inputs.extend(data.iter());
        if self.has_lr {
            inputs.push(&lr_t);
        }
        // Attribute this step's wall time to forward/backward/sgd via
        // telemetry span-ns deltas around the artifact execution.  On the
        // native backend the rollout spans fire inside run_refs; on an
        // uninstrumented backend the deltas are simply zero.
        let reg = crate::telemetry::global();
        let phase_ids = [
            crate::telemetry::SpanId::RolloutForward,
            crate::telemetry::SpanId::BpttBackward,
            crate::telemetry::SpanId::SgdStep,
        ];
        let ns_before = phase_ids.map(|id| reg.span_ns(id));
        let watch = Stopwatch::start();
        let mut outputs = self.artifact.run_refs(&inputs)?;
        let wall = watch.elapsed_s();
        let mut phase_ns = [0u64; 3];
        for (out, (id, before)) in
            phase_ns.iter_mut().zip(phase_ids.iter().zip(ns_before.iter()))
        {
            *out = reg.span_ns(*id).saturating_sub(*before);
        }

        let metrics_out: Vec<HostTensor> = outputs.split_off(self.n_state);
        self.state = outputs;
        let loss = metrics_out
            .first()
            .map(|t| t.scalar())
            .transpose()?
            .unwrap_or(f32::NAN);
        let extra: Vec<f32> = metrics_out[1..]
            .iter()
            .map(|t| t.scalar().unwrap_or(f32::NAN))
            .collect();
        self.history.push_with_phases(self.step, loss, extra.clone(), wall, phase_ns);
        self.step += 1;
        Ok((loss, extra))
    }

    /// Run `steps` iterations pulling batches from `provider`; optional
    /// per-step callback for logging.
    pub fn train(
        &mut self,
        provider: &mut dyn DataProvider,
        steps: usize,
        mut on_step: impl FnMut(usize, f32, &[f32]),
    ) -> Result<()> {
        for _ in 0..steps {
            let batch = provider.next_batch();
            let (loss, metrics) = self.train_step(batch)?;
            on_step(self.step - 1, loss, &metrics);
        }
        Ok(())
    }

    /// The params prefix of the state (before optimizer moments), sized via
    /// the artifact meta's `n_params` when present.
    pub fn params(&self) -> &[HostTensor] {
        let n_params: usize = self
            .artifact
            .spec
            .meta_str("n_params")
            .and_then(|s| s.parse().ok())
            .unwrap_or(self.n_state);
        &self.state[..n_params.min(self.state.len())]
    }
}

/// Run a forward-only `*_eval` artifact on (params..., data...).
pub fn evaluate(
    eval_art: &Compiled,
    params: &[HostTensor],
    data: Vec<HostTensor>,
) -> Result<Vec<f32>> {
    let mut inputs: Vec<&HostTensor> =
        Vec::with_capacity(params.len() + data.len());
    inputs.extend(params.iter());
    inputs.extend(data.iter());
    let out = eval_art.run_refs(&inputs)?;
    out.iter().map(|t| t.scalar()).collect()
}
