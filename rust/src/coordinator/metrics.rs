//! Training metrics: per-step records, EMA smoothing, curve export.

/// Per-step phase column names, aligned with [`StepRecord::phase_ns`]:
/// forward rollout, BPTT backward, SGD apply.  Captured as telemetry
/// span-ns deltas around the step's artifact execution.
pub const PHASE_NAMES: [&str; 3] = ["forward_ns", "backward_ns", "sgd_ns"];

/// One recorded training step.
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub metrics: Vec<f32>,
    pub wall_s: f64,
    /// Nanoseconds attributed to each phase in [`PHASE_NAMES`] order;
    /// all-zero when the executing backend is uninstrumented (PJRT).
    pub phase_ns: [u64; 3],
}

/// Loss/metric history for a run.
#[derive(Clone, Debug, Default)]
pub struct History {
    pub records: Vec<StepRecord>,
    pub metric_names: Vec<String>,
}

impl History {
    pub fn new(metric_names: Vec<String>) -> History {
        History { records: Vec::new(), metric_names }
    }

    pub fn push(&mut self, step: usize, loss: f32, metrics: Vec<f32>, wall_s: f64) {
        self.push_with_phases(step, loss, metrics, wall_s, [0; 3]);
    }

    pub fn push_with_phases(
        &mut self,
        step: usize,
        loss: f32,
        metrics: Vec<f32>,
        wall_s: f64,
        phase_ns: [u64; 3],
    ) {
        self.records.push(StepRecord { step, loss, metrics, wall_s, phase_ns });
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.records.last().map(|r| r.loss)
    }

    /// All recorded values of a named extra metric (e.g. `grad_norm`),
    /// or `None` if the artifact family does not provide it.
    pub fn metric_series(&self, name: &str) -> Option<Vec<f32>> {
        let idx = self.metric_names.iter().position(|n| n == name)?;
        Some(
            self.records
                .iter()
                .map(|r| r.metrics.get(idx).copied().unwrap_or(f32::NAN))
                .collect(),
        )
    }

    /// Most recent value of a named extra metric.
    pub fn last_metric(&self, name: &str) -> Option<f32> {
        let idx = self.metric_names.iter().position(|n| n == name)?;
        self.records.last().and_then(|r| r.metrics.get(idx)).copied()
    }

    /// Mean loss over the most recent `n` steps.
    pub fn recent_mean_loss(&self, n: usize) -> Option<f32> {
        if self.records.is_empty() {
            return None;
        }
        let tail = &self.records[self.records.len().saturating_sub(n)..];
        Some(tail.iter().map(|r| r.loss).sum::<f32>() / tail.len() as f32)
    }

    /// Exponential moving average of the loss curve.
    pub fn ema_loss(&self, alpha: f32) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.records.len());
        let mut ema = None;
        for r in &self.records {
            ema = Some(match ema {
                None => r.loss,
                Some(e) => alpha * r.loss + (1.0 - alpha) * e,
            });
            out.push(ema.unwrap());
        }
        out
    }

    pub fn total_wall_s(&self) -> f64 {
        self.records.iter().map(|r| r.wall_s).sum()
    }

    /// Summed per-phase nanoseconds over all recorded steps, in
    /// [`PHASE_NAMES`] order.
    pub fn phase_totals_ns(&self) -> [u64; 3] {
        let mut out = [0u64; 3];
        for r in &self.records {
            for (acc, &ns) in out.iter_mut().zip(r.phase_ns.iter()) {
                *acc += ns;
            }
        }
        out
    }

    /// Fraction of the total wall time the instrumented phases account
    /// for (0.0 with no records or an uninstrumented backend).  The
    /// `--trace` acceptance gate asserts this is >= 0.9 on native runs.
    pub fn phase_coverage(&self) -> f64 {
        let wall = self.total_wall_s();
        if wall <= 0.0 {
            return 0.0;
        }
        let phase_s = self.phase_totals_ns().iter().sum::<u64>() as f64 * 1e-9;
        phase_s / wall
    }

    /// CSV with header `step,loss,<metrics...>,forward_ns,backward_ns,sgd_ns,wall_s`.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,loss");
        for m in &self.metric_names {
            out.push(',');
            out.push_str(m);
        }
        for p in PHASE_NAMES {
            out.push(',');
            out.push_str(p);
        }
        out.push_str(",wall_s\n");
        for r in &self.records {
            out.push_str(&format!("{},{}", r.step, r.loss));
            for m in &r.metrics {
                out.push_str(&format!(",{m}"));
            }
            for ns in r.phase_ns {
                out.push_str(&format!(",{ns}"));
            }
            out.push_str(&format!(",{:.6}\n", r.wall_s));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> History {
        let mut h = History::new(vec!["acc".into()]);
        for i in 0..10 {
            h.push(i, 10.0 - i as f32, vec![i as f32 / 10.0], 0.01);
        }
        h
    }

    #[test]
    fn recent_mean() {
        let h = sample();
        assert_eq!(h.last_loss(), Some(1.0));
        let m = h.recent_mean_loss(2).unwrap();
        assert!((m - 1.5).abs() < 1e-6);
    }

    #[test]
    fn ema_monotone_for_decreasing_loss() {
        let h = sample();
        let e = h.ema_loss(0.3);
        assert_eq!(e.len(), 10);
        for w in e.windows(2) {
            assert!(w[1] <= w[0] + 1e-6);
        }
    }

    #[test]
    fn csv_header_and_rows() {
        let csv = sample().to_csv();
        let mut lines = csv.lines();
        assert_eq!(
            lines.next().unwrap(),
            "step,loss,acc,forward_ns,backward_ns,sgd_ns,wall_s"
        );
        assert_eq!(csv.lines().count(), 11);
    }

    #[test]
    fn phase_accounting() {
        let mut h = History::new(vec![]);
        h.push_with_phases(0, 1.0, vec![], 1e-3, [400_000, 500_000, 50_000]);
        h.push_with_phases(1, 0.9, vec![], 1e-3, [400_000, 500_000, 50_000]);
        assert_eq!(h.phase_totals_ns(), [800_000, 1_000_000, 100_000]);
        // 1.9ms of phases over 2ms of wall: 95% coverage.
        assert!((h.phase_coverage() - 0.95).abs() < 1e-9);
        // Plain push records zero phases and drags coverage down.
        h.push(2, 0.8, vec![], 1e-3);
        assert!(h.phase_coverage() < 0.95);
        assert!(History::default().phase_coverage() == 0.0);
    }

    #[test]
    fn metric_lookup_by_name() {
        let h = sample();
        let acc = h.metric_series("acc").unwrap();
        assert_eq!(acc.len(), 10);
        assert!((acc[3] - 0.3).abs() < 1e-6);
        assert!((h.last_metric("acc").unwrap() - 0.9).abs() < 1e-6);
        assert!(h.metric_series("grad_norm").is_none());
        assert!(h.last_metric("grad_norm").is_none());
    }
}
