//! Learning-rate schedules.

/// Schedule kinds supported by the trainer CLI.
#[derive(Clone, Debug)]
pub enum Schedule {
    Constant(f32),
    /// lr0 / sqrt(1 + step) — the k^{-0.5} rate of the paper's Theorem 4.
    InvSqrt(f32),
    /// Linear warmup to `lr`, then constant.
    Warmup { lr: f32, warmup_steps: usize },
    /// Step decay: lr * gamma^(step / every).
    StepDecay { lr: f32, gamma: f32, every: usize },
}

impl Schedule {
    pub fn at(&self, step: usize) -> f32 {
        match *self {
            Schedule::Constant(lr) => lr,
            Schedule::InvSqrt(lr0) => lr0 / ((1 + step) as f32).sqrt(),
            Schedule::Warmup { lr, warmup_steps } => {
                if warmup_steps == 0 || step >= warmup_steps {
                    lr
                } else {
                    lr * (step + 1) as f32 / warmup_steps as f32
                }
            }
            Schedule::StepDecay { lr, gamma, every } => {
                lr * gamma.powi((step / every.max(1)) as i32)
            }
        }
    }

    /// Parse "constant:0.001", "invsqrt:0.01", "warmup:0.001:100",
    /// "stepdecay:0.01:0.5:200".
    pub fn parse(s: &str) -> Option<Schedule> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            ["constant", lr] => Some(Schedule::Constant(lr.parse().ok()?)),
            ["invsqrt", lr] => Some(Schedule::InvSqrt(lr.parse().ok()?)),
            ["warmup", lr, w] => Some(Schedule::Warmup {
                lr: lr.parse().ok()?,
                warmup_steps: w.parse().ok()?,
            }),
            ["stepdecay", lr, g, e] => Some(Schedule::StepDecay {
                lr: lr.parse().ok()?,
                gamma: g.parse().ok()?,
                every: e.parse().ok()?,
            }),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invsqrt_matches_theorem_rate() {
        let s = Schedule::InvSqrt(1.0);
        assert!((s.at(0) - 1.0).abs() < 1e-6);
        assert!((s.at(3) - 0.5).abs() < 1e-6);
        assert!((s.at(99) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn warmup_ramps() {
        let s = Schedule::Warmup { lr: 1.0, warmup_steps: 10 };
        assert!(s.at(0) < s.at(5));
        assert_eq!(s.at(10), 1.0);
        assert_eq!(s.at(100), 1.0);
    }

    #[test]
    fn parse_roundtrip() {
        assert!(matches!(Schedule::parse("constant:0.01"), Some(Schedule::Constant(_))));
        assert!(matches!(Schedule::parse("invsqrt:0.1"), Some(Schedule::InvSqrt(_))));
        assert!(Schedule::parse("bogus").is_none());
    }

    #[test]
    fn step_decay() {
        let s = Schedule::StepDecay { lr: 1.0, gamma: 0.5, every: 10 };
        assert_eq!(s.at(0), 1.0);
        assert_eq!(s.at(10), 0.5);
        assert_eq!(s.at(25), 0.25);
    }
}
