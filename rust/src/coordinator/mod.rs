//! L3 coordinator: training orchestration over AOT artifacts.
//!
//! The paper's contribution is a parametrization (L1/L2), so the coordinator
//! is the training fabric around it: generic trainer over step artifacts,
//! simulated multi-worker data parallelism with rust-side all-reduce,
//! schedules, metrics, checkpoints.

pub mod checkpoint;
pub mod metrics;
pub mod parallel;
pub mod schedule;
pub mod trainer;

pub use metrics::History;
pub use parallel::DataParallel;
pub use schedule::Schedule;
pub use trainer::{evaluate, DataProvider, Trainer};
