//! Simulated data-parallel training: W logical workers each compute
//! gradients on a shard via the `*_grad` artifact; the coordinator
//! all-reduces (averages) in rust and applies one fused `*_apply` update.
//!
//! The single CPU PJRT device executes worker grads sequentially — the
//! *communication pattern* (shard -> grad -> all-reduce -> apply) is what
//! this module exercises and tests; on a multi-device PJRT client the same
//! loop maps 1:1 onto devices (DESIGN.md §5).

use std::rc::Rc;

use anyhow::{bail, Result};

use super::metrics::History;
use super::schedule::Schedule;
use crate::runtime::engine::{Compiled, Engine};
use crate::runtime::tensor::HostTensor;
use crate::util::timing::Stopwatch;

pub struct DataParallel {
    grad_art: Rc<Compiled>,
    apply_art: Rc<Compiled>,
    /// Flat state of the apply artifact: params..., m..., v..., t.
    pub state: Vec<HostTensor>,
    pub schedule: Schedule,
    pub history: History,
    pub step: usize,
    pub workers: usize,
    n_params: usize,
}

impl DataParallel {
    /// `base` is the artifact family name, e.g. "copy_cwy" (expects
    /// `<base>_grad` and `<base>_apply` plus `<base>_step` for init state).
    pub fn new(engine: &Engine, base: &str, workers: usize, schedule: Schedule) -> Result<DataParallel> {
        let grad_art = engine.load(&format!("{base}_grad"))?;
        let apply_art = engine.load(&format!("{base}_apply"))?;
        let state = engine.initial_state(&format!("{base}_step"))?;
        let n_params: usize = grad_art
            .spec
            .meta_str("n_params")
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("{base}_grad missing n_params meta"))?;
        if workers == 0 {
            bail!("need at least one worker");
        }
        Ok(DataParallel {
            grad_art,
            apply_art,
            state,
            schedule,
            history: History::new(vec!["loss".into()]),
            step: 0,
            workers,
            n_params,
        })
    }

    pub fn params(&self) -> &[HostTensor] {
        &self.state[..self.n_params]
    }

    /// One data-parallel step over per-worker batches; returns mean loss.
    pub fn train_step(&mut self, worker_batches: Vec<Vec<HostTensor>>) -> Result<f32> {
        if worker_batches.len() != self.workers {
            bail!(
                "got {} worker batches, configured {}",
                worker_batches.len(),
                self.workers
            );
        }
        let watch = Stopwatch::start();
        let params = &self.state[..self.n_params];

        // Fan out gradient computations (one PJRT execution per worker).
        let mut grad_sum: Option<Vec<HostTensor>> = None;
        let mut loss_sum = 0.0f32;
        for batch in &worker_batches {
            let mut inputs: Vec<&HostTensor> =
                Vec::with_capacity(params.len() + batch.len());
            inputs.extend(params.iter());
            inputs.extend(batch.iter());
            let out = self.grad_art.run_refs(&inputs)?;
            let (grads, metrics) = out.split_at(self.n_params);
            loss_sum += metrics[0].scalar()?;
            grad_sum = Some(match grad_sum {
                None => grads.to_vec(),
                Some(mut acc) => {
                    for (a, g) in acc.iter_mut().zip(grads) {
                        let gv = g.as_f32()?;
                        for (x, y) in a.as_f32_mut()?.iter_mut().zip(gv) {
                            *x += *y;
                        }
                    }
                    acc
                }
            });
        }

        // All-reduce: average.
        let mut grads = grad_sum.unwrap();
        let scale = 1.0 / self.workers as f32;
        for g in grads.iter_mut() {
            for x in g.as_f32_mut()? {
                *x *= scale;
            }
        }

        // Fused optimizer apply.
        let lr = self.schedule.at(self.step);
        let lr_t = HostTensor::scalar_f32(lr);
        let mut inputs: Vec<&HostTensor> =
            Vec::with_capacity(self.state.len() + grads.len() + 1);
        inputs.extend(self.state.iter());
        inputs.extend(grads.iter());
        inputs.push(&lr_t);
        self.state = self.apply_art.run_refs(&inputs)?;

        let loss = loss_sum / self.workers as f32;
        self.history.push(self.step, loss, vec![], watch.elapsed_s());
        self.step += 1;
        Ok(loss)
    }
}
