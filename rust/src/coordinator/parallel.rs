//! Simulated data-parallel training: W logical workers each compute
//! gradients on a shard via the `*_grad` artifact; the coordinator
//! all-reduces (averages) in rust and applies one fused `*_apply` update.
//!
//! The single CPU PJRT device executes worker grads sequentially — the
//! *communication pattern* (shard -> grad -> all-reduce -> apply) is what
//! this module exercises and tests; on a multi-device PJRT client the same
//! loop maps 1:1 onto devices (DESIGN.md §5).

use std::rc::Rc;

use anyhow::{bail, Result};

use super::metrics::History;
use super::schedule::Schedule;
use crate::runtime::engine::{Compiled, Engine};
use crate::runtime::tensor::HostTensor;
use crate::util::timing::Stopwatch;

/// Split a global batch along the leading axis into `workers` contiguous
/// shards; the first `rows % workers` shards take one extra row.
///
/// Rejects `workers > rows`: that would hand some workers an empty shard,
/// silently skewing the all-reduce average (DESIGN.md §5).
pub fn shard_batch(batch: &[HostTensor], workers: usize) -> Result<Vec<Vec<HostTensor>>> {
    if workers == 0 {
        bail!("need at least one worker");
    }
    let Some(first) = batch.first() else {
        bail!("cannot shard an empty batch");
    };
    if first.shape.is_empty() {
        bail!("batch tensors must have a leading batch axis");
    }
    let rows = first.shape[0];
    for t in batch {
        if t.shape.first() != Some(&rows) {
            bail!("batch tensors disagree on the leading dim: {:?} vs {rows}", t.shape);
        }
    }
    if workers > rows {
        bail!(
            "workers ({workers}) exceed batch rows ({rows}): \
             every worker needs a non-empty shard"
        );
    }
    let base = rows / workers;
    let extra = rows % workers;
    let mut shards = Vec::with_capacity(workers);
    let mut start = 0usize;
    for w in 0..workers {
        let take = base + usize::from(w < extra);
        let shard: Vec<HostTensor> = batch
            .iter()
            .map(|t| t.slice_rows(start, take))
            .collect::<Result<_>>()?;
        shards.push(shard);
        start += take;
    }
    Ok(shards)
}

pub struct DataParallel {
    grad_art: Rc<Compiled>,
    apply_art: Rc<Compiled>,
    /// Flat state of the apply artifact: params..., m..., v..., t.
    pub state: Vec<HostTensor>,
    pub schedule: Schedule,
    pub history: History,
    pub step: usize,
    pub workers: usize,
    n_params: usize,
}

impl DataParallel {
    /// `base` is the artifact family name, e.g. "copy_cwy" (expects
    /// `<base>_grad` and `<base>_apply` plus `<base>_step` for init state).
    pub fn new(engine: &Engine, base: &str, workers: usize, schedule: Schedule) -> Result<DataParallel> {
        let grad_art = engine.load(&format!("{base}_grad"))?;
        let apply_art = engine.load(&format!("{base}_apply"))?;
        let state = engine.initial_state(&format!("{base}_step"))?;
        let n_params: usize = grad_art
            .spec
            .meta_str("n_params")
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("{base}_grad missing n_params meta"))?;
        if workers == 0 {
            bail!("need at least one worker");
        }
        Ok(DataParallel {
            grad_art,
            apply_art,
            state,
            schedule,
            // The loss column is implicit in `History`; DP records no
            // extra metrics.
            history: History::new(vec![]),
            step: 0,
            workers,
            n_params,
        })
    }

    pub fn params(&self) -> &[HostTensor] {
        &self.state[..self.n_params]
    }

    /// One data-parallel step from a single global batch: shard along the
    /// leading axis (rejecting `workers > rows`) and fan the shards out.
    ///
    /// The grad artifact's input shapes are fixed at export, so the
    /// global batch must split evenly — uneven shards could never match
    /// the compiled shapes and would fail with an opaque shape error.
    pub fn train_step_global(&mut self, batch: Vec<HostTensor>) -> Result<f32> {
        if let Some(first) = batch.first() {
            if let Some(&rows) = first.shape.first() {
                if rows % self.workers != 0 {
                    bail!(
                        "global batch of {rows} rows does not split evenly over \
                         {} workers (grad artifact shapes are fixed at export)",
                        self.workers
                    );
                }
            }
        }
        let shards = shard_batch(&batch, self.workers)?;
        self.train_step(shards)
    }

    /// One data-parallel step over per-worker batches; returns mean loss.
    pub fn train_step(&mut self, worker_batches: Vec<Vec<HostTensor>>) -> Result<f32> {
        if worker_batches.len() != self.workers {
            bail!(
                "got {} worker batches, configured {}",
                worker_batches.len(),
                self.workers
            );
        }
        let watch = Stopwatch::start();
        let params = &self.state[..self.n_params];

        // Fan out gradient computations (one PJRT execution per worker).
        let mut grad_sum: Option<Vec<HostTensor>> = None;
        let mut loss_sum = 0.0f32;
        for batch in &worker_batches {
            let mut inputs: Vec<&HostTensor> =
                Vec::with_capacity(params.len() + batch.len());
            inputs.extend(params.iter());
            inputs.extend(batch.iter());
            let out = self.grad_art.run_refs(&inputs)?;
            let (grads, metrics) = out.split_at(self.n_params);
            loss_sum += metrics[0].scalar()?;
            grad_sum = Some(match grad_sum {
                None => grads.to_vec(),
                Some(mut acc) => {
                    for (a, g) in acc.iter_mut().zip(grads) {
                        let gv = g.as_f32()?;
                        for (x, y) in a.as_f32_mut()?.iter_mut().zip(gv) {
                            *x += *y;
                        }
                    }
                    acc
                }
            });
        }

        // All-reduce: average.
        let mut grads = grad_sum.unwrap();
        let scale = 1.0 / self.workers as f32;
        for g in grads.iter_mut() {
            for x in g.as_f32_mut()? {
                *x *= scale;
            }
        }

        // Fused optimizer apply.
        let lr = self.schedule.at(self.step);
        let lr_t = HostTensor::scalar_f32(lr);
        let mut inputs: Vec<&HostTensor> =
            Vec::with_capacity(self.state.len() + grads.len() + 1);
        inputs.extend(self.state.iter());
        inputs.extend(grads.iter());
        inputs.push(&lr_t);
        self.state = self.apply_art.run_refs(&inputs)?;

        let loss = loss_sum / self.workers as f32;
        self.history.push(self.step, loss, vec![], watch.elapsed_s());
        self.step += 1;
        Ok(loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(rows: usize) -> Vec<HostTensor> {
        vec![
            HostTensor::f32(vec![rows, 2], (0..rows * 2).map(|i| i as f32).collect()),
            HostTensor::i32(vec![rows], (0..rows as i32).collect()),
        ]
    }

    #[test]
    fn even_split_preserves_rows() {
        let shards = shard_batch(&batch(6), 3).unwrap();
        assert_eq!(shards.len(), 3);
        for (w, shard) in shards.iter().enumerate() {
            assert_eq!(shard[0].shape, vec![2, 2]);
            assert_eq!(shard[1].shape, vec![2]);
            let want: Vec<f32> = (w * 4..w * 4 + 4).map(|i| i as f32).collect();
            assert_eq!(shard[0].as_f32().unwrap(), &want[..]);
            assert_eq!(shard[1].as_i32().unwrap(), &[2 * w as i32, 2 * w as i32 + 1][..]);
        }
    }

    #[test]
    fn remainder_rows_go_to_leading_shards() {
        let shards = shard_batch(&batch(5), 2).unwrap();
        assert_eq!(shards[0][0].shape, vec![3, 2]);
        assert_eq!(shards[1][0].shape, vec![2, 2]);
        // No row lost or duplicated.
        let total: usize = shards.iter().map(|s| s[1].shape[0]).sum();
        assert_eq!(total, 5);
    }

    #[test]
    fn workers_equal_rows_is_the_limit() {
        let shards = shard_batch(&batch(4), 4).unwrap();
        assert!(shards.iter().all(|s| s[0].shape[0] == 1));
    }

    /// Regression: `workers > batch` used to be representable only as
    /// silently empty shards; it must be a hard error instead.
    #[test]
    fn workers_exceeding_batch_rows_is_rejected() {
        let err = shard_batch(&batch(2), 3).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("exceed batch rows"), "unhelpful error: {msg}");
    }

    #[test]
    fn degenerate_inputs_are_rejected() {
        assert!(shard_batch(&batch(4), 0).is_err());
        assert!(shard_batch(&[], 2).is_err());
        assert!(shard_batch(&[HostTensor::scalar_f32(1.0)], 1).is_err());
        let mismatched = vec![
            HostTensor::f32(vec![4, 2], vec![0.0; 8]),
            HostTensor::f32(vec![3, 2], vec![0.0; 6]),
        ];
        assert!(shard_batch(&mismatched, 2).is_err());
    }
}
