//! Checkpointing: save/restore the flat training state.
//!
//! Format (little-endian):
//!   magic "CWYCKPT1" | u64 step | u64 n_tensors |
//!   per tensor: u64 rank, u64 dims..., u64 elem_count, f32 data...
//!
//! [`save`] is crash-safe (ISSUE 10): the bytes land in a same-directory
//! temp file that is fsynced before an atomic rename over the
//! destination, and the parent directory is fsynced after.  A crash at
//! any point leaves either the old complete checkpoint or the new one —
//! never a torn file under the real name.  [`load`] validates magic and
//! length, so a torn *temp* (or a checkpoint written by a dying pre-PR10
//! binary) is rejected instead of restoring garbage.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::runtime::tensor::HostTensor;

const MAGIC: &[u8; 8] = b"CWYCKPT1";

/// Serialize the checkpoint body (shared by [`save`] and tests).
fn encode(step: usize, state: &[HostTensor]) -> Result<Vec<u8>> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(step as u64).to_le_bytes());
    buf.extend_from_slice(&(state.len() as u64).to_le_bytes());
    for t in state {
        let data = t
            .as_f32()
            .context("checkpointing supports f32 state only")?;
        buf.extend_from_slice(&(t.shape.len() as u64).to_le_bytes());
        for &d in &t.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        buf.extend_from_slice(&(data.len() as u64).to_le_bytes());
        for &v in data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    Ok(buf)
}

/// Same-directory temp name: the rename that publishes it must not cross
/// a filesystem boundary, and the pid suffix keeps concurrent writers
/// from clobbering each other's temp.
fn tmp_path(path: &Path) -> PathBuf {
    let name = path
        .file_name()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "ckpt".to_string());
    path.with_file_name(format!(".{name}.tmp.{}", std::process::id()))
}

/// Write + fsync the temp file (the torn-write window lives here, on a
/// name `load` never reads).
fn write_durable(tmp: &Path, buf: &[u8]) -> Result<()> {
    let mut f = fs::File::create(tmp)
        .with_context(|| format!("creating {}", tmp.display()))?;
    f.write_all(buf)
        .with_context(|| format!("writing {}", tmp.display()))?;
    f.sync_all()
        .with_context(|| format!("fsync {}", tmp.display()))?;
    Ok(())
}

/// Publish the temp atomically, then fsync the parent directory so the
/// rename itself survives power loss.  The directory fsync is
/// best-effort: some filesystems refuse to sync a directory handle.
fn commit(tmp: &Path, path: &Path) -> Result<()> {
    fs::rename(tmp, path)
        .with_context(|| format!("renaming {} over {}", tmp.display(), path.display()))?;
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        if let Ok(d) = fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

pub fn save(path: impl AsRef<Path>, step: usize, state: &[HostTensor]) -> Result<()> {
    let path = path.as_ref();
    let buf = encode(step, state)?;
    let tmp = tmp_path(path);
    let res = write_durable(&tmp, &buf).and_then(|()| commit(&tmp, path));
    if res.is_err() {
        // Never leave a stale temp behind; the published checkpoint (old
        // or new) is untouched either way.
        let _ = fs::remove_file(&tmp);
    }
    res
}

pub fn load(path: impl AsRef<Path>) -> Result<(usize, Vec<HostTensor>)> {
    let bytes = fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    let mut off = 0usize;
    let take_u64 = |bytes: &[u8], off: &mut usize| -> Result<u64> {
        if *off + 8 > bytes.len() {
            bail!("checkpoint truncated at byte {off}");
        }
        let v = u64::from_le_bytes(bytes[*off..*off + 8].try_into().unwrap());
        *off += 8;
        Ok(v)
    };
    if bytes.len() < 8 || &bytes[..8] != MAGIC {
        bail!("not a CWY checkpoint (bad magic)");
    }
    off += 8;
    let step = take_u64(&bytes, &mut off)? as usize;
    let n = take_u64(&bytes, &mut off)? as usize;
    let mut state = Vec::with_capacity(n);
    for _ in 0..n {
        let rank = take_u64(&bytes, &mut off)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(take_u64(&bytes, &mut off)? as usize);
        }
        let count = take_u64(&bytes, &mut off)? as usize;
        if count != shape.iter().product::<usize>() {
            bail!("checkpoint tensor count/shape mismatch");
        }
        if off + count * 4 > bytes.len() {
            bail!("checkpoint truncated in tensor data");
        }
        let data: Vec<f32> = bytes[off..off + count * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        off += count * 4;
        state.push(HostTensor::f32(shape, data));
    }
    Ok((step, state))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("cwy_ckpt_test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ckpt");
        let state = vec![
            HostTensor::f32(vec![2, 3], (0..6).map(|i| i as f32).collect()),
            HostTensor::f32(vec![], vec![7.5]),
        ];
        save(&path, 42, &state).unwrap();
        let (step, got) = load(&path).unwrap();
        assert_eq!(step, 42);
        assert_eq!(got, state);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("cwy_ckpt_test2");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
    }

    /// ISSUE 10 satellite: every truncation point of a valid image must
    /// be rejected by `load`, not half-restored.
    #[test]
    fn rejects_every_truncation_point() {
        let dir = std::env::temp_dir().join("cwy_ckpt_torn");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cut.ckpt");
        let state = vec![HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0])];
        let full = encode(7, &state).unwrap();
        for cut in 0..full.len() {
            fs::write(&path, &full[..cut]).unwrap();
            assert!(load(&path).is_err(), "truncation at byte {cut} must be rejected");
        }
        fs::write(&path, &full).unwrap();
        assert!(load(&path).is_ok());
    }

    /// ISSUE 10 satellite: a crash mid-write (simulated by writing a torn
    /// temp and never committing) must leave the previously saved
    /// checkpoint fully readable, and the next successful save must clean
    /// the temp up.
    #[test]
    fn torn_write_never_replaces_a_valid_checkpoint() {
        let dir = std::env::temp_dir().join("cwy_ckpt_atomic");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.ckpt");
        let old = vec![HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0])];
        save(&path, 10, &old).unwrap();

        // Simulated crash: the new image gets halfway into the temp file
        // and the process dies before the rename.
        let new = vec![HostTensor::f32(vec![3], vec![9.0, 9.0, 9.0])];
        let torn = encode(11, &new).unwrap();
        let tmp = tmp_path(&path);
        write_durable(&tmp, &torn[..torn.len() / 2]).unwrap();

        let (step, got) = load(&path).expect("published checkpoint must survive the crash");
        assert_eq!(step, 10);
        assert_eq!(got, old);
        assert!(load(&tmp).is_err(), "the torn temp itself is invalid");

        // The next save publishes atomically and leaves no temp behind.
        save(&path, 11, &new).unwrap();
        let (step, got) = load(&path).unwrap();
        assert_eq!(step, 11);
        assert_eq!(got, new);
        assert!(!tmp.exists(), "save must not leave temp files around");
    }

    /// A failing encode (non-f32 state) must not clobber the existing
    /// checkpoint or leave a temp file.
    #[test]
    fn failed_save_leaves_previous_checkpoint_intact() {
        let dir = std::env::temp_dir().join("cwy_ckpt_failsave");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.ckpt");
        let old = vec![HostTensor::f32(vec![1], vec![5.0])];
        save(&path, 3, &old).unwrap();
        let bad = vec![HostTensor::i32(vec![1], vec![1])];
        assert!(save(&path, 4, &bad).is_err());
        let (step, got) = load(&path).unwrap();
        assert_eq!(step, 3);
        assert_eq!(got, old);
        assert!(!tmp_path(&path).exists());
    }
}
