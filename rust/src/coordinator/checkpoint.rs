//! Checkpointing: save/restore the flat training state.
//!
//! Format (little-endian):
//!   magic "CWYCKPT1" | u64 step | u64 n_tensors |
//!   per tensor: u64 rank, u64 dims..., u64 elem_count, f32 data...

use std::fs;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::tensor::HostTensor;

const MAGIC: &[u8; 8] = b"CWYCKPT1";

pub fn save(path: impl AsRef<Path>, step: usize, state: &[HostTensor]) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(step as u64).to_le_bytes());
    buf.extend_from_slice(&(state.len() as u64).to_le_bytes());
    for t in state {
        let data = t
            .as_f32()
            .context("checkpointing supports f32 state only")?;
        buf.extend_from_slice(&(t.shape.len() as u64).to_le_bytes());
        for &d in &t.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        buf.extend_from_slice(&(data.len() as u64).to_le_bytes());
        for &v in data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    let mut f = fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    f.write_all(&buf)?;
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<(usize, Vec<HostTensor>)> {
    let bytes = fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    let mut off = 0usize;
    let take_u64 = |bytes: &[u8], off: &mut usize| -> Result<u64> {
        if *off + 8 > bytes.len() {
            bail!("checkpoint truncated at byte {off}");
        }
        let v = u64::from_le_bytes(bytes[*off..*off + 8].try_into().unwrap());
        *off += 8;
        Ok(v)
    };
    if bytes.len() < 8 || &bytes[..8] != MAGIC {
        bail!("not a CWY checkpoint (bad magic)");
    }
    off += 8;
    let step = take_u64(&bytes, &mut off)? as usize;
    let n = take_u64(&bytes, &mut off)? as usize;
    let mut state = Vec::with_capacity(n);
    for _ in 0..n {
        let rank = take_u64(&bytes, &mut off)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(take_u64(&bytes, &mut off)? as usize);
        }
        let count = take_u64(&bytes, &mut off)? as usize;
        if count != shape.iter().product::<usize>() {
            bail!("checkpoint tensor count/shape mismatch");
        }
        if off + count * 4 > bytes.len() {
            bail!("checkpoint truncated in tensor data");
        }
        let data: Vec<f32> = bytes[off..off + count * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        off += count * 4;
        state.push(HostTensor::f32(shape, data));
    }
    Ok((step, state))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("cwy_ckpt_test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ckpt");
        let state = vec![
            HostTensor::f32(vec![2, 3], (0..6).map(|i| i as f32).collect()),
            HostTensor::f32(vec![], vec![7.5]),
        ];
        save(&path, 42, &state).unwrap();
        let (step, got) = load(&path).unwrap();
        assert_eq!(step, 42);
        assert_eq!(got, state);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("cwy_ckpt_test2");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
    }
}
