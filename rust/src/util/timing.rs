//! Benchmark timing harness (criterion is not vendored; this is the
//! in-repo substitute used by `benches/*` and the perf pass).

use std::time::Instant;

/// Result of one benchmark: wall-clock statistics in seconds.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    /// Median single-iteration time — the robust per-kernel number the
    /// `BENCH_*.json` perf trajectory records.
    pub median_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_s * 1e6
    }

    pub fn median_ms(&self) -> f64 {
        self.median_s * 1e3
    }

    /// Median in nanoseconds per op — the unit `BENCH_*.json` stores.
    pub fn median_ns(&self) -> f64 {
        self.median_s * 1e9
    }

    pub fn row(&self) -> String {
        format!(
            "{:<36} {:>10.3} ms  ±{:>8.3} ms  (min {:.3}, max {:.3}, n={})",
            self.name,
            self.mean_ms(),
            self.std_s * 1e3,
            self.min_s * 1e3,
            self.max_s * 1e3,
            self.iters
        )
    }
}

/// Time `f` with warmup, adapting the iteration count to `target_s` total.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, target_s: f64, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    // Estimate a single-shot time to size the measured run.
    let probe = Instant::now();
    f();
    let once = probe.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_s / once).ceil() as usize).clamp(3, 1000);
    bench_n(name, 0, iters, f)
}

/// Time exactly `iters` iterations after `warmup` — the `--smoke` CI mode
/// (1 iteration: the kernel ran and produced a number; trend analysis is
/// the full run's job).
pub fn bench_n<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let iters = iters.max(1);
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    stats(name, &times)
}

/// Summarize a set of raw timings.
pub fn stats(name: &str, times: &[f64]) -> BenchStats {
    let n = times.len().max(1) as f64;
    let mean = times.iter().sum::<f64>() / n;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
    let median = if times.is_empty() {
        0.0
    } else {
        let mut sorted = times.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let mid = sorted.len() / 2;
        if sorted.len() % 2 == 1 {
            sorted[mid]
        } else {
            0.5 * (sorted[mid - 1] + sorted[mid])
        }
    };
    BenchStats {
        name: name.to_string(),
        iters: times.len(),
        mean_s: mean,
        median_s: median,
        std_s: var.sqrt(),
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: times.iter().cloned().fold(0.0, f64::max),
    }
}

/// Simple scoped stopwatch for coarse phase timing.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs() {
        let s = bench("noop", 1, 0.01, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.iters >= 3);
        assert!(s.mean_s >= 0.0);
        assert!(s.min_s <= s.mean_s && s.mean_s <= s.max_s + 1e-12);
    }

    #[test]
    fn stats_math() {
        let s = stats("x", &[1.0, 3.0]);
        assert!((s.mean_s - 2.0).abs() < 1e-12);
        assert!((s.std_s - 1.0).abs() < 1e-12);
        assert!((s.median_s - 2.0).abs() < 1e-12);
        let s = stats("y", &[5.0, 1.0, 2.0]);
        assert!((s.median_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn bench_n_runs_exactly() {
        let mut count = 0usize;
        let s = bench_n("one", 2, 1, || count += 1);
        assert_eq!(s.iters, 1);
        assert_eq!(count, 3); // 2 warmup + 1 measured
        assert!(s.median_s >= 0.0);
    }
}
