//! In-repo substrates replacing unvendored crates: PRNG (rand), JSON
//! (serde_json), bench harness (criterion), CLI parsing (clap), plus a
//! mini property-testing helper (proptest).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod timing;
