//! Tiny CLI argument parser (clap is not vendored).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl Iterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut argv = argv.peekable();
        while let Some(a) = argv.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if argv
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = argv.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn mixed() {
        let a = parse(&["train", "--steps", "100", "--lr=0.01", "--verbose"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!((a.get_f32("lr", 0.0) - 0.01).abs() < 1e-9);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn flag_before_value_opt() {
        let a = parse(&["--fast", "--n", "8"]);
        assert!(a.has_flag("fast"));
        assert_eq!(a.get_usize("n", 0), 8);
    }
}
