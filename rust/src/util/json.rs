//! Minimal JSON parser + serializer for `artifacts/manifest.json` and the
//! serve wire protocol (`serve::protocol`).
//!
//! serde is not vendored in this environment; the grammar is plain JSON
//! (objects, arrays, strings, numbers, booleans, null), so a recursive-
//! descent parser and a direct writer are the honest substrate.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access that returns Null for misses.
    pub fn path(&self, keys: &[&str]) -> &Json {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k).unwrap_or(&Json::Null);
        }
        cur
    }

    /// Serialize to compact JSON text; `parse(dump(x)) == x` for all values
    /// whose numbers are finite (non-finite numbers render as `null`).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.dump())
    }
}

fn write_num(x: f64, out: &mut String) {
    if !x.is_finite() {
        // JSON has no NaN/Inf; null is the least-surprising encoding.
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 9.0e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        // `{}` on f64 prints the shortest representation that round-trips.
        out.push_str(&format!("{x}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError { msg: msg.to_string(), pos: self.pos })
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            self.err("bad literal")
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| JsonError { msg: "utf8".into(), pos: start })?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { msg: format!("bad number '{s}'"), pos: start })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5],
                            )
                            .ok()
                            .and_then(|h| u32::from_str_radix(h, 16).ok())
                            .and_then(char::from_u32);
                            match hex {
                                Some(c) => out.push(c),
                                None => return self.err("bad \\u escape"),
                            }
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(c) => {
                    // Pass raw UTF-8 bytes through unchanged.
                    let len = utf8_len(c);
                    let end = (self.pos + len).min(self.b.len());
                    out.push_str(
                        std::str::from_utf8(&self.b[self.pos..end])
                            .map_err(|_| JsonError { msg: "utf8".into(), pos: self.pos })?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return p.err("trailing data");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.path(&["a"]).as_arr().unwrap().len(), 3);
        assert_eq!(
            j.path(&["a"]).as_arr().unwrap()[2].path(&["b"]).as_str(),
            Some("c")
        );
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{,}").is_err());
        assert!(parse("[1 2]").is_err());
        assert!(parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn dump_roundtrips() {
        let src = r#"{"a":[1,2.5,{"b":"c\nd"}],"e":null,"f":true,"g":-3}"#;
        let j = parse(src).unwrap();
        assert_eq!(j.dump(), src);
        assert_eq!(parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn dump_escapes_and_specials() {
        let j = Json::Arr(vec![
            Json::Str("q\"\\\u{1}".into()),
            Json::Num(f64::NAN),
            Json::Num(1.0),
        ]);
        assert_eq!(j.dump(), r#"["q\"\\\u0001",null,1]"#);
        assert_eq!(parse(&j.dump()).unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn manifest_shape() {
        let j = parse(
            r#"{"artifacts":[{"name":"x","inputs":[{"shape":[2,3],"dtype":"float32"}]}]}"#,
        )
        .unwrap();
        let arts = j.path(&["artifacts"]).as_arr().unwrap();
        let shape: Vec<usize> = arts[0].path(&["inputs"]).as_arr().unwrap()[0]
            .path(&["shape"])
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![2, 3]);
    }
}
