//! Deterministic PCG32 pseudo-random generator.
//!
//! The `rand` crate is not vendored in this environment, and the dataset
//! generators need *reproducible* streams anyway (every experiment in
//! EXPERIMENTS.md records its seed), so a small PCG32 (O'Neill 2014) is a
//! better fit than a platform RNG.

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seeded generator; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-stream constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) using Lemire's rejection-free-ish method.
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        ((self.next_u32() as u64 * n as u64) >> 32) as u32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-9 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a vector with standard normals scaled by `scale`.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    /// Random permutation of 0..n (Fisher-Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below((i + 1) as u32) as usize;
            p.swap(i, j);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_half() {
        let mut r = Pcg32::seeded(3);
        let mean: f32 = (0..20000).map(|_| r.uniform()).sum::<f32>() / 20000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(9);
        let xs: Vec<f32> = (0..20000).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.08, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg32::seeded(1);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Pcg32::seeded(5);
        let p = r.permutation(50);
        let mut seen = vec![false; 50];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }
}
