//! Mini property-testing helper (proptest is not vendored).
//!
//! `forall(cases, gen, check)` runs `check` over `cases` generated inputs,
//! reporting the seed of the first failing case so it can be replayed with
//! `replay(seed, gen, check)`.

use crate::util::rng::Pcg32;

/// Run `check` on `cases` inputs produced by `gen`; panic with the failing
/// seed on the first counterexample.
pub fn forall<T, G, C>(cases: usize, mut gen: G, mut check: C)
where
    G: FnMut(&mut Pcg32) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x9e3779b97f4a7c15u64.wrapping_mul(case as u64 + 1);
        let mut rng = Pcg32::seeded(seed);
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!("property failed (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<T, G, C>(seed: u64, mut gen: G, mut check: C)
where
    G: FnMut(&mut Pcg32) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Pcg32::seeded(seed);
    let input = gen(&mut rng);
    if let Err(msg) = check(&input) {
        panic!("replayed property failed (seed {seed:#x}): {msg}");
    }
}

/// Assert two slices agree to absolute tolerance, reporting the worst index.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    let mut worst = (0usize, 0f32);
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let d = (x - y).abs();
        if d > worst.1 {
            worst = (i, d);
        }
    }
    if worst.1 > tol {
        return Err(format!(
            "max |a-b| = {} at index {} (a={}, b={}, tol={tol})",
            worst.1, worst.0, a[worst.0], b[worst.0]
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes() {
        forall(32, |r| r.uniform(), |x| {
            if (0.0..1.0).contains(x) { Ok(()) } else { Err(format!("{x}")) }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(8, |r| r.uniform(), |x| {
            if *x < 0.5 { Ok(()) } else { Err("too big".into()) }
        });
    }

    #[test]
    fn close_detects_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.5], 0.1).is_err());
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.00001], 0.1).is_ok());
    }
}
