//! Native optimizers for the pure-rust baselines (the AOT path fuses its
//! optimizer into the step artifact; these drive `crate::orthogonal`'s
//! native implementations in the table harnesses and property tests).

use crate::linalg::Matrix;

/// Plain SGD on a dense matrix parameter.
pub struct Sgd {
    pub lr: f32,
}

impl Sgd {
    pub fn step(&self, param: &mut Matrix, grad: &Matrix) {
        for (p, g) in param.data.iter_mut().zip(&grad.data) {
            *p -= self.lr * g;
        }
    }
}

/// Adam (Kingma & Ba 2015) on a dense matrix parameter.
pub struct Adam {
    pub lr: f32,
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u32,
}

impl Adam {
    pub fn new(lr: f32, n: usize) -> Adam {
        Adam { lr, b1: 0.9, b2: 0.999, eps: 1e-8, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    pub fn step(&mut self, param: &mut Matrix, grad: &Matrix) {
        assert_eq!(param.data.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - self.b1.powi(self.t as i32);
        let bc2 = 1.0 - self.b2.powi(self.t as i32);
        for i in 0..param.data.len() {
            let g = grad.data[i];
            self.m[i] = self.b1 * self.m[i] + (1.0 - self.b1) * g;
            self.v[i] = self.b2 * self.v[i] + (1.0 - self.b2) * g * g;
            param.data[i] -=
                self.lr * (self.m[i] / bc1) / ((self.v[i] / bc2).sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Both optimizers should minimize f(x) = ||x - c||^2 / 2.
    fn quadratic_descent(mut stepper: impl FnMut(&mut Matrix, &Matrix)) -> f32 {
        let target = Matrix::from_rows(2, 2, vec![1.0, -2.0, 0.5, 3.0]);
        let mut x = Matrix::zeros(2, 2);
        for _ in 0..300 {
            let grad = x.sub(&target);
            stepper(&mut x, &grad);
        }
        x.sub(&target).frobenius()
    }

    #[test]
    fn sgd_converges() {
        let opt = Sgd { lr: 0.1 };
        assert!(quadratic_descent(|p, g| opt.step(p, g)) < 1e-3);
    }

    #[test]
    fn adam_converges() {
        let mut opt = Adam::new(0.05, 4);
        assert!(quadratic_descent(|p, g| opt.step(p, g)) < 1e-2);
    }
}
