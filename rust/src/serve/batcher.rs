//! Micro-batching request queue: coalesce up to `max_batch` compatible
//! requests into one fused execution, with bounded-queue backpressure
//! and shed-on-deadline (DESIGN.md §6.3).
//!
//! Split in two layers so the policy is deterministic under test:
//!
//! * [`BatchQueue`] — the pure state machine.  Every method takes `now_us`
//!   explicitly, so unit tests drive it with a fake clock and no threads.
//!   Requests are bucketed per artifact, so a full group of artifact B is
//!   dispatchable even while an older artifact-A request is still waiting
//!   out its window (the pre-PR-8 head-of-line bug).
//! * [`Batcher`] — the thread-safe wrapper (`Mutex` + `Condvar`) the
//!   server submits into and worker threads block on.  In *continuous*
//!   mode (the default) an idle worker dispatches whatever is queued
//!   immediately — batches form from requests that arrive while every
//!   worker is busy, not from holding work back for `max_wait_us`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Duration;

use crate::serve::completion::CompletionHub;
use crate::serve::lock_recover;
use crate::serve::protocol::{ErrCode, InferRequest, Response};
use crate::serve::stats::{Clock, ServeStats};

/// Where a finished request's response frames go.
///
/// Worker code only ever calls [`Pending::reply`]; the sink decides
/// whether that lands on a per-thread mpsc channel (tests, in-process
/// harnesses) or on the event loop's [`CompletionHub`] keyed by
/// connection id (the `cwy serve` front end).
#[derive(Clone)]
pub enum ReplySink {
    /// Direct channel to a dedicated reader (tests, embedded use).
    Channel(mpsc::Sender<Response>),
    /// Completion queue of the serve event loop; `conn` routes the frame
    /// back to the socket that submitted the request.
    Loop { conn: u64, hub: Arc<CompletionHub> },
}

impl From<mpsc::Sender<Response>> for ReplySink {
    fn from(tx: mpsc::Sender<Response>) -> ReplySink {
        ReplySink::Channel(tx)
    }
}

/// A queued request plus its response sink and timing bookkeeping.
pub struct Pending {
    pub req: InferRequest,
    pub enqueued_us: u64,
    /// Absolute shed time on the server clock (enqueue + deadline budget).
    pub expiry_us: Option<u64>,
    sink: ReplySink,
    /// Set by [`Pending::reply`].  Shared with any [`FailoverRoute`]
    /// cloned off this request, so the supervisor can tell an answered
    /// request from one a panicking worker left hanging (ISSUE 10).
    answered: Arc<AtomicBool>,
}

impl Pending {
    pub fn new(req: InferRequest, now_us: u64, sink: impl Into<ReplySink>) -> Pending {
        let expiry_us = req.deadline_us.map(|d| now_us.saturating_add(d));
        Pending {
            req,
            enqueued_us: now_us,
            expiry_us,
            sink: sink.into(),
            answered: Arc::new(AtomicBool::new(false)),
        }
    }

    pub fn expired(&self, now_us: u64) -> bool {
        self.expiry_us.is_some_and(|e| now_us >= e)
    }

    /// Send a response frame; a disconnected client is not an error.
    pub fn reply(&self, resp: Response) {
        self.answered.store(true, Ordering::Release);
        match &self.sink {
            ReplySink::Channel(tx) => {
                let _ = tx.send(resp);
            }
            ReplySink::Loop { conn, hub } => hub.push(*conn, resp),
        }
    }

    /// Detachable reply route for supervisor fail-over: survives the
    /// `Pending` being dropped by an unwinding worker stack.
    pub fn failover_route(&self) -> FailoverRoute {
        FailoverRoute {
            id: self.req.id,
            sink: self.sink.clone(),
            answered: Arc::clone(&self.answered),
        }
    }

    fn deadline_error(&self) -> Response {
        Response::Err {
            id: self.req.id,
            code: ErrCode::Deadline,
            msg: "deadline budget elapsed while queued".to_string(),
        }
    }
}

/// A request's reply address, detached from its [`Pending`].
///
/// The worker moves the `Pending`s into the execution call, so when that
/// call panics they are dropped mid-unwind — but their clients are still
/// waiting.  The supervisor captures one `FailoverRoute` per in-flight
/// request before execution and uses it to emit the typed
/// `worker_failed` frame for everything the panic left unanswered,
/// preserving the exactly-one-completion-per-admitted-infer invariant.
pub struct FailoverRoute {
    id: u64,
    sink: ReplySink,
    answered: Arc<AtomicBool>,
}

impl FailoverRoute {
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn answered(&self) -> bool {
        self.answered.load(Ordering::Acquire)
    }

    /// Answer with a `worker_failed` frame unless the request already got
    /// its one completion.  Returns whether a frame was sent.
    pub fn fail_worker(&self, msg: &str) -> bool {
        if self.answered.swap(true, Ordering::AcqRel) {
            return false;
        }
        let resp = Response::Err {
            id: self.id,
            code: ErrCode::WorkerFailed,
            msg: msg.to_string(),
        };
        match &self.sink {
            ReplySink::Channel(tx) => {
                let _ = tx.send(resp);
            }
            ReplySink::Loop { conn, hub } => hub.push(*conn, resp),
        }
        true
    }
}

/// Why [`BatchQueue::poll`] decided to flush.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    /// `max_batch` compatible requests are waiting.
    Full,
    /// The oldest request has waited `max_wait_us`.
    Timeout,
}

/// What a worker should do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushDecision {
    Flush(FlushReason),
    /// Nothing to flush yet; re-poll after at most this many microseconds
    /// (capped by the earliest request expiry so sheds happen on time).
    WaitUs(u64),
    /// Queue is empty.
    Idle,
}

/// One artifact's FIFO of pending requests.
struct Group {
    artifact: String,
    items: VecDeque<Pending>,
}

/// Pure micro-batching state machine: a bounded queue bucketed per
/// artifact.  FIFO order is preserved within a group, and groups are
/// scanned in creation order so ties break toward the earliest arrival.
pub struct BatchQueue {
    cap: usize,
    groups: Vec<Group>,
}

impl BatchQueue {
    pub fn new(cap: usize) -> BatchQueue {
        BatchQueue { cap: cap.max(1), groups: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.groups.iter().map(|g| g.items.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    /// Enqueue, or hand the request back when the queue is full
    /// (backpressure: the caller sheds it with an `overloaded` frame).
    pub fn push(&mut self, p: Pending) -> Result<(), Pending> {
        if self.len() >= self.cap {
            return Err(p);
        }
        match self.groups.iter_mut().find(|g| g.artifact == p.req.artifact) {
            Some(g) => g.items.push_back(p),
            None => {
                let artifact = p.req.artifact.clone();
                let mut items = VecDeque::new();
                items.push_back(p);
                self.groups.push(Group { artifact, items });
            }
        }
        Ok(())
    }

    /// Remove and return every request whose deadline has passed,
    /// preserving the relative order of the survivors.
    pub fn shed_expired(&mut self, now_us: u64) -> Vec<Pending> {
        let mut shed = Vec::new();
        for g in &mut self.groups {
            let mut keep = VecDeque::with_capacity(g.items.len());
            while let Some(p) = g.items.pop_front() {
                if p.expired(now_us) {
                    shed.push(p);
                } else {
                    keep.push_back(p);
                }
            }
            g.items = keep;
        }
        self.groups.retain(|g| !g.items.is_empty());
        shed
    }

    /// Index of the group whose head request has waited longest.
    fn oldest_group(&self) -> Option<usize> {
        let mut best: Option<(usize, u64)> = None;
        for (i, g) in self.groups.iter().enumerate() {
            if let Some(p) = g.items.front() {
                if best.is_none_or(|(_, t)| p.enqueued_us < t) {
                    best = Some((i, p.enqueued_us));
                }
            }
        }
        best.map(|(i, _)| i)
    }

    /// Decide whether a batch is ready.  *Any* artifact group reaching
    /// `max_batch` flushes `Full` — a full group of artifact B must not
    /// wait behind an aged artifact-A head (the PR-8 HOL fix); otherwise
    /// the oldest head's wait budget decides `Timeout` vs `WaitUs`.
    pub fn poll(&self, max_batch: usize, max_wait_us: u64, now_us: u64) -> FlushDecision {
        if self.groups.is_empty() {
            return FlushDecision::Idle;
        }
        let max_batch = max_batch.max(1);
        if self.groups.iter().any(|g| g.items.len() >= max_batch) {
            return FlushDecision::Flush(FlushReason::Full);
        }
        let oldest = self
            .groups
            .iter()
            .filter_map(|g| g.items.front().map(|p| p.enqueued_us))
            .min()
            .unwrap_or(now_us);
        let waited = now_us.saturating_sub(oldest);
        if waited >= max_wait_us {
            return FlushDecision::Flush(FlushReason::Timeout);
        }
        let mut wait = max_wait_us - waited;
        for g in &self.groups {
            for p in &g.items {
                if let Some(e) = p.expiry_us {
                    wait = wait.min(e.saturating_sub(now_us));
                }
            }
        }
        FlushDecision::WaitUs(wait)
    }

    /// Dequeue the next batch: up to `max_batch` requests from one
    /// artifact group, preferring a group that already reached
    /// `max_batch`, else the one whose head has waited longest.  FIFO
    /// order is preserved within the group and among the survivors.
    pub fn take_batch(&mut self, max_batch: usize) -> Vec<Pending> {
        let max_batch = max_batch.max(1);
        let idx = self
            .groups
            .iter()
            .position(|g| g.items.len() >= max_batch)
            .or_else(|| self.oldest_group());
        let Some(idx) = idx else {
            return Vec::new();
        };
        let g = &mut self.groups[idx];
        let take = g.items.len().min(max_batch);
        let batch: Vec<Pending> = g.items.drain(..take).collect();
        if g.items.is_empty() {
            self.groups.remove(idx);
        }
        batch
    }

    /// Put already-admitted requests back at the FRONT of their artifact
    /// groups, preserving their relative order.  Used by the supervisor
    /// to return the untouched tail of a panicked worker's batch; the
    /// entries were admitted (and counted) once, so the capacity check is
    /// deliberately skipped — dropping them would break exactly-once.
    pub fn requeue_front(&mut self, entries: Vec<Pending>) {
        for p in entries.into_iter().rev() {
            match self.groups.iter_mut().find(|g| g.artifact == p.req.artifact) {
                Some(g) => g.items.push_front(p),
                None => {
                    let artifact = p.req.artifact.clone();
                    let mut items = VecDeque::new();
                    items.push_back(p);
                    self.groups.push(Group { artifact, items });
                }
            }
        }
    }
}

/// Batcher configuration (`cwy serve` flags map 1:1 onto these).
#[derive(Clone, Copy, Debug)]
pub struct BatchCfg {
    pub max_batch: usize,
    pub max_wait_us: u64,
    pub queue_cap: usize,
    /// Continuous batching: an idle worker dispatches queued work
    /// immediately instead of waiting out `max_wait_us` for a fuller
    /// batch.  Occupancy then comes from requests arriving while all
    /// workers are busy — the production default.  `false` restores the
    /// timed window (useful to force coalescing in tests/benches).
    pub continuous: bool,
}

impl Default for BatchCfg {
    fn default() -> BatchCfg {
        BatchCfg { max_batch: 8, max_wait_us: 2_000, queue_cap: 1_024, continuous: true }
    }
}

/// Sleep granted to a timed-mode worker between polls.  The wait from
/// [`BatchQueue::poll`] is honored exactly (a sub-100µs earliest-expiry
/// cap must not be inflated, or tight deadlines shed late — the PR-8
/// clamp fix), bounded to 50ms so shutdown is never far away.
pub fn flush_wait(us: u64) -> Duration {
    Duration::from_micros(us.clamp(1, 50_000))
}

/// Thread-safe micro-batching queue shared by connections and workers.
pub struct Batcher {
    cfg: BatchCfg,
    queue: Mutex<BatchQueue>,
    notify: Condvar,
    clock: Arc<Clock>,
    stats: Arc<ServeStats>,
    stop: AtomicBool,
}

impl Batcher {
    pub fn new(cfg: BatchCfg, clock: Arc<Clock>, stats: Arc<ServeStats>) -> Batcher {
        Batcher {
            queue: Mutex::new(BatchQueue::new(cfg.queue_cap)),
            notify: Condvar::new(),
            cfg,
            clock,
            stats,
            stop: AtomicBool::new(false),
        }
    }

    pub fn cfg(&self) -> &BatchCfg {
        &self.cfg
    }

    /// Submit one request.  On a full queue the request is answered
    /// immediately with an `overloaded` error frame and `false` returned.
    pub fn submit(&self, req: InferRequest, sink: impl Into<ReplySink>) -> bool {
        let now = self.clock.now_us();
        let pending = Pending::new(req, now, sink);
        let mut q = lock_recover(&self.queue);
        // Checked under the queue lock: shutdown() sets the flag before
        // draining, so a request either lands pre-drain (and is answered
        // by the drain) or sees the flag here — never a silent hang.
        if self.stop.load(Ordering::Acquire) {
            drop(q);
            pending.reply(Response::Err {
                id: pending.req.id,
                code: ErrCode::Unavailable,
                msg: "server shutting down".to_string(),
            });
            return false;
        }
        match q.push(pending) {
            Ok(()) => {
                self.stats.record_submit(q.len());
                crate::telemetry::global().set_queue_depth(q.len() as u64);
                drop(q);
                self.notify.notify_one();
                true
            }
            Err(p) => {
                drop(q);
                self.stats.record_rejected_full();
                p.reply(Response::Err {
                    id: p.req.id,
                    code: ErrCode::Overloaded,
                    msg: "queue full".to_string(),
                });
                false
            }
        }
    }

    /// Shed every expired request (deadline frames + stats + gauge) with
    /// the queue lock held.  Returns how many were shed.
    fn shed_locked(&self, q: &mut BatchQueue, now_us: u64) -> usize {
        let shed = q.shed_expired(now_us);
        if shed.is_empty() {
            return 0;
        }
        crate::telemetry::global().set_queue_depth(q.len() as u64);
        let n = shed.len();
        for p in shed {
            self.stats.record_shed_deadline();
            p.reply(p.deadline_error());
        }
        n
    }

    /// Block until a batch is ready (or shutdown).  Expired requests are
    /// answered with `deadline` error frames as they are discovered.
    pub fn next_batch(&self) -> Option<Vec<Pending>> {
        let mut q = lock_recover(&self.queue);
        loop {
            if self.stop.load(Ordering::Acquire) {
                return None;
            }
            let now = self.clock.now_us();
            self.shed_locked(&mut q, now);
            if self.cfg.continuous {
                // Continuous batching: dispatch whatever is ready the
                // moment a worker is free.  take_batch prefers a full
                // group, so a saturated artifact still fuses maximally.
                if !q.is_empty() {
                    let batch = q.take_batch(self.cfg.max_batch);
                    crate::telemetry::global().set_queue_depth(q.len() as u64);
                    return Some(batch);
                }
                q = self.notify.wait_timeout(q, Duration::from_millis(50)).unwrap_or_else(|e| e.into_inner()).0;
                continue;
            }
            match q.poll(self.cfg.max_batch, self.cfg.max_wait_us, now) {
                FlushDecision::Flush(_) => {
                    let batch = q.take_batch(self.cfg.max_batch);
                    crate::telemetry::global().set_queue_depth(q.len() as u64);
                    return Some(batch);
                }
                FlushDecision::WaitUs(us) => {
                    q = self.notify.wait_timeout(q, flush_wait(us)).unwrap_or_else(|e| e.into_inner()).0;
                }
                FlushDecision::Idle => {
                    q = self.notify.wait_timeout(q, Duration::from_millis(50)).unwrap_or_else(|e| e.into_inner()).0;
                }
            }
        }
    }

    /// Return the untouched tail of a panicked worker's batch to the
    /// front of the queue (supervisor fail-over path).
    ///
    /// The entries were admitted and counted at `submit` time, so no
    /// capacity check and no re-counting happens here; they go back at
    /// the head of their artifact groups so a respawned (or sibling)
    /// worker picks them up first.  During shutdown they are answered
    /// `unavailable` instead — the drain already ran, and parking them in
    /// the queue would leave them hanging forever.
    pub fn requeue(&self, entries: Vec<Pending>) {
        if entries.is_empty() {
            return;
        }
        let mut q = lock_recover(&self.queue);
        if self.stop.load(Ordering::Acquire) {
            drop(q);
            for p in entries {
                p.reply(Response::Err {
                    id: p.req.id,
                    code: ErrCode::Unavailable,
                    msg: "server shutting down".to_string(),
                });
            }
            return;
        }
        q.requeue_front(entries);
        crate::telemetry::global().set_queue_depth(q.len() as u64);
        drop(q);
        self.notify.notify_all();
    }

    /// Shed expired requests without dispatching — the event loop calls
    /// this on its tick so deadline frames go out even while every worker
    /// is busy.  Returns how many were shed.
    pub fn reap(&self) -> usize {
        let mut q = lock_recover(&self.queue);
        let now = self.clock.now_us();
        self.shed_locked(&mut q, now)
    }

    pub fn depth(&self) -> usize {
        lock_recover(&self.queue).len()
    }

    /// Ask workers to exit; pending requests are answered `unavailable`.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
        let mut q = lock_recover(&self.queue);
        loop {
            let batch = q.take_batch(usize::MAX);
            if batch.is_empty() {
                break;
            }
            for p in batch {
                p.reply(Response::Err {
                    id: p.req.id,
                    code: ErrCode::Unavailable,
                    msg: "server shutting down".to_string(),
                });
            }
        }
        crate::telemetry::global().set_queue_depth(q.len() as u64);
        drop(q);
        self.notify.notify_all();
    }

    pub fn is_shutdown(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, artifact: &str, deadline_us: Option<u64>) -> InferRequest {
        InferRequest {
            id,
            artifact: artifact.to_string(),
            session: None,
            deadline_us,
            inputs: vec![],
        }
    }

    fn pend(id: u64, artifact: &str, now: u64, deadline: Option<u64>) -> (Pending, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (Pending::new(req(id, artifact, deadline), now, tx), rx)
    }

    fn ids(batch: &[Pending]) -> Vec<u64> {
        batch.iter().map(|p| p.req.id).collect()
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let mut q = BatchQueue::new(16);
        for i in 0..3 {
            let (p, _rx) = pend(i, "a", 0, None);
            q.push(p).ok().unwrap();
        }
        assert_eq!(q.poll(3, 10_000, 1), FlushDecision::Flush(FlushReason::Full));
        let batch = q.take_batch(3);
        assert_eq!(ids(&batch), vec![0, 1, 2]);
        assert!(q.is_empty());
    }

    #[test]
    fn partial_batch_flushes_on_timeout() {
        let mut q = BatchQueue::new(16);
        let (p, _rx) = pend(7, "a", 100, None);
        q.push(p).ok().unwrap();
        // At t=600 the request has waited 500us of its 2000us budget.
        assert_eq!(q.poll(8, 2_000, 600), FlushDecision::WaitUs(1_500));
        // At t=2100 the budget is spent: flush a batch of one.
        assert_eq!(q.poll(8, 2_000, 2_100), FlushDecision::Flush(FlushReason::Timeout));
        assert_eq!(ids(&q.take_batch(8)), vec![7]);
    }

    #[test]
    fn coalesces_to_occupancy_above_one() {
        // The micro-batching claim itself: 5 compatible requests queued
        // while a worker is busy come out as ONE batch of 5.
        let mut q = BatchQueue::new(16);
        for i in 0..5 {
            let (p, _rx) = pend(i, "a", i * 10, None);
            q.push(p).ok().unwrap();
        }
        let batch = q.take_batch(8);
        assert_eq!(batch.len(), 5);
        assert_eq!(ids(&batch), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn sheds_expired_requests_only() {
        let mut q = BatchQueue::new(16);
        let (p1, rx1) = pend(1, "a", 0, Some(100));
        let (p2, _rx2) = pend(2, "a", 0, None);
        let (p3, rx3) = pend(3, "a", 0, Some(10_000));
        q.push(p1).ok().unwrap();
        q.push(p2).ok().unwrap();
        q.push(p3).ok().unwrap();

        assert!(q.shed_expired(50).is_empty());
        let shed = q.shed_expired(150);
        assert_eq!(ids(&shed), vec![1]);
        assert_eq!(q.len(), 2);

        // The shed path emits a deadline error frame on the reply channel.
        shed[0].reply(shed[0].deadline_error());
        match rx1.try_recv().unwrap() {
            Response::Err { id, code, .. } => {
                assert_eq!((id, code), (1, ErrCode::Deadline));
            }
            other => panic!("wrong frame: {other:?}"),
        }
        drop(rx3);
    }

    #[test]
    fn poll_wait_is_capped_by_earliest_expiry() {
        let mut q = BatchQueue::new(16);
        let (p, _rx) = pend(1, "a", 0, Some(500));
        q.push(p).ok().unwrap();
        // Flush timeout would be 2000us away, but the deadline is at 500.
        assert_eq!(q.poll(8, 2_000, 0), FlushDecision::WaitUs(500));

        // The clamp path (PR-8 satellite): a sub-100us expiry cap must
        // survive the worker's sleep conversion exactly — the old
        // `clamp(100, …)` floor answered these deadlines up to 100us late.
        let mut q2 = BatchQueue::new(16);
        let (p2, _rx2) = pend(2, "a", 0, Some(50));
        q2.push(p2).ok().unwrap();
        assert_eq!(q2.poll(8, 2_000, 0), FlushDecision::WaitUs(50));
        assert_eq!(flush_wait(50), Duration::from_micros(50));
    }

    #[test]
    fn flush_wait_honors_sub_100us_deadlines() {
        assert_eq!(flush_wait(50), Duration::from_micros(50));
        assert_eq!(flush_wait(99), Duration::from_micros(99));
        // Zero still sleeps one tick (yield), and huge waits are bounded
        // so shutdown/shed checks come around at least every 50ms.
        assert_eq!(flush_wait(0), Duration::from_micros(1));
        assert_eq!(flush_wait(10_000_000), Duration::from_millis(50));
    }

    #[test]
    fn interleaved_artifacts_preserve_order() {
        let mut q = BatchQueue::new(16);
        for (id, art) in [(1, "a"), (2, "b"), (3, "a"), (4, "b"), (5, "a")] {
            let (p, _rx) = pend(id, art, 0, None);
            q.push(p).ok().unwrap();
        }
        // First flush fuses every queued "a" request, skipping over "b"s
        // without reordering them.
        assert_eq!(ids(&q.take_batch(8)), vec![1, 3, 5]);
        assert_eq!(ids(&q.take_batch(8)), vec![2, 4]);
        assert!(q.is_empty());
    }

    #[test]
    fn full_group_behind_other_artifact_flushes_full() {
        // The PR-8 HOL regression: one aged artifact-A request at the
        // head, then a full max_batch group of artifact B.  The pre-PR
        // poll() only counted the head's group (1 < max_batch) and sat in
        // WaitUs until A timed out; take_batch() then dispatched [1]
        // alone.  The group queue flushes B's full batch immediately.
        let mut q = BatchQueue::new(16);
        let (p, _rx) = pend(1, "a", 0, None);
        q.push(p).ok().unwrap();
        let mut rxs = Vec::new();
        for id in 2..=5 {
            let (p, rx) = pend(id, "b", 100, None);
            q.push(p).ok().unwrap();
            rxs.push(rx);
        }
        assert_eq!(q.poll(4, 10_000, 200), FlushDecision::Flush(FlushReason::Full));
        assert_eq!(ids(&q.take_batch(4)), vec![2, 3, 4, 5]);
        // The aged A head is next out, not lost.
        assert_eq!(ids(&q.take_batch(4)), vec![1]);
        assert!(q.is_empty());
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        let mut q = BatchQueue::new(2);
        let (p1, _r1) = pend(1, "a", 0, None);
        let (p2, _r2) = pend(2, "a", 0, None);
        let (p3, _r3) = pend(3, "a", 0, None);
        assert!(q.push(p1).is_ok());
        assert!(q.push(p2).is_ok());
        let back = q.push(p3).err().unwrap();
        assert_eq!(back.req.id, 3);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn max_batch_splits_large_groups() {
        let mut q = BatchQueue::new(64);
        for i in 0..10 {
            let (p, _rx) = pend(i, "a", 0, None);
            q.push(p).ok().unwrap();
        }
        assert_eq!(q.poll(4, 1_000, 0), FlushDecision::Flush(FlushReason::Full));
        assert_eq!(ids(&q.take_batch(4)), vec![0, 1, 2, 3]);
        assert_eq!(ids(&q.take_batch(4)), vec![4, 5, 6, 7]);
        assert_eq!(ids(&q.take_batch(4)), vec![8, 9]);
    }

    #[test]
    fn submit_after_shutdown_is_answered_unavailable() {
        let clock = Arc::new(Clock::new());
        let stats = Arc::new(ServeStats::new());
        let b = Batcher::new(BatchCfg::default(), clock, stats);
        b.shutdown();
        let (tx, rx) = mpsc::channel();
        assert!(!b.submit(req(9, "a", None), tx));
        match rx.try_recv().unwrap() {
            Response::Err { id, code, .. } => {
                assert_eq!((id, code), (9, ErrCode::Unavailable));
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn threaded_batcher_round_trip() {
        let clock = Arc::new(Clock::new());
        let stats = Arc::new(ServeStats::new());
        let b = Batcher::new(
            BatchCfg { max_batch: 2, max_wait_us: 200_000, queue_cap: 8, continuous: false },
            clock,
            stats.clone(),
        );
        let (tx, _rx) = mpsc::channel();
        assert!(b.submit(req(1, "a", None), tx.clone()));
        assert!(b.submit(req(2, "a", None), tx));
        // Two submissions reach max_batch, so next_batch returns without
        // waiting out the flush timer.
        let batch = b.next_batch().unwrap();
        assert_eq!(ids(&batch), vec![1, 2]);
        assert_eq!(stats.snapshot().submitted, 2);
        b.shutdown();
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn continuous_mode_dispatches_partials_immediately() {
        // max_wait_us is effectively infinite; continuous mode must still
        // hand a lone request to the idle worker right away.
        let clock = Arc::new(Clock::new());
        let stats = Arc::new(ServeStats::new());
        let b = Batcher::new(
            BatchCfg { max_batch: 8, max_wait_us: 10_000_000, queue_cap: 8, continuous: true },
            clock,
            stats,
        );
        let (tx, _rx) = mpsc::channel();
        assert!(b.submit(req(1, "a", None), tx));
        let t0 = std::time::Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(ids(&batch), vec![1]);
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "continuous dispatch waited out the window"
        );
        b.shutdown();
    }

    #[test]
    fn failover_route_answers_exactly_once() {
        let (p, rx) = pend(5, "a", 0, None);
        let route = p.failover_route();
        assert!(!route.answered());
        // A panic with no prior reply: the route delivers worker_failed.
        assert!(route.fail_worker("worker panicked"));
        match rx.try_recv().unwrap() {
            Response::Err { id, code, .. } => {
                assert_eq!((id, code), (5, ErrCode::WorkerFailed));
            }
            other => panic!("wrong frame: {other:?}"),
        }
        // Second fail-over attempt is a no-op — exactly one completion.
        assert!(!route.fail_worker("again"));
        assert!(rx.try_recv().is_err());

        // A request the worker already answered must NOT get a second
        // frame from the fail-over path.
        let (p2, rx2) = pend(6, "a", 0, None);
        let route2 = p2.failover_route();
        p2.reply(Response::Pong { id: 6 });
        assert!(route2.answered());
        assert!(!route2.fail_worker("late panic"));
        assert!(matches!(rx2.try_recv().unwrap(), Response::Pong { id: 6 }));
        assert!(rx2.try_recv().is_err());
    }

    #[test]
    fn requeue_restores_entries_at_the_front() {
        let clock = Arc::new(Clock::new());
        let stats = Arc::new(ServeStats::new());
        let b = Batcher::new(
            BatchCfg { max_batch: 8, max_wait_us: 1, queue_cap: 4, continuous: true },
            clock,
            stats.clone(),
        );
        let (tx, _rx) = mpsc::channel::<Response>();
        assert!(b.submit(req(1, "a", None), tx.clone()));
        assert!(b.submit(req(2, "a", None), tx.clone()));
        let batch = b.next_batch().unwrap();
        assert_eq!(ids(&batch), vec![1, 2]);
        // A later request arrives, then the "panicked" batch's untouched
        // tail goes back: it must come out FIRST, in its original order.
        assert!(b.submit(req(3, "a", None), tx));
        b.requeue(batch);
        assert_eq!(b.depth(), 3);
        assert_eq!(ids(&b.next_batch().unwrap()), vec![1, 2, 3]);
        // Requeue bypasses the submitted counter: 1 and 2 were already
        // counted once at submit time.
        assert_eq!(stats.snapshot().submitted, 3);
        b.shutdown();
    }

    #[test]
    fn requeue_during_shutdown_answers_unavailable() {
        let clock = Arc::new(Clock::new());
        let stats = Arc::new(ServeStats::new());
        let b = Batcher::new(BatchCfg::default(), clock, stats);
        let (tx, rx) = mpsc::channel();
        assert!(b.submit(req(4, "a", None), tx));
        let batch = b.next_batch().unwrap();
        b.shutdown();
        b.requeue(batch);
        match rx.try_recv().unwrap() {
            Response::Err { id, code, .. } => {
                assert_eq!((id, code), (4, ErrCode::Unavailable));
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn batcher_survives_a_poisoned_queue_lock() {
        // A thread panicking with the queue mutex held (the pre-ISSUE-10
        // failure mode when a worker died inside next_batch bookkeeping)
        // must not take down every subsequent submit/depth/shutdown call.
        let clock = Arc::new(Clock::new());
        let stats = Arc::new(ServeStats::new());
        let b = Batcher::new(BatchCfg::default(), clock, stats);
        let poison = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = b.queue.lock().unwrap();
            panic!("injected panic while holding the batcher lock");
        }));
        assert!(poison.is_err());
        assert!(b.queue.is_poisoned());
        let (tx, _rx) = mpsc::channel::<Response>();
        assert!(b.submit(req(11, "a", None), tx));
        assert_eq!(b.depth(), 1);
        assert_eq!(ids(&b.next_batch().unwrap()), vec![11]);
        b.shutdown();
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn reap_sheds_expired_and_updates_depth() {
        let clock = Arc::new(Clock::new());
        let stats = Arc::new(ServeStats::new());
        let b = Batcher::new(BatchCfg::default(), clock, stats.clone());
        let (tx, rx) = mpsc::channel();
        assert!(b.submit(req(1, "a", Some(1)), tx.clone()));
        assert!(b.submit(req(2, "a", None), tx));
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(b.reap(), 1);
        assert_eq!(b.depth(), 1);
        match rx.try_recv().unwrap() {
            Response::Err { id, code, .. } => {
                assert_eq!((id, code), (1, ErrCode::Deadline));
            }
            other => panic!("wrong frame: {other:?}"),
        }
        assert_eq!(stats.snapshot().shed_deadline, 1);
    }
}
