//! Worker pool and fused-batch execution (DESIGN.md §6.5).
//!
//! `runtime::Compiled` holds `Rc`/`RefCell` state and is not `Send`, so
//! the pool shards by engine instance: each worker thread builds its own
//! model through a `Send + Sync` factory and owns it for life.  Workers
//! pull coalesced batches from the shared [`Batcher`], stack request rows
//! into the artifact's fused batch dimension, execute once, and scatter
//! the outputs back to the per-request response channels.

use std::collections::VecDeque;
use std::rc::Rc;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};

use anyhow::{anyhow, bail, Result};

use crate::linalg::ShapeError;
use crate::runtime::engine::{Backend, Compiled, Engine};
use crate::runtime::manifest::{ArtifactSpec, Manifest, Role};
use crate::runtime::tensor::{Dtype, HostTensor};
use crate::serve::batcher::{Batcher, FailoverRoute, Pending};
use crate::serve::faults::{FaultInjector, FaultPlan};
use crate::serve::lock_recover;
use crate::serve::protocol::{ErrCode, InferRequest, Response};
use crate::serve::session::SessionStore;
use crate::serve::stats::{Clock, ServeStats};
use crate::serve::supervisor::{self, RestartPolicy};
use crate::util::json::Json;

/// One input or output of the served signature, in fused-batch shape.
#[derive(Clone, Debug)]
pub struct PortSpec {
    pub name: String,
    /// Fused shape as the artifact sees it (e.g. `[32, 84]`).
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    pub role: Role,
    /// Leading dim equals the fused batch: requests each contribute one
    /// row (the `tail()` shape); otherwise the tensor is shared whole.
    pub per_row: bool,
}

impl PortSpec {
    /// The per-request row shape (full shape for shared ports).
    pub fn tail(&self) -> &[usize] {
        if self.per_row {
            &self.shape[1..]
        } else {
            &self.shape
        }
    }
}

/// Servable signature derived from an [`ArtifactSpec`] (or synthesized by
/// [`FakeModel`]): which ports are per-row, and how outputs map back to
/// state (DESIGN.md §6.2).
#[derive(Clone, Debug)]
pub struct ServeSpec {
    pub artifact: String,
    /// Fused batch size — the micro-batcher's natural `max_batch`.
    pub batch: usize,
    pub inputs: Vec<PortSpec>,
    pub outputs: Vec<PortSpec>,
    /// The first `n_state_out` outputs are updated values for the state
    /// inputs, in order (the step-artifact convention).
    pub n_state_out: usize,
}

impl ServeSpec {
    /// Derive the serving signature from a manifest entry.  The fused
    /// batch comes from the `batch` meta key, falling back to the leading
    /// dim of the first data input; a port is per-row when its leading dim
    /// equals that batch (a heuristic — params that happen to have a
    /// leading dim equal to the batch would be misclassified, which the
    /// manifest can override by recording `batch` explicitly).
    pub fn from_artifact(spec: &ArtifactSpec) -> Result<ServeSpec> {
        let batch = spec
            .meta_str("batch")
            .and_then(|s| s.parse::<usize>().ok())
            .or_else(|| {
                spec.inputs
                    .iter()
                    .find(|s| s.role == Role::Data && !s.shape.is_empty())
                    .map(|s| s.shape[0])
            })
            .ok_or_else(|| {
                anyhow!("{}: cannot infer fused batch size (no batch meta, no data inputs)", spec.name)
            })?;
        if batch == 0 {
            bail!("{}: fused batch size is zero", spec.name);
        }
        let port = |s: &crate::runtime::manifest::TensorSpec, role: Role| PortSpec {
            name: s.name.clone(),
            shape: s.shape.clone(),
            dtype: s.dtype,
            role,
            per_row: s.shape.first() == Some(&batch),
        };
        let inputs: Vec<PortSpec> = spec.inputs.iter().map(|s| port(s, s.role)).collect();
        let outputs: Vec<PortSpec> = spec.outputs.iter().map(|s| port(s, Role::Output)).collect();
        let n_state_out = if spec.kind == "step" {
            spec.n_state().min(outputs.len())
        } else {
            0
        };
        Ok(ServeSpec { artifact: spec.name.clone(), batch, inputs, outputs, n_state_out })
    }

    pub fn data_ports(&self) -> Vec<&PortSpec> {
        self.inputs.iter().filter(|p| p.role == Role::Data).collect()
    }

    pub fn state_ports(&self) -> Vec<&PortSpec> {
        self.inputs.iter().filter(|p| p.role == Role::State).collect()
    }

    /// Signature description for the protocol `spec` frame: what a client
    /// must send (data ports, row shapes) and what it gets back.
    pub fn to_json(&self) -> Json {
        let port_json = |p: &PortSpec| {
            let mut m = std::collections::BTreeMap::new();
            m.insert("name".to_string(), Json::Str(p.name.clone()));
            m.insert(
                "shape".to_string(),
                Json::Arr(p.tail().iter().map(|&d| Json::Num(d as f64)).collect()),
            );
            m.insert(
                "dtype".to_string(),
                Json::Str(
                    match p.dtype {
                        Dtype::F32 => "f32",
                        Dtype::I32 => "i32",
                    }
                    .to_string(),
                ),
            );
            m.insert("per_row".to_string(), Json::Bool(p.per_row));
            Json::Obj(m)
        };
        let mut m = std::collections::BTreeMap::new();
        m.insert("artifact".to_string(), Json::Str(self.artifact.clone()));
        m.insert("batch".to_string(), Json::Num(self.batch as f64));
        m.insert(
            "inputs".to_string(),
            Json::Arr(self.data_ports().into_iter().map(port_json).collect()),
        );
        m.insert(
            "outputs".to_string(),
            Json::Arr(
                self.outputs[self.n_state_out..].iter().map(port_json).collect(),
            ),
        );
        Json::Obj(m)
    }
}

/// Derive the served signature (plus the raw manifest entry) straight
/// from `manifest.json` — no engine open, no artifact compile.  The CLI
/// and benches use this to align batcher configuration with the
/// artifact's fused batch before the worker pool builds real models.
pub fn probe_serve_spec(
    artifacts_dir: &str,
    artifact: &str,
) -> Result<(ServeSpec, ArtifactSpec)> {
    let manifest = Manifest::load(artifacts_dir)?;
    let spec = manifest.get(artifact)?.clone();
    Ok((ServeSpec::from_artifact(&spec)?, spec))
}

/// Check a request against the served signature before it joins a fused
/// batch: one tensor per data port, row shapes and dtypes matching.
pub fn validate_request(spec: &ServeSpec, req: &InferRequest) -> Result<()> {
    if req.artifact != spec.artifact {
        bail!("artifact '{}' is not served (serving '{}')", req.artifact, spec.artifact);
    }
    let ports = spec.data_ports();
    if req.inputs.len() != ports.len() {
        bail!("got {} input tensors, artifact takes {}", req.inputs.len(), ports.len());
    }
    for (t, p) in req.inputs.iter().zip(&ports) {
        let want: &[usize] = if p.per_row { p.tail() } else { &p.shape };
        if t.shape != want {
            bail!("input '{}': shape {:?} != expected {:?}", p.name, t.shape, want);
        }
        if t.dtype() != p.dtype {
            bail!("input '{}': dtype mismatch", p.name);
        }
    }
    Ok(())
}

/// Stack `rows` (each of shape `tail`) into `[fused_batch] + tail`,
/// zero-padding the unused trailing rows.
pub fn stack_rows(
    rows: &[&HostTensor],
    fused_batch: usize,
    tail: &[usize],
    dtype: Dtype,
) -> Result<HostTensor> {
    if rows.len() > fused_batch {
        bail!("{} rows exceed fused batch {fused_batch}", rows.len());
    }
    let row_len: usize = tail.iter().product();
    let mut shape = Vec::with_capacity(tail.len() + 1);
    shape.push(fused_batch);
    shape.extend_from_slice(tail);
    match dtype {
        Dtype::F32 => {
            let mut data = Vec::with_capacity(fused_batch * row_len);
            for r in rows {
                data.extend_from_slice(r.as_f32()?);
            }
            data.resize(fused_batch * row_len, 0.0);
            Ok(HostTensor::f32(shape, data))
        }
        Dtype::I32 => {
            let mut data = Vec::with_capacity(fused_batch * row_len);
            for r in rows {
                data.extend_from_slice(r.as_i32()?);
            }
            data.resize(fused_batch * row_len, 0);
            Ok(HostTensor::i32(shape, data))
        }
    }
}

/// Split the first `k` rows of a fused tensor back into per-request
/// tensors of the tail shape.
pub fn split_rows(t: &HostTensor, k: usize) -> Result<Vec<HostTensor>> {
    if t.shape.is_empty() {
        bail!("cannot split a scalar into rows");
    }
    if k > t.shape[0] {
        bail!("asked for {k} rows, tensor has {}", t.shape[0]);
    }
    (0..k)
        .map(|j| {
            let mut row = t.slice_rows(j, 1)?;
            row.shape.remove(0);
            Ok(row)
        })
        .collect()
}

/// A servable model: a signature plus fused-batch execution.  Implementors
/// need not be `Send` — each worker thread builds its own instance.
pub trait ServeModel {
    fn spec(&self) -> &ServeSpec;

    /// Execute one fused batch; `inputs` follow `spec().inputs` order and
    /// fused shapes, outputs follow `spec().outputs`.
    fn run(&mut self, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>>;

    /// Initial values for the worker-resident (non-per-row) state inputs,
    /// in port order.
    fn initial_resident(&self) -> Result<Vec<HostTensor>>;

    /// Initial per-row state for a fresh session, one tensor per per-row
    /// state port in order; empty means "start from zeros".
    fn initial_session_rows(&self) -> Vec<HostTensor> {
        Vec::new()
    }
}

/// Thread-safe constructor for per-worker models.
pub type ModelFactory = dyn Fn() -> Result<Box<dyn ServeModel>> + Send + Sync;

/// Engine-backed model (PJRT or native — DESIGN.md §2.6): one `Engine` +
/// compiled artifact per worker.
pub struct EngineModel {
    // The engine owns the backend client the executable runs on; it must
    // outlive `artifact`.
    _engine: Engine,
    artifact: Rc<Compiled>,
    spec: ServeSpec,
    resident_init: Vec<HostTensor>,
    /// Row 0 of each per-row state tensor in state.bin — the state a
    /// fresh session starts from (the model's trained initial state).
    session_init: Vec<HostTensor>,
}

impl EngineModel {
    /// Open with backend auto-selection (PJRT when real bindings exist,
    /// native otherwise).
    pub fn open(artifacts_dir: &str, artifact: &str) -> Result<EngineModel> {
        Self::open_with(artifacts_dir, artifact, Backend::Auto)
    }

    /// Open on an explicit backend (`cwy serve --backend ...`).
    pub fn open_with(
        artifacts_dir: &str,
        artifact: &str,
        backend: Backend,
    ) -> Result<EngineModel> {
        let (engine, mut compiled) =
            Engine::open_worker_with(artifacts_dir, backend, &[artifact])?;
        let compiled = compiled.pop().expect("one artifact requested");
        let spec = ServeSpec::from_artifact(&compiled.spec)?;
        let state_ports = spec.state_ports();
        let full_state = if compiled.spec.state_bin.is_some() {
            engine.initial_state(artifact)?
        } else {
            Vec::new()
        };
        let mut resident_init = Vec::new();
        let mut session_init = Vec::new();
        if full_state.len() == state_ports.len() {
            for (t, p) in full_state.into_iter().zip(&state_ports) {
                if p.per_row {
                    let mut row = t.slice_rows(0, 1)?;
                    row.shape.remove(0);
                    session_init.push(row);
                } else {
                    resident_init.push(t);
                }
            }
        } else {
            // No recorded initial state: serve from zeros.
            for p in &state_ports {
                if !p.per_row {
                    resident_init.push(HostTensor::zeros(p.shape.clone(), p.dtype));
                }
            }
        }
        Ok(EngineModel { _engine: engine, artifact: compiled, spec, resident_init, session_init })
    }
}

impl ServeModel for EngineModel {
    fn spec(&self) -> &ServeSpec {
        &self.spec
    }

    fn run(&mut self, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        self.artifact.run(&inputs)
    }

    fn initial_resident(&self) -> Result<Vec<HostTensor>> {
        Ok(self.resident_init.clone())
    }

    fn initial_session_rows(&self) -> Vec<HostTensor> {
        self.session_init.clone()
    }
}

/// Deterministic in-process model for tests, `examples/serve_bench`, and
/// `cwy serve --backend fake`: per-row recurrent state `h' = h + x` and
/// output `y = 2x + h`, with an optional artificial execution delay so
/// load tests exercise queue buildup.
pub struct FakeModel {
    spec: ServeSpec,
    exec_delay_us: u64,
}

impl FakeModel {
    pub const ARTIFACT: &'static str = "fake_affine";

    pub fn new(batch: usize, dim: usize, exec_delay_us: u64) -> FakeModel {
        let shape = vec![batch, dim];
        let spec = ServeSpec {
            artifact: Self::ARTIFACT.to_string(),
            batch,
            inputs: vec![
                PortSpec {
                    name: "h".into(),
                    shape: shape.clone(),
                    dtype: Dtype::F32,
                    role: Role::State,
                    per_row: true,
                },
                PortSpec {
                    name: "x".into(),
                    shape: shape.clone(),
                    dtype: Dtype::F32,
                    role: Role::Data,
                    per_row: true,
                },
            ],
            outputs: vec![
                PortSpec {
                    name: "h_next".into(),
                    shape: shape.clone(),
                    dtype: Dtype::F32,
                    role: Role::Output,
                    per_row: true,
                },
                PortSpec {
                    name: "y".into(),
                    shape,
                    dtype: Dtype::F32,
                    role: Role::Output,
                    per_row: true,
                },
            ],
            n_state_out: 1,
        };
        FakeModel { spec, exec_delay_us }
    }
}

impl ServeModel for FakeModel {
    fn spec(&self) -> &ServeSpec {
        &self.spec
    }

    fn run(&mut self, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        if self.exec_delay_us > 0 {
            thread::sleep(std::time::Duration::from_micros(self.exec_delay_us));
        }
        if inputs.len() != 2 {
            bail!("fake model takes (h, x), got {} inputs", inputs.len());
        }
        let h = inputs[0].as_f32()?;
        let x = inputs[1].as_f32()?;
        let h_next: Vec<f32> = h.iter().zip(x).map(|(a, b)| a + b).collect();
        let y: Vec<f32> = h.iter().zip(x).map(|(a, b)| 2.0 * b + a).collect();
        Ok(vec![
            HostTensor::f32(inputs[0].shape.clone(), h_next),
            HostTensor::f32(inputs[1].shape.clone(), y),
        ])
    }

    fn initial_resident(&self) -> Result<Vec<HostTensor>> {
        Ok(Vec::new())
    }
}

/// Per-worker reusable scratch for batch execution (DESIGN.md §3.3):
/// the control-plane vectors `run_chunk` fills for every fused chunk
/// keep their capacity across requests instead of reallocating in the
/// serve hot loop.  One instance per worker thread, like the model.
#[derive(Default)]
pub struct WorkerScratch {
    /// Taken per-request session state, aligned with the chunk.
    taken: Vec<Option<Vec<HostTensor>>>,
    /// Updated per-row state gathered from the outputs, per request.
    session_rows: Vec<Vec<HostTensor>>,
    /// Per-port split rows of the user-facing outputs.
    rows_by_port: Vec<Option<Vec<HostTensor>>>,
    /// Per-request queue waits for the stats record.
    queue_waits: Vec<u64>,
    /// Cached CWY operator + packed panels (ISSUE 9): a worker serves
    /// the same artifact weights batch after batch, so the operator
    /// build and its operand packs are reused until the weights change.
    op_cache: crate::runtime::native::ops_ortho::OperatorCache,
}

/// Typed shape check for stored session state against the served per-row
/// state ports.  `None` means the state streams straight into the fused
/// batch; `Some` carries the first mismatch (count, shape, or dtype) so
/// the worker can reply with a `stale_state` error frame instead of
/// panicking on a downstream assert or silently serving a reset session.
pub fn session_state_shape_error(
    state: &[HostTensor],
    ports: &[&PortSpec],
) -> Option<ShapeError> {
    if state.len() != ports.len() {
        return Some(ShapeError {
            op: "session state tensor count",
            expected: vec![ports.len()],
            got: vec![state.len()],
        });
    }
    for (t, p) in state.iter().zip(ports) {
        if t.shape != p.tail() {
            return Some(ShapeError {
                op: "session state row",
                expected: p.tail().to_vec(),
                got: t.shape.clone(),
            });
        }
        if t.dtype() != p.dtype {
            // ShapeError's vectors carry shapes, so the op string names
            // both dtypes explicitly (there are only two).
            let op = match p.dtype {
                Dtype::F32 => "session state dtype (port expects f32, stored row is i32)",
                Dtype::I32 => "session state dtype (port expects i32, stored row is f32)",
            };
            return Some(ShapeError {
                op,
                expected: p.tail().to_vec(),
                got: t.shape.clone(),
            });
        }
    }
    None
}

/// Execute one coalesced batch end-to-end: validate, gather session rows,
/// stack, run, scatter state + outputs, reply.  `spec` is the worker's
/// cached copy of `model.spec()` and `scratch` its reusable buffers —
/// both are per-worker state so the hot loop neither re-clones the
/// signature nor reallocates its control vectors per batch.
///
/// Thin wrapper over [`execute_batch_shared`] for callers (tests,
/// embedders) that own the batch outright and need no panic fail-over.
#[allow(clippy::too_many_arguments)]
pub fn execute_batch(
    model: &mut dyn ServeModel,
    spec: &ServeSpec,
    resident: &mut Vec<HostTensor>,
    batch: Vec<Pending>,
    sessions: &SessionStore,
    stats: &ServeStats,
    clock: &Clock,
    lr: f32,
    scratch: &mut WorkerScratch,
) {
    let inbox = Mutex::new(VecDeque::from(batch));
    let inflight = Mutex::new(Vec::new());
    execute_batch_shared(
        model, spec, resident, &inbox, &inflight, sessions, stats, clock, lr, scratch, None,
    );
}

/// Supervised batch execution (ISSUE 10): drain `inbox` chunk by chunk,
/// registering every chunk's reply routes in `inflight` before running
/// it.  The supervisor wraps this call in `catch_unwind`; on a panic the
/// routes still registered identify exactly the requests the dead chunk
/// owed answers to (they get typed `worker_failed` frames), while
/// whatever remains in `inbox` was never touched and can be requeued for
/// the surviving workers.  `faults` is the worker's deterministic chaos
/// injector (`None` outside chaos runs).
#[allow(clippy::too_many_arguments)]
pub fn execute_batch_shared(
    model: &mut dyn ServeModel,
    spec: &ServeSpec,
    resident: &mut Vec<HostTensor>,
    inbox: &Mutex<VecDeque<Pending>>,
    inflight: &Mutex<Vec<FailoverRoute>>,
    sessions: &SessionStore,
    stats: &ServeStats,
    clock: &Clock,
    lr: f32,
    scratch: &mut WorkerScratch,
    mut faults: Option<&mut FaultInjector>,
) {
    let cap = spec.batch.max(1);
    loop {
        // Carve the next fused chunk off the inbox front.  A fused chunk
        // may hold at most one request per session key: a second would
        // read state the first has not written yet.  Cutting the chunk at
        // the duplicate keeps FIFO order, and the duplicate runs in the
        // next sequential chunk, after the state lands.  The scan is
        // quadratic in the chunk length, which is bounded by the fused
        // batch — no per-batch set allocation.  Invalid requests are
        // answered inline and never occupy a chunk slot.
        let mut chunk: Vec<Pending> = Vec::with_capacity(cap);
        let mut rejected: Vec<(Pending, anyhow::Error)> = Vec::new();
        {
            let mut q = lock_recover(inbox);
            while chunk.len() < cap {
                let dup = match q.front() {
                    None => break,
                    Some(p) => p.req.session.as_deref().is_some_and(|s| {
                        chunk.iter().any(|c| c.req.session.as_deref() == Some(s))
                    }),
                };
                if dup {
                    break;
                }
                let p = q.pop_front().expect("front() was Some");
                match validate_request(spec, &p.req) {
                    Ok(()) => chunk.push(p),
                    Err(e) => rejected.push((p, e)),
                }
            }
        }
        // Replies stay outside the inbox lock.
        for (p, e) in rejected {
            stats.record_bad_request();
            p.reply(Response::Err {
                id: p.req.id,
                code: ErrCode::BadRequest,
                msg: format!("{e:#}"),
            });
        }
        if chunk.is_empty() {
            if lock_recover(inbox).is_empty() {
                return;
            }
            continue; // a run of invalid requests; keep draining
        }
        // From here until the chunk is answered, these routes are the
        // supervisor's fail-over set.
        {
            let mut routes = lock_recover(inflight);
            routes.clear();
            routes.extend(chunk.iter().map(Pending::failover_route));
        }
        if let Some(f) = faults.as_mut() {
            if let Some(us) = f.slow_delay_us() {
                thread::sleep(std::time::Duration::from_micros(us));
            }
            if f.should_panic() {
                panic!("injected fault: worker panic (CWY_FAULTS)");
            }
        }
        run_chunk(model, spec, resident, chunk, sessions, stats, clock, lr, scratch);
        lock_recover(inflight).clear();
    }
}

#[allow(clippy::too_many_arguments)]
fn run_chunk(
    model: &mut dyn ServeModel,
    spec: &ServeSpec,
    resident: &mut Vec<HostTensor>,
    chunk: Vec<Pending>,
    sessions: &SessionStore,
    stats: &ServeStats,
    clock: &Clock,
    lr: f32,
    scratch: &mut WorkerScratch,
) {
    let start_us = clock.now_us();
    // Serve-phase spans: validation + session handoff + input assembly
    // under `batch_assemble`, the fused model call under `execute`, and
    // state/output scatter + replies under `write_back`.  Each feeds the
    // registry's phase histogram behind the `metrics` frame.
    let assemble_span = crate::span!(batch_assemble);

    // Shared (non-per-row) data inputs are fed once for the whole fused
    // execution; requests whose values differ from the chunk head's would
    // silently be served with the head's data, so reject them instead.
    let shared_data_idx: Vec<usize> = spec
        .inputs
        .iter()
        .filter(|p| p.role == Role::Data)
        .enumerate()
        .filter(|(_, p)| !p.per_row)
        .map(|(i, _)| i)
        .collect();
    let chunk = if shared_data_idx.is_empty() {
        chunk
    } else {
        let head_shared: Vec<HostTensor> = shared_data_idx
            .iter()
            .map(|&i| chunk[0].req.inputs[i].clone())
            .collect();
        let mut kept = Vec::with_capacity(chunk.len());
        for p in chunk {
            let compatible = shared_data_idx
                .iter()
                .zip(&head_shared)
                .all(|(&i, h)| p.req.inputs[i] == *h);
            if compatible {
                kept.push(p);
            } else {
                stats.record_bad_request();
                p.reply(Response::Err {
                    id: p.req.id,
                    code: ErrCode::BadRequest,
                    msg: "shared (non-batched) input conflicts with the fused batch; \
                          retry to land in a fresh batch"
                        .to_string(),
                });
            }
        }
        kept
    };
    if chunk.is_empty() {
        return;
    }
    let per_row_state: Vec<&PortSpec> =
        spec.inputs.iter().filter(|p| p.role == Role::State && p.per_row).collect();
    let init_rows = model.initial_session_rows();

    // Exclusive session handoff: take state rows for the whole chunk.  A
    // state vector that no longer matches the served signature (stale
    // after a parameter/artifact swap) gets a typed `stale_state` error
    // frame and its request leaves the chunk — previously it silently
    // reset the conversation, and a shape slipping past the reset would
    // have panicked the worker on a downstream assert.  The stale state
    // is discarded so a retry starts fresh.
    scratch.taken.clear();
    let mut kept: Vec<Pending> = Vec::with_capacity(chunk.len());
    for p in chunk {
        match p.req.session.as_ref().and_then(|key| sessions.take(key, start_us)) {
            Some(state) => match session_state_shape_error(&state, &per_row_state) {
                None => {
                    kept.push(p);
                    scratch.taken.push(Some(state));
                }
                Some(e) => {
                    stats.record_bad_request();
                    p.reply(Response::Err {
                        id: p.req.id,
                        code: ErrCode::StaleState,
                        msg: format!(
                            "stored session state no longer matches the served \
                             signature ({e}); state discarded — retry to start fresh"
                        ),
                    });
                }
            },
            None => {
                kept.push(p);
                scratch.taken.push(None);
            }
        }
    }
    let chunk = kept;
    if chunk.is_empty() {
        return;
    }
    let k = chunk.len();

    // Assemble fused inputs in port order.
    let mut inputs: Vec<HostTensor> = Vec::with_capacity(spec.inputs.len());
    let mut resident_idx = 0usize;
    let mut row_state_idx = 0usize;
    let mut data_idx = 0usize;
    let mut assembly: Result<()> = Ok(());
    for port in &spec.inputs {
        let tensor = match (port.role, port.per_row) {
            (Role::State, false) => {
                let t = resident.get(resident_idx).cloned().ok_or_else(|| {
                    anyhow!("resident state missing for port '{}'", port.name)
                });
                resident_idx += 1;
                t
            }
            (Role::State, true) => {
                // Fresh sessions start from the model's recorded initial
                // row when it matches the port, else zeros.
                let fresh = init_rows
                    .get(row_state_idx)
                    .filter(|t| t.shape == port.tail() && t.dtype() == port.dtype)
                    .cloned()
                    .unwrap_or_else(|| HostTensor::zeros(port.tail().to_vec(), port.dtype));
                let rows: Vec<HostTensor> = scratch
                    .taken
                    .iter()
                    .map(|s| {
                        s.as_ref()
                            .map(|v| v[row_state_idx].clone())
                            .unwrap_or_else(|| fresh.clone())
                    })
                    .collect();
                row_state_idx += 1;
                let refs: Vec<&HostTensor> = rows.iter().collect();
                stack_rows(&refs, spec.batch, port.tail(), port.dtype)
            }
            (Role::Data, true) => {
                let rows: Vec<&HostTensor> =
                    chunk.iter().map(|p| &p.req.inputs[data_idx]).collect();
                data_idx += 1;
                stack_rows(&rows, spec.batch, port.tail(), port.dtype)
            }
            (Role::Data, false) => {
                // Shared (non-batched) data input: first request's value.
                let t = Ok(chunk[0].req.inputs[data_idx].clone());
                data_idx += 1;
                t
            }
            (Role::Hyper, _) => Ok(HostTensor::scalar_f32(lr)),
            (Role::Output, _) => Err(anyhow!("output port '{}' in inputs", port.name)),
        };
        match tensor {
            Ok(t) => inputs.push(t),
            Err(e) => {
                assembly = Err(e);
                break;
            }
        }
    }

    drop(assemble_span);
    let outputs = match assembly {
        Ok(()) => {
            let _execute_span = crate::span!(execute);
            // Execute with this worker's operator cache installed, so
            // CWY ops inside reuse the cached operator + packed panels
            // across every batch this worker serves (ISSUE 9).
            crate::runtime::native::ops_ortho::with_operator_cache(&mut scratch.op_cache, || {
                model.run(inputs)
            })
        }
        Err(e) => Err(e),
    };
    let end_us = clock.now_us();
    let exec_us = end_us.saturating_sub(start_us);
    let _write_back_span = crate::span!(write_back);

    let outputs = match outputs {
        Ok(o) => o,
        Err(e) => {
            stats.record_exec_error(k as u64);
            // Put the taken session states back — a transient execution
            // failure must not reset every conversation in the batch.
            for (p, state) in chunk.iter().zip(scratch.taken.drain(..)) {
                if let (Some(key), Some(state)) = (&p.req.session, state) {
                    sessions.put(key, state, end_us);
                }
            }
            for p in &chunk {
                p.reply(Response::Err {
                    id: p.req.id,
                    code: ErrCode::Exec,
                    msg: format!("{e:#}"),
                });
            }
            return;
        }
    };
    // The taken states were consumed by the fused inputs; drop the clones
    // now rather than pinning them in the scratch until the next batch.
    scratch.taken.clear();

    // Scatter updated state: outputs[..n_state_out] align with the state
    // input ports in order.
    let state_ports = spec.state_ports();
    for rows in scratch.session_rows.iter_mut() {
        rows.clear();
    }
    while scratch.session_rows.len() < k {
        scratch.session_rows.push(Vec::new());
    }
    let mut resident_idx = 0usize;
    for (out, port) in outputs.iter().take(spec.n_state_out).zip(&state_ports) {
        if port.per_row {
            if let Ok(rows) = split_rows(out, k) {
                for (j, row) in rows.into_iter().enumerate() {
                    scratch.session_rows[j].push(row);
                }
            }
        } else {
            if let Some(slot) = resident.get_mut(resident_idx) {
                *slot = out.clone();
            }
            resident_idx += 1;
        }
    }
    if !per_row_state.is_empty() {
        for (j, p) in chunk.iter().enumerate() {
            if let Some(key) = &p.req.session {
                if scratch.session_rows[j].len() == per_row_state.len() {
                    sessions.put(key, std::mem::take(&mut scratch.session_rows[j]), end_us);
                }
            }
        }
    }

    // Scatter user-facing outputs and reply.
    let user_ports = &spec.outputs[spec.n_state_out..];
    let user_outputs = &outputs[spec.n_state_out..];
    scratch.rows_by_port.clear();
    for (out, port) in user_outputs.iter().zip(user_ports) {
        if port.per_row {
            scratch.rows_by_port.push(split_rows(out, k).ok());
        } else {
            scratch.rows_by_port.push(None);
        }
    }
    scratch.queue_waits.clear();
    for (j, p) in chunk.iter().enumerate() {
        let outs: Vec<HostTensor> = user_outputs
            .iter()
            .enumerate()
            .map(|(oi, full)| match &scratch.rows_by_port[oi] {
                Some(rows) => rows[j].clone(),
                None => full.clone(),
            })
            .collect();
        let queue_us = start_us.saturating_sub(p.enqueued_us);
        scratch.queue_waits.push(queue_us);
        crate::telemetry::global().record_queue_wait(queue_us);
        p.reply(Response::Ok {
            id: p.req.id,
            outputs: outs,
            queue_us,
            exec_us,
            batch: k,
        });
        stats.record_completed(end_us.saturating_sub(p.enqueued_us));
    }
    stats.record_batch(k, &scratch.queue_waits, exec_us);
}

/// The worker pool: `n` supervised threads, each owning a private model
/// instance.  The per-thread loop — panic isolation, batch fail-over,
/// capped-backoff respawn — lives in [`crate::serve::supervisor`].
pub struct WorkerPool {
    handles: Vec<JoinHandle<()>>,
    live: Arc<AtomicUsize>,
}

impl WorkerPool {
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        n: usize,
        factory: Arc<ModelFactory>,
        batcher: Arc<Batcher>,
        sessions: Arc<SessionStore>,
        stats: Arc<ServeStats>,
        clock: Arc<Clock>,
        lr: f32,
        policy: RestartPolicy,
        faults: Option<FaultPlan>,
    ) -> WorkerPool {
        let n = n.max(1);
        let live = Arc::new(AtomicUsize::new(n));
        let mut handles = Vec::with_capacity(n);
        for w in 0..n {
            let factory = factory.clone();
            let batcher = batcher.clone();
            let sessions = sessions.clone();
            let stats = stats.clone();
            let clock = clock.clone();
            let live = live.clone();
            let handle = thread::Builder::new()
                .name(format!("cwy-serve-worker-{w}"))
                .spawn(move || {
                    supervisor::run_worker(
                        w, &*factory, &batcher, &sessions, &stats, &clock, lr, policy,
                        faults, &live,
                    );
                })
                .expect("spawning worker thread");
            handles.push(handle);
        }
        WorkerPool { handles, live }
    }

    /// Workers currently serving: spawned minus exited (shutdown) or
    /// quarantined (the supervisor's restart budget ran out).  The chaos
    /// suite asserts this returns to the configured count after injected
    /// panics — pool capacity self-heals.
    pub fn live_workers(&self) -> usize {
        self.live.load(Ordering::Acquire)
    }

    pub fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::session::SessionCfg;
    use std::sync::mpsc;

    fn t(v: &[f32]) -> HostTensor {
        HostTensor::f32(vec![v.len()], v.to_vec())
    }

    #[test]
    fn stack_pads_and_split_inverts() {
        let a = t(&[1.0, 2.0]);
        let b = t(&[3.0, 4.0]);
        let fused = stack_rows(&[&a, &b], 4, &[2], Dtype::F32).unwrap();
        assert_eq!(fused.shape, vec![4, 2]);
        assert_eq!(fused.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
        let rows = split_rows(&fused, 2).unwrap();
        assert_eq!(rows, vec![a, b]);
    }

    #[test]
    fn stack_rejects_overflow_and_split_scalars() {
        let a = t(&[1.0]);
        assert!(stack_rows(&[&a, &a, &a], 2, &[1], Dtype::F32).is_err());
        assert!(split_rows(&HostTensor::scalar_f32(1.0), 1).is_err());
    }

    fn pending(
        id: u64,
        session: Option<&str>,
        x: &[f32],
    ) -> (Pending, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        let req = InferRequest {
            id,
            artifact: FakeModel::ARTIFACT.to_string(),
            session: session.map(|s| s.to_string()),
            deadline_us: None,
            inputs: vec![t(x)],
        };
        (Pending::new(req, 0, tx), rx)
    }

    fn harness() -> (FakeModel, SessionStore, ServeStats, Clock) {
        (
            FakeModel::new(4, 2, 0),
            SessionStore::new(SessionCfg::default()),
            ServeStats::new(),
            Clock::new(),
        )
    }

    /// Test-side wrapper supplying the per-worker state (cached spec +
    /// scratch) the pool normally owns.
    fn exec(
        model: &mut dyn ServeModel,
        resident: &mut Vec<HostTensor>,
        batch: Vec<Pending>,
        sessions: &SessionStore,
        stats: &ServeStats,
        clock: &Clock,
        lr: f32,
    ) {
        let spec = model.spec().clone();
        let mut scratch = WorkerScratch::default();
        execute_batch(model, &spec, resident, batch, sessions, stats, clock, lr, &mut scratch);
    }

    #[test]
    fn fused_batch_serves_every_request() {
        let (mut model, sessions, stats, clock) = harness();
        let mut resident = model.initial_resident().unwrap();
        let (p1, r1) = pending(1, None, &[1.0, 2.0]);
        let (p2, r2) = pending(2, None, &[10.0, 20.0]);
        exec(&mut model, &mut resident, vec![p1, p2], &sessions, &stats, &clock, 0.0);

        // y = 2x + h with h = 0.
        match r1.try_recv().unwrap() {
            Response::Ok { id, outputs, batch, .. } => {
                assert_eq!(id, 1);
                assert_eq!(batch, 2);
                assert_eq!(outputs, vec![t(&[2.0, 4.0])]);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match r2.try_recv().unwrap() {
            Response::Ok { id, outputs, .. } => {
                assert_eq!(id, 2);
                assert_eq!(outputs, vec![t(&[20.0, 40.0])]);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        let snap = stats.snapshot();
        assert_eq!(snap.completed, 2);
        assert_eq!(snap.batches, 1);
        assert_eq!(snap.max_occupancy(), 2);
    }

    #[test]
    fn session_state_streams_across_calls() {
        let (mut model, sessions, stats, clock) = harness();
        let mut resident = model.initial_resident().unwrap();

        let (p1, r1) = pending(1, Some("s"), &[1.0, 1.0]);
        exec(&mut model, &mut resident, vec![p1], &sessions, &stats, &clock, 0.0);
        match r1.try_recv().unwrap() {
            Response::Ok { outputs, .. } => assert_eq!(outputs, vec![t(&[2.0, 2.0])]),
            other => panic!("wrong frame: {other:?}"),
        }

        // Second call on the same session sees h = 1: y = 2*1 + 1 = 3.
        let (p2, r2) = pending(2, Some("s"), &[1.0, 1.0]);
        exec(&mut model, &mut resident, vec![p2], &sessions, &stats, &clock, 0.0);
        match r2.try_recv().unwrap() {
            Response::Ok { outputs, .. } => assert_eq!(outputs, vec![t(&[3.0, 3.0])]),
            other => panic!("wrong frame: {other:?}"),
        }
        assert_eq!(sessions.len(), 1);
    }

    #[test]
    fn bad_request_is_rejected_without_poisoning_batch() {
        let (mut model, sessions, stats, clock) = harness();
        let mut resident = model.initial_resident().unwrap();
        let (good, rg) = pending(1, None, &[1.0, 1.0]);
        let (bad, rb) = pending(2, None, &[1.0, 1.0, 1.0]); // wrong row shape
        exec(&mut model, &mut resident, vec![good, bad], &sessions, &stats, &clock, 0.0);
        assert!(matches!(rg.try_recv().unwrap(), Response::Ok { .. }));
        match rb.try_recv().unwrap() {
            Response::Err { code, .. } => assert_eq!(code, ErrCode::BadRequest),
            other => panic!("wrong frame: {other:?}"),
        }
        assert_eq!(stats.snapshot().bad_requests, 1);
    }

    #[test]
    fn same_session_requests_in_one_batch_run_sequentially() {
        // Two pipelined requests on one session must not share a fused
        // chunk: the second reads the state the first writes.
        let (mut model, sessions, stats, clock) = harness();
        let mut resident = model.initial_resident().unwrap();
        let (p1, r1) = pending(1, Some("s"), &[1.0, 1.0]);
        let (p2, r2) = pending(2, Some("s"), &[1.0, 1.0]);
        exec(&mut model, &mut resident, vec![p1, p2], &sessions, &stats, &clock, 0.0);

        match r1.try_recv().unwrap() {
            Response::Ok { outputs, batch, .. } => {
                assert_eq!(outputs, vec![t(&[2.0, 2.0])]); // h = 0
                assert_eq!(batch, 1);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match r2.try_recv().unwrap() {
            Response::Ok { outputs, .. } => {
                assert_eq!(outputs, vec![t(&[3.0, 3.0])]); // h = 1, not 0
            }
            other => panic!("wrong frame: {other:?}"),
        }
        assert_eq!(stats.snapshot().batches, 2);
    }

    #[test]
    fn exec_failure_returns_taken_session_state() {
        // A model that fails on demand: wrong input count triggers the
        // fake model's arity error only via a poisoned wrapper instead.
        struct Failing {
            inner: FakeModel,
            fail: bool,
        }
        impl ServeModel for Failing {
            fn spec(&self) -> &ServeSpec {
                self.inner.spec()
            }
            fn run(&mut self, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
                if self.fail {
                    bail!("injected exec failure");
                }
                self.inner.run(inputs)
            }
            fn initial_resident(&self) -> Result<Vec<HostTensor>> {
                self.inner.initial_resident()
            }
        }
        let sessions = SessionStore::new(SessionCfg::default());
        let stats = ServeStats::new();
        let clock = Clock::new();
        let mut model = Failing { inner: FakeModel::new(4, 2, 0), fail: false };
        let mut resident = model.initial_resident().unwrap();

        // Seed the session with h = 1.
        let (p1, _r1) = pending(1, Some("s"), &[1.0, 1.0]);
        exec(&mut model, &mut resident, vec![p1], &sessions, &stats, &clock, 0.0);

        // Failing execution must not wipe the stored state.
        model.fail = true;
        let (p2, r2) = pending(2, Some("s"), &[1.0, 1.0]);
        exec(&mut model, &mut resident, vec![p2], &sessions, &stats, &clock, 0.0);
        assert!(matches!(r2.try_recv().unwrap(), Response::Err { code: ErrCode::Exec, .. }));

        // Next successful call still sees h = 1: y = 2*1 + 1 = 3.
        model.fail = false;
        let (p3, r3) = pending(3, Some("s"), &[1.0, 1.0]);
        exec(&mut model, &mut resident, vec![p3], &sessions, &stats, &clock, 0.0);
        match r3.try_recv().unwrap() {
            Response::Ok { outputs, .. } => assert_eq!(outputs, vec![t(&[3.0, 3.0])]),
            other => panic!("wrong frame: {other:?}"),
        }
    }

    /// ISSUE 5 satellite: stored session state that no longer matches the
    /// served signature (e.g. after a param swap changed the hidden dim)
    /// must produce a typed `stale_state` error frame — not a worker
    /// panic, and not a silent session reset.  The stale entry is
    /// discarded, so the next call starts a fresh session.
    #[test]
    fn stale_session_state_is_rejected_with_typed_error() {
        let (mut model, sessions, stats, clock) = harness();
        let mut resident = model.initial_resident().unwrap();
        // Seed the store with a state row of the wrong dimension (as if
        // the model was swapped from dim 3 to dim 2).
        sessions.put("s", vec![t(&[9.0, 9.0, 9.0])], 0);
        let (p1, r1) = pending(1, Some("s"), &[1.0, 1.0]);
        exec(&mut model, &mut resident, vec![p1], &sessions, &stats, &clock, 0.0);
        match r1.try_recv().unwrap() {
            Response::Err { code, msg, .. } => {
                assert_eq!(code, ErrCode::StaleState);
                assert!(msg.contains("shape"), "{msg}");
            }
            other => panic!("wrong frame: {other:?}"),
        }
        assert_eq!(stats.snapshot().bad_requests, 1);
        assert_eq!(sessions.len(), 0, "stale state must be discarded");
        // A retry starts fresh and succeeds (h = 0 again).
        let (p2, r2) = pending(2, Some("s"), &[1.0, 1.0]);
        exec(&mut model, &mut resident, vec![p2], &sessions, &stats, &clock, 0.0);
        match r2.try_recv().unwrap() {
            Response::Ok { outputs, .. } => assert_eq!(outputs, vec![t(&[2.0, 2.0])]),
            other => panic!("wrong frame: {other:?}"),
        }
        // The typed checker itself reports count and shape mismatches.
        let port = PortSpec {
            name: "h".into(),
            shape: vec![4, 2],
            dtype: Dtype::F32,
            role: Role::State,
            per_row: true,
        };
        assert!(session_state_shape_error(&[], &[&port]).is_some());
        let bad = session_state_shape_error(&[t(&[1.0, 2.0, 3.0])], &[&port]).unwrap();
        assert_eq!(bad.expected, vec![2]);
        assert_eq!(bad.got, vec![3]);
        assert!(session_state_shape_error(&[t(&[1.0, 2.0])], &[&port]).is_none());
        // A dtype mismatch names both dtypes in the typed error (the
        // shape vectors alone would be identical and useless here).
        let wrong_dtype = HostTensor::i32(vec![2], vec![1, 2]);
        let bad = session_state_shape_error(&[wrong_dtype], &[&port]).unwrap();
        assert!(bad.op.contains("expects f32"), "{}", bad.op);
        assert!(bad.op.contains("i32"), "{}", bad.op);
    }

    #[test]
    fn oversized_batch_splits_into_chunks() {
        let (mut model, sessions, stats, clock) = harness(); // fused batch 4
        let mut resident = model.initial_resident().unwrap();
        let mut rxs = Vec::new();
        let mut batch = Vec::new();
        for i in 0..6 {
            let (p, r) = pending(i, None, &[1.0, 1.0]);
            batch.push(p);
            rxs.push(r);
        }
        exec(&mut model, &mut resident, batch, &sessions, &stats, &clock, 0.0);
        for r in &rxs {
            assert!(matches!(r.try_recv().unwrap(), Response::Ok { .. }));
        }
        let snap = stats.snapshot();
        assert_eq!(snap.batches, 2);
        assert_eq!(snap.occupancy, vec![0, 1, 0, 1]); // one of 2, one of 4
    }

    /// Model with a shared (non-per-row) data input: y = x * c.
    struct ScaledModel {
        spec: ServeSpec,
    }

    impl ScaledModel {
        fn new() -> ScaledModel {
            ScaledModel {
                spec: ServeSpec {
                    artifact: "scaled".to_string(),
                    batch: 4,
                    inputs: vec![
                        PortSpec {
                            name: "x".into(),
                            shape: vec![4, 1],
                            dtype: Dtype::F32,
                            role: Role::Data,
                            per_row: true,
                        },
                        PortSpec {
                            name: "c".into(),
                            shape: vec![1],
                            dtype: Dtype::F32,
                            role: Role::Data,
                            per_row: false,
                        },
                    ],
                    outputs: vec![PortSpec {
                        name: "y".into(),
                        shape: vec![4, 1],
                        dtype: Dtype::F32,
                        role: Role::Output,
                        per_row: true,
                    }],
                    n_state_out: 0,
                },
            }
        }
    }

    impl ServeModel for ScaledModel {
        fn spec(&self) -> &ServeSpec {
            &self.spec
        }

        fn run(&mut self, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
            let x = inputs[0].as_f32()?;
            let c = inputs[1].as_f32()?[0];
            Ok(vec![HostTensor::f32(vec![4, 1], x.iter().map(|v| v * c).collect())])
        }

        fn initial_resident(&self) -> Result<Vec<HostTensor>> {
            Ok(Vec::new())
        }
    }

    #[test]
    fn conflicting_shared_inputs_are_rejected_not_substituted() {
        let mut model = ScaledModel::new();
        let sessions = SessionStore::new(SessionCfg::default());
        let stats = ServeStats::new();
        let clock = Clock::new();
        let mut resident = Vec::new();
        let mk = |id: u64, xv: f32, cv: f32| {
            let (tx, rx) = mpsc::channel();
            let req = InferRequest {
                id,
                artifact: "scaled".to_string(),
                session: None,
                deadline_us: None,
                inputs: vec![
                    HostTensor::f32(vec![1], vec![xv]),
                    HostTensor::f32(vec![1], vec![cv]),
                ],
            };
            (Pending::new(req, 0, tx), rx)
        };
        let (p1, r1) = mk(1, 3.0, 2.0);
        let (p2, r2) = mk(2, 4.0, 2.0);
        let (p3, r3) = mk(3, 5.0, 7.0); // conflicting shared input c
        exec(&mut model, &mut resident, vec![p1, p2, p3], &sessions, &stats, &clock, 0.0);

        match r1.try_recv().unwrap() {
            Response::Ok { outputs, batch, .. } => {
                assert_eq!(outputs, vec![HostTensor::f32(vec![1], vec![6.0])]);
                assert_eq!(batch, 2);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match r2.try_recv().unwrap() {
            Response::Ok { outputs, .. } => {
                assert_eq!(outputs, vec![HostTensor::f32(vec![1], vec![8.0])]);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match r3.try_recv().unwrap() {
            Response::Err { code, .. } => assert_eq!(code, ErrCode::BadRequest),
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn fresh_sessions_start_from_model_initial_rows() {
        // FakeModel has no recorded rows (zeros); wrap it so one exists.
        struct Seeded(FakeModel);
        impl ServeModel for Seeded {
            fn spec(&self) -> &ServeSpec {
                self.0.spec()
            }
            fn run(&mut self, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
                self.0.run(inputs)
            }
            fn initial_resident(&self) -> Result<Vec<HostTensor>> {
                self.0.initial_resident()
            }
            fn initial_session_rows(&self) -> Vec<HostTensor> {
                vec![t(&[10.0, 10.0])]
            }
        }
        let mut model = Seeded(FakeModel::new(4, 2, 0));
        let sessions = SessionStore::new(SessionCfg::default());
        let stats = ServeStats::new();
        let clock = Clock::new();
        let mut resident = Vec::new();
        // y = 2x + h with seeded h = 10 -> 12, not 2.
        let (p, r) = pending(1, None, &[1.0, 1.0]);
        exec(&mut model, &mut resident, vec![p], &sessions, &stats, &clock, 0.0);
        match r.try_recv().unwrap() {
            Response::Ok { outputs, .. } => assert_eq!(outputs, vec![t(&[12.0, 12.0])]),
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn spec_json_describes_client_contract() {
        let model = FakeModel::new(8, 3, 0);
        let j = model.spec().to_json();
        assert_eq!(j.path(&["batch"]).as_f64(), Some(8.0));
        let inputs = j.path(&["inputs"]).as_arr().unwrap();
        assert_eq!(inputs.len(), 1); // only the data port is client-supplied
        assert_eq!(inputs[0].path(&["name"]).as_str(), Some("x"));
    }

    #[test]
    fn serve_spec_from_artifact_manifest() {
        use crate::runtime::manifest::Manifest;
        use std::path::PathBuf;
        let m = Manifest::parse_str(
            r#"{"artifacts":[{"name":"toy_step","file":"f.hlo","kind":"step",
                "inputs":[{"name":"w","shape":[8,8],"dtype":"float32","kind":"state"},
                          {"name":"x","shape":[4,10],"dtype":"int32"},
                          {"name":"lr","shape":[],"dtype":"float32","kind":"hyper"}],
                "outputs":[{"name":"w","shape":[8,8],"dtype":"float32"},
                           {"name":"loss","shape":[],"dtype":"float32"}],
                "meta":{"batch":"4"}}]}"#,
            PathBuf::from("/tmp"),
        )
        .unwrap();
        let spec = ServeSpec::from_artifact(m.get("toy_step").unwrap()).unwrap();
        assert_eq!(spec.batch, 4);
        assert_eq!(spec.n_state_out, 1);
        assert!(!spec.inputs[0].per_row); // w: [8,8] is worker-resident
        assert!(spec.inputs[1].per_row); // x: [4,10] is one row per request
        assert_eq!(spec.inputs[1].tail(), &[10]);
        assert!(!spec.outputs[1].per_row); // loss: scalar broadcast
    }
}
