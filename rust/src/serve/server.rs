//! TCP front end for `cwy serve` (DESIGN.md §6.6).
//!
//! One acceptor thread; per connection, a reader thread (decode frames,
//! feed the batcher) and a writer thread (drain the connection's response
//! channel back onto the socket).  Worker replies travel through the same
//! per-connection channel, so a request's response can arrive after the
//! client has pipelined more requests — frames carry ids for matching.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::{self, JoinHandle};

use anyhow::{Context, Result};

use crate::serve::batcher::{BatchCfg, Batcher};
use crate::serve::protocol::{self, ErrCode, Request, Response};
use crate::serve::session::{SessionCfg, SessionStore};
use crate::serve::stats::{Clock, ServeStats, Snapshot};
use crate::serve::worker::{ModelFactory, ServeSpec, WorkerPool};

/// Server configuration (`cwy serve` flags map 1:1 onto these).
#[derive(Clone, Debug)]
pub struct ServeCfg {
    pub addr: String,
    pub workers: usize,
    pub batch: BatchCfg,
    pub session: SessionCfg,
    /// Learning rate injected into hyper inputs of step artifacts; 0.0
    /// serves without moving the resident parameters.
    pub lr: f32,
}

impl Default for ServeCfg {
    fn default() -> ServeCfg {
        ServeCfg {
            addr: "127.0.0.1:7070".to_string(),
            workers: 2,
            batch: BatchCfg::default(),
            session: SessionCfg::default(),
            lr: 0.0,
        }
    }
}

/// Running server handle.
pub struct Server {
    addr: SocketAddr,
    stats: Arc<ServeStats>,
    batcher: Arc<Batcher>,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    pool: Option<WorkerPool>,
}

/// Bind, spawn the worker pool and acceptor, and return immediately.
///
/// `factory` is invoked once on the calling thread to probe the served
/// signature, then once per worker thread (each worker owns its model —
/// see `worker`).
pub fn serve(cfg: ServeCfg, factory: Arc<ModelFactory>) -> Result<Server> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr().context("reading bound address")?;

    let clock = Arc::new(Clock::new());
    let stats = Arc::new(ServeStats::new());
    let batcher = Arc::new(Batcher::new(cfg.batch, clock.clone(), stats.clone()));
    let sessions = Arc::new(SessionStore::new(cfg.session));
    let spec: ServeSpec = factory().context("initializing model")?.spec().clone();

    let pool = WorkerPool::spawn(
        cfg.workers,
        factory,
        batcher.clone(),
        sessions,
        stats.clone(),
        clock.clone(),
        cfg.lr,
    );

    let shutdown = Arc::new(AtomicBool::new(false));
    let acceptor = {
        let shutdown = shutdown.clone();
        let batcher = batcher.clone();
        let stats = stats.clone();
        thread::Builder::new()
            .name("cwy-serve-accept".to_string())
            .spawn(move || {
                for stream in listener.incoming() {
                    if shutdown.load(Ordering::Acquire) {
                        break;
                    }
                    match stream {
                        Ok(s) => {
                            spawn_connection(s, batcher.clone(), stats.clone(), spec.clone());
                        }
                        Err(e) => {
                            eprintln!("serve: accept failed: {e}");
                        }
                    }
                }
            })
            .expect("spawning acceptor thread")
    };

    Ok(Server {
        addr,
        stats,
        batcher,
        shutdown,
        acceptor: Some(acceptor),
        pool: Some(pool),
    })
}

fn spawn_connection(
    stream: TcpStream,
    batcher: Arc<Batcher>,
    stats: Arc<ServeStats>,
    spec: ServeSpec,
) {
    let _ = stream.set_nodelay(true);
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve: cloning connection failed: {e}");
            return;
        }
    };
    let (tx, rx) = mpsc::channel::<Response>();

    // Writer: drains until every sender (reader + in-flight requests) is
    // gone, so responses still land after the client stops sending.
    let writer = thread::Builder::new().name("cwy-serve-write".to_string()).spawn(move || {
        let mut out = write_half;
        for resp in rx {
            let line = protocol::encode_response(&resp);
            if out.write_all(line.as_bytes()).is_err()
                || out.write_all(b"\n").is_err()
                || out.flush().is_err()
            {
                break;
            }
        }
    });
    if writer.is_err() {
        eprintln!("serve: spawning writer thread failed");
        return;
    }

    let reader = thread::Builder::new().name("cwy-serve-read".to_string()).spawn(move || {
        let buf = BufReader::new(stream);
        for line in buf.lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => break,
            };
            if line.trim().is_empty() {
                continue;
            }
            match protocol::decode_request(&line) {
                Ok(Request::Infer(req)) => {
                    // submit() answers overloaded/deadline internally.
                    batcher.submit(req, tx.clone());
                }
                Ok(Request::Ping { id }) => {
                    let _ = tx.send(Response::Pong { id });
                }
                Ok(Request::Spec) => {
                    let _ = tx.send(Response::Spec(spec.to_json()));
                }
                Ok(Request::Stats) => {
                    let _ = tx.send(Response::Stats(stats.snapshot().to_json()));
                }
                Ok(Request::Metrics) => {
                    let _ = tx.send(Response::Metrics(stats.metrics_json()));
                }
                Err(e) => {
                    stats.record_bad_request();
                    let _ = tx.send(Response::Err {
                        id: 0,
                        code: ErrCode::BadRequest,
                        msg: format!("{e:#}"),
                    });
                }
            }
        }
        // tx drops here; the writer exits once in-flight replies land.
    });
    if reader.is_err() {
        eprintln!("serve: spawning reader thread failed");
    }
}

impl Server {
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn snapshot(&self) -> Snapshot {
        self.stats.snapshot()
    }

    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Block on the acceptor (the `cwy serve` foreground mode).
    pub fn join(mut self) {
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        if let Some(p) = self.pool.take() {
            p.join();
        }
    }

    /// Graceful-enough stop for tests and embedders: stop accepting,
    /// shed the queue, and join the worker pool.  Existing connection
    /// threads exit as their clients disconnect.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Release);
        self.batcher.shutdown();
        // Unblock the acceptor with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        if let Some(p) = self.pool.take() {
            p.join();
        }
    }
}
