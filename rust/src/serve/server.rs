//! TCP front end for `cwy serve` (DESIGN.md §6.6).
//!
//! One event-loop thread drives every client socket through a readiness
//! `poll`: nonblocking reads feed the frame decoder, decoded `infer`
//! frames pass admission control into the batcher, and worker replies
//! come back through the [`CompletionHub`] to be serialized onto the
//! owning connection's write buffer (with per-connection backpressure).
//! This replaces the two-threads-per-connection model, so 10k+ sockets
//! cost one thread plus per-connection buffers, and `stop()` is a waker
//! byte instead of a throwaway TCP dial (which hung on wildcard binds).

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};

use anyhow::{Context, Result};

use crate::serve::admission::{AdmissionCfg, AdmissionCtl};
use crate::serve::batcher::{BatchCfg, Batcher, ReplySink};
use crate::serve::completion::{drain_wakeups, wake_pair, CompletionHub, Waker};
use crate::serve::faults::{FaultInjector, FaultPlan};
use crate::serve::protocol::{self, ErrCode, Request, Response};
use crate::serve::session::{SessionCfg, SessionStore};
use crate::serve::stats::{Clock, ServeStats, Snapshot};
use crate::serve::supervisor::RestartPolicy;
use crate::serve::sys::{poll_fds, PollFd, POLLIN, POLLOUT};
use crate::serve::worker::{ModelFactory, ServeSpec, WorkerPool};

/// Event-loop tick: the longest `poll` sleeps before housekeeping
/// (deadline reap, session purge) runs even with no socket activity.
const TICK_MS: i32 = 25;

/// Server configuration (`cwy serve` flags map 1:1 onto these).
#[derive(Clone, Debug)]
pub struct ServeCfg {
    pub addr: String,
    pub workers: usize,
    pub batch: BatchCfg,
    pub session: SessionCfg,
    pub admission: AdmissionCfg,
    /// Learning rate injected into hyper inputs of step artifacts; 0.0
    /// serves without moving the resident parameters.
    pub lr: f32,
    /// Supervisor restart discipline for panicking workers (ISSUE 10).
    pub restart: RestartPolicy,
    /// Deterministic fault injection (`--faults` / `CWY_FAULTS`); `None`
    /// in production.  Carried in the config — not a process global — so
    /// embedded servers and tests in one process stay independent.
    pub faults: Option<FaultPlan>,
}

impl Default for ServeCfg {
    fn default() -> ServeCfg {
        ServeCfg {
            addr: "127.0.0.1:7070".to_string(),
            workers: 2,
            batch: BatchCfg::default(),
            session: SessionCfg::default(),
            admission: AdmissionCfg::default(),
            lr: 0.0,
            restart: RestartPolicy::default(),
            faults: None,
        }
    }
}

/// One client socket owned by the event loop.
struct Conn {
    stream: TcpStream,
    /// Bytes read but not yet terminated by `\n`.
    rbuf: Vec<u8>,
    /// Frames serialized but not yet accepted by the socket.
    wbuf: Vec<u8>,
    /// How many of `wbuf`'s bytes are already written.
    wpos: usize,
    /// Unanswered `infer` frames submitted on this connection.
    inflight: usize,
    /// Peer sent EOF (or a fatal frame): stop reading, finish writes.
    closing: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn { stream, rbuf: Vec::new(), wbuf: Vec::new(), wpos: 0, inflight: 0, closing: false }
    }

    fn queue_frame(&mut self, resp: &Response) {
        self.wbuf.extend_from_slice(protocol::encode_response(resp).as_bytes());
        self.wbuf.push(b'\n');
    }

    fn wants_write(&self) -> bool {
        self.wpos < self.wbuf.len()
    }

    /// Write as much of the buffer as the socket accepts right now.
    /// `Ok(())` on progress or `WouldBlock`; `Err` means the peer is gone.
    ///
    /// `cap` bounds how many bytes this round may write — the chaos
    /// partial-write fault.  A capped flush leaves the tail buffered with
    /// its cursor intact, exactly like a short kernel write; correctness
    /// must not notice, which is what the chaos suite asserts.
    fn flush(&mut self, cap: Option<usize>) -> io::Result<()> {
        let end = match cap {
            Some(c) => (self.wpos + c).min(self.wbuf.len()),
            None => self.wbuf.len(),
        };
        while self.wpos < end {
            match self.stream.write(&self.wbuf[self.wpos..end]) {
                Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                Ok(n) => self.wpos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos > 64 * 1024 {
            // Compact so a slow reader does not pin the written prefix.
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
        Ok(())
    }

    /// Pending (unwritten) output bytes.
    fn backlog(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// Split complete lines out of the read buffer.
    fn drain_lines(&mut self) -> Vec<String> {
        let mut out = Vec::new();
        let mut start = 0;
        while let Some(pos) = self.rbuf[start..].iter().position(|&b| b == b'\n') {
            let end = start + pos;
            out.push(String::from_utf8_lossy(&self.rbuf[start..end]).into_owned());
            start = end + 1;
        }
        self.rbuf.drain(..start);
        out
    }
}

/// The single-threaded readiness loop: listener + waker + every client
/// socket through one `poll`, admission ahead of the queue, completions
/// fanned back in from the worker pool.
struct EventLoop {
    listener: TcpListener,
    wake_rx: UnixStream,
    hub: Arc<CompletionHub>,
    conns: HashMap<u64, Conn>,
    next_conn: u64,
    admission: AdmissionCtl,
    batcher: Arc<Batcher>,
    sessions: Arc<SessionStore>,
    stats: Arc<ServeStats>,
    clock: Arc<Clock>,
    spec: ServeSpec,
    shutdown: Arc<AtomicBool>,
    /// Event-loop-side chaos injector (partial writes, malformed frames);
    /// `None` outside fault-injection runs.
    injector: Option<FaultInjector>,
}

impl EventLoop {
    fn run(mut self) {
        let mut fds: Vec<PollFd> = Vec::new();
        let mut slots: Vec<u64> = Vec::new();
        let mut scratch = vec![0u8; 64 * 1024];
        loop {
            if self.shutdown.load(Ordering::Acquire) {
                self.final_drain();
                return;
            }
            fds.clear();
            slots.clear();
            fds.push(PollFd::new(self.wake_rx.as_raw_fd(), POLLIN));
            let listener_slot = if self.admission.has_capacity() {
                fds.push(PollFd::new(self.listener.as_raw_fd(), POLLIN));
                Some(fds.len() - 1)
            } else {
                None
            };
            let conn_base = fds.len();
            for (&id, conn) in &self.conns {
                let mut events = 0i16;
                if !conn.closing {
                    events |= POLLIN;
                }
                if conn.wants_write() {
                    events |= POLLOUT;
                }
                fds.push(PollFd::new(conn.stream.as_raw_fd(), events));
                slots.push(id);
            }
            let n = match poll_fds(&mut fds, TICK_MS) {
                Ok(n) => n,
                Err(e) => {
                    eprintln!("serve: poll failed: {e}");
                    self.final_drain();
                    return;
                }
            };

            let span = if n > 0 { Some(crate::span!(event_loop)) } else { None };
            if fds[0].readable() {
                drain_wakeups(&self.wake_rx);
            }
            if listener_slot.is_some_and(|s| fds[s].readable()) {
                self.accept_ready();
            }
            for (i, &id) in slots.iter().enumerate() {
                let pfd = fds[conn_base + i];
                if pfd.error() {
                    self.close_conn(id);
                    continue;
                }
                if pfd.readable() {
                    self.read_ready(id, &mut scratch);
                }
                if pfd.writable() {
                    let cap = self.partial_cap(id);
                    if let Some(conn) = self.conns.get_mut(&id) {
                        if conn.flush(cap).is_err() {
                            self.close_conn(id);
                        }
                    }
                }
            }
            self.drain_completions();
            self.batcher.reap();
            self.sessions.purge(self.clock.now_us());
            self.sweep();
            drop(span);
        }
    }

    /// Accept until the listener runs dry or admission closes the gate.
    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if !self.admission.try_accept() {
                        // Raced one tick past the limit; the listener
                        // stops being polled until a slot frees up.
                        drop(stream);
                        return;
                    }
                    let _ = stream.set_nonblocking(true);
                    let _ = stream.set_nodelay(true);
                    let id = self.next_conn;
                    self.next_conn += 1;
                    self.conns.insert(id, Conn::new(stream));
                    self.stats.record_conn_open();
                    crate::telemetry::global().set_connections(self.conns.len() as u64);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    eprintln!("serve: accept failed: {e}");
                    return;
                }
            }
        }
    }

    /// Nonblocking read + frame decode for one connection.
    fn read_ready(&mut self, id: u64, scratch: &mut [u8]) {
        let mut eof = false;
        let mut dead = false;
        let Some(conn) = self.conns.get_mut(&id) else { return };
        loop {
            match conn.stream.read(scratch) {
                Ok(0) => {
                    eof = true;
                    break;
                }
                Ok(n) => conn.rbuf.extend_from_slice(&scratch[..n]),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    dead = true;
                    break;
                }
            }
        }
        if dead {
            self.close_conn(id);
            return;
        }
        let lines = conn.drain_lines();
        let oversized = conn.rbuf.len() > self.admission.cfg().max_line_bytes;
        for line in lines {
            if line.trim().is_empty() {
                continue;
            }
            self.handle_line(id, &line);
        }
        if oversized {
            // The partial line already exceeds the frame limit: answer
            // once and stop reading — the peer is broken or hostile.
            self.stats.record_bad_request();
            self.queue_to(
                id,
                Response::Err {
                    id: 0,
                    code: ErrCode::BadRequest,
                    msg: "request line exceeds max_line_bytes".to_string(),
                },
            );
            if let Some(conn) = self.conns.get_mut(&id) {
                conn.rbuf.clear();
                conn.closing = true;
            }
        }
        if eof {
            if let Some(conn) = self.conns.get_mut(&id) {
                conn.closing = true;
            }
        }
    }

    /// Fault-injection write cap for the next flush on `id` (`None`
    /// writes normally).
    fn partial_cap(&mut self, id: u64) -> Option<usize> {
        let injector = self.injector.as_mut()?;
        let backlog = self.conns.get(&id).map_or(0, |c| c.backlog());
        injector.partial_write_cap(backlog)
    }

    /// Decode and dispatch one frame from connection `id`.
    fn handle_line(&mut self, id: u64, line: &str) {
        // Malformed-frame fault: corrupt the line before the decoder sees
        // it.  The typed `bad_request` answer must still carry the
        // request id (recovered textually), so exactly-once accounting
        // survives the corruption.
        let corrupted = self.injector.as_mut().and_then(|f| f.corrupt_line(line));
        let line = corrupted.as_deref().unwrap_or(line);
        match protocol::decode_request(line) {
            Ok(Request::Infer(req)) => {
                let inflight = self.conns.get(&id).map_or(0, |c| c.inflight);
                if let Some(reason) = self.admission.check_infer(inflight) {
                    self.stats.record_rejected_inflight();
                    self.queue_to(
                        id,
                        Response::Err {
                            id: req.id,
                            code: reason.err_code(),
                            msg: reason.msg().to_string(),
                        },
                    );
                    return;
                }
                if let Some(conn) = self.conns.get_mut(&id) {
                    conn.inflight += 1;
                }
                // submit() answers overloaded/unavailable through the
                // sink, so every admitted infer yields exactly one
                // completion (which decrements `inflight`).
                self.batcher
                    .submit(req, ReplySink::Loop { conn: id, hub: self.hub.clone() });
            }
            Ok(Request::Ping { id: rid }) => self.queue_to(id, Response::Pong { id: rid }),
            Ok(Request::Spec) => {
                let frame = Response::Spec(self.spec.to_json());
                self.queue_to(id, frame);
            }
            Ok(Request::Stats) => {
                let frame = Response::Stats(self.stats.snapshot().to_json());
                self.queue_to(id, frame);
            }
            Ok(Request::Metrics) => {
                let frame = Response::Metrics(self.stats.metrics_json());
                self.queue_to(id, frame);
            }
            Err(e) => {
                // Best-effort id recovery (DESIGN.md §6.1): a pipelining
                // client can only match the error frame to its request if
                // the id survives the malformed line.
                self.stats.record_bad_request();
                self.queue_to(
                    id,
                    Response::Err {
                        id: protocol::recover_id(line),
                        code: ErrCode::BadRequest,
                        msg: format!("{e:#}"),
                    },
                );
            }
        }
    }

    fn queue_to(&mut self, id: u64, resp: Response) {
        if let Some(conn) = self.conns.get_mut(&id) {
            conn.queue_frame(&resp);
        }
    }

    /// Route finished worker replies back onto their connections.
    fn drain_completions(&mut self) {
        for (conn_id, resp) in self.hub.drain() {
            if let Some(conn) = self.conns.get_mut(&conn_id) {
                conn.inflight = conn.inflight.saturating_sub(1);
                conn.queue_frame(&resp);
            }
            // A closed connection drops its late replies on the floor —
            // there is no socket left to answer on.
        }
    }

    /// Opportunistic flush + overflow/close bookkeeping for every
    /// connection that has pending output or a finished lifecycle.
    fn sweep(&mut self) {
        let mut to_close: Vec<u64> = Vec::new();
        let max_buf = self.admission.cfg().max_conn_buffer;
        // The injector steps out of `self` for the iteration so each
        // connection's flush can consult it without aliasing `conns`.
        let mut injector = self.injector.take();
        for (&id, conn) in &mut self.conns {
            let cap = injector.as_mut().and_then(|f| f.partial_write_cap(conn.backlog()));
            if conn.wants_write() && conn.flush(cap).is_err() {
                to_close.push(id);
                continue;
            }
            if conn.backlog() > max_buf {
                // The peer is not consuming responses; shed the socket
                // rather than buffer without bound.
                self.stats.record_conn_overflow();
                to_close.push(id);
                continue;
            }
            if conn.closing && !conn.wants_write() && conn.inflight == 0 {
                to_close.push(id);
            }
        }
        self.injector = injector;
        for id in to_close {
            self.close_conn(id);
        }
    }

    fn close_conn(&mut self, id: u64) {
        if self.conns.remove(&id).is_some() {
            self.admission.release();
            self.stats.record_conn_close();
            crate::telemetry::global().set_connections(self.conns.len() as u64);
        }
    }

    /// Shutdown path: flush what the sockets will take right now (the
    /// batcher drain queued `unavailable` frames), then drop everything.
    fn final_drain(&mut self) {
        self.drain_completions();
        for conn in self.conns.values_mut() {
            // No fault cap here: shutdown flushes whatever the sockets
            // will take in one last round.
            let _ = conn.flush(None);
        }
        let n = self.conns.len();
        for _ in 0..n {
            self.stats.record_conn_close();
        }
        self.admission = AdmissionCtl::new(*self.admission.cfg());
        self.conns.clear();
        crate::telemetry::global().set_connections(0);
    }
}

/// Running server handle.
pub struct Server {
    addr: SocketAddr,
    stats: Arc<ServeStats>,
    batcher: Arc<Batcher>,
    shutdown: Arc<AtomicBool>,
    waker: Waker,
    event_loop: Option<JoinHandle<()>>,
    pool: Option<WorkerPool>,
}

/// Bind, spawn the worker pool and event loop, and return immediately.
///
/// `factory` is invoked once on the calling thread to probe the served
/// signature, then once per worker thread (each worker owns its model —
/// see `worker`).
pub fn serve(cfg: ServeCfg, factory: Arc<ModelFactory>) -> Result<Server> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    listener.set_nonblocking(true).context("listener nonblocking")?;
    let addr = listener.local_addr().context("reading bound address")?;

    let clock = Arc::new(Clock::new());
    let stats = Arc::new(ServeStats::new());
    let batcher = Arc::new(Batcher::new(cfg.batch, clock.clone(), stats.clone()));
    let sessions = Arc::new(SessionStore::new(cfg.session));
    let spec: ServeSpec = factory().context("initializing model")?.spec().clone();

    if let Some(plan) = cfg.faults.filter(|p| p.is_active()) {
        eprintln!("cwy-fault: injection active ({plan:?})");
    }
    let pool = WorkerPool::spawn(
        cfg.workers,
        factory,
        batcher.clone(),
        sessions.clone(),
        stats.clone(),
        clock.clone(),
        cfg.lr,
        cfg.restart,
        cfg.faults,
    );

    let (waker, wake_rx) = wake_pair().context("creating event-loop waker")?;
    let hub = Arc::new(CompletionHub::new(waker.clone()));
    let shutdown = Arc::new(AtomicBool::new(false));
    let event_loop = {
        let ev = EventLoop {
            listener,
            wake_rx,
            hub,
            conns: HashMap::new(),
            next_conn: 1,
            admission: AdmissionCtl::new(cfg.admission),
            batcher: batcher.clone(),
            sessions,
            stats: stats.clone(),
            clock,
            spec,
            shutdown: shutdown.clone(),
            injector: cfg
                .faults
                .filter(|p| p.is_active())
                .map(|p| p.injector_for_loop()),
        };
        thread::Builder::new()
            .name("cwy-serve-loop".to_string())
            .spawn(move || ev.run())
            .expect("spawning event-loop thread")
    };

    Ok(Server {
        addr,
        stats,
        batcher,
        shutdown,
        waker,
        event_loop: Some(event_loop),
        pool: Some(pool),
    })
}

impl Server {
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn snapshot(&self) -> Snapshot {
        self.stats.snapshot()
    }

    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Workers currently serving (spawned minus quarantined/exited).  The
    /// chaos suite asserts this equals the configured pool size after a
    /// run with injected panics — capacity self-heals via respawn.
    pub fn live_workers(&self) -> usize {
        self.pool.as_ref().map_or(0, |p| p.live_workers())
    }

    /// Block on the event loop (the `cwy serve` foreground mode).
    pub fn join(mut self) {
        if let Some(h) = self.event_loop.take() {
            let _ = h.join();
        }
        if let Some(p) = self.pool.take() {
            p.join();
        }
    }

    /// Graceful drain (ISSUE 10 satellite): every admitted request is
    /// answered before the sockets close.  Ordering matters —
    ///
    /// 1. `batcher.shutdown()` sheds the queue as typed `unavailable`
    ///    and makes `next_batch` return `None`, so workers wind down;
    /// 2. the pool is joined **while the event loop still runs**, so
    ///    completions from mid-execution batches (and the shutdown
    ///    drain) keep flowing out to the sockets;
    /// 3. only then does the loop get its shutdown flag: its
    ///    `final_drain` sees every completion already posted, flushes,
    ///    and closes.  Works for wildcard binds — no TCP dial.
    pub fn stop(mut self) {
        self.batcher.shutdown();
        if let Some(p) = self.pool.take() {
            p.join();
        }
        self.shutdown.store(true, Ordering::Release);
        self.waker.wake();
        if let Some(h) = self.event_loop.take() {
            let _ = h.join();
        }
    }
}
