//! Typed admission control and overload shedding for the serve front
//! end (DESIGN.md §6.6): every way the server refuses work is an
//! explicit [`ShedReason`] mapped to a protocol error code, decided
//! *before* the request touches the batch queue.
//!
//! Admission states, in the order a request meets them:
//!
//! 1. **connection** — at `max_connections` the event loop stops polling
//!    the listener; new dials wait in the kernel backlog instead of
//!    burning an accept+close round trip.
//! 2. **frame** — a line longer than `max_line_bytes` (or a write buffer
//!    past `max_conn_buffer`) closes the connection: the peer is either
//!    broken or not consuming its responses.
//! 3. **in-flight** — more than `max_inflight_per_conn` unanswered
//!    `infer` frames on one socket sheds `overloaded` (per-connection
//!    fairness: one greedy pipeliner cannot monopolize the queue).
//! 4. **queue** — the batcher's bounded queue sheds `overloaded`
//!    (global backpressure), and post-shutdown submits shed
//!    `unavailable`.

use crate::serve::protocol::ErrCode;

/// Admission limits (`cwy serve` flags map onto these).
#[derive(Clone, Copy, Debug)]
pub struct AdmissionCfg {
    /// Concurrent sockets the event loop will service.
    pub max_connections: usize,
    /// Unanswered `infer` frames allowed per connection.
    pub max_inflight_per_conn: usize,
    /// Longest accepted request line, bytes.
    pub max_line_bytes: usize,
    /// Write-buffer bytes per connection before it is dropped as a
    /// non-consuming peer.
    pub max_conn_buffer: usize,
}

impl Default for AdmissionCfg {
    fn default() -> AdmissionCfg {
        AdmissionCfg {
            max_connections: 10_240,
            max_inflight_per_conn: 256,
            max_line_bytes: 1 << 20,
            max_conn_buffer: 16 << 20,
        }
    }
}

/// Every typed way the front end refuses work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// `max_connections` sockets already open.
    ConnLimit,
    /// This connection has `max_inflight_per_conn` unanswered infers.
    InflightLimit,
    /// The batch queue is at `queue_cap`.
    QueueFull,
    /// The server is draining for shutdown.
    Shutdown,
}

impl ShedReason {
    /// The protocol error code this shed answers with.
    pub fn err_code(self) -> ErrCode {
        match self {
            ShedReason::ConnLimit | ShedReason::InflightLimit | ShedReason::QueueFull => {
                ErrCode::Overloaded
            }
            ShedReason::Shutdown => ErrCode::Unavailable,
        }
    }

    pub fn msg(self) -> &'static str {
        match self {
            ShedReason::ConnLimit => "connection limit reached",
            ShedReason::InflightLimit => "per-connection in-flight limit reached",
            ShedReason::QueueFull => "queue full",
            ShedReason::Shutdown => "server shutting down",
        }
    }
}

/// Event-loop-owned admission state (single-threaded: plain counters).
pub struct AdmissionCtl {
    cfg: AdmissionCfg,
    conns: usize,
}

impl AdmissionCtl {
    pub fn new(cfg: AdmissionCfg) -> AdmissionCtl {
        AdmissionCtl { cfg, conns: 0 }
    }

    pub fn cfg(&self) -> &AdmissionCfg {
        &self.cfg
    }

    pub fn conns(&self) -> usize {
        self.conns
    }

    /// Whether the listener should be polled for new connections.
    pub fn has_capacity(&self) -> bool {
        self.conns < self.cfg.max_connections
    }

    /// Admit one accepted socket.  Returns `false` at the limit (the
    /// loop should not have polled the listener, but an accept can race
    /// one tick past the threshold).
    pub fn try_accept(&mut self) -> bool {
        if self.conns >= self.cfg.max_connections {
            return false;
        }
        self.conns += 1;
        true
    }

    pub fn release(&mut self) {
        self.conns = self.conns.saturating_sub(1);
    }

    /// Admission decision for one `infer` frame on a connection that
    /// already has `inflight` unanswered requests.
    pub fn check_infer(&self, inflight: usize) -> Option<ShedReason> {
        if inflight >= self.cfg.max_inflight_per_conn {
            return Some(ShedReason::InflightLimit);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn connection_limit_gates_accepts() {
        let mut ctl = AdmissionCtl::new(AdmissionCfg {
            max_connections: 2,
            ..AdmissionCfg::default()
        });
        assert!(ctl.has_capacity());
        assert!(ctl.try_accept());
        assert!(ctl.try_accept());
        assert!(!ctl.has_capacity());
        assert!(!ctl.try_accept());
        ctl.release();
        assert!(ctl.has_capacity());
        assert!(ctl.try_accept());
        assert_eq!(ctl.conns(), 2);
    }

    #[test]
    fn release_never_underflows() {
        let mut ctl = AdmissionCtl::new(AdmissionCfg::default());
        ctl.release();
        assert_eq!(ctl.conns(), 0);
    }

    #[test]
    fn inflight_limit_sheds_overloaded() {
        let ctl = AdmissionCtl::new(AdmissionCfg {
            max_inflight_per_conn: 3,
            ..AdmissionCfg::default()
        });
        assert_eq!(ctl.check_infer(0), None);
        assert_eq!(ctl.check_infer(2), None);
        assert_eq!(ctl.check_infer(3), Some(ShedReason::InflightLimit));
        assert_eq!(ctl.check_infer(1000), Some(ShedReason::InflightLimit));
    }

    #[test]
    fn shed_taxonomy_maps_to_protocol_codes() {
        assert_eq!(ShedReason::ConnLimit.err_code(), ErrCode::Overloaded);
        assert_eq!(ShedReason::InflightLimit.err_code(), ErrCode::Overloaded);
        assert_eq!(ShedReason::QueueFull.err_code(), ErrCode::Overloaded);
        assert_eq!(ShedReason::Shutdown.err_code(), ErrCode::Unavailable);
        for r in [
            ShedReason::ConnLimit,
            ShedReason::InflightLimit,
            ShedReason::QueueFull,
            ShedReason::Shutdown,
        ] {
            assert!(!r.msg().is_empty());
        }
    }
}
