//! L4 serving subsystem: a multi-threaded, micro-batching inference
//! server over the PJRT runtime (DESIGN.md §6).
//!
//! The paper's point is that CWY/T-CWY turn sequential Householder
//! products into one fused, parallelism-friendly computation; serving
//! exploits the same shape at the other end of the stack by folding many
//! clients' requests into a single fused artifact execution:
//!
//! ```text
//! TCP clients ── event loop (poll, admission) ── Batcher (coalesce/shed)
//!                      ▲                              │ fused batches
//!                CompletionHub ◄── workers (one Engine each)
//!                   │ stack rows → execute → split rows
//!                sessions (per-client RNN state)   stats (p50/p95/p99)
//! ```
//!
//! Module map: [`protocol`] wire format · [`admission`] typed overload
//! shedding · [`batcher`] coalescing queue (continuous batching) ·
//! [`completion`] worker→loop reply hub · [`session`] recurrent-state
//! cache · [`worker`] pool + fused execution · [`supervisor`]
//! panic-isolated batch execution + worker respawn · [`faults`]
//! deterministic fault injection · [`server`] nonblocking event-loop
//! front end · [`client`] load generator + closed-loop harness ·
//! [`stats`] latency/occupancy accounting.

use std::sync::{Mutex, MutexGuard};

pub mod admission;
pub mod batcher;
pub mod client;
pub mod completion;
pub mod faults;
pub mod protocol;
pub mod server;
pub mod session;
pub mod stats;
pub mod supervisor;
mod sys;
pub mod worker;

pub use admission::{AdmissionCfg, AdmissionCtl, ShedReason};
pub use batcher::{BatchCfg, Batcher, ReplySink};
pub use client::{
    fetch_metrics, fetch_spec, fetch_stats, metrics_table, ping, run_load, run_sessions,
    ClientCfg, LoadReport, SessionLoadCfg, SessionLoadReport,
};
pub use completion::{CompletionHub, Waker};
pub use faults::{FaultInjector, FaultPlan};
pub use protocol::{ErrCode, InferRequest, Request, Response};
pub use server::{serve, ServeCfg, Server};
pub use session::{SessionCfg, SessionStore};
pub use stats::{Clock, ServeStats, Snapshot};
pub use supervisor::RestartPolicy;
pub use worker::{
    probe_serve_spec, EngineModel, FakeModel, ModelFactory, ServeModel, ServeSpec, WorkerPool,
};

/// Lock a serve-internal mutex, recovering from poisoning.
///
/// A panicking worker must not cascade-kill the stats path, the batcher,
/// or the completion hub: every guarded structure here keeps simple
/// counter/queue invariants that hold between individual mutations, so a
/// poisoned lock's data is still consistent and the right response is to
/// keep serving (ISSUE 10).  The supervisor converts the panic itself
/// into typed `worker_failed` frames; this helper makes sure the rest of
/// the runtime survives to deliver them.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}
