//! L4 serving subsystem: a multi-threaded, micro-batching inference
//! server over the PJRT runtime (DESIGN.md §6).
//!
//! The paper's point is that CWY/T-CWY turn sequential Householder
//! products into one fused, parallelism-friendly computation; serving
//! exploits the same shape at the other end of the stack by folding many
//! clients' requests into a single fused artifact execution:
//!
//! ```text
//! TCP clients ── protocol (JSON lines) ── Batcher (coalesce/shed)
//!                                             │ fused batches
//!                workers (one Engine each) ◄──┘
//!                   │ stack rows → execute → split rows
//!                sessions (per-client RNN state)   stats (p50/p95/p99)
//! ```
//!
//! Module map: [`protocol`] wire format · [`batcher`] coalescing queue ·
//! [`session`] recurrent-state cache · [`worker`] pool + fused execution ·
//! [`server`] TCP front end · [`client`] load generator · [`stats`]
//! latency/occupancy accounting.

pub mod batcher;
pub mod client;
pub mod protocol;
pub mod server;
pub mod session;
pub mod stats;
pub mod worker;

pub use batcher::{BatchCfg, Batcher};
pub use client::{
    fetch_metrics, fetch_spec, fetch_stats, metrics_table, ping, run_load, ClientCfg, LoadReport,
};
pub use protocol::{ErrCode, InferRequest, Request, Response};
pub use server::{serve, ServeCfg, Server};
pub use session::{SessionCfg, SessionStore};
pub use stats::{Clock, ServeStats, Snapshot};
pub use worker::{
    probe_serve_spec, EngineModel, FakeModel, ModelFactory, ServeModel, ServeSpec, WorkerPool,
};
