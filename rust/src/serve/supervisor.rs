//! Worker supervision: panic isolation, batch fail-over, and capped
//! respawn for the serve pool (ISSUE 10, DESIGN.md §6.8).
//!
//! A worker panic — a genuine bug or an injected chaos fault — must cost
//! exactly the in-flight chunk: one typed `worker_failed` frame per
//! request the dead execution owed, never silence and never a duplicate.
//! Everything still waiting in the worker's inbox was untouched by the
//! panic and goes back to the batcher's queue front, so the surviving
//! workers (or this one, once respawned) serve it in order.
//!
//! ```text
//!          ┌──────────── batch ok (failure count ← 0) ─────────────┐
//!          ▼                                                       │
//!  INIT ─► SERVING ── panic caught ─► FAIL-OVER ─── backoff ─► RESPAWN
//!   │                                 │ in-flight → worker_failed  │
//!   │ init error                      │ untouched inbox → requeue  │ rebuild error
//!   ▼                                 ▼                            │ (counts as a
//!  batcher.shutdown()            QUARANTINE ◄─ failures > max ─────┘  failure too)
//!  (pool-wide fail-fast)         (last worker down → batcher.shutdown())
//! ```
//!
//! The supervisor owns the loop a pool thread runs: pull a batch, feed
//! it through [`execute_batch_shared`] under `catch_unwind`, and on a
//! panic convert the wreckage into accounted outcomes before rebuilding
//! the model with capped exponential backoff.  The shared inbox/inflight
//! pair is the contract that makes the conversion exact: routes in
//! `inflight` identify the chunk the panic killed, entries in `inbox`
//! are provably untouched.  Respawn telemetry (`worker_restarts`,
//! `batches_requeued`) is process-global and surfaces through the
//! `metrics` frame and the Prometheus export.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::thread;
use std::time::Duration;

use crate::runtime::tensor::HostTensor;
use crate::serve::batcher::{Batcher, FailoverRoute, Pending};
use crate::serve::faults::{FaultInjector, FaultPlan};
use crate::serve::lock_recover;
use crate::serve::session::SessionStore;
use crate::serve::stats::{Clock, ServeStats};
use crate::serve::worker::{
    execute_batch_shared, ModelFactory, ServeModel, ServeSpec, WorkerScratch,
};

/// Restart discipline for a panicking worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RestartPolicy {
    /// Backoff before the first respawn attempt.
    pub base_delay_ms: u64,
    /// Backoff ceiling — exponential doubling stops here.
    pub max_delay_ms: u64,
    /// Consecutive failures (panics or rebuild errors, with no clean
    /// batch in between) tolerated before the worker is quarantined.
    pub max_restarts: u32,
}

impl Default for RestartPolicy {
    fn default() -> RestartPolicy {
        RestartPolicy { base_delay_ms: 10, max_delay_ms: 1_000, max_restarts: 8 }
    }
}

impl RestartPolicy {
    /// Capped exponential backoff: `base * 2^(k-1)` milliseconds for the
    /// k-th consecutive failure, clamped to `max_delay_ms`.
    pub fn backoff(&self, consecutive: u32) -> Duration {
        let exp = consecutive.saturating_sub(1).min(20);
        let ms = self.base_delay_ms.saturating_mul(1u64 << exp).min(self.max_delay_ms);
        Duration::from_millis(ms)
    }
}

/// Fresh model + resident state + signature from the pool's factory.
fn build(
    factory: &ModelFactory,
) -> anyhow::Result<(Box<dyn ServeModel>, Vec<HostTensor>, ServeSpec)> {
    let model = factory()?;
    let resident = model.initial_resident()?;
    let spec = model.spec().clone();
    Ok((model, resident, spec))
}

/// Supervised body of one pool thread (spawned by `WorkerPool`).
///
/// `live` counts workers still serving; every exit path decrements it
/// exactly once, and the last worker out shuts the batcher down so
/// queued and future requests get typed `unavailable` frames instead of
/// waiting on a pool that no longer exists.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_worker(
    w: usize,
    factory: &ModelFactory,
    batcher: &Batcher,
    sessions: &SessionStore,
    stats: &ServeStats,
    clock: &Clock,
    lr: f32,
    policy: RestartPolicy,
    faults: Option<FaultPlan>,
    live: &AtomicUsize,
) {
    let mut injector: Option<FaultInjector> =
        faults.filter(|p| p.is_active()).map(|p| p.injector_for_worker(w));

    // Initial build keeps the pool's historical fail-fast contract
    // (DESIGN.md §6.5): a pool that cannot build its model must not
    // accept work nobody serves, so the whole batcher shuts down
    // regardless of how many siblings are healthy.
    let (mut model, mut resident, spec) = match build(factory) {
        Ok(parts) => parts,
        Err(e) => {
            eprintln!("worker {w}: model init failed: {e:#}");
            live.fetch_sub(1, Ordering::AcqRel);
            batcher.shutdown();
            return;
        }
    };
    let mut scratch = WorkerScratch::default();
    let inbox: Mutex<VecDeque<Pending>> = Mutex::new(VecDeque::new());
    let inflight: Mutex<Vec<FailoverRoute>> = Mutex::new(Vec::new());
    let mut consecutive = 0u32;

    while let Some(batch) = batcher.next_batch() {
        lock_recover(&inbox).extend(batch);
        while !lock_recover(&inbox).is_empty() {
            let outcome = {
                let _span = crate::span!(supervisor);
                catch_unwind(AssertUnwindSafe(|| {
                    execute_batch_shared(
                        model.as_mut(),
                        &spec,
                        &mut resident,
                        &inbox,
                        &inflight,
                        sessions,
                        stats,
                        clock,
                        lr,
                        &mut scratch,
                        injector.as_mut(),
                    )
                }))
            };
            match outcome {
                Ok(()) => consecutive = 0,
                Err(payload) => {
                    let msg = panic_message(payload.as_ref());
                    fail_over(w, &inbox, &inflight, batcher, stats, &msg);
                    // The scratch may hold half-written control state
                    // from the dead execution; rebuild it with the model.
                    scratch = WorkerScratch::default();
                    if !respawn(w, factory, policy, &mut consecutive, &mut model, &mut resident)
                    {
                        quarantine(w, batcher, live);
                        return;
                    }
                }
            }
        }
    }
    live.fetch_sub(1, Ordering::AcqRel);
}

/// Convert a caught panic into visible, accounted outcomes: every
/// in-flight route gets exactly one `worker_failed` frame (unless the
/// dead execution already answered it), and every untouched inbox entry
/// goes back to the batcher's queue front in arrival order.
fn fail_over(
    w: usize,
    inbox: &Mutex<VecDeque<Pending>>,
    inflight: &Mutex<Vec<FailoverRoute>>,
    batcher: &Batcher,
    stats: &ServeStats,
    panic_msg: &str,
) {
    let routes = std::mem::take(&mut *lock_recover(inflight));
    let mut failed = 0u64;
    for route in &routes {
        if route
            .fail_worker(&format!("worker panicked during batch execution ({panic_msg}); retry"))
        {
            failed += 1;
        }
    }
    if failed > 0 {
        stats.record_exec_error(failed);
    }
    let untouched: Vec<Pending> = lock_recover(inbox).drain(..).collect();
    let requeued = untouched.len();
    if requeued > 0 {
        crate::telemetry::global().add_batch_requeued();
        batcher.requeue(untouched);
    }
    eprintln!(
        "cwy-supervisor: worker {w} panicked: {panic_msg} \
         ({failed} in-flight failed over, {requeued} requeued)"
    );
}

/// Backed-off rebuild loop.  Bumps `consecutive` per attempt (a rebuild
/// error is a failure too) and returns false once the budget is spent —
/// the caller quarantines the worker.
fn respawn(
    w: usize,
    factory: &ModelFactory,
    policy: RestartPolicy,
    consecutive: &mut u32,
    model: &mut Box<dyn ServeModel>,
    resident: &mut Vec<HostTensor>,
) -> bool {
    loop {
        *consecutive += 1;
        if *consecutive > policy.max_restarts {
            return false;
        }
        let delay = policy.backoff(*consecutive);
        eprintln!(
            "cwy-supervisor: worker {w} respawning in {}ms (failure {}/{})",
            delay.as_millis(),
            *consecutive,
            policy.max_restarts
        );
        thread::sleep(delay);
        match build(factory) {
            Ok((m, r, _spec)) => {
                *model = m;
                *resident = r;
                crate::telemetry::global().add_worker_restart();
                return true;
            }
            Err(e) => eprintln!("cwy-supervisor: worker {w} rebuild failed: {e:#}"),
        }
    }
}

/// Permanent removal after the restart budget is spent.  When the last
/// worker quarantines, the batcher shuts down so queued and future
/// requests get `unavailable` frames instead of waiting forever.
fn quarantine(w: usize, batcher: &Batcher, live: &AtomicUsize) {
    let remaining = live.fetch_sub(1, Ordering::AcqRel) - 1;
    eprintln!("cwy-supervisor: worker {w} quarantined ({remaining} workers left)");
    if remaining == 0 {
        batcher.shutdown();
    }
}

/// Human-readable panic payload (`panic!` carries `&str` or `String`;
/// anything else is opaque).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::HostTensor;
    use crate::serve::batcher::{BatchCfg, Batcher};
    use crate::serve::protocol::{ErrCode, InferRequest, Response};
    use crate::serve::session::{SessionCfg, SessionStore};
    use crate::serve::worker::{FakeModel, WorkerPool};
    use anyhow::Result;
    use std::sync::atomic::AtomicU32;
    use std::sync::{mpsc, Arc};
    use std::time::Duration;

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RestartPolicy { base_delay_ms: 10, max_delay_ms: 100, max_restarts: 8 };
        assert_eq!(p.backoff(1), Duration::from_millis(10));
        assert_eq!(p.backoff(2), Duration::from_millis(20));
        assert_eq!(p.backoff(3), Duration::from_millis(40));
        assert_eq!(p.backoff(4), Duration::from_millis(80));
        assert_eq!(p.backoff(5), Duration::from_millis(100));
        assert_eq!(p.backoff(60), Duration::from_millis(100), "shift must not overflow");
    }

    /// FakeModel wrapper whose `run` panics on globally chosen call
    /// indices (shared across respawns via the counter).
    struct PanicOn {
        inner: FakeModel,
        calls: Arc<AtomicU32>,
        panic_calls: &'static [u32],
    }

    impl crate::serve::worker::ServeModel for PanicOn {
        fn spec(&self) -> &ServeSpec {
            self.inner.spec()
        }

        fn run(&mut self, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
            let n = self.calls.fetch_add(1, Ordering::SeqCst);
            if self.panic_calls.contains(&n) {
                panic!("test panic on call {n}");
            }
            self.inner.run(inputs)
        }

        fn initial_resident(&self) -> Result<Vec<HostTensor>> {
            self.inner.initial_resident()
        }
    }

    fn harness(
        panic_calls: &'static [u32],
        policy: RestartPolicy,
    ) -> (Arc<Batcher>, WorkerPool, Arc<AtomicU32>) {
        let clock = Arc::new(Clock::new());
        let stats = Arc::new(ServeStats::new());
        let cfg = BatchCfg { max_batch: 4, max_wait_us: 500, queue_cap: 64, continuous: true };
        let batcher = Arc::new(Batcher::new(cfg, clock.clone(), stats.clone()));
        let sessions = Arc::new(SessionStore::new(SessionCfg::default()));
        let calls = Arc::new(AtomicU32::new(0));
        let factory_calls = calls.clone();
        let factory: Arc<ModelFactory> = Arc::new(move || {
            Ok(Box::new(PanicOn {
                inner: FakeModel::new(4, 2, 0),
                calls: factory_calls.clone(),
                panic_calls,
            }) as Box<dyn ServeModel>)
        });
        let pool = WorkerPool::spawn(
            1, factory, batcher.clone(), sessions, stats, clock, 0.0, policy, None,
        );
        (batcher, pool, calls)
    }

    fn infer(id: u64) -> InferRequest {
        InferRequest {
            id,
            artifact: FakeModel::ARTIFACT.to_string(),
            session: None,
            deadline_us: None,
            inputs: vec![HostTensor::f32(vec![2], vec![1.0, 1.0])],
        }
    }

    #[test]
    fn panicking_batch_fails_over_and_worker_respawns() {
        let policy = RestartPolicy { base_delay_ms: 1, max_delay_ms: 8, max_restarts: 8 };
        let (batcher, pool, _calls) = harness(&[0], policy);
        let restarts_before = crate::telemetry::global().worker_restarts();

        // First request hits the panicking call: its one completion must
        // be a typed worker_failed frame.
        let (tx, rx) = mpsc::channel();
        assert!(batcher.submit(infer(1), tx));
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            Response::Err { id, code, msg } => {
                assert_eq!(id, 1);
                assert_eq!(code, ErrCode::WorkerFailed);
                assert!(msg.contains("panicked"), "{msg}");
            }
            other => panic!("wrong frame: {other:?}"),
        }

        // The respawned worker serves the next request normally.
        let (tx, rx) = mpsc::channel();
        assert!(batcher.submit(infer(2), tx));
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            Response::Ok { id, .. } => assert_eq!(id, 2),
            other => panic!("wrong frame: {other:?}"),
        }
        assert_eq!(pool.live_workers(), 1, "capacity must self-heal");
        assert!(
            crate::telemetry::global().worker_restarts() > restarts_before,
            "respawn must bump the worker_restarts counter"
        );

        batcher.shutdown();
        pool.join();
    }

    #[test]
    fn exhausted_restart_budget_quarantines_and_fails_fast() {
        // Every call panics; one tolerated restart means the second panic
        // quarantines the (only) worker, which must shut the batcher down
        // rather than leave future submits hanging.
        let policy = RestartPolicy { base_delay_ms: 1, max_delay_ms: 4, max_restarts: 1 };
        let (batcher, pool, _calls) =
            harness(&[0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15], policy);

        let (tx, rx) = mpsc::channel();
        assert!(batcher.submit(infer(1), tx));
        assert!(matches!(
            rx.recv_timeout(Duration::from_secs(5)).unwrap(),
            Response::Err { code: ErrCode::WorkerFailed, .. }
        ));
        let (tx, rx) = mpsc::channel();
        // This submit either lands before the quarantine (worker_failed)
        // or after the shutdown (unavailable) — either way it is answered.
        let accepted = batcher.submit(infer(2), tx);
        if accepted {
            assert!(matches!(
                rx.recv_timeout(Duration::from_secs(5)).unwrap(),
                Response::Err {
                    code: ErrCode::WorkerFailed | ErrCode::Unavailable,
                    ..
                }
            ));
        }
        // Quarantine of the only worker must fail the pool fast: the
        // batcher shuts down and the live count hits zero.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !batcher.is_shutdown() && std::time::Instant::now() < deadline {
            thread::sleep(Duration::from_millis(2));
        }
        assert!(batcher.is_shutdown(), "last quarantine must fail the pool fast");
        assert_eq!(pool.live_workers(), 0);
        pool.join();
    }
}
