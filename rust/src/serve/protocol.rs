//! Wire protocol for `cwy serve`: JSON objects, one per line, over TCP.
//!
//! Transport-agnostic by construction — encode/decode work on single
//! lines, so unit tests exercise the full grammar without sockets.  The
//! frame format is specified in DESIGN.md §6.1; in short:
//!
//! ```text
//! -> {"type":"infer","id":7,"artifact":"copy_cwy_step","session":"s1",
//!     "deadline_us":500000,"inputs":[{"shape":[4],"dtype":"f32",
//!     "data":[1,2,3,4]}]}
//! <- {"type":"ok","id":7,"batch":5,"queue_us":210,"exec_us":850,
//!     "outputs":[{"shape":[4],"dtype":"f32","data":[2,4,6,8]}]}
//! <- {"type":"err","id":7,"code":"deadline","msg":"expired in queue"}
//! ```
//!
//! `deadline_us` is a *relative* budget measured from server enqueue time,
//! so client and server clocks never need to agree.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::runtime::tensor::{Data, Dtype, HostTensor};
use crate::util::json::{parse, Json};

/// One inference call: the client supplies a row per data input of the
/// served artifact (DESIGN.md §6.2).
#[derive(Clone, Debug)]
pub struct InferRequest {
    pub id: u64,
    pub artifact: String,
    /// Session key for streaming models: per-row recurrent state is kept
    /// server-side between calls carrying the same key.
    pub session: Option<String>,
    /// Relative deadline budget in microseconds; requests still queued
    /// past the budget are shed with an `err/deadline` frame.
    pub deadline_us: Option<u64>,
    pub inputs: Vec<HostTensor>,
}

/// Client -> server frames.
#[derive(Clone, Debug)]
pub enum Request {
    Infer(InferRequest),
    Ping { id: u64 },
    /// Ask for the served artifact's signature (batch size, row shapes).
    Spec,
    /// Ask for a server statistics snapshot.
    Stats,
    /// Ask for the combined serve + telemetry metrics snapshot
    /// (`{"serve": .., "telemetry": ..}` — see `ServeStats::metrics_json`).
    Metrics,
}

/// Machine-readable error classes in `err` frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrCode {
    /// Shed: the deadline budget elapsed while queued.
    Deadline,
    /// Backpressure: the bounded queue was full at submit time.
    Overloaded,
    /// The frame did not parse or did not match the artifact signature.
    BadRequest,
    /// The fused execution failed.
    Exec,
    /// The request named a session whose stored state no longer matches
    /// the served signature (e.g. after a parameter/artifact swap) — the
    /// typed form of what used to be a silent reset (worst case, a
    /// worker-thread shape assert).  Clients should drop or re-key the
    /// session and retry.
    StaleState,
    /// The worker executing this request's fused batch panicked.  The
    /// supervisor (serve::supervisor) converts the panic into this typed
    /// frame for every in-flight request instead of hanging the client;
    /// the request itself may be retried safely.
    WorkerFailed,
    /// The server is shutting down.
    Unavailable,
}

impl ErrCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrCode::Deadline => "deadline",
            ErrCode::Overloaded => "overloaded",
            ErrCode::BadRequest => "bad_request",
            ErrCode::Exec => "exec",
            ErrCode::StaleState => "stale_state",
            ErrCode::WorkerFailed => "worker_failed",
            ErrCode::Unavailable => "unavailable",
        }
    }

    pub fn parse(s: &str) -> Result<ErrCode> {
        Ok(match s {
            "deadline" => ErrCode::Deadline,
            "overloaded" => ErrCode::Overloaded,
            "bad_request" => ErrCode::BadRequest,
            "exec" => ErrCode::Exec,
            "stale_state" => ErrCode::StaleState,
            "worker_failed" => ErrCode::WorkerFailed,
            "unavailable" => ErrCode::Unavailable,
            other => bail!("unknown error code '{other}'"),
        })
    }
}

/// Server -> client frames.
#[derive(Clone, Debug)]
pub enum Response {
    Ok {
        id: u64,
        outputs: Vec<HostTensor>,
        /// Time spent queued before the fused execution started.
        queue_us: u64,
        /// Wall time of the fused execution that served this request.
        exec_us: u64,
        /// How many requests were coalesced into that execution.
        batch: usize,
    },
    Err {
        id: u64,
        code: ErrCode,
        msg: String,
    },
    Pong {
        id: u64,
    },
    Spec(Json),
    Stats(Json),
    Metrics(Json),
}

impl Response {
    /// The request id this frame answers, when it answers one.
    pub fn id(&self) -> Option<u64> {
        match self {
            Response::Ok { id, .. } | Response::Err { id, .. } | Response::Pong { id } => {
                Some(*id)
            }
            Response::Spec(_) | Response::Stats(_) | Response::Metrics(_) => None,
        }
    }
}

fn dtype_str(d: Dtype) -> &'static str {
    match d {
        Dtype::F32 => "f32",
        Dtype::I32 => "i32",
    }
}

/// Tensor -> `{"shape":[..],"dtype":"f32","data":[..]}`.
pub fn tensor_to_json(t: &HostTensor) -> Json {
    let mut m = BTreeMap::new();
    m.insert(
        "shape".to_string(),
        Json::Arr(t.shape.iter().map(|&d| Json::Num(d as f64)).collect()),
    );
    m.insert("dtype".to_string(), Json::Str(dtype_str(t.dtype()).to_string()));
    let data = match &t.data {
        Data::F32(v) => Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect()),
        Data::I32(v) => Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect()),
    };
    m.insert("data".to_string(), data);
    Json::Obj(m)
}

/// Inverse of [`tensor_to_json`]; validates shape/data consistency.
pub fn tensor_from_json(j: &Json) -> Result<HostTensor> {
    let shape: Vec<usize> = j
        .path(&["shape"])
        .as_arr()
        .ok_or_else(|| anyhow!("tensor missing 'shape'"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
        .collect::<Result<_>>()?;
    let dtype = Dtype::parse(
        j.path(&["dtype"])
            .as_str()
            .ok_or_else(|| anyhow!("tensor missing 'dtype'"))?,
    )?;
    let data = j
        .path(&["data"])
        .as_arr()
        .ok_or_else(|| anyhow!("tensor missing 'data'"))?;
    let want: usize = shape.iter().product();
    if data.len() != want {
        bail!("tensor data has {} values, shape {:?} needs {want}", data.len(), shape);
    }
    let nums: Vec<f64> = data
        .iter()
        .map(|x| x.as_f64().ok_or_else(|| anyhow!("non-numeric tensor data")))
        .collect::<Result<_>>()?;
    Ok(match dtype {
        Dtype::F32 => HostTensor::f32(shape, nums.iter().map(|&x| x as f32).collect()),
        Dtype::I32 => HostTensor::i32(shape, nums.iter().map(|&x| x as i32).collect()),
    })
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Encode one request frame (no trailing newline; the transport adds it).
pub fn encode_request(req: &Request) -> String {
    match req {
        Request::Infer(r) => {
            let mut pairs = vec![
                ("type", Json::Str("infer".into())),
                ("id", Json::Num(r.id as f64)),
                ("artifact", Json::Str(r.artifact.clone())),
                (
                    "inputs",
                    Json::Arr(r.inputs.iter().map(tensor_to_json).collect()),
                ),
            ];
            if let Some(s) = &r.session {
                pairs.push(("session", Json::Str(s.clone())));
            }
            if let Some(d) = r.deadline_us {
                pairs.push(("deadline_us", Json::Num(d as f64)));
            }
            obj(pairs).dump()
        }
        Request::Ping { id } => {
            obj(vec![("type", Json::Str("ping".into())), ("id", Json::Num(*id as f64))]).dump()
        }
        Request::Spec => obj(vec![("type", Json::Str("spec".into()))]).dump(),
        Request::Stats => obj(vec![("type", Json::Str("stats".into()))]).dump(),
        Request::Metrics => obj(vec![("type", Json::Str("metrics".into()))]).dump(),
    }
}

/// Decode one request line.
pub fn decode_request(line: &str) -> Result<Request> {
    let j = parse(line.trim()).map_err(|e| anyhow!("bad frame: {e}"))?;
    let ty = j
        .path(&["type"])
        .as_str()
        .ok_or_else(|| anyhow!("frame missing 'type'"))?;
    match ty {
        "infer" => {
            let id = j
                .path(&["id"])
                .as_f64()
                .ok_or_else(|| anyhow!("infer frame missing 'id'"))? as u64;
            let artifact = j
                .path(&["artifact"])
                .as_str()
                .ok_or_else(|| anyhow!("infer frame missing 'artifact'"))?
                .to_string();
            let session = j.path(&["session"]).as_str().map(|s| s.to_string());
            let deadline_us = j.path(&["deadline_us"]).as_f64().map(|x| x as u64);
            let inputs = j
                .path(&["inputs"])
                .as_arr()
                .ok_or_else(|| anyhow!("infer frame missing 'inputs'"))?
                .iter()
                .map(tensor_from_json)
                .collect::<Result<Vec<_>>>()?;
            Ok(Request::Infer(InferRequest { id, artifact, session, deadline_us, inputs }))
        }
        "ping" => Ok(Request::Ping {
            id: j.path(&["id"]).as_f64().unwrap_or(0.0) as u64,
        }),
        "spec" => Ok(Request::Spec),
        "stats" => Ok(Request::Stats),
        "metrics" => Ok(Request::Metrics),
        other => bail!("unknown request type '{other}'"),
    }
}

/// Encode one response frame (no trailing newline).
pub fn encode_response(resp: &Response) -> String {
    match resp {
        Response::Ok { id, outputs, queue_us, exec_us, batch } => obj(vec![
            ("type", Json::Str("ok".into())),
            ("id", Json::Num(*id as f64)),
            ("batch", Json::Num(*batch as f64)),
            ("queue_us", Json::Num(*queue_us as f64)),
            ("exec_us", Json::Num(*exec_us as f64)),
            (
                "outputs",
                Json::Arr(outputs.iter().map(tensor_to_json).collect()),
            ),
        ])
        .dump(),
        Response::Err { id, code, msg } => obj(vec![
            ("type", Json::Str("err".into())),
            ("id", Json::Num(*id as f64)),
            ("code", Json::Str(code.as_str().into())),
            ("msg", Json::Str(msg.clone())),
        ])
        .dump(),
        Response::Pong { id } => {
            obj(vec![("type", Json::Str("pong".into())), ("id", Json::Num(*id as f64))]).dump()
        }
        Response::Spec(s) => {
            obj(vec![("type", Json::Str("spec".into())), ("spec", s.clone())]).dump()
        }
        Response::Stats(s) => {
            obj(vec![("type", Json::Str("stats".into())), ("stats", s.clone())]).dump()
        }
        Response::Metrics(m) => {
            obj(vec![("type", Json::Str("metrics".into())), ("metrics", m.clone())]).dump()
        }
    }
}

/// Decode one response line.
pub fn decode_response(line: &str) -> Result<Response> {
    let j = parse(line.trim()).map_err(|e| anyhow!("bad frame: {e}"))?;
    let ty = j
        .path(&["type"])
        .as_str()
        .ok_or_else(|| anyhow!("frame missing 'type'"))?;
    let id = j.path(&["id"]).as_f64().unwrap_or(0.0) as u64;
    match ty {
        "ok" => {
            let outputs = j
                .path(&["outputs"])
                .as_arr()
                .ok_or_else(|| anyhow!("ok frame missing 'outputs'"))?
                .iter()
                .map(tensor_from_json)
                .collect::<Result<Vec<_>>>()?;
            Ok(Response::Ok {
                id,
                outputs,
                queue_us: j.path(&["queue_us"]).as_f64().unwrap_or(0.0) as u64,
                exec_us: j.path(&["exec_us"]).as_f64().unwrap_or(0.0) as u64,
                batch: j.path(&["batch"]).as_f64().unwrap_or(0.0) as usize,
            })
        }
        "err" => Ok(Response::Err {
            id,
            code: ErrCode::parse(j.path(&["code"]).as_str().unwrap_or(""))?,
            msg: j.path(&["msg"]).as_str().unwrap_or("").to_string(),
        }),
        "pong" => Ok(Response::Pong { id }),
        "spec" => Ok(Response::Spec(j.path(&["spec"]).clone())),
        "stats" => Ok(Response::Stats(j.path(&["stats"]).clone())),
        "metrics" => Ok(Response::Metrics(j.path(&["metrics"]).clone())),
        other => bail!("unknown response type '{other}'"),
    }
}

/// Best-effort `id` recovery from a malformed request line.
///
/// A decode failure is still answered with an `err` frame, and a
/// pipelining client can only match that frame to its request if the id
/// survives (DESIGN.md §6.1).  The line failed JSON parsing, so this
/// scans textually: the first `"id"` key followed by `:` and an unsigned
/// integer wins.  Returns 0 — the documented "unattributable" id, which
/// no well-formed client request uses — when nothing recoverable is
/// found.
pub fn recover_id(line: &str) -> u64 {
    let mut rest = line;
    while let Some(at) = rest.find("\"id\"") {
        let after = &rest[at + 4..];
        let after = after.trim_start();
        if let Some(v) = after.strip_prefix(':') {
            let v = v.trim_start();
            let end = v.find(|c: char| !c.is_ascii_digit()).unwrap_or(v.len());
            if let Ok(id) = v[..end].parse::<u64>() {
                return id;
            }
        }
        rest = &rest[at + 4..];
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn infer_req() -> Request {
        Request::Infer(InferRequest {
            id: 42,
            artifact: "copy_cwy_step".into(),
            session: Some("s1".into()),
            deadline_us: Some(500_000),
            inputs: vec![
                HostTensor::f32(vec![2, 2], vec![1.0, 2.5, -3.0, 0.0]),
                HostTensor::i32(vec![3], vec![7, -8, 9]),
            ],
        })
    }

    #[test]
    fn infer_roundtrip() {
        let line = encode_request(&infer_req());
        assert!(!line.contains('\n'));
        match decode_request(&line).unwrap() {
            Request::Infer(r) => {
                assert_eq!(r.id, 42);
                assert_eq!(r.artifact, "copy_cwy_step");
                assert_eq!(r.session.as_deref(), Some("s1"));
                assert_eq!(r.deadline_us, Some(500_000));
                assert_eq!(r.inputs[0], HostTensor::f32(vec![2, 2], vec![1.0, 2.5, -3.0, 0.0]));
                assert_eq!(r.inputs[1], HostTensor::i32(vec![3], vec![7, -8, 9]));
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn response_roundtrip() {
        let resp = Response::Ok {
            id: 42,
            outputs: vec![HostTensor::f32(vec![2], vec![0.5, -0.25])],
            queue_us: 210,
            exec_us: 850,
            batch: 5,
        };
        let line = encode_response(&resp);
        match decode_response(&line).unwrap() {
            Response::Ok { id, outputs, queue_us, exec_us, batch } => {
                assert_eq!((id, queue_us, exec_us, batch), (42, 210, 850, 5));
                assert_eq!(outputs[0], HostTensor::f32(vec![2], vec![0.5, -0.25]));
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn error_frame_roundtrip() {
        let line = encode_response(&Response::Err {
            id: 9,
            code: ErrCode::Deadline,
            msg: "expired in queue".into(),
        });
        match decode_response(&line).unwrap() {
            Response::Err { id, code, msg } => {
                assert_eq!(id, 9);
                assert_eq!(code, ErrCode::Deadline);
                assert_eq!(msg, "expired in queue");
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn every_err_code_roundtrips() {
        for code in [
            ErrCode::Deadline,
            ErrCode::Overloaded,
            ErrCode::BadRequest,
            ErrCode::Exec,
            ErrCode::StaleState,
            ErrCode::WorkerFailed,
            ErrCode::Unavailable,
        ] {
            assert_eq!(ErrCode::parse(code.as_str()).unwrap(), code);
        }
        assert_eq!(ErrCode::WorkerFailed.as_str(), "worker_failed");
    }

    #[test]
    fn ping_and_meta_frames() {
        match decode_request(&encode_request(&Request::Ping { id: 3 })).unwrap() {
            Request::Ping { id } => assert_eq!(id, 3),
            other => panic!("wrong frame: {other:?}"),
        }
        assert!(matches!(decode_request(&encode_request(&Request::Spec)).unwrap(), Request::Spec));
        assert!(matches!(
            decode_request(&encode_request(&Request::Stats)).unwrap(),
            Request::Stats
        ));
        assert!(matches!(
            decode_request(&encode_request(&Request::Metrics)).unwrap(),
            Request::Metrics
        ));
    }

    #[test]
    fn metrics_frame_roundtrip() {
        let payload = parse(r#"{"serve":{"completed":3},"telemetry":{"spans":{}}}"#).unwrap();
        let resp = Response::Metrics(payload.clone());
        assert_eq!(resp.id(), None);
        match decode_response(&encode_response(&resp)).unwrap() {
            Response::Metrics(m) => {
                assert_eq!(m, payload);
                assert_eq!(m.path(&["serve", "completed"]).as_f64(), Some(3.0));
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn rejects_malformed_frames() {
        assert!(decode_request("not json").is_err());
        assert!(decode_request(r#"{"id":1}"#).is_err());
        assert!(decode_request(r#"{"type":"infer","id":1}"#).is_err());
        assert!(decode_request(r#"{"type":"launch_rockets"}"#).is_err());
        // shape/data mismatch
        let bad = r#"{"type":"infer","id":1,"artifact":"a",
                      "inputs":[{"shape":[3],"dtype":"f32","data":[1,2]}]}"#;
        assert!(decode_request(bad).is_err());
    }

    #[test]
    fn tensor_json_preserves_exact_f32() {
        // f32 -> f64 -> text -> f64 -> f32 must be exact for any f32.
        for v in [1.0e-20f32, 3.333_333_3, -1.5e20, f32::MIN_POSITIVE] {
            let t = HostTensor::f32(vec![1], vec![v]);
            let back = tensor_from_json(&parse(&tensor_to_json(&t).dump()).unwrap()).unwrap();
            assert_eq!(back, t);
        }
    }

    #[test]
    fn recovers_id_from_malformed_lines() {
        // The PR-8 satellite: err frames for undecodable lines must carry
        // the request id whenever it is textually recoverable.
        assert_eq!(recover_id(r#"{"type":"infer","id":7,"artifact""#), 7);
        assert_eq!(recover_id(r#"{"id": 42, "type":"bogus"}"#), 42);
        assert_eq!(recover_id(r#"{"id"   :   9001}"#), 9001);
        // First recoverable "id" key wins; lookalikes are skipped.
        assert_eq!(recover_id(r#"{"ids":[1,2],"id":9}"#), 9);
        assert_eq!(recover_id(r#"{"id":"not-a-number","id":5}"#), 5);
        // Nothing recoverable falls back to the documented id 0.
        assert_eq!(recover_id("not json at all"), 0);
        assert_eq!(recover_id(r#"{"id":"abc"}"#), 0);
        assert_eq!(recover_id(r#"{"id":-3}"#), 0);
        assert_eq!(recover_id(""), 0);
    }
}
