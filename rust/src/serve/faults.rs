//! Deterministic fault injection for the serve runtime (ISSUE 10).
//!
//! Chaos testing only proves something if a failing run can be replayed,
//! so every fault decision comes from a seeded [`Pcg32`] stream — the
//! same seed and spec produce the same fault schedule at every site.
//! The plan is pure configuration ([`FaultPlan`], parsed from
//! `CWY_FAULTS=seed:spec` or `cwy serve --faults seed:spec`); each
//! injection site owns a [`FaultInjector`] with its own RNG streams, so
//! worker threads and the event loop never contend and per-site
//! schedules are independent of thread interleaving.
//!
//! Spec grammar (rates are probabilities in [0, 1]):
//!
//! ```text
//! spec   := seed ":" clause ("," clause)*
//! clause := "panic=" rate          worker panics before/within a batch
//!         | "slow=" rate ["@" us]  injected execution delay (default 1000us)
//!         | "partial=" rate        short socket writes in the event loop
//!         | "malformed=" rate      corrupt an inbound frame before parse
//! ```
//!
//! Example: `CWY_FAULTS=42:panic=0.1,slow=0.05@2000,malformed=0.01`.
//!
//! Every fired fault bumps the process-wide `faults_injected` telemetry
//! counter and writes one line to stderr — the "fault log" the CI chaos
//! job uploads on failure.

use anyhow::{bail, Context, Result};

use crate::util::rng::Pcg32;

/// Which injection site is asking (also the RNG stream selector, so each
/// site's schedule is an independent deterministic sequence).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Site {
    Panic = 0,
    Slow = 1,
    PartialWrite = 2,
    Malformed = 3,
}

/// Parsed, immutable fault configuration.  `Copy`-cheap on purpose: the
/// server config clones it into every worker's injector.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Probability a supervised batch execution panics.
    pub panic_rate: f32,
    /// Probability a batch execution is delayed by `slow_us`.
    pub slow_rate: f32,
    pub slow_us: u64,
    /// Probability a socket flush writes only half its backlog.
    pub partial_write_rate: f32,
    /// Probability an inbound request line is corrupted before parsing
    /// (the server must still answer `bad_request` under the recovered
    /// id — exactly-once survives).
    pub malformed_rate: f32,
}

impl FaultPlan {
    /// Parse a `seed:spec` string (see the module grammar).
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let s = s.trim();
        let (seed_s, spec) = s
            .split_once(':')
            .with_context(|| format!("fault spec '{s}' missing 'seed:' prefix"))?;
        let seed: u64 = seed_s
            .trim()
            .parse()
            .with_context(|| format!("bad fault seed '{seed_s}'"))?;
        let mut plan = FaultPlan { seed, slow_us: 1_000, ..FaultPlan::default() };
        for clause in spec.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (key, val) = clause
                .split_once('=')
                .with_context(|| format!("fault clause '{clause}' missing '='"))?;
            let rate_of = |v: &str| -> Result<f32> {
                let r: f32 = v
                    .parse()
                    .with_context(|| format!("bad fault rate '{v}' in '{clause}'"))?;
                if !(0.0..=1.0).contains(&r) {
                    bail!("fault rate {r} in '{clause}' outside [0, 1]");
                }
                Ok(r)
            };
            match key.trim() {
                "panic" => plan.panic_rate = rate_of(val)?,
                "slow" => match val.split_once('@') {
                    Some((rate, us)) => {
                        plan.slow_rate = rate_of(rate)?;
                        plan.slow_us = us
                            .parse()
                            .with_context(|| format!("bad slow delay '{us}' in '{clause}'"))?;
                    }
                    None => plan.slow_rate = rate_of(val)?,
                },
                "partial" => plan.partial_write_rate = rate_of(val)?,
                "malformed" => plan.malformed_rate = rate_of(val)?,
                other => bail!("unknown fault kind '{other}' (panic|slow|partial|malformed)"),
            }
        }
        Ok(plan)
    }

    /// True when at least one site can ever fire.
    pub fn is_active(&self) -> bool {
        self.panic_rate > 0.0
            || self.slow_rate > 0.0
            || self.partial_write_rate > 0.0
            || self.malformed_rate > 0.0
    }

    /// Injector for worker `w` — distinct workers get distinct streams so
    /// the schedule does not depend on which thread wins a batch.
    pub fn injector_for_worker(&self, w: usize) -> FaultInjector {
        FaultInjector::new(*self, 1 + w as u64)
    }

    /// Injector for the (single-threaded) event loop.
    pub fn injector_for_loop(&self) -> FaultInjector {
        FaultInjector::new(*self, 0)
    }
}

/// Per-site fault decision maker: one seeded RNG stream per fault kind,
/// owned by exactly one thread (no locks on any hot path).
pub struct FaultInjector {
    plan: FaultPlan,
    label: u64,
    streams: [Pcg32; 4],
}

impl FaultInjector {
    fn new(plan: FaultPlan, label: u64) -> FaultInjector {
        let stream = |site: Site| Pcg32::new(plan.seed, label * 16 + site as u64);
        FaultInjector {
            plan,
            label,
            streams: [
                stream(Site::Panic),
                stream(Site::Slow),
                stream(Site::PartialWrite),
                stream(Site::Malformed),
            ],
        }
    }

    fn fire(&mut self, site: Site, rate: f32) -> bool {
        if rate <= 0.0 {
            return false;
        }
        let hit = self.streams[site as usize].uniform() < rate;
        if hit {
            crate::telemetry::global().add_fault_injected();
            eprintln!(
                "cwy-fault: {:?} injected (seed {}, stream {})",
                site, self.plan.seed, self.label
            );
        }
        hit
    }

    /// Should the supervised batch execution panic now?  (The caller
    /// panics; the supervisor's `catch_unwind` turns it into
    /// `worker_failed` frames + a requeue + a respawn.)
    pub fn should_panic(&mut self) -> bool {
        self.fire(Site::Panic, self.plan.panic_rate)
    }

    /// Injected execution delay, when the slow fault fires.
    pub fn slow_delay_us(&mut self) -> Option<u64> {
        self.fire(Site::Slow, self.plan.slow_rate).then_some(self.plan.slow_us)
    }

    /// Cap a socket flush to `pending / 2` bytes (min 1) when the
    /// partial-write fault fires; `None` writes normally.  Correctness
    /// must not care — TCP is a stream and the write buffer keeps its
    /// cursor — which is exactly what the chaos suite asserts.
    pub fn partial_write_cap(&mut self, pending: usize) -> Option<usize> {
        if pending < 2 {
            return None;
        }
        self.fire(Site::PartialWrite, self.plan.partial_write_rate)
            .then_some((pending / 2).max(1))
    }

    /// Corrupt an inbound request line when the malformed fault fires.
    /// The corruption prepends junk, so the textual `"id":N` stays
    /// recoverable and the `bad_request` answer keeps its attribution.
    pub fn corrupt_line(&mut self, line: &str) -> Option<String> {
        self.fire(Site::Malformed, self.plan.malformed_rate)
            .then(|| format!("\u{1}garbage{line}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let p = FaultPlan::parse("42:panic=0.1,slow=0.05@2000,partial=0.2,malformed=0.01")
            .unwrap();
        assert_eq!(p.seed, 42);
        assert_eq!(p.panic_rate, 0.1);
        assert_eq!(p.slow_rate, 0.05);
        assert_eq!(p.slow_us, 2_000);
        assert_eq!(p.partial_write_rate, 0.2);
        assert_eq!(p.malformed_rate, 0.01);
        assert!(p.is_active());

        // Slow without an explicit delay keeps the 1ms default.
        let p = FaultPlan::parse("7:slow=0.5").unwrap();
        assert_eq!(p.slow_us, 1_000);
        assert_eq!(p.panic_rate, 0.0);

        assert!(!FaultPlan::parse("3:").unwrap().is_active());
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(FaultPlan::parse("no-seed").is_err());
        assert!(FaultPlan::parse("x:panic=0.1").is_err());
        assert!(FaultPlan::parse("1:panic").is_err());
        assert!(FaultPlan::parse("1:panic=1.5").is_err());
        assert!(FaultPlan::parse("1:panic=-0.1").is_err());
        assert!(FaultPlan::parse("1:explode=0.5").is_err());
        assert!(FaultPlan::parse("1:slow=0.1@abc").is_err());
    }

    #[test]
    fn schedules_are_deterministic_per_seed_and_site() {
        let plan = FaultPlan::parse("42:panic=0.3,slow=0.3").unwrap();
        let schedule = |mut inj: FaultInjector| -> Vec<bool> {
            (0..64).map(|_| inj.should_panic()).collect()
        };
        let a = schedule(plan.injector_for_worker(0));
        let b = schedule(plan.injector_for_worker(0));
        assert_eq!(a, b, "same seed + site must replay identically");
        assert!(a.iter().any(|&x| x), "rate 0.3 over 64 draws should fire");
        assert!(!a.iter().all(|&x| x), "rate 0.3 must not always fire");

        // Distinct workers draw from distinct streams.
        let c = schedule(plan.injector_for_worker(1));
        assert_ne!(a, c);

        // The panic stream is independent of how often slow is consulted.
        let mut mixed = plan.injector_for_worker(0);
        let mut panics = Vec::new();
        for _ in 0..64 {
            let _ = mixed.slow_delay_us();
            panics.push(mixed.should_panic());
        }
        assert_eq!(a, panics, "sites must not share a stream");
    }

    #[test]
    fn rates_are_respected_statistically() {
        let plan = FaultPlan::parse("9:panic=0.25").unwrap();
        let mut inj = plan.injector_for_worker(0);
        let fired = (0..4000).filter(|_| inj.should_panic()).count();
        let rate = fired as f64 / 4000.0;
        assert!((rate - 0.25).abs() < 0.03, "measured rate {rate}");
        // A zero-rate site never fires no matter how often it's asked.
        let mut none = FaultPlan::parse("9:slow=0").unwrap().injector_for_worker(0);
        assert!((0..1000).all(|_| none.slow_delay_us().is_none()));
    }

    #[test]
    fn corrupted_lines_keep_the_id_recoverable() {
        let plan = FaultPlan::parse("4:malformed=1").unwrap();
        let mut inj = plan.injector_for_loop();
        let line = r#"{"type":"infer","id":77,"artifact":"a","inputs":[]}"#;
        let bad = inj.corrupt_line(line).expect("rate 1 must fire");
        assert!(crate::serve::protocol::decode_request(&bad).is_err());
        assert_eq!(crate::serve::protocol::recover_id(&bad), 77);
    }

    #[test]
    fn partial_write_caps_but_never_zeroes() {
        let plan = FaultPlan::parse("4:partial=1").unwrap();
        let mut inj = plan.injector_for_loop();
        assert_eq!(inj.partial_write_cap(100), Some(50));
        assert_eq!(inj.partial_write_cap(3), Some(1));
        // A 1-byte backlog can't be split.
        assert_eq!(inj.partial_write_cap(1), None);
    }
}
