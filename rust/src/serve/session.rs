//! Session store: per-client recurrent state kept server-side between
//! `infer` calls, so streaming models (copying/NMT/video RNNs) consume one
//! token per request without resending their hidden state (DESIGN.md §6.4).
//!
//! Handoff is exclusive: [`SessionStore::take`] removes the state for the
//! duration of the fused execution and the worker [`SessionStore::put`]s
//! the updated state back.  Two in-flight requests on one session
//! therefore never race — the second simply starts from the initial state,
//! which is the documented client contract (serialize your own session).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::runtime::tensor::HostTensor;

#[derive(Clone, Copy, Debug)]
pub struct SessionCfg {
    /// Max live sessions; least-recently-used entries are evicted beyond.
    pub capacity: usize,
    /// Idle time after which a session's state is dropped.
    pub ttl_us: u64,
}

impl Default for SessionCfg {
    fn default() -> SessionCfg {
        SessionCfg { capacity: 4_096, ttl_us: 300_000_000 }
    }
}

struct Entry {
    state: Vec<HostTensor>,
    last_used_us: u64,
}

/// Thread-safe map from session key to stored recurrent state.
pub struct SessionStore {
    cfg: SessionCfg,
    inner: Mutex<HashMap<String, Entry>>,
}

impl SessionStore {
    pub fn new(cfg: SessionCfg) -> SessionStore {
        SessionStore { cfg, inner: Mutex::new(HashMap::new()) }
    }

    /// Remove and return the session's state; `None` if absent or idle
    /// past the TTL (expired state must not leak into a new conversation).
    pub fn take(&self, key: &str, now_us: u64) -> Option<Vec<HostTensor>> {
        let mut m = self.inner.lock().unwrap();
        let entry = m.remove(key)?;
        if now_us.saturating_sub(entry.last_used_us) >= self.cfg.ttl_us {
            return None;
        }
        Some(entry.state)
    }

    /// Store updated state, evicting expired entries first and then the
    /// least-recently-used entry if still at capacity.
    pub fn put(&self, key: &str, state: Vec<HostTensor>, now_us: u64) {
        let mut m = self.inner.lock().unwrap();
        m.retain(|_, e| now_us.saturating_sub(e.last_used_us) < self.cfg.ttl_us);
        if m.len() >= self.cfg.capacity && !m.contains_key(key) {
            if let Some(lru) = m
                .iter()
                .min_by_key(|(_, e)| e.last_used_us)
                .map(|(k, _)| k.clone())
            {
                m.remove(&lru);
            }
        }
        m.insert(key.to_string(), Entry { state, last_used_us: now_us });
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every expired session; returns how many were removed.
    pub fn purge(&self, now_us: u64) -> usize {
        let mut m = self.inner.lock().unwrap();
        let before = m.len();
        m.retain(|_, e| now_us.saturating_sub(e.last_used_us) < self.cfg.ttl_us);
        before - m.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn h(v: f32) -> Vec<HostTensor> {
        vec![HostTensor::f32(vec![2], vec![v, v])]
    }

    fn store(capacity: usize, ttl_us: u64) -> SessionStore {
        SessionStore::new(SessionCfg { capacity, ttl_us })
    }

    #[test]
    fn take_is_exclusive() {
        let s = store(8, 1_000_000);
        s.put("a", h(1.0), 10);
        let got = s.take("a", 20).unwrap();
        assert_eq!(got, h(1.0));
        // Second take sees nothing until the state is put back.
        assert!(s.take("a", 30).is_none());
        s.put("a", h(2.0), 40);
        assert_eq!(s.take("a", 50).unwrap(), h(2.0));
    }

    #[test]
    fn ttl_expires_idle_sessions() {
        let s = store(8, 100);
        s.put("a", h(1.0), 0);
        assert!(s.take("a", 99).is_some());
        s.put("b", h(2.0), 0);
        assert!(s.take("b", 100).is_none());
    }

    #[test]
    fn lru_eviction_at_capacity() {
        let s = store(2, 1_000_000);
        s.put("old", h(1.0), 10);
        s.put("mid", h(2.0), 20);
        s.put("new", h(3.0), 30);
        assert_eq!(s.len(), 2);
        assert!(s.take("old", 40).is_none());
        assert!(s.take("mid", 40).is_some());
        assert!(s.take("new", 40).is_some());
    }

    #[test]
    fn purge_counts_expired() {
        let s = store(8, 100);
        s.put("a", h(1.0), 0);
        s.put("b", h(2.0), 50);
        assert_eq!(s.purge(120), 1);
        assert_eq!(s.len(), 1);
    }
}
