//! Closed-loop load generator for `cwy client` and the serve tests.
//!
//! Two harnesses share the connection/payload plumbing:
//!
//! * [`run_load`] — `concurrency` threads, one connection each, one
//!   request in flight per thread (send, wait, repeat);
//! * [`run_sessions`] — the production-concurrency harness
//!   (`cwy client --closed-loop --sessions N`): N logical sessions
//!   multiplexed over `conns` pipelined connections, each session
//!   keeping exactly one request in flight for `rounds` rounds, with
//!   per-(session, round) accounting that proves the every-request-
//!   answered-exactly-once invariant (zero silent drops, zero dupes).
//!
//! The server's micro-batcher coalesces across connections, so
//! client-side latency plus server-side occupancy together demonstrate
//! the fusing the paper's parametrization makes cheap.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::report::Table;
use crate::runtime::tensor::{Dtype, HostTensor};
use crate::serve::protocol::{self, ErrCode, InferRequest, Request, Response};
use crate::util::json::Json;
use crate::util::rng::Pcg32;

/// Typed errors worth resending: transient server conditions where the
/// request itself is fine (ISSUE 10 taxonomy — DESIGN.md §6.1).
/// `overloaded` clears when the queue drains, `stale_state` after the
/// discarded session restarts fresh, `worker_failed` once the supervisor
/// respawns the panicked worker.
pub fn retriable(code: ErrCode) -> bool {
    matches!(code, ErrCode::Overloaded | ErrCode::StaleState | ErrCode::WorkerFailed)
}

/// Capped exponential backoff with deterministic jitter for retries:
/// 500us base doubling to a 20ms cap, plus up to +25% seeded jitter so
/// synchronized clients don't re-land in one thundering herd.
fn retry_backoff(rng: &mut Pcg32, attempt: u32) -> Duration {
    let base_us = 500u64;
    let cap_us = 20_000u64;
    let us = base_us.saturating_mul(1u64 << attempt.saturating_sub(1).min(10)).min(cap_us);
    let jitter = ((rng.uniform() * 0.25) * us as f32) as u64;
    Duration::from_micros(us + jitter)
}

/// Load-run configuration (`cwy client` flags map 1:1 onto these).
#[derive(Clone, Debug)]
pub struct ClientCfg {
    pub addr: String,
    /// Total requests across all connections.
    pub requests: usize,
    pub concurrency: usize,
    /// Per-request relative deadline budget.
    pub deadline_us: Option<u64>,
    /// Attach a per-connection session key to every request, exercising
    /// the server-side recurrent-state path.
    pub use_sessions: bool,
    /// Resend budget per request for [`retriable`] typed errors; retries
    /// are reported, not counted as failures (ISSUE 10).
    pub max_retries: u32,
}

impl Default for ClientCfg {
    fn default() -> ClientCfg {
        ClientCfg {
            addr: "127.0.0.1:7070".to_string(),
            requests: 1_000,
            concurrency: 32,
            deadline_us: None,
            use_sessions: false,
            max_retries: 3,
        }
    }
}

/// What the server says it serves (decoded `spec` frame).
#[derive(Clone, Debug)]
pub struct SpecInfo {
    pub artifact: String,
    pub batch: usize,
    /// (shape, dtype) per client-supplied input row.
    pub inputs: Vec<(Vec<usize>, Dtype)>,
}

/// Aggregated results of one load run.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub sent: u64,
    pub ok: u64,
    pub err_deadline: u64,
    pub err_overloaded: u64,
    pub err_other: u64,
    /// Resends after retriable typed errors (`overloaded`, `stale_state`,
    /// `worker_failed`) that were absorbed by the retry budget.
    pub retries: u64,
    pub wall_s: f64,
    pub lat_p50_us: u64,
    pub lat_p95_us: u64,
    pub lat_p99_us: u64,
    /// Mean server-side batch occupancy observed in `ok` frames.
    pub mean_batch: f64,
}

impl LoadReport {
    pub fn dropped(&self) -> u64 {
        self.err_overloaded + self.err_other
    }

    pub fn rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.ok as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn to_table(&self) -> Table {
        let mut t = Table::new(&["metric", "value"]);
        let rows: Vec<(&str, String)> = vec![
            ("requests sent", self.sent.to_string()),
            ("ok", self.ok.to_string()),
            ("err deadline", self.err_deadline.to_string()),
            ("err overloaded", self.err_overloaded.to_string()),
            ("err other", self.err_other.to_string()),
            ("retries (recovered)", self.retries.to_string()),
            ("wall (s)", format!("{:.3}", self.wall_s)),
            ("throughput (req/s)", format!("{:.1}", self.rps())),
            ("latency p50 (us)", self.lat_p50_us.to_string()),
            ("latency p95 (us)", self.lat_p95_us.to_string()),
            ("latency p99 (us)", self.lat_p99_us.to_string()),
            ("mean server batch", format!("{:.2}", self.mean_batch)),
        ];
        for (k, v) in rows {
            t.row(&[k.to_string(), v]);
        }
        t
    }
}

struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Conn {
    fn open(addr: &str) -> Result<Conn> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        let _ = stream.set_nodelay(true);
        let writer = stream.try_clone().context("cloning stream")?;
        Ok(Conn { reader: BufReader::new(stream), writer })
    }

    fn send(&mut self, req: &Request) -> Result<()> {
        let line = protocol::encode_request(req);
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Response> {
        let mut line = String::new();
        loop {
            line.clear();
            let n = self.reader.read_line(&mut line)?;
            if n == 0 {
                bail!("server closed the connection");
            }
            if !line.trim().is_empty() {
                return protocol::decode_response(&line);
            }
        }
    }

    fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        self.reader.get_ref().set_read_timeout(d)
    }
}

/// Ask a server what it serves.
pub fn fetch_spec(addr: &str) -> Result<SpecInfo> {
    let mut conn = Conn::open(addr)?;
    conn.send(&Request::Spec)?;
    match conn.recv()? {
        Response::Spec(j) => spec_from_json(&j),
        other => bail!("expected spec frame, got {other:?}"),
    }
}

fn spec_from_json(j: &Json) -> Result<SpecInfo> {
    let artifact = j
        .path(&["artifact"])
        .as_str()
        .ok_or_else(|| anyhow!("spec missing artifact"))?
        .to_string();
    let batch = j
        .path(&["batch"])
        .as_usize()
        .ok_or_else(|| anyhow!("spec missing batch"))?;
    let mut inputs = Vec::new();
    for p in j
        .path(&["inputs"])
        .as_arr()
        .ok_or_else(|| anyhow!("spec missing inputs"))?
    {
        let shape: Vec<usize> = p
            .path(&["shape"])
            .as_arr()
            .ok_or_else(|| anyhow!("spec input missing shape"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
            .collect::<Result<_>>()?;
        let dtype = Dtype::parse(p.path(&["dtype"]).as_str().unwrap_or("f32"))?;
        inputs.push((shape, dtype));
    }
    Ok(SpecInfo { artifact, batch, inputs })
}

/// Deterministic payload row for input `i` of request `n`.
fn payload(spec: &SpecInfo, n: u64) -> Vec<HostTensor> {
    spec.inputs
        .iter()
        .map(|(shape, dtype)| {
            let len: usize = shape.iter().product();
            match dtype {
                Dtype::F32 => HostTensor::f32(
                    shape.clone(),
                    (0..len).map(|j| ((n as usize + j) % 13) as f32 * 0.125).collect(),
                ),
                Dtype::I32 => HostTensor::i32(
                    shape.clone(),
                    (0..len).map(|j| ((n as usize + j) % 7) as i32).collect(),
                ),
            }
        })
        .collect()
}

fn exact_percentile(sorted_us: &[u64], p: f64) -> u64 {
    if sorted_us.is_empty() {
        return 0;
    }
    let rank = ((p * sorted_us.len() as f64).ceil() as usize).clamp(1, sorted_us.len());
    sorted_us[rank - 1]
}

struct ThreadOutcome {
    ok: u64,
    err_deadline: u64,
    err_overloaded: u64,
    err_other: u64,
    retries: u64,
    latencies_us: Vec<u64>,
    batch_sum: u64,
}

fn run_thread(
    cfg: &ClientCfg,
    spec: &SpecInfo,
    thread_idx: usize,
    count: usize,
) -> ThreadOutcome {
    let mut out = ThreadOutcome {
        ok: 0,
        err_deadline: 0,
        err_overloaded: 0,
        err_other: 0,
        retries: 0,
        latencies_us: Vec::with_capacity(count),
        batch_sum: 0,
    };
    let mut conn = match Conn::open(&cfg.addr) {
        Ok(c) => c,
        Err(_) => {
            out.err_other += count as u64;
            return out;
        }
    };
    let session = cfg.use_sessions.then(|| format!("load-{thread_idx}"));
    let mut rng = Pcg32::new(0xC11E_4700 + thread_idx as u64, 1);
    'requests: for i in 0..count {
        let id = ((thread_idx as u64) << 32) | i as u64;
        let req = Request::Infer(InferRequest {
            id,
            artifact: spec.artifact.clone(),
            session: session.clone(),
            deadline_us: cfg.deadline_us,
            inputs: payload(spec, id),
        });
        let t0 = Instant::now();
        if conn.send(&req).is_err() {
            out.err_other += (count - i) as u64;
            break;
        }
        let mut attempt = 0u32;
        loop {
            match conn.recv() {
                Ok(Response::Ok { id: rid, batch, .. }) => {
                    out.latencies_us.push(t0.elapsed().as_micros() as u64);
                    if rid == id {
                        out.ok += 1;
                        out.batch_sum += batch as u64;
                    } else {
                        out.err_other += 1;
                    }
                    break;
                }
                // Transient typed errors resend the same request after a
                // capped, jittered backoff; only budget exhaustion turns
                // them into a counted failure.
                Ok(Response::Err { code, .. })
                    if retriable(code) && attempt < cfg.max_retries =>
                {
                    attempt += 1;
                    out.retries += 1;
                    thread::sleep(retry_backoff(&mut rng, attempt));
                    if conn.send(&req).is_err() {
                        out.err_other += (count - i) as u64;
                        break 'requests;
                    }
                }
                Ok(Response::Err { code, .. }) => {
                    match code {
                        ErrCode::Deadline => out.err_deadline += 1,
                        ErrCode::Overloaded => out.err_overloaded += 1,
                        _ => out.err_other += 1,
                    }
                    break;
                }
                Ok(_) => {
                    out.err_other += 1;
                    break;
                }
                Err(_) => {
                    out.err_other += (count - i) as u64;
                    break 'requests;
                }
            }
        }
    }
    out
}

/// Run a closed-loop load test; returns aggregate counters + latency
/// percentiles.  Never fails on per-request errors — those are counted.
pub fn run_load(cfg: &ClientCfg) -> Result<LoadReport> {
    let spec = fetch_spec(&cfg.addr)?;
    let threads = cfg.concurrency.max(1);
    let base = cfg.requests / threads;
    let extra = cfg.requests % threads;

    let t0 = Instant::now();
    let outcomes: Vec<ThreadOutcome> = thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for w in 0..threads {
            let cfg = &*cfg;
            let spec = &spec;
            let count = base + usize::from(w < extra);
            handles.push(s.spawn(move || run_thread(cfg, spec, w, count)));
        }
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let mut report = LoadReport { sent: cfg.requests as u64, wall_s, ..Default::default() };
    let mut all_lat: Vec<u64> = Vec::with_capacity(cfg.requests);
    let mut batch_sum = 0u64;
    for o in outcomes {
        report.ok += o.ok;
        report.err_deadline += o.err_deadline;
        report.err_overloaded += o.err_overloaded;
        report.err_other += o.err_other;
        report.retries += o.retries;
        batch_sum += o.batch_sum;
        all_lat.extend(o.latencies_us);
    }
    all_lat.sort_unstable();
    report.lat_p50_us = exact_percentile(&all_lat, 0.50);
    report.lat_p95_us = exact_percentile(&all_lat, 0.95);
    report.lat_p99_us = exact_percentile(&all_lat, 0.99);
    report.mean_batch = if report.ok > 0 {
        batch_sum as f64 / report.ok as f64
    } else {
        0.0
    };
    Ok(report)
}

/// Closed-loop session-harness configuration
/// (`cwy client --closed-loop` flags map 1:1 onto these).
#[derive(Clone, Debug)]
pub struct SessionLoadCfg {
    pub addr: String,
    /// Concurrent logical sessions, each serially issuing `rounds`
    /// requests (one in flight per session at all times).
    pub sessions: usize,
    pub rounds: usize,
    /// TCP connections the sessions are multiplexed over (pipelined).
    pub conns: usize,
    pub deadline_us: Option<u64>,
    /// Attach a per-session key to every request, exercising the
    /// server-side recurrent-state path at full concurrency.
    pub use_sessions: bool,
    /// Resend budget per request for [`retriable`] typed errors
    /// (refreshed each round); recovered retries are reported, never
    /// counted as failures.
    pub max_retries: u32,
}

impl Default for SessionLoadCfg {
    fn default() -> SessionLoadCfg {
        SessionLoadCfg {
            addr: "127.0.0.1:7070".to_string(),
            sessions: 1_000,
            rounds: 3,
            conns: 64,
            deadline_us: None,
            use_sessions: true,
            max_retries: 3,
        }
    }
}

/// Request id for (session, round): session+1 in the high bits so id 0 —
/// the protocol's "unattributable" fallback — never collides with a real
/// request, and the answer decodes back to its exact (session, round).
pub fn session_request_id(sess: usize, round: usize) -> u64 {
    (((sess + 1) as u64) << 16) | round as u64
}

fn split_session_id(id: u64) -> Option<(usize, usize)> {
    let sess = (id >> 16) as usize;
    if sess == 0 {
        return None;
    }
    Some((sess - 1, (id & 0xffff) as usize))
}

/// Aggregated results of one closed-loop session run.  The acceptance
/// invariant is [`SessionLoadReport::complete`]: every submitted request
/// answered exactly once — ok, deadline, overloaded, or unavailable all
/// count as answers; silent drops, duplicates, and unattributable frames
/// all fail it.
#[derive(Clone, Debug, Default)]
pub struct SessionLoadReport {
    pub sessions: u64,
    pub rounds: u64,
    pub sent: u64,
    pub ok: u64,
    pub err_deadline: u64,
    pub err_overloaded: u64,
    pub err_unavailable: u64,
    pub err_other: u64,
    /// Requests sent but never answered before the harness timed out.
    pub unanswered: u64,
    /// Extra answers for a (session, round) already answered.
    pub duplicates: u64,
    /// Frames whose id maps to no in-flight (session, round).
    pub stray: u64,
    /// Connections that failed to open (their sessions never sent).
    pub conn_failures: u64,
    /// Resends of [`retriable`] typed errors that stayed within budget
    /// (each retried request still resolves to exactly one final answer).
    pub retries: u64,
    pub wall_s: f64,
    pub lat_p50_us: u64,
    pub lat_p95_us: u64,
    pub lat_p99_us: u64,
    /// Mean server-side batch occupancy observed in `ok` frames.
    pub mean_batch: f64,
}

impl SessionLoadReport {
    pub fn answered(&self) -> u64 {
        self.ok + self.err_deadline + self.err_overloaded + self.err_unavailable + self.err_other
    }

    /// Every sent request answered exactly once, nothing unattributable.
    pub fn exactly_once(&self) -> bool {
        self.unanswered == 0
            && self.duplicates == 0
            && self.stray == 0
            && self.answered() == self.sent
    }

    /// [`exactly_once`](Self::exactly_once) *and* the full schedule went
    /// out: `sessions * rounds` requests sent on healthy connections.
    pub fn complete(&self) -> bool {
        self.exactly_once()
            && self.conn_failures == 0
            && self.sent == self.sessions * self.rounds
    }

    pub fn rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.answered() as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn to_table(&self) -> Table {
        let mut t = Table::new(&["metric", "value"]);
        let rows: Vec<(&str, String)> = vec![
            ("sessions", self.sessions.to_string()),
            ("rounds per session", self.rounds.to_string()),
            ("requests sent", self.sent.to_string()),
            ("ok", self.ok.to_string()),
            ("err deadline", self.err_deadline.to_string()),
            ("err overloaded", self.err_overloaded.to_string()),
            ("err unavailable", self.err_unavailable.to_string()),
            ("err other", self.err_other.to_string()),
            ("unanswered", self.unanswered.to_string()),
            ("duplicates", self.duplicates.to_string()),
            ("stray frames", self.stray.to_string()),
            ("conn failures", self.conn_failures.to_string()),
            ("retries (recovered)", self.retries.to_string()),
            ("wall (s)", format!("{:.3}", self.wall_s)),
            ("throughput (req/s)", format!("{:.1}", self.rps())),
            ("latency p50 (us)", self.lat_p50_us.to_string()),
            ("latency p95 (us)", self.lat_p95_us.to_string()),
            ("latency p99 (us)", self.lat_p99_us.to_string()),
            ("mean server batch", format!("{:.2}", self.mean_batch)),
            ("answered exactly once", self.exactly_once().to_string()),
        ];
        for (k, v) in rows {
            t.row(&[k.to_string(), v]);
        }
        t
    }
}

#[derive(Default)]
struct SessionOutcome {
    sent: u64,
    ok: u64,
    err_deadline: u64,
    err_overloaded: u64,
    err_unavailable: u64,
    err_other: u64,
    unanswered: u64,
    duplicates: u64,
    stray: u64,
    conn_failed: bool,
    retries: u64,
    latencies_us: Vec<u64>,
    batch_sum: u64,
    batch_n: u64,
}

fn session_infer(cfg: &SessionLoadCfg, spec: &SpecInfo, sess: usize, round: usize) -> Request {
    let id = session_request_id(sess, round);
    Request::Infer(InferRequest {
        id,
        artifact: spec.artifact.clone(),
        session: cfg.use_sessions.then(|| format!("cl-{sess}")),
        deadline_us: cfg.deadline_us,
        inputs: payload(spec, id),
    })
}

/// One connection's worth of sessions: fire round 0 for every owned
/// session (pipelined), then advance each session to its next round as
/// its answer arrives — the closed loop.
fn run_session_thread(
    cfg: &SessionLoadCfg,
    spec: &SpecInfo,
    thread_idx: usize,
) -> SessionOutcome {
    let mut out = SessionOutcome::default();
    let conns = cfg.conns.max(1);
    let rounds = cfg.rounds.max(1);
    let owned: Vec<usize> = (0..cfg.sessions).filter(|s| s % conns == thread_idx).collect();
    if owned.is_empty() {
        return out;
    }
    let mut conn = match Conn::open(&cfg.addr) {
        Ok(c) => c,
        Err(_) => {
            out.conn_failed = true;
            return out;
        }
    };
    let _ = conn.set_read_timeout(Some(Duration::from_secs(30)));

    let n = owned.len();
    let local_of: HashMap<usize, usize> =
        owned.iter().enumerate().map(|(l, &s)| (s, l)).collect();
    // answers[local][round]: how many frames answered that request.
    let mut answers: Vec<Vec<u8>> = vec![vec![0u8; rounds]; n];
    let mut sent_rounds: Vec<usize> = vec![0; n];
    let mut send_at: Vec<Instant> = vec![Instant::now(); n];
    // Per-session resend budget for retriable errors, refreshed each round.
    let mut retries_left: Vec<u32> = vec![cfg.max_retries; n];
    let mut rng = Pcg32::new(0x5E55_1400 + thread_idx as u64, 1);
    let mut in_flight = 0usize;

    for local in 0..n {
        let req = session_infer(cfg, spec, owned[local], 0);
        send_at[local] = Instant::now();
        if conn.send(&req).is_err() {
            break;
        }
        out.sent += 1;
        sent_rounds[local] = 1;
        in_flight += 1;
    }

    while in_flight > 0 {
        let resp = match conn.recv() {
            Ok(r) => r,
            Err(_) => break, // timeout or closed: the rest is unanswered
        };
        let Some((sess, round)) = resp.id().and_then(split_session_id) else {
            out.stray += 1;
            continue;
        };
        let Some(&local) = local_of.get(&sess) else {
            out.stray += 1;
            continue;
        };
        if round >= sent_rounds[local] {
            // An answer for a round this session never sent.
            out.stray += 1;
            continue;
        }
        answers[local][round] += 1;
        if answers[local][round] > 1 {
            out.duplicates += 1;
            continue;
        }
        // Retriable typed errors resend the *same* (session, round) id
        // with backoff, so the request still resolves exactly once:
        // `sent`/`in_flight` are untouched and the answer slot is
        // reopened for the resend's reply.
        if let Response::Err { code, .. } = &resp {
            if retriable(*code) && retries_left[local] > 0 {
                retries_left[local] -= 1;
                out.retries += 1;
                answers[local][round] = 0;
                let attempt = cfg.max_retries - retries_left[local];
                thread::sleep(retry_backoff(&mut rng, attempt));
                let req = session_infer(cfg, spec, owned[local], round);
                send_at[local] = Instant::now();
                if conn.send(&req).is_err() {
                    break;
                }
                continue;
            }
        }
        in_flight -= 1;
        out.latencies_us.push(send_at[local].elapsed().as_micros() as u64);
        match &resp {
            Response::Ok { batch, .. } => {
                out.ok += 1;
                out.batch_sum += *batch as u64;
                out.batch_n += 1;
            }
            Response::Err { code, .. } => match code {
                ErrCode::Deadline => out.err_deadline += 1,
                ErrCode::Overloaded => out.err_overloaded += 1,
                ErrCode::Unavailable => out.err_unavailable += 1,
                _ => out.err_other += 1,
            },
            _ => out.err_other += 1,
        }
        // Closed loop: any answer (ok or typed shed) advances the session.
        if sent_rounds[local] < rounds {
            let next = sent_rounds[local];
            let req = session_infer(cfg, spec, owned[local], next);
            send_at[local] = Instant::now();
            if conn.send(&req).is_err() {
                break;
            }
            out.sent += 1;
            sent_rounds[local] = next + 1;
            retries_left[local] = cfg.max_retries;
            in_flight += 1;
        }
    }
    out.unanswered += in_flight as u64;
    out
}

/// Run the closed-loop session harness: `cfg.sessions` logical sessions
/// over `cfg.conns` pipelined connections, each issuing `cfg.rounds`
/// serial requests.  Per-request errors are counted, never fatal; the
/// caller checks [`SessionLoadReport::complete`] for the zero-silent-
/// drops invariant.
pub fn run_sessions(cfg: &SessionLoadCfg) -> Result<SessionLoadReport> {
    let spec = fetch_spec(&cfg.addr)?;
    let threads = cfg.conns.max(1);

    let t0 = Instant::now();
    let outcomes: Vec<SessionOutcome> = thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for w in 0..threads {
            let cfg = &*cfg;
            let spec = &spec;
            handles.push(s.spawn(move || run_session_thread(cfg, spec, w)));
        }
        handles.into_iter().map(|h| h.join().expect("session thread")).collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let mut report = SessionLoadReport {
        sessions: cfg.sessions as u64,
        rounds: cfg.rounds.max(1) as u64,
        wall_s,
        ..Default::default()
    };
    let mut all_lat: Vec<u64> = Vec::with_capacity(cfg.sessions * cfg.rounds.max(1));
    let mut batch_sum = 0u64;
    let mut batch_n = 0u64;
    for o in outcomes {
        report.sent += o.sent;
        report.ok += o.ok;
        report.err_deadline += o.err_deadline;
        report.err_overloaded += o.err_overloaded;
        report.err_unavailable += o.err_unavailable;
        report.err_other += o.err_other;
        report.unanswered += o.unanswered;
        report.duplicates += o.duplicates;
        report.stray += o.stray;
        report.conn_failures += u64::from(o.conn_failed);
        report.retries += o.retries;
        batch_sum += o.batch_sum;
        batch_n += o.batch_n;
        all_lat.extend(o.latencies_us);
    }
    all_lat.sort_unstable();
    report.lat_p50_us = exact_percentile(&all_lat, 0.50);
    report.lat_p95_us = exact_percentile(&all_lat, 0.95);
    report.lat_p99_us = exact_percentile(&all_lat, 0.99);
    report.mean_batch = if batch_n > 0 { batch_sum as f64 / batch_n as f64 } else { 0.0 };
    Ok(report)
}

/// One ping round-trip; returns the measured latency.
pub fn ping(addr: &str) -> Result<f64> {
    let mut conn = Conn::open(addr)?;
    let t0 = Instant::now();
    conn.send(&Request::Ping { id: 1 })?;
    match conn.recv()? {
        Response::Pong { id: 1 } => Ok(t0.elapsed().as_secs_f64()),
        other => bail!("expected pong, got {other:?}"),
    }
}

/// Fetch a server-side stats snapshot as JSON.
pub fn fetch_stats(addr: &str) -> Result<Json> {
    let mut conn = Conn::open(addr)?;
    conn.send(&Request::Stats)?;
    match conn.recv()? {
        Response::Stats(j) => Ok(j),
        other => bail!("expected stats frame, got {other:?}"),
    }
}

/// Fetch the combined serve + telemetry metrics frame as JSON.
pub fn fetch_metrics(addr: &str) -> Result<Json> {
    let mut conn = Conn::open(addr)?;
    conn.send(&Request::Metrics)?;
    match conn.recv()? {
        Response::Metrics(j) => Ok(j),
        other => bail!("expected metrics frame, got {other:?}"),
    }
}

/// Final per-run latency table from the server's `metrics` frame — the
/// server-side truth (`cwy client`'s ad-hoc client-side timers remain in
/// [`LoadReport`] for the transport view, but this is what the run
/// reports).  Covers end-to-end latency percentiles, shed/reject counts,
/// occupancy, and the per-phase serve pipeline percentiles.
pub fn metrics_table(metrics: &Json) -> Table {
    let g = |keys: &[&str]| -> String {
        metrics
            .path(keys)
            .as_f64()
            .map(|x| {
                if x.fract() == 0.0 {
                    format!("{}", x as i64)
                } else {
                    format!("{x:.1}")
                }
            })
            .unwrap_or_else(|| "-".to_string())
    };
    // The dispatched GEMM microkernel is a string gauge, not a number —
    // read it directly rather than through the numeric formatter.
    let kernel = match metrics.path(&["telemetry", "gauges", "kernel"]) {
        Json::Str(s) => s.clone(),
        _ => "-".to_string(),
    };
    let mut t = Table::new(&["metric", "value"]);
    let rows: Vec<(&str, String)> = vec![
        ("gemm kernel", kernel),
        ("requests completed", g(&["serve", "completed"])),
        ("latency p50 (us)", g(&["serve", "latency_p50_us"])),
        ("latency p95 (us)", g(&["serve", "latency_p95_us"])),
        ("latency p99 (us)", g(&["serve", "latency_p99_us"])),
        ("latency p999 (us)", g(&["serve", "latency_p999_us"])),
        ("latency mean (us)", g(&["serve", "latency_mean_us"])),
        ("shed (deadline)", g(&["serve", "shed_deadline"])),
        ("rejected (queue full)", g(&["serve", "rejected_full"])),
        ("mean batch occupancy", g(&["serve", "mean_occupancy"])),
        ("max batch occupancy", g(&["serve", "max_occupancy"])),
        (
            "queue wait p50/p99 (us)",
            format!(
                "{} / {}",
                g(&["telemetry", "phases", "queue_wait_us", "p50"]),
                g(&["telemetry", "phases", "queue_wait_us", "p99"]),
            ),
        ),
        (
            "batch assemble p50/p99 (us)",
            format!(
                "{} / {}",
                g(&["telemetry", "phases", "batch_assemble_us", "p50"]),
                g(&["telemetry", "phases", "batch_assemble_us", "p99"]),
            ),
        ),
        (
            "execute p50/p99 (us)",
            format!(
                "{} / {}",
                g(&["telemetry", "phases", "execute_us", "p50"]),
                g(&["telemetry", "phases", "execute_us", "p99"]),
            ),
        ),
        (
            "write back p50/p99 (us)",
            format!(
                "{} / {}",
                g(&["telemetry", "phases", "write_back_us", "p50"]),
                g(&["telemetry", "phases", "write_back_us", "p99"]),
            ),
        ),
        // Persistent-pool + operand-cache health (ISSUE 9).
        ("pool workers", g(&["telemetry", "gauges", "pool_workers"])),
        (
            "pool tasks / steals",
            format!(
                "{} / {}",
                g(&["telemetry", "gauges", "pool_tasks"]),
                g(&["telemetry", "gauges", "pool_steals"]),
            ),
        ),
        ("pool queue depth", g(&["telemetry", "gauges", "pool_queue_depth"])),
        (
            "pool park p50/p99 (us)",
            format!(
                "{} / {}",
                g(&["telemetry", "phases", "pool_park_us", "p50"]),
                g(&["telemetry", "phases", "pool_park_us", "p99"]),
            ),
        ),
        (
            "pack cache hits / misses",
            format!(
                "{} / {}",
                g(&["telemetry", "gauges", "pack_hits"]),
                g(&["telemetry", "gauges", "pack_misses"]),
            ),
        ),
        // Supervision + chaos health (ISSUE 10).
        ("worker restarts", g(&["telemetry", "gauges", "worker_restarts"])),
        ("batches requeued", g(&["telemetry", "gauges", "batches_requeued"])),
        ("faults injected", g(&["telemetry", "gauges", "faults_injected"])),
    ];
    for (k, v) in rows {
        t.row(&[k.to_string(), v]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_policy_covers_transient_codes_only() {
        assert!(retriable(ErrCode::Overloaded));
        assert!(retriable(ErrCode::StaleState));
        assert!(retriable(ErrCode::WorkerFailed));
        assert!(!retriable(ErrCode::Deadline));
        assert!(!retriable(ErrCode::BadRequest));
        assert!(!retriable(ErrCode::Exec));
        assert!(!retriable(ErrCode::Unavailable));
    }

    #[test]
    fn retry_backoff_doubles_caps_and_jitters_deterministically() {
        let mut rng = Pcg32::new(1, 1);
        let base = retry_backoff(&mut rng, 1);
        assert!(base >= Duration::from_micros(500));
        assert!(base < Duration::from_micros(625 + 1), "jitter tops out at +25%");
        // Far past the doubling range: capped at 20ms (+25% jitter).
        let capped = retry_backoff(&mut rng, 30);
        assert!(capped >= Duration::from_micros(20_000));
        assert!(capped <= Duration::from_micros(25_000));
        // Same seed, same sequence.
        let mut a = Pcg32::new(9, 1);
        let mut b = Pcg32::new(9, 1);
        for attempt in 1..6 {
            assert_eq!(retry_backoff(&mut a, attempt), retry_backoff(&mut b, attempt));
        }
    }

    #[test]
    fn percentile_is_exact_on_small_sets() {
        let v = vec![10, 20, 30, 40];
        assert_eq!(exact_percentile(&v, 0.50), 20);
        assert_eq!(exact_percentile(&v, 0.95), 40);
        assert_eq!(exact_percentile(&[], 0.5), 0);
    }

    #[test]
    fn payload_matches_spec_shapes() {
        let spec = SpecInfo {
            artifact: "a".into(),
            batch: 4,
            inputs: vec![(vec![3], Dtype::F32), (vec![2, 2], Dtype::I32)],
        };
        let p = payload(&spec, 5);
        assert_eq!(p.len(), 2);
        assert_eq!(p[0].shape, vec![3]);
        assert_eq!(p[1].shape, vec![2, 2]);
        assert_eq!(p[1].dtype(), Dtype::I32);
    }

    #[test]
    fn report_renders() {
        let r = LoadReport { sent: 10, ok: 10, wall_s: 1.0, ..Default::default() };
        assert_eq!(r.dropped(), 0);
        assert!(r.to_table().to_markdown().contains("requests sent"));
    }

    #[test]
    fn metrics_table_renders_from_frame_json() {
        let frame = crate::util::json::parse(
            r#"{"serve":{"completed":12,"latency_p50_us":100,"latency_p95_us":200,
                 "latency_p99_us":300,"latency_p999_us":400,"latency_mean_us":123.4,
                 "shed_deadline":1,"rejected_full":0,"mean_occupancy":3.5,
                 "max_occupancy":4},
                "telemetry":{"gauges":{"kernel":"avx2fma","pool_workers":3,
                 "pool_tasks":640,"pool_steals":412,"pool_queue_depth":0,
                 "pack_hits":960,"pack_misses":4,
                 "worker_restarts":2,"batches_requeued":1,"faults_injected":9},
                 "phases":{"queue_wait_us":{"p50":10,"p99":20},
                 "batch_assemble_us":{"p50":1,"p99":2},
                 "execute_us":{"p50":500,"p99":900},
                 "write_back_us":{"p50":5,"p99":9},
                 "pool_park_us":{"p50":40,"p99":80}}}}"#,
        )
        .unwrap();
        let md = metrics_table(&frame).to_markdown();
        assert!(md.contains("latency p999 (us)"));
        assert!(md.contains("123.4"));
        assert!(md.contains("500 / 900"));
        assert!(md.contains("gemm kernel"));
        assert!(md.contains("avx2fma"));
        assert!(md.contains("pool workers"));
        assert!(md.contains("640 / 412"));
        assert!(md.contains("40 / 80"));
        assert!(md.contains("960 / 4"));
        assert!(md.contains("worker restarts"));
        assert!(md.contains("batches requeued"));
        assert!(md.contains("faults injected"));
        // Missing keys degrade to "-", not panics.
        let empty = metrics_table(&Json::Obj(Default::default())).to_markdown();
        assert!(empty.contains('-'));
    }

    #[test]
    fn session_ids_roundtrip_and_never_collide_with_zero() {
        for sess in [0usize, 1, 41, 9_999, 65_000] {
            for round in [0usize, 1, 2, 100] {
                let id = session_request_id(sess, round);
                assert_ne!(id, 0, "id 0 is the unattributable fallback");
                assert_eq!(split_session_id(id), Some((sess, round)));
            }
        }
        // id 0 and low raw ids (round-only bits) decode to no session.
        assert_eq!(split_session_id(0), None);
        assert_eq!(split_session_id(7), None);
    }

    #[test]
    fn session_report_invariants() {
        let mut r = SessionLoadReport {
            sessions: 4,
            rounds: 3,
            sent: 12,
            ok: 10,
            err_deadline: 1,
            err_overloaded: 1,
            wall_s: 2.0,
            ..Default::default()
        };
        assert_eq!(r.answered(), 12);
        assert!(r.exactly_once());
        assert!(r.complete());
        assert!((r.rps() - 6.0).abs() < 1e-9);
        let md = r.to_table().to_markdown();
        assert!(md.contains("answered exactly once"));
        assert!(md.contains("conn failures"));

        // One silent drop breaks the invariant.
        r.unanswered = 1;
        assert!(!r.exactly_once());
        r.unanswered = 0;
        // A duplicate answer breaks it even with counts balanced.
        r.duplicates = 1;
        assert!(!r.exactly_once());
        r.duplicates = 0;
        // A failed connection means the schedule never fully went out.
        r.conn_failures = 1;
        assert!(r.exactly_once() && !r.complete());
    }
}
