//! Minimal `poll(2)` shim for the serve event loop.
//!
//! The workspace vendors no `libc`/`mio`, so the one syscall the
//! readiness loop needs is declared directly: `poll` is in POSIX and on
//! every target this crate builds for.  Only the constants the loop
//! actually uses are defined, and `EINTR` is retried here so callers
//! never see a spurious error from a signal.

use std::ffi::c_int;
use std::io;

/// Readable readiness (requested and returned).
pub const POLLIN: i16 = 0x001;
/// Writable readiness (requested and returned).
pub const POLLOUT: i16 = 0x004;
/// Error condition (returned only).
pub const POLLERR: i16 = 0x008;
/// Peer hung up (returned only).
pub const POLLHUP: i16 = 0x010;
/// Fd not open (returned only) — a loop bookkeeping bug if ever seen.
pub const POLLNVAL: i16 = 0x020;

/// Mirrors `struct pollfd`.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PollFd {
    pub fd: i32,
    pub events: i16,
    pub revents: i16,
}

impl PollFd {
    pub fn new(fd: i32, events: i16) -> PollFd {
        PollFd { fd, events, revents: 0 }
    }

    pub fn readable(&self) -> bool {
        self.revents & (POLLIN | POLLHUP) != 0
    }

    pub fn writable(&self) -> bool {
        self.revents & POLLOUT != 0
    }

    pub fn error(&self) -> bool {
        self.revents & (POLLERR | POLLNVAL) != 0
    }
}

#[cfg(target_os = "linux")]
type NFds = std::ffi::c_ulong;
#[cfg(not(target_os = "linux"))]
type NFds = std::ffi::c_uint;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NFds, timeout: c_int) -> c_int;
}

/// Wait for readiness on `fds` for at most `timeout_ms` (-1 = forever).
/// Returns the number of fds with nonzero `revents`; 0 on timeout.
pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    loop {
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NFds, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() == io::ErrorKind::Interrupted {
            continue;
        }
        return Err(err);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::os::fd::AsRawFd;
    use std::os::unix::net::UnixStream;

    #[test]
    fn poll_reports_readability_and_timeout() {
        let (mut a, b) = UnixStream::pair().unwrap();
        let mut fds = [PollFd::new(b.as_raw_fd(), POLLIN)];
        // Nothing written yet: a zero-timeout poll returns no readiness.
        assert_eq!(poll_fds(&mut fds, 0).unwrap(), 0);
        assert!(!fds[0].readable());
        a.write_all(&[9u8]).unwrap();
        let n = poll_fds(&mut fds, 1_000).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].readable());
        assert!(!fds[0].error());
    }

    #[test]
    fn poll_reports_hangup() {
        let (a, b) = UnixStream::pair().unwrap();
        drop(a);
        let mut fds = [PollFd::new(b.as_raw_fd(), POLLIN)];
        assert_eq!(poll_fds(&mut fds, 1_000).unwrap(), 1);
        // EOF surfaces as POLLIN and/or POLLHUP depending on platform;
        // both route through readable() so the loop reads the EOF.
        assert!(fds[0].readable());
    }
}
