//! Completion plumbing between worker threads and the serve event loop.
//!
//! Workers finish requests on their own threads; the event loop owns
//! every socket.  The [`CompletionHub`] is the hand-off point: workers
//! push `(conn, frame)` pairs and ring the [`Waker`], the loop wakes
//! from `poll`, drains the queue, and serializes each frame onto the
//! owning connection's write buffer (DESIGN.md §6.6).
//!
//! The waker is one byte down a nonblocking `UnixStream` pair — the
//! self-pipe trick, with no dependency beyond std.  A full pipe means a
//! wakeup is already in flight, so `WouldBlock` is success.

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::os::unix::net::UnixStream;
use std::sync::{Arc, Mutex};

use crate::serve::lock_recover;
use crate::serve::protocol::Response;

/// Cloneable handle that interrupts the event loop's `poll` sleep.
#[derive(Clone)]
pub struct Waker {
    stream: Arc<UnixStream>,
}

impl Waker {
    /// Ring the event loop.  Never blocks; a saturated pipe or a closed
    /// peer (loop already exiting) are both fine to ignore.
    pub fn wake(&self) {
        let _ = (&*self.stream).write(&[1u8]);
    }
}

/// Build the waker and the read half the event loop polls on.
pub fn wake_pair() -> io::Result<(Waker, UnixStream)> {
    let (tx, rx) = UnixStream::pair()?;
    tx.set_nonblocking(true)?;
    rx.set_nonblocking(true)?;
    Ok((Waker { stream: Arc::new(tx) }, rx))
}

/// Drain every queued wakeup byte so the next `poll` sleeps again.
pub fn drain_wakeups(rx: &UnixStream) {
    let mut buf = [0u8; 64];
    loop {
        match (&*rx).read(&mut buf) {
            Ok(0) => break,
            Ok(_) => continue,
            Err(_) => break,
        }
    }
}

/// MPSC queue of finished response frames, keyed by connection id.
pub struct CompletionHub {
    queue: Mutex<VecDeque<(u64, Response)>>,
    waker: Waker,
}

impl CompletionHub {
    pub fn new(waker: Waker) -> CompletionHub {
        CompletionHub { queue: Mutex::new(VecDeque::new()), waker }
    }

    /// Queue one frame for `conn` and ring the loop.  Recovers from a
    /// poisoned queue: the hub is the only road completions travel, so
    /// it must outlive any panicking producer.
    pub fn push(&self, conn: u64, resp: Response) {
        lock_recover(&self.queue).push_back((conn, resp));
        self.waker.wake();
    }

    /// Take everything queued so far (event-loop side).
    pub fn drain(&self) -> VecDeque<(u64, Response)> {
        std::mem::take(&mut *lock_recover(&self.queue))
    }

    pub fn is_empty(&self) -> bool {
        lock_recover(&self.queue).is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::protocol::ErrCode;

    #[test]
    fn hub_routes_frames_by_connection() {
        let (waker, rx) = wake_pair().unwrap();
        let hub = CompletionHub::new(waker);
        assert!(hub.is_empty());
        hub.push(3, Response::Pong { id: 1 });
        hub.push(
            7,
            Response::Err { id: 2, code: ErrCode::Overloaded, msg: "q".to_string() },
        );
        let drained = hub.drain();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0].0, 3);
        assert_eq!(drained[1].0, 7);
        assert!(hub.is_empty());
        // Both pushes rang the waker; draining leaves the pipe empty.
        drain_wakeups(&rx);
        let mut buf = [0u8; 8];
        assert!((&rx).read(&mut buf).is_err(), "pipe should be drained");
    }

    #[test]
    fn waker_tolerates_saturation_and_closed_peer() {
        let (waker, rx) = wake_pair().unwrap();
        for _ in 0..100_000 {
            waker.wake(); // fills the pipe; later wakes hit WouldBlock
        }
        drain_wakeups(&rx);
        drop(rx);
        waker.wake(); // EPIPE after the loop exits — still must not panic
    }
}
